"""Autopilot CLI — run / inspect / override the continuous-deployment loop.

    # seed an incumbent first (any emit works):
    PYTHONPATH=src python -m repro.evolve --dataset breast_cancer \\
        --emit-dir runs/fleet --epochs 1 --islands 2 --pop 12

    # then let the autopilot keep improving + shadow-verifying it:
    PYTHONPATH=src python -m repro.autopilot run --emit-dir runs/fleet \\
        --tenant tnn_breast_cancer --dataset breast_cancer --rounds 2

`run` drives the full loop in-process: campaign epochs against (optionally
drifting) data, candidate staging under ``<emit-dir>/candidates/``, shadow
deployment on mirrored live traffic, and journaled promote/rollback
decisions (``<emit-dir>/autopilot_journal.jsonl``).  `--port` additionally
serves the fleet over the wire protocol while the loop runs, so STATS /
LIST show the shadow and deploy identity live.  Re-running after a crash
resumes mid-rollout from the journal.  `status` summarizes the journal;
`promote`/`rollback` are operator overrides for a *stopped* controller.
"""
from __future__ import annotations

import argparse
import json
from pathlib import Path

from repro.autopilot.controller import (Autopilot, AutopilotConfig,
                                        CampaignSource, PromotionPolicy,
                                        dataset_traffic)
from repro.autopilot.journal import DecisionJournal
from repro.compile import artifact as A
from repro.serve.fleet import ClassifierFleet


def _parse_args(argv=None) -> argparse.Namespace:
    ap = argparse.ArgumentParser(prog="python -m repro.autopilot",
                                 description=__doc__)
    sub = ap.add_subparsers(dest="cmd", required=True)

    run = sub.add_parser("run", help="drive the evolve→shadow→promote loop")
    run.add_argument("--emit-dir", required=True)
    run.add_argument("--tenant", required=True,
                     help="incumbent manifest tenant to keep improving")
    run.add_argument("--dataset", required=True,
                     help="dataset for the campaign + mirrored traffic")
    run.add_argument("--rounds", type=int, default=2)
    run.add_argument("--journal", default=None,
                     help="decision journal path (default: "
                          "<emit-dir>/autopilot_journal.jsonl)")
    run.add_argument("--out", default=None,
                     help="write a JSON report of round outcomes here")
    # serving
    run.add_argument("--serve-backend", default="np",
                     choices=("np", "swar", "pallas"))
    run.add_argument("--replicas", type=int, default=1)
    run.add_argument("--port", type=int, default=None,
                     help="also serve the fleet over TCP while running")
    run.add_argument("--shards", type=int, default=1)
    # mirrored-traffic verdict
    run.add_argument("--mirror-pairs", type=int, default=96)
    run.add_argument("--traffic-batch", type=int, default=32)
    run.add_argument("--verdict-timeout-s", type=float, default=120.0)
    run.add_argument("--min-pairs", type=int, default=64)
    run.add_argument("--min-agreement", type=float, default=0.98)
    run.add_argument("--min-truth", type=int, default=32)
    run.add_argument("--accuracy-margin", type=float, default=0.0)
    run.add_argument("--max-latency-factor", type=float, default=None)
    # campaign budgets (examples-scale defaults, cf. repro.evolve)
    run.add_argument("--islands", type=int, default=2)
    run.add_argument("--pop", type=int, default=12)
    run.add_argument("--gens-per-epoch", type=int, default=2)
    run.add_argument("--epochs-per-round", type=int, default=1)
    run.add_argument("--migrate-k", type=int, default=2)
    run.add_argument("--seed", type=int, default=0)
    run.add_argument("--eval-backend", default="np",
                     choices=("np", "swar", "pallas"))
    run.add_argument("--tnn-epochs", type=int, default=8)
    run.add_argument("--cgp-iters", type=int, default=150)
    run.add_argument("--cgp-points", type=int, default=2)
    run.add_argument("--pcc-samples", type=int, default=6000)
    run.add_argument("--phase-cache", default=None,
                     help="Phase-1/2 product cache dir (default: "
                          "$REPRO_PHASE_CACHE or ~/.cache/repro/"
                          "phase_cache); restarted controllers skip the "
                          "TNN/CGP/PCC rebuild entirely")
    run.add_argument("--drift-rate", type=float, default=0.0,
                     help="fraction of the objective's sample plane "
                          "bootstrap-resampled each round (0 = static data)")
    run.add_argument("--no-require-improvement", action="store_true",
                     help="shadow-verify every round's winner even when the "
                          "campaign objective did not improve")
    # drills / debug
    run.add_argument("--sabotage-round", type=int, action="append",
                     default=[],
                     help="deliberately break this round's candidate "
                          "(rollback drill; repeatable)")
    run.add_argument("--kill-after", default=None, metavar="STAGE:ROUND",
                     help="debug: SIGKILL self right after journaling this "
                          "stage (candidate|shadow|verdict|decision)")

    st = sub.add_parser("status", help="summarize the decision journal")
    st.add_argument("--emit-dir", required=True)
    st.add_argument("--journal", default=None)
    st.add_argument("--json", action="store_true")

    pr = sub.add_parser("promote", help="operator override: promote a "
                                        "staged candidate (stopped "
                                        "controller only)")
    pr.add_argument("--emit-dir", required=True)
    pr.add_argument("--journal", default=None)
    pr.add_argument("--round", type=int, required=True)

    rb = sub.add_parser("rollback", help="operator override: close an open "
                                         "round as rolled back")
    rb.add_argument("--emit-dir", required=True)
    rb.add_argument("--journal", default=None)
    rb.add_argument("--round", type=int, required=True)
    return ap.parse_args(argv)


def _journal_for(args) -> DecisionJournal:
    path = args.journal or (Path(args.emit_dir) / "autopilot_journal.jsonl")
    return DecisionJournal(path)


def _baseline_obj(emit_dir: Path, tenant: str) -> float | None:
    """Incumbent's recorded objective-0 (campaign provenance), if any."""
    try:
        rows = {r["name"]: r for r in A.load_manifest(emit_dir)}
        objectives = rows[tenant].get("provenance", {}).get("objectives")
        return float(objectives[0]) if objectives else None
    except (FileNotFoundError, KeyError, TypeError, ValueError):
        return None


def _cmd_run(args) -> int:
    from repro.evolve.campaign import Campaign
    from repro.evolve.config import CampaignConfig
    from repro.evolve.problems import attach_tnn_drift, build_tnn_problem

    emit_dir = Path(args.emit_dir)
    journal = _journal_for(args)
    kill_after = None
    if args.kill_after:
        stage, _, rnd = args.kill_after.partition(":")
        kill_after = (stage, int(rnd))

    problem = build_tnn_problem(args.dataset, seed=args.seed,
                                epochs=args.tnn_epochs,
                                cgp_points=args.cgp_points,
                                cgp_iters=args.cgp_iters,
                                pcc_samples=args.pcc_samples,
                                eval_backend=args.eval_backend,
                                cache_dir=args.phase_cache)
    if args.drift_rate > 0.0:
        attach_tnn_drift(problem, args.drift_rate, seed=args.seed)
    cfg = CampaignConfig(n_islands=args.islands, pop_size=args.pop,
                         n_epochs=args.rounds * args.epochs_per_round,
                         gens_per_epoch=args.gens_per_epoch,
                         migrate_k=args.migrate_k, seed=args.seed,
                         eval_backend=args.eval_backend)
    campaign = Campaign(problem.domains, problem.objective, cfg,
                        checkpoint_dir=str(emit_dir / "autopilot_ckpt"
                                           / args.tenant),
                        seed_population=problem.seed_population,
                        name=problem.name)
    source = CampaignSource(
        problem, campaign, epochs_per_round=args.epochs_per_round,
        baseline_obj=_baseline_obj(emit_dir, args.tenant),
        require_improvement=not args.no_require_improvement)

    policy = PromotionPolicy(
        min_pairs=args.min_pairs, min_agreement=args.min_agreement,
        min_truth=args.min_truth, accuracy_margin=args.accuracy_margin,
        max_latency_factor=args.max_latency_factor)
    cfg_ap = AutopilotConfig(
        tenant=args.tenant, rounds=args.rounds,
        mirror_pairs=args.mirror_pairs, traffic_batch=args.traffic_batch,
        verdict_timeout_s=args.verdict_timeout_s,
        shadow_replicas=args.replicas, policy=policy,
        sabotage_rounds=frozenset(args.sabotage_round),
        kill_after=kill_after)

    server = None
    fleet = ClassifierFleet.from_emit_dir(
        emit_dir, backends=args.serve_backend, replicas=args.replicas)
    try:
        if args.port is not None:
            from repro.serve.server import FleetServer
            server = FleetServer(fleet, port=args.port, shards=args.shards)
            host, port = server.start_background()
            print(f"autopilot: fleet served on {host}:{port} "
                  f"({args.shards} shard(s))", flush=True)
        traffic = dataset_traffic(args.dataset, batch=args.traffic_batch,
                                  seed=args.seed)
        pilot = Autopilot(
            fleet, source, traffic, journal, cfg_ap,
            on_event=lambda ev: print(
                f"autopilot: [round {ev.get('round', '-')}] {ev['event']}"
                + (f" -> {ev['action']} ({ev['reason']})"
                   if ev["event"] == "decision" else ""), flush=True))
        outcomes = pilot.run()
        generation = int(A.load_manifest_doc(emit_dir)["generation"])
        n_promoted = sum(o["event"] == "promoted" for o in outcomes)
        print(f"autopilot: {len(outcomes)} round(s) decided, "
              f"{n_promoted} promoted; manifest generation {generation}",
              flush=True)
        if args.out:
            Path(args.out).parent.mkdir(parents=True, exist_ok=True)
            Path(args.out).write_text(json.dumps(
                {"tenant": args.tenant, "rounds": args.rounds,
                 "outcomes": outcomes, "generation": generation},
                indent=2, sort_keys=True) + "\n")
            print(f"wrote {args.out}", flush=True)
    finally:
        if server is not None:
            server.stop()
        fleet.shutdown(drain=False)
    return 0


def _round_states(journal: DecisionJournal) -> dict[int, dict]:
    states = {}
    for r, events in sorted(journal.rounds().items()):
        latest = events[-1]
        state = {"stage": latest["event"]}
        for ev in events:
            if ev["event"] == "candidate":
                state["candidate"] = ev["name"]
                state["sha256"] = ev["sha256"]
            elif ev["event"] == "decision":
                state["action"] = ev["action"]
                state["reason"] = ev["reason"]
            elif ev["event"] == "promoted":
                state["generation"] = ev["generation"]
        states[r] = state
    return states


def _cmd_status(args) -> int:
    journal = _journal_for(args)
    states = _round_states(journal)
    try:
        generation = int(A.load_manifest_doc(args.emit_dir)["generation"])
    except FileNotFoundError:
        generation = None
    if args.json:
        print(json.dumps({"generation": generation,
                          "rounds": {str(r): s for r, s in states.items()}},
                         indent=2, sort_keys=True))
        return 0
    print(f"manifest generation: {generation}")
    if not states:
        print("journal: no rounds recorded")
    for r, s in states.items():
        line = f"round {r}: {s['stage']}"
        if "candidate" in s:
            line += f"  candidate={s['candidate']}"
        if "action" in s:
            line += f"  action={s['action']} ({s['reason']})"
        if "generation" in s:
            line += f"  generation={s['generation']}"
        print(line)
    return 0


def _open_round(journal: DecisionJournal, r: int) -> dict:
    events = journal.rounds().get(r)
    if not events:
        raise SystemExit(f"round {r} has no journal entries")
    by_event = {ev["event"]: ev for ev in events}
    for terminal in ("promoted", "rolled_back", "held", "no_candidate"):
        if terminal in by_event:
            raise SystemExit(f"round {r} already closed: {terminal}")
    if "candidate" not in by_event:
        raise SystemExit(f"round {r} has no staged candidate")
    return by_event["candidate"]


def _cmd_promote(args) -> int:
    emit_dir = Path(args.emit_dir)
    journal = _journal_for(args)
    cand = _open_round(journal, args.round)
    tenant = cand["name"].rsplit("__cand_r", 1)[0]
    rows = {r["name"]: r for r in A.load_manifest(emit_dir)}
    incumbent = rows.get(tenant, {})
    A.register_tenant(emit_dir, {
        "name": tenant,
        "program": str(emit_dir / cand["program"]),
        "dataset": cand.get("dataset") or incumbent.get("dataset"),
        "n_features": cand["n_features"],
        "n_classes": cand["n_classes"],
        "replicas": incumbent.get("replicas", 1),
        "sha256": cand["sha256"],
        "provenance": dict(cand.get("provenance", {})),
    })
    generation = int(A.load_manifest_doc(emit_dir)["generation"])
    journal.append("promoted", round=args.round, candidate=cand["name"],
                   sha256=cand["sha256"], generation=generation,
                   operator=True)
    print(f"promoted {cand['name']} -> tenant {tenant!r} "
          f"(manifest generation {generation}); watching fleets pick it up "
          "on their next sync")
    return 0


def _cmd_rollback(args) -> int:
    journal = _journal_for(args)
    cand = _open_round(journal, args.round)
    journal.append("rolled_back", round=args.round, candidate=cand["name"],
                   reason="operator rollback", operator=True)
    print(f"rolled back round {args.round} ({cand['name']}); the incumbent "
          "row is untouched")
    return 0


def main(argv=None) -> int:
    args = _parse_args(argv)
    return {"run": _cmd_run, "status": _cmd_status,
            "promote": _cmd_promote, "rollback": _cmd_rollback}[args.cmd](args)


if __name__ == "__main__":
    raise SystemExit(main())
