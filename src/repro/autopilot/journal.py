"""Append-only decision journal — the autopilot's crash-safe memory.

Every step of a rollout round (candidate staged, shadow deployed, verdict
evidence, decision, terminal outcome) is appended as one JSON line and
fsynced before the controller acts on it — *journal first, act second*.
That ordering is what makes the continuous-deployment loop resumable: a
controller SIGKILLed between accumulating verdict evidence and executing
the promotion restarts, replays the journal, and recomputes the same
decision from the journaled evidence (`repro.autopilot.controller.decide`
is a pure function of the journaled summary), instead of re-measuring a
different sample of traffic and possibly flipping the call.

Replay is tolerant of exactly one torn tail line (a crash mid-append);
anything else malformed raises, because a journal that lies about
promotions is worse than no journal at all.
"""
from __future__ import annotations

import json
import os
import time
from pathlib import Path


class JournalCorruptError(RuntimeError):
    """A non-tail journal line failed to parse — history is untrustworthy."""


class DecisionJournal:
    """Append-only JSONL of autopilot events, fsynced per append."""

    def __init__(self, path: str | Path):
        self.path = Path(path)
        self.path.parent.mkdir(parents=True, exist_ok=True)
        self._seq = 0
        for ev in self.replay():            # continue the sequence numbers
            self._seq = max(self._seq, int(ev.get("seq", 0)))

    def append(self, event: str, **fields) -> dict:
        """Durably record one event; returns the full row as written."""
        self._seq += 1
        row = {"seq": self._seq, "event": event,
               "t": round(time.time(), 3), **fields}
        line = json.dumps(row, sort_keys=True) + "\n"
        with open(self.path, "a") as f:
            f.write(line)
            f.flush()
            os.fsync(f.fileno())
        return row

    def replay(self) -> list[dict]:
        """All durable events, in order.

        A torn final line (crash mid-append) is dropped — the event it
        would have recorded never governed any action, because actions
        only ever follow a *successful* append.  A malformed line
        anywhere else raises `JournalCorruptError`.
        """
        if not self.path.exists():
            return []
        lines = self.path.read_text().splitlines()
        events: list[dict] = []
        for i, line in enumerate(lines):
            if not line.strip():
                continue
            try:
                events.append(json.loads(line))
            except json.JSONDecodeError:
                if i == len(lines) - 1:
                    break                    # torn tail from a crash
                raise JournalCorruptError(
                    f"{self.path}: line {i + 1} is not valid JSON (only the "
                    "final line may be torn)") from None
        return events

    def rounds(self) -> dict[int, list[dict]]:
        """Events grouped by rollout round (events without a round skipped)."""
        by_round: dict[int, list[dict]] = {}
        for ev in self.replay():
            if "round" in ev:
                by_round.setdefault(int(ev["round"]), []).append(ev)
        return by_round
