"""repro.autopilot — continuous evolve→compile→shadow-deploy→promote loop.

The controller (`Autopilot`) keeps a per-tenant evolution `Campaign`
searching, stages every improved winner as a provenance-stamped candidate
bundle, shadow-deploys it against the live `ClassifierFleet` on mirrored
traffic, and promotes or rolls back from the `ShadowComparator` evidence
— journaling every step so a killed controller resumes mid-rollout to
the same decision.  CLI: ``python -m repro.autopilot {run,status,promote,
rollback}``.
"""
from repro.autopilot.controller import (Autopilot, AutopilotConfig,
                                        CampaignSource, Candidate,
                                        PromotionPolicy, ScriptedSource,
                                        dataset_traffic, decide,
                                        sabotage_classifier)
from repro.autopilot.journal import DecisionJournal, JournalCorruptError

__all__ = [
    "Autopilot", "AutopilotConfig", "CampaignSource", "Candidate",
    "DecisionJournal", "JournalCorruptError", "PromotionPolicy",
    "ScriptedSource", "dataset_traffic", "decide", "sabotage_classifier",
]
