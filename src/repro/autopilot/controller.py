"""The autopilot controller: evolve → compile → shadow-deploy → promote.

One `Autopilot` closes the loop the rest of the repo builds in pieces: a
resumable evolution `Campaign` keeps searching against (optionally
drifting) data, every improved Pareto winner is lowered through
`repro.compile`, staged in the emit dir's ``candidates/`` sub-manifest
with full provenance, and deployed to the live `ClassifierFleet` as a
**shadow replica** of the incumbent tenant.  The fleet mirrors admitted
traffic to the shadow; a `ShadowComparator` accumulates agreement /
accuracy / latency evidence; and when enough mirrored pairs have scored,
`decide` turns the journaled evidence into a verdict:

  * **promote** — the candidate row is registered under the incumbent's
    name (one atomic manifest write that bumps the generation counter)
    and `sync_manifest()` swaps it into the serving slot without dropping
    a queued request;
  * **rollback** — the shadow is retired; the incumbent never noticed.

Every stage is journaled *before* it acts (`journal.py`), so a controller
SIGKILLed anywhere mid-rollout resumes to the same decision: evidence
already journaled is never re-measured, and `decide` is a pure function
of the journaled summary.
"""
from __future__ import annotations

import dataclasses
import os
import signal
import time
from dataclasses import dataclass, field
from pathlib import Path
from typing import Callable, Iterator, Protocol

import numpy as np

from repro.compile import artifact as A
from repro.compile.ir import CircuitIR, CompiledClassifier
from repro.hw.egfet import Gate
from repro.serve.fleet import ClassifierFleet, TenantSpec
from repro.autopilot.journal import DecisionJournal

TERMINAL_EVENTS = ("promoted", "rolled_back", "held", "no_candidate")
STAGES = ("candidate", "shadow", "verdict", "decision")
CANDIDATES_SUBDIR = "candidates"


# -- promotion policy --------------------------------------------------------
@dataclass(frozen=True)
class PromotionPolicy:
    """Thresholds `decide` applies to a comparator summary.

    Accuracy is the primary signal when the traffic source supplied
    ground truth (`min_truth` labeled pairs): an *improved* candidate
    legitimately disagrees with the incumbent, so raw agreement must not
    veto it.  Without enough labeled pairs the policy falls back to
    bit-agreement, where anything under `min_agreement` is treated as a
    broken artifact.  `max_latency_factor` (off by default — mirrored
    queues share machines with the incumbent, so wall-clock deltas are
    noisy at test scale) bounds shadow p50 as a multiple of incumbent p50.
    """

    min_pairs: int = 64
    min_agreement: float = 0.98
    min_truth: int = 32
    accuracy_margin: float = 0.0
    max_latency_factor: float | None = None


def decide(summary: dict, policy: PromotionPolicy) -> tuple[str, str]:
    """Pure verdict over a journaled comparator summary.

    Returns ``(action, reason)`` with action one of ``promote`` /
    ``rollback`` / ``hold``.  Purity is a resume guarantee, not a style
    choice: re-running this on the same journaled summary must reproduce
    the same decision (pinned by tests/test_autopilot.py).
    """
    n = summary["n_pairs"]
    if summary.get("n_shadow_errors", 0) > 0:
        return "rollback", (f"shadow erred on {summary['n_shadow_errors']} "
                            "mirrored request(s)")
    if n < policy.min_pairs:
        return "hold", f"only {n}/{policy.min_pairs} scored pairs"
    if policy.max_latency_factor is not None:
        inc_p50 = summary.get("incumbent_p50_ms") or 0.0
        sh_p50 = summary.get("shadow_p50_ms") or 0.0
        if inc_p50 > 0.0 and sh_p50 > policy.max_latency_factor * inc_p50:
            return "rollback", (
                f"shadow p50 {sh_p50:.3f} ms exceeds "
                f"{policy.max_latency_factor}x incumbent p50 "
                f"{inc_p50:.3f} ms")
    if summary.get("n_truth", 0) >= policy.min_truth:
        inc_acc = summary["incumbent_accuracy"]
        sh_acc = summary["shadow_accuracy"]
        if sh_acc + 1e-12 >= inc_acc + policy.accuracy_margin:
            return "promote", (
                f"shadow accuracy {sh_acc:.4f} >= incumbent {inc_acc:.4f} "
                f"+ margin {policy.accuracy_margin} on "
                f"{summary['n_truth']} labeled pairs")
        return "rollback", (
            f"shadow accuracy {sh_acc:.4f} < incumbent {inc_acc:.4f} "
            f"+ margin {policy.accuracy_margin}")
    if summary["agreement"] >= policy.min_agreement:
        return "promote", (f"agreement {summary['agreement']:.4f} >= "
                           f"{policy.min_agreement} on {n} pairs "
                           "(no ground truth)")
    return "rollback", (f"agreement {summary['agreement']:.4f} < "
                        f"{policy.min_agreement} and no ground truth "
                        "to justify the disagreement")


# -- candidate sources -------------------------------------------------------
@dataclass
class Candidate:
    """One compiled design a source proposes for shadow verification."""

    cc: CompiledClassifier
    objectives: list[float]
    provenance: dict
    dataset: str | None = None


class CandidateSource(Protocol):
    def next_candidate(self, round_idx: int) -> Candidate | None: ...


class ScriptedSource:
    """Fixed per-round candidates — the deterministic test harness.

    Indexed by round (not consumed), so a resumed controller that skips
    an already-journaled round still sees the same candidate for the
    rounds it re-enters.
    """

    def __init__(self, candidates: list[Candidate | None]):
        self._candidates = list(candidates)

    def next_candidate(self, round_idx: int) -> Candidate | None:
        if round_idx < len(self._candidates):
            return self._candidates[round_idx]
        return None


class CampaignSource:
    """Steps a resumable `Campaign` and surfaces improved Pareto winners.

    Each round: apply the problem's drift hook (fresh data — and clear
    the campaign's memoized fitness cache, which is stale the moment the
    sample plane moves), run `epochs_per_round` checkpointed epochs, and
    lower the archive's best objective-0 chromosome iff it improved on
    the best already emitted (`require_improvement=False` emits every
    round's winner — useful when the incumbent's objective is unknown).
    """

    def __init__(self, problem, campaign, *, epochs_per_round: int = 1,
                 min_improve: float = 0.0, baseline_obj: float | None = None,
                 require_improvement: bool = True):
        self.problem = problem
        self.campaign = campaign
        self.epochs_per_round = epochs_per_round
        self.min_improve = min_improve
        self.best_obj = baseline_obj
        self.require_improvement = require_improvement

    def next_candidate(self, round_idx: int) -> Candidate | None:
        from repro.evolve.problems import compile_archive_winner

        if self.problem.drift is not None:
            self.problem.drift(round_idx)
            # mark_drift (not bare clear_eval_cache): with a parallel
            # campaign the executor's workers must replay this round on
            # their own problem copies before stepping again
            self.campaign.mark_drift(round_idx)
        epoch = None
        for _ in range(self.epochs_per_round):
            epoch = self.campaign.step_epoch()
        x, f = self.campaign.best_by_objective(0)
        obj0 = float(f[0])
        if (self.require_improvement and self.best_obj is not None
                and obj0 >= self.best_obj - self.min_improve):
            return None
        self.best_obj = obj0
        cc = compile_archive_winner(self.problem, x)
        cfg = self.campaign.cfg
        return Candidate(
            cc=cc,
            objectives=[float(v) for v in f],
            provenance={
                "seed": cfg.seed,
                "islands": cfg.n_islands,
                "pop_size": cfg.pop_size,
                "generations": (epoch + 1) * cfg.gens_per_epoch,
                "objectives": [float(v) for v in f],
                "config_fingerprint": self.campaign.fingerprint(),
                "backend": cfg.eval_backend,
                "drift_round": (round_idx if self.problem.drift is not None
                                else None),
            },
            dataset=(self.problem.dataset.name
                     if self.problem.dataset is not None else None))


# -- traffic + sabotage ------------------------------------------------------
def dataset_traffic(dataset, batch: int = 32,
                    seed: int = 0) -> Iterator[tuple[np.ndarray, np.ndarray]]:
    """Deterministic infinite `(X, y)` batches from a dataset's test split."""
    if isinstance(dataset, str):
        from repro.data.tabular import make_dataset
        dataset = make_dataset(dataset)
    X = np.asarray(dataset.x_test, dtype=np.float64)
    y = np.asarray(dataset.y_test, dtype=np.int64)
    rng = np.random.default_rng(seed)
    while True:
        idx = rng.integers(0, X.shape[0], size=batch)
        yield X[idx], y[idx]


def sabotage_classifier(cc: CompiledClassifier) -> CompiledClassifier:
    """Deterministically break a classifier: NOT-gate the label's LSB.

    Appending one NOT gate rewired over ``outputs[0]`` flips the low bit
    of *every* predicted class index, so the sabotaged design disagrees
    with the original on 100% of inputs — a worst-case bad artifact for
    rollback drills (probabilistic corruptions like threshold jitter can
    accidentally still agree).  The IR stays levelized and feed-forward,
    so it lowers, saves, and serves like any legitimate candidate.
    """
    ir = cc.ir
    node = ir.n_inputs + ir.n_gates
    src = np.int32(ir.outputs[0])
    outputs = ir.outputs.copy()
    outputs[0] = node
    lvl = (int(ir.levels.max()) + 1) if ir.n_gates else 1
    ir2 = CircuitIR(
        n_inputs=ir.n_inputs,
        op=np.append(ir.op, np.int16(Gate.NOT)).astype(np.int16),
        in0=np.append(ir.in0, src).astype(np.int32),
        in1=np.append(ir.in1, src).astype(np.int32),
        outputs=outputs.astype(np.int32),
        levels=np.append(ir.levels, np.int32(lvl)).astype(np.int32),
        taps={k: v.copy() for k, v in ir.taps.items()},
        name=(ir.name or "classifier") + "_sabotaged",
        meta=dict(ir.meta))
    ir2.to_netlist()                    # still a valid feed-forward circuit
    return dataclasses.replace(cc, ir=ir2,
                               name=(cc.name or "classifier") + "_sabotaged")


# -- the controller ----------------------------------------------------------
@dataclass
class AutopilotConfig:
    tenant: str                          # incumbent tenant to improve
    rounds: int = 1
    mirror_pairs: int = 128              # scored pairs needed per verdict
    traffic_batch: int = 32
    verdict_timeout_s: float = 120.0
    shadow_backend: str | None = None    # default: incumbent's backend
    shadow_replicas: int = 1
    shadow_max_queue: int | None = 1024
    policy: PromotionPolicy = field(default_factory=PromotionPolicy)
    sabotage_rounds: frozenset = frozenset()
    # debug hook for resume tests: SIGKILL self right after journaling
    # stage (one of STAGES) of the given round
    kill_after: tuple[str, int] | None = None


class Autopilot:
    """Drives rollout rounds against one live fleet, journaling each step."""

    def __init__(self, fleet: ClassifierFleet, source: CandidateSource,
                 traffic: Iterator[tuple[np.ndarray, np.ndarray]],
                 journal: DecisionJournal, cfg: AutopilotConfig,
                 on_event: Callable[[dict], None] | None = None):
        if fleet._manifest_ctx is None:
            raise ValueError("autopilot needs a fleet built by "
                             "ClassifierFleet.from_emit_dir (promotion is a "
                             "manifest write + sync)")
        if cfg.tenant not in fleet._tenants:
            raise KeyError(f"incumbent tenant {cfg.tenant!r} is not served "
                           f"by this fleet (serving: "
                           f"{', '.join(fleet.tenants)})")
        self.fleet = fleet
        self.source = source
        self.traffic = traffic
        self.journal = journal
        self.cfg = cfg
        self.emit_dir = Path(fleet._manifest_ctx["emit_dir"])
        self._on_event = on_event

    # -- lifecycle -----------------------------------------------------------
    def run(self) -> list[dict]:
        """Run (or resume) every configured round; returns terminal events."""
        outcomes = []
        for r in range(self.cfg.rounds):
            out = self.run_round(r)
            if out is not None:
                outcomes.append(out)
        return outcomes

    def run_round(self, r: int) -> dict | None:
        """One rollout round, resuming mid-round from the journal.

        Already-journaled stages are *reused*, never re-executed:
        evidence measured before a crash governs the decision after it.
        """
        events = {}
        for ev in self.journal.rounds().get(r, []):
            events[ev["event"]] = ev        # last occurrence wins
        for terminal in TERMINAL_EVENTS:
            if terminal in events:
                return events[terminal]

        cand = events.get("candidate")
        if cand is None:
            candidate = self.source.next_candidate(r)
            if candidate is None:
                return self._journal("no_candidate", round=r)
            if r in self.cfg.sabotage_rounds:
                candidate = dataclasses.replace(
                    candidate, cc=sabotage_classifier(candidate.cc),
                    provenance={**candidate.provenance, "sabotaged": True})
            cand = self._stage_candidate(r, candidate)
        self._maybe_kill("candidate", r)

        verdict = events.get("verdict")
        if verdict is None:
            summary = self._shadow_and_measure(r, cand)
            verdict = self._journal("verdict", round=r, summary=summary)
        self._maybe_kill("verdict", r)

        decision = events.get("decision")
        if decision is None:
            action, reason = decide(verdict["summary"], self.cfg.policy)
            decision = self._journal("decision", round=r, action=action,
                                     reason=reason)
        self._maybe_kill("decision", r)

        return self._execute(r, cand, decision)

    # -- stages --------------------------------------------------------------
    def _journal(self, event: str, **fields) -> dict:
        row = self.journal.append(event, **fields)
        if self._on_event is not None:
            self._on_event(row)
        return row

    def _maybe_kill(self, stage: str, r: int) -> None:
        if self.cfg.kill_after == (stage, r):
            os.kill(os.getpid(), signal.SIGKILL)

    def _stage_candidate(self, r: int, candidate: Candidate) -> dict:
        """Lower the candidate into ``<emit_dir>/candidates/`` and journal it.

        The staging area is its own manifest directory, so candidates are
        registered with full provenance *without* becoming routable rows
        of the serving manifest — only a promotion writes those.
        """
        base = f"{self.cfg.tenant}__cand_r{r}"
        cand_dir = self.emit_dir / CANDIDATES_SUBDIR
        cand_dir.mkdir(parents=True, exist_ok=True)
        ppath = cand_dir / f"{base}{A.PROGRAM_SUFFIX}"
        A.save_program(candidate.cc, ppath)
        sha = ppath.with_name(ppath.name + A.SHA_SUFFIX).read_text().strip()
        cc = candidate.cc
        A.register_tenant(cand_dir, {
            "name": base,
            "program": str(ppath),
            "dataset": candidate.dataset,
            "n_features": cc.n_features,
            "n_classes": cc.n_classes,
            "n_gates": cc.ir.n_gates,
            "replicas": self.cfg.shadow_replicas,
            "sha256": sha,
            "provenance": dict(candidate.provenance),
        })
        return self._journal(
            "candidate", round=r, name=base,
            program=str(ppath.relative_to(self.emit_dir)), sha256=sha,
            objectives=candidate.objectives, dataset=candidate.dataset,
            n_features=cc.n_features, n_classes=cc.n_classes,
            provenance=dict(candidate.provenance))

    def _shadow_and_measure(self, r: int, cand: dict) -> dict:
        """Deploy the staged candidate as a shadow and mirror traffic at it
        until the comparator has `mirror_pairs` scored pairs (or the
        verdict timeout lapses — the policy then holds/rolls back on
        whatever evidence exists)."""
        from repro.compile.artifact import load_program

        of = self.cfg.tenant
        shadow_name = f"{of}!shadow"
        if of in self.fleet._shadows:
            comp = self.fleet.shadow_comparator(of)
        else:
            backend = self.cfg.shadow_backend or self.fleet.tenant_backend(of)
            program = load_program(self.emit_dir / cand["program"],
                                   backend=backend,
                                   expect_sha256=cand["sha256"])
            # best_effort: mirrored traffic yields scheduling priority to
            # every serving tenant; shadows are additionally invisible to
            # the fleet autoscaler (it never resizes a shadow pool — that
            # would skew the very comparison this deploy exists to make)
            spec = TenantSpec(
                name=shadow_name, program=program, backend=backend,
                replicas=self.cfg.shadow_replicas,
                max_queue=self.cfg.shadow_max_queue,
                qos="best_effort",
                dataset=cand.get("dataset"), sha256=cand["sha256"],
                meta={"candidate": cand["name"]})
            comp = self.fleet.deploy_shadow(spec, of)
            self._journal("shadow_deployed", round=r, name=shadow_name,
                          candidate=cand["name"], sha256=cand["sha256"])
        self._maybe_kill("shadow", r)
        deadline = time.monotonic() + self.cfg.verdict_timeout_s
        while comp.n_pairs < self.cfg.mirror_pairs:
            if time.monotonic() > deadline:
                break
            X, y = next(self.traffic)
            reqs, _, _ = self.fleet.submit_many(of, X)
            for req, label in zip(reqs, y):
                comp.attach_truth(req.uid, int(label))
            self.fleet.flush(timeout=self.cfg.verdict_timeout_s)
        return comp.summary()

    def _execute(self, r: int, cand: dict, decision: dict) -> dict:
        action = decision["action"]
        of = self.cfg.tenant
        if action == "promote":
            if of in self.fleet._shadows:   # absent after a crash-resume
                self.fleet.retire_shadow(of)
            generation = self._register_promotion(cand)
            actions = self.fleet.sync_manifest()
            return self._journal("promoted", round=r, candidate=cand["name"],
                                 sha256=cand["sha256"],
                                 generation=generation,
                                 replaced=actions["replaced"])
        if of in self.fleet._shadows:
            self.fleet.retire_shadow(of)
        event = "rolled_back" if action == "rollback" else "held"
        return self._journal(event, round=r, candidate=cand["name"],
                             reason=decision["reason"])

    def _register_promotion(self, cand: dict) -> int:
        """One atomic manifest write: the staged candidate becomes the
        incumbent's row, bumping the generation counter the fleet's
        replace machinery keys on.  Needs only journaled facts + staged
        files, so a resumed controller can re-execute it without the
        in-memory `CompiledClassifier`."""
        of = self.cfg.tenant
        incumbent = self.fleet._tenant(of)
        A.register_tenant(self.emit_dir, {
            "name": of,
            "program": str(self.emit_dir / cand["program"]),
            "dataset": cand.get("dataset") or incumbent.spec.dataset,
            "n_features": cand["n_features"],
            "n_classes": cand["n_classes"],
            "replicas": incumbent.pool.size,
            "sha256": cand["sha256"],
            "provenance": dict(cand.get("provenance", {})),
        })
        return int(A.load_manifest_doc(self.emit_dir)["generation"])
