from repro.optim.adamw import (  # noqa: F401
    AdamWConfig,
    AdamWState,
    apply_updates,
    global_norm,
    init,
    schedule,
)
