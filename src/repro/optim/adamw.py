"""AdamW with warmup-cosine schedule — pure-JAX, pytree-native.

No optax in this environment, so the framework carries its own optimizer.
States are stored as a pytree congruent with params, so the distributed
layer can shard them with the same partition rules as the parameters
(ZeRO-1: see `repro.launch.train` which additionally shards the states'
FSDP dim over the data axis).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jnp.ndarray          # scalar int32
    mu: Any                    # first moment, pytree like params
    nu: Any                    # second moment, pytree like params


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 1e-3
    b1: float = 0.9
    b2: float = 0.999
    eps: float = 1e-8
    weight_decay: float = 0.0
    grad_clip: float | None = 1.0
    warmup_steps: int = 0
    total_steps: int | None = None     # enables cosine decay when set
    min_lr_ratio: float = 0.1


def schedule(cfg: AdamWConfig, step: jnp.ndarray) -> jnp.ndarray:
    """Linear warmup + (optional) cosine decay to min_lr_ratio * lr."""
    step = step.astype(jnp.float32)
    lr = jnp.asarray(cfg.lr, jnp.float32)
    if cfg.warmup_steps > 0:
        warm = jnp.minimum(1.0, (step + 1.0) / cfg.warmup_steps)
    else:
        warm = 1.0
    if cfg.total_steps is not None:
        frac = jnp.clip((step - cfg.warmup_steps)
                        / max(1, cfg.total_steps - cfg.warmup_steps), 0.0, 1.0)
        cos = 0.5 * (1.0 + jnp.cos(jnp.pi * frac))
        decay = cfg.min_lr_ratio + (1.0 - cfg.min_lr_ratio) * cos
    else:
        decay = 1.0
    return lr * warm * decay


def init(params: Any) -> AdamWState:
    zeros = jax.tree.map(lambda p: jnp.zeros_like(p, dtype=jnp.float32), params)
    return AdamWState(step=jnp.zeros((), jnp.int32), mu=zeros,
                      nu=jax.tree.map(jnp.copy, zeros))


def global_norm(tree: Any) -> jnp.ndarray:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in leaves))


def apply_updates(params: Any, grads: Any, state: AdamWState,
                  cfg: AdamWConfig) -> tuple[Any, AdamWState]:
    """One AdamW step.  Returns (new_params, new_state)."""
    step = state.step + 1
    if cfg.grad_clip is not None:
        gnorm = global_norm(grads)
        scale = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))
        grads = jax.tree.map(lambda g: g * scale, grads)

    b1, b2 = cfg.b1, cfg.b2
    mu = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g.astype(jnp.float32),
                      state.mu, grads)
    nu = jax.tree.map(lambda v, g: b2 * v + (1 - b2) * jnp.square(g.astype(jnp.float32)),
                      state.nu, grads)
    stepf = step.astype(jnp.float32)
    mu_hat_scale = 1.0 / (1.0 - b1 ** stepf)
    nu_hat_scale = 1.0 / (1.0 - b2 ** stepf)
    lr = schedule(cfg, state.step)

    def upd(p, m, v):
        u = (m * mu_hat_scale) / (jnp.sqrt(v * nu_hat_scale) + cfg.eps)
        if cfg.weight_decay:
            u = u + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * u).astype(p.dtype)

    new_params = jax.tree.map(upd, params, mu, nu)
    return new_params, AdamWState(step=step, mu=mu, nu=nu)
