"""Block-wise int8 AdamW moments (8-bit-optimizer style).

Why: arctic-480b on one 256-chip v5e pod cannot hold f32 Adam moments
(480e9 * 8 B = 3.8 TB ~ the whole pod's HBM before params/grads).  Storing
m and v as int8 with per-block f32 absmax scales cuts moment memory ~4x and
fits (DESIGN.md §6).

Sharding-compatible layout: the int8 codes keep the *parameter's shape*, so
they shard with the parameter's own PartitionSpec (ZeRO-1 falls out of the
FSDP dim for free).  Scales are per-block along the last axis:
shape[:-1] + (ceil(last/BLOCK),), sharded like the param minus its last
axis.  `opt_partition_specs` in launch/steps.py builds exactly that tree.

Dynamics match f32 AdamW to quantization error (parity-tested in
tests/test_optim.py).
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.optim.adamw import AdamWConfig, schedule, global_norm

BLOCK = 512


class Q8Tensor(NamedTuple):
    codes: jax.Array     # int8, same shape as the parameter
    scales: jax.Array    # f32, shape[:-1] + (n_blocks,)


class AdamW8bitState(NamedTuple):
    step: jnp.ndarray
    mu: Any              # pytree of Q8Tensor
    nu: Any


def _nblocks(last: int) -> int:
    return -(-last // BLOCK)


def _quantize(x: jax.Array) -> Q8Tensor:
    *lead, last = x.shape
    nb = _nblocks(last)
    pad = nb * BLOCK - last
    xp = jnp.pad(x, [(0, 0)] * len(lead) + [(0, pad)])
    blocks = xp.reshape(*lead, nb, BLOCK)
    scales = jnp.max(jnp.abs(blocks), axis=-1) / 127.0 + 1e-12   # (*lead, nb)
    codes = jnp.clip(jnp.round(blocks / scales[..., None]), -127, 127)
    codes = codes.reshape(*lead, nb * BLOCK)[..., :last].astype(jnp.int8)
    return Q8Tensor(codes, scales)


def _dequantize(q: Q8Tensor) -> jax.Array:
    *lead, last = q.codes.shape
    nb = q.scales.shape[-1]
    pad = nb * BLOCK - last
    cp = jnp.pad(q.codes.astype(jnp.float32),
                 [(0, 0)] * len(lead) + [(0, pad)])
    blocks = cp.reshape(*lead, nb, BLOCK) * q.scales[..., None]
    return blocks.reshape(*lead, nb * BLOCK)[..., :last]


def init(params: Any) -> AdamW8bitState:
    def zq(p):
        shape = p.shape if p.ndim > 0 else (1,)
        return _quantize(jnp.zeros(shape, jnp.float32))
    z = jax.tree.map(zq, params)
    z2 = jax.tree.map(zq, params)
    return AdamW8bitState(step=jnp.zeros((), jnp.int32), mu=z, nu=z2)


def _is_q8(t) -> bool:
    return isinstance(t, Q8Tensor)


def apply_updates(params: Any, grads: Any, state: AdamW8bitState,
                  cfg: AdamWConfig) -> tuple[Any, AdamW8bitState]:
    step = state.step + 1
    if cfg.grad_clip is not None:
        gnorm = global_norm(grads)
        cscale = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))
        grads = jax.tree.map(lambda g: g * cscale, grads)

    b1, b2 = cfg.b1, cfg.b2
    stepf = step.astype(jnp.float32)
    mu_hat_scale = 1.0 / (1.0 - b1 ** stepf)
    nu_hat_scale = 1.0 / (1.0 - b2 ** stepf)
    lr = schedule(cfg, state.step)

    p_leaves, treedef = jax.tree.flatten(params)
    g_leaves = treedef.flatten_up_to(grads)
    m_leaves = jax.tree.leaves(state.mu, is_leaf=_is_q8)
    v_leaves = jax.tree.leaves(state.nu, is_leaf=_is_q8)

    new_p, new_m, new_v = [], [], []
    for p, g, mq, vq in zip(p_leaves, g_leaves, m_leaves, v_leaves):
        shape = p.shape if p.ndim > 0 else (1,)
        g32 = g.astype(jnp.float32).reshape(shape)
        m = b1 * _dequantize(mq) + (1 - b1) * g32
        v = b2 * _dequantize(vq) + (1 - b2) * jnp.square(g32)
        u = (m * mu_hat_scale) / (jnp.sqrt(v * nu_hat_scale) + cfg.eps)
        if cfg.weight_decay:
            u = u + cfg.weight_decay * p.astype(jnp.float32).reshape(shape)
        newp = (p.astype(jnp.float32).reshape(shape) - lr * u).astype(p.dtype)
        new_p.append(newp.reshape(p.shape))
        new_m.append(_quantize(m))
        new_v.append(_quantize(v))

    return (jax.tree.unflatten(treedef, new_p),
            AdamW8bitState(step=step,
                           mu=jax.tree.unflatten(treedef, new_m),
                           nu=jax.tree.unflatten(treedef, new_v)))
