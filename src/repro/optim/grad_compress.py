"""Error-feedback int8 gradient compression for DP all-reduce.

Large-scale trick (DESIGN.md §6): before the data-parallel all-reduce each
worker quantizes its gradient to int8 with a per-tensor scale, keeping the
quantization residual in a local error buffer that is added back the next
step (error feedback makes the compression unbiased over time).  Cuts DP
all-reduce bytes 4x vs f32 / 2x vs bf16.

In the pjit world the all-reduce is implicit, so compression is expressed
as quantize -> dequantize around the gradient (XLA then moves int8 bytes
through the collective when beneficial).  The error buffer is an explicit
optimizer-state-like pytree.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp


def init_error_buffer(params: Any) -> Any:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def _q8(g: jax.Array) -> tuple[jax.Array, jax.Array]:
    scale = jnp.max(jnp.abs(g)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale


def compress_grads(grads: Any, err: Any) -> tuple[Any, Any]:
    """Returns (dequantized grads to feed the optimizer, new error buffer)."""

    def one(g, e):
        g32 = g.astype(jnp.float32) + e
        q, scale = _q8(g32)
        deq = q.astype(jnp.float32) * scale
        return deq, g32 - deq

    flat = jax.tree.map(one, grads, err)
    deq = jax.tree.map(lambda t: t[0], flat, is_leaf=lambda t: isinstance(t, tuple))
    new_err = jax.tree.map(lambda t: t[1], flat, is_leaf=lambda t: isinstance(t, tuple))
    return deq, new_err
