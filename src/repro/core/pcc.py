"""Phase 2 — approximate popcount-compare (PCC) circuits + Pareto analysis.

A hidden-layer ternary neuron computes Eq. (2):

    popcount(inputs with w=+1)  >=  popcount(inputs with w=-1)

A PCC circuit = PC(n_pos) + PC(n_neg) + j-bit comparator.  Approximating it
with Hamming distance on the single-bit output is misleading (Sec. 4.1.2), so
the paper defines the *distance metric*:

    D(x, z) = 0      if rel(x,z) == rel'(x,z)
              x - z  otherwise                                   (Eq. 4)

and eps_mde / eps_wcde as mean/max |D| over the input domain G (Eq. 5),
estimated over 1e6 random (x, z) pairs.  Pareto-optimal (eps_mde, est. area)
combinations of approximate PCs form the PCC library used by Phase 3.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from repro.core.circuits import (
    Netlist,
    compose_pcc,
    pack_vectors,
    popcount_netlist,
    popcount_of_packed,
)


@dataclass
class PCCEntry:
    """One approximate PCC candidate (a pair of PC circuits + comparator)."""

    n_pos: int
    n_neg: int
    pc_pos: Netlist
    pc_neg: Netlist
    est_area: float          # sum of PC areas (the paper's Phase-2 proxy)
    mde: float               # eps_mde over the sampled domain
    wcde: float              # eps_wcde
    correct_frac: float      # fraction of error-free PCC decisions
    netlist: Netlist | None = None   # composed circuit (built lazily)

    def compose(self) -> Netlist:
        if self.netlist is None:
            self.netlist = compose_pcc(self.pc_pos, self.pc_neg, self.n_pos, self.n_neg)
        return self.netlist

    @property
    def synth_area(self) -> float:
        """'Post-synthesis' area: cost model applied to the composed netlist
        (includes the comparator the Phase-2 estimate ignores, cf. Fig. 6)."""
        return self.compose().cost().area_mm2


@dataclass
class PCCLibrary:
    """Pareto-optimal PCC entries per (n_pos, n_neg) size."""

    entries: dict[tuple[int, int], list[PCCEntry]] = field(default_factory=dict)

    def sizes(self) -> list[tuple[int, int]]:
        return sorted(self.entries)

    def get(self, n_pos: int, n_neg: int) -> list[PCCEntry]:
        return self.entries[(n_pos, n_neg)]

    def __len__(self) -> int:
        return sum(len(v) for v in self.entries.values())


def _rand_bit_matrix(rng: np.random.Generator, n_samples: int, n: int) -> np.ndarray:
    return (rng.random((n_samples, n)) < 0.5).astype(np.uint8)


def evaluate_pcc_pair(pc_pos: Netlist, pc_neg: Netlist, n_pos: int, n_neg: int,
                      n_samples: int = 100_000, seed: int = 0,
                      ) -> tuple[float, float, float]:
    """(eps_mde, eps_wcde, correct_frac) of a PC-pair over random samples.

    x = true popcount of the positive vector, z = of the negative vector;
    rel = (x >= z); rel' = (pc_pos'(v_pos) >= pc_neg'(v_neg)).
    """
    rng = np.random.default_rng(seed)
    vp = _rand_bit_matrix(rng, n_samples, n_pos)
    vn = _rand_bit_matrix(rng, n_samples, n_neg)
    pp, pn = pack_vectors(vp), pack_vectors(vn)
    x = popcount_of_packed(pp)[: n_samples]
    z = popcount_of_packed(pn)[: n_samples]
    xa = pc_pos.eval_uint(pp)[: n_samples]
    za = pc_neg.eval_uint(pn)[: n_samples]
    rel = x >= z
    rel_a = xa >= za
    D = np.where(rel == rel_a, 0, x - z)
    abs_d = np.abs(D)
    return float(abs_d.mean()), float(abs_d.max()), float((rel == rel_a).mean())


def _pareto_front(points: list[tuple[float, float, int]]) -> list[int]:
    """Indices of the Pareto front minimizing both coords (mde, area)."""
    order = sorted(range(len(points)), key=lambda i: (points[i][0], points[i][1]))
    front, best_area = [], float("inf")
    for i in order:
        if points[i][1] < best_area - 1e-12:
            front.append(i)
            best_area = points[i][1]
    return front


def build_pcc_library(sizes: list[tuple[int, int]],
                      pc_libs: dict[int, list[Netlist]],
                      n_samples: int = 100_000,
                      seed: int = 0,
                      max_per_size: int = 10) -> PCCLibrary:
    """For every (n_pos, n_neg) size used by the target TNNs: evaluate all
    combinations of approximate PC circuits and keep the Pareto front on
    (eps_mde, estimated area).  Exact PC circuits are the zero-error members.
    """
    lib = PCCLibrary()
    for (n_pos, n_neg) in sizes:
        pos_cands = pc_libs.get(n_pos) or [popcount_netlist(n_pos)]
        neg_cands = pc_libs.get(n_neg) or [popcount_netlist(n_neg)]
        cands: list[PCCEntry] = []
        for i, pp in enumerate(pos_cands):
            for k, pn in enumerate(neg_cands):
                mde, wcde, cf = evaluate_pcc_pair(
                    pp, pn, n_pos, n_neg, n_samples=n_samples,
                    seed=seed + 7919 * i + 104729 * k)
                est = pp.cost().area_mm2 + pn.cost().area_mm2
                cands.append(PCCEntry(n_pos, n_neg, pp, pn, est, mde, wcde, cf))
        pts = [(c.mde, c.est_area, idx) for idx, c in enumerate(cands)]
        front = _pareto_front(pts)[:max_per_size]
        sel = sorted((cands[i] for i in front), key=lambda c: c.mde)
        # index 0 must be the exact PCC (mde == 0 always exists: exact+exact)
        assert sel and sel[0].mde == 0.0
        lib.entries[(n_pos, n_neg)] = sel
    return lib


def pc_pareto(pc_lib: list[Netlist]) -> list[Netlist]:
    """Pareto filter a PC library on (mae, area) — used for output neurons."""
    pts = [(nl.meta.get("mae", 0.0), nl.cost().area_mm2, i) for i, nl in enumerate(pc_lib)]
    front = _pareto_front(pts)
    sel = sorted((pc_lib[i] for i in front), key=lambda nl: nl.meta.get("mae", 0.0))
    assert sel and sel[0].meta.get("mae", 0.0) == 0.0
    return sel
