"""Phase 2 — approximate popcount-compare (PCC) circuits + Pareto analysis.

A hidden-layer ternary neuron computes Eq. (2):

    popcount(inputs with w=+1)  >=  popcount(inputs with w=-1)

A PCC circuit = PC(n_pos) + PC(n_neg) + j-bit comparator.  Approximating it
with Hamming distance on the single-bit output is misleading (Sec. 4.1.2), so
the paper defines the *distance metric*:

    D(x, z) = 0      if rel(x,z) == rel'(x,z)
              x - z  otherwise                                   (Eq. 4)

and eps_mde / eps_wcde as mean/max |D| over the input domain G (Eq. 5),
estimated over 1e6 random (x, z) pairs.  Pareto-optimal (eps_mde, est. area)
combinations of approximate PCs form the PCC library used by Phase 3.

Library construction is population-parallel: per (n_pos, n_neg) size one
shared sample domain is drawn, every positive/negative PC candidate is
simulated once through a padded `NetlistPopulation` batch, and all candidate
*pairs* are scored from the cached outputs — instead of re-sampling and
re-simulating both circuits for each of the |pos| x |neg| combinations.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from repro.core.circuits import (
    Netlist,
    NetlistPopulation,
    compose_pcc,
    pack_vectors,
    popcount_netlist,
    popcount_of_packed,
)


@dataclass
class PCCEntry:
    """One approximate PCC candidate (a pair of PC circuits + comparator)."""

    n_pos: int
    n_neg: int
    pc_pos: Netlist
    pc_neg: Netlist
    est_area: float          # sum of PC areas (the paper's Phase-2 proxy)
    mde: float               # eps_mde over the sampled domain
    wcde: float              # eps_wcde
    correct_frac: float      # fraction of error-free PCC decisions
    netlist: Netlist | None = None   # composed circuit (built lazily)

    def compose(self) -> Netlist:
        if self.netlist is None:
            self.netlist = compose_pcc(self.pc_pos, self.pc_neg, self.n_pos, self.n_neg)
        return self.netlist

    @property
    def synth_area(self) -> float:
        """'Post-synthesis' area: cost model applied to the composed netlist
        (includes the comparator the Phase-2 estimate ignores, cf. Fig. 6)."""
        return self.compose().cost().area_mm2


@dataclass
class PCCLibrary:
    """Pareto-optimal PCC entries per (n_pos, n_neg) size."""

    entries: dict[tuple[int, int], list[PCCEntry]] = field(default_factory=dict)

    def sizes(self) -> list[tuple[int, int]]:
        return sorted(self.entries)

    def get(self, n_pos: int, n_neg: int) -> list[PCCEntry]:
        return self.entries[(n_pos, n_neg)]

    def __len__(self) -> int:
        return sum(len(v) for v in self.entries.values())


def _rand_bit_matrix(rng: np.random.Generator, n_samples: int, n: int) -> np.ndarray:
    return (rng.random((n_samples, n)) < 0.5).astype(np.uint8)


def evaluate_pcc_pair(pc_pos: Netlist, pc_neg: Netlist, n_pos: int, n_neg: int,
                      n_samples: int = 100_000, seed: int = 0,
                      ) -> tuple[float, float, float]:
    """(eps_mde, eps_wcde, correct_frac) of a PC-pair over random samples.

    x = true popcount of the positive vector, z = of the negative vector;
    rel = (x >= z); rel' = (pc_pos'(v_pos) >= pc_neg'(v_neg)).
    """
    pp, pn, x, z = sample_pair_domain(n_pos, n_neg, n_samples, seed)
    xa = pc_pos.eval_uint(pp)[: n_samples]
    za = pc_neg.eval_uint(pn)[: n_samples]
    return pair_distance_stats(xa, za, x, z)


def sample_pair_domain(n_pos: int, n_neg: int, n_samples: int, seed: int
                       ) -> tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
    """Shared random (pos, neg) sample domain for one PCC size.

    Returns (packed_pos, packed_neg, x, z): packed uint64 input words plus
    the true popcounts x, z of each sample pair.
    """
    rng = np.random.default_rng(seed)
    pp = pack_vectors(_rand_bit_matrix(rng, n_samples, n_pos))
    pn = pack_vectors(_rand_bit_matrix(rng, n_samples, n_neg))
    x = popcount_of_packed(pp)[:n_samples]
    z = popcount_of_packed(pn)[:n_samples]
    return pp, pn, x, z


def pair_distance_stats(xa: np.ndarray, za: np.ndarray,
                        x: np.ndarray, z: np.ndarray
                        ) -> tuple[float, float, float]:
    """(eps_mde, eps_wcde, correct_frac) from precomputed approximate
    popcounts xa, za over a shared sample domain with true counts x, z."""
    rel = x >= z
    rel_a = xa >= za
    correct = rel == rel_a
    abs_d = np.where(correct, 0, np.abs(x - z))
    return float(abs_d.mean()), float(abs_d.max()), float(correct.mean())


def _pareto_front(points: list[tuple[float, float, int]]) -> list[int]:
    """Indices of the Pareto front minimizing both coords (mde, area)."""
    order = sorted(range(len(points)), key=lambda i: (points[i][0], points[i][1]))
    front, best_area = [], float("inf")
    for i in order:
        if points[i][1] < best_area - 1e-12:
            front.append(i)
            best_area = points[i][1]
    return front


def build_pcc_library(sizes: list[tuple[int, int]],
                      pc_libs: dict[int, list[Netlist]],
                      n_samples: int = 100_000,
                      seed: int = 0,
                      max_per_size: int = 10) -> PCCLibrary:
    """For every (n_pos, n_neg) size used by the target TNNs: evaluate all
    combinations of approximate PC circuits and keep the Pareto front on
    (eps_mde, estimated area).  Exact PC circuits are the zero-error members.

    Population-parallel: each candidate circuit is simulated exactly once
    over a shared per-size sample domain (padded `NetlistPopulation` batch);
    the |pos| x |neg| pair statistics then come from the cached outputs.
    """
    lib = PCCLibrary()
    for (n_pos, n_neg) in sizes:
        pos_cands = pc_libs.get(n_pos) or [popcount_netlist(n_pos)]
        neg_cands = pc_libs.get(n_neg) or [popcount_netlist(n_neg)]
        pp, pn, x, z = sample_pair_domain(
            n_pos, n_neg, n_samples, seed + 7919 * n_pos + 104729 * n_neg)
        xa = NetlistPopulation.from_netlists(pos_cands).eval_uint(pp)[:, :n_samples]
        za = NetlistPopulation.from_netlists(neg_cands).eval_uint(pn)[:, :n_samples]
        pos_areas = [c.cost().area_mm2 for c in pos_cands]
        neg_areas = [c.cost().area_mm2 for c in neg_cands]
        cands: list[PCCEntry] = []
        for i, pc_p in enumerate(pos_cands):
            for k, pc_n in enumerate(neg_cands):
                mde, wcde, cf = pair_distance_stats(xa[i], za[k], x, z)
                est = pos_areas[i] + neg_areas[k]
                cands.append(PCCEntry(n_pos, n_neg, pc_p, pc_n, est, mde, wcde, cf))
        pts = [(c.mde, c.est_area, idx) for idx, c in enumerate(cands)]
        front = _pareto_front(pts)[:max_per_size]
        sel = sorted((cands[i] for i in front), key=lambda c: c.mde)
        # index 0 must be the exact PCC (mde == 0 always exists: exact+exact)
        assert sel and sel[0].mde == 0.0
        lib.entries[(n_pos, n_neg)] = sel
    return lib


def pc_pareto(pc_lib: list[Netlist]) -> list[Netlist]:
    """Pareto filter a PC library on (mae, area) — used for output neurons."""
    pts = [(nl.meta.get("mae", 0.0), nl.cost().area_mm2, i) for i, nl in enumerate(pc_lib)]
    front = _pareto_front(pts)
    sel = sorted((pc_lib[i] for i in front), key=lambda nl: nl.meta.get("mae", 0.0))
    assert sel and sel[0].meta.get("mae", 0.0) == 0.0
    return sel
