"""Baselines the paper compares against (Tables 2-3).

* Exact bespoke MLP [Mubarik et al., MICRO'20]: 4-bit inputs, 8-bit weights,
  hardwired multipliers (shift-add trees), ReLU, argmax.
* Power-of-2 Ax MLP [Afentaki et al., ICCAD'23/DATE'24]: weights constrained
  to ±2^k (multiplication = rewiring), truncated accumulation, low-precision
  activation.

Both are (a) trained with QAT in JAX on the same synthetic datasets, and
(b) costed with the same EGFET gate model used for our TNNs, via an
adder-tree area estimator for bespoke MAC hardware.  The published Table-3
numbers are also carried verbatim (PAPER_TABLE3) so benchmarks can print
modeled-vs-published side by side.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from repro.data.tabular import TabularDataset
from repro.hw.egfet import Gate, HwCost, gate_cost, interface_cost
from repro.optim import adamw
from repro.optim.adamw import AdamWConfig


# ---------------------------------------------------------------------------
# Area model for bespoke arithmetic (EGFET)
# ---------------------------------------------------------------------------
_FA = (gate_cost(Gate.XOR).scale(2) + gate_cost(Gate.AND).scale(2)
       + gate_cost(Gate.OR))           # full adder


def adder_cost(width: int) -> HwCost:
    """Ripple adder of `width` bits (bespoke, carry chain of FAs)."""
    return _FA.scale(max(width, 1))


def shift_add_multiplier_cost(w: int, in_bits: int) -> HwCost:
    """Hardwired multiply of an `in_bits` input by constant w: one shifted
    add per set bit beyond the first (bespoke constant multiplier)."""
    ones = bin(abs(int(w))).count("1")
    if ones <= 1:
        return HwCost(0.0, 0.0)        # power of two: pure rewiring
    width = in_bits + max(abs(int(w)).bit_length(), 1)
    return adder_cost(width).scale(ones - 1)


def accumulator_tree_cost(n_addends: int, width: int) -> HwCost:
    """Adder tree over n addends of `width` bits (width grows up the tree)."""
    total = HwCost(0.0, 0.0)
    level_w = width
    n = n_addends
    while n > 1:
        total = total + adder_cost(level_w).scale(n // 2)
        n = (n + 1) // 2
        level_w += 1
    return total


def relu_cost(width: int) -> HwCost:
    # sign check + AND gating per bit
    return gate_cost(Gate.AND).scale(width)


def mlp_hw_cost(weights: list[np.ndarray], in_bits: int, w_bits: int,
                pow2: bool, interface: str | None) -> HwCost:
    """Bespoke MLP cost: hardwired multipliers + accumulation + ReLU/argmax."""
    total = HwCost(0.0, 0.0)
    bits = in_bits
    for li, W in enumerate(weights):
        fan_in, n_out = W.shape
        acc_w = bits + int(np.ceil(np.log2(max(fan_in, 2)))) + w_bits
        for o in range(n_out):
            col = W[:, o]
            nz = col[col != 0]
            if not pow2:
                for w in nz:
                    total = total + shift_add_multiplier_cost(int(w), bits)
            total = total + accumulator_tree_cost(max(len(nz), 1), acc_w)
            if li < len(weights) - 1:
                total = total + relu_cost(acc_w)
        bits = min(acc_w, 8)           # low-precision inter-layer activation
    # argmax comparators over the last layer
    n_cls = weights[-1].shape[1]
    cmp_w = bits
    total = total + (adder_cost(cmp_w) + gate_cost(Gate.AND).scale(cmp_w)
                     ).scale(max(n_cls - 1, 1))
    if interface:
        total = total + interface_cost(weights[0].shape[0], interface)
    return total


# ---------------------------------------------------------------------------
# QAT training for the two baselines
# ---------------------------------------------------------------------------
def _quant_input_4bit(x: np.ndarray) -> np.ndarray:
    return np.round(np.clip(x, 0, 1) * 15.0) / 15.0


def _int_ste(w, bits):
    lim = 2.0 ** (bits - 1) - 1
    q = jnp.clip(jnp.round(w * lim), -lim, lim) / lim
    return w + jax.lax.stop_gradient(q - w)


def _pow2_ste(w):
    mag = jnp.clip(jnp.abs(w), 2.0 ** -3, 1.0)
    q = jnp.sign(w) * 2.0 ** jnp.round(jnp.log2(mag))
    q = jnp.where(jnp.abs(w) < 2.0 ** -4, 0.0, q)
    return w + jax.lax.stop_gradient(q - w)


@dataclass
class TrainedMLP:
    weights_int: list[np.ndarray]    # integer (or pow2-integer) hardware weights
    test_acc: float
    pow2: bool
    in_bits: int
    w_bits: int

    def cost(self, interface: str | None = "adc4") -> HwCost:
        return mlp_hw_cost(self.weights_int, self.in_bits, self.w_bits,
                           self.pow2, interface)


def train_mlp_baseline(ds: TabularDataset, hidden: int, *, pow2: bool = False,
                       epochs: int = 15, lr: float = 5e-3, seed: int = 0,
                       w_bits: int = 8) -> TrainedMLP:
    xq_tr = _quant_input_4bit(ds.x_train)
    xq_te = _quant_input_4bit(ds.x_test)
    F, C = ds.spec.n_features, ds.spec.n_classes
    rng = np.random.default_rng(seed)
    params = {"w1": jnp.asarray(rng.normal(0, 0.3, (F, hidden)), jnp.float32),
              "w2": jnp.asarray(rng.normal(0, 0.3, (hidden, C)), jnp.float32)}
    quant = _pow2_ste if pow2 else (lambda w: _int_ste(w, w_bits))

    def fwd(p, x):
        h = jax.nn.relu(x @ quant(p["w1"]))
        return h @ quant(p["w2"])

    def loss(p, x, y):
        lg = fwd(p, x)
        lp = jax.nn.log_softmax(lg, axis=-1)
        return -jnp.mean(jnp.take_along_axis(lp, y[:, None], 1))

    ocfg = AdamWConfig(lr=lr)
    state = adamw.init(params)
    step = jax.jit(lambda p, s, x, y: (lambda l_g: adamw.apply_updates(
        p, l_g[1], s, ocfg) + (l_g[0],))(
        jax.value_and_grad(loss)(p, x, y)))
    xj, yj = jnp.asarray(xq_tr), jnp.asarray(ds.y_train.astype(np.int32))
    n = xj.shape[0]
    for _ in range(epochs):
        perm = rng.permutation(n)
        for s in range(0, n, 64):
            idx = perm[s:s + 64]
            params, state, _ = step(params, state, xj[idx], yj[idx])

    pred = np.asarray(jnp.argmax(fwd(params, jnp.asarray(xq_te)), axis=-1))
    acc = float((pred == ds.y_test).mean())
    lim = 2 ** (w_bits - 1) - 1

    def to_int(w):
        wq = np.asarray(quant(w))
        if pow2:
            return np.round(wq * 8).astype(np.int32)   # pow2 grid, 1/8 lsb
        return np.round(wq * lim).astype(np.int32)

    return TrainedMLP(weights_int=[to_int(params["w1"]), to_int(params["w2"])],
                      test_acc=acc, pow2=pow2, in_bits=4, w_bits=w_bits)


# ---------------------------------------------------------------------------
# Published Table 3 rows (reference comparison values from the paper)
# area cm^2 / power mW, w/o interface cost
# ---------------------------------------------------------------------------
PAPER_TABLE3 = {
    "arrhythmia": {"exact_mlp": (62, 266.00, 998.00),
                   "ax_mlp": (60, 13.51, 12.80),
                   "our_exact_tnn": (60, 8.87, 8.09),
                   "our_ax_tnn": (60, 7.73, 7.12)},
    "breast_cancer": {"exact_mlp": (98, 12.00, 40.00),
                      "ax_mlp": (94, 0.03, 0.03),
                      "our_exact_tnn": (98, 0.29, 0.31),
                      "our_ax_tnn": (98, 0.05, 0.04)},
    "cardio": {"exact_mlp": (88, 33.40, 124.20),
               "ax_mlp": (87, 1.46, 1.70),
               "our_exact_tnn": (85, 0.75, 0.91),
               "our_ax_tnn": (85, 0.36, 0.42)},
    "redwine": {"exact_mlp": (56, 17.60, 73.50),
                "ax_mlp": (55, 0.03, 0.02),
                "our_exact_tnn": (56, 0.08, 0.09),
                "our_ax_tnn": (56, 0.03, 0.03)},
    "whitewine": {"exact_mlp": (54, 31.20, 126.40),
                  "ax_mlp": (51, 0.23, 0.25),
                  "our_exact_tnn": (50, 0.16, 0.18),
                  "our_ax_tnn": (50, 0.11, 0.12)},
}
