"""Gate-level netlists + bit-parallel simulation + EGFET cost.

This is the substrate for the paper's three-phase approximation flow:
  * Phase 1 (CGP) mutates netlists of this form and needs fast error
    evaluation -> `simulate()` is bit-parallel: every uint64 word carries 64
    test vectors, so exhaustive evaluation of an n<=16-input circuit touches
    2**n / 64 words per signal (the offline stand-in for the paper's BDDs).
  * Phase 2 composes popcount netlists + comparators into PCC circuits.
  * Phase 3 plugs chosen netlists into the circuit-accurate TNN.

Node ids: inputs are 0..n_inputs-1; gate g (0-based) has id n_inputs+g and
may only read strictly smaller ids (a feed-forward DAG by construction).

Population-parallel evaluation
------------------------------
`NetlistPopulation` is the structure-of-arrays twin of `Netlist`: a whole
population of same-shape genomes as `(P, n_gates)` opcode/operand arrays,
simulated in one vectorized pass over all packed test words.  Per gate
column the heterogeneous opcodes are applied through their algebraic normal
form r = c0 ^ (ca & a) ^ (cb & b) ^ (cab & a & b) with per-individual
uint64 coefficient masks, so a column costs a constant number of numpy ops
regardless of P (columns where the whole population agrees on the opcode
take a cheaper direct path).  This is what makes CGP fitness evaluation
population-parallel (see `core.cgp`): measured on this substrate the
batched path is bit-identical to the per-child `Netlist.simulate` loop at
~14x its evals/s for lambda=16 (n=8; 19-33x at lambda 32-64, ~14x at n=12,
see `benchmarks/cgp_throughput.py` / BENCH_cgp.json).  `kernels.circuit_sim`
provides the jittable uint32-SWAR JAX twin for on-device fitness, another
~5-8x on top of the numpy path.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from repro.hw.egfet import Gate, GATE_AREA_MM2, GATE_POWER_UW, HwCost

_U64 = np.uint64
_FULL = _U64(0xFFFFFFFFFFFFFFFF)

_N_OPS = max(int(g) for g in Gate) + 1
GATE_AREA_VEC = np.zeros(_N_OPS, dtype=np.float64)
GATE_POWER_VEC = np.zeros(_N_OPS, dtype=np.float64)
for _g in Gate:
    GATE_AREA_VEC[int(_g)] = GATE_AREA_MM2[_g]
    GATE_POWER_VEC[int(_g)] = GATE_POWER_UW[_g]

# Algebraic-normal-form coefficients per opcode: f(a, b) = c0 ^ (ca & a)
# ^ (cb & b) ^ (cab & a & b).  INPUT slots behave like BUF (never emitted
# by builders/CGP, but harmless under padding).
_ANF_COEFF = {
    Gate.INPUT: (0, 1, 0, 0),
    Gate.CONST0: (0, 0, 0, 0),
    Gate.CONST1: (1, 0, 0, 0),
    Gate.BUF: (0, 1, 0, 0),
    Gate.NOT: (1, 1, 0, 0),
    Gate.AND: (0, 0, 0, 1),
    Gate.OR: (0, 1, 1, 1),
    Gate.XOR: (0, 1, 1, 0),
    Gate.NAND: (1, 0, 0, 1),
    Gate.NOR: (1, 1, 1, 1),
    Gate.XNOR: (1, 1, 1, 0),
    Gate.ANDN: (0, 1, 0, 1),
    Gate.ORN: (1, 0, 1, 1),
}
_ANF_C0 = np.zeros(_N_OPS, dtype=_U64)
_ANF_CA = np.zeros(_N_OPS, dtype=_U64)
_ANF_CB = np.zeros(_N_OPS, dtype=_U64)
_ANF_CAB = np.zeros(_N_OPS, dtype=_U64)
for _g, (_c0, _ca, _cb, _cab) in _ANF_COEFF.items():
    _ANF_C0[int(_g)] = _FULL * _U64(_c0)
    _ANF_CA[int(_g)] = _FULL * _U64(_ca)
    _ANF_CB[int(_g)] = _FULL * _U64(_cb)
    _ANF_CAB[int(_g)] = _FULL * _U64(_cab)

# Liveness propagation rules (mirrors Netlist.active_mask's branches).
_USES_A = np.ones(_N_OPS, dtype=bool)
_USES_B = np.ones(_N_OPS, dtype=bool)
for _g in (Gate.INPUT, Gate.CONST0, Gate.CONST1):
    _USES_A[int(_g)] = False
for _g in (Gate.INPUT, Gate.CONST0, Gate.CONST1, Gate.NOT, Gate.BUF):
    _USES_B[int(_g)] = False

_HOMOG_BINOP = {
    Gate.AND: lambda a, b: a & b,
    Gate.OR: lambda a, b: a | b,
    Gate.XOR: lambda a, b: a ^ b,
    Gate.NAND: lambda a, b: ~(a & b),
    Gate.NOR: lambda a, b: ~(a | b),
    Gate.XNOR: lambda a, b: ~(a ^ b),
    Gate.ANDN: lambda a, b: a & ~b,
    Gate.ORN: lambda a, b: a | ~b,
}


@dataclass
class Netlist:
    n_inputs: int
    op: np.ndarray        # (n_gates,) int16 Gate opcodes
    in0: np.ndarray       # (n_gates,) int32 node ids
    in1: np.ndarray       # (n_gates,) int32 node ids
    outputs: np.ndarray   # (n_outputs,) int32 node ids, LSB-first
    name: str = ""
    meta: dict = field(default_factory=dict)

    # -- structure ----------------------------------------------------------
    @property
    def n_gates(self) -> int:
        return int(self.op.shape[0])

    @property
    def n_outputs(self) -> int:
        return int(self.outputs.shape[0])

    def validate(self) -> None:
        ids = np.arange(self.n_gates) + self.n_inputs
        if self.n_gates:
            if (self.in0 >= ids).any() or (self.in1 >= ids).any():
                raise ValueError("netlist is not feed-forward")
            if (self.in0 < 0).any() or (self.in1 < 0).any():
                raise ValueError("negative input id")
        if (self.outputs < 0).any() or (self.outputs >= self.n_inputs + self.n_gates).any():
            raise ValueError("output id out of range")

    def active_mask(self) -> np.ndarray:
        """Boolean mask over gates reachable from the outputs (live logic)."""
        live = np.zeros(self.n_inputs + self.n_gates, dtype=bool)
        live[self.outputs] = True
        # reverse sweep: DAG edges always point backwards
        for g in range(self.n_gates - 1, -1, -1):
            nid = self.n_inputs + g
            if live[nid]:
                o = self.op[g]
                if o not in (Gate.INPUT, Gate.CONST0, Gate.CONST1):
                    live[self.in0[g]] = True
                    if o not in (Gate.NOT, Gate.BUF):
                        live[self.in1[g]] = True
        return live[self.n_inputs:]

    # -- cost ---------------------------------------------------------------
    def cost(self) -> HwCost:
        act = self.active_mask()
        ops = self.op[act]
        area = float(GATE_AREA_VEC[ops].sum())
        power = float(GATE_POWER_VEC[ops].sum()) * 1e-3
        return HwCost(area, power)

    def area(self) -> float:
        return self.cost().area_mm2

    # -- simulation ---------------------------------------------------------
    def simulate(self, inputs: np.ndarray) -> np.ndarray:
        """Bit-parallel evaluation.

        inputs: uint64 (n_inputs, W) — bit k of word w of row i is test
        vector (w*64+k)'s value for input i.  Returns (n_outputs, W).
        """
        if inputs.shape[0] != self.n_inputs:
            raise ValueError(f"expected {self.n_inputs} input rows, got {inputs.shape[0]}")
        W = inputs.shape[1]
        vals = np.zeros((self.n_inputs + self.n_gates, W), dtype=_U64)
        vals[: self.n_inputs] = inputs
        op, in0, in1 = self.op, self.in0, self.in1
        for g in range(self.n_gates):
            o = op[g]
            a = vals[in0[g]]
            if o == Gate.CONST0:
                continue  # already zeros
            if o == Gate.CONST1:
                vals[self.n_inputs + g] = _FULL
                continue
            if o == Gate.BUF:
                vals[self.n_inputs + g] = a
                continue
            if o == Gate.NOT:
                vals[self.n_inputs + g] = ~a
                continue
            b = vals[in1[g]]
            if o == Gate.AND:
                r = a & b
            elif o == Gate.OR:
                r = a | b
            elif o == Gate.XOR:
                r = a ^ b
            elif o == Gate.NAND:
                r = ~(a & b)
            elif o == Gate.NOR:
                r = ~(a | b)
            elif o == Gate.XNOR:
                r = ~(a ^ b)
            elif o == Gate.ANDN:
                r = a & ~b
            elif o == Gate.ORN:
                r = a | ~b
            else:
                raise ValueError(f"bad opcode {o}")
            vals[self.n_inputs + g] = r
        return vals[self.outputs]

    def eval_uint(self, inputs: np.ndarray) -> np.ndarray:
        """Simulate and decode outputs (LSB-first) into per-vector uints.

        Returns int64 array of shape (W*64,).
        """
        outw = self.simulate(inputs)  # (n_out, W)
        return _decode_words(outw[None])[0]


def _decode_bits(outw: np.ndarray) -> np.ndarray:
    """(P, n_out, W) packed words -> (P, n_out, W*64) LSB-first bit planes.

    Little-endian native byte order + bitorder='little' puts bit k of word w
    at vector w*64+k directly — no byte/bit reversal copies.
    """
    P, n_out, W = outw.shape
    return np.unpackbits(np.ascontiguousarray(outw).view(np.uint8)
                         .reshape(P, n_out, W * 8), axis=-1, bitorder="little")


def _accumulate_u8(bits: np.ndarray) -> np.ndarray:
    """Merge <=8 bit planes into per-vector uint8 values (OR of disjoint bits)."""
    P, n_out, S = bits.shape
    acc = np.zeros((P, S), dtype=np.uint8)
    for o in range(n_out):
        acc |= bits[:, o] << o
    return acc


def _decode_words(outw: np.ndarray) -> np.ndarray:
    """(P, n_out, W) packed output words -> (P, W*64) int64 LSB-first uints.

    Per-output accumulation keeps temporaries at (P, S); narrow outputs
    (n_out <= 8, i.e. every popcount/PCC in the paper) stay uint8 until the
    final cast, which keeps the hot decode memory-bound on ~1/8 the bytes.
    """
    bits = _decode_bits(outw)
    P, n_out, S = bits.shape
    if n_out <= 8:
        return _accumulate_u8(bits).astype(np.int64)
    out = np.zeros((P, S), dtype=np.int64)
    for o in range(n_out):
        out += bits[:, o].astype(np.int64) << o
    return out


# ---------------------------------------------------------------------------
# Population-parallel evaluation (structure-of-arrays over same-shape genomes)
# ---------------------------------------------------------------------------
@dataclass
class NetlistPopulation:
    """A population of P same-shape netlists as `(P, n_gates)` plan arrays.

    All individuals share `n_inputs` and `n_outputs`; gate counts are
    equalized by padding with dead CONST0 gates (`from_netlists`).  The
    evaluator walks gate columns once, applying every individual's opcode
    simultaneously via ANF coefficient masks — the per-gate Python cost is
    O(1) in P, versus O(P) for a per-child `Netlist.simulate` loop.
    """

    n_inputs: int
    op: np.ndarray        # (P, n_gates) int16 Gate opcodes
    in0: np.ndarray       # (P, n_gates) int32 node ids
    in1: np.ndarray       # (P, n_gates) int32 node ids
    outputs: np.ndarray   # (P, n_outputs) int32 node ids, LSB-first

    @property
    def size(self) -> int:
        return int(self.op.shape[0])

    @property
    def n_gates(self) -> int:
        return int(self.op.shape[1])

    @property
    def n_outputs(self) -> int:
        return int(self.outputs.shape[1])

    @classmethod
    def from_netlists(cls, nls: list["Netlist"]) -> "NetlistPopulation":
        """Stack netlists (same n_inputs/n_outputs) into one population.

        Heterogeneous gate counts are padded at the high-id end with CONST0
        gates, which are never reachable from the (unchanged) output ids.
        """
        if not nls:
            raise ValueError("empty population")
        n_in = nls[0].n_inputs
        n_out = nls[0].n_outputs
        for nl in nls:
            if nl.n_inputs != n_in or nl.n_outputs != n_out:
                raise ValueError("population members must share I/O shape")
        G = max(nl.n_gates for nl in nls)
        P = len(nls)
        op = np.full((P, G), int(Gate.CONST0), dtype=np.int16)
        in0 = np.zeros((P, G), dtype=np.int32)
        in1 = np.zeros((P, G), dtype=np.int32)
        outputs = np.empty((P, n_out), dtype=np.int32)
        for p, nl in enumerate(nls):
            g = nl.n_gates
            op[p, :g] = nl.op
            in0[p, :g] = nl.in0
            in1[p, :g] = nl.in1
            outputs[p] = nl.outputs
        return cls(n_in, op, in0, in1, outputs)

    def take(self, indices: np.ndarray) -> "NetlistPopulation":
        """Row-select (with repetition) a sub-population."""
        idx = np.asarray(indices)
        return NetlistPopulation(self.n_inputs, self.op[idx], self.in0[idx],
                                 self.in1[idx], self.outputs[idx])

    def netlist(self, p: int, name: str = "") -> "Netlist":
        nl = Netlist(self.n_inputs, self.op[p].astype(np.int16),
                     self.in0[p].astype(np.int32), self.in1[p].astype(np.int32),
                     self.outputs[p].astype(np.int32), name=name)
        nl.validate()
        return nl

    # -- simulation ---------------------------------------------------------
    def simulate(self, inputs: np.ndarray) -> np.ndarray:
        """Bit-parallel evaluation of the whole population.

        inputs: uint64, either shared `(n_inputs, W)` or per-individual
        `(P, n_inputs, W)`.  Returns `(P, n_outputs, W)` — row p is
        bit-identical to `self.netlist(p).simulate(...)`.

        Wide word sets are processed in cache-sized chunks along the word
        axis (words are independent), keeping the whole population's value
        plane resident instead of streaming a multi-MB array per gate.
        """
        inputs = np.ascontiguousarray(inputs, dtype=_U64)
        P, G = self.op.shape
        n_in = self.n_inputs
        if inputs.ndim == 2:
            if inputs.shape[0] != n_in:
                raise ValueError(f"expected {n_in} input rows, got {inputs.shape[0]}")
            W = inputs.shape[1]
            inputs = inputs[None]
        elif inputs.ndim == 3:
            if inputs.shape[:2] != (P, n_in):
                raise ValueError(f"expected ({P}, {n_in}, W) inputs, got {inputs.shape}")
            W = inputs.shape[2]
        else:
            raise ValueError("inputs must be (n_inputs, W) or (P, n_inputs, W)")
        chunk = max(16, (4 << 20) // ((n_in + G) * P * 8))
        if W > chunk:
            return np.concatenate(
                [self._simulate_block(inputs[..., s:s + chunk], P, W=min(chunk, W - s))
                 for s in range(0, W, chunk)], axis=-1)
        return self._simulate_block(inputs, P, W)

    def _simulate_block(self, inputs: np.ndarray, P: int, W: int) -> np.ndarray:
        n_in = self.n_inputs
        G = self.op.shape[1]
        # node-major (N, P, W) layout: gate writes and homogeneous-column ops
        # touch one contiguous (P, W) block instead of P strided slices
        vals = np.zeros((n_in + G, P, W), dtype=_U64)
        vals[:n_in] = inputs.transpose(1, 0, 2)
        rows = np.arange(P)
        op, in0, in1 = self.op, self.in0, self.in1
        homog = (op == op[:1]).all(axis=0)
        c0, ca = _ANF_C0[op], _ANF_CA[op]
        cb, cab = _ANF_CB[op], _ANF_CAB[op]
        for g in range(G):
            if homog[g]:
                o = int(op[0, g])
                if o == Gate.CONST0:
                    continue
                if o == Gate.CONST1:
                    vals[n_in + g] = _FULL
                    continue
                a = vals[in0[:, g], rows]
                if o in (Gate.BUF, Gate.INPUT):
                    vals[n_in + g] = a
                elif o == Gate.NOT:
                    vals[n_in + g] = ~a
                else:
                    b = vals[in1[:, g], rows]
                    vals[n_in + g] = _HOMOG_BINOP[Gate(o)](a, b)
            else:
                a = vals[in0[:, g], rows]
                b = vals[in1[:, g], rows]
                vals[n_in + g] = (c0[:, g, None]
                                  ^ (ca[:, g, None] & a)
                                  ^ (cb[:, g, None] & b)
                                  ^ (cab[:, g, None] & (a & b)))
        return vals[self.outputs.T, rows[None, :]].transpose(1, 0, 2)

    def eval_uint(self, inputs: np.ndarray) -> np.ndarray:
        """Simulate and decode outputs (LSB-first) into per-vector uints.

        Returns int64 `(P, W*64)` — row p matches `netlist(p).eval_uint`.
        """
        return _decode_words(self.simulate(inputs))

    def pc_errors(self, packed: np.ndarray, true: np.ndarray
                  ) -> tuple[np.ndarray, np.ndarray]:
        """Per-individual (mae, wcae) against true popcounts: two (P,) arrays.

        Narrow outputs keep the whole |approx - true| pipeline in int16 —
        same integers, same float64 statistics, ~1/4 the memory traffic of
        the int64 route.
        """
        bits = _decode_bits(self.simulate(packed))
        n_out = bits.shape[1]
        true = np.asarray(true)
        if n_out <= 8 and (true.size == 0 or 0 <= true.min() <= true.max() < 2 ** 14):
            approx = _accumulate_u8(bits).astype(np.int16)
            err = np.abs(approx - true.astype(np.int16)[None, :])
        else:
            P, _, S = bits.shape
            approx = np.zeros((P, S), dtype=np.int64)
            for o in range(n_out):
                approx += bits[:, o].astype(np.int64) << o
            err = np.abs(approx - true[None, :])
        return err.mean(axis=1), err.max(axis=1).astype(np.float64)

    # -- structure / cost ---------------------------------------------------
    def active_masks(self) -> np.ndarray:
        """(P, n_gates) liveness — row p equals `netlist(p).active_mask()`."""
        P, G = self.op.shape
        n_in = self.n_inputs
        live = np.zeros((P, n_in + G), dtype=bool)
        rows = np.arange(P)
        live[rows[:, None], self.outputs] = True
        uses_a = _USES_A[self.op]
        uses_b = _USES_B[self.op]
        for g in range(G - 1, -1, -1):
            m = live[:, n_in + g]
            live[rows, self.in0[:, g]] |= m & uses_a[:, g]
            live[rows, self.in1[:, g]] |= m & uses_b[:, g]
        return live[:, n_in:]

    def areas(self) -> np.ndarray:
        """(P,) active-gate EGFET areas, bit-identical to `Netlist.cost()`."""
        act = self.active_masks()
        return np.array([GATE_AREA_VEC[self.op[p][act[p]]].sum()
                         for p in range(self.size)])


FUZZ_OPS: tuple[int, ...] = tuple(int(g) for g in Gate if g != Gate.INPUT)
# INPUT is a placeholder opcode (never emitted by builders or CGP); the
# serial `Netlist.simulate` rejects it, so differential fuzzing excludes it.


def random_netlist_population(rng: np.random.Generator, n_inputs: int,
                              n_gates: int, n_outputs: int, size: int
                              ) -> NetlistPopulation:
    """`size` random feed-forward same-shape netlists (conformance fuzzing).

    Operand ids respect the DAG constraint (gate g reads ids < n_inputs + g);
    opcodes are drawn uniformly from the full simulate-able gate set, output
    taps uniformly over all nodes — the adversarial shape for evaluator
    conformance, covering dead gates, const-only cones, repeated taps and
    input-passthrough outputs that structured CGP genomes rarely produce.
    """
    if n_outputs > 8:
        raise ValueError("fuzz populations keep n_outputs <= 8 (u8 decode)")
    op = rng.choice(np.array(FUZZ_OPS, dtype=np.int16),
                    size=(size, n_gates)).astype(np.int16)
    hi = n_inputs + np.arange(n_gates)
    in0 = rng.integers(0, hi[None, :], size=(size, n_gates)).astype(np.int32)
    in1 = rng.integers(0, hi[None, :], size=(size, n_gates)).astype(np.int32)
    outputs = rng.integers(0, n_inputs + n_gates,
                           size=(size, n_outputs)).astype(np.int32)
    pop = NetlistPopulation(n_inputs, op, in0, in1, outputs)
    for p in range(size):
        pop.netlist(p)        # validates feed-forwardness per row
    return pop


# ---------------------------------------------------------------------------
# Builders
# ---------------------------------------------------------------------------
class _Builder:
    """Convenience netlist builder (ids flow through python ints)."""

    def __init__(self, n_inputs: int):
        self.n_inputs = n_inputs
        self.ops: list[int] = []
        self.i0: list[int] = []
        self.i1: list[int] = []

    def gate(self, op: int, a: int, b: int | None = None) -> int:
        self.ops.append(int(op))
        self.i0.append(int(a))
        self.i1.append(int(b if b is not None else a))
        return self.n_inputs + len(self.ops) - 1

    def const(self, v: int) -> int:
        return self.gate(Gate.CONST1 if v else Gate.CONST0, 0)

    def half_adder(self, a: int, b: int) -> tuple[int, int]:
        return self.gate(Gate.XOR, a, b), self.gate(Gate.AND, a, b)

    # -- composition hooks (used by compose_pcc and repro.compile) ----------
    def inline(self, nl: "Netlist", input_map: list[int]) -> list[int]:
        """Splice `nl`'s gates into this builder.

        `input_map[i]` is the id (in this builder) feeding `nl`'s input i;
        returns the ids of `nl`'s outputs in this builder.  Extra map entries
        are ignored, so callers can pass a shared padded map.
        """
        if len(input_map) < nl.n_inputs:
            raise ValueError(
                f"input_map has {len(input_map)} ids, netlist needs {nl.n_inputs}")
        remap = [int(i) for i in input_map[: nl.n_inputs]]
        for g in range(nl.n_gates):
            remap.append(self.gate(int(nl.op[g]), remap[nl.in0[g]],
                                   remap[nl.in1[g]]))
        return [remap[int(i)] for i in nl.outputs]

    def geq(self, a_bits: list[int], b_bits: list[int]) -> int:
        """Unsigned comparator a >= b over equal-length LSB-first bit ids."""
        if len(a_bits) != len(b_bits) or not a_bits:
            raise ValueError("geq needs equal-length non-empty bit lists")
        ge = self.gate(Gate.ORN, a_bits[0], b_bits[0])  # a0 OR NOT b0
        for k in range(1, len(a_bits)):
            gt = self.gate(Gate.ANDN, a_bits[k], b_bits[k])
            eq = self.gate(Gate.XNOR, a_bits[k], b_bits[k])
            keep = self.gate(Gate.AND, eq, ge)
            ge = self.gate(Gate.OR, gt, keep)
        return ge

    def full_adder(self, a: int, b: int, c: int) -> tuple[int, int]:
        x = self.gate(Gate.XOR, a, b)
        s = self.gate(Gate.XOR, x, c)
        g1 = self.gate(Gate.AND, a, b)
        g2 = self.gate(Gate.AND, x, c)
        cout = self.gate(Gate.OR, g1, g2)
        return s, cout

    def finish(self, outputs: list[int], name: str = "", meta: dict | None = None) -> Netlist:
        nl = Netlist(
            n_inputs=self.n_inputs,
            op=np.array(self.ops, dtype=np.int16),
            in0=np.array(self.i0, dtype=np.int32),
            in1=np.array(self.i1, dtype=np.int32),
            outputs=np.array(outputs, dtype=np.int32),
            name=name,
            meta=meta or {},
        )
        nl.validate()
        return nl


def popcount_width(n: int) -> int:
    """Output bits needed to represent popcount of n inputs (0..n)."""
    return max(1, int(np.ceil(np.log2(n + 1))))


def _reduce_counter(b: _Builder, bits: list[int]) -> list[int]:
    """Sum a list of equal-weight bits into a binary number (LSB-first ids).

    Classic carry-save counter tree: fold triples through full adders, pairs
    through half adders, recursing on the carries at the next weight.
    """
    layers: dict[int, list[int]] = {0: list(bits)}
    result: list[int] = []
    w = 0
    while any(layers.get(k) for k in layers if k >= w):
        cur = layers.setdefault(w, [])
        while len(cur) >= 3:
            s, co = b.full_adder(cur.pop(), cur.pop(), cur.pop())
            cur.append(s)
            layers.setdefault(w + 1, []).append(co)
        if len(cur) == 2:
            s, co = b.half_adder(cur.pop(), cur.pop())
            cur.append(s)
            layers.setdefault(w + 1, []).append(co)
        result.append(cur[0] if cur else b.const(0))
        w += 1
        if w > 64:
            raise RuntimeError("counter runaway")
    return result


def popcount_netlist(n: int) -> Netlist:
    """Exact n-input popcount as a carry-save adder tree."""
    b = _Builder(n)
    outs = _reduce_counter(b, list(range(n)))
    m = popcount_width(n)
    while len(outs) < m:
        outs.append(b.const(0))
    return b.finish(outs[:m], name=f"pc{n}_exact", meta={"n": n, "exact": True})


def truncated_popcount_netlist(n: int, drop: int) -> Netlist:
    """Truncation baseline (Fig. 4): ignore the last `drop` inputs and add
    a constant compensation of drop/2 (round-to-nearest expected value)."""
    b = _Builder(n)
    outs = _reduce_counter(b, list(range(n - drop)))
    m = popcount_width(n)
    comp = drop // 2
    # add constant comp via wiring const-1s into the counter would be wasteful;
    # instead add comp as extra const bits (synthesizable: they fold away).
    if comp:
        cbits = []
        for k in range(m):
            if (comp >> k) & 1:
                cbits.append((k, b.const(1)))
        # ripple-add the constant
        res = list(outs) + [b.const(0)] * (m - len(outs))
        carry = None
        for k in range(m):
            addend = None
            for kk, cid in cbits:
                if kk == k:
                    addend = cid
            terms = [t for t in (res[k] if k < len(res) else None, addend, carry) if t is not None]
            if len(terms) == 3:
                s, carry = b.full_adder(*terms)
            elif len(terms) == 2:
                s, carry = b.half_adder(*terms)
            else:
                s, carry = (terms[0] if terms else b.const(0)), None
            if k < len(res):
                res[k] = s
            else:
                res.append(s)
        outs = res
    m = popcount_width(n)
    while len(outs) < m:
        outs.append(b.const(0))
    return b.finish(outs[:m], name=f"pc{n}_trunc{drop}", meta={"n": n, "drop": drop})


def comparator_geq_netlist(j: int) -> Netlist:
    """j-bit unsigned comparator: out = (a >= b).

    Inputs: a_0..a_{j-1} (ids 0..j-1, LSB first), b_0..b_{j-1} (ids j..2j-1).
    """
    b = _Builder(2 * j)
    ge = b.geq(list(range(j)), list(range(j, 2 * j)))
    return b.finish([ge], name=f"cmp_geq{j}", meta={"j": j})


def compose_pcc(pc_pos: Netlist, pc_neg: Netlist, n_pos: int, n_neg: int) -> Netlist:
    """Popcount-compare circuit: out = (pc_pos(x_pos) >= pc_neg(x_neg)).

    Inputs: first n_pos bits then n_neg bits.  The two PC netlists are
    inlined, zero-extended to a common width j, followed by the comparator.
    """
    j = max(popcount_width(n_pos), popcount_width(n_neg))
    b = _Builder(n_pos + n_neg)
    pos_out = b.inline(pc_pos, list(range(n_pos)))
    neg_out = b.inline(pc_neg, list(range(n_pos, n_pos + n_neg)))
    zero = None

    def pad(bits: list[int]) -> list[int]:
        nonlocal zero
        while len(bits) < j:
            if zero is None:
                zero = b.const(0)
            bits.append(zero)
        return bits[:j]

    a_bits = pad(pos_out)
    b_bits = pad(neg_out)
    ge = b.geq(a_bits, b_bits)
    nl = b.finish(
        [ge],
        name=f"pcc_{n_pos}x{n_neg}[{pc_pos.name},{pc_neg.name}]",
        meta={"n_pos": n_pos, "n_neg": n_neg, "pos": pc_pos.name, "neg": pc_neg.name},
    )
    return nl


# ---------------------------------------------------------------------------
# Test-vector generation (the BDD stand-in)
# ---------------------------------------------------------------------------
def pack_vectors(vectors: np.ndarray) -> np.ndarray:
    """Pack boolean test vectors (..., S, n) into uint64 words (..., n, ceil(S/64)).

    Vector s lands in bit (s % 64) of word (s // 64).  Leading batch axes
    (e.g. one vector set per population member) pass through unchanged.
    """
    *lead, S, n = vectors.shape
    W = (S + 63) // 64
    padded = np.zeros((*lead, W * 64, n), dtype=np.uint8)
    padded[..., :S, :] = vectors.astype(np.uint8)
    # bit k of word w <- vector w*64+k  => within each 64 block, LSB-first
    blocks = padded.reshape(*lead, W, 64, n)
    weights = (np.uint64(1) << np.arange(64, dtype=np.uint64))[:, None]
    words = (blocks.astype(np.uint64) * weights).sum(axis=-2, dtype=np.uint64)
    return np.ascontiguousarray(np.swapaxes(words, -1, -2))


def exhaustive_vectors(n: int) -> np.ndarray:
    """All 2^n input vectors, packed: (n, 2^n/64) uint64."""
    if n > 22:
        raise ValueError("exhaustive sweep limited to n<=22")
    S = 1 << n
    idx = np.arange(S, dtype=np.uint64)
    vecs = ((idx[:, None] >> np.arange(n, dtype=np.uint64)[None, :]) & np.uint64(1)).astype(np.uint8)
    return pack_vectors(vecs)


def stratified_vectors(n: int, n_samples: int, seed: int = 0) -> np.ndarray:
    """Hamming-weight-stratified random vectors for n > exhaustive limit.

    Popcount-circuit error depends on input weight, so uniform-bit sampling
    under-covers extreme weights; stratify ~uniformly over weights 0..n plus
    a uniform-bit tail (mirrors the paper's 1e6-random-pair methodology).
    """
    rng = np.random.default_rng(seed)
    per_w = max(1, n_samples // (2 * (n + 1)))
    rows = []
    for w in range(n + 1):
        m = np.zeros((per_w, n), dtype=np.uint8)
        for r in range(per_w):
            m[r, rng.choice(n, size=w, replace=False)] = 1
        rows.append(m)
    n_tail = max(0, n_samples - per_w * (n + 1))
    if n_tail:
        rows.append((rng.random((n_tail, n)) < 0.5).astype(np.uint8))
    vecs = np.concatenate(rows, axis=0)
    return pack_vectors(vecs)


def eval_vectors(n: int, exhaustive_limit: int = 16, n_samples: int = 1 << 17,
                 seed: int = 0) -> tuple[np.ndarray, np.ndarray]:
    """(packed_inputs, true_popcounts) for error evaluation of an n-bit PC."""
    if n <= exhaustive_limit:
        packed = exhaustive_vectors(n)
        S = 1 << n
        idx = np.arange(S, dtype=np.uint64)
        true = np.zeros(S, dtype=np.int64)
        for k in range(n):
            true += ((idx >> np.uint64(k)) & np.uint64(1)).astype(np.int64)
        # pad up to word multiple with vector 0 replicas (weight 0)
        W = packed.shape[1]
        if W * 64 > S:
            true = np.concatenate([true, np.zeros(W * 64 - S, dtype=np.int64)])
        return packed, true
    packed = stratified_vectors(n, n_samples, seed)
    true = popcount_of_packed(packed)
    return packed, true


def popcount_of_packed(packed: np.ndarray) -> np.ndarray:
    """True per-vector popcount from packed inputs (n, W) -> (W*64,)."""
    n, W = packed.shape
    bits = np.unpackbits(np.ascontiguousarray(packed).view(np.uint8)
                         .reshape(n, W * 8), axis=-1, bitorder="little")
    return bits.sum(axis=0).astype(np.int64)


def pc_error(nl: Netlist, packed: np.ndarray, true: np.ndarray) -> tuple[float, float]:
    """(mean_abs_error, worst_case_abs_error) of a popcount netlist."""
    approx = nl.eval_uint(packed)
    err = np.abs(approx - true)
    return float(err.mean()), float(err.max())
