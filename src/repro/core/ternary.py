"""Ternary / binary quantizers + the ABC input interface — JAX.

Faithful mode (the paper, Sec. 3.2.1):
  * weights  -> ternary {-1, 0, 1} via a fixed threshold (qkeras `ternary`
    with alpha=1; default threshold 1/3),
  * hidden activations -> binary step on the popcount sum ({-1,+1} encoding),
  * first-layer inputs -> ABC binarization at the per-feature *median* of the
    normalized training distribution (V_q; not learnable).

LM mode (framework scale, BitNet-b1.58-style): same ternary codes plus a
per-output-channel scale alpha = mean|W| so large transformers train stably.
Both share the 2-bit packing used by the Pallas serving kernel.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

TERNARY_THRESHOLD = 1.0 / 3.0


# ---------------------------------------------------------------------------
# Quantizers (straight-through estimators)
# ---------------------------------------------------------------------------
def ternarize(w: jax.Array, threshold: float = TERNARY_THRESHOLD) -> jax.Array:
    """Hard ternarization to {-1, 0, +1} (no gradient)."""
    return jnp.sign(w) * (jnp.abs(w) > threshold)


def ternary_ste(w: jax.Array, threshold: float = TERNARY_THRESHOLD) -> jax.Array:
    """Ternary forward, identity backward inside [-1, 1] (clipped STE)."""
    q = ternarize(w, threshold)
    # gradient window: pass-through where the latent weight is in [-1, 1]
    gate = (jnp.abs(w) <= 1.0).astype(w.dtype)
    return w * gate + jax.lax.stop_gradient(q - w * gate)


def binary_step_ste(a: jax.Array, grad_width: float = 1.0) -> jax.Array:
    """sign(a) in {-1,+1} with a>=0 -> +1; hard-tanh surrogate gradient.

    Matches the hardware comparator semantics (sum >= 0 -> output 1).
    """
    h = jnp.where(a >= 0, 1.0, -1.0).astype(a.dtype)
    surrogate = jnp.clip(a / grad_width, -1.0, 1.0)
    return surrogate + jax.lax.stop_gradient(h - surrogate)


def ternary_quantize_lm(w: jax.Array) -> tuple[jax.Array, jax.Array]:
    """BitNet-style absmean ternarization: returns (codes {-1,0,1}, scale).

    scale alpha is per-output-channel (last dim); W ~= alpha * codes.
    """
    alpha = jnp.mean(jnp.abs(w), axis=tuple(range(w.ndim - 1)), keepdims=True) + 1e-8
    codes = jnp.clip(jnp.round(w / alpha), -1, 1)
    return codes, alpha


def ternary_ste_lm(w: jax.Array) -> jax.Array:
    """Absmean-scaled ternary forward with STE backward (LM training path)."""
    codes, alpha = ternary_quantize_lm(w)
    q = codes * alpha
    return w + jax.lax.stop_gradient(q - w)


# ---------------------------------------------------------------------------
# ABC — analog-to-binary converter (Sec. 3.1)
# ---------------------------------------------------------------------------
def abc_fit_thresholds(x_train: np.ndarray) -> np.ndarray:
    """Per-feature V_q = median of the normalized training distribution.

    In hardware, V_q is realized by the R1/R2 divider ratio of each ABC.
    """
    return np.median(x_train, axis=0)


def abc_binarize(x: jax.Array | np.ndarray, thresholds: np.ndarray) -> jax.Array:
    """Comparator output: 1 when the sensor voltage exceeds V_q."""
    return (jnp.asarray(x) > jnp.asarray(thresholds)[None, :]).astype(jnp.float32)


# ---------------------------------------------------------------------------
# 2-bit packing (shared by core + Pallas serving kernels)
#   code 0b00 -> 0, 0b01 -> +1, 0b10 -> -1 ; 4 codes per int8, along axis 0 (K)
# ---------------------------------------------------------------------------
def pack_ternary(codes: jax.Array) -> jax.Array:
    """Pack {-1,0,1} codes (K, N) -> (K//4, N) int8.  K must be %4 == 0."""
    K = codes.shape[0]
    if K % 4:
        raise ValueError(f"K={K} not a multiple of 4")
    u = jnp.where(codes > 0, 1, jnp.where(codes < 0, 2, 0)).astype(jnp.uint8)
    u = u.reshape(K // 4, 4, *codes.shape[1:])
    packed = (u[:, 0] | (u[:, 1] << 2) | (u[:, 2] << 4) | (u[:, 3] << 6))
    return packed.astype(jnp.int8)


def unpack_ternary(packed: jax.Array, dtype=jnp.float32) -> jax.Array:
    """Inverse of `pack_ternary`: (K//4, N) int8 -> (K, N) dtype in {-1,0,1}."""
    u = packed.astype(jnp.uint8)
    parts = [(u >> (2 * i)) & 0x3 for i in range(4)]
    stacked = jnp.stack(parts, axis=1)           # (K//4, 4, N...)
    vals = (stacked == 1).astype(dtype) - (stacked == 2).astype(dtype)
    return vals.reshape(-1, *packed.shape[1:])


def zero_fraction(codes: jax.Array) -> jax.Array:
    """Sparsity of a ternary tensor — drives the paper's wire-removal gains."""
    return jnp.mean((codes == 0).astype(jnp.float32))
