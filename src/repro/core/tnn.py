"""Bespoke ternary neural networks (Sec. 3.2) — QAT + circuit-accurate path.

Semantics (and the invariant the tests pin down):

  hidden neuron i :  h'_i = +1  iff  sum_{w=+1} x - sum_{w=-1} x >= 0
                     == PCC( x[w=+1], x[w=-1] )            (Eq. 2)
  output neuron o :  score_o = #XNOR matches = (logits_o + nnz_o) / 2
                     where logits_o = sum_i w_io h'_i
  With zero counts balanced across output neurons (same N), nnz_o is the
  same constant, so  argmax(score) == argmax(logits)  — exactly the paper's
  +N/2 correction-term argument.  Hence the JAX training forward and the
  integer circuit path must produce identical predictions (tested).
"""
from __future__ import annotations

from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import circuits as C
from repro.core.nsga2 import NSGA2Config, NSGA2Result, nsga2
from repro.core.pcc import PCCLibrary, PCCEntry
from repro.core.ternary import (
    TERNARY_THRESHOLD,
    abc_binarize,
    abc_fit_thresholds,
    binary_step_ste,
    ternarize,
    ternary_ste,
)
from repro.data.tabular import TabularDataset
from repro.hw.egfet import Gate, HwCost, gate_cost, interface_cost
from repro.optim import adamw


# ---------------------------------------------------------------------------
# Training (QAT)
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class TNNTrainConfig:
    n_hidden: int
    epochs: int = 15            # paper: 10-20
    lr: float = 5e-3            # paper: 1e-3..1e-2 (Bayesian-opt'd)
    batch_size: int = 64
    seed: int = 0
    threshold: float = TERNARY_THRESHOLD
    weight_decay: float = 0.0


@dataclass
class TrainedTNN:
    w1t: np.ndarray             # (F, H) int8 ternary codes
    w2t: np.ndarray             # (H, C) int8, zero-balanced columns
    thresholds: np.ndarray      # (F,) ABC V_q per feature
    train_acc: float
    test_acc: float
    name: str = ""

    @property
    def topology(self) -> tuple[int, int, int]:
        return (self.w1t.shape[0], self.w1t.shape[1], self.w2t.shape[1])

    def hidden_sizes(self) -> list[tuple[int, int]]:
        return [(int((self.w1t[:, i] == 1).sum()), int((self.w1t[:, i] == -1).sum()))
                for i in range(self.w1t.shape[1])]

    @property
    def out_nnz(self) -> int:
        """Non-zero inputs per output neuron (equal across neurons)."""
        nnz = (self.w2t != 0).sum(axis=0)
        assert (nnz == nnz[0]).all(), "output zero counts not balanced"
        return int(nnz[0])


def _forward_logits(params, xbin, threshold):
    w1q = ternary_ste(params["w1"], threshold)
    a = xbin @ w1q
    # surrogate-gradient window scaled to the integer popcount-sum magnitude,
    # otherwise hidden units saturate and w1 receives no learning signal
    h = binary_step_ste(a, grad_width=jnp.sqrt(float(xbin.shape[-1])))
    w2q = ternary_ste(params["w2"], threshold)
    return h @ w2q, h


def _loss_fn(params, xbin, y, threshold, n_hidden):
    logits, _ = _forward_logits(params, xbin, threshold)
    logits = logits / jnp.sqrt(float(n_hidden))
    logp = jax.nn.log_softmax(logits, axis=-1)
    return -jnp.mean(jnp.take_along_axis(logp, y[:, None], axis=1))


def balance_zero_counts(w2_latent: np.ndarray, threshold: float) -> np.ndarray:
    """Ternarize output weights and equalize per-column zero counts.

    The paper requires the same number N of zero-valued connections in every
    output neuron so the +N/2 correction term cancels in the argmax.  We
    project to N* = median zero count, moving the least-important weights:
      * columns with too few zeros: demote smallest-|latent| nonzeros to 0,
      * columns with too many zeros: promote largest-|latent| zeros to +-1.
    (Projecting to max() instead can zero out entire columns — catastrophic
    for narrow TNNs; see tests/test_tnn.py::test_balance_preserves_accuracy.)
    """
    codes = np.asarray(ternarize(jnp.asarray(w2_latent), threshold)).astype(np.int8)
    zeros = (codes == 0).sum(axis=0)
    N = int(np.median(zeros))
    for o in range(codes.shape[1]):
        delta = N - int(zeros[o])
        if delta > 0:        # need more zeros: demote weakest nonzeros
            nz = np.where(codes[:, o] != 0)[0]
            order = nz[np.argsort(np.abs(w2_latent[nz, o]), kind="stable")]
            codes[order[:delta], o] = 0
        elif delta < 0:      # need fewer zeros: promote strongest zeros
            z = np.where(codes[:, o] == 0)[0]
            order = z[np.argsort(-np.abs(w2_latent[z, o]), kind="stable")]
            for r in order[: -delta]:
                s = np.sign(w2_latent[r, o])
                codes[r, o] = np.int8(s if s != 0 else 1)
    return codes


def train_tnn(ds: TabularDataset, cfg: TNNTrainConfig) -> TrainedTNN:
    """Quantization-aware training of a (F, H, C) bespoke TNN."""
    thresholds = abc_fit_thresholds(ds.x_train)
    xb_tr = np.asarray(abc_binarize(ds.x_train, thresholds))
    xb_te = np.asarray(abc_binarize(ds.x_test, thresholds))
    F, H, Cc = ds.spec.n_features, cfg.n_hidden, ds.spec.n_classes

    rng = np.random.default_rng(cfg.seed)
    params = {
        "w1": jnp.asarray(rng.normal(0, 0.7, size=(F, H)), jnp.float32),
        "w2": jnp.asarray(rng.normal(0, 0.7, size=(H, Cc)), jnp.float32),
    }
    ocfg = adamw.AdamWConfig(lr=cfg.lr, weight_decay=cfg.weight_decay, grad_clip=1.0)
    ostate = adamw.init(params)

    @jax.jit
    def step(params, ostate, xb, y):
        loss, grads = jax.value_and_grad(_loss_fn)(params, xb, y, cfg.threshold, H)
        params, ostate = adamw.apply_updates(params, grads, ostate, ocfg)
        return params, ostate, loss

    n = xb_tr.shape[0]
    xb_j, y_j = jnp.asarray(xb_tr), jnp.asarray(ds.y_train.astype(np.int32))
    for epoch in range(cfg.epochs):
        perm = rng.permutation(n)
        for s in range(0, n, cfg.batch_size):
            idx = perm[s:s + cfg.batch_size]
            params, ostate, _ = step(params, ostate, xb_j[idx], y_j[idx])

    w1t = np.asarray(ternarize(params["w1"], cfg.threshold)).astype(np.int8)
    w2t = balance_zero_counts(np.asarray(params["w2"]), cfg.threshold)
    tnn = TrainedTNN(w1t=w1t, w2t=w2t, thresholds=thresholds,
                     train_acc=0.0, test_acc=0.0, name=ds.name)
    tnn.train_acc = float((predict_exact(tnn, xb_tr) == ds.y_train).mean())
    tnn.test_acc = float((predict_exact(tnn, xb_te) == ds.y_test).mean())
    return tnn


def search_tnn(ds: TabularDataset, hidden_options: list[int],
               lr_options: list[float] | None = None, seeds: tuple[int, ...] = (0, 1),
               epochs: int = 15) -> TrainedTNN:
    """Scaled-down version of the paper's exhaustive/Bayesian hyperparameter
    search (Sec. 5): best test accuracy, ties broken by fewer neurons."""
    lrs = lr_options or [2e-3, 5e-3, 1e-2]
    best: TrainedTNN | None = None
    for h in hidden_options:
        for lr in lrs:
            for seed in seeds:
                t = train_tnn(ds, TNNTrainConfig(n_hidden=h, lr=lr, seed=seed,
                                                 epochs=epochs))
                if (best is None or t.test_acc > best.test_acc + 1e-9
                        or (abs(t.test_acc - best.test_acc) <= 1e-9
                            and t.w1t.shape[1] < best.w1t.shape[1])):
                    best = t
    assert best is not None
    return best


# ---------------------------------------------------------------------------
# Circuit-accurate integer inference
# ---------------------------------------------------------------------------
def predict_exact(tnn: TrainedTNN, xbin: np.ndarray) -> np.ndarray:
    """Exact integer path (popcounts + comparators), vectorized in numpy."""
    x = xbin.astype(np.int64)
    w1 = tnn.w1t.astype(np.int64)
    a = x @ w1
    hbit = (a >= 0).astype(np.int64)                      # {0,1}
    w2 = tnn.w2t.astype(np.int64)
    # score_o = sum_{w=+1} h + sum_{w=-1} (1-h)
    score = hbit @ (w2 == 1) + (1 - hbit) @ (w2 == -1)
    return np.argmax(score, axis=1).astype(np.int32)


def hidden_exact_netlist(n_pos: int, n_neg: int) -> C.Netlist:
    """Exact PCC for one hidden neuron, incl. degenerate shapes."""
    if n_neg == 0:
        # sum_pos >= 0 is always true -> constant 1 (zero hardware)
        b = C._Builder(max(n_pos, 1))
        one = b.const(1)
        return b.finish([one], name=f"pcc_{n_pos}x0_const1")
    if n_pos == 0:
        # 0 >= sum_neg  iff  all neg inputs are 0  ->  NOR tree
        b = C._Builder(n_neg)
        acc = 0
        for i in range(1, n_neg):
            acc = b.gate(Gate.OR, acc, i)
        out = b.gate(Gate.NOT, acc) if n_neg > 1 else b.gate(Gate.NOT, 0)
        return b.finish([out], name=f"pcc_0x{n_neg}_nor")
    return C.compose_pcc(C.popcount_netlist(n_pos), C.popcount_netlist(n_neg),
                         n_pos, n_neg)


def _hidden_inputs(tnn: TrainedTNN, xbin: np.ndarray, i: int) -> np.ndarray:
    """Concatenated [pos..., neg...] input matrix (S, n_pos+n_neg) for neuron i."""
    col = tnn.w1t[:, i]
    pos = xbin[:, col == 1]
    neg = xbin[:, col == -1]
    return np.concatenate([pos, neg], axis=1)


def _output_bits(tnn: TrainedTNN, hbits: np.ndarray, o: int) -> np.ndarray:
    """XNOR-simplified input bits (S, nnz) for output neuron o."""
    col = tnn.w2t[:, o]
    plus = hbits[:, col == 1]              # wire
    minus = 1 - hbits[:, col == -1]        # NOT gate
    return np.concatenate([plus, minus], axis=1)


def predict_with_circuits(tnn: TrainedTNN, xbin: np.ndarray,
                          hidden_nls: list[C.Netlist],
                          out_nls: list[C.Netlist]) -> np.ndarray:
    """Inference through explicit (possibly approximate) netlists."""
    S = xbin.shape[0]
    H = tnn.w1t.shape[1]
    hbits = np.empty((S, H), dtype=np.uint8)
    for i in range(H):
        sizes = tnn.hidden_sizes()[i]
        if sizes == (0, 0):
            hbits[:, i] = 1
            continue
        inp = _hidden_inputs(tnn, xbin, i)
        packed = C.pack_vectors(inp)
        hbits[:, i] = hidden_nls[i].eval_uint(packed)[:S].astype(np.uint8)
    Cc = tnn.w2t.shape[1]
    scores = np.empty((S, Cc), dtype=np.int64)
    for o in range(Cc):
        bits = _output_bits(tnn, hbits, o)
        if bits.shape[1] == 0:
            scores[:, o] = 0
            continue
        packed = C.pack_vectors(bits)
        scores[:, o] = out_nls[o].eval_uint(packed)[:S]
    return np.argmax(scores, axis=1).astype(np.int32)


def exact_netlists(tnn: TrainedTNN) -> tuple[list[C.Netlist], list[C.Netlist]]:
    hidden = [hidden_exact_netlist(p, n) for (p, n) in tnn.hidden_sizes()]
    out = [C.popcount_netlist(max(tnn.out_nnz, 1))] * tnn.w2t.shape[1]
    return hidden, out


# ---------------------------------------------------------------------------
# Hardware cost accounting (EGFET)
# ---------------------------------------------------------------------------
def argmax_cost(n_classes: int, score_bits: int) -> HwCost:
    """(C-1) comparators + (C-1) score-wide 2:1 muxes (value propagation)."""
    cmp_cost = C.comparator_geq_netlist(score_bits).cost()
    mux_bit = gate_cost(Gate.AND) + gate_cost(Gate.ANDN) + gate_cost(Gate.OR)
    total = HwCost(0.0, 0.0)
    for _ in range(n_classes - 1):
        total = total + cmp_cost + mux_bit.scale(score_bits)
    return total


def tnn_hw_cost(tnn: TrainedTNN,
                hidden_nls: list[C.Netlist],
                out_nls: list[C.Netlist],
                interface: str | None = "abc") -> HwCost:
    """Full-system cost: neurons + output NOT gates + argmax + interface."""
    total = HwCost(0.0, 0.0)
    for nl in hidden_nls:
        total = total + nl.cost()
    for nl in out_nls:
        total = total + nl.cost()
    n_not = int((tnn.w2t == -1).sum())          # XNOR -> NOT for w = -1
    total = total + gate_cost(Gate.NOT).scale(n_not)
    total = total + argmax_cost(tnn.w2t.shape[1],
                                C.popcount_width(max(tnn.out_nnz, 1)))
    if interface:
        total = total + interface_cost(tnn.w1t.shape[0], interface)
    return total


# ---------------------------------------------------------------------------
# Phase 3 — NSGA-II integration problem
# ---------------------------------------------------------------------------
@dataclass
class TNNApproxProblem:
    """Integer-chromosome encoding: one gene per non-degenerate hidden neuron
    (PCC library index) + one gene per output neuron (PC library index)."""

    tnn: TrainedTNN
    pcc_lib: PCCLibrary
    pc_out_lib: list[C.Netlist]
    xbin: np.ndarray
    y: np.ndarray
    # gate-simulation executor for the population-batched output plane:
    # "np" (NetlistPopulation reference), "swar" (lax.scan uint32 twin) or
    # "pallas" (kernels.pallas_circuit_sim) — all bit-identical, see
    # kernels.dispatch / tests/test_conformance.py
    eval_backend: str = "np"
    # derived
    hidden_idx: list[int] = field(default_factory=list)     # non-degenerate neurons
    hidden_cands: list[list[PCCEntry]] = field(default_factory=list)
    hidden_bit_cache: list[np.ndarray] = field(default_factory=list)  # (n_cand, S) u8
    fixed_hbits: np.ndarray | None = None                    # (S, H) exact base
    fixed_cost: HwCost = field(default_factory=lambda: HwCost(0, 0))

    def __post_init__(self):
        S = self.xbin.shape[0]
        H = self.tnn.w1t.shape[1]
        sizes = self.tnn.hidden_sizes()
        self.fixed_hbits = np.empty((S, H), dtype=np.uint8)
        for i, (p, n) in enumerate(sizes):
            if p >= 1 and n >= 1 and (p, n) in self.pcc_lib.entries:
                cands = self.pcc_lib.get(p, n)
                self.hidden_idx.append(i)
                self.hidden_cands.append(cands)
                inp = C.pack_vectors(_hidden_inputs(self.tnn, self.xbin, i))
                cache = np.empty((len(cands), S), dtype=np.uint8)
                for k, e in enumerate(cands):
                    cache[k] = e.compose().eval_uint(inp)[:S].astype(np.uint8)
                self.hidden_bit_cache.append(cache)
                self.fixed_hbits[:, i] = cache[0]            # exact = index 0
            else:
                nl = hidden_exact_netlist(p, n)
                self.fixed_cost = self.fixed_cost + nl.cost()
                if (p, n) == (0, 0) or n == 0:
                    self.fixed_hbits[:, i] = 1
                else:
                    inp = C.pack_vectors(_hidden_inputs(self.tnn, self.xbin, i))
                    self.fixed_hbits[:, i] = nl.eval_uint(inp)[:S].astype(np.uint8)
        # output candidates: Pareto PC library for size out_nnz
        self.out_cands = self.pc_out_lib
        # fixed costs independent of gene choices
        self.fixed_cost = (self.fixed_cost
                           + gate_cost(Gate.NOT).scale(int((self.tnn.w2t == -1).sum()))
                           + argmax_cost(self.tnn.w2t.shape[1],
                                         C.popcount_width(max(self.tnn.out_nnz, 1))))
        # batched-objective caches: per-gene candidate areas + one padded
        # population over the output PC candidates (row-selected per genome)
        self._hidden_gene_areas = [np.array([e.est_area for e in cands])
                                   for cands in self.hidden_cands]
        self._out_areas = np.array([nl.cost().area_mm2 for nl in self.out_cands])
        self._out_pop = C.NetlistPopulation.from_netlists(self.out_cands)

    # -- chromosome layout ---------------------------------------------------
    @property
    def n_genes(self) -> int:
        return len(self.hidden_idx) + self.tnn.w2t.shape[1]

    def domains(self) -> np.ndarray:
        d = [len(c) for c in self.hidden_cands]
        d += [len(self.out_cands)] * self.tnn.w2t.shape[1]
        return np.array(d, dtype=np.int64)

    def decode(self, x: np.ndarray) -> tuple[list[C.Netlist], list[C.Netlist]]:
        """Chromosome -> full netlist selection (for reporting/synthesis)."""
        sizes = self.tnn.hidden_sizes()
        hidden_nls: list[C.Netlist] = []
        gi = 0
        for i, (p, n) in enumerate(sizes):
            if i in self.hidden_idx:
                e = self.hidden_cands[self.hidden_idx.index(i)][int(x[gi])]
                hidden_nls.append(e.compose())
                gi += 1
            else:
                hidden_nls.append(hidden_exact_netlist(p, n))
        out_nls = [self.out_cands[int(g)] for g in x[len(self.hidden_idx):]]
        return hidden_nls, out_nls

    # -- objectives ------------------------------------------------------------
    def _eval_one(self, x: np.ndarray) -> tuple[float, float]:
        S = self.xbin.shape[0]
        hbits = self.fixed_hbits.copy()
        est_area = self.fixed_cost.area_mm2
        for g, (i, cands, cache) in enumerate(zip(self.hidden_idx,
                                                  self.hidden_cands,
                                                  self.hidden_bit_cache)):
            k = int(x[g])
            hbits[:, i] = cache[k]
            est_area += cands[k].est_area
        Cc = self.tnn.w2t.shape[1]
        scores = np.empty((S, Cc), dtype=np.int64)
        for o in range(Cc):
            nl = self.out_cands[int(x[len(self.hidden_idx) + o])]
            est_area += nl.cost().area_mm2
            bits = _output_bits(self.tnn, hbits, o)
            if bits.shape[1] == 0:
                scores[:, o] = 0
            else:
                scores[:, o] = nl.eval_uint(C.pack_vectors(bits))[:S]
        acc = float((np.argmax(scores, axis=1) == self.y).mean())
        return 1.0 - acc, est_area

    def objective(self, pop: np.ndarray) -> np.ndarray:
        """Population-parallel objectives: (N, n_genes) int -> (N, 2).

        Hidden-gene bits come from the per-candidate caches via one gather;
        every output neuron is scored for the whole population in a single
        `NetlistPopulation` pass over per-individual packed inputs.  Matches
        `_eval_one` (the serial reference) bit-for-bit.
        """
        pop = np.asarray(pop, dtype=np.int64)
        P = pop.shape[0]
        S = self.xbin.shape[0]
        est = np.full(P, self.fixed_cost.area_mm2)
        hbits = np.repeat(self.fixed_hbits[None], P, axis=0)     # (P, S, H)
        for g, cache in enumerate(self.hidden_bit_cache):
            hbits[:, :, self.hidden_idx[g]] = cache[pop[:, g]]
            est = est + self._hidden_gene_areas[g][pop[:, g]]
        nh = len(self.hidden_idx)
        Cc = self.tnn.w2t.shape[1]
        scores = np.empty((P, S, Cc), dtype=np.int64)
        for o in range(Cc):
            k = pop[:, nh + o]
            est = est + self._out_areas[k]
            col = self.tnn.w2t[:, o]
            bits = np.concatenate([hbits[:, :, col == 1],
                                   1 - hbits[:, :, col == -1]], axis=2)
            if bits.shape[2] == 0:
                scores[:, :, o] = 0
                continue
            packed = C.pack_vectors(bits)                        # (P, nnz, W)
            sub = self._out_pop.take(k)
            if self.eval_backend == "np":
                scores[:, :, o] = sub.eval_uint(packed)[:, :S]
            else:
                from repro.kernels.dispatch import population_eval_pop
                scores[:, :, o] = population_eval_pop(
                    sub, packed, backend=self.eval_backend)[:, :S]
        acc = (np.argmax(scores, axis=2) == self.y[None, :]).mean(axis=1)
        return np.stack([1.0 - acc, est], axis=1)

    def optimize(self, cfg: NSGA2Config) -> NSGA2Result:
        seed = np.zeros((1, self.n_genes), dtype=np.int64)   # all-exact individual
        return nsga2(self.domains(), self.objective, cfg, seed_population=seed)
