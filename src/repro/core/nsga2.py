"""Phase 3 — NSGA-II multi-objective integration (Deb et al. 2002).

The paper encodes an approximate TNN as an integer chromosome: one gene per
neuron, indexing into that neuron's candidate list (PCC library entries for
hidden neurons, PC library entries for output neurons).  Objectives are
(1 - accuracy, total estimated area), both minimized.  Operators follow the
paper's pymoo setup: simulated-binary crossover + polynomial mutation adapted
to integers (value rounded + clipped to the per-gene domain).

This module is problem-agnostic: `nsga2(...)` takes per-gene domain sizes and
a vectorized objective callback, so tests can drive it on synthetic problems
and `core.tnn` uses it for the real TNN integration.

Stepwise API
------------
`NSGA2Driver` exposes the same algorithm one generation at a time over an
explicit `NSGA2State` (population, objectives, generation counter, RNG).
Everything the next generation depends on lives in the state, so a driver
rebuilt in a fresh process from a checkpointed state continues the *exact*
generation sequence — the substrate for `repro.evolve`'s resumable
island-model campaigns.  `nsga2()` is now a thin wrapper over the driver and
produces bit-identical results to the original monolithic loop.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable

import numpy as np


@dataclass
class NSGA2Config:
    pop_size: int = 40
    n_generations: int = 60
    crossover_prob: float = 0.9
    crossover_eta: float = 15.0
    mutation_eta: float = 20.0
    mutation_prob: float | None = None   # default 1/n_genes
    seed: int = 0
    dedup_eval: bool = True              # memoize duplicate chromosomes


@dataclass
class NSGA2Result:
    pareto_x: np.ndarray     # (P, n_genes) int
    pareto_f: np.ndarray     # (P, 2) objectives
    history: list[tuple[int, float, float]] = field(default_factory=list)
    # history rows: (generation, best obj0 on front, best obj1 on front)


# ---------------------------------------------------------------------------
# Core NSGA-II machinery
# ---------------------------------------------------------------------------
def fast_non_dominated_sort(F: np.ndarray) -> list[np.ndarray]:
    """Return fronts (lists of indices), best first. F: (N, M) minimized."""
    N = F.shape[0]
    # dominates[i, j] = i dominates j
    le = (F[:, None, :] <= F[None, :, :]).all(-1)
    lt = (F[:, None, :] < F[None, :, :]).any(-1)
    dom = le & lt
    n_dominated = dom.sum(0)         # how many dominate each j
    fronts = []
    current = np.where(n_dominated == 0)[0]
    assigned = np.zeros(N, dtype=bool)
    while current.size:
        fronts.append(current)
        assigned[current] = True
        n_dominated = n_dominated - dom[current].sum(0)
        nxt = np.where((n_dominated == 0) & ~assigned)[0]
        current = nxt
    return fronts


def crowding_distance(F: np.ndarray) -> np.ndarray:
    N, M = F.shape
    if N <= 2:
        return np.full(N, np.inf)
    dist = np.zeros(N)
    for m in range(M):
        order = np.argsort(F[:, m], kind="stable")
        fmin, fmax = F[order[0], m], F[order[-1], m]
        dist[order[0]] = dist[order[-1]] = np.inf
        if fmax - fmin > 1e-15:
            dist[order[1:-1]] += (F[order[2:], m] - F[order[:-2], m]) / (fmax - fmin)
    return dist


def _tournament(rank, crowd, rng, k=2):
    cand = rng.integers(rank.shape[0], size=k)
    best = cand[0]
    for c in cand[1:]:
        if (rank[c] < rank[best]) or (rank[c] == rank[best] and crowd[c] > crowd[best]):
            best = c
    return best


def _sbx_int(p1, p2, domains, eta, prob, rng):
    """Integer-adapted simulated binary crossover."""
    c1, c2 = p1.astype(np.float64).copy(), p2.astype(np.float64).copy()
    if rng.random() < prob:
        for i in range(p1.shape[0]):
            if rng.random() < 0.5 and abs(p1[i] - p2[i]) > 1e-12:
                x1, x2 = sorted((float(p1[i]), float(p2[i])))
                u = rng.random()
                beta = (2 * u) ** (1 / (eta + 1)) if u <= 0.5 else (1 / (2 * (1 - u))) ** (1 / (eta + 1))
                c1[i] = 0.5 * ((x1 + x2) - beta * (x2 - x1))
                c2[i] = 0.5 * ((x1 + x2) + beta * (x2 - x1))
    hi = domains.astype(np.float64) - 1
    c1 = np.clip(np.rint(c1), 0, hi).astype(np.int64)
    c2 = np.clip(np.rint(c2), 0, hi).astype(np.int64)
    return c1, c2


def _poly_mutate_int(x, domains, eta, prob, rng):
    y = x.astype(np.float64).copy()
    hi = domains.astype(np.float64) - 1
    for i in range(x.shape[0]):
        if hi[i] <= 0 or rng.random() >= prob:
            continue
        u = rng.random()
        delta = (2 * u) ** (1 / (eta + 1)) - 1 if u < 0.5 else 1 - (2 * (1 - u)) ** (1 / (eta + 1))
        y[i] = y[i] + delta * hi[i]
    return np.clip(np.rint(y), 0, hi).astype(np.int64)


def _memoized(objective: Callable[[np.ndarray], np.ndarray],
              maxsize: int | None = None
              ) -> Callable[[np.ndarray], np.ndarray]:
    """Wrap a batched objective with a bounded chromosome-level LRU cache.

    Integer GAs re-visit identical chromosomes constantly (SBX clones
    parents, elitism carries survivors across generations); with circuit-
    level fitness each duplicate costs a full batched simulation.  Only
    never-seen rows reach the wrapped objective — results are unchanged for
    any row-independent objective (the batched-evaluator contract), and
    LRU eviction (`maxsize`) cannot change them either: an evicted
    chromosome that reappears is simply re-evaluated to the same value.
    `maxsize=None` keeps the cache unbounded (the historical behavior);
    long campaigns should bound it so memory cannot grow with the number
    of distinct chromosomes ever visited.

    `evaluate.cache_info()` reports cumulative hits / misses / evictions
    plus the current size — `Campaign` folds these into its per-epoch
    cache history rows.
    """
    from collections import OrderedDict

    cache: OrderedDict[bytes, np.ndarray] = OrderedDict()
    stats = {"hits": 0, "misses": 0, "evictions": 0}

    def evaluate(X: np.ndarray) -> np.ndarray:
        X = np.ascontiguousarray(X)
        keys = [row.tobytes() for row in X]
        fresh_rows, fresh_keys, seen = [], [], set()
        for i, k in enumerate(keys):
            if k in cache:
                cache.move_to_end(k)
                stats["hits"] += 1
            elif k not in seen:
                seen.add(k)
                fresh_rows.append(i)
                fresh_keys.append(k)
        fresh: dict[bytes, np.ndarray] = {}
        if fresh_rows:
            stats["misses"] += len(fresh_keys)
            F = objective(X[np.array(fresh_rows)])
            for k, f in zip(fresh_keys, F):
                fresh[k] = np.asarray(f, dtype=np.float64)
        # gather BEFORE eviction so a tiny maxsize can never evict a row
        # this very batch still needs
        out = np.stack([cache.get(k, fresh.get(k)) for k in keys])
        cache.update(fresh)
        if maxsize is not None:
            while len(cache) > maxsize:
                cache.popitem(last=False)
                stats["evictions"] += 1
        return out

    def cache_info() -> dict:
        return {**stats, "size": len(cache), "maxsize": maxsize}

    evaluate.cache_clear = cache.clear    # data drifted -> memo is stale
    evaluate.cache_info = cache_info
    return evaluate


# ---------------------------------------------------------------------------
# Stepwise (resumable) API
# ---------------------------------------------------------------------------
def encode_rng_state(rng: np.random.Generator) -> dict:
    """Serialize a Generator's bit-generator state to msgpack-safe types.

    PCG64 carries 128-bit integers, which overflow msgpack's int64 — encode
    every int as a hex string and restore with `decode_rng_state`.
    """
    def enc(v):
        if isinstance(v, dict):
            return {k: enc(x) for k, x in v.items()}
        if isinstance(v, (int, np.integer)):
            return f"0x{int(v):x}"
        return v

    return enc(rng.bit_generator.state)


def decode_rng_state(state: dict) -> np.random.Generator:
    """Inverse of `encode_rng_state`: rebuild a Generator mid-stream."""
    def dec(v):
        if isinstance(v, dict):
            return {k: dec(x) for k, x in v.items()}
        if isinstance(v, str) and v.startswith("0x"):
            return int(v, 16)
        return v

    decoded = dec(state)
    bg = getattr(np.random, decoded["bit_generator"])()
    bg.state = decoded
    return np.random.Generator(bg)


@dataclass
class NSGA2State:
    """Everything generation g+1 depends on.  Checkpoint `pop`/`F` as arrays
    and the RNG via `encode_rng_state` for bit-identical resume."""

    pop: np.ndarray          # (pop_size, n_genes) int chromosomes
    F: np.ndarray            # (pop_size, 2) float objectives
    generation: int
    rng: np.random.Generator
    history: list[tuple[int, float, float]] = field(default_factory=list)


def extract_front(pop: np.ndarray, F: np.ndarray
                  ) -> tuple[np.ndarray, np.ndarray]:
    """Current Pareto front, deduped by objectives and sorted by obj0."""
    fronts = fast_non_dominated_sort(F)
    fr0 = fronts[0]
    # dedupe identical objective rows for a clean reported front
    _, uniq = np.unique(np.round(F[fr0], 10), axis=0, return_index=True)
    sel = fr0[np.sort(uniq)]
    order = np.argsort(F[sel, 0], kind="stable")
    return pop[sel[order]], F[sel[order]]


class NSGA2Driver:
    """One NSGA-II problem instance, advanced one generation at a time.

    The evaluator (with its dedup cache) lives on the driver, not the state:
    the cache is a pure memoization of a row-independent objective, so a
    resumed driver with a cold cache replays the identical trajectory.
    `on_generation(state)` fires after each completed generation — the
    archive hook used by `repro.evolve` to fold island fronts into a global
    Pareto archive without re-evaluating anything.
    """

    def __init__(self, domains: np.ndarray,
                 objective: Callable[[np.ndarray], np.ndarray],
                 cfg: NSGA2Config,
                 evaluate: Callable[[np.ndarray], np.ndarray] | None = None,
                 on_generation: Callable[["NSGA2State"], None] | None = None):
        self.domains = np.asarray(domains)
        self.cfg = cfg
        self.n_genes = int(self.domains.shape[0])
        self.mut_prob = (cfg.mutation_prob if cfg.mutation_prob is not None
                         else 1.0 / max(1, self.n_genes))
        self.evaluate = (evaluate if evaluate is not None
                         else (_memoized(objective) if cfg.dedup_eval
                               else objective))
        self.on_generation = on_generation

    # -- lifecycle -----------------------------------------------------------
    def init_state(self, seed_population: np.ndarray | None = None
                   ) -> NSGA2State:
        rng = np.random.default_rng(self.cfg.seed)
        pop = rng.integers(0, self.domains[None, :],
                           size=(self.cfg.pop_size, self.n_genes))
        if seed_population is not None:
            k = min(seed_population.shape[0], self.cfg.pop_size)
            pop[:k] = seed_population[:k]
        return NSGA2State(pop=pop, F=self.evaluate(pop), generation=0, rng=rng)

    def restore_state(self, pop: np.ndarray, F: np.ndarray, generation: int,
                      rng_state: dict,
                      history: list[tuple[int, float, float]] | None = None
                      ) -> NSGA2State:
        """Rebuild a state from checkpointed pieces (RNG mid-stream)."""
        return NSGA2State(pop=np.asarray(pop, dtype=np.int64),
                          F=np.asarray(F, dtype=np.float64),
                          generation=int(generation),
                          rng=decode_rng_state(rng_state),
                          history=list(history or []))

    # -- one generation ------------------------------------------------------
    def step(self, state: NSGA2State) -> NSGA2State:
        cfg, domains, rng = self.cfg, self.domains, state.rng
        pop, F = state.pop, state.F
        fronts = fast_non_dominated_sort(F)
        rank = np.empty(cfg.pop_size, dtype=np.int64)
        crowd = np.empty(cfg.pop_size)
        for r, fr in enumerate(fronts):
            rank[fr] = r
            crowd[fr] = crowding_distance(F[fr])
        state.history.append((state.generation, float(F[fronts[0], 0].min()),
                              float(F[fronts[0], 1].min())))

        children = []
        while len(children) < cfg.pop_size:
            i1 = _tournament(rank, crowd, rng)
            i2 = _tournament(rank, crowd, rng)
            c1, c2 = _sbx_int(pop[i1], pop[i2], domains, cfg.crossover_eta,
                              cfg.crossover_prob, rng)
            children.append(_poly_mutate_int(c1, domains, cfg.mutation_eta,
                                             self.mut_prob, rng))
            if len(children) < cfg.pop_size:
                children.append(_poly_mutate_int(c2, domains, cfg.mutation_eta,
                                                 self.mut_prob, rng))
        Q = np.stack(children)
        FQ = self.evaluate(Q)

        R = np.concatenate([pop, Q], axis=0)
        FR = np.concatenate([F, FQ], axis=0)
        fronts = fast_non_dominated_sort(FR)
        new_idx: list[int] = []
        for fr in fronts:
            if len(new_idx) + fr.size <= cfg.pop_size:
                new_idx.extend(fr.tolist())
            else:
                cd = crowding_distance(FR[fr])
                order = np.argsort(-cd, kind="stable")
                need = cfg.pop_size - len(new_idx)
                new_idx.extend(fr[order[:need]].tolist())
                break
        state.pop, state.F = R[new_idx], FR[new_idx]
        state.generation += 1
        if self.on_generation is not None:
            self.on_generation(state)
        return state

    def result(self, state: NSGA2State) -> NSGA2Result:
        px, pf = extract_front(state.pop, state.F)
        return NSGA2Result(pareto_x=px, pareto_f=pf, history=state.history)


def nsga2(domains: np.ndarray,
          objective: Callable[[np.ndarray], np.ndarray],
          cfg: NSGA2Config,
          seed_population: np.ndarray | None = None) -> NSGA2Result:
    """Minimize a 2-objective function over integer chromosomes.

    domains:  (n_genes,) number of choices per gene (gene i in [0, domains[i})).
    objective: (N, n_genes) int -> (N, 2) float, both minimized; rows must be
        independent (the population-parallel fitness contract), which lets
        duplicate chromosomes be served from a cache (`cfg.dedup_eval`).
    seed_population: optional known-good individuals (e.g. the all-exact TNN).
    """
    driver = NSGA2Driver(domains, objective, cfg)
    state = driver.init_state(seed_population)
    for _ in range(cfg.n_generations):
        state = driver.step(state)
    return driver.result(state)
