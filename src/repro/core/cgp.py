"""Phase 1 — Cartesian Genetic Programming for approximate popcount circuits.

Implements the paper's Sec. 4.1.1: a (mu+lambda) evolutionary strategy over an
integer, address-based genome.  The initial population contains the *exact*
popcount adder tree; mutants trade arithmetic error for EGFET area under the
constrained fitness of Eq. (3):

    F(c) = area(c)  if  eps(c) <= tau   else  +inf

Error evaluation is the bit-parallel sweep from `circuits.eval_vectors` —
exhaustive for n <= 16 inputs, Hamming-weight-stratified Monte-Carlo above
(the offline stand-in for the paper's BDD-based formal evaluation).

Population-parallel fitness: all lambda children of a generation are scored
in a single `NetlistPopulation` call (structure-of-arrays batched simulation
+ batched active-mask/area accounting), instead of a per-child Python loop —
bit-identical results and trajectories, measured ~14x fitness evals/s at
lambda=16 and ~7.5x end-to-end `evolve_popcount` wall-clock (n=8; see
`benchmarks/cgp_throughput.py` / BENCH_cgp.json; `batch_eval=False` keeps
the serial reference path).  `evolve_pc_library` additionally runs the
independent tau-schedule points concurrently in a thread pool.

Classic CGP efficiency trick: a mutation that touches only *inactive* genes
yields a functionally identical circuit, so the child inherits the parent's
fitness without re-simulation (neutral drift is retained, cf. Miller'11).
"""
from __future__ import annotations

import os
import time
from concurrent.futures import ThreadPoolExecutor
from dataclasses import dataclass, field

import numpy as np

from repro.hw.egfet import Gate
from repro.core.circuits import (
    Netlist,
    NetlistPopulation,
    eval_vectors,
    popcount_netlist,
    popcount_width,
)

# Function set for evolved nodes (2-input ops + unaries).
DEFAULT_FUNCS: tuple[int, ...] = (
    Gate.AND, Gate.OR, Gate.XOR, Gate.NAND, Gate.NOR, Gate.XNOR,
    Gate.NOT, Gate.BUF, Gate.ANDN, Gate.ORN, Gate.CONST0, Gate.CONST1,
)


@dataclass
class CGPConfig:
    n_inputs: int
    n_outputs: int
    n_nodes: int                      # grid size (single row, full levels-back)
    funcs: tuple[int, ...] = DEFAULT_FUNCS
    lam: int = 4                      # lambda children per generation
    mu: int = 1                       # parents kept per generation (mu+lambda)
    mut_genes: int = 5                # genes mutated per child
    seed: int = 0
    max_iters: int = 2000
    time_limit_s: float | None = None
    error_metric: str = "mae"         # "mae" | "wcae"
    tau: float = 0.0                  # error threshold (Eq. 3)
    batch_eval: bool = True           # population-parallel child evaluation


@dataclass
class CGPResult:
    best: Netlist
    best_area: float
    best_error: tuple[float, float]   # (mae, wcae) of the winner
    history: list[tuple[int, float]] = field(default_factory=list)  # (iter, area)
    evaluations: int = 0


class _Genome:
    """func[g], a[g], b[g] int arrays + out[] output addresses."""

    __slots__ = ("n_inputs", "func", "a", "b", "out")

    def __init__(self, n_inputs, func, a, b, out):
        self.n_inputs = n_inputs
        self.func = func
        self.a = a
        self.b = b
        self.out = out

    def copy(self) -> "_Genome":
        return _Genome(self.n_inputs, self.func.copy(), self.a.copy(),
                       self.b.copy(), self.out.copy())

    def to_netlist(self, name: str = "") -> Netlist:
        nl = Netlist(
            n_inputs=self.n_inputs,
            op=self.func.astype(np.int16),
            in0=self.a.astype(np.int32),
            in1=self.b.astype(np.int32),
            outputs=self.out.astype(np.int32),
            name=name,
        )
        nl.validate()
        return nl

    def active_nodes(self) -> np.ndarray:
        """Boolean mask over grid nodes reachable from outputs."""
        n_in = self.n_inputs
        n_nodes = self.func.shape[0]
        live = np.zeros(n_in + n_nodes, dtype=bool)
        live[self.out] = True
        for g in range(n_nodes - 1, -1, -1):
            if live[n_in + g]:
                f = self.func[g]
                if f not in (Gate.CONST0, Gate.CONST1):
                    live[self.a[g]] = True
                    if f not in (Gate.NOT, Gate.BUF):
                        live[self.b[g]] = True
        return live[n_in:]


def _seed_genome(exact: Netlist, n_nodes: int, rng: np.random.Generator,
                 funcs: tuple[int, ...]) -> _Genome:
    """Embed the exact netlist in a larger grid; random-fill the slack."""
    g0 = exact.n_gates
    if n_nodes < g0:
        raise ValueError(f"grid {n_nodes} smaller than exact circuit {g0}")
    n_in = exact.n_inputs
    func = np.empty(n_nodes, dtype=np.int64)
    a = np.empty(n_nodes, dtype=np.int64)
    b = np.empty(n_nodes, dtype=np.int64)
    func[:g0] = exact.op
    a[:g0] = exact.in0
    b[:g0] = exact.in1
    for g in range(g0, n_nodes):
        func[g] = funcs[rng.integers(len(funcs))]
        a[g] = rng.integers(n_in + g)
        b[g] = rng.integers(n_in + g)
    return _Genome(n_in, func, a, b, exact.outputs.astype(np.int64).copy())


def _mutate(parent: _Genome, cfg: CGPConfig, rng: np.random.Generator,
            active: np.ndarray | None = None) -> tuple["_Genome", bool]:
    """Point-mutate `mut_genes` genes; report whether any *active* gene moved.

    `active` lets callers share one liveness sweep across a generation's
    lambda children instead of recomputing it per child.
    """
    child = parent.copy()
    n_nodes = child.func.shape[0]
    n_in = cfg.n_inputs
    active = parent.active_nodes() if active is None else active
    touched_active = False
    n_genes = 3 * n_nodes + child.out.shape[0]
    for _ in range(cfg.mut_genes):
        gi = int(rng.integers(n_genes))
        if gi < 3 * n_nodes:
            g, which = divmod(gi, 3)
            if which == 0:
                child.func[g] = cfg.funcs[rng.integers(len(cfg.funcs))]
            elif which == 1:
                child.a[g] = rng.integers(n_in + g)
            else:
                child.b[g] = rng.integers(n_in + g)
            if active[g]:
                touched_active = True
        else:
            o = gi - 3 * n_nodes
            child.out[o] = rng.integers(n_in + n_nodes)
            touched_active = True
    return child, touched_active


def _population_of(genomes: list[_Genome]) -> NetlistPopulation:
    """Stack same-grid genomes into a structure-of-arrays population."""
    return NetlistPopulation(
        n_inputs=genomes[0].n_inputs,
        op=np.stack([g.func for g in genomes]).astype(np.int16),
        in0=np.stack([g.a for g in genomes]).astype(np.int32),
        in1=np.stack([g.b for g in genomes]).astype(np.int32),
        outputs=np.stack([g.out for g in genomes]).astype(np.int32),
    )


def _area_of(genome: _Genome) -> float:
    return genome.to_netlist().cost().area_mm2


def _errors(genome: _Genome, packed: np.ndarray, true: np.ndarray) -> tuple[float, float]:
    approx = genome.to_netlist().eval_uint(packed)
    err = np.abs(approx - true)
    return float(err.mean()), float(err.max())


def evolve_popcount(cfg: CGPConfig,
                    exact: Netlist | None = None,
                    eval_set: tuple[np.ndarray, np.ndarray] | None = None) -> CGPResult:
    """(mu+lambda) CGP search for an approximate popcount under eps <= tau.

    Every generation's children are scored in one batched population call
    (`cfg.batch_eval`, default) — bit-identical to the serial per-child loop,
    which remains available as the reference path (`batch_eval=False`).
    Children whose mutations touched only inactive genes inherit the parent's
    error without re-simulation either way.
    """
    rng = np.random.default_rng(cfg.seed)
    n = cfg.n_inputs
    exact = exact if exact is not None else popcount_netlist(n)
    assert exact.n_outputs == cfg.n_outputs
    packed, true = eval_set if eval_set is not None else eval_vectors(n)

    def fitness(err: tuple[float, float], area: float) -> float:
        e = err[0] if cfg.error_metric == "mae" else err[1]
        return area if e <= cfg.tau else float("inf")

    root = _seed_genome(exact, cfg.n_nodes, rng, cfg.funcs)
    p_err = _errors(root, packed, true)
    p_fit = _area_of(root)  # exact circuit always satisfies tau
    evaluations = 1
    history = [(0, p_fit)]
    t0 = time.monotonic()

    mu = max(1, cfg.mu)
    # parents: (genome, fit, err); mu > 1 widens the strategy to mu+lambda
    parents: list[tuple[_Genome, float, tuple[float, float]]] = \
        [(root, p_fit, p_err)] * mu

    best_g, best_fit, best_err = root.copy(), p_fit, p_err
    for it in range(1, cfg.max_iters + 1):
        if cfg.time_limit_s is not None and time.monotonic() - t0 > cfg.time_limit_s:
            break
        # mutate first (sole rng consumer -> identical children either path);
        # one liveness sweep per parent serves all its children
        pmasks = [parents[pi][0].active_nodes() for pi in range(mu)]
        kids: list[tuple[_Genome, bool, int]] = []
        for j in range(cfg.lam):
            pi = j % mu
            child, touched = _mutate(parents[pi][0], cfg, rng, active=pmasks[pi])
            kids.append((child, touched, pi))

        genomes = [k[0] for k in kids]
        errs: list[tuple[float, float]] = [parents[k[2]][2] for k in kids]
        touched_idx = [j for j, k in enumerate(kids) if k[1]]
        if cfg.batch_eval:
            pop = _population_of(genomes)
            areas = pop.areas()
            if touched_idx:
                mae, wc = pop.take(np.array(touched_idx)).pc_errors(packed, true)
                for s, j in enumerate(touched_idx):
                    errs[j] = (float(mae[s]), float(wc[s]))
        else:  # serial reference: the original per-child Netlist loop
            areas = [_area_of(g) for g in genomes]
            for j in touched_idx:
                errs[j] = _errors(genomes[j], packed, true)
        evaluations += len(touched_idx)
        fits = [fitness(errs[j], float(areas[j])) for j in range(cfg.lam)]

        if mu == 1:
            j = int(np.argmin(fits))          # first minimum, like min(...)
            c_fit, c_err, child = fits[j], errs[j], genomes[j]
            p_fit = parents[0][1]
            # <= : accept neutral moves (CGP drift)
            if c_fit <= (p_fit if np.isfinite(p_fit) else float("inf")):
                parents = [(child, c_fit, c_err)]
        else:
            # truncation selection over parents+children; children first so
            # equal-fitness ties drift to the new genome
            pool = ([(fits[j], errs[j], genomes[j]) for j in range(cfg.lam)]
                    + [(f, e, g) for (g, f, e) in parents])
            pool.sort(key=lambda t: t[0])
            parents = [(g, f, e) for (f, e, g) in pool[:mu]]
            c_fit, c_err, child = pool[0]
        if c_fit < best_fit:
            best_g, best_fit, best_err = child.copy(), c_fit, c_err
            history.append((it, best_fit))

    name = f"pc{n}_cgp_{cfg.error_metric}{cfg.tau:g}_s{cfg.seed}"
    best_nl = best_g.to_netlist(name=name)
    best_nl.meta.update({"n": n, "tau": cfg.tau, "metric": cfg.error_metric,
                         "mae": best_err[0], "wcae": best_err[1]})
    return CGPResult(best=best_nl, best_area=best_fit, best_error=best_err,
                     history=history, evaluations=evaluations)


def tau_schedule(n: int, n_points: int = 6) -> list[tuple[str, float]]:
    """The paper's error-limit grid: tau_mae log-spaced in [0.1, 0.5*2^m],
    tau_wcae log-spaced in [1, 0.5*2^m], with m = ceil(log2 n)."""
    m = max(1, int(np.ceil(np.log2(n))))
    hi = 0.5 * (1 << m)
    taus_mae = np.geomspace(0.1, hi, n_points)
    taus_wcae = np.geomspace(1.0, hi, n_points)
    return [("mae", float(t)) for t in taus_mae] + [("wcae", float(t)) for t in taus_wcae]


def _truncation_stats(n: int, packed, true) -> list[tuple[Netlist, float, float, float]]:
    """(netlist, mae, wcae, area) for every truncation depth, evaluated in a
    single padded population call (shared by all tau points)."""
    from repro.core.circuits import truncated_popcount_netlist
    nls = [truncated_popcount_netlist(n, drop) for drop in range(1, n - 1)]
    if not nls:
        return []
    pop = NetlistPopulation.from_netlists(nls)
    mae, wcae = pop.pc_errors(packed, true)
    areas = pop.areas()
    return [(nl, float(mae[i]), float(wcae[i]), float(areas[i]))
            for i, nl in enumerate(nls)]


def _best_feasible_seed(n: int, metric: str, tau: float,
                        packed, true,
                        trunc_stats=None) -> Netlist:
    """Cheapest known-feasible start: the exact tree or a truncated variant
    already satisfying tau (warm-starting CGP from the truncation baseline
    converges far faster than from the exact circuit alone)."""
    stats = trunc_stats if trunc_stats is not None else _truncation_stats(n, packed, true)
    best = popcount_netlist(n)
    best_area = best.cost().area_mm2
    for nl, mae, wcae, a in stats:
        err = mae if metric == "mae" else wcae
        if err <= tau and a < best_area:
            best, best_area = nl, a
    return best


def evolve_pc_library(n: int,
                      n_points: int = 4,
                      max_iters: int = 800,
                      n_nodes: int | None = None,
                      seed: int = 0,
                      time_limit_s: float | None = None,
                      parallel: bool = True,
                      n_workers: int | None = None) -> list[Netlist]:
    """Evolve a small library of approximate n-input popcounts across the tau
    grid.  Always includes the exact circuit as the zero-error member.

    The tau-schedule points are independent (1+lambda) runs with disjoint
    seeds, so they execute concurrently in a thread pool (`parallel`, default
    on; numpy releases the GIL inside the batched simulation).  Results are
    collected in schedule order — the library is deterministic either way.
    Wall-clock-limited runs are the exception: under `time_limit_s` the
    per-point generation counts depend on core contention, so those runs
    stay sequential to preserve the pre-existing (deterministic-per-machine)
    behavior.
    """
    exact = popcount_netlist(n)
    exact.meta.update({"mae": 0.0, "wcae": 0.0, "tau": 0.0, "metric": "exact"})
    packed, true = eval_vectors(n)
    grid = n_nodes if n_nodes is not None else max(exact.n_gates + 16, int(exact.n_gates * 1.5))
    trunc_stats = _truncation_stats(n, packed, true)
    points = tau_schedule(n, n_points)

    def run_point(i: int, metric: str, tau: float) -> CGPResult:
        seed_nl = _best_feasible_seed(n, metric, tau, packed, true, trunc_stats)
        cfg = CGPConfig(n_inputs=n, n_outputs=popcount_width(n), n_nodes=grid,
                        seed=seed + i, max_iters=max_iters, tau=tau,
                        error_metric=metric, time_limit_s=time_limit_s)
        return evolve_popcount(cfg, exact=seed_nl, eval_set=(packed, true))

    if parallel and time_limit_s is None and len(points) > 1:
        workers = n_workers or min(len(points), os.cpu_count() or 1)
        with ThreadPoolExecutor(max_workers=workers) as ex:
            results = list(ex.map(lambda a: run_point(*a),
                                  [(i, m, t) for i, (m, t) in enumerate(points)]))
    else:
        results = [run_point(i, m, t) for i, (m, t) in enumerate(points)]

    lib = [exact]
    for res in results:
        if np.isfinite(res.best_area):
            lib.append(res.best)
    # dedupe by (area, mae) signature
    seen, out = set(), []
    for nl in lib:
        key = (round(nl.cost().area_mm2, 6), round(nl.meta.get("mae", 0.0), 6))
        if key not in seen:
            seen.add(key)
            out.append(nl)
    return out
