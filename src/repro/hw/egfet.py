"""EGFET (electrolyte-gated FET) printed-technology cost model.

The paper evaluates every circuit on the EGFET PDK [Bleier et al., ISCA'20]
at 0.6 V / 5 Hz with Synopsys DC + PrimeTime.  Offline we replace synthesis
with an analytical per-gate cost model applied to the *actual* netlists we
generate (adder trees, comparators, CGP-evolved circuits).

Anchors used to fit the constants (all from the paper / its references):
  * 4-bit flash ADC:             12    mm^2, 1.0  mW      (Sec. 3.1, [6])
  * proposed ABC:                 0.07 mm^2, 0.03 mW      (Sec. 3.1)
  * BreastCancer exact TNN
    (10,10,2):                   29    mm^2, 0.31 mW      (Table 3)
  * sensor power overhead:      ~5 uW                     (Sec. 5, [12])

EGFET digital logic is n-type-only resistive-load ("ratioed") logic: an
inverter is 1 EGT + 1 printed resistor, NAND2/NOR2 are 2 EGT + 1 R, and an
XOR needs a two-level network.  Area scales with (transistor + resistor)
count; power at these frequencies is static-dominated (current through the
pull-up resistor), so it scales with resistor count weighted by duty.  The
constants below reproduce the paper's Table-3 magnitudes within ~1.5x and —
more importantly — preserve *ratios* between exact and approximate designs,
which is what the paper's evaluation is about.
"""
from __future__ import annotations

import enum
from dataclasses import dataclass


class Gate(enum.IntEnum):
    """Gate/function opcodes shared by the netlist + CGP genome."""

    INPUT = 0
    CONST0 = 1
    CONST1 = 2
    BUF = 3     # wire / identity(a)
    NOT = 4
    AND = 5
    OR = 6
    XOR = 7
    NAND = 8
    NOR = 9
    XNOR = 10
    ANDN = 11   # a AND (NOT b)  -- cheap in ratioed logic, used by comparators
    ORN = 12    # a OR  (NOT b)


# mm^2 per gate.  (INPUT/CONST are free: they are wires / rails.)
GATE_AREA_MM2: dict[int, float] = {
    Gate.INPUT: 0.0,
    Gate.CONST0: 0.0,
    Gate.CONST1: 0.0,
    Gate.BUF: 0.0,          # a wire in a bespoke (hardwired) design
    Gate.NOT: 0.045,
    Gate.AND: 0.11,
    Gate.OR: 0.11,
    Gate.XOR: 0.22,
    Gate.NAND: 0.08,
    Gate.NOR: 0.08,
    Gate.XNOR: 0.22,
    Gate.ANDN: 0.13,
    Gate.ORN: 0.13,
}

# uW per gate (static-dominated at 0.6 V / 5 Hz).
GATE_POWER_UW: dict[int, float] = {
    Gate.INPUT: 0.0,
    Gate.CONST0: 0.0,
    Gate.CONST1: 0.0,
    Gate.BUF: 0.0,
    Gate.NOT: 0.40,
    Gate.AND: 1.00,
    Gate.OR: 1.00,
    Gate.XOR: 1.90,
    Gate.NAND: 0.70,
    Gate.NOR: 0.70,
    Gate.XNOR: 1.90,
    Gate.ANDN: 1.15,
    Gate.ORN: 1.15,
}

# ---------------------------------------------------------------------------
# Sensor interface costs (Sec. 3.1 / Table 3 "w/ ADC cost" columns).
# ---------------------------------------------------------------------------
ADC4_AREA_MM2 = 12.0     # 4-bit flash ADC, per input feature
ADC4_POWER_MW = 1.0
ABC_AREA_MM2 = 0.07      # proposed analog-to-binary converter, per feature
ABC_POWER_MW = 0.03
SENSOR_POWER_MW = 0.005  # ~5 uW per sensor

# v/f operating point (kept for documentation & power-budget checks)
VDD_V = 0.6
FREQ_HZ = 5.0

# Printed power sources (Sec. 5): can the design be powered?
HARVESTER_BUDGET_MW = 2.0     # printed energy harvester [4]
ZINERGY_BATTERY_MW = 15.0
MOLEX_BATTERY_MW = 30.0


@dataclass(frozen=True)
class HwCost:
    """Area (mm^2) / power (mW) aggregate for a circuit or system."""

    area_mm2: float
    power_mw: float

    def __add__(self, other: "HwCost") -> "HwCost":
        return HwCost(self.area_mm2 + other.area_mm2, self.power_mw + other.power_mw)

    def scale(self, k: float) -> "HwCost":
        return HwCost(self.area_mm2 * k, self.power_mw * k)

    @property
    def area_cm2(self) -> float:
        return self.area_mm2 / 100.0


def gate_cost(op: int) -> HwCost:
    return HwCost(GATE_AREA_MM2[op], GATE_POWER_UW[op] * 1e-3)


def interface_cost(n_features: int, kind: str) -> HwCost:
    """Sensor-processor interface cost for `n_features` analog inputs."""
    if kind == "adc4":
        return HwCost(ADC4_AREA_MM2 * n_features, ADC4_POWER_MW * n_features)
    if kind == "abc":
        return HwCost(ABC_AREA_MM2 * n_features, ABC_POWER_MW * n_features)
    raise ValueError(f"unknown interface kind: {kind!r}")


def power_source(total_power_mw: float) -> str:
    """Which printed power source can drive the design (Sec. 5 discussion)."""
    if total_power_mw <= HARVESTER_BUDGET_MW:
        return "energy-harvester"
    if total_power_mw <= ZINERGY_BATTERY_MW:
        return "zinergy-battery"
    if total_power_mw <= MOLEX_BATTERY_MW:
        return "molex-battery"
    return "exceeds-printed-budget"
