"""Hardware cost models for the printed-electronics (EGFET) target."""
from repro.hw.egfet import (  # noqa: F401
    Gate,
    HwCost,
    gate_cost,
    interface_cost,
    power_source,
)
