"""Campaign-facing fitness evaluation API.

The actual backend dispatch (np / SWAR / Pallas) and device-row sharding
live below the orchestration layer in `repro.kernels.dispatch`, so core
problems (`core.tnn.TNNApproxProblem`) can select an executor without
importing upward into this package.  This module re-exports that API under
the name campaigns and benchmarks use.
"""
from repro.kernels.dispatch import (  # noqa: F401
    BACKENDS,
    population_eval_pop,
    population_eval_uint,
    population_pc_errors,
)
