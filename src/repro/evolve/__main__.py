"""Campaign CLI — run / resume island-model evolution searches.

    PYTHONPATH=src python -m repro.evolve --problem tnn --dataset cardio \
        --islands 4 --epochs 8 --ckpt-dir runs/cardio --out front_cardio.json

Re-running the same command against an existing `--ckpt-dir` resumes from
the newest valid snapshot (use `--fresh` to wipe and restart).  `--dataset
all` sweeps every Table-2 dataset into per-dataset checkpoint subdirs.
`--emit-dir` lowers the best-accuracy archive entry of a TNN campaign
through repro.compile and writes Verilog + EGFET report artifacts.
"""
from __future__ import annotations

import argparse
import json
import shutil
import time
from pathlib import Path

import numpy as np

from repro.data.tabular import DATASETS
from repro.evolve.campaign import Campaign
from repro.evolve.config import CampaignConfig
from repro.evolve.problems import (ProblemSpec, build_problem,
                                   compile_archive_winner)


def _parse_args(argv=None) -> argparse.Namespace:
    ap = argparse.ArgumentParser(prog="python -m repro.evolve",
                                 description=__doc__)
    ap.add_argument("--problem", choices=("tnn", "synth"), default="tnn")
    ap.add_argument("--dataset", default="cardio",
                    help=f"one of {', '.join(DATASETS)}, or 'all'")
    ap.add_argument("--islands", type=int, default=4)
    ap.add_argument("--pop", type=int, default=24)
    ap.add_argument("--epochs", type=int, default=8)
    ap.add_argument("--gens-per-epoch", type=int, default=5)
    ap.add_argument("--migrate-k", type=int, default=2)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--backend", choices=("np", "swar", "pallas"),
                    default="np", help="gate-sim executor for fitness")
    ap.add_argument("--workers", type=int, default=0,
                    help="island-executor process count (0/1 = serial; "
                         "N>1 steps islands concurrently, bit-identical)")
    ap.add_argument("--phase-cache", default=None,
                    help="Phase-1/2 product cache dir (default: "
                         "$REPRO_PHASE_CACHE or ~/.cache/repro/phase_cache;"
                         " set the env to 'off' to disable)")
    ap.add_argument("--ckpt-dir", default=None,
                    help="checkpoint root (resume happens automatically)")
    ap.add_argument("--fresh", action="store_true",
                    help="delete existing checkpoints before running")
    ap.add_argument("--out", default=None,
                    help="write the final Pareto archive as JSON here")
    ap.add_argument("--emit-dir", default=None,
                    help="TNN only: write winner RTL + EGFET report here")
    # TNN problem budgets (examples-scale defaults)
    ap.add_argument("--tnn-epochs", type=int, default=12)
    ap.add_argument("--cgp-iters", type=int, default=500)
    ap.add_argument("--cgp-points", type=int, default=3)
    ap.add_argument("--pcc-samples", type=int, default=30000)
    # synth problem shape
    ap.add_argument("--genes", type=int, default=10)
    ap.add_argument("--domain", type=int, default=6)
    ap.add_argument("--kill-after-epoch", type=int, default=None,
                    help="debug: SIGKILL self right after this epoch's "
                         "checkpoint (resume-test harness)")
    return ap.parse_args(argv)


def _run_one(args: argparse.Namespace, dataset: str | None) -> dict:
    if args.problem == "synth":
        spec = ProblemSpec("synth", {"n_genes": args.genes,
                                     "domain": args.domain})
    else:
        spec = ProblemSpec("tnn", {"dataset": dataset, "seed": args.seed,
                                   "epochs": args.tnn_epochs,
                                   "cgp_points": args.cgp_points,
                                   "cgp_iters": args.cgp_iters,
                                   "pcc_samples": args.pcc_samples,
                                   "eval_backend": args.backend,
                                   "cache_dir": args.phase_cache})
    problem = build_problem(spec)
    cfg = CampaignConfig(n_islands=args.islands, pop_size=args.pop,
                         n_epochs=args.epochs,
                         gens_per_epoch=args.gens_per_epoch,
                         migrate_k=args.migrate_k, seed=args.seed,
                         eval_backend=args.backend, workers=args.workers)
    ckpt_dir = args.ckpt_dir
    if ckpt_dir and dataset and args.dataset == "all":
        ckpt_dir = str(Path(ckpt_dir) / dataset)
    if ckpt_dir and args.fresh:
        shutil.rmtree(ckpt_dir, ignore_errors=True)

    campaign = Campaign(problem.domains, problem.objective, cfg,
                        checkpoint_dir=ckpt_dir,
                        seed_population=problem.seed_population,
                        name=problem.name, problem_spec=spec)

    def on_epoch(epoch: int, c: Campaign) -> None:
        best = c.archive.F[:, 0].min() if len(c.archive) else float("nan")
        print(f"[{problem.name}] epoch {epoch + 1}/{cfg.n_epochs}: "
              f"archive {len(c.archive)} designs, best obj0 {best:.4f}",
              flush=True)

    t0 = time.perf_counter()
    try:
        res = campaign.run(on_epoch=on_epoch,
                           kill_after_epoch=args.kill_after_epoch)
    finally:
        campaign.close()
    dt = time.perf_counter() - t0
    if res.resumed_from is not None:
        print(f"[{problem.name}] resumed from epoch {res.resumed_from} "
              f"checkpoint ({res.epochs_run} epochs this process)")
    print(f"[{problem.name}] archive: {len(res.archive_x)} Pareto designs "
          f"in {dt:.1f}s")

    payload = {
        "problem": problem.name,
        "config": {"islands": cfg.n_islands, "pop": cfg.pop_size,
                   "epochs": cfg.n_epochs,
                   "gens_per_epoch": cfg.gens_per_epoch,
                   "migrate_k": cfg.migrate_k, "seed": cfg.seed,
                   "backend": cfg.eval_backend, "workers": cfg.workers},
        "resumed_from": res.resumed_from,
        "cache": res.cache_history[-1] if res.cache_history else None,
        "archive": [{"x": x.tolist(), "f": [float(a), float(b)]}
                    for x, (a, b) in zip(res.archive_x, res.archive_f)],
    }
    if args.emit_dir and problem.approx is not None and len(res.archive_x):
        from repro.compile import egfet_report, write_artifacts
        best_i = int(np.argmin(res.archive_f[:, 0]))
        best_x = res.archive_x[best_i]
        cc = compile_archive_winner(problem, best_x)
        provenance = {
            "seed": cfg.seed,
            "islands": cfg.n_islands,
            "pop_size": cfg.pop_size,
            "generations": campaign.next_epoch * cfg.gens_per_epoch,
            "objectives": [float(v) for v in res.archive_f[best_i]],
            "config_fingerprint": campaign.fingerprint(),
            "backend": cfg.eval_backend,
            "resumed_from": res.resumed_from,
        }
        paths = write_artifacts(cc, args.emit_dir, base=problem.name,
                                dataset=dataset, provenance=provenance)
        payload["artifacts"] = paths
        rep = egfet_report(cc)
        print(f"[{problem.name}] emitted winner: {cc.ir.n_gates} gates, "
              f"{rep['total_area_mm2']:.2f} mm^2 -> {paths['verilog']}")
        print(f"[{problem.name}] fleet tenant registered in "
              f"{paths['manifest']} (python -m repro.serve --emit-dir "
              f"{args.emit_dir})")
    return payload


def main(argv=None) -> None:
    args = _parse_args(argv)
    datasets = (sorted(DATASETS) if args.dataset == "all"
                else [args.dataset])
    if args.problem == "tnn":
        unknown = [d for d in datasets if d not in DATASETS]
        if unknown:
            raise SystemExit(f"unknown dataset(s): {', '.join(unknown)}; "
                             f"valid: {', '.join(sorted(DATASETS))}, all")
    else:
        datasets = [None]
    payloads = [_run_one(args, d) for d in datasets]
    if args.out:
        out = payloads[0] if len(payloads) == 1 else {"campaigns": payloads}
        Path(args.out).parent.mkdir(parents=True, exist_ok=True)
        Path(args.out).write_text(json.dumps(out, indent=2, sort_keys=True)
                                  + "\n")
        print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
