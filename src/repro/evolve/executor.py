"""Process-pool island executor — multi-core campaign stepping.

Within an epoch, islands are embarrassingly parallel: each island's next
`gens_per_epoch` generations depend only on its own `NSGA2State` (pop,
objectives, mid-stream RNG).  Islands interact *only* at the epoch
boundary — archive fold + ring migration — which stays in the parent.
So an epoch can fan islands out over a spawned process pool and remain
bit-identical to serial stepping:

  * per-island RNG streams travel with the state
    (`encode_rng_state`/`decode_rng_state`, the checkpoint codec);
  * the shared fitness memo is pure row-independent memoization
    (`campaign.py`'s own resume contract) — per-worker caches change
    which rows hit the wrapped objective, never the values returned;
  * generation order within one island is serial either way (serial
    stepping interleaves islands generation-major, workers run each
    island epoch-major — indistinguishable because islands are
    independent between sync points).

Workers rebuild the objective from a picklable `ProblemSpec` once per
process (spawn initializer) — TNN problems ride the content-addressed
phase cache, so a worker boot costs a cache load, not a retrain — and
keep their own bounded `_memoized` cache across tasks and epochs.

Pinned by tests/test_evolve.py: identical archive X/F arrays and island
histories across 1/2/4 workers, and the executor path survives the
existing SIGKILL-resume tests (checkpointing is unchanged — the parent
owns states, archive and the manifest exactly as before).
"""
from __future__ import annotations

import os
from concurrent.futures import ProcessPoolExecutor

import numpy as np

from repro.core.nsga2 import (NSGA2Driver, NSGA2State, _memoized,
                              decode_rng_state, encode_rng_state)
from repro.evolve.config import CampaignConfig
from repro.evolve.problems import ProblemSpec, build_problem

# Per-worker-process globals, installed by `_worker_init` (spawn context:
# each worker imports fresh, so this dict is private per process).
_WORKER: dict = {}


def _pack_state(s: NSGA2State) -> tuple:
    return (np.ascontiguousarray(s.pop), np.ascontiguousarray(s.F),
            int(s.generation), encode_rng_state(s.rng),
            [tuple(h) for h in s.history])


def _unpack_state(t: tuple) -> NSGA2State:
    pop, F, generation, rng_state, history = t
    return NSGA2State(pop=np.asarray(pop, dtype=np.int64),
                      F=np.asarray(F, dtype=np.float64),
                      generation=int(generation),
                      rng=decode_rng_state(rng_state),
                      history=[tuple(h) for h in history])


def _worker_init(spec: ProblemSpec, cfg: CampaignConfig) -> None:
    problem = build_problem(spec)
    evaluate = (_memoized(problem.objective, maxsize=cfg.memo_maxsize)
                if cfg.base.dedup_eval else problem.objective)
    _WORKER.update(problem=problem, cfg=cfg, evaluate=evaluate, drivers={},
                   cache_epoch=0, drift_applied=0)


def _sync_worker(cache_epoch: int, drift_rounds: tuple) -> None:
    """Bring this worker's objective/memo up to the parent's data epoch.

    Drift and cache invalidation happen in the parent between epochs; a
    worker cannot be *told* (tasks are pulled, not addressed), so every
    step task carries the parent's cache-epoch counter and full drift
    round history, and the worker catches up lazily before stepping.
    Drift hooks compose across rounds (each call advances the sample
    plane from where the last left it), so the worker replays exactly
    the suffix of rounds it has not applied yet — deterministic:
    `problem.drift` is a pure function of the round sequence, so any
    worker replaying the same rounds lands on the same data.
    """
    applied = _WORKER["drift_applied"]
    if len(drift_rounds) > applied:
        problem = _WORKER["problem"]
        if problem.drift is None:
            raise RuntimeError("parent drifted but worker problem has no "
                               "drift hook — ProblemSpec out of sync")
        for r in drift_rounds[applied:]:
            problem.drift(r)
        _WORKER["drift_applied"] = len(drift_rounds)
    if cache_epoch != _WORKER["cache_epoch"]:
        clear = getattr(_WORKER["evaluate"], "cache_clear", None)
        if clear is not None:
            clear()
        _WORKER["cache_epoch"] = cache_epoch


def _step_island(island: int, payload: tuple, gens: int,
                 cache_epoch: int = 0, drift_rounds: tuple = ()
                 ) -> tuple:
    _sync_worker(cache_epoch, drift_rounds)
    cfg: CampaignConfig = _WORKER["cfg"]
    driver = _WORKER["drivers"].get(island)
    if driver is None:
        problem = _WORKER["problem"]
        driver = NSGA2Driver(problem.domains, problem.objective,
                             cfg.island_nsga2(island),
                             evaluate=_WORKER["evaluate"])
        _WORKER["drivers"][island] = driver
    state = _unpack_state(payload)
    for _ in range(gens):
        state = driver.step(state)
    info = getattr(_WORKER["evaluate"], "cache_info", lambda: {})()
    if info:
        info = {**info, "pid": os.getpid()}
    return island, _pack_state(state), info


class IslandExecutor:
    """Steps a campaign's islands concurrently on spawned workers.

    One executor serves one campaign for its lifetime; `close()` (or use
    as a context manager) tears the pool down.  `n_workers` may exceed
    the island count — extra workers idle.
    """

    def __init__(self, spec: ProblemSpec, cfg: CampaignConfig,
                 n_workers: int | None = None):
        if not isinstance(spec, ProblemSpec):
            raise TypeError("IslandExecutor needs a picklable ProblemSpec "
                            "(raw objective callables cannot cross the "
                            "process boundary)")
        import multiprocessing as mp

        self.n_workers = int(n_workers or cfg.workers or
                             min(cfg.n_islands, os.cpu_count() or 1))
        if self.n_workers < 1:
            raise ValueError(f"n_workers must be >= 1, got {self.n_workers}")
        self._pool = ProcessPoolExecutor(
            max_workers=self.n_workers,
            mp_context=mp.get_context("spawn"),
            initializer=_worker_init, initargs=(spec, cfg))
        self._cache_epoch = 0
        self._drift_rounds: tuple[int, ...] = ()

    def step_islands(self, states: list[NSGA2State], gens: int
                     ) -> tuple[list[NSGA2State], dict]:
        """Advance every island `gens` generations; returns (states, stats).

        `stats` aggregates the workers' fitness-memo counters (cumulative
        per worker — the campaign diffs them per epoch).
        """
        futs = [self._pool.submit(_step_island, i, _pack_state(s), gens,
                                  self._cache_epoch, self._drift_rounds)
                for i, s in enumerate(states)]
        out: list[NSGA2State | None] = [None] * len(states)
        # one worker may step several islands and reports its cumulative
        # counters once per island — keep only the most advanced report
        # per worker pid (counters are monotonic), then sum across pids
        per_pid: dict[int, dict] = {}
        for fut in futs:
            island, payload, info = fut.result()
            out[island] = _unpack_state(payload)
            if info:
                pid = info["pid"]
                best = per_pid.get(pid)
                if (best is None or info["hits"] + info["misses"]
                        >= best["hits"] + best["misses"]):
                    per_pid[pid] = info
        agg = {"hits": 0, "misses": 0, "evictions": 0, "size": 0}
        for info in per_pid.values():
            for k in agg:
                agg[k] += int(info.get(k, 0))
        agg["workers"] = self.n_workers
        agg["reports"] = len(per_pid)
        return out, agg

    def clear_eval_cache(self) -> None:
        """Invalidate every worker's fitness memo (post-drift hygiene).

        Tasks are pulled by whichever worker frees up first, so a clear
        cannot be *pushed*; instead the executor bumps a cache-epoch
        counter that rides along with every subsequent step task, and
        each worker clears lazily the first time it sees the new value —
        guaranteed to land before that worker evaluates another row.
        """
        self._cache_epoch += 1

    def mark_drift(self, round_idx: int) -> None:
        """Record that the parent applied `problem.drift(round_idx)`.

        Workers replay the same deterministic drift sequence before
        their next step (see `_sync_worker`) so their sample planes
        match the parent's.  Implies a cache invalidation.
        """
        self._drift_rounds = self._drift_rounds + (int(round_idx),)
        self.clear_eval_cache()

    def close(self) -> None:
        self._pool.shutdown(wait=True)

    def __enter__(self) -> "IslandExecutor":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
