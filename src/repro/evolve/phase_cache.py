"""Content-addressed on-disk cache for Phase-1/2 pipeline products.

`build_tnn_problem` runs the paper's whole producer pipeline — TNN
quantization-aware training, CGP evolution of approximate popcount
libraries, and the Pareto PCC library build — before a single NSGA-II
generation happens.  The pipeline is deterministic in
``(dataset, seed, budgets)``, yet every caller used to pay it again:
each autopilot round in a fresh process, every zoo sweep entry, every CI
job.  This module persists the three products

  * the trained ternary network (``TrainedTNN`` weight codes + ABC
    thresholds + recorded accuracies),
  * the per-size approximate PC libraries (lists of ``Netlist``),
  * the Pareto PCC library (``PCCLibrary`` of PC-pair entries) and the
    output-neuron Pareto PC list,

under a sha256 key of every input the pipeline's output depends on, in
`checkpoint.manager` style: one npz payload written via tmp + rename,
fsynced, with a sha256 sidecar recorded only after the payload it
vouches for is durable.  A truncated or bit-flipped entry raises
`PhaseCacheCorruptError` on load — callers rebuild loudly (warn +
recompute + rewrite) instead of silently serving garbage circuits.

The cache directory resolves from ``REPRO_PHASE_CACHE`` (set it to
``off`` / ``0`` / empty to disable caching entirely), falling back to
``~/.cache/repro/phase_cache``.
"""
from __future__ import annotations

import hashlib
import json
import os
from pathlib import Path

import numpy as np

from repro.core.circuits import Netlist
from repro.core.pcc import PCCEntry, PCCLibrary
from repro.core.tnn import TrainedTNN

# Bump when the Phase-1/2 pipeline changes in a way that affects its
# products — stale entries then simply miss instead of poisoning builds.
PHASE_CACHE_VERSION = 1
_SUFFIX = ".npz"
_SHA_SUFFIX = ".sha256"
_DISABLED = {"off", "0", "false", "no", ""}


class PhaseCacheCorruptError(RuntimeError):
    """A cache entry failed its checksum or cannot be decoded."""


def phase_key(dataset: str, seed: int, epochs: int, cgp_points: int,
              cgp_iters: int, pcc_samples: int) -> str:
    """sha256 over every input the Phase-1/2 products depend on."""
    blob = json.dumps({
        "version": PHASE_CACHE_VERSION,
        "dataset": dataset,
        "seed": int(seed),
        "epochs": int(epochs),
        "cgp_points": int(cgp_points),
        "cgp_iters": int(cgp_iters),
        "pcc_samples": int(pcc_samples),
    }, sort_keys=True)
    return hashlib.sha256(blob.encode()).hexdigest()


def default_cache_dir() -> Path | None:
    """Resolve the cache root (None = caching disabled via env)."""
    env = os.environ.get("REPRO_PHASE_CACHE")
    if env is not None:
        if env.strip().lower() in _DISABLED:
            return None
        return Path(env)
    return Path.home() / ".cache" / "repro" / "phase_cache"


def entry_path(cache_dir: str | Path, key: str) -> Path:
    return Path(cache_dir) / f"phase_{key}{_SUFFIX}"


# -- (de)serialization -------------------------------------------------------
def _pack_netlist(arrays: dict, prefix: str, nl: Netlist) -> dict:
    arrays[f"{prefix}_op"] = np.asarray(nl.op, dtype=np.int16)
    arrays[f"{prefix}_in0"] = np.asarray(nl.in0, dtype=np.int32)
    arrays[f"{prefix}_in1"] = np.asarray(nl.in1, dtype=np.int32)
    arrays[f"{prefix}_out"] = np.asarray(nl.outputs, dtype=np.int32)
    return {"n_inputs": int(nl.n_inputs), "name": nl.name, "meta": nl.meta}


def _unpack_netlist(fix, prefix: str, header: dict) -> Netlist:
    return Netlist(n_inputs=int(header["n_inputs"]),
                   op=fix[f"{prefix}_op"].astype(np.int16),
                   in0=fix[f"{prefix}_in0"].astype(np.int32),
                   in1=fix[f"{prefix}_in1"].astype(np.int32),
                   outputs=fix[f"{prefix}_out"].astype(np.int32),
                   name=str(header["name"]), meta=dict(header["meta"]))


def save_phase(cache_dir: str | Path, key: str, tnn: TrainedTNN,
               pc_libs: dict[int, list[Netlist]], pcc_lib: PCCLibrary,
               pc_out: list[Netlist]) -> Path:
    """Persist one pipeline run's products atomically under `key`."""
    cache_dir = Path(cache_dir)
    cache_dir.mkdir(parents=True, exist_ok=True)
    path = entry_path(cache_dir, key)

    arrays: dict[str, np.ndarray] = {
        "tnn_w1t": np.asarray(tnn.w1t, dtype=np.int8),
        "tnn_w2t": np.asarray(tnn.w2t, dtype=np.int8),
        "tnn_thresholds": np.asarray(tnn.thresholds, dtype=np.float64),
        "tnn_acc": np.array([tnn.train_acc, tnn.test_acc], dtype=np.float64),
    }
    header: dict = {"version": PHASE_CACHE_VERSION, "key": key,
                    "tnn_name": tnn.name, "pc_libs": {}, "pcc": [],
                    "pc_out": []}
    for n, nls in sorted(pc_libs.items()):
        header["pc_libs"][str(n)] = [
            _pack_netlist(arrays, f"pc{n}_{i}", nl)
            for i, nl in enumerate(nls)]
    for e, (size, entries) in enumerate(sorted(pcc_lib.entries.items())):
        for i, ent in enumerate(entries):
            header["pcc"].append({
                "n_pos": int(ent.n_pos), "n_neg": int(ent.n_neg),
                "pos": _pack_netlist(arrays, f"pcc{e}_{i}_p", ent.pc_pos),
                "neg": _pack_netlist(arrays, f"pcc{e}_{i}_n", ent.pc_neg),
                "prefix": f"pcc{e}_{i}",
            })
            arrays[f"pcc{e}_{i}_stats"] = np.array(
                [ent.est_area, ent.mde, ent.wcde, ent.correct_frac],
                dtype=np.float64)
    header["pc_out"] = [_pack_netlist(arrays, f"out_{i}", nl)
                        for i, nl in enumerate(pc_out)]
    arrays["header_json"] = np.frombuffer(
        json.dumps(header, sort_keys=True, default=_json_scalar).encode(),
        dtype=np.uint8)

    # pid-unique tmp names: concurrent writers of the SAME key (zoo
    # workers whose entries share phase products) must not clobber each
    # other's in-flight tmp file — each rename lands a complete payload,
    # last writer wins, both are byte-valid for this key.  A racing
    # payload/sidecar interleave can pair one writer's payload with the
    # other's digest; a reader in that window gets the *loud* corrupt
    # path (drop + rebuild), never a silently wrong product.
    tmp = path.with_name(f".tmp-{os.getpid()}-{path.name}")
    with open(tmp, "wb") as f:
        np.savez_compressed(f, **arrays)
        f.flush()
        os.fsync(f.fileno())
    digest = _sha256_file(tmp)
    os.replace(tmp, path)
    sidecar = path.with_name(path.name + _SHA_SUFFIX)
    tmp_sc = sidecar.with_name(f".tmp-{os.getpid()}-{sidecar.name}")
    tmp_sc.write_text(digest + "\n")
    os.replace(tmp_sc, sidecar)
    return path


def load_phase(cache_dir: str | Path, key: str
               ) -> tuple[TrainedTNN, dict[int, list[Netlist]], PCCLibrary,
                          list[Netlist]]:
    """Load one entry; FileNotFoundError on miss, corruption is loud."""
    path = entry_path(cache_dir, key)
    if not path.exists():
        raise FileNotFoundError(f"no phase-cache entry for {key[:12]}… "
                                f"under {cache_dir}")
    sidecar = path.with_name(path.name + _SHA_SUFFIX)
    if not sidecar.exists():
        raise PhaseCacheCorruptError(
            f"phase-cache entry {path} has no sha256 sidecar — the write "
            "was interrupted; rebuilding")
    want = sidecar.read_text().strip()
    got = _sha256_file(path)
    if got != want:
        raise PhaseCacheCorruptError(
            f"phase-cache entry {path} fails its checksum (sha256 "
            f"{got[:12]}… != recorded {want[:12]}…) — truncated or "
            "bit-flipped on disk; rebuilding")
    try:
        with np.load(path) as fix:
            header = json.loads(bytes(fix["header_json"]).decode())
            acc = fix["tnn_acc"]
            tnn = TrainedTNN(w1t=fix["tnn_w1t"].astype(np.int8),
                             w2t=fix["tnn_w2t"].astype(np.int8),
                             thresholds=fix["tnn_thresholds"].astype(
                                 np.float64),
                             train_acc=float(acc[0]), test_acc=float(acc[1]),
                             name=str(header["tnn_name"]))
            pc_libs = {int(n): [_unpack_netlist(fix, f"pc{n}_{i}", h)
                                for i, h in enumerate(hs)]
                       for n, hs in header["pc_libs"].items()}
            pcc = PCCLibrary()
            for row in header["pcc"]:
                stats = fix[f"{row['prefix']}_stats"]
                ent = PCCEntry(
                    n_pos=int(row["n_pos"]), n_neg=int(row["n_neg"]),
                    pc_pos=_unpack_netlist(fix, f"{row['prefix']}_p",
                                           row["pos"]),
                    pc_neg=_unpack_netlist(fix, f"{row['prefix']}_n",
                                           row["neg"]),
                    est_area=float(stats[0]), mde=float(stats[1]),
                    wcde=float(stats[2]), correct_frac=float(stats[3]))
                pcc.entries.setdefault((ent.n_pos, ent.n_neg), []).append(ent)
            pc_out = [_unpack_netlist(fix, f"out_{i}", h)
                      for i, h in enumerate(header["pc_out"])]
    except PhaseCacheCorruptError:
        raise
    except Exception as exc:  # checksum passed but the archive won't decode
        raise PhaseCacheCorruptError(
            f"phase-cache entry {path} cannot be decoded "
            f"({type(exc).__name__}: {exc}); rebuilding") from exc
    return tnn, pc_libs, pcc, pc_out


def drop_entry(cache_dir: str | Path, key: str) -> None:
    """Remove one entry (payload + sidecar), tolerating absence."""
    path = entry_path(cache_dir, key)
    for p in (path, path.with_name(path.name + _SHA_SUFFIX)):
        try:
            p.unlink()
        except FileNotFoundError:
            pass


def _json_scalar(v):
    """Netlist meta dicts may carry numpy scalars — map them to exact
    Python equivalents (np.float64 -> float is lossless)."""
    if isinstance(v, np.integer):
        return int(v)
    if isinstance(v, np.floating):
        return float(v)
    raise TypeError(f"unserializable meta value {v!r} ({type(v).__name__})")


def _sha256_file(path: Path) -> str:
    h = hashlib.sha256()
    with open(path, "rb") as f:
        for chunk in iter(lambda: f.read(1 << 20), b""):
            h.update(chunk)
    return h.hexdigest()
