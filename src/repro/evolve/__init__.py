"""repro.evolve — resumable island-model evolution campaigns.

`Campaign` runs N independent NSGA-II islands over one shared memoized
objective with periodic ring migration of Pareto elites, checkpointing the
full search state (populations, objectives, archive, RNG streams) every
epoch through `repro.checkpoint` — a killed campaign resumes to a
bit-identical Pareto front.  `repro.evolve.evaluator` dispatches the hot
population x packed-word gate simulation across the np / SWAR / Pallas
backends, sharded over local devices.

CLI:  python -m repro.evolve --problem tnn --dataset cardio ...
"""
from repro.evolve.campaign import Campaign, CampaignResult  # noqa: F401
from repro.evolve.config import CampaignConfig  # noqa: F401
from repro.evolve.executor import IslandExecutor  # noqa: F401
from repro.evolve.islands import ParetoArchive, migrate_ring  # noqa: F401
from repro.evolve.phase_cache import (  # noqa: F401
    PhaseCacheCorruptError,
    default_cache_dir,
    load_phase,
    phase_key,
    save_phase,
)
from repro.evolve.problems import (  # noqa: F401
    CampaignProblem,
    ProblemSpec,
    build_problem,
    build_synth_problem,
    build_tnn_problem,
    compile_archive_winner,
)
