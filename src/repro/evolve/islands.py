"""Island state + deterministic ring migration of Pareto-front elites."""
from __future__ import annotations

import numpy as np

from repro.core.nsga2 import (NSGA2State, crowding_distance, extract_front,
                              fast_non_dominated_sort)


def select_elites(state: NSGA2State, k: int) -> tuple[np.ndarray, np.ndarray]:
    """Up to `k` Pareto-front members (deduped, sorted by obj0) with their F."""
    X, F = extract_front(state.pop, state.F)
    return X[:k], F[:k]


def _replacement_order(F: np.ndarray) -> np.ndarray:
    """Indices worst-first: highest rank, then lowest crowding, then highest
    index — a total order with no RNG, so migration is deterministic."""
    N = F.shape[0]
    rank = np.empty(N, dtype=np.int64)
    crowd = np.empty(N)
    for r, fr in enumerate(fast_non_dominated_sort(F)):
        rank[fr] = r
        crowd[fr] = crowding_distance(F[fr])
    crowd = np.nan_to_num(crowd, posinf=np.finfo(np.float64).max)
    return np.lexsort((-np.arange(N), crowd, -rank))


def migrate_ring(states: list[NSGA2State], k: int) -> int:
    """Copy each island's top-`k` front elites into its ring successor.

    Elites are chosen from the *pre-migration* snapshot of every island, so
    the result is independent of island iteration order; they overwrite the
    receiver's worst-ranked individuals (objective values travel with the
    chromosomes — no re-evaluation).  Returns the number of migrants placed.
    """
    n = len(states)
    if n < 2 or k < 1:
        return 0
    elites = [select_elites(s, k) for s in states]
    placed = 0
    for dst in range(n):
        ex, ef = elites[(dst - 1) % n]
        if not len(ex):
            continue
        state = states[dst]
        worst = _replacement_order(state.F)[: len(ex)]
        state.pop[worst] = ex
        state.F[worst] = ef
        placed += len(ex)
    return placed


class ParetoArchive:
    """Global non-dominated archive across all islands and epochs.

    Maintains (X, F) pairs: dominated rows are dropped on every update,
    duplicate chromosomes collapse to one row, and the archive is kept in a
    canonical order (obj0, obj1, chromosome bytes) so two campaigns with
    identical trajectories serialize byte-identically.
    """

    def __init__(self, n_genes: int,
                 X: np.ndarray | None = None, F: np.ndarray | None = None):
        self.X = (np.zeros((0, n_genes), dtype=np.int64) if X is None
                  else np.asarray(X, dtype=np.int64))
        self.F = (np.zeros((0, 2), dtype=np.float64) if F is None
                  else np.asarray(F, dtype=np.float64))

    def __len__(self) -> int:
        return int(self.X.shape[0])

    def update(self, X: np.ndarray, F: np.ndarray) -> None:
        X = np.concatenate([self.X, np.asarray(X, dtype=np.int64)], axis=0)
        F = np.concatenate([self.F, np.asarray(F, dtype=np.float64)], axis=0)
        if not X.shape[0]:
            return
        # drop duplicate chromosomes (first occurrence wins)
        _, uniq = np.unique(X, axis=0, return_index=True)
        keep = np.sort(uniq)
        X, F = X[keep], F[keep]
        front = fast_non_dominated_sort(F)[0]
        X, F = X[front], F[front]
        order = np.lexsort(
            (np.array([x.tobytes() for x in X]), F[:, 1], F[:, 0]))
        self.X, self.F = X[order], F[order]

    def rows(self) -> list[dict]:
        """JSON-ready archive rows (chromosome + objectives)."""
        return [{"x": x.tolist(), "f": [float(f0), float(f1)]}
                for x, (f0, f1) in zip(self.X, self.F)]
