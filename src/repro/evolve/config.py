"""Campaign configuration for resumable island-model NSGA-II searches."""
from __future__ import annotations

from dataclasses import asdict, dataclass, field

from repro.core.nsga2 import NSGA2Config


@dataclass(frozen=True)
class CampaignConfig:
    """An island-model evolution campaign.

    `n_islands` independent NSGA-II populations evolve `gens_per_epoch`
    generations per epoch; at every epoch boundary each island's Pareto
    front is folded into the global archive and `migrate_k` front elites
    travel one step around the island ring.  Per-island RNG streams are
    derived as `seed + island * island_seed_stride`, so fronts are a pure
    function of (config, objective) — the determinism contract the resume
    and seed-determinism tests pin down.
    """

    n_islands: int = 4
    pop_size: int = 24
    n_epochs: int = 8
    gens_per_epoch: int = 5
    migrate_k: int = 2
    seed: int = 0
    island_seed_stride: int = 9973
    # evaluator backend for problems that honor it ("np" | "swar" | "pallas")
    eval_backend: str = "np"
    checkpoint_keep: int = 3
    # process-pool island executor: 0/1 = step islands serially in-process;
    # N>1 spawns N workers that advance islands concurrently within an
    # epoch (bit-identical to serial — islands only interact at migration
    # and archive-fold boundaries, which stay in the parent).  Excluded
    # from the resume fingerprint: a checkpoint written serially resumes
    # under any worker count and vice versa.
    workers: int = 0
    # LRU bound on the shared fitness memo (chromosome keys); None =
    # unbounded.  Pure memoization — eviction re-evaluates to the same
    # value — so this too is excluded from the fingerprint.
    memo_maxsize: int | None = 131072
    base: NSGA2Config = field(default_factory=NSGA2Config)   # operator params

    @property
    def total_generations(self) -> int:
        return self.n_epochs * self.gens_per_epoch

    def island_nsga2(self, island: int) -> NSGA2Config:
        """Per-island NSGA-II config (independent seed stream)."""
        b = self.base
        return NSGA2Config(
            pop_size=self.pop_size,
            n_generations=self.total_generations,
            crossover_prob=b.crossover_prob,
            crossover_eta=b.crossover_eta,
            mutation_eta=b.mutation_eta,
            mutation_prob=b.mutation_prob,
            seed=self.seed + island * self.island_seed_stride,
            dedup_eval=b.dedup_eval,
        )

    def to_dict(self) -> dict:
        return asdict(self)
