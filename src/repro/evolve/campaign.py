"""Resumable island-model NSGA-II campaigns.

A `Campaign` owns `n_islands` stepwise `NSGA2Driver`s over one shared
(memoized) objective, a global `ParetoArchive`, and a `CheckpointManager`.
Execution is epoch-structured:

    epoch e:  every island advances `gens_per_epoch` generations
              -> island fronts fold into the archive
              -> ring migration of `migrate_k` front elites
              -> checkpoint (island pops/objectives + archive as arrays,
                 RNG streams + epoch counter in the manifest extra)

`run()` first tries to resume: if the checkpoint directory holds a valid
snapshot for this config, populations, archive, histories and mid-stream
RNG states are restored and the loop continues at the next epoch — a
campaign SIGKILLed between generations replays to a bit-identical final
Pareto front versus an uninterrupted run (pinned by tests/test_evolve.py).
A snapshot truncated by the kill is detected by its checksum and the
previous epoch's snapshot loads instead (`checkpoint.manager`).

The fitness dedup cache is shared across islands: chromosomes are evaluated
once per campaign process no matter how many islands revisit them.  The
cache is pure memoization of a row-independent objective, so a resumed
process with a cold cache follows the identical trajectory.  It is LRU
bounded by `cfg.memo_maxsize`, and its hit/miss/eviction counters are
surfaced per epoch in `cache_history` (one row per `step_epoch`).

With `cfg.workers > 1` and a picklable `problem_spec`, epoch stepping
fans the islands out over `evolve.executor.IslandExecutor`'s process
pool — bit-identical to serial stepping (islands only interact at the
epoch boundary, which stays here) and transparent to checkpoints: the
parent still owns states, archive and manifest, so a campaign stepped
serially resumes parallel and vice versa.
"""
from __future__ import annotations

import hashlib
import json
import os
import signal
from dataclasses import dataclass, field
from typing import Callable

import numpy as np

from repro.checkpoint import CheckpointManager
from repro.core.nsga2 import (NSGA2Driver, NSGA2State, _memoized,
                              encode_rng_state, extract_front)
from repro.evolve.config import CampaignConfig
from repro.evolve.islands import ParetoArchive, migrate_ring

_CKPT_VERSION = 1


@dataclass
class CampaignResult:
    archive_x: np.ndarray    # (A, n_genes) global Pareto archive
    archive_f: np.ndarray    # (A, 2)
    epochs_run: int          # epochs executed in *this* process
    resumed_from: int | None # epoch of the loaded snapshot, if any
    histories: list[list[tuple[int, float, float]]] = field(
        default_factory=list)
    # one row per epoch stepped in this process: fitness-memo counters
    # (cumulative) + executor metadata — see Campaign.cache_history
    cache_history: list[dict] = field(default_factory=list)


class Campaign:
    """One resumable multi-island search over a fixed objective."""

    def __init__(self, domains: np.ndarray,
                 objective: Callable[[np.ndarray], np.ndarray],
                 cfg: CampaignConfig,
                 checkpoint_dir: str | None = None,
                 seed_population: np.ndarray | None = None,
                 name: str = "campaign",
                 problem_spec=None):
        self.domains = np.asarray(domains)
        self.cfg = cfg
        self.name = name
        self.n_genes = int(self.domains.shape[0])
        self.seed_population = seed_population
        evaluate = (_memoized(objective, maxsize=cfg.memo_maxsize)
                    if cfg.base.dedup_eval else objective)
        self._evaluate = evaluate       # shared memo (see clear_eval_cache)
        self.drivers = [
            NSGA2Driver(self.domains, objective, cfg.island_nsga2(i),
                        evaluate=evaluate)
            for i in range(cfg.n_islands)
        ]
        self.ckpt = (CheckpointManager(checkpoint_dir,
                                       keep=cfg.checkpoint_keep)
                     if checkpoint_dir else None)
        self.states: list[NSGA2State] = []
        self.archive = ParetoArchive(self.n_genes)
        self.next_epoch = 0
        self.resumed_from: int | None = None
        # fitness-memo counters, one row per epoch stepped here (serial
        # rows read the in-process memo; parallel rows aggregate the
        # worker memos reported with each epoch's step results)
        self.cache_history: list[dict] = []
        self.problem_spec = problem_spec
        self._executor = None           # built lazily on first step_epoch
        if cfg.workers > 1 and problem_spec is None:
            raise ValueError(
                f"cfg.workers={cfg.workers} needs a picklable problem_spec "
                "(ProblemSpec) — a bare objective callable cannot cross "
                "the process boundary")

    # -- checkpoint plumbing -------------------------------------------------
    def _state_tree(self) -> dict:
        return {
            "islands": [{"pop": np.ascontiguousarray(s.pop, dtype=np.int64),
                         "F": np.ascontiguousarray(s.F, dtype=np.float64)}
                        for s in self.states],
            "archive": {"X": self.archive.X, "F": self.archive.F},
        }

    def _template(self) -> dict:
        P = self.cfg.pop_size
        return {
            "islands": [{"pop": np.zeros((P, self.n_genes), dtype=np.int64),
                         "F": np.zeros((P, 2), dtype=np.float64)}
                        for _ in range(self.cfg.n_islands)],
            "archive": {"X": np.zeros((0, self.n_genes), dtype=np.int64),
                        "F": np.zeros((0, 2), dtype=np.float64)},
        }

    def _config_fingerprint(self) -> dict:
        """Every config field the generation sequence depends on.

        Deliberately excluded: `n_epochs` (extending a finished campaign is
        the resume feature) and `eval_backend` (all backends are
        bit-identical by the conformance contract, so resuming on a
        different executor cannot change the trajectory).
        """
        b = self.cfg.base
        return {"n_islands": self.cfg.n_islands,
                "pop_size": self.cfg.pop_size,
                "gens_per_epoch": self.cfg.gens_per_epoch,
                "migrate_k": self.cfg.migrate_k,
                "seed": self.cfg.seed,
                "island_seed_stride": self.cfg.island_seed_stride,
                "n_genes": self.n_genes,
                "crossover_prob": b.crossover_prob,
                "crossover_eta": b.crossover_eta,
                "mutation_eta": b.mutation_eta,
                "mutation_prob": b.mutation_prob,
                "dedup_eval": b.dedup_eval}

    def fingerprint(self) -> str:
        """sha256 of the trajectory-determining config — the provenance
        stamp emitted into manifest rows so a promotion decision can tell
        which search produced a candidate."""
        blob = json.dumps(self._config_fingerprint(), sort_keys=True)
        return hashlib.sha256(blob.encode()).hexdigest()

    def _save(self, epoch: int) -> None:
        if self.ckpt is None:
            return
        extra = {
            "version": _CKPT_VERSION,
            "name": self.name,
            "epoch": epoch,
            "rngs": [encode_rng_state(s.rng) for s in self.states],
            "generations": [s.generation for s in self.states],
            "histories": [[list(h) for h in s.history] for s in self.states],
            "config": self._config_fingerprint(),
        }
        self.ckpt.save(epoch, self._state_tree(), extra=extra)

    def _try_resume(self) -> bool:
        if self.ckpt is None or self.ckpt.latest_valid_step() is None:
            return False
        _, tree, extra = self.ckpt.restore(self._template(), to_device=False)
        saved = extra.get("config", {})
        mine = self._config_fingerprint()
        if {k: saved.get(k) for k in mine} != mine:
            raise ValueError(
                f"checkpoint under {self.ckpt.dir} was written by an "
                f"incompatible campaign config: {saved} vs {mine}")
        self.states = [
            self.drivers[i].restore_state(
                isl["pop"], isl["F"], extra["generations"][i],
                extra["rngs"][i],
                [tuple(h) for h in extra["histories"][i]])
            for i, isl in enumerate(tree["islands"])
        ]
        self.archive = ParetoArchive(self.n_genes, tree["archive"]["X"],
                                     tree["archive"]["F"])
        self.resumed_from = int(extra["epoch"])
        self.next_epoch = self.resumed_from + 1
        return True

    # -- execution -----------------------------------------------------------
    def init_or_resume(self) -> None:
        """Populate island states: resume from a valid checkpoint or init."""
        if self.states:
            return
        if not self._try_resume():
            self.states = [d.init_state(self.seed_population)
                           for d in self.drivers]
            self.next_epoch = 0

    def clear_eval_cache(self) -> None:
        """Drop the shared fitness memo between data refreshes.

        The dedup cache assumes a *fixed* objective; a drift hook that
        mutates the underlying data would otherwise keep serving stale
        fitness values for revisited chromosomes.  The autopilot calls
        this after every `CampaignProblem.drift` application.  With a
        live executor, worker memos are invalidated too (lazily, before
        the next row any worker evaluates).
        """
        clear = getattr(self._evaluate, "cache_clear", None)
        if clear is not None:
            clear()
        if self._executor is not None:
            self._executor.clear_eval_cache()

    def mark_drift(self, round_idx: int) -> None:
        """Record a `problem.drift(round_idx)` the caller just applied.

        Clears the in-process memo and, when stepping parallel, tells the
        executor so its workers replay the same deterministic drift round
        on their problem copies before stepping again.  Callers that
        drift must use this (not bare `clear_eval_cache`) if the campaign
        may run with `workers > 1`.
        """
        if self._executor is not None:
            self._executor.mark_drift(round_idx)
        clear = getattr(self._evaluate, "cache_clear", None)
        if clear is not None:
            clear()

    def _ensure_executor(self):
        if self._executor is None and self.cfg.workers > 1:
            from repro.evolve.executor import IslandExecutor
            self._executor = IslandExecutor(self.problem_spec, self.cfg,
                                            n_workers=self.cfg.workers)
        return self._executor

    def _record_cache_row(self, epoch: int, executor_stats: dict | None
                          ) -> None:
        if executor_stats is not None:
            row = {"epoch": epoch, "mode": "parallel", **executor_stats}
        else:
            info = getattr(self._evaluate, "cache_info", lambda: {})()
            row = {"epoch": epoch, "mode": "serial", **info}
        self.cache_history.append(row)

    def step_epoch(self) -> int:
        """Advance exactly one epoch (+checkpoint); returns its index.

        The continuous-evolution API: unlike `run()`, stepping is not
        bounded by `cfg.n_epochs` — a long-running controller keeps
        calling this for as long as it wants candidates, and every epoch
        lands a resumable checkpoint exactly like the batch path.

        With `cfg.workers > 1` the epoch's generations run on the island
        executor's process pool; archive fold, migration and the
        checkpoint stay in this process either way.
        """
        self.init_or_resume()
        epoch = self.next_epoch
        executor = self._ensure_executor()
        stats = None
        if executor is not None:
            self.states, stats = executor.step_islands(
                self.states, self.cfg.gens_per_epoch)
        else:
            for _ in range(self.cfg.gens_per_epoch):
                for i, driver in enumerate(self.drivers):
                    self.states[i] = driver.step(self.states[i])
        for state in self.states:
            self.archive.update(*extract_front(state.pop, state.F))
        migrate_ring(self.states, self.cfg.migrate_k)
        self._record_cache_row(epoch, stats)
        self._save(epoch)
        self.next_epoch = epoch + 1
        return epoch

    def close(self) -> None:
        """Tear down the executor pool, if one was spawned."""
        if self._executor is not None:
            self._executor.close()
            self._executor = None

    def __enter__(self) -> "Campaign":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    def best_by_objective(self, obj: int = 0) -> tuple[np.ndarray, np.ndarray]:
        """(chromosome, objectives) of the archive entry minimizing `obj`."""
        if not len(self.archive):
            raise ValueError("empty archive — step the campaign first")
        i = int(np.argmin(self.archive.F[:, obj]))
        return self.archive.X[i].copy(), self.archive.F[i].copy()

    def run(self, on_epoch: Callable[[int, "Campaign"], None] | None = None,
            kill_after_epoch: int | None = None) -> CampaignResult:
        """Advance to `cfg.n_epochs`, checkpointing every epoch boundary.

        `kill_after_epoch=e` SIGKILLs the process right after epoch e's
        checkpoint lands — the deterministic stand-in for an external kill
        between generations, used by the resume tests and the CLI's
        `--kill-after-epoch` debug flag.
        """
        self.init_or_resume()
        ran = 0
        while self.next_epoch < self.cfg.n_epochs:
            epoch = self.step_epoch()
            ran += 1
            if on_epoch is not None:
                on_epoch(epoch, self)
            if kill_after_epoch is not None and epoch >= kill_after_epoch:
                os.kill(os.getpid(), signal.SIGKILL)
        return CampaignResult(
            archive_x=self.archive.X.copy(), archive_f=self.archive.F.copy(),
            epochs_run=ran, resumed_from=self.resumed_from,
            histories=[list(s.history) for s in self.states],
            cache_history=[dict(r) for r in self.cache_history])
