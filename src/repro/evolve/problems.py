"""Campaign problem builders: synthetic (tests/CI) and the real Table-2 TNN.

`build_tnn_problem` runs the paper's Phase 1/2 pipeline (CGP popcount
libraries + Pareto PCC combinations) at a configurable budget and wraps the
Phase-3 `TNNApproxProblem` for the campaign runner; `compile_archive_winner`
closes the loop by lowering an archive chromosome straight through
`repro.compile.lower_classifier` to a servable `CompiledClassifier`.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable

import numpy as np


@dataclass
class CampaignProblem:
    """Everything a `Campaign` needs, plus decode hooks for the winner."""

    name: str
    domains: np.ndarray
    objective: Callable[[np.ndarray], np.ndarray]
    seed_population: np.ndarray | None = None
    # TNN problems carry their phase-3 context for compile/emit
    tnn: object | None = None
    approx: object | None = None        # core.tnn.TNNApproxProblem
    dataset: object | None = None       # data.tabular.TabularDataset
    # continuous-evolution hook: `drift(round)` refreshes the data the
    # objective scores against (deterministic in `round`).  Callers that
    # memoize fitness must clear their cache after applying it
    # (`Campaign.clear_eval_cache`).
    drift: Callable[[int], None] | None = None


def build_synth_problem(n_genes: int = 10, domain: int = 6) -> CampaignProblem:
    """Deterministic two-objective toy with a known diagonal Pareto front.

    Pure integer arithmetic — no training, no RNG — so two processes agree
    bit-for-bit on every objective value.  Used by the CLI's `synth` problem
    and the resume / seed-determinism tests.
    """
    domains = np.full(n_genes, domain, dtype=np.int64)
    scale = n_genes * (domain - 1)

    def objective(pop: np.ndarray) -> np.ndarray:
        pop = np.asarray(pop, dtype=np.int64)
        f0 = pop.sum(1) / scale
        f1 = (domain - 1 - pop).sum(1) / scale
        pen = (pop == 2).sum(1) * 0.2       # middle values are dominated
        return np.stack([f0 + pen, f1 + pen], 1)

    return CampaignProblem(name=f"synth{n_genes}x{domain}", domains=domains,
                           objective=objective)


def build_tnn_problem(dataset: str, seed: int = 0, epochs: int = 12,
                      cgp_points: int = 3, cgp_iters: int = 500,
                      pcc_samples: int = 30000,
                      eval_backend: str = "np") -> CampaignProblem:
    """Phases 1-3 setup for one Table-2 dataset at a configurable budget.

    Mirrors examples/evolve_approx_tnn.py: train the exact TNN, evolve
    approximate popcount libraries for every neuron size, build the Pareto
    PCC library, and return the NSGA-II integration problem whose objective
    scores whole populations (on `eval_backend` for the output-plane gate
    simulation).  Deterministic in (dataset, seed, budgets).
    """
    from repro.core import tnn as T
    from repro.core.cgp import evolve_pc_library
    from repro.core.nsga2 import NSGA2Config  # noqa: F401 (re-export site)
    from repro.core.pcc import build_pcc_library, pc_pareto
    from repro.core.ternary import abc_binarize
    from repro.data.tabular import make_dataset

    ds = make_dataset(dataset)
    tnn = T.train_tnn(ds, T.TNNTrainConfig(
        n_hidden=ds.spec.topology[1], epochs=epochs, lr=1e-2, seed=seed))

    sizes, pcc_sizes = set(), []
    for (p, n) in tnn.hidden_sizes():
        if p >= 1 and n >= 1:
            sizes.update([p, n])
            pcc_sizes.append((p, n))
    sizes.add(max(tnn.out_nnz, 1))
    pc_libs = {n: evolve_pc_library(n, n_points=cgp_points,
                                    max_iters=cgp_iters)
               for n in sorted(sizes)}
    pcc_lib = build_pcc_library(sorted(set(pcc_sizes)), pc_libs,
                                n_samples=pcc_samples)
    pc_out = pc_pareto(pc_libs[max(tnn.out_nnz, 1)])

    xb_tr = np.asarray(abc_binarize(ds.x_train, tnn.thresholds))
    prob = T.TNNApproxProblem(tnn=tnn, pcc_lib=pcc_lib, pc_out_lib=pc_out,
                              xbin=xb_tr, y=ds.y_train,
                              eval_backend=eval_backend)
    seed_pop = np.zeros((1, prob.n_genes), dtype=np.int64)  # all-exact design
    return CampaignProblem(name=f"tnn_{dataset}", domains=prob.domains(),
                           objective=prob.objective,
                           seed_population=seed_pop,
                           tnn=tnn, approx=prob, dataset=ds)


def attach_tnn_drift(problem: CampaignProblem, rate: float,
                     seed: int = 0) -> CampaignProblem:
    """Arm a TNN problem with a bootstrap-resampling drift hook.

    Each `drift(round)` call replaces `rate` of the objective's sample
    rows with fresh bootstrap draws from the original training pool — a
    cheap, deterministic stand-in for "the sensor stream moved" that
    reuses the cached per-candidate bit planes (the caches are per-sample
    rows, so reindexing them *is* redrawing the data; nothing is
    re-simulated).  Deterministic in `(seed, round)`: two controllers
    replaying the same round sequence score identical objectives.
    """
    if problem.approx is None:
        raise ValueError("only TNN problems carry a sample plane to drift")
    if not 0.0 < rate <= 1.0:
        raise ValueError("drift rate must be in (0, 1]")
    ap = problem.approx
    orig_hbits = ap.fixed_hbits.copy()
    orig_caches = [c.copy() for c in ap.hidden_bit_cache]
    orig_y = ap.y.copy()
    orig_xbin = ap.xbin.copy()
    S = orig_y.shape[0]
    index_map = np.arange(S)

    def drift(round_idx: int) -> None:
        rng = np.random.default_rng((seed, int(round_idx)))
        k = max(1, int(np.ceil(rate * S)))
        pos = rng.choice(S, size=k, replace=False)
        index_map[pos] = rng.integers(0, S, size=k)
        ap.fixed_hbits = orig_hbits[index_map]
        ap.hidden_bit_cache = [c[:, index_map] for c in orig_caches]
        ap.y = orig_y[index_map]
        ap.xbin = orig_xbin[index_map]

    problem.drift = drift
    return problem


def compile_archive_winner(problem: CampaignProblem, x: np.ndarray):
    """Lower one archive chromosome to a `CompiledClassifier` (emit/serve)."""
    if problem.approx is None:
        raise ValueError("only TNN problems can be compiled")
    from repro.compile import lower_classifier
    hidden_nls, out_nls = problem.approx.decode(np.asarray(x, dtype=np.int64))
    return lower_classifier(problem.tnn, hidden_nls, out_nls)
