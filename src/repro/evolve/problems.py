"""Campaign problem builders: synthetic (tests/CI) and the real Table-2 TNN.

`build_tnn_problem` runs the paper's Phase 1/2 pipeline (CGP popcount
libraries + Pareto PCC combinations) at a configurable budget and wraps the
Phase-3 `TNNApproxProblem` for the campaign runner; `compile_archive_winner`
closes the loop by lowering an archive chromosome straight through
`repro.compile.lower_classifier` to a servable `CompiledClassifier`.

The Phase-1/2 products are cached twice over: an in-process memo keyed by
the content hash (`evolve.phase_cache.phase_key`) makes repeated
`build_tnn_problem` calls with identical args free inside one process,
and the on-disk content-addressed cache (`evolve.phase_cache`) carries
them across processes — autopilot rounds, zoo sweeps, CI jobs, and the
spawned workers of the parallel island executor all skip retraining.

`ProblemSpec` is the picklable recipe a spawned executor worker uses to
rebuild the same problem on its side of the process boundary (closures
over numpy state don't pickle; a named builder + kwargs does).
"""
from __future__ import annotations

import warnings
from dataclasses import dataclass, field
from typing import Callable

import numpy as np


@dataclass
class CampaignProblem:
    """Everything a `Campaign` needs, plus decode hooks for the winner."""

    name: str
    domains: np.ndarray
    objective: Callable[[np.ndarray], np.ndarray]
    seed_population: np.ndarray | None = None
    # TNN problems carry their phase-3 context for compile/emit
    tnn: object | None = None
    approx: object | None = None        # core.tnn.TNNApproxProblem
    dataset: object | None = None       # data.tabular.TabularDataset
    # continuous-evolution hook: `drift(round)` refreshes the data the
    # objective scores against (deterministic in `round`).  Callers that
    # memoize fitness must clear their cache after applying it
    # (`Campaign.clear_eval_cache`).
    drift: Callable[[int], None] | None = None


@dataclass(frozen=True)
class ProblemSpec:
    """Picklable recipe for rebuilding a `CampaignProblem` in a worker.

    The parallel island executor spawns fresh processes; an objective
    closure cannot cross that boundary, but (builder name, kwargs) can.
    `build_problem` dispatches back to the named builder — workers
    rebuilding a TNN problem ride the phase cache, so the rebuild costs a
    cache load, not a retrain.
    """

    kind: str                       # "synth" | "tnn"
    kwargs: dict = field(default_factory=dict)

    def build(self) -> "CampaignProblem":
        return build_problem(self)


def build_problem(spec: ProblemSpec) -> CampaignProblem:
    """Rebuild the problem a `ProblemSpec` names (executor worker entry)."""
    if spec.kind == "synth":
        return build_synth_problem(**spec.kwargs)
    if spec.kind == "tnn":
        return build_tnn_problem(**spec.kwargs)
    raise ValueError(f"unknown problem kind {spec.kind!r} "
                     "(expected 'synth' or 'tnn')")


def build_synth_problem(n_genes: int = 10, domain: int = 6,
                        work: int = 0,
                        wait_ms: float = 0.0) -> CampaignProblem:
    """Deterministic two-objective toy with a known diagonal Pareto front.

    Pure integer arithmetic — no training, no RNG — so two processes agree
    bit-for-bit on every objective value.  Used by the CLI's `synth` problem
    and the resume / seed-determinism tests.

    Two expensive-objective stand-ins for the `evolve_parallel` benchmark
    (results discarded, objective values untouched either way):

      * `work` > 0 burns that many 128x128 matmuls per evaluated row —
        CPU-bound load that scales with cores;
      * `wait_ms` > 0 blocks that long per evaluated row — an objective
        that waits on an external device (accelerator dispatch, RPC),
        which is what the island executor overlaps even when only one
        core is visible (CPU-bound work cannot speed up there, blocking
        work can).
    """
    domains = np.full(n_genes, domain, dtype=np.int64)
    scale = n_genes * (domain - 1)
    burn = (np.linspace(0.0, 1.0, 128 * 128, dtype=np.float64)
            .reshape(128, 128) if work else None)

    def objective(pop: np.ndarray) -> np.ndarray:
        pop = np.asarray(pop, dtype=np.int64)
        if work:
            acc = burn
            for _ in range(work * pop.shape[0]):
                acc = burn @ acc
                acc *= 1e-4                     # keep magnitudes finite
        if wait_ms > 0.0:
            import time
            time.sleep(wait_ms * pop.shape[0] / 1000.0)
        f0 = pop.sum(1) / scale
        f1 = (domain - 1 - pop).sum(1) / scale
        pen = (pop == 2).sum(1) * 0.2       # middle values are dominated
        return np.stack([f0 + pen, f1 + pen], 1)

    name = (f"synth{n_genes}x{domain}" + (f"w{work}" if work else "")
            + (f"d{wait_ms:g}" if wait_ms else ""))
    return CampaignProblem(name=name, domains=domains, objective=objective)


# in-process memo over phase products, keyed by the content hash — the
# layer in front of the on-disk cache (same process, same args -> the
# exact TNN is trained once, not once per build_tnn_problem call)
_PHASE_MEMO: dict = {}


def clear_phase_memo() -> None:
    """Drop the in-process Phase-1/2 product memo (tests/benchmarks)."""
    _PHASE_MEMO.clear()


def _compute_phase_products(dataset: str, seed: int, epochs: int,
                            cgp_points: int, cgp_iters: int,
                            pcc_samples: int):
    """Run Phases 1-2 from scratch (the cache-miss path)."""
    from repro.core import tnn as T
    from repro.core.cgp import evolve_pc_library
    from repro.core.pcc import build_pcc_library, pc_pareto
    from repro.data.tabular import make_dataset

    ds = make_dataset(dataset)
    tnn = T.train_tnn(ds, T.TNNTrainConfig(
        n_hidden=ds.spec.topology[1], epochs=epochs, lr=1e-2, seed=seed))

    sizes, pcc_sizes = set(), []
    for (p, n) in tnn.hidden_sizes():
        if p >= 1 and n >= 1:
            sizes.update([p, n])
            pcc_sizes.append((p, n))
    sizes.add(max(tnn.out_nnz, 1))
    pc_libs = {n: evolve_pc_library(n, n_points=cgp_points,
                                    max_iters=cgp_iters)
               for n in sorted(sizes)}
    pcc_lib = build_pcc_library(sorted(set(pcc_sizes)), pc_libs,
                                n_samples=pcc_samples)
    pc_out = pc_pareto(pc_libs[max(tnn.out_nnz, 1)])
    return tnn, pc_libs, pcc_lib, pc_out


def _phase_products(dataset: str, seed: int, epochs: int, cgp_points: int,
                    cgp_iters: int, pcc_samples: int,
                    cache_dir: str | None):
    """Phase-1/2 products via memo -> disk cache -> recompute (+backfill)."""
    from repro.evolve import phase_cache as PC

    key = PC.phase_key(dataset, seed, epochs, cgp_points, cgp_iters,
                       pcc_samples)
    if key in _PHASE_MEMO:
        return _PHASE_MEMO[key]
    root = PC.default_cache_dir() if cache_dir is None else cache_dir
    if root is not None:
        try:
            products = PC.load_phase(root, key)
            _PHASE_MEMO[key] = products
            return products
        except FileNotFoundError:
            pass
        except PC.PhaseCacheCorruptError as exc:
            warnings.warn(f"{exc}", RuntimeWarning, stacklevel=3)
            PC.drop_entry(root, key)
    products = _compute_phase_products(dataset, seed, epochs, cgp_points,
                                       cgp_iters, pcc_samples)
    if root is not None:
        PC.save_phase(root, key, *products)
    _PHASE_MEMO[key] = products
    return products


def build_tnn_problem(dataset: str, seed: int = 0, epochs: int = 12,
                      cgp_points: int = 3, cgp_iters: int = 500,
                      pcc_samples: int = 30000,
                      eval_backend: str = "np",
                      cache_dir: str | None = None) -> CampaignProblem:
    """Phases 1-3 setup for one Table-2 dataset at a configurable budget.

    Mirrors examples/evolve_approx_tnn.py: train the exact TNN, evolve
    approximate popcount libraries for every neuron size, build the Pareto
    PCC library, and return the NSGA-II integration problem whose objective
    scores whole populations (on `eval_backend` for the output-plane gate
    simulation).  Deterministic in (dataset, seed, budgets) — which is why
    the expensive Phase-1/2 half is served from `evolve.phase_cache` (and
    an in-process memo) instead of recomputed per call.  `cache_dir=None`
    resolves the default cache root (``REPRO_PHASE_CACHE`` env, else
    ``~/.cache/repro/phase_cache``; set the env to ``off`` to disable).
    The cheap Phase-3 wrapper (`TNNApproxProblem` + its per-candidate bit
    caches) is rebuilt per call so callers can mutate their problem
    (drift hooks, `eval_backend` swaps) without aliasing each other.
    """
    from repro.core import tnn as T
    from repro.core.nsga2 import NSGA2Config  # noqa: F401 (re-export site)
    from repro.core.ternary import abc_binarize
    from repro.data.tabular import make_dataset

    tnn, pc_libs, pcc_lib, pc_out = _phase_products(
        dataset, seed, epochs, cgp_points, cgp_iters, pcc_samples, cache_dir)
    ds = make_dataset(dataset)
    xb_tr = np.asarray(abc_binarize(ds.x_train, tnn.thresholds))
    prob = T.TNNApproxProblem(tnn=tnn, pcc_lib=pcc_lib, pc_out_lib=pc_out,
                              xbin=xb_tr, y=ds.y_train,
                              eval_backend=eval_backend)
    seed_pop = np.zeros((1, prob.n_genes), dtype=np.int64)  # all-exact design
    return CampaignProblem(name=f"tnn_{dataset}", domains=prob.domains(),
                           objective=prob.objective,
                           seed_population=seed_pop,
                           tnn=tnn, approx=prob, dataset=ds)


def attach_tnn_drift(problem: CampaignProblem, rate: float,
                     seed: int = 0) -> CampaignProblem:
    """Arm a TNN problem with a bootstrap-resampling drift hook.

    Each `drift(round)` call replaces `rate` of the objective's sample
    rows with fresh bootstrap draws from the original training pool — a
    cheap, deterministic stand-in for "the sensor stream moved" that
    reuses the cached per-candidate bit planes (the caches are per-sample
    rows, so reindexing them *is* redrawing the data; nothing is
    re-simulated).  Deterministic in `(seed, round)`: two controllers
    replaying the same round sequence score identical objectives.
    """
    if problem.approx is None:
        raise ValueError("only TNN problems carry a sample plane to drift")
    if not 0.0 < rate <= 1.0:
        raise ValueError("drift rate must be in (0, 1]")
    ap = problem.approx
    orig_hbits = ap.fixed_hbits.copy()
    orig_caches = [c.copy() for c in ap.hidden_bit_cache]
    orig_y = ap.y.copy()
    orig_xbin = ap.xbin.copy()
    S = orig_y.shape[0]
    index_map = np.arange(S)

    def drift(round_idx: int) -> None:
        rng = np.random.default_rng((seed, int(round_idx)))
        k = max(1, int(np.ceil(rate * S)))
        pos = rng.choice(S, size=k, replace=False)
        index_map[pos] = rng.integers(0, S, size=k)
        ap.fixed_hbits = orig_hbits[index_map]
        ap.hidden_bit_cache = [c[:, index_map] for c in orig_caches]
        ap.y = orig_y[index_map]
        ap.xbin = orig_xbin[index_map]

    problem.drift = drift
    return problem


def compile_archive_winner(problem: CampaignProblem, x: np.ndarray):
    """Lower one archive chromosome to a `CompiledClassifier` (emit/serve)."""
    if problem.approx is None:
        raise ValueError("only TNN problems can be compiled")
    from repro.compile import lower_classifier
    hidden_nls, out_nls = problem.approx.decode(np.asarray(x, dtype=np.int64))
    return lower_classifier(problem.tnn, hidden_nls, out_nls)
