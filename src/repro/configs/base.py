"""Config dataclasses for architectures and input shapes.

Every assigned architecture is a `ModelConfig` in `repro/configs/<id>.py`;
shapes are the four assignment-wide cells.  `reduced()` derives the small
same-family config used by per-arch CPU smoke tests.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass


@dataclass(frozen=True)
class MoESpec:
    n_experts: int
    top_k: int
    capacity_factor: float = 1.25
    dense_residual: bool = False      # arctic: dense FFN in parallel with MoE
    d_ff_dense: int | None = None     # width of the parallel dense FFN


@dataclass(frozen=True)
class SSMSpec:
    kind: str                         # "mamba" | "rwkv6"
    state_size: int = 16              # mamba N
    conv_width: int = 4
    expand: int = 2                   # d_inner = expand * d_model
    dt_rank: int = 0                  # 0 -> d_inner (simplified)
    rwkv_head_size: int = 64
    lora_rank: int = 32


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                       # dense|moe|ssm|hybrid|audio|vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    d_head: int | None = None         # default d_model // n_heads
    rope: str = "std"                 # std | mrope | none
    rope_theta: float = 1e6
    mrope_sections: tuple[int, ...] = (16, 24, 24)
    qk_norm: bool = False
    qkv_bias: bool = False
    swa_window: int | None = None
    moe: MoESpec | None = None
    ssm: SSMSpec | None = None
    enc_layers: int = 0               # whisper encoder depth
    enc_seq: int = 1500               # whisper audio frames (stub frontend)
    frontend: str | None = None       # "audio" | "vision" (stub embeddings)
    n_vision_tokens: int = 256        # vlm stub patch embeddings per sample
    act: str = "swiglu"               # swiglu | gelu
    norm: str = "rmsnorm"             # rmsnorm | layernorm
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    quant: str = "dense"              # dense | ternary | ternary_packed
    param_dtype: str = "bfloat16"
    compute_dtype: str = "bfloat16"
    remat: bool = True
    opt_8bit: bool = False            # int8 AdamW moments (480b-scale fit)
    accum_dtype: str = "float32"      # gradient-accumulation buffer dtype
    moe_fsdp: str = "d"               # expert-weight extra shard dim: d|f|none
    attn_block_k: int = 1024          # blockwise-attention KV block size
    serve_fsdp: bool = True           # False: serving params TP-only (no
                                      # per-token FSDP weight gathers)
    kv_cache_dtype: str = "compute"   # "compute" | "float8_e4m3fn"
    replicate_kv: bool = False        # replicate wk/wv across "model": tiny
                                      # redundant compute kills the per-layer
                                      # k/v all-gather (GQA K << model axis)
    serve_sharded_logits: bool = False  # keep decode logits vocab-sharded
    notes: str = ""

    @property
    def head_dim(self) -> int:
        return self.d_head if self.d_head is not None else self.d_model // self.n_heads

    @property
    def attention_free(self) -> bool:
        return self.family == "ssm" and self.ssm is not None and self.ssm.kind == "rwkv6"

    @property
    def sub_quadratic(self) -> bool:
        """Eligible for long_500k (SSM / hybrid / windowed attention)."""
        return self.attention_free or self.family == "hybrid" or self.swa_window is not None

    @property
    def has_decoder(self) -> bool:
        return True   # all assigned archs decode (whisper is enc-dec)

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    def reduced(self) -> "ModelConfig":
        """Small same-family config for CPU smoke tests."""
        half = 16 // 2   # reduced d_head = 16
        sec = (half - 2 * (half * 3 // 8), half * 3 // 8, half * 3 // 8)
        kw: dict = dict(
            n_layers=2, d_model=64,
            n_heads=4, n_kv_heads=max(1, min(self.n_kv_heads, 2)),
            d_head=16, d_ff=128, vocab=128,
            mrope_sections=sec,
            enc_layers=2 if self.enc_layers else 0, enc_seq=12,
            n_vision_tokens=4 if self.frontend == "vision" else self.n_vision_tokens,
            param_dtype="float32", compute_dtype="float32",
            remat=False, opt_8bit=False,
            swa_window=8 if self.swa_window else None,
        )
        if self.moe is not None:
            kw["moe"] = dataclasses.replace(
                self.moe, n_experts=4, top_k=2,
                d_ff_dense=64 if self.moe.d_ff_dense else None)
        if self.ssm is not None:
            kw["ssm"] = dataclasses.replace(
                self.ssm, state_size=4, rwkv_head_size=16, lora_rank=4)
        return self.replace(**kw)


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str        # "train" | "prefill" | "decode"


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


def shape_applicable(cfg: ModelConfig, shape: ShapeConfig) -> tuple[bool, str]:
    """(runnable, reason-if-not).  long_500k needs sub-quadratic attention."""
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, ("pure full-attention arch: 500k-token KV decode has no "
                       "sub-quadratic path (DESIGN.md §Arch-applicability)")
    return True, ""
