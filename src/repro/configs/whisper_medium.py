"""whisper-medium [audio] — encoder-decoder, conv frontend stubbed.

24L d_model=1024 16H (MHA kv=16) d_ff=4096 vocab=51865 [arXiv:2212.04356].
24 encoder + 24 decoder layers; the conv1d/log-mel frontend is a STUB:
input_specs() provides precomputed frame embeddings (B, 1500, d_model).
LayerNorm + GELU per the original architecture; learned positions.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-medium",
    family="audio",
    n_layers=24,          # decoder depth
    enc_layers=24,
    enc_seq=1500,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_head=64,
    d_ff=4096,
    vocab=51865,
    rope="none",          # learned positional embeddings
    act="gelu",
    norm="layernorm",
    frontend="audio",
    notes="full attention -> long_500k skipped",
)
