"""hymba-1.5b [hybrid] — parallel attention + Mamba heads per layer.

32L d_model=1600 25H (GQA kv=5) d_ff=5504 vocab=32001, ssm_state=16
[arXiv:2411.13676; hf].  Simplifications recorded in DESIGN.md: meta tokens
omitted; sliding-window attention (2048) on the attention path, which is the
property that makes long_500k decode O(window + state) and hence runnable.
"""
from repro.configs.base import ModelConfig, SSMSpec

CONFIG = ModelConfig(
    name="hymba-1.5b",
    family="hybrid",
    n_layers=32,
    d_model=1600,
    n_heads=25,
    n_kv_heads=5,
    d_head=64,
    d_ff=5504,
    vocab=32001,
    rope="std",
    rope_theta=1e4,
    swa_window=2048,
    ssm=SSMSpec(kind="mamba", state_size=16, conv_width=4, expand=2),
)
