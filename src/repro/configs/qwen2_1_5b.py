"""qwen2-1.5b [dense] — GQA kv=2, QKV bias. 28L d_model=1536 12H d_ff=8960
vocab=151936 [arXiv:2407.10671]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-1.5b",
    family="dense",
    n_layers=28,
    d_model=1536,
    n_heads=12,
    n_kv_heads=2,
    d_head=128,
    d_ff=8960,
    vocab=151936,
    rope="std",
    rope_theta=1e6,
    qkv_bias=True,
    tie_embeddings=True,
    notes="full attention -> long_500k skipped",
)
