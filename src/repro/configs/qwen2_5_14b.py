"""qwen2.5-14b [dense] — GQA kv=8, QKV bias. 48L d_model=5120 40H d_ff=13824
vocab=152064 [hf:Qwen/Qwen2.5 family]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2.5-14b",
    family="dense",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    d_head=128,
    d_ff=13824,
    vocab=152064,
    rope="std",
    rope_theta=1e6,
    qkv_bias=True,
    notes="full attention -> long_500k skipped",
)
