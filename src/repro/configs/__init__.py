"""Architecture registry: one module per assigned architecture.

`get_config(name)` resolves an arch id (e.g. "qwen3-4b") to its ModelConfig;
`ARCHS` lists all assigned ids.  The paper's own TNN configs live in
`repro.configs.tnn_paper`.
"""
from __future__ import annotations

import importlib

from repro.configs.base import (  # noqa: F401
    ModelConfig,
    MoESpec,
    SSMSpec,
    ShapeConfig,
    SHAPES,
    shape_applicable,
)

ARCHS: tuple[str, ...] = (
    "qwen2-vl-72b",
    "hymba-1.5b",
    "whisper-medium",
    "arctic-480b",
    "mixtral-8x22b",
    "llama3.2-1b",
    "qwen2-1.5b",
    "qwen3-4b",
    "qwen2.5-14b",
    "rwkv6-7b",
)

_MODULES = {name: "repro.configs." + name.replace("-", "_").replace(".", "_")
            for name in ARCHS}


def get_config(name: str) -> ModelConfig:
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; available: {sorted(_MODULES)}")
    mod = importlib.import_module(_MODULES[name])
    return mod.CONFIG
