"""mixtral-8x22b [moe] — 8 experts top-2, sliding-window attention.

56L d_model=6144 48H (GQA kv=8) d_ff=16384 vocab=32768 [arXiv:2401.04088].
SWA window 4096 -> rolling KV cache is O(window), so long_500k decode runs.
8 experts < 16-way model axis -> TP inside experts (F on "model"), experts
co-located (DESIGN.md §6 EP-vs-TP fallback).
"""
from repro.configs.base import ModelConfig, MoESpec

CONFIG = ModelConfig(
    name="mixtral-8x22b",
    family="moe",
    n_layers=56,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_head=128,
    d_ff=16384,
    vocab=32768,
    rope="std",
    rope_theta=1e6,
    swa_window=4096,
    moe=MoESpec(n_experts=8, top_k=2, capacity_factor=1.25),
)
