"""qwen2-vl-72b [vlm] — M-RoPE, dynamic-resolution vision (stub frontend).

80L d_model=8192 64H (GQA kv=8) d_ff=29568 vocab=152064 [arXiv:2409.12191; hf]
Vision tower is a STUB per the assignment: input_specs() provides precomputed
patch embeddings merged into the first n_vision_tokens positions; M-RoPE
position ids (B, 3, S) carry the (t, h, w) streams.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-72b",
    family="vlm",
    n_layers=80,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_head=128,
    d_ff=29568,
    vocab=152064,
    rope="mrope",
    rope_theta=1e6,
    mrope_sections=(16, 24, 24),
    qkv_bias=True,              # qwen2 family uses QKV bias
    frontend="vision",
    n_vision_tokens=256,
    notes="full attention -> long_500k skipped (DESIGN.md §Arch-applicability)",
)
