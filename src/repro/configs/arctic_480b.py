"""arctic-480b [moe] — 128 experts top-2 + parallel dense residual FFN.

35L d_model=7168 56H (GQA kv=8) d_ff=4864 vocab=32000
[hf:Snowflake/snowflake-arctic-base].  Dense-MoE hybrid: every layer runs a
dense FFN residual branch in parallel with the 128e top-2 MoE.  Experts
shard over the model axis (EP, 128 % 16 == 0); int8 AdamW moments keep the
optimizer inside 16 GB/chip on a single 256-chip pod (DESIGN.md §6).
"""
from repro.configs.base import ModelConfig, MoESpec

CONFIG = ModelConfig(
    name="arctic-480b",
    family="moe",
    n_layers=35,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    d_head=128,
    d_ff=4864,
    vocab=32000,
    rope="std",
    rope_theta=1e6,
    moe=MoESpec(n_experts=128, top_k=2, capacity_factor=1.25,
                dense_residual=True, d_ff_dense=4864),
    opt_8bit=True,
    notes="full attention -> long_500k skipped",
)
