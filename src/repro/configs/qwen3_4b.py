"""qwen3-4b [dense] — qk_norm, GQA kv=8. 36L d_model=2560 32H d_ff=9728
vocab=151936 [hf:Qwen/Qwen3-8B family].  Note qwen3 uses a decoupled
head_dim=128 (n_heads*d_head != d_model)."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-4b",
    family="dense",
    n_layers=36,
    d_model=2560,
    n_heads=32,
    n_kv_heads=8,
    d_head=128,
    d_ff=9728,
    vocab=151936,
    rope="std",
    rope_theta=1e6,
    qk_norm=True,
    tie_embeddings=True,
    notes="full attention -> long_500k skipped",
)
