"""rwkv6-7b [ssm] — "Finch", attention-free, data-dependent decay.

32L d_model=4096 d_ff=14336 vocab=65536 [arXiv:2404.05892].  64 heads of
size 64 in the WKV mixer; O(1)-state decode makes long_500k trivial
(state replaces the KV cache entirely).
"""
from repro.configs.base import ModelConfig, SSMSpec

CONFIG = ModelConfig(
    name="rwkv6-7b",
    family="ssm",
    n_layers=32,
    d_model=4096,
    n_heads=64,            # wkv heads (d_model / rwkv_head_size)
    n_kv_heads=64,
    d_head=64,
    d_ff=14336,
    vocab=65536,
    rope="none",
    ssm=SSMSpec(kind="rwkv6", rwkv_head_size=64, lora_rank=64),
)
