"""The paper's own bespoke-TNN configurations (Table 2).

One entry per UCI dataset: topology (in, hidden, out), training recipe
bands (epochs 10-20, lr 1e-3..1e-2), and the approximation-run defaults
used by the benchmarks.  These are the `--arch tnn-<dataset>` configs of
the faithful scale; the LM-scale archs live in the sibling modules.
"""
from __future__ import annotations

from dataclasses import dataclass

from repro.data.tabular import DATASETS


@dataclass(frozen=True)
class TNNPaperConfig:
    dataset: str
    topology: tuple[int, int, int]
    epochs: int = 15
    lrs: tuple[float, ...] = (2e-3, 5e-3, 1e-2)
    seeds: tuple[int, ...] = (0, 1)
    # Phase-1 CGP budget (scaled from the paper's 30-300 min limits)
    cgp_points: int = 4
    cgp_iters: int = 800
    # Phase-3 NSGA-II budget (paper: pop from pymoo defaults, 200 gens)
    nsga_pop: int = 32
    nsga_generations: int = 60


TNN_CONFIGS: dict[str, TNNPaperConfig] = {
    name: TNNPaperConfig(dataset=name, topology=spec.topology)
    for name, spec in DATASETS.items()
}


def get_tnn_config(dataset: str) -> TNNPaperConfig:
    return TNN_CONFIGS[dataset]
