"""Fleet autoscaling + admission rate limiting as pure, clock-free logic.

PR 5 left the control signals lying on the table: every tenant already
tracks `n_shed` (admission pressure), a dispatch-cost EMA (how expensive
a flush is right now) and queue depth (how far behind the scheduler is).
This module turns those into replica-count decisions — and adds the
token buckets that gate per-tenant admission — without owning a thread
or reading a wall clock.  Callers pass `now` / tick explicitly:

  * the fleet's `autoscale_tick()` snapshots per-tenant `TenantSignals`
    under its scheduler conditions and feeds them to `Autoscaler.observe`,
    applying the returned deltas (grow replicas built outside the lock,
    shrink only idle ones);
  * the deterministic tests drive the identical decision code with
    hand-built signals and a fake clock — bounded rounds, zero timing
    flake.

Hysteresis is round-based: a tenant must show pressure for `up_rounds`
consecutive observations before it grows, be completely idle for
`down_rounds` before it shrinks, and after any action sits out a
`cooldown_rounds` refractory period so the controller cannot thrash.
Shadow tenants (non-routable mirrors deployed by the autopilot) are
*never* scaled — their load is a copy of the incumbent's, and resizing
them would skew the promotion comparison they exist to make.
"""
from __future__ import annotations

from dataclasses import dataclass, field

QOS_CLASSES = ("guaranteed", "best_effort")


class TokenBucket:
    """Classic token bucket; `now` is always passed in, never sampled.

    `rate` tokens accrue per second up to `burst`; `take_upto` grants as
    many of the requested tokens as the bucket holds (the prefix-admission
    shape `submit_many` needs), and `retry_after_s` tells a shed caller
    when `need` tokens will next be available — the honest `retry_after_ms`
    hint for rate sheds.
    """

    def __init__(self, rate: float, burst: float, *, now: float = 0.0):
        if rate <= 0:
            raise ValueError("token bucket rate must be positive")
        if burst < 1:
            raise ValueError("token bucket burst must be >= 1")
        self.rate = float(rate)
        self.burst = float(burst)
        self._tokens = float(burst)
        self._t_last = float(now)

    def _refill(self, now: float) -> None:
        dt = now - self._t_last
        if dt > 0:
            self._tokens = min(self.burst, self._tokens + dt * self.rate)
        self._t_last = max(self._t_last, now)

    def tokens(self, now: float) -> float:
        self._refill(now)
        return self._tokens

    def take_upto(self, n: int, now: float) -> int:
        """Consume and return min(n, whole tokens available)."""
        if n <= 0:
            return 0
        self._refill(now)
        grant = min(int(n), int(self._tokens))
        if grant > 0:
            self._tokens -= grant
        return grant

    def retry_after_s(self, need: int, now: float) -> float:
        """Seconds until `need` tokens will be available (0 if already)."""
        self._refill(now)
        deficit = max(0.0, float(need) - self._tokens)
        return deficit / self.rate


@dataclass
class AutoscaleConfig:
    """Hysteresis knobs for the replica autoscaler (all round-based)."""

    up_rounds: int = 2           # consecutive pressured rounds before grow
    down_rounds: int = 3         # consecutive idle rounds before shrink
    cooldown_rounds: int = 1     # refractory rounds after any action
    grow_step: int = 1           # replicas added per grow action
    queue_high_frac: float = 0.5  # queued/capacity above this = pressure
    shed_pressure: int = 1       # shed delta >= this per round = pressure
    cost_high_ms: float | None = None  # dispatch EMA above this = pressure

    def __post_init__(self):
        if self.up_rounds < 1 or self.down_rounds < 1:
            raise ValueError("hysteresis rounds must be >= 1")
        if self.cooldown_rounds < 0:
            raise ValueError("cooldown_rounds must be >= 0")
        if self.grow_step < 1:
            raise ValueError("grow_step must be >= 1")
        if not 0.0 < self.queue_high_frac <= 1.0:
            raise ValueError("queue_high_frac must be in (0, 1]")


@dataclass
class TenantSignals:
    """One tenant's control signals for one autoscaler round."""

    name: str
    pool_size: int
    queue_depth: int             # requests sitting in the micro-batch queue
    inflight: int                # dispatches currently executing
    shed_delta: int              # sheds recorded since the last round
    request_delta: int           # admissions since the last round
    est_dispatch_ms: float       # the tenant's dispatch-cost EMA
    max_batch: int
    max_queue: int | None
    min_replicas: int = 1
    max_replicas: int = 1
    is_shadow: bool = False


@dataclass
class _TenantScaleState:
    pressure_rounds: int = 0
    idle_rounds: int = 0
    cooldown: int = 0


@dataclass
class ScaleAction:
    """One decided resize: tenant + signed replica delta + the why."""

    name: str
    delta: int
    reason: str
    round_no: int

    def as_dict(self) -> dict:
        return {"tenant": self.name, "delta": self.delta,
                "reason": self.reason, "round": self.round_no}


class Autoscaler:
    """Round-based grow/shrink decisions with hysteresis and bounds.

    `observe` is the entire control law: feed it every tenant's signals
    for the round, get back the list of `ScaleAction`s to apply.  It is
    deterministic (no clocks, no randomness) and keeps only per-tenant
    round counters between calls, so tests can step it to a decision in
    a bounded, known number of rounds.
    """

    def __init__(self, config: AutoscaleConfig | None = None):
        self.config = config or AutoscaleConfig()
        self.round_no = 0
        self._states: dict[str, _TenantScaleState] = {}

    def _pressured(self, s: TenantSignals) -> bool:
        cfg = self.config
        if s.shed_delta >= cfg.shed_pressure:
            return True
        capacity = (s.max_queue if s.max_queue is not None
                    else s.max_batch * max(1, s.pool_size))
        if capacity > 0 and s.queue_depth >= cfg.queue_high_frac * capacity:
            return True
        if cfg.cost_high_ms is not None and s.est_dispatch_ms >= cfg.cost_high_ms:
            return True
        return False

    @staticmethod
    def _idle(s: TenantSignals) -> bool:
        return (s.queue_depth == 0 and s.inflight == 0
                and s.request_delta == 0 and s.shed_delta == 0)

    def observe(self, signals: list[TenantSignals]) -> list[ScaleAction]:
        cfg = self.config
        self.round_no += 1
        actions: list[ScaleAction] = []
        seen = set()
        for s in signals:
            seen.add(s.name)
            if s.is_shadow:
                # shadows mirror the incumbent's traffic; never resize them
                self._states.pop(s.name, None)
                continue
            st = self._states.setdefault(s.name, _TenantScaleState())
            if st.cooldown > 0:
                st.cooldown -= 1
                st.pressure_rounds = 0
                st.idle_rounds = 0
                continue
            if self._pressured(s):
                st.pressure_rounds += 1
                st.idle_rounds = 0
            elif self._idle(s):
                st.idle_rounds += 1
                st.pressure_rounds = 0
            else:
                st.pressure_rounds = 0
                st.idle_rounds = 0
            if (st.pressure_rounds >= cfg.up_rounds
                    and s.pool_size < s.max_replicas):
                delta = min(cfg.grow_step, s.max_replicas - s.pool_size)
                actions.append(ScaleAction(s.name, delta, "pressure",
                                           self.round_no))
                st.pressure_rounds = 0
                st.cooldown = cfg.cooldown_rounds
            elif (st.idle_rounds >= cfg.down_rounds
                    and s.pool_size > max(1, s.min_replicas)):
                actions.append(ScaleAction(s.name, -1, "idle", self.round_no))
                st.idle_rounds = 0
                st.cooldown = cfg.cooldown_rounds
        # drop state for tenants that disappeared (retired / replaced away)
        for name in list(self._states):
            if name not in seen:
                del self._states[name]
        return actions

    def summary(self) -> dict:
        return {
            "round": self.round_no,
            "tracked": sorted(self._states),
            "config": {
                "up_rounds": self.config.up_rounds,
                "down_rounds": self.config.down_rounds,
                "cooldown_rounds": self.config.cooldown_rounds,
                "grow_step": self.config.grow_step,
                "queue_high_frac": self.config.queue_high_frac,
                "shed_pressure": self.config.shed_pressure,
                "cost_high_ms": self.config.cost_high_ms,
            },
        }
