"""Deadline-driven micro-batching: the fleet's flush policy as pure logic.

A tenant's queue used to be drained by a manual `flush()` call; the fleet
replaces that with a policy object that decides *when* a batch is due:

  * **full**     — `max_batch` requests are queued (amortization can't
    improve further, ship it), or
  * **deadline** — the oldest queued request could not sit through one more
    dispatch interval without busting its latency budget (waiting any
    longer would convert a possible hit into a certain miss).

The policy is deliberately free of threads and wall clocks — callers pass
`now` explicitly (the fleet passes `time.perf_counter()`, the property
tests a fake clock), and callers synchronize access (the fleet holds its
scheduler condition around every call).  That split is what lets the
hypothesis suite drive arbitrary arrival orders, batch sizes and budgets
through the exact production decision code with zero timing flake.

Invariants (pinned by tests/test_serve_fleet.py):
  * batches are formed in arrival order and never reordered within a
    tenant;
  * no batch exceeds `max_batch`;
  * `drain()` empties the queue, in order, on shutdown.
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass
from typing import Any, Iterator


@dataclass
class QueuedItem:
    """One queued request: payload + the timing the flush policy needs."""

    item: Any
    t_submit: float
    deadline_s: float          # latency budget, seconds from t_submit

    @property
    def due_at(self) -> float:
        return self.t_submit + self.deadline_s


class MicroBatcher:
    """Arrival-order queue with the full-or-deadline flush policy."""

    def __init__(self, max_batch: int, default_deadline_ms: float):
        if max_batch < 1:
            raise ValueError("max_batch must be >= 1")
        if default_deadline_ms <= 0:
            raise ValueError("deadline budget must be positive")
        self.max_batch = max_batch
        self.default_deadline_ms = default_deadline_ms
        self._queue: deque[QueuedItem] = deque()

    def __len__(self) -> int:
        return len(self._queue)

    def __iter__(self) -> Iterator[QueuedItem]:
        return iter(self._queue)

    def submit(self, item: Any, now: float,
               deadline_ms: float | None = None) -> QueuedItem:
        deadline_ms = (self.default_deadline_ms if deadline_ms is None
                       else deadline_ms)
        if deadline_ms <= 0:
            raise ValueError("deadline budget must be positive")
        entry = QueuedItem(item, now, deadline_ms * 1e-3)
        self._queue.append(entry)
        return entry

    def submit_many(self, items: list, now: float,
                    deadlines_ms=None) -> list[QueuedItem]:
        """Enqueue a whole batched frame as one contiguous arrival-order run.

        The ingest fast path: the fleet holds its scheduler lock exactly
        once per *frame* instead of once per reading.  `deadlines_ms` is
        None (every row gets the default budget) or one value per item,
        where NaN rows fall back to the default — the v2 wire encoding.
        All rows share one `t_submit`, which is what "arrived as one
        frame" means to the flush policy.

        Admission is all-or-nothing per frame: the entire deadline table
        is validated before any entry is constructed, so a bad row late
        in the frame cannot leave earlier rows materialized (let alone
        enqueued) while the caller sees a ValueError.
        """
        default_s = self.default_deadline_ms * 1e-3
        if deadlines_ms is None:
            entries = [QueuedItem(item, now, default_s) for item in items]
        else:
            if len(deadlines_ms) != len(items):
                raise ValueError(f"{len(deadlines_ms)} deadlines for "
                                 f"{len(items)} items")
            budgets_s = []
            for d in deadlines_ms:
                d = float(d)
                if d != d:                  # NaN -> tenant default
                    budgets_s.append(default_s)
                elif d <= 0:
                    raise ValueError("deadline budget must be positive")
                else:
                    budgets_s.append(d * 1e-3)
            entries = [QueuedItem(item, now, b)
                       for item, b in zip(items, budgets_s)]
        self._queue.extend(entries)
        return entries

    def adopt(self, entries: list[QueuedItem]) -> None:
        """Take over already-timed entries from another batcher, in order.

        The hot-reload transfer path: when a tenant is replaced, its
        queued-but-undispatched requests move to the successor's queue
        with their original submit times and budgets intact, so a reload
        never resets anyone's deadline clock.
        """
        self._queue.extend(entries)

    @property
    def oldest_due_at(self) -> float | None:
        return self._queue[0].due_at if self._queue else None

    def due(self, now: float, est_dispatch_s: float = 0.0) -> bool:
        """Is a batch due right now (full, or oldest about to bust budget)?"""
        if len(self._queue) >= self.max_batch:
            return True
        if not self._queue:
            return False
        return now + est_dispatch_s >= self._queue[0].due_at

    def next_due_at(self, est_dispatch_s: float = 0.0) -> float | None:
        """Earliest instant `due` can flip true without new arrivals."""
        if not self._queue:
            return None
        if len(self._queue) >= self.max_batch:
            return self._queue[0].t_submit        # already due (in the past)
        return self._queue[0].due_at - est_dispatch_s

    def pop_batch(self) -> list[QueuedItem]:
        """Up to `max_batch` oldest entries, in arrival order."""
        n = min(len(self._queue), self.max_batch)
        return [self._queue.popleft() for _ in range(n)]

    def drain(self) -> list[list[QueuedItem]]:
        """Everything left, as consecutive arrival-order batches."""
        batches = []
        while self._queue:
            batches.append(self.pop_batch())
        return batches
