"""Length-prefixed binary wire protocol for the sensor-serving fleet.

The transport half of `repro.serve`'s network front: pure
bytes-in/bytes-out framing + message codecs with no sockets, threads or
asyncio in them, shared verbatim by the asyncio server (`server.py`) and
the blocking client (`client.py`) — and therefore drivable by hypothesis
through arbitrary chunkings without either endpoint in the loop.

Framing: every message is ``!I`` payload length (big-endian u32, length
of the payload only) followed by the payload; payload byte 0 is the
message type, the rest is type-specific fixed `struct` fields + raw
bodies.  Sensor readings travel as raw little-endian float64 — the same
bytes `np.float64.tobytes()` produces on every platform we serve from —
so a reading crosses the wire without any text encode/decode on the hot
path.  A 64 MiB frame cap bounds memory against a corrupt or hostile
length prefix.

Conversation:

  client  ──HELLO──▶  server          magic + highest version it speaks
  client  ◀─WELCOME── server          negotiated version (min of the two)
  client  ──SUBMIT──▶ server          req_id, tenant, deadline, readings
  client  ──SUBMIT_BATCH──▶ server    v2: many readings in one frame
  client  ◀─RESULT──  server          req_id, label, server latency
  client  ◀─RESULT_BATCH── server     v2: many completions in one frame
  client  ◀─SHED────  server          req_id, retry_after_ms  (admission)
  client  ◀─ERROR───  server          req_id (or CONN_ERR), message
  client  ──LIST/STATS/RELOAD──▶      JSON-bodied admin round-trips

RESULT/SHED/ERROR stream back in completion order, not submit order —
req_ids are the correlation, so a client may pipeline arbitrarily many
SUBMITs before reading anything back.

**Version negotiation** (v2): HELLO carries the highest version the
client speaks; the server answers WELCOME with ``min(client, server)``
and both sides hold to that for the rest of the connection.  A v1 client
(HELLO version 1) therefore keeps working against a v2 server — it is
answered with WELCOME version 1 and only ever sees v1 frames.

**Batch frames** (v2): `SUBMIT_BATCH` amortizes framing + syscall +
event-loop cost over a whole sensor batch — one contiguous little-endian
float64 ``(B, F)`` reading plane prefixed by a packed per-row req_id
(u64) and deadline (f8, NaN = tenant default) table.  `RESULT_BATCH` is
the mirror image for completions (req_id/label/latency tables).  Both
stay inside the 64 MiB frame cap: `encode_submit_batch` refuses larger
planes (`batch_rows_per_frame` tells a sender how to chunk).
"""
from __future__ import annotations

import json
import struct
from dataclasses import dataclass

import numpy as np

PROTOCOL_MAGIC = b"RSRV"
PROTOCOL_VERSION = 2            # highest version this codec speaks
MIN_PROTOCOL_VERSION = 1        # oldest version still negotiable
MAX_FRAME = 64 << 20            # hard cap on one payload (corruption guard)
CONN_ERR = 0xFFFFFFFFFFFFFFFF   # req_id of a connection-level ERROR

MSG_HELLO = 1
MSG_WELCOME = 2
MSG_SUBMIT = 3
MSG_RESULT = 4
MSG_SHED = 5
MSG_ERROR = 6
MSG_LIST = 7
MSG_TENANTS = 8
MSG_STATS = 9
MSG_STATS_REPLY = 10
MSG_RELOAD = 11
MSG_RELOADED = 12
MSG_SUBMIT_BATCH = 13           # v2
MSG_RESULT_BATCH = 14           # v2

_LEN = struct.Struct("!I")
_HELLO = struct.Struct("!4sB")          # magic, version
_SUBMIT_HEAD = struct.Struct("!QdHI")   # req_id, deadline_ms, name_len, n_feat
_RESULT = struct.Struct("!Qid")         # req_id, label, latency_ms
_SHED = struct.Struct("!Qd")            # req_id, retry_after_ms
_ERROR_HEAD = struct.Struct("!QH")      # req_id, msg_len
_SUBMIT_BATCH_HEAD = struct.Struct("!HII")   # name_len, n_rows, n_feat
_RESULT_BATCH_HEAD = struct.Struct("!I")     # n_rows
_ROW_TABLE_BYTES = 8 + 8        # per-row req_id (u64) + deadline (f8)


class ProtocolError(RuntimeError):
    """Malformed frame / bad magic / version mismatch / oversized payload."""


def frame(payload: bytes) -> bytes:
    """Wrap one payload in its length prefix."""
    if len(payload) > MAX_FRAME:
        raise ProtocolError(f"payload of {len(payload)} bytes exceeds the "
                            f"{MAX_FRAME}-byte frame cap")
    return _LEN.pack(len(payload)) + payload


# -- encoders ---------------------------------------------------------------
def encode_hello(version: int = PROTOCOL_VERSION) -> bytes:
    return frame(bytes([MSG_HELLO]) + _HELLO.pack(PROTOCOL_MAGIC, version))


def encode_welcome(version: int = PROTOCOL_VERSION) -> bytes:
    return frame(bytes([MSG_WELCOME]) + _HELLO.pack(PROTOCOL_MAGIC, version))


def negotiate_version(client_version: int) -> int:
    """The version a server holds the connection to (raises if hopeless)."""
    if client_version < MIN_PROTOCOL_VERSION:
        raise ProtocolError(f"protocol version {client_version} is older "
                            f"than the oldest supported "
                            f"({MIN_PROTOCOL_VERSION})")
    return min(client_version, PROTOCOL_VERSION)


def encode_submit(req_id: int, tenant: str, readings: np.ndarray,
                  deadline_ms: float | None = None) -> bytes:
    """One sensor reading: header + tenant utf8 + raw LE float64 features.

    `deadline_ms=None` (encoded as NaN) means "use the tenant's configured
    budget" — the one float value a budget can never legitimately be.
    """
    name = tenant.encode()
    x = np.ascontiguousarray(np.asarray(readings, dtype="<f8").reshape(-1))
    head = _SUBMIT_HEAD.pack(
        req_id, float("nan") if deadline_ms is None else float(deadline_ms),
        len(name), x.shape[0])
    return frame(bytes([MSG_SUBMIT]) + head + name + x.tobytes())


def batch_rows_per_frame(n_feat: int, max_frame: int = MAX_FRAME) -> int:
    """How many readings of `n_feat` features fit in one SUBMIT_BATCH frame.

    Senders chunk a larger plane into this many rows per frame; the
    tenant-name bytes are bounded by the u16 length field, so budgeting
    for the worst case keeps the arithmetic name-independent.
    """
    budget = max_frame - 1 - _SUBMIT_BATCH_HEAD.size - 65535
    return max(1, budget // (_ROW_TABLE_BYTES + 8 * n_feat))


def encode_submit_batch(req_ids, tenant: str, plane: np.ndarray,
                        deadlines_ms=None) -> bytes:
    """Many readings in one frame: header + tenant + row tables + f8 plane.

    `plane` is ``(B, F)`` float64 (any input convertible to it); `req_ids`
    is one u64 per row; `deadlines_ms` is None (all rows use the tenant's
    configured budget), a scalar, or one float per row — NaN rows fall
    back to the tenant default, exactly like v1 SUBMIT.
    """
    plane = np.ascontiguousarray(np.asarray(plane, dtype="<f8"))
    if plane.ndim != 2:
        raise ProtocolError(f"submit batch plane must be (B, F), "
                            f"got shape {plane.shape}")
    n_rows, n_feat = plane.shape
    rids = np.ascontiguousarray(np.asarray(req_ids, dtype="<u8").reshape(-1))
    if rids.shape[0] != n_rows:
        raise ProtocolError(f"{rids.shape[0]} req_ids for {n_rows} rows")
    if deadlines_ms is None:
        dls = np.full(n_rows, np.nan, dtype="<f8")
    else:
        dls = np.ascontiguousarray(
            np.broadcast_to(np.asarray(deadlines_ms, dtype="<f8"),
                            (n_rows,)))
    name = tenant.encode()
    if len(name) > 65535:
        raise ProtocolError("tenant name exceeds 65535 bytes")
    head = _SUBMIT_BATCH_HEAD.pack(len(name), n_rows, n_feat)
    return frame(b"".join((bytes([MSG_SUBMIT_BATCH]), head, name,
                           rids.tobytes(), dls.tobytes(), plane.tobytes())))


def encode_result(req_id: int, label: int, latency_ms: float) -> bytes:
    return frame(bytes([MSG_RESULT])
                 + _RESULT.pack(req_id, int(label), float(latency_ms)))


def encode_result_batch(req_ids, labels, latencies_ms) -> bytes:
    """Many completions in one frame: req_id/label/latency row tables."""
    rids = np.ascontiguousarray(np.asarray(req_ids, dtype="<u8").reshape(-1))
    lbls = np.ascontiguousarray(np.asarray(labels, dtype="<i4").reshape(-1))
    lats = np.ascontiguousarray(np.asarray(latencies_ms,
                                           dtype="<f8").reshape(-1))
    if not (rids.shape == lbls.shape == lats.shape):
        raise ProtocolError("result batch tables disagree on length")
    head = _RESULT_BATCH_HEAD.pack(rids.shape[0])
    return frame(b"".join((bytes([MSG_RESULT_BATCH]), head, rids.tobytes(),
                           lbls.tobytes(), lats.tobytes())))


def encode_shed(req_id: int, retry_after_ms: float) -> bytes:
    return frame(bytes([MSG_SHED]) + _SHED.pack(req_id, float(retry_after_ms)))


def encode_error(req_id: int, message: str) -> bytes:
    msg = message.encode()[:65535]
    return frame(bytes([MSG_ERROR]) + _ERROR_HEAD.pack(req_id, len(msg)) + msg)


def _encode_json(msg_type: int, doc) -> bytes:
    return frame(bytes([msg_type]) + json.dumps(doc, sort_keys=True).encode())


def encode_list() -> bytes:
    return frame(bytes([MSG_LIST]))


def encode_tenants(rows: list[dict]) -> bytes:
    return _encode_json(MSG_TENANTS, rows)


def encode_stats() -> bytes:
    return frame(bytes([MSG_STATS]))


def encode_stats_reply(summary: dict) -> bytes:
    return _encode_json(MSG_STATS_REPLY, summary)


def encode_reload() -> bytes:
    return frame(bytes([MSG_RELOAD]))


def encode_reloaded(actions: dict) -> bytes:
    return _encode_json(MSG_RELOADED, actions)


# -- decoder ----------------------------------------------------------------
@dataclass
class Message:
    """One decoded payload: `type` + the type-specific fields as attrs."""

    type: int
    req_id: int = 0
    tenant: str = ""
    readings: np.ndarray | None = None      # (F,) v1 submit; (B, F) v2 batch
    deadline_ms: float | None = None
    label: int = 0
    latency_ms: float = 0.0
    retry_after_ms: float = 0.0
    message: str = ""
    doc: object = None
    version: int = PROTOCOL_VERSION         # HELLO/WELCOME payload version
    req_ids: np.ndarray | None = None       # (B,) u64, batch frames
    deadlines_ms: np.ndarray | None = None  # (B,) f8 (NaN = tenant default)
    labels: np.ndarray | None = None        # (B,) i4, RESULT_BATCH
    latencies_ms: np.ndarray | None = None  # (B,) f8, RESULT_BATCH


def _need(payload: bytes, n: int, what: str) -> None:
    if len(payload) < n:
        raise ProtocolError(f"truncated {what}: {len(payload)} < {n} bytes")


def decode_message(payload: bytes) -> Message:
    """Decode one de-framed payload (raises `ProtocolError` on garbage)."""
    _need(payload, 1, "payload")
    mtype, body = payload[0], payload[1:]
    if mtype in (MSG_HELLO, MSG_WELCOME):
        _need(body, _HELLO.size, "hello")
        magic, version = _HELLO.unpack_from(body)
        if magic != PROTOCOL_MAGIC:
            raise ProtocolError(f"bad magic {magic!r} (not a repro.serve "
                                "endpoint?)")
        if not MIN_PROTOCOL_VERSION <= version <= PROTOCOL_VERSION:
            raise ProtocolError(
                f"protocol version {version} outside the supported range "
                f"[{MIN_PROTOCOL_VERSION}, {PROTOCOL_VERSION}]")
        return Message(type=mtype, version=version)
    if mtype == MSG_SUBMIT:
        _need(body, _SUBMIT_HEAD.size, "submit header")
        req_id, deadline_ms, name_len, n_feat = _SUBMIT_HEAD.unpack_from(body)
        off = _SUBMIT_HEAD.size
        _need(body, off + name_len + 8 * n_feat, "submit body")
        try:
            tenant = body[off: off + name_len].decode()
        except UnicodeDecodeError as exc:
            raise ProtocolError(f"submit tenant name is not UTF-8: "
                                f"{exc}") from exc
        off += name_len
        readings = np.frombuffer(body, dtype="<f8", count=n_feat,
                                 offset=off).astype(np.float64)
        return Message(type=mtype, req_id=req_id, tenant=tenant,
                       readings=readings,
                       deadline_ms=(None if np.isnan(deadline_ms)
                                    else float(deadline_ms)))
    if mtype == MSG_SUBMIT_BATCH:
        _need(body, _SUBMIT_BATCH_HEAD.size, "submit batch header")
        name_len, n_rows, n_feat = _SUBMIT_BATCH_HEAD.unpack_from(body)
        off = _SUBMIT_BATCH_HEAD.size
        need = off + name_len + n_rows * (_ROW_TABLE_BYTES + 8 * n_feat)
        _need(body, need, "submit batch body")
        try:
            tenant = body[off: off + name_len].decode()
        except UnicodeDecodeError as exc:
            raise ProtocolError(f"submit batch tenant name is not UTF-8: "
                                f"{exc}") from exc
        off += name_len
        req_ids = np.frombuffer(body, dtype="<u8", count=n_rows, offset=off)
        off += 8 * n_rows
        deadlines = np.frombuffer(body, dtype="<f8", count=n_rows,
                                  offset=off).astype(np.float64)
        off += 8 * n_rows
        plane = np.frombuffer(body, dtype="<f8", count=n_rows * n_feat,
                              offset=off).astype(np.float64)
        return Message(type=mtype, tenant=tenant,
                       req_ids=req_ids.astype(np.uint64),
                       deadlines_ms=deadlines,
                       readings=plane.reshape(n_rows, n_feat))
    if mtype == MSG_RESULT_BATCH:
        _need(body, _RESULT_BATCH_HEAD.size, "result batch header")
        (n_rows,) = _RESULT_BATCH_HEAD.unpack_from(body)
        off = _RESULT_BATCH_HEAD.size
        _need(body, off + n_rows * (8 + 4 + 8), "result batch body")
        req_ids = np.frombuffer(body, dtype="<u8", count=n_rows, offset=off)
        off += 8 * n_rows
        labels = np.frombuffer(body, dtype="<i4", count=n_rows, offset=off)
        off += 4 * n_rows
        lats = np.frombuffer(body, dtype="<f8", count=n_rows, offset=off)
        return Message(type=mtype, req_ids=req_ids.astype(np.uint64),
                       labels=labels.astype(np.int32),
                       latencies_ms=lats.astype(np.float64))
    if mtype == MSG_RESULT:
        _need(body, _RESULT.size, "result")
        req_id, label, latency_ms = _RESULT.unpack_from(body)
        return Message(type=mtype, req_id=req_id, label=label,
                       latency_ms=latency_ms)
    if mtype == MSG_SHED:
        _need(body, _SHED.size, "shed")
        req_id, retry_after_ms = _SHED.unpack_from(body)
        return Message(type=mtype, req_id=req_id,
                       retry_after_ms=retry_after_ms)
    if mtype == MSG_ERROR:
        _need(body, _ERROR_HEAD.size, "error header")
        req_id, msg_len = _ERROR_HEAD.unpack_from(body)
        _need(body, _ERROR_HEAD.size + msg_len, "error body")
        # "replace", not strict: an error report must never itself become
        # undecodable (encode_error's byte-level truncation can split a
        # multibyte character)
        msg = body[_ERROR_HEAD.size: _ERROR_HEAD.size + msg_len].decode(
            errors="replace")
        return Message(type=mtype, req_id=req_id, message=msg)
    if mtype in (MSG_LIST, MSG_STATS, MSG_RELOAD):
        return Message(type=mtype)
    if mtype in (MSG_TENANTS, MSG_STATS_REPLY, MSG_RELOADED):
        try:
            doc = json.loads(body.decode())
        except (UnicodeDecodeError, json.JSONDecodeError) as exc:
            raise ProtocolError(f"bad JSON body in message type {mtype}: "
                                f"{exc}") from exc
        return Message(type=mtype, doc=doc)
    raise ProtocolError(f"unknown message type {mtype}")


class FrameReader:
    """Incremental de-framer: feed byte chunks, collect complete payloads.

    Chunk boundaries are arbitrary (a TCP stream guarantees nothing about
    them), so the reader buffers until a full length-prefixed frame is in
    and yields exactly the payload bytes — pinned against random
    re-chunkings by the protocol property test.
    """

    def __init__(self, max_frame: int = MAX_FRAME):
        self.max_frame = max_frame
        self._buf = bytearray()

    def feed(self, chunk: bytes) -> list[bytes]:
        self._buf.extend(chunk)
        out = []
        while True:
            if len(self._buf) < _LEN.size:
                return out
            (length,) = _LEN.unpack_from(self._buf)
            if length > self.max_frame:
                raise ProtocolError(f"frame of {length} bytes exceeds the "
                                    f"{self.max_frame}-byte cap")
            if len(self._buf) < _LEN.size + length:
                return out
            out.append(bytes(self._buf[_LEN.size: _LEN.size + length]))
            del self._buf[: _LEN.size + length]

    @property
    def buffered(self) -> int:
        return len(self._buf)
