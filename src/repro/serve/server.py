"""Asyncio socket front for a `ClassifierFleet`: sharded TCP + UDP ingest.

The server owns one running fleet and up to three kinds of transport
front ends:

* **Sharded TCP accept loops** — `shards=N` runs N worker threads, each
  with its own asyncio event loop and its own listening socket bound to
  the *same* port via ``SO_REUSEPORT``, so the kernel spreads incoming
  connections across loops and no single accept loop (or its event loop)
  becomes the choke point of a 10k-connection swarm.  Every connection is
  de-framed by `protocol.FrameReader`; v2 clients may ship whole
  `SUBMIT_BATCH` frames that enter the fleet through the
  `ClassifierFleet.submit_many` single-lock fast path.
* **Per-connection write coalescing** — completions are queued per
  connection as plain tuples; the writer task drains whatever is ready
  and, on a v2 connection, folds every ready completion into one
  `RESULT_BATCH` frame + one ``writer.write`` call, so a thousand labels
  cost one syscall instead of a thousand.
* **Connectionless UDP ingest** (`udp_port=`) — fire-and-forget mode for
  sensor swarms that cannot hold a TCP connection: each datagram is one
  SUBMIT or SUBMIT_BATCH payload (no length prefix — the datagram
  boundary is the frame), submitted into the fleet with no reply path.
  Delivery is best-effort (drops are the client's problem by design);
  the server counts datagrams/readings/sheds/errors in `udp_stats` and
  reports them through the STATS RPC so a firehose can verify receipt.

Protocol version negotiation happens at HELLO: the server answers
WELCOME with ``min(client_version, PROTOCOL_VERSION)`` and holds the
connection to that — a v1 client keeps its per-reading SUBMIT/RESULT
conversation, byte-compatible with the PR 5 wire format.

The fleet's dispatch threads hand finished requests to the owning
connection's event loop via `FleetRequest.add_done_callback` +
`loop.call_soon_threadsafe`, so no thread ever parks on a request and a
connection can pipeline thousands of readings.  Admission-control sheds
(`FleetOverloadError` / partial `submit_many` admission) become SHED
frames with the `retry_after_ms` hint; bad tenants / feature counts
become per-request ERROR frames; a protocol violation gets one
connection-level ERROR (`CONN_ERR`) and the connection is closed.
LIST/STATS/RELOAD are JSON-bodied admin round-trips (RELOAD runs
`fleet.sync_manifest()`).

With `watch_manifest=True` shard 0 also polls the emit dir's
`fleet.json` mtime + generation and hot-reloads added/replaced/retired
tenants without draining anything — the network half of the manifest
story (`compile/artifact.py` bumps the generation, the fleet reconciles).

The server runs either in the foreground (`python -m repro.serve serve`)
or on background threads (`start_background()` — what the tests and the
cross-process CI smoke use); either way every shard is a plain
`asyncio.run` loop on its own daemon thread.
"""
from __future__ import annotations

import asyncio
import socket
import threading
from pathlib import Path

from repro.compile.artifact import manifest_path
from repro.serve import protocol as P
from repro.serve.fleet import ClassifierFleet, FleetOverloadError

_CLOSE = None                   # writer-queue close sentinel


class _ConnState:
    """Per-connection context shared by the reader and writer halves."""

    def __init__(self, loop: asyncio.AbstractEventLoop):
        self.loop = loop
        self.out_q: asyncio.Queue = asyncio.Queue()
        self.version = P.PROTOCOL_VERSION   # negotiated at HELLO

    def send_raw(self, data: bytes) -> None:
        self.out_q.put_nowait(("raw", data))

    def send_result(self, req_id: int, label: int,
                    latency_ms: float) -> None:
        self.out_q.put_nowait(("res", req_id, label, latency_ms))


class _UdpIngest(asyncio.DatagramProtocol):
    """Fire-and-forget ingest: one datagram = one SUBMIT/SUBMIT_BATCH."""

    def __init__(self, server: "FleetServer"):
        self.server = server

    def datagram_received(self, data: bytes, addr) -> None:
        stats = self.server.udp_stats
        stats["n_datagrams"] += 1
        fleet = self.server.fleet
        try:
            msg = P.decode_message(data)
            if msg.type == P.MSG_SUBMIT:
                stats["n_readings"] += 1
                fleet.submit(msg.tenant, msg.readings,
                             deadline_ms=msg.deadline_ms)
                stats["n_admitted"] += 1
            elif msg.type == P.MSG_SUBMIT_BATCH:
                stats["n_readings"] += msg.readings.shape[0]
                reqs, shed_idx, _ = fleet.submit_many(
                    msg.tenant, msg.readings, msg.deadlines_ms)
                stats["n_admitted"] += len(reqs)
                stats["n_shed"] += len(shed_idx)
            else:
                stats["n_errors"] += 1
        except FleetOverloadError:
            stats["n_shed"] += 1
        except Exception:       # garbage datagram / bad tenant: drop, count
            stats["n_errors"] += 1


class FleetServer:
    """Socket transport + lifecycle around one running fleet."""

    def __init__(self, fleet: ClassifierFleet, host: str = "127.0.0.1",
                 port: int = 0, *, shards: int = 1,
                 udp_port: int | None = None, watch_manifest: bool = False,
                 watch_interval_s: float = 0.5):
        if shards < 1:
            raise ValueError("shards must be >= 1")
        self.fleet = fleet
        self.host = host
        self.port = port
        self.shards = shards
        self.udp_port = udp_port
        self.watch_manifest = watch_manifest
        self.watch_interval_s = watch_interval_s
        self.address: tuple[str, int] | None = None
        self.udp_address: tuple[str, int] | None = None
        self.reloads: list[dict] = []       # sync_manifest action records
        self.n_connections = 0
        self.udp_stats = {"n_datagrams": 0, "n_readings": 0,
                          "n_admitted": 0, "n_shed": 0, "n_errors": 0}
        self._count_lock = threading.Lock()
        self._socks: list[socket.socket] = []
        self._udp_sock: socket.socket | None = None
        self._loops: list[asyncio.AbstractEventLoop | None] = []
        self._stops: list[asyncio.Event | None] = []
        self._threads: list[threading.Thread] = []
        self._ready: list[threading.Event] = []
        self._startup_exc: BaseException | None = None

    # -- tenant table (LIST) -------------------------------------------------
    def _tenant_rows(self) -> list[dict]:
        rows = []
        for name in self.fleet.tenants:
            t = self.fleet._tenant(name)
            rows.append({
                "name": name,
                "n_features": t.engine.n_features,
                "n_classes": t.engine.program.n_classes,
                "backend": t.spec.backend,
                "deadline_ms": t.spec.deadline_ms,
                "max_batch": t.spec.max_batch,
                "max_queue": t.spec.max_queue,
                "replicas": t.pool.size,
                "dataset": t.spec.dataset,
                "generation": t.spec.generation,
                "sha256": t.spec.sha256,
                "qos": t.spec.qos,
                "rate_limit_rps": t.spec.rate_limit_rps,
                "shadow": (self.fleet._shadows[name].name
                           if name in self.fleet._shadows else None),
            })
        return rows

    def _stats_doc(self) -> dict:
        # stats_summary already carries the controller sections when armed:
        # "workers" (per-backend subprocess hosts + slab ring) and
        # "autoscale" (round counter + recent scale events)
        doc = self.fleet.stats_summary()
        doc["transport"] = {
            "shards": self.shards,
            "n_connections": self.n_connections,
            "worker_procs": self.fleet.workers,
            "udp": (dict(self.udp_stats)
                    if self.udp_address is not None else None),
        }
        return doc

    # -- socket binding ------------------------------------------------------
    def _bind_sockets(self) -> None:
        """Bind all shard listeners (and the UDP socket) up front.

        With more than one shard every listener sets ``SO_REUSEPORT`` and
        binds the same port, so the kernel load-balances accepts across
        the shard loops.  Binding before any thread starts means a
        ``port=0`` ephemeral pick is resolved once and shared.
        """
        port = self.port
        for i in range(self.shards):
            sock = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
            try:
                sock.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
                if self.shards > 1:
                    sock.setsockopt(socket.SOL_SOCKET,
                                    socket.SO_REUSEPORT, 1)
                sock.bind((self.host, port))
                sock.listen(4096)
                sock.setblocking(False)
            except BaseException:
                sock.close()
                raise
            if i == 0:
                port = sock.getsockname()[1]
                self.address = sock.getsockname()[:2]
            self._socks.append(sock)
        if self.udp_port is not None:
            usock = socket.socket(socket.AF_INET, socket.SOCK_DGRAM)
            try:
                usock.setsockopt(socket.SOL_SOCKET, socket.SO_RCVBUF,
                                 1 << 22)
                usock.bind((self.host, self.udp_port))
                usock.setblocking(False)
            except BaseException:
                usock.close()
                raise
            self.udp_address = usock.getsockname()[:2]
            self._udp_sock = usock

    # -- per-connection plumbing ---------------------------------------------
    async def _writer_loop(self, writer: asyncio.StreamWriter,
                           conn: _ConnState) -> None:
        out_q = conn.out_q
        closing = False
        while not closing:
            items = [await out_q.get()]
            while True:     # coalesce whatever else is ready into one write
                try:
                    items.append(out_q.get_nowait())
                except asyncio.QueueEmpty:
                    break
            if _CLOSE in items:     # close sentinel — may arrive mid-burst
                closing = True      # (a dispatch completing after the
                items = [it for it in items if it is not _CLOSE]  # disconnect)
            chunks, results = [], []
            for it in items:
                if it[0] == "raw":
                    chunks.append(it[1])
                else:
                    results.append(it[1:])
            if results:
                if conn.version >= 2 and len(results) > 1:
                    rids, labels, lats = zip(*results)
                    chunks.append(P.encode_result_batch(rids, labels, lats))
                else:
                    chunks.extend(P.encode_result(*r) for r in results)
            if chunks:
                writer.write(b"".join(chunks))
                try:
                    await writer.drain()
                except (ConnectionError, OSError):
                    return

    def _completion_callback(self, req_id: int, conn: _ConnState):
        """Bridge a fleet dispatch thread back onto this connection's loop."""

        def on_done(freq) -> None:
            try:
                if freq.error is not None:
                    conn.loop.call_soon_threadsafe(
                        conn.send_raw, P.encode_error(req_id, freq.error))
                else:
                    conn.loop.call_soon_threadsafe(
                        conn.send_result, req_id, freq.label,
                        freq.latency_ms)
            except RuntimeError:
                pass        # loop already closed; connection is gone anyway

        return on_done

    def _handle_submit_batch(self, msg: P.Message, conn: _ConnState) -> None:
        """One SUBMIT_BATCH frame -> the fleet's single-lock fast path."""
        try:
            reqs, shed_idx, retry_ms = self.fleet.submit_many(
                msg.tenant, msg.readings, msg.deadlines_ms)
        except (KeyError, ValueError, RuntimeError) as exc:
            err = str(exc)
            for rid in msg.req_ids:     # fail every row loudly, none hang
                conn.send_raw(P.encode_error(int(rid), err))
            return
        for req, rid in zip(reqs, msg.req_ids):
            req.add_done_callback(self._completion_callback(int(rid), conn))
        for i in shed_idx:
            conn.send_raw(P.encode_shed(int(msg.req_ids[i]), retry_ms))

    async def _handle_message(self, msg: P.Message,
                              conn: _ConnState) -> None:
        if msg.type == P.MSG_SUBMIT:
            try:
                req = self.fleet.submit(msg.tenant, msg.readings,
                                        deadline_ms=msg.deadline_ms)
            except FleetOverloadError as exc:
                conn.send_raw(P.encode_shed(msg.req_id, exc.retry_after_ms))
                return
            except (KeyError, ValueError, RuntimeError) as exc:
                conn.send_raw(P.encode_error(msg.req_id, str(exc)))
                return
            req.add_done_callback(self._completion_callback(msg.req_id,
                                                            conn))
        elif msg.type == P.MSG_SUBMIT_BATCH:
            self._handle_submit_batch(msg, conn)
        elif msg.type == P.MSG_LIST:
            conn.send_raw(P.encode_tenants(self._tenant_rows()))
        elif msg.type == P.MSG_STATS:
            conn.send_raw(P.encode_stats_reply(self._stats_doc()))
        elif msg.type == P.MSG_RELOAD:
            actions = await asyncio.get_running_loop().run_in_executor(
                None, self.fleet.sync_manifest)
            self.reloads.append(actions)
            conn.send_raw(P.encode_reloaded(actions))
        else:
            raise P.ProtocolError(f"unexpected message type {msg.type}")

    async def _handle_connection(self, reader: asyncio.StreamReader,
                                 writer: asyncio.StreamWriter) -> None:
        conn = _ConnState(asyncio.get_running_loop())
        wtask = asyncio.ensure_future(self._writer_loop(writer, conn))
        framer = P.FrameReader()
        with self._count_lock:
            self.n_connections += 1
        greeted = False
        try:
            while True:
                chunk = await reader.read(1 << 16)
                if not chunk:
                    break
                for payload in framer.feed(chunk):
                    msg = P.decode_message(payload)
                    if not greeted:
                        if msg.type != P.MSG_HELLO:
                            raise P.ProtocolError(
                                "first message must be HELLO")
                        conn.version = P.negotiate_version(msg.version)
                        conn.send_raw(P.encode_welcome(conn.version))
                        greeted = True
                        continue
                    await self._handle_message(msg, conn)
        except P.ProtocolError as exc:
            conn.send_raw(P.encode_error(P.CONN_ERR, str(exc)))
        except (ConnectionError, OSError):
            pass
        finally:
            conn.out_q.put_nowait(_CLOSE)
            await wtask
            writer.close()
            try:
                await writer.wait_closed()
            except (ConnectionError, OSError):
                pass

    # -- manifest watcher ----------------------------------------------------
    async def _watch_manifest(self) -> None:
        ctx = self.fleet._manifest_ctx
        if ctx is None:
            return
        path: Path = manifest_path(ctx["emit_dir"])
        loop = asyncio.get_running_loop()
        # baseline 0, not the current mtime: an emit that landed between
        # fleet load and watcher start must trigger the first sync (a
        # clean first poll just runs one no-op reconcile)
        last_mtime = 0
        while True:
            await asyncio.sleep(self.watch_interval_s)
            try:
                mtime = path.stat().st_mtime_ns
            except OSError:
                continue
            if mtime == last_mtime:
                continue
            last_mtime = mtime
            try:
                actions = await loop.run_in_executor(
                    None, self.fleet.sync_manifest)
            except Exception as exc:    # a half-written emit: retry next poll
                print(f"[serve] manifest sync failed: {exc}", flush=True)
                continue
            if any(actions[k] for k in ("added", "replaced", "retired")):
                self.reloads.append(actions)
                print(f"[serve] manifest gen {actions['generation']}: "
                      f"+{actions['added']} ~{actions['replaced']} "
                      f"-{actions['retired']}", flush=True)

    # -- lifecycle -----------------------------------------------------------
    async def _shard_main(self, idx: int, sock: socket.socket) -> None:
        """One shard: its own loop, its own listener (shard 0 also owns the
        manifest watcher and the UDP ingest endpoint)."""
        loop = asyncio.get_running_loop()
        self._loops[idx] = loop
        self._stops[idx] = stop = asyncio.Event()
        extras = []
        udp_transport = None
        try:
            server = await asyncio.start_server(self._handle_connection,
                                                sock=sock)
        except BaseException as exc:
            self._startup_exc = exc
            self._ready[idx].set()
            raise
        if idx == 0:
            if self.watch_manifest:
                extras.append(asyncio.ensure_future(self._watch_manifest()))
            if self._udp_sock is not None:
                udp_transport, _ = await loop.create_datagram_endpoint(
                    lambda: _UdpIngest(self), sock=self._udp_sock)
        self._ready[idx].set()
        try:
            async with server:
                await stop.wait()
        finally:
            for task in extras:
                task.cancel()
            if udp_transport is not None:
                udp_transport.close()

    def start_background(self) -> tuple[str, int]:
        """Bind, run every shard on a daemon thread; returns the address."""
        self._bind_sockets()
        self._loops = [None] * self.shards
        self._stops = [None] * self.shards
        self._ready = [threading.Event() for _ in range(self.shards)]
        for i, sock in enumerate(self._socks):
            th = threading.Thread(
                target=lambda i=i, sock=sock: asyncio.run(
                    self._shard_main(i, sock)),
                name=f"fleet-server-{i}", daemon=True)
            self._threads.append(th)
            th.start()
        for ev in self._ready:
            if not ev.wait(30.0):
                raise TimeoutError("fleet server did not come up within 30s")
        if self._startup_exc is not None:
            raise self._startup_exc
        return self.address

    def stop(self, timeout: float = 30.0) -> None:
        """Stop serving (background-thread mode); the fleet stays up."""
        for loop, stop in zip(self._loops, self._stops):
            if loop is None or stop is None:
                continue
            try:
                loop.call_soon_threadsafe(stop.set)
            except RuntimeError:
                continue                     # loop already gone
        for th in self._threads:
            th.join(timeout)
            if th.is_alive():
                raise TimeoutError(f"fleet server thread {th.name} did not "
                                   f"stop within {timeout}s")
        self._threads = []


def serve_forever(fleet: ClassifierFleet, host: str, port: int, *,
                  shards: int = 1, udp_port: int | None = None,
                  watch_manifest: bool = False) -> None:
    """Foreground entry point for the CLI: serve until KeyboardInterrupt."""
    server = FleetServer(fleet, host, port, shards=shards,
                         udp_port=udp_port, watch_manifest=watch_manifest)
    try:
        h, p = server.start_background()
        udp = (f", udp ingest on {server.udp_address[0]}:"
               f"{server.udp_address[1]}" if server.udp_address else "")
        print(f"[serve] fleet of {len(fleet.tenants)} tenant(s) "
              f"listening on {h}:{p} x{shards} shard(s){udp} "
              f"(watch={'on' if watch_manifest else 'off'})", flush=True)
        threading.Event().wait()            # park until interrupted
    except KeyboardInterrupt:
        print("[serve] interrupted; draining fleet", flush=True)
        server.stop()
    finally:
        fleet.shutdown(drain=True)
