"""Asyncio socket front for a `ClassifierFleet`.

One `FleetServer` owns a listening TCP socket and a running fleet: each
connection is de-framed by `protocol.FrameReader`, SUBMIT messages are
deserialized straight into `ClassifierFleet.submit`, and completions
stream back as RESULT frames from a per-connection writer task — the
fleet's dispatch threads hand finished requests to the event loop via
`FleetRequest.add_done_callback` + `loop.call_soon_threadsafe`, so no
thread ever parks on a request and a connection can pipeline thousands
of readings.

Admission-control sheds (`FleetOverloadError`) become SHED frames with
the `retry_after_ms` hint; bad tenants / feature counts become per-request
ERROR frames; a protocol violation gets one connection-level ERROR
(`CONN_ERR`) and the connection is closed.  LIST/STATS/RELOAD are
JSON-bodied admin round-trips (RELOAD runs `fleet.sync_manifest()`).

With `watch_manifest=True` the server also polls the emit dir's
`fleet.json` mtime + generation and hot-reloads added/replaced/retired
tenants without draining anything — the network half of the manifest
story (`compile/artifact.py` bumps the generation, the fleet reconciles).

The server runs either in the foreground (`python -m repro.serve serve`)
or on a background thread (`start_background()` — what the tests and the
cross-process CI smoke use), in both cases on a plain `asyncio.run` loop.
"""
from __future__ import annotations

import asyncio
import threading
import time
from pathlib import Path

from repro.compile.artifact import manifest_path
from repro.serve import protocol as P
from repro.serve.fleet import ClassifierFleet, FleetOverloadError


class FleetServer:
    """Socket transport + lifecycle around one running fleet."""

    def __init__(self, fleet: ClassifierFleet, host: str = "127.0.0.1",
                 port: int = 0, *, watch_manifest: bool = False,
                 watch_interval_s: float = 0.5):
        self.fleet = fleet
        self.host = host
        self.port = port
        self.watch_manifest = watch_manifest
        self.watch_interval_s = watch_interval_s
        self.address: tuple[str, int] | None = None
        self.reloads: list[dict] = []       # sync_manifest action records
        self.n_connections = 0
        self._loop: asyncio.AbstractEventLoop | None = None
        self._stop: asyncio.Event | None = None
        self._ready = threading.Event()
        self._startup_exc: BaseException | None = None
        self._thread: threading.Thread | None = None

    # -- tenant table (LIST) -------------------------------------------------
    def _tenant_rows(self) -> list[dict]:
        rows = []
        for name in self.fleet.tenants:
            t = self.fleet._tenant(name)
            rows.append({
                "name": name,
                "n_features": t.engine.n_features,
                "n_classes": t.engine.program.n_classes,
                "backend": t.spec.backend,
                "deadline_ms": t.spec.deadline_ms,
                "max_batch": t.spec.max_batch,
                "max_queue": t.spec.max_queue,
                "replicas": t.pool.size,
                "dataset": t.spec.dataset,
                "generation": t.spec.generation,
            })
        return rows

    # -- per-connection plumbing ---------------------------------------------
    async def _writer_loop(self, writer: asyncio.StreamWriter,
                           out_q: asyncio.Queue) -> None:
        closing = False
        while not closing:
            chunks = [await out_q.get()]
            while True:     # coalesce whatever else is ready into one write
                try:
                    chunks.append(out_q.get_nowait())
                except asyncio.QueueEmpty:
                    break
            if None in chunks:      # close sentinel — may arrive mid-burst
                closing = True      # (a dispatch completing after the
                chunks = [c for c in chunks if c is not None]   # disconnect)
            if chunks:
                writer.write(b"".join(chunks))
                try:
                    await writer.drain()
                except (ConnectionError, OSError):
                    return

    def _completion_callback(self, req_id: int, out_q: asyncio.Queue):
        """Bridge a fleet dispatch thread back onto this connection's loop."""
        loop = self._loop

        def on_done(freq) -> None:
            data = (P.encode_error(req_id, freq.error)
                    if freq.error is not None else
                    P.encode_result(req_id, freq.label, freq.latency_ms))
            try:
                loop.call_soon_threadsafe(out_q.put_nowait, data)
            except RuntimeError:
                pass        # loop already closed; connection is gone anyway

        return on_done

    async def _handle_message(self, msg: P.Message,
                              out_q: asyncio.Queue) -> None:
        if msg.type == P.MSG_SUBMIT:
            try:
                req = self.fleet.submit(msg.tenant, msg.readings,
                                        deadline_ms=msg.deadline_ms)
            except FleetOverloadError as exc:
                out_q.put_nowait(P.encode_shed(msg.req_id,
                                               exc.retry_after_ms))
                return
            except (KeyError, ValueError, RuntimeError) as exc:
                out_q.put_nowait(P.encode_error(msg.req_id, str(exc)))
                return
            req.add_done_callback(self._completion_callback(msg.req_id,
                                                            out_q))
        elif msg.type == P.MSG_LIST:
            out_q.put_nowait(P.encode_tenants(self._tenant_rows()))
        elif msg.type == P.MSG_STATS:
            out_q.put_nowait(P.encode_stats_reply(self.fleet.stats_summary()))
        elif msg.type == P.MSG_RELOAD:
            actions = await asyncio.get_running_loop().run_in_executor(
                None, self.fleet.sync_manifest)
            self.reloads.append(actions)
            out_q.put_nowait(P.encode_reloaded(actions))
        else:
            raise P.ProtocolError(f"unexpected message type {msg.type}")

    async def _handle_connection(self, reader: asyncio.StreamReader,
                                 writer: asyncio.StreamWriter) -> None:
        out_q: asyncio.Queue = asyncio.Queue()
        wtask = asyncio.ensure_future(self._writer_loop(writer, out_q))
        framer = P.FrameReader()
        self.n_connections += 1
        greeted = False
        try:
            while True:
                chunk = await reader.read(1 << 16)
                if not chunk:
                    break
                for payload in framer.feed(chunk):
                    msg = P.decode_message(payload)
                    if not greeted:
                        if msg.type != P.MSG_HELLO:
                            raise P.ProtocolError(
                                "first message must be HELLO")
                        out_q.put_nowait(P.encode_welcome())
                        greeted = True
                        continue
                    await self._handle_message(msg, out_q)
        except P.ProtocolError as exc:
            out_q.put_nowait(P.encode_error(P.CONN_ERR, str(exc)))
        except (ConnectionError, OSError):
            pass
        finally:
            out_q.put_nowait(None)
            await wtask
            writer.close()
            try:
                await writer.wait_closed()
            except (ConnectionError, OSError):
                pass

    # -- manifest watcher ----------------------------------------------------
    async def _watch_manifest(self) -> None:
        ctx = self.fleet._manifest_ctx
        if ctx is None:
            return
        path: Path = manifest_path(ctx["emit_dir"])
        loop = asyncio.get_running_loop()
        # baseline 0, not the current mtime: an emit that landed between
        # fleet load and watcher start must trigger the first sync (a
        # clean first poll just runs one no-op reconcile)
        last_mtime = 0
        while True:
            await asyncio.sleep(self.watch_interval_s)
            try:
                mtime = path.stat().st_mtime_ns
            except OSError:
                continue
            if mtime == last_mtime:
                continue
            last_mtime = mtime
            try:
                actions = await loop.run_in_executor(
                    None, self.fleet.sync_manifest)
            except Exception as exc:    # a half-written emit: retry next poll
                print(f"[serve] manifest sync failed: {exc}", flush=True)
                continue
            if any(actions[k] for k in ("added", "replaced", "retired")):
                self.reloads.append(actions)
                print(f"[serve] manifest gen {actions['generation']}: "
                      f"+{actions['added']} ~{actions['replaced']} "
                      f"-{actions['retired']}", flush=True)

    # -- lifecycle -----------------------------------------------------------
    async def serve(self) -> None:
        """Bind, announce readiness, and serve until `stop()` (or cancel)."""
        self._loop = asyncio.get_running_loop()
        self._stop = asyncio.Event()
        try:
            server = await asyncio.start_server(self._handle_connection,
                                                self.host, self.port)
        except BaseException as exc:
            self._startup_exc = exc
            self._ready.set()
            raise
        self.address = server.sockets[0].getsockname()[:2]
        watcher = (asyncio.ensure_future(self._watch_manifest())
                   if self.watch_manifest else None)
        self._ready.set()
        try:
            async with server:
                await self._stop.wait()
        finally:
            if watcher is not None:
                watcher.cancel()

    def start_background(self) -> tuple[str, int]:
        """Run the server on a daemon thread; returns the bound address."""
        self._thread = threading.Thread(
            target=lambda: asyncio.run(self.serve()),
            name="fleet-server", daemon=True)
        self._thread.start()
        if not self._ready.wait(30.0):
            raise TimeoutError("fleet server did not come up within 30s")
        if self._startup_exc is not None:
            raise self._startup_exc
        return self.address

    def stop(self, timeout: float = 30.0) -> None:
        """Stop serving (background-thread mode); the fleet stays up."""
        if self._loop is None or self._stop is None:
            return
        try:
            self._loop.call_soon_threadsafe(self._stop.set)
        except RuntimeError:
            return                           # loop already gone
        if self._thread is not None:
            self._thread.join(timeout)
            if self._thread.is_alive():
                raise TimeoutError("fleet server did not stop "
                                   f"within {timeout}s")


def serve_forever(fleet: ClassifierFleet, host: str, port: int, *,
                  watch_manifest: bool = False) -> None:
    """Foreground entry point for the CLI: serve until KeyboardInterrupt."""
    server = FleetServer(fleet, host, port, watch_manifest=watch_manifest)

    async def _main() -> None:
        task = asyncio.ensure_future(server.serve())
        while server.address is None and not task.done():
            await asyncio.sleep(0.01)
        if server.address is not None:
            h, p = server.address
            print(f"[serve] fleet of {len(fleet.tenants)} tenant(s) "
                  f"listening on {h}:{p} "
                  f"(watch={'on' if watch_manifest else 'off'})", flush=True)
        await task

    try:
        asyncio.run(_main())
    except KeyboardInterrupt:
        print("[serve] interrupted; draining fleet", flush=True)
    finally:
        fleet.shutdown(drain=True)
