"""Blocking client for the fleet's socket transport.

The consumer half of `protocol.py`: one TCP connection, a background
reader thread that de-frames RESULT/SHED/ERROR messages and resolves
them against pending request handles by req_id, and a pipelined submit
path — `submit` returns a `PendingResult` immediately, so a producer can
keep thousands of readings in flight and collect labels in completion
order.  This is what the replay CLI (`python -m repro.serve replay
--connect host:port`) and the cross-process CI smoke drive; it has no
dependency on the fleet, so a sensor gateway can vendor just
`protocol.py` + this file.

Admission sheds surface as `FleetShedError` (carrying the server's
`retry_after_ms` hint) from `PendingResult.result()`; `classify` can
optionally honor the hint and resubmit (`retry_shed=True`), which is the
polite-producer loop the admission controller is designed for.
"""
from __future__ import annotations

import socket
import threading
import time
from queue import Empty, Queue

import numpy as np

from repro.serve import protocol as P


class FleetClientError(RuntimeError):
    """Connection-level failure (bad handshake, server error, disconnect)."""


class FleetShedError(RuntimeError):
    """The server shed this submission; retry after `retry_after_ms`."""

    def __init__(self, req_id: int, retry_after_ms: float):
        super().__init__(f"request {req_id} shed by admission control; "
                         f"retry after {retry_after_ms:.1f} ms")
        self.req_id = req_id
        self.retry_after_ms = retry_after_ms


class PendingResult:
    """Completion handle for one submitted reading."""

    def __init__(self, req_id: int, tenant: str):
        self.req_id = req_id
        self.tenant = tenant
        self.label: int | None = None
        self.latency_ms: float | None = None    # server-side submit -> label
        self.error: str | None = None
        self.retry_after_ms: float | None = None    # set iff shed
        self._event = threading.Event()

    def done(self) -> bool:
        return self._event.is_set()

    @property
    def shed(self) -> bool:
        return self.retry_after_ms is not None

    def result(self, timeout: float | None = None) -> int:
        if not self._event.wait(timeout):
            raise TimeoutError(f"request {self.req_id} ({self.tenant}) not "
                               f"answered within {timeout}s")
        if self.retry_after_ms is not None:
            raise FleetShedError(self.req_id, self.retry_after_ms)
        if self.error is not None:
            raise FleetClientError(f"request {self.req_id} ({self.tenant}) "
                                   f"failed: {self.error}")
        return self.label


class FleetClient:
    """One connection to a `FleetServer`; safe for multi-threaded submits."""

    def __init__(self, host: str, port: int, *,
                 connect_timeout: float = 10.0):
        self._sock = socket.create_connection((host, port),
                                              timeout=connect_timeout)
        self._sock.settimeout(None)
        self._send_lock = threading.Lock()
        self._pending: dict[int, PendingResult] = {}
        self._pending_lock = threading.Lock()
        self._next_id = 1
        self._closed = False
        self._conn_error: str | None = None
        self._welcome = threading.Event()
        self._rpc: dict[int, Queue] = {P.MSG_TENANTS: Queue(),
                                       P.MSG_STATS_REPLY: Queue(),
                                       P.MSG_RELOADED: Queue()}
        self._rpc_lock = threading.Lock()
        self._reader = threading.Thread(target=self._read_loop,
                                        name="fleet-client-reader",
                                        daemon=True)
        self._reader.start()
        self._sendall(P.encode_hello())
        if not self._welcome.wait(connect_timeout):
            err = self._conn_error or "no WELCOME from server"
            self.close()
            raise FleetClientError(f"handshake failed: {err}")

    # -- wire plumbing -------------------------------------------------------
    def _sendall(self, data: bytes) -> None:
        with self._send_lock:
            if self._closed:
                raise FleetClientError("client is closed")
            try:
                self._sock.sendall(data)
            except OSError as exc:
                raise FleetClientError(f"send failed: {exc}") from exc

    def _read_loop(self) -> None:
        framer = P.FrameReader()
        try:
            while True:
                chunk = self._sock.recv(1 << 16)
                if not chunk:
                    break
                for payload in framer.feed(chunk):
                    self._on_message(P.decode_message(payload))
        except (OSError, P.ProtocolError) as exc:
            if not self._closed:
                self._conn_error = self._conn_error or str(exc)
        finally:
            self._fail_all(self._conn_error or "connection closed")
            self._welcome.set()     # unblock a handshake waiter, if any

    def _on_message(self, msg: P.Message) -> None:
        if msg.type == P.MSG_WELCOME:
            self._welcome.set()
        elif msg.type in (P.MSG_RESULT, P.MSG_SHED, P.MSG_ERROR):
            if msg.type == P.MSG_ERROR and msg.req_id == P.CONN_ERR:
                self._conn_error = msg.message
                self._fail_all(f"server: {msg.message}")
                return
            with self._pending_lock:
                pend = self._pending.pop(msg.req_id, None)
            if pend is None:
                return              # late answer for an abandoned request
            if msg.type == P.MSG_RESULT:
                pend.label = msg.label
                pend.latency_ms = msg.latency_ms
            elif msg.type == P.MSG_SHED:
                pend.retry_after_ms = msg.retry_after_ms
            else:
                pend.error = msg.message
            pend._event.set()
        elif msg.type in self._rpc:
            self._rpc[msg.type].put(msg.doc)

    def _fail_all(self, why: str) -> None:
        with self._pending_lock:
            pending, self._pending = self._pending, {}
        for pend in pending.values():
            pend.error = why
            pend._event.set()

    # -- request path --------------------------------------------------------
    def submit(self, tenant: str, readings: np.ndarray,
               deadline_ms: float | None = None) -> PendingResult:
        """Pipeline one reading; returns immediately with a handle."""
        if self._conn_error is not None:
            raise FleetClientError(self._conn_error)
        with self._pending_lock:
            req_id = self._next_id
            self._next_id += 1
            pend = PendingResult(req_id, tenant)
            self._pending[req_id] = pend
        try:
            self._sendall(P.encode_submit(req_id, tenant, readings,
                                          deadline_ms))
        except FleetClientError:
            with self._pending_lock:
                self._pending.pop(req_id, None)
            raise
        return pend

    def classify(self, tenant: str, x: np.ndarray,
                 deadline_ms: float | None = None, *,
                 timeout: float = 120.0, retry_shed: bool = False,
                 max_retries: int = 64) -> np.ndarray:
        """Submit every row of `(S, F)` readings; block for `(S,)` labels.

        With `retry_shed`, a shed row sleeps out the server's
        `retry_after_ms` hint and resubmits (up to `max_retries` times) —
        the cooperative backoff loop admission control expects of bulk
        producers.
        """
        x = np.asarray(x)
        if x.ndim != 2:
            raise ValueError(f"expected (S, F) readings, got {x.shape}")
        handles = [self.submit(tenant, row, deadline_ms) for row in x]
        labels = np.empty(x.shape[0], dtype=np.int32)
        deadline = time.monotonic() + timeout
        for i, pend in enumerate(handles):
            for attempt in range(max_retries + 1):
                try:
                    labels[i] = pend.result(max(0.0, deadline
                                                - time.monotonic()))
                    break
                except FleetShedError as exc:
                    if not retry_shed or attempt == max_retries:
                        raise
                    time.sleep(min(exc.retry_after_ms, 1000.0) * 1e-3)
                    pend = self.submit(tenant, x[i], deadline_ms)
        return labels

    # -- admin round-trips ---------------------------------------------------
    def _rpc_call(self, request: bytes, reply_type: int,
                  timeout: float):
        with self._rpc_lock:        # one outstanding admin call at a time
            q = self._rpc[reply_type]
            while True:     # a reply that arrived after a past timeout is
                try:        # stale — drop it or every later call is off by one
                    q.get_nowait()
                except Empty:
                    break
            self._sendall(request)
            try:
                return q.get(timeout=timeout)
            except Empty:
                raise TimeoutError(
                    f"no reply (type {reply_type}) within {timeout}s; "
                    + (self._conn_error or "server unresponsive")) from None

    def tenants(self, timeout: float = 30.0) -> list[dict]:
        """The server's tenant table (name, n_features, backend, ...)."""
        return self._rpc_call(P.encode_list(), P.MSG_TENANTS, timeout)

    def stats(self, timeout: float = 30.0) -> dict:
        """The server fleet's `stats_summary()`."""
        return self._rpc_call(P.encode_stats(), P.MSG_STATS_REPLY, timeout)

    def reload(self, timeout: float = 120.0) -> dict:
        """Ask the server to `sync_manifest()`; returns the action record."""
        return self._rpc_call(P.encode_reload(), P.MSG_RELOADED, timeout)

    # -- lifecycle -----------------------------------------------------------
    def close(self) -> None:
        if self._closed:
            return
        self._closed = True
        try:
            self._sock.shutdown(socket.SHUT_RDWR)
        except OSError:
            pass
        self._sock.close()
        if threading.current_thread() is not self._reader:
            self._reader.join(5.0)

    def __enter__(self) -> "FleetClient":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
