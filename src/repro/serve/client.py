"""Blocking client for the fleet's socket transport.

The consumer half of `protocol.py`: one TCP connection, a background
reader thread that de-frames RESULT/RESULT_BATCH/SHED/ERROR messages and
resolves them against pending request handles by req_id, and a pipelined
submit path — `submit` returns a `PendingResult` immediately, so a
producer can keep thousands of readings in flight and collect labels in
completion order.  This is what the replay CLI (`python -m repro.serve
replay --connect host:port`) and the cross-process CI smoke drive; it
has no dependency on the fleet, so a sensor gateway can vendor just
`protocol.py` + this file.

The protocol version is negotiated at HELLO (the server answers WELCOME
with ``min(client, server)``); on a v2 connection `submit_many` ships a
whole ``(B, F)`` reading plane as one `SUBMIT_BATCH` frame per
`batch_rows_per_frame` chunk — one syscall for thousands of readings —
and transparently falls back to coalesced per-reading SUBMIT frames when
the server only speaks v1.  `CoalescingSubmitter` adds optional
time/size-based client-side coalescing on top (single-reading producers
get batch frames without changing their call sites), and
`UdpSwarmSender` is the connectionless fire-and-forget path: SUBMIT /
SUBMIT_BATCH payloads as raw datagrams, no handshake, no replies, no
delivery guarantee.

Admission sheds surface as `FleetShedError` (carrying the server's
`retry_after_ms` hint) from `PendingResult.result()`; `classify` can
optionally honor the hint and resubmit (`retry_shed=True`), which is the
polite-producer loop the admission controller is designed for.
"""
from __future__ import annotations

import socket
import threading
import time
from queue import Empty, Queue

import numpy as np

from repro.serve import protocol as P


class FleetClientError(RuntimeError):
    """Connection-level failure (bad handshake, server error, disconnect)."""


class FleetShedError(RuntimeError):
    """The server shed this submission; retry after `retry_after_ms`."""

    def __init__(self, req_id: int, retry_after_ms: float):
        super().__init__(f"request {req_id} shed by admission control; "
                         f"retry after {retry_after_ms:.1f} ms")
        self.req_id = req_id
        self.retry_after_ms = retry_after_ms


class PendingResult:
    """Completion handle for one submitted reading."""

    def __init__(self, req_id: int, tenant: str):
        self.req_id = req_id
        self.tenant = tenant
        self.label: int | None = None
        self.latency_ms: float | None = None    # server-side submit -> label
        self.error: str | None = None
        self.retry_after_ms: float | None = None    # set iff shed
        self._event = threading.Event()

    def done(self) -> bool:
        return self._event.is_set()

    @property
    def shed(self) -> bool:
        return self.retry_after_ms is not None

    def result(self, timeout: float | None = None) -> int:
        if not self._event.wait(timeout):
            raise TimeoutError(f"request {self.req_id} ({self.tenant}) not "
                               f"answered within {timeout}s")
        if self.retry_after_ms is not None:
            raise FleetShedError(self.req_id, self.retry_after_ms)
        if self.error is not None:
            raise FleetClientError(f"request {self.req_id} ({self.tenant}) "
                                   f"failed: {self.error}")
        return self.label


class FleetClient:
    """One connection to a `FleetServer`; safe for multi-threaded submits."""

    def __init__(self, host: str, port: int, *,
                 connect_timeout: float = 10.0,
                 protocol_version: int = P.PROTOCOL_VERSION):
        self._sock = socket.create_connection((host, port),
                                              timeout=connect_timeout)
        self._sock.settimeout(None)
        self._send_lock = threading.Lock()
        self._pending: dict[int, PendingResult] = {}
        self._pending_lock = threading.Lock()
        self._next_id = 1
        self._closed = False
        self._conn_error: str | None = None
        self._welcome = threading.Event()
        self.protocol_version = protocol_version    # negotiated at WELCOME
        self._rpc: dict[int, Queue] = {P.MSG_TENANTS: Queue(),
                                       P.MSG_STATS_REPLY: Queue(),
                                       P.MSG_RELOADED: Queue()}
        self._rpc_lock = threading.Lock()
        self._reader = threading.Thread(target=self._read_loop,
                                        name="fleet-client-reader",
                                        daemon=True)
        self._reader.start()
        self._sendall(P.encode_hello(protocol_version))
        if not self._welcome.wait(connect_timeout):
            err = self._conn_error or "no WELCOME from server"
            self.close()
            raise FleetClientError(f"handshake failed: {err}")

    # -- wire plumbing -------------------------------------------------------
    def _sendall(self, data: bytes) -> None:
        with self._send_lock:
            if self._closed:
                raise FleetClientError("client is closed")
            try:
                self._sock.sendall(data)
            except OSError as exc:
                raise FleetClientError(f"send failed: {exc}") from exc

    def _read_loop(self) -> None:
        framer = P.FrameReader()
        try:
            while True:
                chunk = self._sock.recv(1 << 16)
                if not chunk:
                    break
                for payload in framer.feed(chunk):
                    self._on_message(P.decode_message(payload))
        except (OSError, P.ProtocolError) as exc:
            if not self._closed:
                self._conn_error = self._conn_error or str(exc)
        finally:
            self._fail_all(self._conn_error or "connection closed")
            self._welcome.set()     # unblock a handshake waiter, if any

    def _resolve(self, req_id: int, label: int | None,
                 latency_ms: float | None, error: str | None = None,
                 retry_after_ms: float | None = None) -> None:
        with self._pending_lock:
            pend = self._pending.pop(req_id, None)
        if pend is None:
            return                  # late answer for an abandoned request
        pend.label = label
        pend.latency_ms = latency_ms
        pend.error = error
        pend.retry_after_ms = retry_after_ms
        pend._event.set()

    def _on_message(self, msg: P.Message) -> None:
        if msg.type == P.MSG_WELCOME:
            self.protocol_version = min(self.protocol_version, msg.version)
            self._welcome.set()
        elif msg.type == P.MSG_RESULT_BATCH:
            for rid, label, lat in zip(msg.req_ids, msg.labels,
                                       msg.latencies_ms):
                self._resolve(int(rid), int(label), float(lat))
        elif msg.type in (P.MSG_RESULT, P.MSG_SHED, P.MSG_ERROR):
            if msg.type == P.MSG_ERROR and msg.req_id == P.CONN_ERR:
                self._conn_error = msg.message
                self._fail_all(f"server: {msg.message}")
                return
            with self._pending_lock:
                pend = self._pending.pop(msg.req_id, None)
            if pend is None:
                return              # late answer for an abandoned request
            if msg.type == P.MSG_RESULT:
                pend.label = msg.label
                pend.latency_ms = msg.latency_ms
            elif msg.type == P.MSG_SHED:
                pend.retry_after_ms = msg.retry_after_ms
            else:
                pend.error = msg.message
            pend._event.set()
        elif msg.type in self._rpc:
            self._rpc[msg.type].put(msg.doc)

    def _fail_all(self, why: str) -> None:
        with self._pending_lock:
            pending, self._pending = self._pending, {}
        for pend in pending.values():
            pend.error = why
            pend._event.set()

    # -- request path --------------------------------------------------------
    def submit(self, tenant: str, readings: np.ndarray,
               deadline_ms: float | None = None) -> PendingResult:
        """Pipeline one reading; returns immediately with a handle."""
        if self._conn_error is not None:
            raise FleetClientError(self._conn_error)
        with self._pending_lock:
            req_id = self._next_id
            self._next_id += 1
            pend = PendingResult(req_id, tenant)
            self._pending[req_id] = pend
        try:
            self._sendall(P.encode_submit(req_id, tenant, readings,
                                          deadline_ms))
        except FleetClientError:
            with self._pending_lock:
                self._pending.pop(req_id, None)
            raise
        return pend

    def submit_many(self, tenant: str, x: np.ndarray,
                    deadlines_ms=None, *,
                    max_frame: int = P.MAX_FRAME) -> list[PendingResult]:
        """Pipeline a whole `(B, F)` reading plane; one handle per row.

        On a v2 connection the plane ships as `SUBMIT_BATCH` frames
        (auto-chunked to stay under the frame cap — `max_frame` exists so
        tests can force chunking without 64 MiB of traffic); a v1 server
        gets per-reading SUBMIT frames coalesced into one send.  Either
        way every reading costs a fraction of a syscall instead of a
        full frame + write round trip.  `deadlines_ms` is None, a
        scalar, or one value per row (NaN = the tenant's default budget).
        """
        if self._conn_error is not None:
            raise FleetClientError(self._conn_error)
        x = np.ascontiguousarray(np.asarray(x, dtype=np.float64))
        if x.ndim != 2:
            raise ValueError(f"expected (B, F) readings, got {x.shape}")
        B = x.shape[0]
        if B == 0:
            return []
        dls = (None if deadlines_ms is None else
               np.broadcast_to(np.asarray(deadlines_ms, dtype=np.float64),
                               (B,)))
        with self._pending_lock:
            req_id0 = self._next_id
            self._next_id += B
            handles = [PendingResult(req_id0 + i, tenant) for i in range(B)]
            self._pending.update((h.req_id, h) for h in handles)
        req_ids = np.arange(req_id0, req_id0 + B, dtype=np.uint64)
        try:
            if self.protocol_version >= 2:
                step = P.batch_rows_per_frame(x.shape[1], max_frame)
                for s in range(0, B, step):
                    e = min(B, s + step)
                    self._sendall(P.encode_submit_batch(
                        req_ids[s:e], tenant, x[s:e],
                        None if dls is None else dls[s:e]))
            else:               # v1 server: coalesce classic SUBMIT frames
                self._sendall(b"".join(
                    P.encode_submit(
                        int(req_ids[i]), tenant, x[i],
                        None if dls is None or dls[i] != dls[i]
                        else float(dls[i]))
                    for i in range(B)))
        except FleetClientError:
            with self._pending_lock:
                for h in handles:
                    self._pending.pop(h.req_id, None)
            raise
        return handles

    def classify(self, tenant: str, x: np.ndarray,
                 deadline_ms: float | None = None, *,
                 timeout: float = 120.0, retry_shed: bool = False,
                 max_retries: int = 64) -> np.ndarray:
        """Submit every row of `(S, F)` readings; block for `(S,)` labels.

        Rows travel via `submit_many` (batch frames on a v2 connection).
        With `retry_shed`, a shed row sleeps out the server's
        `retry_after_ms` hint and resubmits (up to `max_retries` times) —
        the cooperative backoff loop admission control expects of bulk
        producers.
        """
        x = np.asarray(x)
        if x.ndim != 2:
            raise ValueError(f"expected (S, F) readings, got {x.shape}")
        handles = self.submit_many(tenant, x, deadline_ms)
        labels = np.empty(x.shape[0], dtype=np.int32)
        deadline = time.monotonic() + timeout
        for i, pend in enumerate(handles):
            for attempt in range(max_retries + 1):
                try:
                    labels[i] = pend.result(max(0.0, deadline
                                                - time.monotonic()))
                    break
                except FleetShedError as exc:
                    if not retry_shed or attempt == max_retries:
                        raise
                    time.sleep(min(exc.retry_after_ms, 1000.0) * 1e-3)
                    pend = self.submit(tenant, x[i], deadline_ms)
        return labels

    # -- admin round-trips ---------------------------------------------------
    def _rpc_call(self, request: bytes, reply_type: int,
                  timeout: float):
        with self._rpc_lock:        # one outstanding admin call at a time
            q = self._rpc[reply_type]
            while True:     # a reply that arrived after a past timeout is
                try:        # stale — drop it or every later call is off by one
                    q.get_nowait()
                except Empty:
                    break
            self._sendall(request)
            try:
                return q.get(timeout=timeout)
            except Empty:
                raise TimeoutError(
                    f"no reply (type {reply_type}) within {timeout}s; "
                    + (self._conn_error or "server unresponsive")) from None

    def tenants(self, timeout: float = 30.0) -> list[dict]:
        """The server's tenant table (name, n_features, backend, ...)."""
        return self._rpc_call(P.encode_list(), P.MSG_TENANTS, timeout)

    def stats(self, timeout: float = 30.0) -> dict:
        """The server fleet's `stats_summary()`."""
        return self._rpc_call(P.encode_stats(), P.MSG_STATS_REPLY, timeout)

    def reload(self, timeout: float = 120.0) -> dict:
        """Ask the server to `sync_manifest()`; returns the action record."""
        return self._rpc_call(P.encode_reload(), P.MSG_RELOADED, timeout)

    # -- lifecycle -----------------------------------------------------------
    def close(self) -> None:
        if self._closed:
            return
        self._closed = True
        try:
            self._sock.shutdown(socket.SHUT_RDWR)
        except OSError:
            pass
        self._sock.close()
        if threading.current_thread() is not self._reader:
            self._reader.join(5.0)

    def __enter__(self) -> "FleetClient":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


class CoalescingSubmitter:
    """Time/size-based client-side coalescing over one `FleetClient`.

    Single-reading producers keep their per-reading call site —
    ``submit(tenant, row)`` returns a `PendingResult` immediately — but
    rows accumulate in a per-tenant buffer that ships as one
    `submit_many` plane when it reaches `max_rows` **or** when its oldest
    row has waited `max_delay_ms` (a background ticker flushes stale
    buffers, so a trickle of readings is never stranded).  The classic
    latency/amortization trade, client-side: bound the added latency,
    amortize the wire cost.
    """

    def __init__(self, client: FleetClient, *, max_rows: int = 256,
                 max_delay_ms: float = 5.0):
        if max_rows < 1:
            raise ValueError("max_rows must be >= 1")
        if max_delay_ms <= 0:
            raise ValueError("max_delay_ms must be positive")
        self.client = client
        self.max_rows = max_rows
        self.max_delay_ms = max_delay_ms
        self._buffers: dict[str, list] = {}     # tenant -> [(row, dl), ...]
        self._oldest: dict[str, float] = {}     # tenant -> first-row instant
        self._lock = threading.Lock()
        self._closed = False
        self._wake = threading.Event()
        self._ticker = threading.Thread(target=self._tick_loop,
                                        name="coalescing-submitter",
                                        daemon=True)
        self._ticker.start()

    def submit(self, tenant: str, readings: np.ndarray,
               deadline_ms: float | None = None) -> "PendingResult":
        row = np.asarray(readings, dtype=np.float64).reshape(-1)
        pend = PendingResult(0, tenant)     # req_id assigned at flush
        flush_rows = None
        with self._lock:
            if self._closed:
                raise FleetClientError("submitter is closed")
            buf = self._buffers.setdefault(tenant, [])
            if not buf:
                self._oldest[tenant] = time.monotonic()
            buf.append((row, deadline_ms, pend))
            if len(buf) >= self.max_rows:
                flush_rows = self._take_locked(tenant)
        if flush_rows:
            self._ship(tenant, flush_rows)
        return pend

    def _take_locked(self, tenant: str) -> list:
        rows = self._buffers.pop(tenant, [])
        self._oldest.pop(tenant, None)
        return rows

    def _ship(self, tenant: str, rows: list) -> None:
        plane = np.stack([r for r, _, _ in rows])
        dls = np.array([np.nan if d is None else float(d)
                        for _, d, _ in rows])
        try:
            handles = self.client.submit_many(tenant, plane, dls)
        except FleetClientError:
            for _, _, pend in rows:     # resolve, or result() waits forever
                pend.error = self.client._conn_error or "send failed"
                pend._event.set()
            raise
        for (_, _, pend), h in zip(rows, handles):
            pend.req_id = h.req_id
            # Swap the caller's handle in for the internal one — unless the
            # result already landed, in which case copy it over.  _resolve
            # pops under _pending_lock, so exactly one branch runs.
            with self.client._pending_lock:
                landed = h.req_id not in self.client._pending
                if not landed:
                    self.client._pending[h.req_id] = pend
            if landed:
                pend.label = h.label
                pend.latency_ms = h.latency_ms
                pend.error = h.error
                pend.retry_after_ms = h.retry_after_ms
                pend._event.set()

    def flush(self) -> None:
        """Ship every buffered row now, regardless of age or size."""
        with self._lock:
            pending = {t: self._take_locked(t)
                       for t in list(self._buffers)}
        for tenant, rows in pending.items():
            if rows:
                self._ship(tenant, rows)

    def _tick_loop(self) -> None:
        period_s = self.max_delay_ms * 1e-3 / 2
        while not self._wake.wait(period_s):
            now = time.monotonic()
            stale = []
            with self._lock:
                for tenant, t0 in list(self._oldest.items()):
                    if (now - t0) * 1e3 >= self.max_delay_ms:
                        stale.append((tenant, self._take_locked(tenant)))
            for tenant, rows in stale:
                if rows:
                    try:
                        self._ship(tenant, rows)
                    except FleetClientError:
                        pass        # _ship resolved the handles with errors

    def close(self, flush: bool = True) -> None:
        with self._lock:
            if self._closed:
                return
            self._closed = True
        if flush:
            self.flush()
        self._wake.set()
        self._ticker.join(5.0)

    def __enter__(self) -> "CoalescingSubmitter":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


class UdpSwarmSender:
    """Fire-and-forget UDP ingest: datagrams out, nothing ever comes back.

    The connectionless half of the swarm story — a sensor that cannot
    hold a TCP connection (or afford its handshake) blasts SUBMIT /
    SUBMIT_BATCH payloads as raw datagrams at the server's UDP port.  No
    HELLO, no results, no ordering, no delivery guarantee: datagrams may
    be dropped by either kernel under load, and the server only counts
    what arrived (`udp` section of the STATS RPC).  Use TCP when every
    label matters; use this when the swarm's job is to saturate the
    fleet.  `max_datagram` bounds each payload (65507 is the loopback
    ceiling; ~1400 survives a real ethernet path without fragmenting).
    """

    def __init__(self, host: str, port: int, *, max_datagram: int = 65507):
        self.addr = (host, port)
        self.max_datagram = max_datagram
        self._sock = socket.socket(socket.AF_INET, socket.SOCK_DGRAM)
        self._sock.setsockopt(socket.SOL_SOCKET, socket.SO_SNDBUF, 1 << 22)
        self._next_id = 1
        self.n_sent = 0             # readings handed to the kernel

    def send(self, tenant: str, readings: np.ndarray,
             deadline_ms: float | None = None) -> None:
        """One reading as one SUBMIT datagram (strip the length prefix —
        the datagram boundary is the frame)."""
        payload = P.encode_submit(self._next_id, tenant, readings,
                                  deadline_ms)[4:]
        self._next_id += 1
        self._sock.sendto(payload, self.addr)
        self.n_sent += 1

    def send_many(self, tenant: str, x: np.ndarray,
                  deadlines_ms=None) -> int:
        """A `(B, F)` plane as SUBMIT_BATCH datagrams; returns rows sent.

        Chunked so each datagram (payload only, no length prefix) fits
        `max_datagram`.
        """
        x = np.ascontiguousarray(np.asarray(x, dtype=np.float64))
        if x.ndim != 2:
            raise ValueError(f"expected (B, F) readings, got {x.shape}")
        B = x.shape[0]
        dls = (None if deadlines_ms is None else
               np.broadcast_to(np.asarray(deadlines_ms, dtype=np.float64),
                               (B,)))
        # per-row cost: u64 req_id + f8 deadline + F f8 features
        head = 1 + 10 + len(tenant.encode())    # type + !HII head + name
        step = max(1, (self.max_datagram - head)
                   // (16 + 8 * x.shape[1]))
        sent = 0
        for s in range(0, B, step):
            e = min(B, s + step)
            rids = np.arange(self._next_id, self._next_id + (e - s),
                             dtype=np.uint64)
            self._next_id += e - s
            payload = P.encode_submit_batch(
                rids, tenant, x[s:e],
                None if dls is None else dls[s:e])[4:]
            self._sock.sendto(payload, self.addr)
            sent += e - s
        self.n_sent += sent
        return sent

    def close(self) -> None:
        self._sock.close()

    def __enter__(self) -> "UdpSwarmSender":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
