"""Sensor-stream classification serving on compiled circuit programs.

The execution layer of the `repro.serve` stack (formerly
`repro.serving.circuit_engine`, folded in when the serving layers were
unified): there is no decode loop — every request is one sensor reading
classified in a single circuit pass — so the engine's entire job is
batching.  Queued readings are gathered in arrival order into fixed-shape
padded batches (`max_batch` rows, so the jitted SWAR program compiles
exactly one shape), dispatched as one bit-packed evaluation, and the
labels are scattered back with per-request latency.  At 32 readings per
machine word a single dispatch of a `max_batch=1024` engine costs ~32
word-ops per gate, which is what lets a software model of a 5 Hz printed
circuit serve readings at MHz-equivalent rates.

`classify_stream` is the bulk path (one numpy array in, labels out);
`submit`/`flush` is the request-queue path with per-request bookkeeping.
Both feed the same `ServeStats` (readings/s + batch/request latency
percentiles + SLO-violation and admission-shed counters).  The queue path
is thread-safe: producers may `submit` while another thread flushes, and
concurrent `flush` calls partition the queue instead of double-dispatching
it — the contract `repro.serve.ClassifierFleet`'s dispatch threads rely
on.  A fleet tenant runs N of these engines as a replica pool
(`serve/replicas.py`), each pinned to its own device slice.
"""
from __future__ import annotations

import threading
import time
from dataclasses import dataclass

import numpy as np

from repro.compile.program import CircuitProgram

STATS_WINDOW = 4096


class _Ring:
    """Fixed-capacity ring of float samples (keeps the most recent N).

    Long-running streams push one batch sample per dispatch; an unbounded
    list grows without limit (and made every percentile call slower), so
    percentiles are computed over a sliding window instead.  Totals that
    must stay exact (counts, busy seconds) live outside the ring.
    """

    def __init__(self, capacity: int):
        if capacity < 1:
            raise ValueError("ring capacity must be >= 1")
        self._buf = np.zeros(capacity, dtype=np.float64)
        self._pushed = 0

    def push(self, v: float) -> None:
        self._buf[self._pushed % self._buf.shape[0]] = v
        self._pushed += 1

    def __len__(self) -> int:
        return min(self._pushed, self._buf.shape[0])

    @property
    def total_pushed(self) -> int:
        return self._pushed

    def values(self) -> np.ndarray:
        return self._buf[: len(self)]

    def percentile(self, q: float) -> float:
        return float(np.percentile(self.values(), q)) if len(self) else 0.0

    def max(self) -> float:
        return float(self.values().max()) if len(self) else 0.0


class ServeStats:
    """Throughput + latency accounting for one engine (or a whole fleet).

    Batch samples (one per dispatch) and request samples (one per queued
    request) are kept in bounded rings of `window` entries, so a stream of
    millions of readings holds stats memory constant; counters and busy
    time are exact over the full stream.  `n_shed` counts submissions the
    admission controller rejected (they never enter the request rings, so
    p50/p99 describe *accepted* traffic only).  Thread-safe: dispatch
    threads and stat readers may interleave freely.
    """

    def __init__(self, window: int = STATS_WINDOW):
        self.window = window
        self.n_readings = 0
        self.n_batches = 0
        self.busy_s = 0.0                 # time spent inside dispatches
        self.n_requests = 0
        self.n_slo_miss = 0               # requests finishing past deadline
        self.n_shed = 0                   # submissions refused at admission
        self.batch_ms = _Ring(window)     # per-dispatch wall time
        self.request_ms = _Ring(window)   # per-request submit -> label
        self._lock = threading.Lock()

    def record(self, n: int, dt_s: float) -> None:
        with self._lock:
            self.n_readings += n
            self.n_batches += 1
            self.busy_s += dt_s
            self.batch_ms.push(dt_s * 1e3)

    def record_request(self, latency_ms: float,
                       deadline_ms: float | None = None) -> None:
        with self._lock:
            self.n_requests += 1
            self.request_ms.push(latency_ms)
            if deadline_ms is not None and latency_ms > deadline_ms:
                self.n_slo_miss += 1

    def record_shed(self, n: int = 1) -> None:
        with self._lock:
            self.n_shed += n

    @property
    def readings_per_s(self) -> float:
        return self.n_readings / self.busy_s if self.busy_s > 0 else 0.0

    def percentile_ms(self, q: float) -> float:
        return self.batch_ms.percentile(q)

    def request_percentile_ms(self, q: float) -> float:
        return self.request_ms.percentile(q)

    def summary(self) -> dict:
        with self._lock:
            return {
                "n_readings": self.n_readings,
                "n_batches": self.n_batches,
                "busy_s": round(self.busy_s, 6),
                "readings_per_s": round(self.readings_per_s, 1),
                "p50_ms": round(self.batch_ms.percentile(50), 4),
                "p99_ms": round(self.batch_ms.percentile(99), 4),
                "n_requests": self.n_requests,
                "req_p50_ms": round(self.request_ms.percentile(50), 4),
                "req_p99_ms": round(self.request_ms.percentile(99), 4),
                "n_slo_miss": self.n_slo_miss,
                "n_shed": self.n_shed,
                "window": self.window,
            }


@dataclass
class SensorRequest:
    uid: int
    readings: np.ndarray             # (F,) raw sensor values
    label: int | None = None
    latency_ms: float | None = None  # submit -> label
    deadline_ms: float | None = None  # latency budget (SLO), if any
    _t_submit: float = 0.0

    @property
    def slo_miss(self) -> bool:
        return (self.deadline_ms is not None and self.latency_ms is not None
                and self.latency_ms > self.deadline_ms)


class CircuitServingEngine:
    """Batched request->label serving over one compiled classifier."""

    def __init__(self, program: CircuitProgram, max_batch: int = 1024,
                 stats_window: int = STATS_WINDOW):
        if program.n_classes is None:
            raise ValueError("engine needs a classifier program "
                             "(CircuitProgram.from_classifier)")
        if max_batch < 1:
            raise ValueError("max_batch must be >= 1")
        self.program = program
        self.max_batch = max_batch
        self.stats = ServeStats(window=stats_window)
        self._queue: list[SensorRequest] = []
        self._next_uid = 0
        self._lock = threading.Lock()

    @property
    def n_features(self) -> int:
        return self.program.ir.n_inputs

    def warmup(self) -> float:
        """Trigger jit compilation of the fixed batch shape (not counted).

        Returns the wall time of one *warm* dispatch in seconds — callers
        (the fleet scheduler) use it to seed their dispatch-interval
        estimate.
        """
        dummy = np.zeros((self.max_batch, self.n_features), dtype=np.float64)
        for _ in range(2):       # first call compiles; second is the measure
            t0 = time.perf_counter()
            if self.program.thresholds is not None:
                self.program.predict(dummy)
            else:
                self.program.predict_bits(dummy.astype(np.uint8))
            dt = time.perf_counter() - t0
        return dt

    # -- request-queue path -------------------------------------------------
    def submit(self, readings: np.ndarray,
               deadline_ms: float | None = None) -> SensorRequest:
        readings = np.asarray(readings, dtype=np.float64).reshape(-1)
        if readings.shape[0] != self.n_features:
            raise ValueError(f"expected {self.n_features} features, "
                             f"got {readings.shape[0]}")
        with self._lock:
            req = SensorRequest(self._next_uid, readings,
                                deadline_ms=deadline_ms,
                                _t_submit=time.perf_counter())
            self._next_uid += 1
            self._queue.append(req)
        return req

    @property
    def pending(self) -> int:
        with self._lock:
            return len(self._queue)

    def _pop_group(self) -> list[SensorRequest]:
        with self._lock:
            group = self._queue[: self.max_batch]
            del self._queue[: len(group)]
        return group

    def flush(self) -> list[SensorRequest]:
        """Drain the queue in arrival order; returns the completed requests.

        Each batch is popped atomically before dispatch, so requests that
        arrive while a dispatch is in flight — or a second flusher running
        concurrently — find the queue consistent: every request is
        dispatched exactly once and always completes with both `label` and
        `latency_ms` set (regression-pinned in tests/test_circuit_engine).
        """
        done: list[SensorRequest] = []
        while True:
            group = self._pop_group()
            if not group:
                break
            x = np.stack([r.readings for r in group])
            labels = self._dispatch(x)
            self.complete(group, labels)
            done.extend(group)
        return done

    def complete(self, group: list[SensorRequest],
                 labels: np.ndarray) -> None:
        """Attach labels + latency to dispatched requests (stats included)."""
        t_done = time.perf_counter()
        for r, lbl in zip(group, labels):
            r.label = int(lbl)
            r.latency_ms = (t_done - r._t_submit) * 1e3
            self.stats.record_request(r.latency_ms, r.deadline_ms)

    # -- bulk path ----------------------------------------------------------
    def classify_stream(self, x: np.ndarray) -> np.ndarray:
        """Classify `(S, F)` readings in max_batch chunks; returns `(S,)`."""
        x = np.asarray(x)
        if x.ndim != 2 or x.shape[1] != self.n_features:
            raise ValueError(f"expected (S, {self.n_features}) readings, "
                             f"got {x.shape}")
        out = np.empty(x.shape[0], dtype=np.int32)
        for s in range(0, x.shape[0], self.max_batch):
            chunk = x[s: s + self.max_batch]
            out[s: s + chunk.shape[0]] = self._dispatch(chunk)
        return out

    def classify_batch(self, x: np.ndarray) -> np.ndarray:
        """One `(B <= max_batch, F)` batch -> labels, padded to the jit shape.

        The fleet dispatch path: the scheduler forms the batch, the engine
        executes it.
        """
        x = np.asarray(x)
        if x.ndim != 2 or x.shape[1] != self.n_features:
            raise ValueError(f"expected (B, {self.n_features}) readings, "
                             f"got {x.shape}")
        if x.shape[0] > self.max_batch:
            raise ValueError(f"batch of {x.shape[0]} exceeds max_batch "
                             f"{self.max_batch}")
        return self._dispatch(x)

    def prepare_packed_batch(self, x: np.ndarray) -> tuple[np.ndarray, int]:
        """One `(B <= max_batch, F)` batch -> packed uint32 word plane.

        The megakernel half of `classify_batch`: validate, binarize
        through the program's thresholds (or take raw bits when there are
        none), zero-pad to the compiled `max_batch` shape, and bit-pack to
        the `(F, max_batch/32)` plane a fused fleet launch consumes.
        Returns `(words32, B)`; the caller slices the decoded labels back
        to `B` rows (pad rows decode through the same circuit and are
        discarded).
        """
        x = np.asarray(x)
        if x.ndim != 2 or x.shape[1] != self.n_features:
            raise ValueError(f"expected (B, {self.n_features}) readings, "
                             f"got {x.shape}")
        B = x.shape[0]
        if B > self.max_batch:
            raise ValueError(f"batch of {B} exceeds max_batch "
                             f"{self.max_batch}")
        xbin = (self.program.binarize(x)
                if self.program.thresholds is not None
                else np.asarray(x, dtype=np.uint8))
        if B < self.max_batch:
            pad = np.zeros((self.max_batch - B, xbin.shape[1]),
                           dtype=xbin.dtype)
            xbin = np.concatenate([xbin, pad], axis=0)
        return self.program.pack_input_bits(xbin), B

    def _dispatch(self, x: np.ndarray) -> np.ndarray:
        """One padded fixed-shape batch through the program (timed)."""
        B = x.shape[0]
        if B < self.max_batch:      # pad to the compiled shape
            pad = np.zeros((self.max_batch - B, x.shape[1]), dtype=x.dtype)
            x = np.concatenate([x, pad], axis=0)
        t0 = time.perf_counter()
        labels = (self.program.predict(x) if self.program.thresholds is not None
                  else self.program.predict_bits(x.astype(np.uint8)))
        dt = time.perf_counter() - t0
        self.stats.record(B, dt)
        return labels[:B]
