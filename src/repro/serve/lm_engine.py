"""Batched LM serving engine: prefill + decode with slot-based batching.

Folded into `repro.serve` when the serving layers were unified (formerly
`repro.serving.engine`): the token engine and the sensor-stream circuit
engine (`serve/engine.py`) now live in one stack, with
`launch/serve.py` driving this one.

Requests are bucketed by prompt length (the decode step is batch-uniform in
position — see models/transformer.decode_step), padded into a fixed batch,
prefilled once, then decoded greedily until max_new_tokens or EOS.  This is
the single-host reference engine; at pod scale the same prefill/decode
functions lower under pjit with the cache sharded per
`cache_partition_specs` (launch/serve.py drives that path).
"""
from __future__ import annotations

from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import transformer as TF


@dataclass
class Request:
    uid: int
    prompt: list[int]
    max_new_tokens: int = 16
    eos_id: int | None = None
    output: list[int] = field(default_factory=list)


class ServingEngine:
    def __init__(self, cfg: ModelConfig, params, max_batch: int = 8,
                 cache_len: int = 256):
        self.cfg = cfg
        self.params = params
        self.max_batch = max_batch
        self.cache_len = cache_len
        self._prefill = jax.jit(
            lambda p, b: TF.prefill(cfg, p, b, cache_len=cache_len))
        self._decode = jax.jit(
            lambda p, c, t, pos: TF.decode_step(cfg, p, c, t, pos))

    def _make_batch(self, group: list[Request], plen: int) -> dict:
        B = len(group)
        toks = np.zeros((B, plen), np.int32)
        for i, r in enumerate(group):
            toks[i, : len(r.prompt)] = r.prompt
        batch = {"tokens": jnp.asarray(toks)}
        if self.cfg.frontend == "vision":
            batch["vision_embeds"] = jnp.zeros(
                (B, self.cfg.n_vision_tokens, self.cfg.d_model), jnp.float32)
            batch["positions"] = jnp.broadcast_to(
                jnp.arange(plen)[None, None, :], (B, 3, plen)).astype(jnp.int32)
        if self.cfg.enc_layers:
            batch["enc_frames"] = jnp.zeros(
                (B, self.cfg.enc_seq, self.cfg.d_model), jnp.float32)
        return batch

    def run(self, requests: list[Request]) -> list[Request]:
        """Process all requests; returns them with .output filled."""
        # bucket by prompt length so positions stay batch-uniform
        buckets: dict[int, list[Request]] = {}
        for r in requests:
            buckets.setdefault(len(r.prompt), []).append(r)
        for plen, group in sorted(buckets.items()):
            for s in range(0, len(group), self.max_batch):
                self._run_group(group[s: s + self.max_batch], plen)
        return requests

    def _run_group(self, group: list[Request], plen: int) -> None:
        batch = self._make_batch(group, plen)
        hidden, cache = self._prefill(self.params, batch)
        logits = TF.logits_from_hidden(self.cfg, self.params,
                                       hidden[:, -1:, :])
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)       # (B, 1)
        max_new = max(r.max_new_tokens for r in group)
        done = np.zeros(len(group), bool)
        for step in range(max_new):
            toks_np = np.asarray(tok[:, 0])
            for i, r in enumerate(group):
                if not done[i] and len(r.output) < r.max_new_tokens:
                    t = int(toks_np[i])
                    r.output.append(t)
                    if r.eos_id is not None and t == r.eos_id:
                        done[i] = True
                elif len(r.output) >= r.max_new_tokens:
                    done[i] = True
            if done.all() or step == max_new - 1:
                break
            pos = jnp.int32(plen + step)
            logits, cache = self._decode(self.params, cache, tok, pos)
            tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
