"""Multi-tenant sensor-serving fleet: router, replica pools, admission.

One `ClassifierFleet` serves every classifier emitted under an emit
directory (`repro.evolve --emit-dir`, `python -m repro.compile.export`):
each manifest tenant gets a **replica pool** of `CircuitServingEngine`s
over the loaded program (`serve/replicas.py` — least-loaded pick, devices
round-robined via `kernels.dispatch.replica_devices`), pinned to an
execution backend (`np`/`swar`/`pallas` — the same `kernels.dispatch`
routing the campaign evaluators use), and a single router fans
`submit(tenant, reading)` calls into per-tenant `MicroBatcher` queues.

Dispatch is pushed off the caller thread: one background scheduler thread
per *backend* watches the queues of the tenants pinned to it and hands a
due batch — `max_batch` queued, or the oldest request about to outlive
its latency budget (see `batcher.py`) — to the least-loaded idle replica
on a per-backend dispatch executor, so a hot tenant's batches overlap
across replicas instead of queueing behind each other.  Per-batch
execution cost is tracked as an EMA per tenant and fed back into the
deadline policy, so "about to" means "could not survive one more dispatch
interval".

**Admission control**: a tenant with `max_queue` set sheds new
submissions once its queue is that deep — `submit` raises
`FleetOverloadError` carrying a `retry_after_ms` hint sized from the
backlog and the tenant's dispatch-cost estimate — so overload shows up as
explicit sheds (counted in `ServeStats.n_shed`) instead of silent SLO
misses on accepted traffic.

**QoS + rate limits**: tenants carry a QoS class — `guaranteed` sheds
only on hard queue limits and is scheduled first among due tenants;
`best_effort` additionally sheds whenever its backend's total backlog
crosses the fleet's `best_effort_backlog` threshold, so under overload
the best-effort tenants give way *before* guaranteed tenants start
missing SLOs.  A per-tenant token bucket (`rate_limit_rps` +
`rate_burst`) gates admission the same way, with `retry_after_ms` hints
sized from the bucket's actual refill deficit.

**Autoscaling**: pass an `AutoscaleConfig` and each tenant's replica
pool is resized from its live signals — sustained sheds, queue-depth
pressure, dispatch-cost EMA — under round-based hysteresis with
`min_replicas`/`max_replicas` bounds from the spec (`serve/autoscale.py`
is the pure decision law; `autoscale_tick()` applies it and is safe to
drive from a test with a fake clock).  Shadow tenants are never scaled.

**Worker processes**: with `workers=N`, dispatch leaves this process —
each backend gets N spawned subprocesses holding their own engines, fed
through a ring of shared-memory reading planes (`serve/workers.py`).
Scheduling, admission, stats and completion all stay here; only
`classify_batch` crosses the process boundary, so np/swar/pallas
dispatch runs on real cores instead of sharing this process's GIL.

**Hot reload**: a fleet built by `from_emit_dir` can `sync_manifest()` at
any time — new manifest rows become tenants, rows whose generation
counter moved are replaced (queued requests transfer to the successor
with their deadline clocks intact; in-flight batches finish on the old
engines), and vanished rows retire after their backlog is served.  The
socket server (`serve/server.py`) drives this from an mtime watcher.

Everything the scheduler adds is bookkeeping — labels come from the same
`CircuitProgram` the offline path runs, so fleet output is bit-identical
to `CircuitProgram.predict` per tenant on every backend (pinned by
tests/test_serve_fleet.py, the tests/test_conformance.py fleet matrix,
and over the wire by tests/test_serve_transport.py).
"""
from __future__ import annotations

import contextlib
import math
import threading
import time
from concurrent.futures import ThreadPoolExecutor
from dataclasses import dataclass, field
from pathlib import Path

import numpy as np

from repro.compile.artifact import load_manifest_doc, load_program
from repro.compile.program import CircuitProgram
from repro.serve.autoscale import (QOS_CLASSES, Autoscaler, AutoscaleConfig,
                                   TenantSignals, TokenBucket)
from repro.serve.batcher import MicroBatcher, QueuedItem
from repro.serve.engine import (STATS_WINDOW, CircuitServingEngine,
                                ServeStats)
from repro.serve.replicas import EngineReplica, ReplicaPool, make_replica
from repro.serve.shadow import ShadowComparator
from repro.serve.workers import WorkerHost

FLEET_BACKENDS = ("np", "swar", "pallas")
DEFAULT_DEADLINE_MS = 50.0
DEFAULT_MAX_BATCH = 256


class FleetOverloadError(RuntimeError):
    """Submission shed by admission control; retry after `retry_after_ms`.

    `reason` names which gate shed it: ``"queue"`` (the tenant's
    `max_queue` depth limit), ``"rate"`` (its token bucket ran dry), or
    ``"qos"`` (a best-effort tenant gave way to backend-wide backlog).
    """

    def __init__(self, tenant: str, queue_depth: int, max_queue: int | None,
                 retry_after_ms: float, reason: str = "queue"):
        super().__init__(
            f"tenant {tenant!r} shed ({reason}: {queue_depth} queued"
            + (f", limit {max_queue}" if max_queue is not None else "")
            + f"); retry after {retry_after_ms:.1f} ms")
        self.tenant = tenant
        self.queue_depth = queue_depth
        self.max_queue = max_queue
        self.retry_after_ms = retry_after_ms
        self.reason = reason


@dataclass
class FleetRequest:
    """One routed sensor reading; completion is signalled via `result()`."""

    uid: int
    tenant: str
    readings: np.ndarray
    deadline_ms: float
    label: int | None = None
    latency_ms: float | None = None
    error: str | None = None
    batch_uid: int | None = None    # frame identity (submit_many arrivals)
    _plane: np.ndarray | None = field(default=None, repr=False)
    _row: int = 0                   # this request's row in `_plane`
    _t_submit: float = 0.0
    _event: threading.Event = field(default_factory=threading.Event,
                                    repr=False)
    _callbacks: list = field(default_factory=list, repr=False)
    _cb_lock: threading.Lock = field(default_factory=threading.Lock,
                                     repr=False)

    def done(self) -> bool:
        return self._event.is_set()

    def add_done_callback(self, fn) -> None:
        """Run `fn(self)` when the request completes (immediately if it
        already has) — the hook the socket server uses to stream results
        back without parking a thread per request."""
        with self._cb_lock:
            if not self._event.is_set():
                self._callbacks.append(fn)
                return
        fn(self)

    def _complete(self) -> None:
        with self._cb_lock:
            self._event.set()
            callbacks, self._callbacks = self._callbacks, []
        for fn in callbacks:
            fn(self)

    def result(self, timeout: float | None = None) -> int:
        """Block until the label is ready (raises on timeout/cancel)."""
        if not self._event.wait(timeout):
            raise TimeoutError(f"request {self.uid} ({self.tenant}) not "
                               f"served within {timeout}s")
        if self.error is not None:
            raise RuntimeError(f"request {self.uid} ({self.tenant}) failed: "
                               f"{self.error}")
        return self.label

    @property
    def slo_miss(self) -> bool:
        return self.latency_ms is not None and self.latency_ms > self.deadline_ms


@dataclass
class TenantSpec:
    """Everything needed to stand up one tenant's replica pool."""

    name: str
    program: CircuitProgram
    backend: str = "swar"              # np | swar | pallas
    max_batch: int = DEFAULT_MAX_BATCH
    deadline_ms: float = DEFAULT_DEADLINE_MS
    replicas: int = 1
    max_queue: int | None = None       # admission limit; None = never shed
    dataset: str | None = None
    generation: int = 0                # manifest generation that emitted it
    sha256: str | None = None          # bundle digest the manifest recorded
    qos: str = "guaranteed"            # guaranteed | best_effort
    rate_limit_rps: float | None = None  # token-bucket admission rate
    rate_burst: float | None = None    # bucket depth; default max(rate, batch)
    min_replicas: int | None = None    # autoscale floor; default 1
    max_replicas: int | None = None    # autoscale ceiling; default `replicas`
    meta: dict = field(default_factory=dict)


class _Tenant:
    """Runtime state: replica pool + queue + dispatch-cost estimate."""

    def __init__(self, spec: TenantSpec, stats_window: int):
        if spec.backend not in FLEET_BACKENDS:
            raise ValueError(f"unknown tenant backend {spec.backend!r}; "
                             f"valid: {', '.join(FLEET_BACKENDS)}")
        if spec.replicas < 1:
            raise ValueError("a tenant needs at least one replica")
        if spec.max_queue is not None and spec.max_queue < 1:
            raise ValueError("max_queue must be >= 1 (or None)")
        if spec.qos not in QOS_CLASSES:
            raise ValueError(f"unknown qos class {spec.qos!r}; "
                             f"valid: {', '.join(QOS_CLASSES)}")
        if spec.min_replicas is not None and spec.min_replicas < 1:
            raise ValueError("min_replicas must be >= 1 (or None)")
        if (spec.max_replicas is not None
                and spec.max_replicas < max(1, spec.min_replicas or 1)):
            raise ValueError("max_replicas must be >= min_replicas")
        self.spec = spec
        self.pool = ReplicaPool.from_program(spec.program, spec.replicas,
                                             spec.max_batch,
                                             stats_window=stats_window)
        self.batcher = MicroBatcher(spec.max_batch, spec.deadline_ms)
        self.stats = ServeStats(window=stats_window)
        self.bucket: TokenBucket | None = None
        if spec.rate_limit_rps is not None:
            burst = (spec.rate_burst if spec.rate_burst is not None
                     else max(spec.rate_limit_rps, spec.max_batch))
            self.bucket = TokenBucket(spec.rate_limit_rps, burst)
        self.est_dispatch_s = 1e-3      # EMA of recent dispatch cost
        self.last_dispatch_s = 1e-3     # most recent (spike-sensitive)
        self.retiring = False           # drain, then drop from the worker
        self.from_manifest = False      # sync_manifest may retire it
        self.shadow_of: str | None = None      # incumbent it mirrors, if any
        self.comparator: ShadowComparator | None = None
        self.worker_key: str | None = None     # set when dispatch is
                                               # delegated to a WorkerHost
        self._as_last_shed = 0          # autoscale_tick round deltas
        self._as_last_requests = 0

    @property
    def name(self) -> str:
        return self.spec.name

    @property
    def engine(self) -> CircuitServingEngine:
        """Replica 0 — the bulk/offline-reference engine."""
        return self.pool.replicas[0].engine


class _BackendWorker(threading.Thread):
    """One scheduler thread per execution backend.

    Owns the queues of every tenant pinned to its backend behind one
    condition variable: producers notify on submit, the loop sleeps until
    the earliest possible due instant, pops the most urgent due batch
    *that has an idle replica*, and hands it to the dispatch executor so
    the scheduler never blocks on device time — that is what lets two due
    batches of one hot tenant overlap on different replicas.
    """

    def __init__(self, fleet: "ClassifierFleet", backend: str,
                 tenants: list[_Tenant]):
        super().__init__(name=f"fleet-dispatch-{backend}", daemon=True)
        self.fleet = fleet
        self.backend = backend
        self.tenants = tenants
        # megakernel mode: every due pallas tenant rides ONE multi-program
        # kernel launch per scheduler pass instead of per-tenant dispatches
        self.fused = bool(fleet.megakernel) and backend == "pallas"
        self.cond = threading.Condition()
        self.stop = False          # set under cond; drain-all then exit
        self.kick = False          # flush(): treat every queue as due
        self.in_flight = 0
        self._exec: ThreadPoolExecutor | None = None
        self._exec_workers = 0

    def _ensure_executor(self) -> ThreadPoolExecutor:
        want = max(2, sum(t.pool.size for t in self.tenants))
        if self._exec is None or want > self._exec_workers:
            old = self._exec
            self._exec = ThreadPoolExecutor(
                max_workers=want,
                thread_name_prefix=f"fleet-exec-{self.backend}")
            self._exec_workers = want
            if old is not None:     # running dispatches finish on old threads
                old.shutdown(wait=False)
        return self._exec

    # policy: urgency-ordered among due tenants --------------------------
    def _eta_s(self, t: _Tenant) -> float:
        """Expected submit-of-flush -> completion cost for one batch.

        Taking the max of the smoothed and the most recent dispatch time
        keeps the deadline trigger honest when a backend's cost spikes
        (e.g. pallas interpret retrace): an EMA alone lags the spike and
        converts near-deadline flushes into systematic small overshoots.
        """
        return (max(t.est_dispatch_s, t.last_dispatch_s)
                * self.fleet.safety_factor + self.fleet.sched_slack_s)

    def _due(self, t: _Tenant, now: float) -> bool:
        return bool(len(t.batcher)) and (
            self.stop or self.kick or t.retiring
            or t.batcher.due(now, self._eta_s(t)))

    @staticmethod
    def _qos_rank(t: _Tenant) -> int:
        """Scheduling priority among due tenants: guaranteed first, then
        best-effort, then shadows (mirrored traffic never delays either)."""
        if t.shadow_of is not None:
            return 2
        return 0 if t.spec.qos == "guaranteed" else 1

    def _pick(self, now: float) -> _Tenant | None:
        due = [t for t in self.tenants
               if self._due(t, now) and t.pool.has_idle()]
        if not due:
            return None
        return min(due, key=lambda t: (self._qos_rank(t),
                                       t.batcher.oldest_due_at))

    def _wait_s(self, now: float) -> float | None:
        # tenants whose pool is saturated wake via the release notify, not
        # a timer — including them here would spin the scheduler
        wakes = [t.batcher.next_due_at(self._eta_s(t))
                 for t in self.tenants if len(t.batcher)
                 and t.pool.has_idle()]
        if not wakes:
            return None                      # sleep until notified
        return max(1e-4, min(wakes) - now)

    def queued(self) -> int:
        return sum(len(t.batcher) for t in self.tenants)

    def _reap_retired(self) -> None:
        """Drop fully drained retiring tenants (caller holds `cond`)."""
        drained = [t for t in self.tenants
                   if t.retiring and not len(t.batcher) and t.pool.idle()]
        if drained:
            self.tenants = [t for t in self.tenants if t not in drained]
            for t in drained:       # free the worker procs' engines too
                self.fleet._unload_worker_tenant(t)
            self.cond.notify_all()

    def _pick_jobs(self, now: float) -> list[_Tenant]:
        """Megakernel mode: EVERY due tenant with an idle replica, ordered
        guaranteed -> best-effort -> shadow (they all share one launch, so
        the order only fixes result/stat attribution, not service)."""
        due = [t for t in self.tenants
               if self._due(t, now) and t.pool.has_idle()]
        return sorted(due, key=lambda t: (self._qos_rank(t),
                                          t.batcher.oldest_due_at))

    def run(self) -> None:
        while True:
            with self.cond:
                while True:
                    self._reap_retired()
                    now = self.fleet._clock()
                    picked = (self._pick_jobs(now) if self.fused
                              else [t for t in (self._pick(now),)
                                    if t is not None])
                    if picked:
                        jobs = []
                        for tenant in picked:
                            batch = tenant.batcher.pop_batch()
                            replica = tenant.pool.acquire(len(batch))
                            self.in_flight += len(batch)
                            jobs.append((tenant, replica, batch))
                        break
                    if (self.stop and self.queued() == 0
                            and self.in_flight == 0):
                        if self._exec is not None:
                            self._exec.shutdown(wait=False)
                        return
                    self.cond.wait(self._wait_s(now))
                ex = self._ensure_executor()
            if self.fused:
                ex.submit(self._run_dispatch_fused, jobs)
            else:
                ex.submit(self._run_dispatch, *jobs[0])

    def _run_dispatch(self, tenant: _Tenant, replica: EngineReplica,
                      batch: list[QueuedItem]) -> None:
        ok = False
        try:
            ok = self.fleet._dispatch(tenant, replica, batch)
        finally:
            with self.cond:
                # a failed dispatch served nothing: credit the acquire-time
                # readings charge back so routing doesn't treat the error
                # as load this replica carried
                tenant.pool.release(replica, n_readings=len(batch), ok=ok)
                self.in_flight -= len(batch)
                self._reap_retired()
                self.cond.notify_all()

    def _run_dispatch_fused(self, jobs: list) -> None:
        ok = False
        try:
            ok = self.fleet._dispatch_fused(jobs)
        finally:
            with self.cond:
                for tenant, replica, batch in jobs:
                    tenant.pool.release(replica, n_readings=len(batch),
                                        ok=ok)
                    self.in_flight -= len(batch)
                self._reap_retired()
                self.cond.notify_all()


class ClassifierFleet:
    """Router + scheduler over per-tenant replica pools."""

    def __init__(self, specs: list[TenantSpec], *,
                 stats_window: int = STATS_WINDOW,
                 safety_factor: float = 1.5, sched_slack_s: float = 5e-3,
                 warmup: bool = True, autostart: bool = True,
                 workers: int | None = None,
                 best_effort_backlog: int | None = None,
                 autoscale: AutoscaleConfig | None = None,
                 autoscale_interval_s: float = 1.0,
                 megakernel: bool = False,
                 megakernel_block_words: int | None = None,
                 clock=time.perf_counter):
        if not specs:
            raise ValueError("a fleet needs at least one tenant")
        names = [s.name for s in specs]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate tenant names: {sorted(names)}")
        if workers is not None and workers < 1:
            raise ValueError("workers must be >= 1 (or None for in-process)")
        if megakernel and workers is not None:
            raise ValueError("megakernel dispatch is in-process (the fused "
                             "launch pools every tenant's plan in one "
                             "kernel) — it cannot ride worker subprocesses")
        self.stats = ServeStats(window=stats_window)
        self.stats_window = stats_window
        self.safety_factor = safety_factor
        self.sched_slack_s = sched_slack_s
        self.warmup_on_load = warmup
        self.best_effort_backlog = best_effort_backlog
        self._clock = clock
        self.workers = workers
        self.megakernel = bool(megakernel)
        self.megakernel_block_words = megakernel_block_words
        self._megakernel_launches = 0       # fused multi-tenant launches
        self._megakernel_peak_tenants = 0   # most tenants in one launch
        self._worker_hosts: dict[str, WorkerHost] = {}  # backend -> host
        self._worker_key_seq = 0
        self._autoscaler = Autoscaler(autoscale) if autoscale else None
        self._autoscale_interval_s = autoscale_interval_s
        self._autoscale_stop = threading.Event()
        self._autoscale_thread: threading.Thread | None = None
        self._scale_events: list[dict] = []
        self._tenants: dict[str, _Tenant] = {
            s.name: self._build_tenant(s) for s in specs}
        by_backend: dict[str, list[_Tenant]] = {}
        for t in self._tenants.values():
            by_backend.setdefault(t.spec.backend, []).append(t)
        self._workers = {b: _BackendWorker(self, b, ts)
                         for b, ts in sorted(by_backend.items())}
        self._uid_lock = threading.Lock()
        self._next_uid = 0
        self._next_batch_uid = 0        # one per submit_many frame
        self._shadows: dict[str, _Tenant] = {}   # incumbent name -> shadow
        self._manifest_generation = 0
        self.errors: list[str] = []     # dispatch-thread failures, in order
        self._shutdown = False
        self._started = False
        self._admin_lock = threading.Lock()   # add/replace/retire
        self._sync_lock = threading.Lock()    # one manifest reconcile at a
                                              # time (watcher + RELOAD RPC)
        self._manifest_ctx: dict | None = None   # set by from_emit_dir
        if autostart:
            self.start()

    def _ensure_host(self, backend: str) -> WorkerHost:
        host = self._worker_hosts.get(backend)
        if host is None:
            host = WorkerHost(backend, self.workers)
            host.start()
            self._worker_hosts[backend] = host
        return host

    def _unload_worker_tenant(self, t: _Tenant) -> None:
        """Drop a reaped tenant's engines from its worker procs, if any."""
        if t.worker_key is None:
            return
        host = self._worker_hosts.get(t.spec.backend)
        if host is not None:
            host.unload(t.worker_key)

    def _build_tenant(self, spec: TenantSpec) -> _Tenant:
        t = _Tenant(spec, self.stats_window)
        if self.workers is not None:
            # dispatch runs out-of-process: broadcast the program to the
            # backend's worker procs (each holds its own engine + jit
            # cache) under a generation-unique key, so a replaced tenant's
            # in-flight batches still hit the *old* program until reaped
            host = self._ensure_host(spec.backend)
            self._worker_key_seq += 1
            t.worker_key = f"{spec.name}#{self._worker_key_seq}"
            host.load(t.worker_key, spec.program, spec.max_batch)
            if self.warmup_on_load:
                est = max(1e-4, host.warmup(t.worker_key))
                t.est_dispatch_s = est
                t.last_dispatch_s = est
        elif self.warmup_on_load:
            # every replica: each is pinned to its own device, so each has
            # its own executable to compile — a cold replica would pay jit
            # inside its first deadline-bound batch
            est = 1e-4
            for rep in t.pool.replicas:
                est = max(est, rep.engine.warmup())
            t.est_dispatch_s = est
            t.last_dispatch_s = est
        return t

    # -- construction -------------------------------------------------------
    @classmethod
    def from_emit_dir(cls, emit_dir: str | Path,
                      backends: str | dict[str, str] = "swar",
                      max_batch: int = DEFAULT_MAX_BATCH,
                      deadline_ms: float = DEFAULT_DEADLINE_MS,
                      tenants: list[str] | None = None,
                      replicas: int | dict[str, int] | None = None,
                      max_queue: int | None = None,
                      qos: str | dict[str, str] | None = None,
                      rate_limit_rps: float | dict[str, float] | None = None,
                      min_replicas: int | None = None,
                      max_replicas: int | None = None,
                      pallas_block_words: int | None = None,
                      **kw) -> "ClassifierFleet":
        """Serve every artifact the emit dir's `fleet.json` manifest names.

        `backends` pins execution: one string for the whole fleet, or a
        `{tenant: backend}` map (missing names fall back to `swar`).
        `replicas` overrides the manifest's per-tenant replica hints the
        same way; `max_queue` arms admission control for every tenant.
        `qos` / `rate_limit_rps` follow the same scalar-or-map shape
        (missing names fall back to `guaranteed` / unlimited), and
        `min_replicas`/`max_replicas` bound the autoscaler for every
        tenant.  The resulting fleet remembers the directory, so
        `sync_manifest()` hot-reloads added/replaced/retired manifest
        rows later.
        """
        emit_dir = Path(emit_dir)
        ctx = {"emit_dir": emit_dir, "backends": backends,
               "max_batch": max_batch, "deadline_ms": deadline_ms,
               "tenants": tenants, "replicas": replicas,
               "max_queue": max_queue, "qos": qos,
               "rate_limit_rps": rate_limit_rps,
               "min_replicas": min_replicas, "max_replicas": max_replicas,
               "pallas_block_words": pallas_block_words}
        doc = load_manifest_doc(emit_dir)
        rows = doc["tenants"]
        if tenants is not None:
            known = {r["name"] for r in rows}
            missing = sorted(set(tenants) - known)
            if missing:
                raise KeyError(f"tenants not in manifest: "
                               f"{', '.join(missing)}; available: "
                               f"{', '.join(sorted(known))}")
            rows = [r for r in rows if r["name"] in tenants]
        specs = [cls._spec_from_row(row, ctx) for row in rows]
        fleet = cls(specs, **kw)
        fleet._manifest_ctx = ctx
        fleet._manifest_generation = doc.get("generation", 0)
        for t in fleet._tenants.values():
            t.from_manifest = True
        return fleet

    @staticmethod
    def _spec_from_row(row: dict, ctx: dict) -> TenantSpec:
        backends = ctx["backends"]
        backend = (backends if isinstance(backends, str)
                   else backends.get(row["name"], "swar"))
        replicas = ctx["replicas"]
        n_replicas = (replicas if isinstance(replicas, int)
                      else (replicas or {}).get(row["name"],
                                                int(row.get("replicas", 1))))
        # cross-check the bundle against the digest the row recorded: a
        # sidecar that agrees with its bundle can still disagree with the
        # manifest that promised it (stale emit, swapped file, tampered row)
        program_kw = {}
        if backend == "pallas" and ctx.get("pallas_block_words") is not None:
            program_kw["pallas_block_words"] = int(ctx["pallas_block_words"])
        program = load_program(ctx["emit_dir"] / row["program"],
                               backend=backend,
                               expect_sha256=row.get("sha256"),
                               **program_kw)
        qos_ctx = ctx.get("qos")
        qos = (qos_ctx if isinstance(qos_ctx, str)
               else (qos_ctx or {}).get(row["name"],
                                        row.get("qos", "guaranteed")))
        rate_ctx = ctx.get("rate_limit_rps")
        rate = (rate_ctx if isinstance(rate_ctx, (int, float))
                else (rate_ctx or {}).get(row["name"]))
        return TenantSpec(
            name=row["name"], program=program, backend=backend,
            max_batch=ctx["max_batch"], deadline_ms=ctx["deadline_ms"],
            replicas=max(1, n_replicas), max_queue=ctx["max_queue"],
            dataset=row.get("dataset"),
            generation=int(row.get("generation", 0)),
            sha256=row.get("sha256"), qos=qos, rate_limit_rps=rate,
            min_replicas=ctx.get("min_replicas"),
            max_replicas=ctx.get("max_replicas"), meta=dict(row))

    # -- lifecycle ----------------------------------------------------------
    def start(self) -> None:
        if not self._started:
            self._started = True
            for w in self._workers.values():
                w.start()
            if self._autoscaler is not None and self._autoscale_interval_s > 0:
                self._autoscale_thread = threading.Thread(
                    target=self._autoscale_loop, name="fleet-autoscale",
                    daemon=True)
                self._autoscale_thread.start()

    def _autoscale_loop(self) -> None:
        while not self._autoscale_stop.wait(self._autoscale_interval_s):
            try:
                self.autoscale_tick()
            except Exception as exc:    # noqa: BLE001 — keep the loop alive
                self.errors.append(f"autoscale: {type(exc).__name__}: {exc}")

    def __enter__(self) -> "ClassifierFleet":
        self.start()
        return self

    def __exit__(self, *exc) -> None:
        self.shutdown(drain=exc == (None, None, None))

    @property
    def tenants(self) -> list[str]:
        return sorted(self._tenants)

    def tenant_backend(self, name: str) -> str:
        return self._tenant(name).spec.backend

    def tenant_replicas(self, name: str) -> int:
        return self._tenant(name).pool.size

    def n_features(self, name: str) -> int:
        return self._tenant(name).engine.n_features

    def _tenant(self, name: str) -> _Tenant:
        try:
            return self._tenants[name]
        except KeyError:
            raise KeyError(f"unknown tenant {name!r}; serving: "
                           f"{', '.join(self.tenants)}") from None

    @property
    def pending(self) -> int:
        return sum(w.queued() + w.in_flight for w in self._workers.values())

    # -- request path --------------------------------------------------------
    def _retry_after_ms(self, t: _Tenant, depth: int) -> float:
        """How long until the backlog plausibly fits under `max_queue`:
        batches ahead of a new arrival, spread over the replica pool, at
        the tenant's current dispatch-cost estimate."""
        batches_ahead = math.ceil(max(1, depth) / t.spec.max_batch)
        est = max(t.est_dispatch_s, t.last_dispatch_s, 1e-4)
        return max(1.0, batches_ahead * est * 1e3 / t.pool.size)

    def _qos_shed(self, t: _Tenant, worker: _BackendWorker) -> bool:
        """Should a best-effort submission give way right now?

        True when the tenant is `best_effort`, the fleet has a
        `best_effort_backlog` threshold, and the tenant's *backend* —
        queued plus in-flight across every tenant pinned to it — is
        already past that threshold.  Caller holds `worker.cond`.
        """
        return (t.spec.qos == "best_effort"
                and self.best_effort_backlog is not None
                and worker.queued() + worker.in_flight
                >= self.best_effort_backlog)

    def submit(self, tenant: str, readings: np.ndarray,
               deadline_ms: float | None = None) -> FleetRequest:
        """Queue one reading for `tenant`; returns a completion handle.

        Raises `FleetOverloadError` (with a `retry_after_ms` hint) instead
        of queueing when an admission gate trips — the tenant's
        `max_queue` depth limit, a best-effort tenant's backend backlog
        threshold, or the tenant's token bucket — so accepted requests
        keep meeting their deadlines and overload becomes visible as
        sheds rather than SLO misses.
        """
        readings = np.asarray(readings, dtype=np.float64).reshape(-1)
        while True:
            t = self._tenant(tenant)
            if readings.shape[0] != t.engine.n_features:
                raise ValueError(f"{tenant}: expected {t.engine.n_features} "
                                 f"features, got {readings.shape[0]}")
            worker = self._worker_of(t)
            with worker.cond:
                if self._shutdown:
                    raise RuntimeError("fleet is shut down")
                if self._tenants.get(tenant) is not t:
                    continue        # replaced mid-flight; retry on successor
                depth = len(t.batcher)
                if t.spec.max_queue is not None and depth >= t.spec.max_queue:
                    retry_ms = self._retry_after_ms(t, depth)
                    t.stats.record_shed()
                    self.stats.record_shed()
                    raise FleetOverloadError(tenant, depth, t.spec.max_queue,
                                             retry_ms)
                if self._qos_shed(t, worker):
                    retry_ms = self._retry_after_ms(t, depth)
                    t.stats.record_shed()
                    self.stats.record_shed()
                    raise FleetOverloadError(tenant, depth, t.spec.max_queue,
                                             retry_ms, reason="qos")
                if t.bucket is not None:
                    now = self._clock()
                    if t.bucket.take_upto(1, now) < 1:
                        retry_ms = max(1.0,
                                       t.bucket.retry_after_s(1, now) * 1e3)
                        t.stats.record_shed()
                        self.stats.record_shed()
                        raise FleetOverloadError(tenant, depth,
                                                 t.spec.max_queue, retry_ms,
                                                 reason="rate")
                with self._uid_lock:
                    uid = self._next_uid
                    self._next_uid += 1
                req = FleetRequest(
                    uid=uid, tenant=tenant, readings=readings,
                    deadline_ms=(t.spec.deadline_ms if deadline_ms is None
                                 else deadline_ms))
                entry = t.batcher.submit(req, now=self._clock(),
                                         deadline_ms=req.deadline_ms)
                req._t_submit = entry.t_submit
                worker.cond.notify_all()
            # mirror *after* the incumbent's scheduler lock is released:
            # shadow traffic must never serialize against — or error into —
            # the serving path that admitted the request
            self._mirror(tenant, [req])
            return req

    def submit_many(self, tenant: str, readings: np.ndarray,
                    deadlines_ms=None
                    ) -> tuple[list[FleetRequest], np.ndarray, float]:
        """Queue a whole `(B, F)` frame under one scheduler-lock acquisition.

        The batched-ingest fast path: uids are allocated in one block, the
        frame enters the tenant's queue as one contiguous arrival-order
        run (`MicroBatcher.submit_many`), and every request keeps a view
        into the shared reading plane so dispatch can slice it instead of
        re-stacking rows (`batch_uid` threads the frame identity through
        to `ReplicaPool` accounting).

        Admission is per-row: with `max_queue` armed, the head of the
        frame is admitted up to the remaining queue room — further capped
        by the tenant's token-bucket grant when rate limits are armed,
        and zeroed entirely for a best-effort tenant whose backend is
        past the fleet's backlog threshold — and the tail is shed.
        Returns ``(requests, shed_idx, retry_after_ms)`` — admitted
        requests in row order, the row indices that were shed, and the
        backoff hint for them (0.0 when nothing shed).  `deadlines_ms` is
        None, a scalar, or one value per row; NaN rows use the tenant's
        default budget.

        A malformed deadline table (any non-positive finite row) rejects
        the *whole* frame with ValueError before any row is admitted,
        shed-counted, or assigned a uid — admission is all-or-nothing per
        row, never torn mid-frame.
        """
        x = np.ascontiguousarray(np.asarray(readings, dtype=np.float64))
        if x.ndim == 1:
            x = x.reshape(1, -1)
        if x.ndim != 2:
            raise ValueError(f"expected (B, F) readings, got {x.shape}")
        B = x.shape[0]
        if deadlines_ms is None:
            dls = None
        else:
            dls = np.broadcast_to(
                np.asarray(deadlines_ms, dtype=np.float64), (B,))
            bad = ~np.isnan(dls) & ~(dls > 0)    # catches <=0 and -inf
            if bad.any():
                rows = np.flatnonzero(bad)[:8].tolist()
                raise ValueError(
                    f"{tenant}: non-positive deadline_ms at rows {rows} — "
                    f"frame rejected whole (deadline budget must be "
                    f"positive)")
        while True:
            t = self._tenant(tenant)
            if x.shape[1] != t.engine.n_features:
                raise ValueError(f"{tenant}: expected {t.engine.n_features} "
                                 f"features, got {x.shape[1]}")
            worker = self._worker_of(t)
            with worker.cond:
                if self._shutdown:
                    raise RuntimeError("fleet is shut down")
                if self._tenants.get(tenant) is not t:
                    continue        # replaced mid-flight; retry on successor
                depth = len(t.batcher)
                if t.spec.max_queue is None:
                    n_admit = B
                else:
                    n_admit = max(0, min(B, t.spec.max_queue - depth))
                retry_hint = 0.0
                if n_admit and self._qos_shed(t, worker):
                    n_admit = 0     # best-effort gives way wholesale
                if n_admit and t.bucket is not None:
                    now = self._clock()
                    granted = t.bucket.take_upto(n_admit, now)
                    if granted < n_admit:
                        retry_hint = max(
                            1.0, t.bucket.retry_after_s(1, now) * 1e3)
                    n_admit = granted
                n_shed = B - n_admit
                if n_shed:
                    t.stats.record_shed(n_shed)
                    self.stats.record_shed(n_shed)
                if n_admit == 0:
                    return ([], np.arange(B),
                            max(retry_hint, self._retry_after_ms(t, depth)))
                with self._uid_lock:
                    uid0 = self._next_uid
                    self._next_uid += n_admit
                    batch_uid = self._next_batch_uid
                    self._next_batch_uid += 1
                default = t.spec.deadline_ms
                reqs = []
                for i in range(n_admit):
                    d = default if dls is None else float(dls[i])
                    if d != d:              # NaN -> tenant default
                        d = default
                    reqs.append(FleetRequest(
                        uid=uid0 + i, tenant=tenant, readings=x[i],
                        deadline_ms=d, batch_uid=batch_uid,
                        _plane=x, _row=i))
                entries = t.batcher.submit_many(
                    reqs, now=self._clock(),
                    deadlines_ms=[r.deadline_ms for r in reqs])
                for r, e in zip(reqs, entries):
                    r._t_submit = e.t_submit
                worker.cond.notify_all()
            self._mirror(tenant, reqs)   # admitted rows only; sheds are not
            shed_idx = np.arange(n_admit, B)     # real traffic to compare on
            retry_ms = (max(retry_hint, self._retry_after_ms(t, depth + n_admit))
                        if n_shed else 0.0)
            return reqs, shed_idx, retry_ms

    def _worker_of(self, t: _Tenant) -> _BackendWorker:
        return self._workers[t.spec.backend]

    def _mirror(self, tenant: str, primaries: list[FleetRequest]) -> None:
        """Copy freshly admitted requests to `tenant`'s shadow, if any.

        Best-effort by design: a full shadow queue *drops* mirrors
        (counted in the comparator) rather than backpressuring the
        incumbent — mirrored traffic must cost the serving path nothing.
        Each mirror is paired with its primary by the primary's uid via
        completion callbacks into the `ShadowComparator`.
        """
        if not primaries:
            return
        sh = self._shadows.get(tenant)
        if sh is None:
            return
        comp = sh.comparator
        worker = self._worker_of(sh)
        with worker.cond:
            if (self._shutdown or sh.retiring
                    or self._shadows.get(tenant) is not sh):
                comp.record_dropped(len(primaries))
                return
            room = (len(primaries) if sh.spec.max_queue is None
                    else max(0, sh.spec.max_queue - len(sh.batcher)))
            admit, dropped = primaries[:room], primaries[room:]
            if dropped:
                comp.record_dropped(len(dropped))
            if not admit:
                return
            with self._uid_lock:
                uid0 = self._next_uid
                self._next_uid += len(admit)
            mirrors = []
            for i, p in enumerate(admit):
                m = FleetRequest(
                    uid=uid0 + i, tenant=sh.name, readings=p.readings,
                    deadline_ms=p.deadline_ms, batch_uid=p.batch_uid,
                    _plane=p._plane, _row=p._row)
                comp.expect(p.uid)
                m.add_done_callback(
                    lambda r, _uid=p.uid: comp.observe_shadow(_uid, r))
                mirrors.append(m)
            entries = sh.batcher.submit_many(
                mirrors, now=self._clock(),
                deadlines_ms=[m.deadline_ms for m in mirrors])
            for m, e in zip(mirrors, entries):
                m._t_submit = e.t_submit
            worker.cond.notify_all()
        # outside the shadow worker lock — a primary that already completed
        # runs the callback synchronously right here
        for p in admit:
            p.add_done_callback(comp.observe_primary)

    def classify_stream(self, tenant: str, x: np.ndarray) -> np.ndarray:
        """Bulk path: route a whole `(S, F)` stream straight to replica 0."""
        return self._tenant(tenant).engine.classify_stream(x)

    # -- dispatch (executor threads) -----------------------------------------
    @staticmethod
    def _gather_batch(reqs: list[FleetRequest]) -> np.ndarray:
        """Readings of a popped batch as one `(B, F)` array.

        When every request is a consecutive row of the same submit_many
        plane (the batched-ingest case), the batch is a zero-copy slice of
        that plane; anything else falls back to stacking per-request rows.
        """
        first = reqs[0]
        plane = first._plane
        if plane is not None and all(
                r._plane is plane and r._row == first._row + i
                for i, r in enumerate(reqs)):
            return plane[first._row: first._row + len(reqs)]
        return np.stack([r.readings for r in reqs])

    def _dispatch(self, tenant: _Tenant, replica: EngineReplica,
                  entries: list[QueuedItem]) -> bool:
        """Serve one popped batch; returns True iff it completed cleanly."""
        reqs: list[FleetRequest] = [e.item for e in entries]
        # a shadow's dispatches never touch fleet-level stats or the fleet
        # error log: mirrored traffic is an experiment riding alongside the
        # SLO-accounted serving path, and a broken candidate must show up
        # in its comparator, not in the fleet's health signals
        is_shadow = tenant.shadow_of is not None
        host = (self._worker_hosts.get(tenant.spec.backend)
                if tenant.worker_key is not None else None)
        try:
            x = self._gather_batch(reqs)
            # the dispatch timing deliberately includes the worker-path IPC
            # (slab copy + queue round-trip): it is the cost the deadline
            # policy must budget for, not just device time
            t0 = self._clock()
            if host is not None:
                labels = host.eval(tenant.worker_key, x)
            else:
                labels = replica.engine.classify_batch(x)
            dt = self._clock() - t0
        except Exception as exc:        # complete exceptionally, never hang
            msg = f"{type(exc).__name__}: {exc}"
            if not is_shadow:
                self.errors.append(f"{tenant.name}: {msg}")
            for r in reqs:
                r.error = msg
                r._complete()
            return False
        tenant.est_dispatch_s = 0.7 * tenant.est_dispatch_s + 0.3 * dt
        tenant.last_dispatch_s = dt
        if not is_shadow:
            self.stats.record(len(reqs), dt)
        tenant.stats.record(len(reqs), dt)
        if host is not None:
            # keep the replica-level ledger honest in worker mode too:
            # timing/labels came from the worker proc, but the attach path
            # (label, latency, request stats) is identical
            replica.engine.stats.record(len(reqs), dt)
        # FleetRequest carries the same completion fields as SensorRequest,
        # so the engine's label/latency attach is reused verbatim (request
        # stats land on the replica's engine; tenant + fleet get them here)
        replica.engine.complete(reqs, labels)
        for r in reqs:
            if not is_shadow:
                self.stats.record_request(r.latency_ms, r.deadline_ms)
            tenant.stats.record_request(r.latency_ms, r.deadline_ms)
            r._complete()
        return True

    def _dispatch_fused(self, jobs: list) -> bool:
        """Serve MANY tenants' popped batches in one megakernel launch.

        `jobs` is `[(tenant, replica, entries), ...]` — every due pallas
        tenant of this scheduler pass.  Each tenant's batch is binarized
        with its own ABC thresholds, padded to its engine's compiled
        batch shape (so the fused kernel sees stable word widths and the
        jit cache stays warm), bit-packed, and the whole manifest goes
        through `kernels.dispatch.fleet_eval_words` as ONE launch.
        Per-tenant accounting mirrors `_dispatch`: every tenant is
        charged the full launch wall time (that IS the latency its batch
        paid), the fleet-level batch sample is recorded once per launch,
        and shadows stay out of fleet stats and the error log.  A launch
        failure fails every request of every job — the whole launch is
        the unit of execution.
        """
        from repro.kernels import dispatch as D

        prepared = []
        try:
            plans, words_list = [], []
            for tenant, replica, entries in jobs:
                reqs = [e.item for e in entries]
                words32, B = replica.engine.prepare_packed_batch(
                    self._gather_batch(reqs))
                plans.append(replica.engine.program.plan())
                words_list.append(words32)
                prepared.append((tenant, replica, reqs, B))
            t0 = self._clock()
            outs = D.fleet_eval_words(
                plans, words_list, backend="pallas",
                block_words=self.megakernel_block_words)
            dt = self._clock() - t0
        except Exception as exc:        # complete exceptionally, never hang
            msg = f"megakernel: {type(exc).__name__}: {exc}"
            for tenant, replica, entries in jobs:
                if tenant.shadow_of is None:
                    self.errors.append(f"{tenant.name}: {msg}")
                for e in entries:
                    e.item.error = msg
                    e.item._complete()
            return False
        live_readings = sum(len(reqs) for t, _, reqs, _ in prepared
                            if t.shadow_of is None)
        if live_readings:
            self.stats.record(live_readings, dt)   # one launch = one batch
        self._megakernel_launches += 1
        self._megakernel_peak_tenants = max(self._megakernel_peak_tenants,
                                            len(jobs))
        for (tenant, replica, reqs, B), out in zip(prepared, outs):
            labels = np.asarray(out[:B], dtype=np.int32)
            is_shadow = tenant.shadow_of is not None
            tenant.est_dispatch_s = 0.7 * tenant.est_dispatch_s + 0.3 * dt
            tenant.last_dispatch_s = dt
            tenant.stats.record(len(reqs), dt)
            replica.engine.stats.record(len(reqs), dt)
            replica.engine.complete(reqs, labels)
            for r in reqs:
                if not is_shadow:
                    self.stats.record_request(r.latency_ms, r.deadline_ms)
                tenant.stats.record_request(r.latency_ms, r.deadline_ms)
                r._complete()
        return True

    # -- shadow deployment ---------------------------------------------------
    def deploy_shadow(self, spec: TenantSpec, of: str) -> ShadowComparator:
        """Stand up `spec` as a **shadow replica** of live tenant `of`.

        The shadow gets its own replica pool and queue on its backend's
        scheduler but is not routable: it only ever sees copies of traffic
        admitted for `of` (`_mirror`), and its dispatches stay out of the
        fleet's stats and error log.  Returns the `ShadowComparator`
        accumulating agreement/accuracy/latency deltas — the evidence a
        promotion decision is made from.  One shadow per incumbent; give
        the shadow's `max_queue` a value to bound mirror backlog (excess
        mirrors are dropped, never backpressured).
        """
        with self._admin_lock:
            if self._shutdown:
                raise RuntimeError("fleet is shut down")
            incumbent = self._tenant(of)
            if of in self._shadows:
                raise ValueError(
                    f"tenant {of!r} already has a shadow "
                    f"({self._shadows[of].name!r}); retire it first")
            if spec.name in self._tenants or any(
                    s.name == spec.name for s in self._shadows.values()):
                raise ValueError(f"name {spec.name!r} is already in use")
            t = self._build_tenant(spec)    # warmup outside any worker lock
            if t.engine.n_features != incumbent.engine.n_features:
                raise ValueError(
                    f"shadow {spec.name!r} expects {t.engine.n_features} "
                    f"features but incumbent {of!r} serves "
                    f"{incumbent.engine.n_features}")
            t.shadow_of = of
            t.comparator = ShadowComparator(of, spec.name,
                                            window=self.stats_window)
            worker = self._workers.get(spec.backend)
            if worker is None:
                worker = _BackendWorker(self, spec.backend, [])
                self._workers[spec.backend] = worker
                if self._started:
                    worker.start()
            with worker.cond:
                self._shadows[of] = t
                worker.tenants.append(t)
                worker.cond.notify_all()
            return t.comparator

    def shadow_comparator(self, of: str) -> ShadowComparator:
        t = self._shadows.get(of)
        if t is None:
            raise KeyError(f"tenant {of!r} has no shadow; shadowed: "
                           f"{', '.join(sorted(self._shadows)) or '(none)'}")
        return t.comparator

    def retire_shadow(self, of: str, timeout: float = 30.0) -> dict:
        """Tear down `of`'s shadow; returns the comparator's final summary.

        Mirroring stops immediately; the queued mirror backlog is served
        (so every expected pair closes) before the pool is dropped.  Both
        the rollback path and the promotion path end here — promotion
        additionally re-registers the winner under the incumbent's name
        and `sync_manifest()`s it into the serving slot.
        """
        with self._admin_lock:
            t = self._shadows.pop(of, None)
            if t is None:
                raise KeyError(f"tenant {of!r} has no shadow")
            worker = self._worker_of(t)
            with worker.cond:
                t.retiring = True
                worker.cond.notify_all()
        deadline = self._clock() + timeout
        with worker.cond:
            while t in worker.tenants:
                left = deadline - self._clock()
                if left <= 0:
                    raise TimeoutError(
                        f"shadow of {of!r} still draining after {timeout}s "
                        f"({len(t.batcher)} queued)")
                worker.cond.wait(min(left, 0.05))
        return t.comparator.summary()

    # -- hot reload ----------------------------------------------------------
    def add_tenant(self, spec: TenantSpec) -> None:
        """Stand up a new tenant without draining anything."""
        with self._admin_lock:
            # shutdown() flips the flag under this lock, so checking here
            # can't race a concurrent shutdown into leaking a worker
            # thread that nobody will ever stop
            if self._shutdown:
                raise RuntimeError("fleet is shut down")
            if spec.name in self._tenants:
                raise ValueError(f"tenant {spec.name!r} already exists "
                                 "(use replace_tenant)")
            t = self._build_tenant(spec)    # warmup outside any worker lock
            worker = self._workers.get(spec.backend)
            if worker is None:
                worker = _BackendWorker(self, spec.backend, [])
                self._workers[spec.backend] = worker
                if self._started:
                    worker.start()
            with worker.cond:
                self._tenants[spec.name] = t
                worker.tenants.append(t)
                worker.cond.notify_all()

    def replace_tenant(self, spec: TenantSpec) -> None:
        """Swap a tenant for a new program/config without dropping requests.

        Queued requests transfer to the successor (original submit times
        and budgets intact) when the feature count still matches; batches
        already in flight finish on the old replicas.  The old pool drains
        and is dropped by its scheduler.
        """
        with self._admin_lock:
            if self._shutdown:
                raise RuntimeError("fleet is shut down")
            old = self._tenant(spec.name)
            new = self._build_tenant(spec)
            new.from_manifest = old.from_manifest
            old_worker = self._worker_of(old)
            new_worker = self._workers.get(spec.backend)
            if new_worker is None:
                new_worker = _BackendWorker(self, spec.backend, [])
                self._workers[spec.backend] = new_worker
                if self._started:
                    new_worker.start()
            first, second = ((old_worker, new_worker)
                             if id(old_worker) <= id(new_worker)
                             else (new_worker, old_worker))
            with first.cond:
                ctx = second.cond if second is not first else \
                    threading.Lock()        # dummy when same worker
                with ctx:
                    moved = [e for b in old.batcher.drain() for e in b]
                    compatible = (new.engine.n_features
                                  == old.engine.n_features)
                    if compatible:
                        new.batcher.adopt(moved)
                    self._tenants[spec.name] = new
                    new_worker.tenants.append(new)
                    old.retiring = True
                    old_worker.cond.notify_all()
                    new_worker.cond.notify_all()
            if not compatible:
                for e in moved:
                    e.item.error = (f"tenant {spec.name!r} replaced with an "
                                    f"incompatible feature count")
                    e.item._complete()

    def retire_tenant(self, name: str, timeout: float = 30.0) -> None:
        """Remove a tenant: refuse new submits, serve the backlog, drop it."""
        with self._admin_lock:
            t = self._tenant(name)
            worker = self._worker_of(t)
            with worker.cond:
                del self._tenants[name]
                t.retiring = True
                worker.cond.notify_all()
        deadline = self._clock() + timeout
        with worker.cond:
            while t in worker.tenants:
                left = deadline - self._clock()
                if left <= 0:
                    raise TimeoutError(
                        f"tenant {name!r} still draining after {timeout}s "
                        f"({len(t.batcher)} queued)")
                worker.cond.wait(min(left, 0.05))

    def sync_manifest(self) -> dict:
        """Reconcile live tenants with the emit dir's current `fleet.json`.

        Only fleets built by `from_emit_dir` can sync.  Returns the action
        summary `{"added": [...], "replaced": [...], "retired": [...],
        "generation": N}` — empty lists mean the manifest generation
        matched and nothing moved.
        """
        if self._manifest_ctx is None:
            raise RuntimeError("fleet was not built from an emit dir; "
                               "nothing to sync against")
        with self._sync_lock:
            return self._sync_manifest_locked()

    def _sync_manifest_locked(self) -> dict:
        ctx = self._manifest_ctx
        doc = load_manifest_doc(ctx["emit_dir"])
        actions = {"added": [], "replaced": [], "retired": [],
                   "generation": doc.get("generation", 0)}
        rows = {r["name"]: r for r in doc["tenants"]}
        if ctx["tenants"] is not None:
            rows = {n: r for n, r in rows.items() if n in ctx["tenants"]}
        for name in sorted(set(self._tenants) - set(rows)):
            if self._tenants[name].from_manifest:
                self.retire_tenant(name)
                actions["retired"].append(name)
        for name, row in sorted(rows.items()):
            cur = self._tenants.get(name)
            if cur is None:
                spec = self._spec_from_row(row, ctx)
                self.add_tenant(spec)
                self._tenants[name].from_manifest = True
                actions["added"].append(name)
            elif int(row.get("generation", 0)) != cur.spec.generation:
                self.replace_tenant(self._spec_from_row(row, ctx))
                actions["replaced"].append(name)
        self._manifest_generation = actions["generation"]
        return actions

    # -- autoscaling ---------------------------------------------------------
    def _tenant_signals(self) -> list[TenantSignals]:
        """Snapshot every tenant's control signals (one round's input).

        Each tenant is read under its backend's scheduler condition so
        queue depth / inflight / shed counters are mutually consistent;
        the per-round deltas are kept on the tenant so a tick sees only
        what happened since the previous tick.
        """
        signals = []
        live = list(self._tenants.values()) + list(self._shadows.values())
        for t in live:
            worker = self._worker_of(t)
            with worker.cond:
                s = t.stats.summary()
                shed, nreq = s["n_shed"], s["n_requests"]
                spec = t.spec
                signals.append(TenantSignals(
                    name=t.name,
                    pool_size=t.pool.size,
                    queue_depth=len(t.batcher),
                    inflight=t.pool.total_inflight,
                    shed_delta=shed - t._as_last_shed,
                    request_delta=nreq - t._as_last_requests,
                    est_dispatch_ms=max(t.est_dispatch_s,
                                        t.last_dispatch_s) * 1e3,
                    max_batch=spec.max_batch,
                    max_queue=spec.max_queue,
                    min_replicas=spec.min_replicas or 1,
                    max_replicas=(spec.max_replicas
                                  if spec.max_replicas is not None
                                  else spec.replicas),
                    is_shadow=t.shadow_of is not None))
                t._as_last_shed = shed
                t._as_last_requests = nreq
        return signals

    def autoscale_tick(self) -> list[dict]:
        """One autoscaler round: observe signals, resize pools, log events.

        Deterministic given the fleet's state — the background loop calls
        it on a timer, and tests call it directly to step the controller a
        bounded number of rounds with zero wall-clock dependence.  Returns
        the applied actions (also appended to the bounded event log
        surfaced by `stats_summary`).
        """
        if self._autoscaler is None:
            return []
        actions = self._autoscaler.observe(self._tenant_signals())
        applied = []
        for act in actions:
            t = self._tenants.get(act.name)
            if t is None or t.retiring:
                continue        # retired/replaced between snapshot and apply
            n = (self._grow_tenant(t, act.delta) if act.delta > 0
                 else self._shrink_tenant(t))
            if n:
                applied.append({**act.as_dict(), "applied": n,
                                "pool_size": t.pool.size})
        if applied:
            self._scale_events.extend(applied)
            del self._scale_events[:-256]
        return applied

    def _grow_tenant(self, t: _Tenant, k: int) -> int:
        """Add `k` replicas to `t`'s pool; engines are built (and warmed)
        outside the scheduler lock so growth never stalls dispatch."""
        worker = self._worker_of(t)
        with worker.cond:
            base = t.pool.next_index()
        fresh = []
        for i in range(k):
            rep = make_replica(t.spec.program, base + i, t.spec.max_batch,
                               stats_window=self.stats_window)
            # in worker mode the subprocess engines are already warm; the
            # fleet-side replica is only a concurrency token + ledger
            if self.warmup_on_load and t.worker_key is None:
                rep.engine.warmup()
            fresh.append(rep)
        with worker.cond:
            if self._tenants.get(t.name) is not t or t.retiring:
                return 0
            for rep in fresh:
                t.pool.grow(rep)
            worker.cond.notify_all()    # saturated pickers may proceed now
        return len(fresh)

    def _shrink_tenant(self, t: _Tenant) -> int:
        worker = self._worker_of(t)
        with worker.cond:
            if self._tenants.get(t.name) is not t or t.retiring:
                return 0
            dropped = t.pool.shrink_idle()
        return 1 if dropped is not None else 0

    @property
    def autoscale_events(self) -> list[dict]:
        return list(self._scale_events)

    # -- drain / shutdown ----------------------------------------------------
    def flush(self, timeout: float | None = 30.0) -> None:
        """Force-dispatch the whole backlog and wait until it is served.

        Waits on queued *and* in-flight work: a request popped by a worker
        just before flush() is called is still awaited (workers notify the
        condition after every dispatch completes).
        """
        deadline = None if timeout is None else self._clock() + timeout
        for w in list(self._workers.values()):
            with w.cond:
                w.kick = True
                w.cond.notify_all()
        try:
            for w in list(self._workers.values()):
                with w.cond:
                    while w.queued() or w.in_flight:
                        left = (None if deadline is None
                                else deadline - self._clock())
                        if left is not None and left <= 0:
                            raise TimeoutError(
                                f"flush: {w.queued()} queued + "
                                f"{w.in_flight} in-flight requests still "
                                f"pending on backend {w.backend}")
                        w.cond.wait(0.05 if left is None
                                    else min(left, 0.05))
        finally:
            for w in list(self._workers.values()):
                with w.cond:
                    w.kick = False

    def shutdown(self, drain: bool = True, timeout: float = 60.0) -> None:
        """Stop dispatch threads; `drain` serves the backlog first."""
        with self._admin_lock:      # serialized against add/replace, so no
            if self._shutdown:      # worker can be created+started after
                return              # the flag flips
            self._shutdown = True
        self._autoscale_stop.set()
        if self._autoscale_thread is not None:
            self._autoscale_thread.join(timeout=5.0)
        for w in self._workers.values():
            with w.cond:
                if not drain:       # cancel the backlog deterministically
                    for t in w.tenants:
                        for batch in t.batcher.drain():
                            for e in batch:
                                e.item.error = "cancelled at shutdown"
                                e.item._complete()
                w.stop = True
                w.cond.notify_all()
        if self._started:
            for w in self._workers.values():
                w.join(timeout)
                if w.is_alive():
                    raise TimeoutError(f"worker {w.name} did not stop "
                                       f"within {timeout}s")
        # dispatch threads are parked; the worker procs have nothing in
        # flight and can be torn down (slabs unlink here too)
        for host in self._worker_hosts.values():
            host.close()

    # -- observability -------------------------------------------------------
    def stats_summary(self) -> dict:
        """Fleet-wide + per-tenant (+ per-replica) `ServeStats` summaries.

        Each tenant row carries its *deploy identity* — the artifact
        sha256 its manifest row recorded and the manifest generation the
        fleet last synced to — so an operator (or the autopilot) can tell
        exactly which emitted design is live without touching the emit
        dir.  Tenants with a live shadow get a `"shadow"` sub-dict with
        the comparator's running verdict evidence.

        The snapshot is *consistent*: every backend's scheduler condition
        is held (in one canonical order, so this cannot deadlock against
        `replace_tenant`'s two-lock ordering) while the rows are read,
        so a STATS frame served from a sharded accept loop can never
        report a queue depth from mid-admission or a fleet shed total
        that disagrees with the per-tenant sheds it sums over.
        """
        # snapshot the worker set first — admin ops may add workers, and
        # new workers start with no tenants, so missing a *brand-new*
        # backend only means its (empty) tenants appear next call
        workers = sorted(self._workers.values(), key=id)
        with contextlib.ExitStack() as stack:
            for w in workers:
                stack.enter_context(w.cond)
            tenants = {}
            for name, t in sorted(self._tenants.items()):
                row = {
                    "backend": t.spec.backend,
                    "max_batch": t.spec.max_batch,
                    "deadline_ms": t.spec.deadline_ms,
                    "max_queue": t.spec.max_queue,
                    "dataset": t.spec.dataset,
                    "generation": t.spec.generation,
                    "sha256": t.spec.sha256,
                    "qos": t.spec.qos,
                    "rate_limit_rps": t.spec.rate_limit_rps,
                    "pool_size": t.pool.size,
                    "pending": len(t.batcher),
                    "replicas": t.pool.summary(),
                    **t.stats.summary(),
                }
                sh = self._shadows.get(name)
                if sh is not None:
                    row["shadow"] = {
                        "name": sh.name,
                        "backend": sh.spec.backend,
                        "sha256": sh.spec.sha256,
                        "pending": len(sh.batcher),
                        **sh.comparator.summary(),
                    }
                tenants[name] = row
            out = {
                "fleet": self.stats.summary(),
                "manifest_generation": self._manifest_generation,
                "tenants": tenants,
            }
            if self.megakernel:
                out["megakernel"] = {
                    "launches": self._megakernel_launches,
                    "peak_tenants_per_launch": self._megakernel_peak_tenants,
                    "block_words": self.megakernel_block_words,
                }
        if self._worker_hosts:
            out["workers"] = {b: h.summary()
                              for b, h in sorted(self._worker_hosts.items())}
        if self._autoscaler is not None:
            out["autoscale"] = {**self._autoscaler.summary(),
                                "events": self.autoscale_events[-16:]}
        return out
