"""Multi-tenant sensor-serving fleet: router + deadline-driven dispatch.

One `ClassifierFleet` serves every classifier emitted under an emit
directory (`repro.evolve --emit-dir`, `python -m repro.compile.export`):
each manifest tenant gets its own `CircuitServingEngine` over the loaded
program, pinned to an execution backend (`np`/`swar`/`pallas` — the same
`kernels.dispatch` routing the campaign evaluators use, so a `swar` or
`pallas` tenant shards large batches along the packed-word axis across
local devices), and a single router fans `submit(tenant, reading)` calls
into per-tenant `MicroBatcher` queues.

Dispatch is pushed off the caller thread: one background scheduler thread
per *backend* watches the queues of the tenants pinned to it and flushes a
tenant the moment a batch is due — `max_batch` queued, or the oldest
request about to outlive its latency budget (see `batcher.py`).  Per-batch
execution cost is tracked as an EMA per tenant and fed back into the
deadline policy, so "about to" means "could not survive one more dispatch
interval".  Completed requests carry label + measured latency; per-tenant
and fleet-wide `ServeStats` accumulate throughput, p50/p99 batch and
request latency, and SLO-violation counts.

Everything the scheduler adds is bookkeeping — labels come from the same
`CircuitProgram` the offline path runs, so fleet output is bit-identical
to `CircuitProgram.predict` per tenant on every backend (pinned by
tests/test_serve_fleet.py and the tests/test_conformance.py fleet matrix).
"""
from __future__ import annotations

import threading
import time
from dataclasses import dataclass, field
from pathlib import Path

import numpy as np

from repro.compile.artifact import load_manifest, load_program
from repro.compile.program import CircuitProgram
from repro.serve.batcher import MicroBatcher, QueuedItem
from repro.serving.circuit_engine import (STATS_WINDOW, CircuitServingEngine,
                                          ServeStats)

FLEET_BACKENDS = ("np", "swar", "pallas")
DEFAULT_DEADLINE_MS = 50.0
DEFAULT_MAX_BATCH = 256


@dataclass
class FleetRequest:
    """One routed sensor reading; completion is signalled via `result()`."""

    uid: int
    tenant: str
    readings: np.ndarray
    deadline_ms: float
    label: int | None = None
    latency_ms: float | None = None
    error: str | None = None
    _t_submit: float = 0.0
    _event: threading.Event = field(default_factory=threading.Event,
                                    repr=False)

    def done(self) -> bool:
        return self._event.is_set()

    def result(self, timeout: float | None = None) -> int:
        """Block until the label is ready (raises on timeout/cancel)."""
        if not self._event.wait(timeout):
            raise TimeoutError(f"request {self.uid} ({self.tenant}) not "
                               f"served within {timeout}s")
        if self.error is not None:
            raise RuntimeError(f"request {self.uid} ({self.tenant}) failed: "
                               f"{self.error}")
        return self.label

    @property
    def slo_miss(self) -> bool:
        return self.latency_ms is not None and self.latency_ms > self.deadline_ms


@dataclass
class TenantSpec:
    """Everything needed to stand up one tenant engine."""

    name: str
    program: CircuitProgram
    backend: str = "swar"              # np | swar | pallas
    max_batch: int = DEFAULT_MAX_BATCH
    deadline_ms: float = DEFAULT_DEADLINE_MS
    dataset: str | None = None
    meta: dict = field(default_factory=dict)


class _Tenant:
    """Runtime state: engine + queue + dispatch-cost estimate."""

    def __init__(self, spec: TenantSpec, stats_window: int):
        if spec.backend not in FLEET_BACKENDS:
            raise ValueError(f"unknown tenant backend {spec.backend!r}; "
                             f"valid: {', '.join(FLEET_BACKENDS)}")
        self.spec = spec
        self.engine = CircuitServingEngine(spec.program, spec.max_batch,
                                           stats_window=stats_window)
        self.batcher = MicroBatcher(spec.max_batch, spec.deadline_ms)
        self.est_dispatch_s = 1e-3      # EMA of recent dispatch cost
        self.last_dispatch_s = 1e-3     # most recent (spike-sensitive)

    @property
    def name(self) -> str:
        return self.spec.name


class _BackendWorker(threading.Thread):
    """One dispatch thread per execution backend.

    Owns the queues of every tenant pinned to its backend behind one
    condition variable: producers notify on submit, the loop sleeps until
    the earliest possible due instant, pops the most urgent due batch, and
    dispatches it outside the lock so producers never block on device time.
    """

    def __init__(self, fleet: "ClassifierFleet", backend: str,
                 tenants: list[_Tenant]):
        super().__init__(name=f"fleet-dispatch-{backend}", daemon=True)
        self.fleet = fleet
        self.backend = backend
        self.tenants = tenants
        self.cond = threading.Condition()
        self.stop = False          # set under cond; drain-all then exit
        self.kick = False          # flush(): treat every queue as due
        self.in_flight = 0

    # policy: urgency-ordered among due tenants --------------------------
    def _eta_s(self, t: _Tenant) -> float:
        """Expected submit-of-flush -> completion cost for one batch.

        Taking the max of the smoothed and the most recent dispatch time
        keeps the deadline trigger honest when a backend's cost spikes
        (e.g. pallas interpret retrace): an EMA alone lags the spike and
        converts near-deadline flushes into systematic small overshoots.
        """
        return (max(t.est_dispatch_s, t.last_dispatch_s)
                * self.fleet.safety_factor + self.fleet.sched_slack_s)

    def _pick(self, now: float) -> _Tenant | None:
        due = [t for t in self.tenants if len(t.batcher)
               and (self.stop or self.kick
                    or t.batcher.due(now, self._eta_s(t)))]
        if not due:
            return None
        return min(due, key=lambda t: t.batcher.oldest_due_at)

    def _wait_s(self, now: float) -> float | None:
        wakes = [t.batcher.next_due_at(self._eta_s(t))
                 for t in self.tenants if len(t.batcher)]
        if not wakes:
            return None                      # sleep until notified
        return max(1e-4, min(wakes) - now)

    def queued(self) -> int:
        return sum(len(t.batcher) for t in self.tenants)

    def run(self) -> None:
        while True:
            with self.cond:
                while True:
                    now = self.fleet._clock()
                    tenant = self._pick(now)
                    if tenant is not None:
                        batch = tenant.batcher.pop_batch()
                        self.in_flight += len(batch)
                        break
                    if self.stop and self.queued() == 0:
                        return
                    self.cond.wait(self._wait_s(now))
            try:
                self.fleet._dispatch(tenant, batch)
            finally:
                with self.cond:
                    self.in_flight -= len(batch)
                    self.cond.notify_all()


class ClassifierFleet:
    """Router + scheduler over per-tenant serving engines."""

    def __init__(self, specs: list[TenantSpec], *,
                 stats_window: int = STATS_WINDOW,
                 safety_factor: float = 1.5, sched_slack_s: float = 5e-3,
                 warmup: bool = True, autostart: bool = True,
                 clock=time.perf_counter):
        if not specs:
            raise ValueError("a fleet needs at least one tenant")
        names = [s.name for s in specs]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate tenant names: {sorted(names)}")
        self.stats = ServeStats(window=stats_window)
        self.safety_factor = safety_factor
        self.sched_slack_s = sched_slack_s
        self._clock = clock
        self._tenants: dict[str, _Tenant] = {
            s.name: _Tenant(s, stats_window) for s in specs}
        if warmup:
            for t in self._tenants.values():
                t.est_dispatch_s = max(t.engine.warmup(), 1e-4)
                t.last_dispatch_s = t.est_dispatch_s
        by_backend: dict[str, list[_Tenant]] = {}
        for t in self._tenants.values():
            by_backend.setdefault(t.spec.backend, []).append(t)
        self._workers = {b: _BackendWorker(self, b, ts)
                         for b, ts in sorted(by_backend.items())}
        self._worker_of = {t.name: self._workers[t.spec.backend]
                           for t in self._tenants.values()}
        self._uid_lock = threading.Lock()
        self._next_uid = 0
        self.errors: list[str] = []     # dispatch-thread failures, in order
        self._shutdown = False
        self._started = False
        if autostart:
            self.start()

    # -- construction -------------------------------------------------------
    @classmethod
    def from_emit_dir(cls, emit_dir: str | Path,
                      backends: str | dict[str, str] = "swar",
                      max_batch: int = DEFAULT_MAX_BATCH,
                      deadline_ms: float = DEFAULT_DEADLINE_MS,
                      tenants: list[str] | None = None,
                      **kw) -> "ClassifierFleet":
        """Serve every artifact the emit dir's `fleet.json` manifest names.

        `backends` pins execution: one string for the whole fleet, or a
        `{tenant: backend}` map (missing names fall back to `swar`).
        """
        emit_dir = Path(emit_dir)
        rows = load_manifest(emit_dir)
        if tenants is not None:
            known = {r["name"] for r in rows}
            missing = sorted(set(tenants) - known)
            if missing:
                raise KeyError(f"tenants not in manifest: "
                               f"{', '.join(missing)}; available: "
                               f"{', '.join(sorted(known))}")
            rows = [r for r in rows if r["name"] in tenants]
        specs = []
        for row in rows:
            backend = (backends if isinstance(backends, str)
                       else backends.get(row["name"], "swar"))
            program = load_program(emit_dir / row["program"], backend=backend)
            specs.append(TenantSpec(
                name=row["name"], program=program, backend=backend,
                max_batch=max_batch, deadline_ms=deadline_ms,
                dataset=row.get("dataset"), meta=dict(row)))
        return cls(specs, **kw)

    # -- lifecycle ----------------------------------------------------------
    def start(self) -> None:
        if not self._started:
            self._started = True
            for w in self._workers.values():
                w.start()

    def __enter__(self) -> "ClassifierFleet":
        self.start()
        return self

    def __exit__(self, *exc) -> None:
        self.shutdown(drain=exc == (None, None, None))

    @property
    def tenants(self) -> list[str]:
        return sorted(self._tenants)

    def tenant_backend(self, name: str) -> str:
        return self._tenant(name).spec.backend

    def n_features(self, name: str) -> int:
        return self._tenant(name).engine.n_features

    def _tenant(self, name: str) -> _Tenant:
        try:
            return self._tenants[name]
        except KeyError:
            raise KeyError(f"unknown tenant {name!r}; serving: "
                           f"{', '.join(self.tenants)}") from None

    @property
    def pending(self) -> int:
        return sum(w.queued() + w.in_flight for w in self._workers.values())

    # -- request path --------------------------------------------------------
    def submit(self, tenant: str, readings: np.ndarray,
               deadline_ms: float | None = None) -> FleetRequest:
        """Queue one reading for `tenant`; returns a completion handle."""
        t = self._tenant(tenant)
        readings = np.asarray(readings, dtype=np.float64).reshape(-1)
        if readings.shape[0] != t.engine.n_features:
            raise ValueError(f"{tenant}: expected {t.engine.n_features} "
                             f"features, got {readings.shape[0]}")
        if deadline_ms is None:
            deadline_ms = t.spec.deadline_ms
        with self._uid_lock:
            uid = self._next_uid
            self._next_uid += 1
        req = FleetRequest(uid=uid, tenant=tenant, readings=readings,
                           deadline_ms=deadline_ms)
        worker = self._worker_of[tenant]
        with worker.cond:
            if self._shutdown:
                raise RuntimeError("fleet is shut down")
            entry = t.batcher.submit(req, now=self._clock(),
                                     deadline_ms=deadline_ms)
            req._t_submit = entry.t_submit
            worker.cond.notify_all()
        return req

    def classify_stream(self, tenant: str, x: np.ndarray) -> np.ndarray:
        """Bulk path: route a whole `(S, F)` stream straight to the engine."""
        return self._tenant(tenant).engine.classify_stream(x)

    # -- dispatch (worker threads) -------------------------------------------
    def _dispatch(self, tenant: _Tenant, entries: list[QueuedItem]) -> None:
        reqs: list[FleetRequest] = [e.item for e in entries]
        try:
            x = np.stack([r.readings for r in reqs])
            t0 = self._clock()
            labels = tenant.engine.classify_batch(x)
            dt = self._clock() - t0
        except Exception as exc:        # complete exceptionally, never hang
            msg = f"{type(exc).__name__}: {exc}"
            self.errors.append(f"{tenant.name}: {msg}")
            for r in reqs:
                r.error = msg
                r._event.set()
            return
        tenant.est_dispatch_s = 0.7 * tenant.est_dispatch_s + 0.3 * dt
        tenant.last_dispatch_s = dt
        self.stats.record(len(reqs), dt)
        # FleetRequest carries the same completion fields as SensorRequest,
        # so the engine's label/latency/stats attach is reused verbatim
        tenant.engine.complete(reqs, labels)
        for r in reqs:
            self.stats.record_request(r.latency_ms, r.deadline_ms)
            r._event.set()

    # -- drain / shutdown ----------------------------------------------------
    def flush(self, timeout: float | None = 30.0) -> None:
        """Force-dispatch the whole backlog and wait until it is served.

        Waits on queued *and* in-flight work: a request popped by a worker
        just before flush() is called is still awaited (workers notify the
        condition after every dispatch completes).
        """
        deadline = None if timeout is None else self._clock() + timeout
        for w in self._workers.values():
            with w.cond:
                w.kick = True
                w.cond.notify_all()
        try:
            for w in self._workers.values():
                with w.cond:
                    while w.queued() or w.in_flight:
                        left = (None if deadline is None
                                else deadline - self._clock())
                        if left is not None and left <= 0:
                            raise TimeoutError(
                                f"flush: {w.queued()} queued + "
                                f"{w.in_flight} in-flight requests still "
                                f"pending on backend {w.backend}")
                        w.cond.wait(0.05 if left is None
                                    else min(left, 0.05))
        finally:
            for w in self._workers.values():
                with w.cond:
                    w.kick = False

    def shutdown(self, drain: bool = True, timeout: float = 60.0) -> None:
        """Stop dispatch threads; `drain` serves the backlog first."""
        if self._shutdown:
            return
        self._shutdown = True
        for w in self._workers.values():
            with w.cond:
                if not drain:       # cancel the backlog deterministically
                    for t in w.tenants:
                        for batch in t.batcher.drain():
                            for e in batch:
                                e.item.error = "cancelled at shutdown"
                                e.item._event.set()
                w.stop = True
                w.cond.notify_all()
        if self._started:
            for w in self._workers.values():
                w.join(timeout)
                if w.is_alive():
                    raise TimeoutError(f"worker {w.name} did not stop "
                                       f"within {timeout}s")

    # -- observability -------------------------------------------------------
    def stats_summary(self) -> dict:
        """Fleet-wide + per-tenant `ServeStats` summaries."""
        return {
            "fleet": self.stats.summary(),
            "tenants": {
                name: {
                    "backend": t.spec.backend,
                    "max_batch": t.spec.max_batch,
                    "deadline_ms": t.spec.deadline_ms,
                    "dataset": t.spec.dataset,
                    "pending": len(t.batcher),
                    **t.engine.stats.summary(),
                }
                for name, t in sorted(self._tenants.items())
            },
        }
