"""Fleet CLI — serve an emitted fleet over a socket, or replay against it.

    # stand the emit dir up as a network service (hot-reloads fleet.json);
    # --shards N runs N SO_REUSEPORT accept loops, --udp-port adds the
    # connectionless fire-and-forget ingest endpoint
    PYTHONPATH=src python -m repro.serve serve --emit-dir artifacts \
        --port 7341 --shards 2 --udp-port 7342 --replicas 2 \
        --max-queue 4096 --watch

    # replay held-out sensor streams in-process (the classic mode; the
    # bare-flag legacy form `python -m repro.serve --emit-dir ...` still
    # resolves here)
    PYTHONPATH=src python -m repro.serve replay --emit-dir artifacts \
        --replay all --producers 4 --readings 1024 --deadline-ms 100

    # same replay, but through the wire against a running server;
    # --batch N ships N readings per SUBMIT_BATCH frame (protocol v2)
    PYTHONPATH=src python -m repro.serve replay --emit-dir artifacts \
        --connect 127.0.0.1:7341 --replay all --batch 256

    # blast readings at the UDP ingest port, then bound the loss via the
    # server's TCP STATS counters
    PYTHONPATH=src python -m repro.serve firehose --emit-dir artifacts \
        --connect 127.0.0.1:7341 --udp 127.0.0.1:7342 --readings 4096

Both replay modes load every tenant the emit dir's `fleet.json` manifest
names (emitted by `repro.evolve --emit-dir` or `python -m
repro.compile.export`), replay each tenant's held-out test split from N
concurrent producer threads, and print a per-tenant report: throughput,
p50/p99 request latency, SLO violations, admission sheds, and
bit-identity of the served labels against the offline
`CircuitProgram.predict` reference.  **Any label mismatch or dispatch
error exits nonzero on its own**; `--strict` additionally turns SLO
violations and sheds into a nonzero exit — the CI fleet smoke runs
exactly that.
"""
from __future__ import annotations

import argparse
import json
import sys
import threading
from pathlib import Path

import numpy as np

from repro.serve.fleet import (DEFAULT_DEADLINE_MS, DEFAULT_MAX_BATCH,
                               FLEET_BACKENDS, ClassifierFleet)

SUBCOMMANDS = ("serve", "replay", "firehose")


def _add_fleet_args(ap: argparse.ArgumentParser) -> None:
    ap.add_argument("--emit-dir", required=True,
                    help="directory holding fleet.json + program bundles")
    ap.add_argument("--backend", choices=FLEET_BACKENDS, default="swar",
                    help="execution backend for every tenant")
    ap.add_argument("--backends", default=None,
                    help="per-tenant pins, e.g. 'tnn_cardio=pallas,"
                         "tnn_breast_cancer=np' (overrides --backend)")
    ap.add_argument("--max-batch", type=int, default=DEFAULT_MAX_BATCH)
    ap.add_argument("--deadline-ms", type=float, default=DEFAULT_DEADLINE_MS,
                    help="per-request latency budget (SLO)")
    ap.add_argument("--replicas", type=int, default=None,
                    help="engine replicas per tenant (default: manifest "
                         "hint, else 1)")
    ap.add_argument("--max-queue", type=int, default=None,
                    help="admission limit: shed submits beyond this queue "
                         "depth (default: never shed)")
    ap.add_argument("--workers", type=int, default=None,
                    help="process-per-backend dispatch workers: run N "
                         "subprocesses per backend fed over shared-memory "
                         "reading planes (default: dispatch in-process)")
    ap.add_argument("--qos", default=None,
                    help="QoS classes: one of guaranteed|best_effort for "
                         "every tenant, or per-tenant pairs "
                         "'tnn_cardio=guaranteed,tnn_redwine=best_effort'")
    ap.add_argument("--rate-limit", default=None,
                    help="token-bucket admission rate (readings/s): one "
                         "float for every tenant, or per-tenant pairs "
                         "'tnn_cardio=5000'")
    ap.add_argument("--best-effort-backlog", type=int, default=None,
                    help="shed best_effort submissions once their backend's "
                         "total backlog (queued + in flight) reaches this")
    ap.add_argument("--megakernel", action="store_true",
                    help="fused multi-tenant dispatch: every due pallas "
                         "tenant's circuit rides ONE multi-program kernel "
                         "launch per scheduler pass (in-process only; "
                         "non-pallas tenants dispatch normally)")
    ap.add_argument("--block-words", type=int, default=None,
                    help="pallas word-tile width override (per-tenant "
                         "dispatch AND the fused megakernel launch)")
    ap.add_argument("--autoscale", action="store_true",
                    help="grow/shrink replica pools from shed/queue/cost "
                         "pressure (bounds: --min-replicas/--max-replicas)")
    ap.add_argument("--autoscale-interval", type=float, default=1.0,
                    help="seconds between autoscaler rounds")
    ap.add_argument("--min-replicas", type=int, default=None,
                    help="autoscale floor per tenant (default 1)")
    ap.add_argument("--max-replicas", type=int, default=None,
                    help="autoscale ceiling per tenant (default: the "
                         "tenant's initial replica count)")


def _parse_args(argv=None) -> argparse.Namespace:
    argv = list(sys.argv[1:] if argv is None else argv)
    # legacy spelling: `python -m repro.serve --emit-dir ...` == replay
    if argv and argv[0].startswith("-"):
        argv = ["replay"] + argv
    ap = argparse.ArgumentParser(prog="python -m repro.serve",
                                 description=__doc__)
    sub = ap.add_subparsers(dest="command", required=True)

    sp = sub.add_parser("serve", help="serve the fleet over a TCP socket")
    _add_fleet_args(sp)
    sp.add_argument("--host", default="127.0.0.1")
    sp.add_argument("--port", type=int, default=7341)
    sp.add_argument("--shards", type=int, default=1,
                    help="SO_REUSEPORT accept loops (threads); connections "
                         "are kernel-balanced across them")
    sp.add_argument("--udp-port", type=int, default=None,
                    help="also listen for fire-and-forget SUBMIT[_BATCH] "
                         "datagrams on this UDP port")
    sp.add_argument("--watch", action="store_true",
                    help="watch fleet.json and hot-reload tenants")

    rp = sub.add_parser("replay", help="replay held-out streams and verify")
    _add_fleet_args(rp)
    rp.add_argument("--connect", default=None, metavar="HOST:PORT",
                    help="replay through a running server instead of "
                         "in-process")
    rp.add_argument("--replay", default="all",
                    help="comma list of tenant or dataset names (default: "
                         "every tenant with a dataset)")
    rp.add_argument("--producers", type=int, default=4,
                    help="concurrent submitter threads")
    rp.add_argument("--readings", type=int, default=1024,
                    help="readings replayed per tenant")
    rp.add_argument("--batch", type=int, default=1,
                    help="readings per SUBMIT_BATCH frame when replaying "
                         "through --connect (1 = classic per-reading "
                         "SUBMIT frames)")
    rp.add_argument("--seed", type=int, default=0)
    rp.add_argument("--timeout", type=float, default=120.0,
                    help="overall completion timeout (seconds)")
    rp.add_argument("--strict", action="store_true",
                    help="also exit nonzero on any SLO miss or shed "
                         "(mismatches and errors always exit nonzero)")
    rp.add_argument("--out", default=None,
                    help="write the replay report as JSON here")

    fp = sub.add_parser("firehose", help="blast the UDP ingest endpoint and "
                                         "bound the loss via TCP stats")
    _add_fleet_args(fp)
    fp.add_argument("--connect", required=True, metavar="HOST:PORT",
                    help="the server's TCP address (for STATS counters)")
    fp.add_argument("--udp", required=True, metavar="HOST:PORT",
                    help="the server's UDP ingest address")
    fp.add_argument("--replay", default="all",
                    help="comma list of tenant or dataset names")
    fp.add_argument("--readings", type=int, default=4096,
                    help="readings blasted per tenant")
    fp.add_argument("--batch", type=int, default=64,
                    help="readings per SUBMIT_BATCH datagram")
    fp.add_argument("--seed", type=int, default=0)
    fp.add_argument("--timeout", type=float, default=30.0,
                    help="how long to wait for the received count to settle")
    fp.add_argument("--min-frac", type=float, default=0.5,
                    help="exit nonzero when fewer than this fraction of "
                         "blasted readings reached the server (UDP is "
                         "best-effort; loopback should deliver ~all)")
    fp.add_argument("--out", default=None,
                    help="write the firehose report as JSON here")
    return ap.parse_args(argv)


def _parse_backends(args) -> str | dict:
    if not args.backends:
        return args.backend
    backends = {}
    for pair in args.backends.split(","):
        name, _, be = pair.strip().partition("=")
        if be not in FLEET_BACKENDS:
            raise SystemExit(f"bad --backends entry {pair!r}; backends: "
                             f"{', '.join(FLEET_BACKENDS)}")
        backends[name] = be
    return backends


def _scalar_or_map(raw: str | None, cast):
    """Parse 'value' or 'name=value,name=value' CLI spellings."""
    if raw is None:
        return None
    if "=" not in raw:
        return cast(raw)
    out = {}
    for pair in raw.split(","):
        name, _, val = pair.strip().partition("=")
        if not name or not val:
            raise SystemExit(f"bad per-tenant entry {pair!r}; want "
                             f"'tenant=value'")
        out[name] = cast(val)
    return out


def _build_fleet(args, live: bool = True) -> ClassifierFleet:
    """`live=False` builds a reference-only fleet (the --connect client
    path: offline programs + tenant metadata, no warmup jit, no replica
    pools spun hot, no scheduler threads)."""
    from repro.serve.autoscale import AutoscaleConfig

    autoscale = (AutoscaleConfig() if live and getattr(args, "autoscale",
                                                       False) else None)
    return ClassifierFleet.from_emit_dir(
        args.emit_dir, backends=_parse_backends(args),
        max_batch=args.max_batch, deadline_ms=args.deadline_ms,
        replicas=(args.replicas if live else 1), max_queue=args.max_queue,
        qos=_scalar_or_map(getattr(args, "qos", None), str),
        rate_limit_rps=_scalar_or_map(getattr(args, "rate_limit", None),
                                      float),
        min_replicas=getattr(args, "min_replicas", None),
        max_replicas=getattr(args, "max_replicas", None),
        workers=(getattr(args, "workers", None) if live else None),
        best_effort_backlog=getattr(args, "best_effort_backlog", None),
        autoscale=autoscale,
        autoscale_interval_s=getattr(args, "autoscale_interval", 1.0),
        megakernel=(getattr(args, "megakernel", False) if live else False),
        megakernel_block_words=getattr(args, "block_words", None),
        pallas_block_words=getattr(args, "block_words", None),
        warmup=live, autostart=live)


def _build_streams(fleet: ClassifierFleet, selected: list[str],
                   n_readings: int, seed: int) -> dict[str, np.ndarray]:
    from repro.data.tabular import make_dataset

    streams = {}
    for i, name in enumerate(selected):
        dataset = fleet._tenant(name).spec.dataset
        if dataset is None:
            raise SystemExit(f"tenant {name} has no dataset in the "
                             "manifest — nothing to replay against")
        ds = make_dataset(dataset)
        rng = np.random.default_rng(seed + i)
        idx = rng.integers(0, ds.x_test.shape[0], size=n_readings)
        streams[name] = ds.x_test[idx]
    return streams


def _select_tenants(fleet: ClassifierFleet, replay: str) -> list[str]:
    rows = {name: fleet._tenant(name).spec for name in fleet.tenants}
    if replay == "all":
        selected = [n for n, s in rows.items() if s.dataset]
        skipped = [n for n, s in rows.items() if not s.dataset]
        if skipped:
            print(f"[fleet] skipping tenants without a dataset: "
                  f"{', '.join(sorted(skipped))}")
    else:
        want = [w.strip() for w in replay.split(",") if w.strip()]
        selected = [n for n, s in rows.items()
                    if n in want or (s.dataset in want)]
        missing = [w for w in want
                   if not any(n == w or rows[n].dataset == w
                              for n in rows)]
        if missing:
            raise SystemExit(f"--replay names not served by this fleet: "
                             f"{', '.join(missing)}")
    if not selected:
        raise SystemExit("nothing to replay (no tenant with a dataset "
                         "matched --replay)")
    return sorted(selected)


def _interleave(streams: dict[str, np.ndarray], batch: int = 1):
    """(sorted tenant order, [(tenant, start)] interleaved across tenants)
    — so every producer hits every tenant rather than draining them one
    at a time.  With `batch > 1` each task is a chunk start; the submit
    callback owns rows [start, start+batch)."""
    order = sorted(streams)
    tasks = []
    max_len = max(x.shape[0] for x in streams.values())
    for i in range(0, max_len, batch):
        for name in order:
            if i < streams[name].shape[0]:
                tasks.append((name, i))
    return order, tasks


def _run_producers(tasks, producers: int, submit_one, timeout: float) -> None:
    """Drive `submit_one(tenant, row_index)` from N interleaved threads;
    surface producer exceptions instead of hanging the join."""
    errors: list[str] = []

    def produce(worker: int) -> None:
        try:
            for name, i in tasks[worker::producers]:
                submit_one(name, i)
        except Exception as exc:
            errors.append(f"producer {worker}: {type(exc).__name__}: {exc}")

    threads = [threading.Thread(target=produce, args=(w,), daemon=True)
               for w in range(max(1, producers))]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout)
    stuck = [t.name for t in threads if t.is_alive()]
    if stuck:
        raise TimeoutError(f"producers still submitting after {timeout}s: "
                           f"{', '.join(stuck)}")
    if errors:
        raise RuntimeError("; ".join(errors))


def replay_fleet(fleet: ClassifierFleet, streams: dict[str, np.ndarray],
                 producers: int = 4, timeout: float = 120.0) -> dict:
    """Submit every stream row from `producers` interleaved threads; wait;
    verify served labels bit-identical to offline `CircuitProgram.predict`.

    When the fleet has admission control armed (`max_queue`), a shed
    producer honors the `retry_after_ms` hint and resubmits; sheds are
    counted per tenant.
    """
    import time as _time

    from repro.serve.fleet import FleetOverloadError

    order, tasks = _interleave(streams)
    results: dict[str, list] = {n: [None] * streams[n].shape[0]
                                for n in order}
    shed_counts = {n: 0 for n in order}
    shed_lock = threading.Lock()

    def submit_one(name: str, i: int) -> None:
        while True:
            try:
                results[name][i] = fleet.submit(name, streams[name][i])
                return
            except FleetOverloadError as exc:
                with shed_lock:
                    shed_counts[name] += 1
                _time.sleep(min(exc.retry_after_ms, 1000.0) * 1e-3)

    _run_producers(tasks, producers, submit_one, timeout)

    report = {"tenants": {}, "producers": producers, "transport": "inproc"}
    ok = True
    for name in order:
        reqs = results[name]
        for r in reqs:
            r.result(timeout)                 # waits; raises on error
        labels = np.array([r.label for r in reqs], dtype=np.int32)
        prog = fleet._tenant(name).engine.program
        ref = prog.predict(streams[name]).astype(np.int32)
        match = bool((labels == ref).all())
        ok &= match
        misses = sum(r.slo_miss for r in reqs)
        worst = max((r.latency_ms for r in reqs), default=0.0)
        s = fleet._tenant(name).stats.summary()
        report["tenants"][name] = {
            "backend": fleet.tenant_backend(name),
            "replicas": fleet.tenant_replicas(name),
            "dataset": fleet._tenant(name).spec.dataset,
            "readings": len(reqs),
            "labels_match_offline": match,
            "slo_miss": int(misses),
            "n_shed": shed_counts[name],
            "worst_latency_ms": round(worst, 3),
            **s,
        }
    report["fleet"] = fleet.stats.summary()
    if fleet.megakernel:
        report["megakernel"] = fleet.stats_summary().get("megakernel")
    report["errors"] = list(fleet.errors)
    report["labels_match_offline"] = ok
    return report


def replay_client(client, fleet: ClassifierFleet,
                  streams: dict[str, np.ndarray], producers: int = 4,
                  timeout: float = 120.0, batch: int = 1) -> dict:
    """`replay_fleet`, but every reading crosses the socket transport.

    `fleet` here is the *local* reference (offline programs + tenant
    metadata — it may be built with `warmup=False, autostart=False`);
    nothing is submitted to it.  Producers are submit-only so batching,
    not round-trips, sets the pace; `batch > 1` ships chunks of that many
    rows per `SUBMIT_BATCH` frame via `submit_many` (the v2 fast path).
    Sheds are retried in the collection pass with the server's
    `retry_after_ms` hint and counted.
    """
    import time as _time

    from repro.serve.client import FleetShedError

    order, tasks = _interleave(streams, batch)
    results: dict[str, list] = {n: [None] * streams[n].shape[0]
                                for n in order}
    shed_counts = {n: 0 for n in order}

    def submit_one(name: str, s: int) -> None:
        deadline_ms = fleet._tenant(name).spec.deadline_ms
        if batch == 1:
            results[name][s] = client.submit(name, streams[name][s],
                                             deadline_ms=deadline_ms)
        else:
            e = min(s + batch, streams[name].shape[0])
            results[name][s:e] = client.submit_many(
                name, streams[name][s:e], deadline_ms)

    _run_producers(tasks, producers, submit_one, timeout)

    for name in order:          # collect; a shed row backs off and retries
        deadline_ms = fleet._tenant(name).spec.deadline_ms
        for i, pend in enumerate(results[name]):
            while True:
                try:
                    pend.result(timeout)
                except FleetShedError as exc:
                    shed_counts[name] += 1
                    _time.sleep(min(exc.retry_after_ms, 1000.0) * 1e-3)
                    pend = client.submit(name, streams[name][i],
                                         deadline_ms=deadline_ms)
                    continue
                results[name][i] = pend
                break

    server_stats = client.stats()
    report = {"tenants": {}, "producers": producers, "transport": "socket",
              "batch": batch, "protocol_version": client.protocol_version}
    ok = True
    total_miss = total = 0
    for name in order:
        pends = results[name]
        labels = np.array([p.label for p in pends], dtype=np.int32)
        prog = fleet._tenant(name).engine.program
        ref = prog.predict(streams[name]).astype(np.int32)
        match = bool((labels == ref).all())
        ok &= match
        deadline_ms = fleet._tenant(name).spec.deadline_ms
        lat = np.array([p.latency_ms for p in pends])
        misses = int((lat > deadline_ms).sum())
        total_miss += misses
        total += len(pends)
        remote = server_stats["tenants"].get(name, {})
        report["tenants"][name] = {
            "backend": remote.get("backend"),
            "replicas": len(remote.get("replicas", [])) or None,
            "dataset": fleet._tenant(name).spec.dataset,
            "readings": len(pends),
            "labels_match_offline": match,
            "slo_miss": misses,
            "n_shed": shed_counts[name],
            "worst_latency_ms": round(float(lat.max()), 3),
            **{k: remote[k] for k in ("n_readings", "n_batches",
                                      "readings_per_s", "req_p50_ms",
                                      "req_p99_ms", "n_slo_miss")
               if k in remote},
        }
    sf = server_stats["fleet"]
    # gate (n_slo_miss / n_shed) on *this replay's* traffic — the server's
    # lifetime counters may carry misses/sheds from earlier clients; its
    # throughput/latency figures stay as informational context
    report["fleet"] = {
        **sf,
        "n_readings": total,
        "n_slo_miss": total_miss,
        "n_shed": sum(shed_counts.values()),
    }
    report["server_fleet_lifetime"] = sf
    report["errors"] = []
    report["labels_match_offline"] = ok
    return report


def exit_code(report: dict, strict: bool) -> int:
    """1 on any mismatch or dispatch error — strict or not; `strict`
    additionally fails on SLO misses and admission sheds."""
    bad = (not report["labels_match_offline"]) or bool(report["errors"])
    if strict:
        bad = (bad or report["fleet"].get("n_slo_miss", 0) > 0
               or report["fleet"].get("n_shed", 0) > 0
               or any(t.get("n_shed", 0) > 0
                      for t in report["tenants"].values()))
    return 1 if bad else 0


def _print_report(report: dict) -> None:
    for name, row in report["tenants"].items():
        verdict = "ok" if row["labels_match_offline"] else "MISMATCH"
        print(f"[{name}] backend={row['backend']} "
              f"replicas={row.get('replicas')} "
              f"{row['readings']} readings, "
              f"req p50 {row.get('req_p50_ms', 0):.2f} ms "
              f"p99 {row.get('req_p99_ms', 0):.2f} ms, "
              f"slo_miss={row['slo_miss']} "
              f"shed={row.get('n_shed', 0)} labels={verdict}")
    f = report["fleet"]
    print(f"[fleet/{report['transport']}] total {f['n_readings']} readings, "
          f"{f['n_batches']} dispatches, slo_miss={f['n_slo_miss']}, "
          f"shed={f.get('n_shed', 0)}, req p99 {f['req_p99_ms']:.2f} ms")
    if report["errors"]:
        print(f"[fleet] dispatch errors: {report['errors']}")


def _main_serve(args) -> int:
    from repro.serve.server import serve_forever

    fleet = _build_fleet(args)
    serve_forever(fleet, args.host, args.port, shards=args.shards,
                  udp_port=args.udp_port, watch_manifest=args.watch)
    return 0


def _main_firehose(args) -> int:
    import time as _time

    from repro.serve.client import FleetClient, UdpSwarmSender

    fleet = _build_fleet(args, live=False)
    selected = _select_tenants(fleet, args.replay)
    streams = _build_streams(fleet, selected, args.readings, args.seed)
    host, _, port = args.connect.rpartition(":")
    uhost, _, uport = args.udp.rpartition(":")
    with FleetClient(host or "127.0.0.1", int(port)) as client:
        before = client.stats()["transport"]["udp"]
        sender = UdpSwarmSender(uhost or "127.0.0.1", int(uport))
        t0 = _time.perf_counter()
        sent = sum(
            sender.send_many(name, streams[name][s:s + args.batch])
            for name in selected
            for s in range(0, streams[name].shape[0], args.batch))
        send_s = _time.perf_counter() - t0
        sender.close()
        # wait for the received count to stop moving (drain), then read it
        deadline = _time.monotonic() + args.timeout
        last = -1
        while _time.monotonic() < deadline:
            udp = client.stats()["transport"]["udp"]
            got = udp["n_readings"] - before["n_readings"]
            if got >= sent or (got == last and got > 0):
                break
            last = got
            _time.sleep(0.25)
        udp = client.stats()["transport"]["udp"]
    received = udp["n_readings"] - before["n_readings"]
    frac = received / max(1, sent)
    report = {
        "transport": "udp", "tenants": sorted(selected),
        "readings_sent": int(sent), "readings_received": int(received),
        "received_frac": round(frac, 4),
        "send_rate_per_s": round(sent / max(send_s, 1e-9), 1),
        "n_admitted": udp["n_admitted"] - before["n_admitted"],
        "n_shed": udp["n_shed"] - before["n_shed"],
        "n_errors": udp["n_errors"] - before["n_errors"],
    }
    print(f"[firehose] sent {sent} readings "
          f"({report['send_rate_per_s']:.0f}/s), server received "
          f"{received} ({frac:.1%}), admitted {report['n_admitted']}, "
          f"shed {report['n_shed']}, errors {report['n_errors']}")
    if args.out:
        Path(args.out).parent.mkdir(parents=True, exist_ok=True)
        Path(args.out).write_text(json.dumps(report, indent=2,
                                             sort_keys=True) + "\n")
        print(f"wrote {args.out}")
    if frac < args.min_frac:
        print(f"[firehose] FAIL: received fraction {frac:.1%} below "
              f"--min-frac {args.min_frac:.1%}")
        return 1
    return 0


def _main_replay(args) -> int:
    if args.batch > 1 and not args.connect:
        raise SystemExit("--batch frames only exist on the wire; "
                         "pair it with --connect")
    fleet = _build_fleet(args, live=not args.connect)
    client = None
    try:
        selected = _select_tenants(fleet, args.replay)
        streams = _build_streams(fleet, selected, args.readings, args.seed)
        mode = f"socket {args.connect}" if args.connect else "in-process"
        print(f"[fleet] {len(fleet.tenants)} tenant(s) loaded, replaying "
              f"{', '.join(selected)} x {args.readings} readings from "
              f"{args.producers} producers (deadline {args.deadline_ms} ms, "
              f"{mode})")
        if args.connect:
            from repro.serve.client import FleetClient

            host, _, port = args.connect.rpartition(":")
            client = FleetClient(host or "127.0.0.1", int(port))
            report = replay_client(client, fleet, streams,
                                   producers=args.producers,
                                   timeout=args.timeout, batch=args.batch)
        else:
            report = replay_fleet(fleet, streams, producers=args.producers,
                                  timeout=args.timeout)
    finally:
        if client is not None:
            client.close()
        fleet.shutdown(drain=True)

    _print_report(report)
    if args.out:
        Path(args.out).parent.mkdir(parents=True, exist_ok=True)
        Path(args.out).write_text(json.dumps(report, indent=2, sort_keys=True)
                                  + "\n")
        print(f"wrote {args.out}")
    return exit_code(report, args.strict)


def main(argv=None) -> int:
    args = _parse_args(argv)
    if args.command == "serve":
        return _main_serve(args)
    if args.command == "firehose":
        return _main_firehose(args)
    return _main_replay(args)


if __name__ == "__main__":
    sys.exit(main())
