"""Fleet CLI — replay held-out sensor streams against an emitted fleet.

    PYTHONPATH=src python -m repro.serve --emit-dir artifacts \
        --replay all --producers 4 --readings 1024 --deadline-ms 100

Loads every tenant the emit dir's `fleet.json` manifest names (emitted by
`repro.evolve --emit-dir` or `python -m repro.compile.export`), replays
each tenant's held-out test split through the fleet from N concurrent
producer threads, and prints a per-tenant report: throughput, p50/p99
request latency, SLO violations, and bit-identity of the served labels
against the offline `CircuitProgram.predict` reference.  `--strict` turns
any mismatch, SLO violation or dispatch error into a nonzero exit — the CI
fleet smoke runs exactly that.
"""
from __future__ import annotations

import argparse
import json
import sys
import threading
from pathlib import Path

import numpy as np

from repro.serve.fleet import (DEFAULT_DEADLINE_MS, DEFAULT_MAX_BATCH,
                               FLEET_BACKENDS, ClassifierFleet)


def _parse_args(argv=None) -> argparse.Namespace:
    ap = argparse.ArgumentParser(prog="python -m repro.serve",
                                 description=__doc__)
    ap.add_argument("--emit-dir", required=True,
                    help="directory holding fleet.json + program bundles")
    ap.add_argument("--replay", default="all",
                    help="comma list of tenant or dataset names (default: "
                         "every tenant with a dataset)")
    ap.add_argument("--backend", choices=FLEET_BACKENDS, default="swar",
                    help="execution backend for every tenant")
    ap.add_argument("--backends", default=None,
                    help="per-tenant pins, e.g. 'tnn_cardio=pallas,"
                         "tnn_breast_cancer=np' (overrides --backend)")
    ap.add_argument("--max-batch", type=int, default=DEFAULT_MAX_BATCH)
    ap.add_argument("--deadline-ms", type=float, default=DEFAULT_DEADLINE_MS,
                    help="per-request latency budget (SLO)")
    ap.add_argument("--producers", type=int, default=4,
                    help="concurrent submitter threads")
    ap.add_argument("--readings", type=int, default=1024,
                    help="readings replayed per tenant")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--timeout", type=float, default=120.0,
                    help="overall completion timeout (seconds)")
    ap.add_argument("--strict", action="store_true",
                    help="exit nonzero on any mismatch / SLO miss / error")
    ap.add_argument("--out", default=None,
                    help="write the replay report as JSON here")
    return ap.parse_args(argv)


def _build_streams(fleet: ClassifierFleet, selected: list[str],
                   n_readings: int, seed: int) -> dict[str, np.ndarray]:
    from repro.data.tabular import make_dataset

    streams = {}
    for i, name in enumerate(selected):
        dataset = fleet._tenant(name).spec.dataset
        if dataset is None:
            raise SystemExit(f"tenant {name} has no dataset in the "
                             "manifest — nothing to replay against")
        ds = make_dataset(dataset)
        rng = np.random.default_rng(seed + i)
        idx = rng.integers(0, ds.x_test.shape[0], size=n_readings)
        streams[name] = ds.x_test[idx]
    return streams


def _select_tenants(fleet: ClassifierFleet, replay: str) -> list[str]:
    rows = {name: fleet._tenant(name).spec for name in fleet.tenants}
    if replay == "all":
        selected = [n for n, s in rows.items() if s.dataset]
        skipped = [n for n, s in rows.items() if not s.dataset]
        if skipped:
            print(f"[fleet] skipping tenants without a dataset: "
                  f"{', '.join(sorted(skipped))}")
    else:
        want = [w.strip() for w in replay.split(",") if w.strip()]
        selected = [n for n, s in rows.items()
                    if n in want or (s.dataset in want)]
        missing = [w for w in want
                   if not any(n == w or rows[n].dataset == w
                              for n in rows)]
        if missing:
            raise SystemExit(f"--replay names not served by this fleet: "
                             f"{', '.join(missing)}")
    if not selected:
        raise SystemExit("nothing to replay (no tenant with a dataset "
                         "matched --replay)")
    return sorted(selected)


def replay_fleet(fleet: ClassifierFleet, streams: dict[str, np.ndarray],
                 producers: int = 4, timeout: float = 120.0) -> dict:
    """Submit every stream row from `producers` interleaved threads; wait;
    verify served labels bit-identical to offline `CircuitProgram.predict`.
    """
    # interleave across tenants so every producer hits every tenant
    tasks = []
    order = sorted(streams)
    max_len = max(x.shape[0] for x in streams.values())
    for i in range(max_len):
        for name in order:
            if i < streams[name].shape[0]:
                tasks.append((name, i))
    results: dict[str, list] = {n: [None] * streams[n].shape[0]
                                for n in order}
    errors: list[str] = []

    def produce(worker: int) -> None:
        try:
            for name, i in tasks[worker::producers]:
                results[name][i] = fleet.submit(name, streams[name][i])
        except Exception as exc:    # surface instead of hanging the join
            errors.append(f"producer {worker}: {type(exc).__name__}: {exc}")

    threads = [threading.Thread(target=produce, args=(w,), daemon=True)
               for w in range(max(1, producers))]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout)
    stuck = [t.name for t in threads if t.is_alive()]
    if stuck:
        raise TimeoutError(f"producers still submitting after {timeout}s: "
                           f"{', '.join(stuck)}")
    if errors:
        raise RuntimeError("; ".join(errors))

    report = {"tenants": {}, "producers": producers}
    ok = True
    for name in order:
        reqs = results[name]
        for r in reqs:
            r.result(timeout)                 # waits; raises on error
        labels = np.array([r.label for r in reqs], dtype=np.int32)
        prog = fleet._tenant(name).engine.program
        ref = prog.predict(streams[name]).astype(np.int32)
        match = bool((labels == ref).all())
        ok &= match
        misses = sum(r.slo_miss for r in reqs)
        worst = max((r.latency_ms for r in reqs), default=0.0)
        s = fleet._tenant(name).engine.stats.summary()
        report["tenants"][name] = {
            "backend": fleet.tenant_backend(name),
            "dataset": fleet._tenant(name).spec.dataset,
            "readings": len(reqs),
            "labels_match_offline": match,
            "slo_miss": int(misses),
            "worst_latency_ms": round(worst, 3),
            **s,
        }
    report["fleet"] = fleet.stats.summary()
    report["errors"] = list(fleet.errors)
    report["labels_match_offline"] = ok
    return report


def main(argv=None) -> int:
    args = _parse_args(argv)
    backends: str | dict = args.backend
    if args.backends:
        backends = {}
        for pair in args.backends.split(","):
            name, _, be = pair.strip().partition("=")
            if be not in FLEET_BACKENDS:
                raise SystemExit(f"bad --backends entry {pair!r}; backends: "
                                 f"{', '.join(FLEET_BACKENDS)}")
            backends[name] = be
    fleet = ClassifierFleet.from_emit_dir(
        args.emit_dir, backends=backends, max_batch=args.max_batch,
        deadline_ms=args.deadline_ms)
    try:
        selected = _select_tenants(fleet, args.replay)
        streams = _build_streams(fleet, selected, args.readings, args.seed)
        print(f"[fleet] {len(fleet.tenants)} tenant(s) loaded, replaying "
              f"{', '.join(selected)} x {args.readings} readings from "
              f"{args.producers} producers (deadline {args.deadline_ms} ms)")
        report = replay_fleet(fleet, streams, producers=args.producers,
                              timeout=args.timeout)
    finally:
        fleet.shutdown(drain=True)

    for name, row in report["tenants"].items():
        verdict = "ok" if row["labels_match_offline"] else "MISMATCH"
        print(f"[{name}] backend={row['backend']} "
              f"{row['readings']} readings in {row['n_batches']} batches, "
              f"{row['readings_per_s']:.0f} readings/s, req p50 "
              f"{row['req_p50_ms']:.2f} ms p99 {row['req_p99_ms']:.2f} ms, "
              f"slo_miss={row['slo_miss']} labels={verdict}")
    f = report["fleet"]
    print(f"[fleet] total {f['n_readings']} readings, "
          f"{f['n_batches']} dispatches, slo_miss={f['n_slo_miss']}, "
          f"req p99 {f['req_p99_ms']:.2f} ms")
    if report["errors"]:
        print(f"[fleet] dispatch errors: {report['errors']}")
    if args.out:
        Path(args.out).parent.mkdir(parents=True, exist_ok=True)
        Path(args.out).write_text(json.dumps(report, indent=2, sort_keys=True)
                                  + "\n")
        print(f"wrote {args.out}")

    bad = (not report["labels_match_offline"]) or report["errors"]
    if args.strict:
        bad = bad or report["fleet"]["n_slo_miss"] > 0
    return 1 if bad else 0


if __name__ == "__main__":
    sys.exit(main())
