"""Process-per-backend dispatch workers fed by shared-memory reading planes.

The fleet used to run every replica's dispatch inside its own process, so
np/swar/pallas batches all contended on one GIL no matter how many cores
the host had.  This module moves *dispatch only* out of process:

  * the scheduler, admission control, micro-batching and stats stay in
    the fleet process (single-threaded-ish, lock-simple);
  * each backend gets a `WorkerHost` owning N spawned subprocesses, each
    holding its own `CircuitServingEngine` per loaded tenant (its own
    jit cache, its own interpreter — true core parallelism);
  * reading planes cross the process boundary through a ring of
    `multiprocessing.shared_memory` slabs: the fleet writes the gathered
    ``(B, F)`` float64 plane into a slab, ships only the slab *name* and
    shape over a task queue, and the worker writes the ``(B,)`` int32
    label plane back into the same slab — request/response queues carry
    tens of bytes regardless of batch size.

Slab layout: input plane at offset 0 (``B*F*8`` bytes, so the label
region at offset ``B*F*8`` is always 8-aligned), labels directly after.
Slabs are pooled: `acquire` reuses the smallest free slab that fits and
allocates on demand, so the ring grows to peak dispatch concurrency and
no further.  The fleet side owns every slab's lifetime (create + unlink);
workers attach lazily by name and cache the mapping.

Failure model: a worker that dies mid-dispatch fails its in-flight evals
with `WorkerError` (the fleet completes those requests exceptionally,
exactly like an in-process dispatch error) and is respawned with all
tenant programs re-broadcast; the respawned child re-jits lazily on its
next eval.  Timeouts are treated the same way, except the slab a late
worker might still scribble on is quarantined until host close instead
of returning to the ring.

Replies travel over one pipe *per worker*, never a shared queue: a
worker killed mid-write (crash, OOM, terminate) can tear its own frame,
and on a shared channel that one partial write desyncs every other
worker's replies too — the collector would hang on garbage while
perfectly healthy workers keep answering into the void.  With a
single-writer pipe the blast radius is the dead worker alone: its pipe
raises/EOFs, its pendings fail fast, it respawns on a fresh pipe.
"""
from __future__ import annotations

import pickle
import threading
import time
from dataclasses import dataclass, field
from multiprocessing import get_context
from multiprocessing import shared_memory as _shm
from multiprocessing.connection import wait as _wait_ready

import numpy as np

DEFAULT_SLAB_BYTES = 1 << 20
_CTX = get_context("spawn")     # fleet process has threads; fork is unsafe


class WorkerError(RuntimeError):
    """A worker-side dispatch failed (error, death, or timeout)."""


def _attach_slab(name: str) -> _shm.SharedMemory:
    """Attach to a fleet-owned slab without confusing the resource tracker.

    On Python >= 3.13 `track=False` says what we mean: the fleet process
    is the sole owner and unlinks on close.  Older interpreters register
    the attach too — but spawn children share the parent's resource
    tracker process, so that register is a set-add of an already-tracked
    name and harmless; explicitly unregistering here would instead erase
    the *parent's* registration and make its unlink warn.
    """
    try:
        return _shm.SharedMemory(name=name, track=False)
    except TypeError:
        return _shm.SharedMemory(name=name)


def _worker_main(wid: int, n_procs: int, task_q, result_c) -> None:
    """Worker child entry point (module-level: spawn must pickle it).

    Ops arrive as tuples on the dedicated task queue; every op that has a
    `seq` answers on this worker's own result pipe as ``("ack"|"ok"|"err",
    wid, seq, payload)``.  Engines import lazily so an np-only worker
    never pays the jax import.
    """
    from repro.kernels.dispatch import configure_worker_process
    configure_worker_process(n_procs)

    from repro.compile.program import CircuitProgram
    from repro.serve.engine import CircuitServingEngine

    engines: dict[str, CircuitServingEngine] = {}
    slabs: dict[str, _shm.SharedMemory] = {}
    result_c.send(("hello", wid, None, None))
    try:
        while True:
            msg = task_q.get()
            op = msg[0]
            if op == "stop":
                break
            if op == "unload":
                engines.pop(msg[1], None)
                continue
            seq = msg[1]
            try:
                if op == "load":
                    _, _, key, blob = msg
                    spec = pickle.loads(blob)
                    program = CircuitProgram(
                        ir=spec["ir"], thresholds=spec["thresholds"],
                        n_classes=spec["n_classes"], backend=spec["backend"])
                    engines[key] = CircuitServingEngine(
                        program, spec["max_batch"])
                    result_c.send(("ack", wid, seq, None))
                elif op == "warmup":
                    _, _, key = msg
                    dt = engines[key].warmup()
                    result_c.send(("ack", wid, seq, dt))
                elif op == "eval":
                    _, _, key, slab_name, B, F = msg
                    engine = engines.get(key)
                    if engine is None:
                        raise KeyError(f"tenant {key!r} not loaded in "
                                       f"worker {wid}")
                    shm = slabs.get(slab_name)
                    if shm is None:
                        shm = slabs[slab_name] = _attach_slab(slab_name)
                    x = np.ndarray((B, F), dtype=np.float64, buffer=shm.buf)
                    t0 = time.perf_counter()
                    labels = engine.classify_batch(x)
                    dt = time.perf_counter() - t0
                    out = np.ndarray((B,), dtype=np.int32, buffer=shm.buf,
                                     offset=B * F * 8)
                    out[:] = labels
                    del x, out
                    result_c.send(("ok", wid, seq, dt))
                else:
                    raise ValueError(f"unknown worker op {op!r}")
            except Exception as exc:            # noqa: BLE001 — report, don't die
                result_c.send(("err", wid, seq,
                               f"{type(exc).__name__}: {exc}"))
    finally:
        for shm in slabs.values():
            try:
                shm.close()
            except Exception:
                pass


@dataclass
class _Slab:
    shm: _shm.SharedMemory
    capacity: int

    @property
    def name(self) -> str:
        return self.shm.name


class SlabRing:
    """Fleet-owned pool of shared-memory slabs, grown to peak concurrency."""

    def __init__(self, default_bytes: int = DEFAULT_SLAB_BYTES):
        self._lock = threading.Lock()
        self._free: list[_Slab] = []
        self._all: list[_Slab] = []
        self._default = int(default_bytes)
        self._closed = False

    def acquire(self, nbytes: int) -> _Slab:
        with self._lock:
            if self._closed:
                raise WorkerError("slab ring is closed")
            fits = [s for s in self._free if s.capacity >= nbytes]
            if fits:
                slab = min(fits, key=lambda s: s.capacity)
                self._free.remove(slab)
                return slab
            slab = _Slab(_shm.SharedMemory(
                create=True, size=max(nbytes, self._default)),
                capacity=max(nbytes, self._default))
            self._all.append(slab)
            return slab

    def release(self, slab: _Slab) -> None:
        with self._lock:
            if not self._closed:
                self._free.append(slab)

    def quarantine(self, slab: _Slab) -> None:
        """Never reuse `slab` (a timed-out worker may still write to it)."""
        # it stays in `_all`, so close() still unlinks it

    def summary(self) -> dict:
        with self._lock:
            return {"n_slabs": len(self._all),
                    "n_free": len(self._free),
                    "bytes": sum(s.capacity for s in self._all)}

    def close(self) -> None:
        with self._lock:
            self._closed = True
            slabs, self._all, self._free = self._all, [], []
        for slab in slabs:
            try:
                slab.shm.close()
                slab.shm.unlink()
            except Exception:
                pass


@dataclass
class _Pending:
    event: threading.Event
    wid: int
    slot: dict = field(default_factory=dict)


class _Proc:
    def __init__(self, wid: int, n_procs: int):
        self.wid = wid
        self.task_q = _CTX.Queue()
        # single writer per pipe: this worker's death can only tear its
        # own reply channel, never another worker's
        self.result_r, result_w = _CTX.Pipe(duplex=False)
        self.process = _CTX.Process(
            target=_worker_main, args=(wid, n_procs, self.task_q, result_w),
            daemon=True)
        self.outstanding = 0
        self.failed = False     # reply pipe tore; reap even if still alive
        self.process.start()
        result_w.close()        # child holds the only writer: EOF = death

    def destroy(self) -> None:
        try:
            if self.process.is_alive():
                self.process.terminate()
        except Exception:
            pass
        try:
            self.result_r.close()
        except Exception:
            pass


class WorkerHost:
    """N spawned dispatch workers for one backend + the slab ring feeding them.

    Thread-safe: the fleet's per-backend executor threads call `eval`
    concurrently; one collector thread multiplexes the per-worker result
    pipes, completes pending calls, and respawns dead workers.
    """

    def __init__(self, backend: str, n_procs: int, *,
                 slab_bytes: int = DEFAULT_SLAB_BYTES,
                 start_timeout_s: float = 60.0,
                 load_timeout_s: float = 60.0,
                 eval_timeout_s: float = 180.0):
        if n_procs < 1:
            raise ValueError("worker host needs at least one process")
        self.backend = backend
        self.n_procs = n_procs
        self.eval_timeout_s = eval_timeout_s
        self.load_timeout_s = load_timeout_s
        self._start_timeout_s = start_timeout_s
        self._ring = SlabRing(slab_bytes)
        self._lock = threading.Lock()
        self._seq = 0
        self._pending: dict[int, _Pending] = {}
        self._tenants: dict[str, bytes] = {}    # key -> pickled load payload
        self._procs: list[_Proc] = []
        self._closing = False
        self.n_evals = 0
        self.n_errors = 0
        self.n_respawns = 0
        self._collector: threading.Thread | None = None

    # -- lifecycle ---------------------------------------------------------

    def start(self) -> None:
        self._procs = [_Proc(i, self.n_procs)
                       for i in range(self.n_procs)]
        self._collector = threading.Thread(
            target=self._collect, name=f"workers-{self.backend}", daemon=True)
        self._collector.start()
        deadline = time.monotonic() + self._start_timeout_s
        for p in self._procs:
            if not p.process.is_alive() and time.monotonic() > deadline:
                raise WorkerError(f"worker {p.wid} failed to start")

    def close(self) -> None:
        with self._lock:
            self._closing = True
            pending = list(self._pending.values())
            self._pending.clear()
        for ctx in pending:
            ctx.slot["err"] = "worker host closed"
            ctx.event.set()
        for p in self._procs:
            try:
                p.task_q.put(("stop",))
            except Exception:
                pass
        for p in self._procs:
            p.process.join(timeout=10.0)
            if p.process.is_alive():
                p.process.kill()
                p.process.join(timeout=5.0)
            p.task_q.close()
        if self._collector is not None:
            self._collector.join(timeout=5.0)
        for p in self._procs:
            try:
                p.result_r.close()
            except Exception:
                pass
        self._ring.close()

    # -- control plane -----------------------------------------------------

    @staticmethod
    def _payload(program, max_batch: int) -> bytes:
        return pickle.dumps({
            "ir": program.ir, "thresholds": program.thresholds,
            "n_classes": program.n_classes, "backend": program.backend,
            "max_batch": int(max_batch)})

    def _next_seq(self) -> int:
        self._seq += 1
        return self._seq

    def _broadcast(self, builder, timeout_s: float, what: str) -> list:
        """Send one op per proc, wait for every ack, return payloads."""
        waits = []
        with self._lock:
            if self._closing:
                raise WorkerError("worker host closed")
            for p in self._procs:
                seq = self._next_seq()
                ctx = _Pending(threading.Event(), p.wid)
                self._pending[seq] = ctx
                p.outstanding += 1
                waits.append((p, seq, ctx))
        for p, seq, ctx in waits:
            p.task_q.put(builder(seq))
        out = []
        for p, seq, ctx in waits:
            if not ctx.event.wait(timeout_s):
                with self._lock:
                    self._pending.pop(seq, None)
                raise WorkerError(f"{what} timed out on worker {p.wid} "
                                  f"({self.backend})")
            if "err" in ctx.slot:
                raise WorkerError(f"{what} failed on worker {p.wid}: "
                                  f"{ctx.slot['err']}")
            out.append(ctx.slot.get("ok"))
        return out

    def load(self, key: str, program, max_batch: int) -> None:
        """Broadcast a tenant's program to every worker (waits for acks)."""
        blob = self._payload(program, max_batch)
        self._tenants[key] = blob
        self._broadcast(lambda seq: ("load", seq, key, blob),
                        self.load_timeout_s, f"load {key!r}")

    def unload(self, key: str) -> None:
        self._tenants.pop(key, None)
        with self._lock:
            if self._closing:
                return
            procs = list(self._procs)
        for p in procs:
            try:
                p.task_q.put(("unload", key))
            except Exception:
                pass

    def warmup(self, key: str, timeout_s: float = 300.0) -> float:
        """Warm every worker's engine for `key`; slowest warm dispatch wins."""
        dts = self._broadcast(lambda seq: ("warmup", seq, key),
                              timeout_s, f"warmup {key!r}")
        return max(float(d) for d in dts)

    # -- data plane --------------------------------------------------------

    def eval(self, key: str, x: np.ndarray) -> np.ndarray:
        """Classify one gathered (B, F) plane on the least-busy worker."""
        x = np.ascontiguousarray(x, dtype=np.float64)
        B, F = x.shape
        need = B * F * 8 + B * 4
        slab = self._ring.acquire(need)
        timed_out = False
        try:
            np.ndarray((B, F), dtype=np.float64,
                       buffer=slab.shm.buf)[:] = x
            with self._lock:
                if self._closing:
                    raise WorkerError("worker host closed")
                proc = min(self._procs, key=lambda p: (p.outstanding, p.wid))
                seq = self._next_seq()
                ctx = _Pending(threading.Event(), proc.wid)
                self._pending[seq] = ctx
                proc.outstanding += 1
                self.n_evals += 1
            proc.task_q.put(("eval", seq, key, slab.name, B, F))
            if not ctx.event.wait(self.eval_timeout_s):
                timed_out = True
                with self._lock:
                    self._pending.pop(seq, None)
                    self.n_errors += 1
                raise WorkerError(
                    f"eval timed out after {self.eval_timeout_s:.0f}s on "
                    f"worker {proc.wid} ({self.backend})")
            if "err" in ctx.slot:
                with self._lock:
                    self.n_errors += 1
                raise WorkerError(ctx.slot["err"])
            return np.array(np.ndarray((B,), dtype=np.int32,
                                       buffer=slab.shm.buf, offset=B * F * 8))
        finally:
            if timed_out:
                self._ring.quarantine(slab)
            else:
                self._ring.release(slab)

    # -- collector ---------------------------------------------------------

    def _collect(self) -> None:
        while True:
            with self._lock:
                if self._closing and not self._pending:
                    return
                conns = {p.result_r: p for p in self._procs if not p.failed}
            try:
                ready = _wait_ready(list(conns), timeout=0.25)
            except OSError:
                ready = []
            if not ready:
                if self._closing:
                    continue            # re-check pending under the lock
                self._check_procs()
                continue
            for c in ready:
                p = conns[c]
                try:
                    kind, wid, seq, payload = c.recv()
                except Exception:       # noqa: BLE001 — EOF or torn frame
                    p.failed = True     # reap + respawn on the next pass
                    continue
                if kind == "hello" or seq is None:
                    continue
                with self._lock:
                    ctx = self._pending.pop(seq, None)
                    if p.outstanding > 0:
                        p.outstanding -= 1
                if ctx is None:
                    continue            # timed out / host closing
                if kind == "err":
                    ctx.slot["err"] = payload
                else:
                    ctx.slot["ok"] = payload
                ctx.event.set()

    def _check_procs(self) -> None:
        """Fail pendings of dead workers and respawn them, tenants intact."""
        with self._lock:
            if self._closing:
                return
            dead = [i for i, p in enumerate(self._procs)
                    if p.failed or not p.process.is_alive()]
            if not dead:
                return
            orphans: list[_Pending] = []
            for i in dead:
                wid = self._procs[i].wid
                self._procs[i].destroy()
                mine = [self._pending.pop(s)
                        for s, c in list(self._pending.items())
                        if c.wid == wid]
                orphans.extend(mine)
                self.n_respawns += 1
                self.n_errors += len(mine)
                self._procs[i] = _Proc(wid, self.n_procs)
                for key, blob in self._tenants.items():
                    seq = self._next_seq()
                    # nobody waits on the reload ack; bookkeeping only
                    self._pending[seq] = _Pending(threading.Event(), wid)
                    self._procs[i].outstanding += 1
                    self._procs[i].task_q.put(("load", seq, key, blob))
        for ctx in orphans:
            ctx.slot["err"] = f"worker {ctx.wid} ({self.backend}) died " \
                              f"mid-dispatch"
            ctx.event.set()

    def summary(self) -> dict:
        with self._lock:
            procs = [{"wid": p.wid, "pid": p.process.pid,
                      "alive": p.process.is_alive(),
                      "outstanding": p.outstanding} for p in self._procs]
        return {"backend": self.backend, "n_procs": self.n_procs,
                "n_evals": self.n_evals, "n_errors": self.n_errors,
                "n_respawns": self.n_respawns,
                "tenants": sorted(self._tenants),
                "slabs": self._ring.summary(), "procs": procs}
