"""Per-tenant engine replica pools: least-loaded pick, device round-robin.

One fleet tenant used to be exactly one `CircuitServingEngine`, so a hot
tenant's dispatches serialized on a single engine no matter how many
devices the host had.  A `ReplicaPool` runs N engines over the *same*
compiled classifier behind the tenant's one micro-batch queue: the fleet
scheduler acquires the least-loaded idle replica for each due batch, so
two due batches of the same tenant overlap on different replicas (each
pinned to its own local device via `kernels.dispatch.replica_devices` —
the word-axis sharding in `program_eval_words` is the intra-dispatch half
of that story, this pool is the inter-dispatch half).

The pick policy is pure bookkeeping with no threads or clocks in it —
`acquire`/`release` mutate integer counters under whatever lock the
caller already holds (the fleet holds its scheduler condition) — which is
what lets the hypothesis suite drive arbitrary acquire/release schedules
through the exact production code and pin the invariants:

  * **work conserving** — `acquire` refuses only when *every* replica is
    busy; an idle replica is always handed out;
  * **least-loaded** — among idle replicas the one with the fewest total
    dispatched readings wins (index breaks ties), so sustained load
    spreads over the whole pool and no replica starves;
  * **conservation** — readings handed out equal readings accounted *for
    dispatches that succeeded*: `release` takes the outcome and credits a
    failed dispatch's readings back, so a replica whose dispatches error
    does not look permanently loaded and least-loaded routing keeps it in
    healthy rotation; `inflight` returns to zero once every dispatch is
    released.

The pool is also elastic: the autoscaler appends replicas with `grow`
and retires idle ones with `shrink_idle` under the same caller-held
lock, so pool size changes are just more bookkeeping on the identical
pick policy.
"""
from __future__ import annotations

from dataclasses import dataclass, field

from repro.serve.engine import STATS_WINDOW, CircuitServingEngine


def make_replica(program, index: int, max_batch: int,
                 stats_window: int = STATS_WINDOW) -> "EngineReplica":
    """One fresh replica of `program` pinned to device slot `index`.

    Shared by `ReplicaPool.from_program` (initial sizing) and the fleet's
    autoscaler (incremental growth), so grown replicas get the identical
    clone + device round-robin treatment as boot-time ones.
    """
    from repro.compile.program import CircuitProgram

    devices = None
    if program.backend != "np":
        from repro.kernels.dispatch import replica_devices
        devices = replica_devices(index)
    prog = CircuitProgram(ir=program.ir, thresholds=program.thresholds,
                          n_classes=program.n_classes,
                          backend=program.backend, devices=devices)
    return EngineReplica(
        index=index,
        engine=CircuitServingEngine(prog, max_batch,
                                    stats_window=stats_window),
        devices=devices)


@dataclass
class EngineReplica:
    """One engine of a tenant's pool + its scheduling counters."""

    index: int
    engine: CircuitServingEngine
    devices: tuple | None = None
    inflight: int = 0            # dispatches currently executing
    n_dispatches: int = 0        # total batches handed to this replica
    n_readings: int = 0          # total readings handed to this replica
    n_errors: int = 0            # dispatches that ended in an error
    meta: dict = field(default_factory=dict)

    @property
    def busy(self) -> bool:
        return self.inflight > 0

    def summary(self) -> dict:
        return {
            "index": self.index,
            "devices": [str(d) for d in (self.devices or ())],
            "inflight": self.inflight,
            "n_dispatches": self.n_dispatches,
            "n_readings": self.n_readings,
            "n_errors": self.n_errors,
            **{k: self.engine.stats.summary()[k]
               for k in ("busy_s", "readings_per_s", "p50_ms", "p99_ms")},
        }


class ReplicaPool:
    """Least-loaded routing over N replicas of one compiled classifier."""

    def __init__(self, replicas: list[EngineReplica]):
        if not replicas:
            raise ValueError("a replica pool needs at least one replica")
        self.replicas = list(replicas)

    @classmethod
    def from_program(cls, program, n_replicas: int, max_batch: int,
                     stats_window: int = STATS_WINDOW) -> "ReplicaPool":
        """Clone `program` into `n_replicas` engines, one per device slot.

        Device backends (`swar`/`pallas` and the historical `jax` alias)
        pin replica i to local device ``i % n_devices``; the `np`
        reference backend has no device placement, so replicas share the
        host and only the overlap (one GIL-releasing jit-free dispatch per
        replica thread) remains.
        """
        if n_replicas < 1:
            raise ValueError("n_replicas must be >= 1")
        return cls([make_replica(program, i, max_batch,
                                 stats_window=stats_window)
                    for i in range(n_replicas)])

    @property
    def size(self) -> int:
        return len(self.replicas)

    def idle(self) -> bool:
        return all(r.inflight == 0 for r in self.replicas)

    def has_idle(self) -> bool:
        return any(r.inflight == 0 for r in self.replicas)

    @property
    def total_inflight(self) -> int:
        return sum(r.inflight for r in self.replicas)

    def acquire(self, n_readings: int = 0) -> EngineReplica | None:
        """Claim the least-loaded idle replica for a batch of `n_readings`.

        Returns None iff every replica is mid-dispatch (the scheduler then
        leaves the batch queued and retries when a release notifies it).
        Load is total readings ever handed out — not inflight count — so
        ties from identical batch sizes rotate deterministically by index.
        """
        idle = [r for r in self.replicas if r.inflight == 0]
        if not idle:
            return None
        pick = min(idle, key=lambda r: (r.n_readings, r.index))
        pick.inflight += 1
        pick.n_dispatches += 1
        pick.n_readings += n_readings
        return pick

    def release(self, replica: EngineReplica, n_readings: int = 0,
                ok: bool = True) -> None:
        """Return a replica after its dispatch, reconciling the outcome.

        A failed dispatch did no useful work: its `n_readings` charge
        (made optimistically at `acquire` time) is credited back so the
        least-loaded pick keeps routing *to* — not away from — a replica
        that errored, instead of treating the failure as served load.
        """
        if replica.inflight <= 0:
            raise ValueError(f"replica {replica.index} released while idle")
        replica.inflight -= 1
        if not ok:
            replica.n_errors += 1
            replica.n_readings -= min(int(n_readings), replica.n_readings)

    def grow(self, replica: EngineReplica) -> EngineReplica:
        """Append an autoscaler-built replica (caller holds the lock)."""
        self.replicas.append(replica)
        return replica

    def next_index(self) -> int:
        """Device-slot index for the next grown replica.

        Indices stay monotonic across shrink/grow cycles so device
        pinning never doubles up with a still-live replica's slot.
        """
        return max(r.index for r in self.replicas) + 1

    def shrink_idle(self) -> EngineReplica | None:
        """Retire one idle replica (highest index first), if any.

        Returns None — and the pool is untouched — when every replica is
        mid-dispatch or the pool is already at one replica; the caller
        (autoscaler tick) just retries on a later round.
        """
        if len(self.replicas) <= 1:
            return None
        idle = [r for r in self.replicas if r.inflight == 0]
        if not idle:
            return None
        drop = max(idle, key=lambda r: r.index)
        self.replicas.remove(drop)
        return drop

    def summary(self) -> list[dict]:
        return [r.summary() for r in self.replicas]
