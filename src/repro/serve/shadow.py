"""Shadow-deployment comparator: incumbent vs candidate on mirrored traffic.

A shadow replica serves a *copy* of every admitted reading (the fleet
mirrors traffic in `fleet.submit`/`submit_many` — see
`ClassifierFleet.deploy_shadow`), and this object is where the two sides
meet: each mirrored request is paired with its primary by the primary's
uid, and when both labels have landed the pair is scored —

  * **bit-exactness** — do incumbent and shadow agree on the label?
  * **accuracy** — when the traffic source knows the ground truth
    (`attach_truth`), which side classified it correctly?  An *improved*
    candidate legitimately disagrees with the incumbent, so agreement
    alone cannot justify a promotion — accuracy deltas can.
  * **latency** — shadow-minus-incumbent request latency, kept in a
    bounded ring so a slow candidate shows up before it is promoted into
    the serving path.

Everything here is passive bookkeeping fed by completion callbacks from
the fleet's dispatch threads; the comparator never blocks a request and
mirrored traffic never touches the incumbent's own `ServeStats` (pinned
by tests/test_autopilot.py).  `summary()` is the JSON-able snapshot the
STATS RPC surfaces and the autopilot journals before deciding — the
promotion policy itself lives in `repro.autopilot.controller.decide`,
a pure function of that snapshot, which is what makes a killed
controller resume from its journal to the same decision.
"""
from __future__ import annotations

import threading
from collections import OrderedDict

from repro.serve.engine import STATS_WINDOW, _Ring

# closed pairs kept around for late-arriving ground truth (the traffic
# generator attaches truth after submit() returns, which can lose the race
# with a fast dispatch); bounded so an unlabeled stream can't grow it
_CLOSED_KEEP = 4 * STATS_WINDOW


class ShadowComparator:
    """Pairs mirrored completions with their primaries and keeps score."""

    def __init__(self, incumbent: str, shadow: str,
                 window: int = STATS_WINDOW):
        self.incumbent = incumbent
        self.shadow = shadow
        self.n_mirrored = 0          # mirror requests actually enqueued
        self.n_dropped = 0           # mirrors dropped (queue cap/retiring)
        self.n_pairs = 0             # both sides completed
        self.n_agree = 0             # ... with identical labels
        self.n_primary_errors = 0
        self.n_shadow_errors = 0
        self.n_truth = 0             # scored pairs with ground truth
        self.n_incumbent_correct = 0
        self.n_shadow_correct = 0
        self.delta_ms = _Ring(window)        # shadow - incumbent latency
        self.incumbent_ms = _Ring(window)
        self.shadow_ms = _Ring(window)
        self._open: dict[int, dict] = {}     # primary uid -> half a pair
        self._truth: dict[int, int] = {}     # uid -> label, pre-close
        self._closed: OrderedDict[int, tuple] = OrderedDict()
        self._lock = threading.Lock()

    # -- feeding (fleet callbacks + traffic generator) -----------------------
    def expect(self, uid: int) -> None:
        """A mirror for primary `uid` was enqueued; a pair will form."""
        with self._lock:
            self.n_mirrored += 1
            self._open.setdefault(uid, {})

    def record_dropped(self, n: int = 1) -> None:
        with self._lock:
            self.n_dropped += n

    def attach_truth(self, uid: int, label: int) -> None:
        """Ground truth for primary `uid` (optional; enables accuracy)."""
        with self._lock:
            if uid in self._closed:
                self._score_truth(label, *self._closed.pop(uid))
            else:
                self._truth[uid] = int(label)

    def observe_primary(self, req) -> None:
        self._observe(req.uid, "primary", req)

    def observe_shadow(self, uid: int, req) -> None:
        self._observe(uid, "shadow", req)

    def _observe(self, uid: int, side: str, req) -> None:
        with self._lock:
            pair = self._open.get(uid)
            if pair is None or side in pair:
                return
            pair[side] = (req.label, req.latency_ms, req.error)
            if len(pair) == 2:
                del self._open[uid]
                self._close(uid, pair)

    # -- scoring (caller holds the lock) -------------------------------------
    def _close(self, uid: int, pair: dict) -> None:
        (p_label, p_lat, p_err) = pair["primary"]
        (s_label, s_lat, s_err) = pair["shadow"]
        if p_err is not None:
            self.n_primary_errors += 1
        if s_err is not None:
            self.n_shadow_errors += 1
        if p_err is not None or s_err is not None:
            self._truth.pop(uid, None)
            return
        self.n_pairs += 1
        if p_label == s_label:
            self.n_agree += 1
        if p_lat is not None and s_lat is not None:
            self.delta_ms.push(s_lat - p_lat)
            self.incumbent_ms.push(p_lat)
            self.shadow_ms.push(s_lat)
        truth = self._truth.pop(uid, None)
        if truth is not None:
            self._score_truth(truth, p_label, s_label)
        else:
            self._closed[uid] = (p_label, s_label)
            while len(self._closed) > _CLOSED_KEEP:
                self._closed.popitem(last=False)

    def _score_truth(self, truth: int, p_label: int, s_label: int) -> None:
        self.n_truth += 1
        self.n_incumbent_correct += int(p_label == truth)
        self.n_shadow_correct += int(s_label == truth)

    # -- reading -------------------------------------------------------------
    @property
    def agreement(self) -> float:
        return self.n_agree / self.n_pairs if self.n_pairs else 0.0

    def summary(self) -> dict:
        """JSON-able snapshot — the STATS payload and the journaled
        evidence the promotion decision is computed from."""
        with self._lock:
            n = self.n_pairs
            return {
                "incumbent": self.incumbent,
                "shadow": self.shadow,
                "n_mirrored": self.n_mirrored,
                "n_dropped": self.n_dropped,
                "n_pairs": n,
                "n_agree": self.n_agree,
                "agreement": round(self.n_agree / n, 6) if n else 0.0,
                "n_primary_errors": self.n_primary_errors,
                "n_shadow_errors": self.n_shadow_errors,
                "n_truth": self.n_truth,
                "incumbent_accuracy": (
                    round(self.n_incumbent_correct / self.n_truth, 6)
                    if self.n_truth else None),
                "shadow_accuracy": (
                    round(self.n_shadow_correct / self.n_truth, 6)
                    if self.n_truth else None),
                "latency_delta_p50_ms": round(self.delta_ms.percentile(50), 4),
                "latency_delta_p99_ms": round(self.delta_ms.percentile(99), 4),
                "incumbent_p50_ms": round(self.incumbent_ms.percentile(50), 4),
                "shadow_p50_ms": round(self.shadow_ms.percentile(50), 4),
            }
