"""repro.serve — the unified multi-tenant sensor-serving stack.

One package now holds every serving layer: the batched execution engine
(`engine.py`, formerly `repro.serving.circuit_engine`), per-tenant engine
**replica pools** with least-loaded routing and per-replica device pins
(`replicas.py`), the fleet router with deadline-driven micro-batching,
queue-depth **admission control** and manifest **hot-reload**
(`fleet.py` + `batcher.py`), the fleet controller — QoS classes,
per-tenant token-bucket rate limits, and a hysteresis replica
autoscaler (`autoscale.py`) — **process-per-backend dispatch workers**
fed over shared-memory reading planes (`workers.py`), and a real
network front: a length-prefixed binary wire protocol with
version-negotiated batch frames (`protocol.py`), a sharded asyncio
socket server with optional connectionless UDP ingest (`server.py`)
and a blocking client library with batched submits and client-side
coalescing (`client.py`).

In-process:

    from repro.serve import ClassifierFleet
    fleet = ClassifierFleet.from_emit_dir("artifacts", backends="swar",
                                          replicas=2, max_queue=2048)
    req = fleet.submit("tnn_cardio", reading)      # returns immediately
    label = req.result(timeout=1.0)                # blocks until served
    reqs, shed, retry_ms = fleet.submit_many("tnn_cardio", plane)  # batched
    fleet.shutdown(drain=True)

Over the wire:

    python -m repro.serve serve --emit-dir artifacts --port 7341 \
        --shards 2 --udp-port 7342                                 # server
    python -m repro.serve replay --emit-dir artifacts \
        --connect 127.0.0.1:7341 --batch 256                       # client

    from repro.serve.client import FleetClient
    with FleetClient("127.0.0.1", 7341) as c:
        label = c.submit("tnn_cardio", reading).result(timeout=1.0)
        labels = c.classify("tnn_cardio", plane)   # SUBMIT_BATCH frames
"""
from repro.serve.autoscale import (
    QOS_CLASSES,
    Autoscaler,
    AutoscaleConfig,
    TenantSignals,
    TokenBucket,
)
from repro.serve.batcher import MicroBatcher, QueuedItem
from repro.serve.engine import (
    STATS_WINDOW,
    CircuitServingEngine,
    SensorRequest,
    ServeStats,
)
from repro.serve.fleet import (
    DEFAULT_DEADLINE_MS,
    DEFAULT_MAX_BATCH,
    FLEET_BACKENDS,
    ClassifierFleet,
    FleetOverloadError,
    FleetRequest,
    TenantSpec,
)
from repro.serve.replicas import EngineReplica, ReplicaPool
from repro.serve.workers import WorkerError, WorkerHost

__all__ = [
    "DEFAULT_DEADLINE_MS",
    "DEFAULT_MAX_BATCH",
    "FLEET_BACKENDS",
    "QOS_CLASSES",
    "STATS_WINDOW",
    "Autoscaler",
    "AutoscaleConfig",
    "CircuitServingEngine",
    "ClassifierFleet",
    "EngineReplica",
    "FleetOverloadError",
    "FleetRequest",
    "MicroBatcher",
    "QueuedItem",
    "ReplicaPool",
    "SensorRequest",
    "ServeStats",
    "TenantSignals",
    "TenantSpec",
    "TokenBucket",
    "WorkerError",
    "WorkerHost",
]
