"""repro.serve — multi-tenant sensor-serving fleet.

Loads every classifier artifact an emit directory's `fleet.json` manifest
names into per-tenant `CircuitServingEngine`s behind one router, replaces
manual `flush()` with a deadline-driven micro-batching scheduler (flush on
`max_batch` *or* when the oldest queued request would outlive its latency
budget), runs one background dispatch thread per execution backend
(`np`/`swar`/`pallas` via `kernels.dispatch`), and tracks per-tenant +
fleet-wide throughput / p50/p99 latency / SLO violations.

    from repro.serve import ClassifierFleet
    fleet = ClassifierFleet.from_emit_dir("artifacts", backends="swar")
    req = fleet.submit("tnn_cardio", reading)      # returns immediately
    label = req.result(timeout=1.0)                # blocks until served
    fleet.shutdown(drain=True)

CLI replay of held-out test streams:  python -m repro.serve --emit-dir ...
"""
from repro.serve.batcher import MicroBatcher, QueuedItem
from repro.serve.fleet import (
    DEFAULT_DEADLINE_MS,
    DEFAULT_MAX_BATCH,
    FLEET_BACKENDS,
    ClassifierFleet,
    FleetRequest,
    TenantSpec,
)

__all__ = [
    "DEFAULT_DEADLINE_MS",
    "DEFAULT_MAX_BATCH",
    "FLEET_BACKENDS",
    "ClassifierFleet",
    "FleetRequest",
    "MicroBatcher",
    "QueuedItem",
    "TenantSpec",
]
