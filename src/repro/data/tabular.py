"""Synthetic stand-ins for the paper's five UCI datasets (offline container).

Each dataset preserves the UCI feature/class dimensionality used in the
paper's Table 2 and a class structure (Gaussian class prototypes + noise +
uninformative features) whose difficulty is tuned so the exact-TNN accuracy
lands in the paper's reported band.  Inputs are normalized to [0, 1] exactly
as the paper does before ABC threshold fitting.  Deterministic in `seed`.
"""
from __future__ import annotations

import hashlib
from dataclasses import dataclass

import numpy as np


@dataclass(frozen=True)
class DatasetSpec:
    name: str
    n_features: int
    n_classes: int
    n_samples: int
    separation: float        # class-prototype separation (difficulty knob)
    informative_frac: float  # fraction of features that carry signal
    major_prior: float       # majority-class prior (UCI sets are imbalanced;
                             # e.g. arrhythmia's majority class is ~54%)
    topology: tuple[int, int, int]      # paper's TNN topology (in, hidden, out)
    mlp_topology: tuple[int, int, int]  # paper's baseline MLP topology
    paper_tnn_acc: float     # Table 2 "Our Exact TNN" accuracy (reference)
    paper_mlp_acc: float     # Table 2 "Exact MLP [37]" accuracy (reference)


# Table 2 of the paper. separation/informative tuned for comparable accuracy.
DATASETS: dict[str, DatasetSpec] = {
    "arrhythmia": DatasetSpec("arrhythmia", 274, 16, 452 * 4, 0.55, 0.25, 0.54,
                              (274, 3, 16), (274, 5, 16), 0.60, 0.62),
    "breast_cancer": DatasetSpec("breast_cancer", 10, 2, 699 * 2, 15.0, 0.9, 0.65,
                                 (10, 10, 2), (10, 3, 2), 0.98, 0.98),
    "cardio": DatasetSpec("cardio", 21, 3, 2126, 2.1, 0.7, 0.58,
                          (21, 3, 3), (21, 3, 3), 0.85, 0.88),
    "redwine": DatasetSpec("redwine", 11, 6, 1599, 1.7, 0.7, 0.43,
                           (11, 3, 6), (11, 2, 6), 0.56, 0.56),
    "whitewine": DatasetSpec("whitewine", 11, 7, 2449, 0.9, 0.7, 0.45,
                             (11, 11, 7), (11, 4, 7), 0.50, 0.54),
}


@dataclass
class TabularDataset:
    name: str
    x_train: np.ndarray   # (N, F) float32 in [0, 1]
    y_train: np.ndarray   # (N,) int32
    x_test: np.ndarray
    y_test: np.ndarray
    spec: DatasetSpec


def make_dataset(name: str, seed: int = 0) -> TabularDataset:
    """Seeded synthetic dataset with the UCI dims; 70/30 split (paper's)."""
    spec = DATASETS[name]
    # stable across processes (python's str hash is salted per-process)
    digest = hashlib.sha256(f"{name}:{seed}".encode()).digest()
    rng = np.random.default_rng(int.from_bytes(digest[:8], "little"))
    F, C, N = spec.n_features, spec.n_classes, spec.n_samples

    n_inf = max(1, int(round(spec.informative_frac * F)))
    # class prototypes are BIT patterns: the signal is threshold-recoverable,
    # matching sensor data where the paper's 1-bit ABC inputs lose little
    # information vs a 4-bit ADC (otherwise the TNN-vs-MLP comparison of
    # Table 2 is unfaithful — multi-bit inputs would dominate on Gaussians).
    if C > 8:
        # many-class sets (arrhythmia): low-rank prototypes — XOR mixes of
        # few base patterns, so narrow TNN hidden layers can capture them
        # (real UCI arrhythmia behaves this way: few latent factors)
        k = 4
        basis = rng.random((k, n_inf)) < 0.5
        codes = (np.arange(C)[:, None] >> np.arange(k)[None, :]) & 1
        protos = (codes @ basis.astype(np.int64)) % 2 == 1
    else:
        protos = (rng.random((C, n_inf)) < 0.5)
    flip_p = 0.5 / (1.0 + spec.separation)
    # deterministic geometric class priors hitting the target majority
    # fraction (real UCI tabular data is strongly imbalanced)
    if C == 1:
        priors = np.ones(1)
    else:
        lo_r, hi_r = 1e-6, 1.0 - 1e-6

        def maj_of(r):
            w = r ** np.arange(C)
            return w[0] / w.sum()

        for _ in range(60):   # bisection on the decay ratio
            mid = 0.5 * (lo_r + hi_r)
            if maj_of(mid) > spec.major_prior:
                lo_r = mid
            else:
                hi_r = mid
        w = (0.5 * (lo_r + hi_r)) ** np.arange(C)
        priors = w / w.sum()
    y = rng.choice(C, size=N, p=priors).astype(np.int32)

    x = rng.normal(0.0, 1.0, size=(N, F))          # uninformative background
    flips = rng.random((N, n_inf)) < flip_p
    bits = protos[y] ^ flips
    x[:, :n_inf] = (0.3 + 0.4 * bits
                    + rng.normal(0.0, 0.10, size=(N, n_inf))) * 2.5 - 1.25
    # a nonlinear interaction feature to give hidden neurons work to do
    if n_inf >= 2:
        x[:, 0] += 0.4 * np.where(bits[:, 1], 1.0, -1.0) * (y % 2 * 2 - 1)

    # normalize to [0, 1] (paper Sec. 3.2.1)
    lo, hi = x.min(axis=0, keepdims=True), x.max(axis=0, keepdims=True)
    x = (x - lo) / np.maximum(hi - lo, 1e-9)

    n_train = int(0.7 * N)
    perm = rng.permutation(N)
    tr, te = perm[:n_train], perm[n_train:]
    return TabularDataset(name, x[tr].astype(np.float32), y[tr],
                          x[te].astype(np.float32), y[te], spec)
