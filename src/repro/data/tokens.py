"""Deterministic, stateless, resumable synthetic token pipeline.

Fault-tolerance property the train loop relies on: batch(step) is a pure
function of (seed, step, shape) — a restarted/elastically-resized job
regenerates exactly the token stream it would have seen, with no iterator
state to checkpoint.  Sharded hosts slice their rows of the same global
batch (host i takes rows [i*per_host, (i+1)*per_host)).

The stream is a Zipf-ish unigram mix with induced bigram structure so small
LMs have something learnable (examples/train_lm.py reaches well below the
uniform-entropy floor within a few hundred steps).
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np


@dataclass(frozen=True)
class TokenPipelineConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    zipf_a: float = 1.2          # unigram skew
    bigram_period: int = 16      # deterministic bigram structure strength


def _unigram_logits(cfg: TokenPipelineConfig) -> np.ndarray:
    ranks = np.arange(1, cfg.vocab + 1, dtype=np.float64)
    p = 1.0 / np.power(ranks, cfg.zipf_a)
    return np.log(p / p.sum()).astype(np.float32)


class TokenPipeline:
    def __init__(self, cfg: TokenPipelineConfig):
        self.cfg = cfg
        self._logits = jnp.asarray(_unigram_logits(cfg))

    def batch_at(self, step: int | jax.Array) -> dict:
        """Global batch for `step`: {"tokens", "labels"} (B, S) int32."""
        cfg = self.cfg
        key = jax.random.fold_in(jax.random.PRNGKey(cfg.seed), step)
        draw = jax.random.categorical(
            key, self._logits, shape=(cfg.global_batch, cfg.seq_len + 1))
        # induce learnable bigram structure: every k-th token repeats a
        # deterministic function of its predecessor
        prev = jnp.roll(draw, 1, axis=1)
        idx = jnp.arange(cfg.seq_len + 1)[None, :]
        use_bigram = (idx % cfg.bigram_period) == (cfg.bigram_period - 1)
        mapped = (prev * 31 + 7) % cfg.vocab
        seq = jnp.where(use_bigram, mapped, draw).astype(jnp.int32)
        return {"tokens": seq[:, :-1], "labels": seq[:, 1:]}

    def host_batch_at(self, step: int, host_id: int, n_hosts: int) -> dict:
        full = self.batch_at(step)
        per = self.cfg.global_batch // n_hosts
        sl = slice(host_id * per, (host_id + 1) * per)
        return {k: v[sl] for k, v in full.items()}
