from repro.data.tabular import DATASETS, TabularDataset, make_dataset  # noqa: F401
from repro.data.tokens import TokenPipeline, TokenPipelineConfig  # noqa: F401
