"""Sensor-stream classification serving on compiled circuit programs.

The on-sensor counterpart of the token engine in `serving/engine.py`: there
is no decode loop — every request is one sensor reading classified in a
single circuit pass — so the engine's entire job is batching.  Queued
readings are gathered in arrival order into fixed-shape padded batches
(`max_batch` rows, so the jitted SWAR program compiles exactly one shape),
dispatched as one bit-packed evaluation, and the labels are scattered back
with per-request latency.  At 32 readings per machine word a single
dispatch of a `max_batch=1024` engine costs ~32 word-ops per gate, which is
what lets a software model of a 5 Hz printed circuit serve readings at
MHz-equivalent rates.

`classify_stream` is the bulk path (one numpy array in, labels out);
`submit`/`flush` is the request-queue path with per-request bookkeeping.
Both feed the same `ServeStats` (readings/s + batch latency percentiles).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field

import numpy as np

from repro.compile.program import CircuitProgram


@dataclass
class SensorRequest:
    uid: int
    readings: np.ndarray            # (F,) raw sensor values
    label: int | None = None
    latency_ms: float | None = None  # submit -> label
    _t_submit: float = 0.0


@dataclass
class ServeStats:
    n_readings: int = 0
    n_batches: int = 0
    busy_s: float = 0.0              # time spent inside dispatches
    batch_ms: list[float] = field(default_factory=list)

    def record(self, n: int, dt_s: float) -> None:
        self.n_readings += n
        self.n_batches += 1
        self.busy_s += dt_s
        self.batch_ms.append(dt_s * 1e3)

    @property
    def readings_per_s(self) -> float:
        return self.n_readings / self.busy_s if self.busy_s > 0 else 0.0

    def percentile_ms(self, q: float) -> float:
        return float(np.percentile(self.batch_ms, q)) if self.batch_ms else 0.0

    def summary(self) -> dict:
        return {
            "n_readings": self.n_readings,
            "n_batches": self.n_batches,
            "busy_s": round(self.busy_s, 6),
            "readings_per_s": round(self.readings_per_s, 1),
            "p50_ms": round(self.percentile_ms(50), 4),
            "p99_ms": round(self.percentile_ms(99), 4),
        }


class CircuitServingEngine:
    """Batched request->label serving over one compiled classifier."""

    def __init__(self, program: CircuitProgram, max_batch: int = 1024):
        if program.n_classes is None:
            raise ValueError("engine needs a classifier program "
                             "(CircuitProgram.from_classifier)")
        if max_batch < 1:
            raise ValueError("max_batch must be >= 1")
        self.program = program
        self.max_batch = max_batch
        self.stats = ServeStats()
        self._queue: list[SensorRequest] = []
        self._next_uid = 0

    @property
    def n_features(self) -> int:
        return self.program.ir.n_inputs

    def warmup(self) -> None:
        """Trigger jit compilation of the fixed batch shape (not counted)."""
        dummy = np.zeros((self.max_batch, self.n_features), dtype=np.float64)
        if self.program.thresholds is not None:
            self.program.predict(dummy)
        else:
            self.program.predict_bits(dummy.astype(np.uint8))

    # -- request-queue path -------------------------------------------------
    def submit(self, readings: np.ndarray) -> SensorRequest:
        readings = np.asarray(readings, dtype=np.float64).reshape(-1)
        if readings.shape[0] != self.n_features:
            raise ValueError(f"expected {self.n_features} features, "
                             f"got {readings.shape[0]}")
        req = SensorRequest(self._next_uid, readings,
                            _t_submit=time.perf_counter())
        self._next_uid += 1
        self._queue.append(req)
        return req

    @property
    def pending(self) -> int:
        return len(self._queue)

    def flush(self) -> list[SensorRequest]:
        """Drain the queue in arrival order; returns the completed requests."""
        done: list[SensorRequest] = []
        while self._queue:
            group = self._queue[: self.max_batch]
            del self._queue[: len(group)]
            x = np.stack([r.readings for r in group])
            labels = self._dispatch(x)
            t_done = time.perf_counter()
            for r, lbl in zip(group, labels):
                r.label = int(lbl)
                r.latency_ms = (t_done - r._t_submit) * 1e3
            done.extend(group)
        return done

    # -- bulk path ----------------------------------------------------------
    def classify_stream(self, x: np.ndarray) -> np.ndarray:
        """Classify `(S, F)` readings in max_batch chunks; returns `(S,)`."""
        x = np.asarray(x)
        if x.ndim != 2 or x.shape[1] != self.n_features:
            raise ValueError(f"expected (S, {self.n_features}) readings, "
                             f"got {x.shape}")
        out = np.empty(x.shape[0], dtype=np.int32)
        for s in range(0, x.shape[0], self.max_batch):
            chunk = x[s: s + self.max_batch]
            out[s: s + chunk.shape[0]] = self._dispatch(chunk)
        return out

    def _dispatch(self, x: np.ndarray) -> np.ndarray:
        """One padded fixed-shape batch through the program (timed)."""
        B = x.shape[0]
        if B < self.max_batch:      # pad to the compiled shape
            pad = np.zeros((self.max_batch - B, x.shape[1]), dtype=x.dtype)
            x = np.concatenate([x, pad], axis=0)
        t0 = time.perf_counter()
        labels = (self.program.predict(x) if self.program.thresholds is not None
                  else self.program.predict_bits(x.astype(np.uint8)))
        dt = time.perf_counter() - t0
        self.stats.record(B, dt)
        return labels[:B]
