"""Deprecated shim — the circuit serving engine now lives in `repro.serve`.

The serving layers were unified under `repro.serve` (engine + replica
pools + fleet router + wire transport); import from `repro.serve.engine`
(or `repro.serve` directly).  This module re-exports the old names so
pre-unification callers keep working.
"""
from repro.serve.engine import (  # noqa: F401
    STATS_WINDOW,
    CircuitServingEngine,
    SensorRequest,
    ServeStats,
)

__all__ = ["STATS_WINDOW", "CircuitServingEngine", "SensorRequest",
           "ServeStats"]
