"""Fault-tolerant training loop.

Large-scale runnability features (DESIGN.md §6):
  * resume      — restores the latest checkpoint; the token pipeline is
    stateless in (seed, step) so the data stream continues exactly;
  * preemption  — SIGTERM/SIGINT triggers a synchronous checkpoint before
    exit (cluster evictions lose at most the in-flight step);
  * stragglers  — per-step wall time is monitored; steps slower than
    `straggler_factor` x the running median are logged with their step id
    (on real fleets this feeds the scheduler's replace/restart policy);
  * periodic checkpoints with retention, optional background writes;
  * microbatching — gradient accumulation over `microbatches` chunks
    (scan), so the 256-seq global batches fit memory;
  * gradient compression — optional int8 error-feedback (DP all-reduce
    traffic 4x down vs f32).
"""
from __future__ import annotations

import signal
import time
from dataclasses import dataclass, field
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.checkpoint import CheckpointManager
from repro.models import transformer as TF
from repro.models.sharding import ShardCtx
from repro.optim import adamw, adamw8bit
from repro.optim.grad_compress import compress_grads


@dataclass
class TrainLoopConfig:
    total_steps: int = 100
    microbatches: int = 1
    ckpt_every: int = 50
    log_every: int = 10
    keep_ckpts: int = 3
    straggler_factor: float = 3.0
    grad_compress: bool = False
    background_ckpt: bool = False
    optimizer: adamw.AdamWConfig = field(default_factory=adamw.AdamWConfig)


def make_train_step(cfg: ModelConfig, loop_cfg: TrainLoopConfig,
                    ctx: ShardCtx | None = None) -> Callable:
    """Builds the (jit-able) train_step(params, opt_state, batch) function.

    Gradient accumulation scans over microbatches; the optimizer is AdamW
    (f32 or int8 moments per cfg.opt_8bit); optional int8 error-feedback
    gradient compression sits between accumulation and the update.
    """
    opt_mod = adamw8bit if cfg.opt_8bit else adamw
    ocfg = loop_cfg.optimizer

    def grads_of(params, batch):
        (loss, metrics), grads = jax.value_and_grad(
            lambda p: TF.loss_fn(cfg, p, batch, ctx), has_aux=True)(params)
        return grads, metrics

    def train_step(params, opt_state, batch, err_buf=None):
        n_mb = loop_cfg.microbatches
        if n_mb > 1:
            B = batch["tokens"].shape[0]
            assert B % n_mb == 0, (B, n_mb)
            mb = jax.tree.map(
                lambda x: x.reshape(n_mb, B // n_mb, *x.shape[1:]), batch)

            acc_dt = {"float32": jnp.float32,
                      "bfloat16": jnp.bfloat16}[cfg.accum_dtype]

            def acc_body(carry, mbatch):
                gacc, nll_acc, tok_acc = carry
                g, met = grads_of(params, mbatch)
                gacc = jax.tree.map(lambda a, b: a + b.astype(acc_dt), gacc, g)
                return (gacc, nll_acc + met["nll"], tok_acc + met["tokens"]), None

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, acc_dt), params)
            (gsum, nll, ntok), _ = jax.lax.scan(
                acc_body, (zeros, jnp.zeros(()), jnp.zeros(())), mb)
            grads = jax.tree.map(lambda g: g / n_mb, gsum)
            metrics = {"loss": nll / jnp.maximum(ntok, 1.0),
                       "nll": nll, "tokens": ntok,
                       "moe_aux": jnp.zeros(())}
        else:
            grads, metrics = grads_of(params, batch)

        if loop_cfg.grad_compress and err_buf is not None:
            grads, err_buf = compress_grads(grads, err_buf)

        params, opt_state = opt_mod.apply_updates(params, grads, opt_state, ocfg)
        return params, opt_state, metrics, err_buf

    return train_step


@dataclass
class StepStats:
    times: list = field(default_factory=list)
    stragglers: list = field(default_factory=list)

    def record(self, step: int, dt: float, factor: float) -> bool:
        self.times.append(dt)
        med = float(np.median(self.times[-50:]))
        slow = len(self.times) > 5 and dt > factor * med
        if slow:
            self.stragglers.append((step, dt, med))
        return slow


class Trainer:
    """Orchestrates train_step + checkpointing + fault handling."""

    def __init__(self, cfg: ModelConfig, loop_cfg: TrainLoopConfig,
                 pipeline, ckpt_dir: str, ctx: ShardCtx | None = None):
        self.cfg = cfg
        self.loop_cfg = loop_cfg
        self.pipeline = pipeline
        self.ctx = ctx
        self.ckpt = CheckpointManager(ckpt_dir, keep=loop_cfg.keep_ckpts)
        self.stats = StepStats()
        self._preempted = False
        self.train_step = jax.jit(
            make_train_step(cfg, loop_cfg, ctx),
            donate_argnums=(0, 1)) if loop_cfg.grad_compress is False else \
            jax.jit(make_train_step(cfg, loop_cfg, ctx))

    def _install_signal_handlers(self):
        def handler(signum, frame):
            self._preempted = True
        for sig in (signal.SIGTERM, signal.SIGINT):
            try:
                signal.signal(sig, handler)
            except ValueError:
                pass   # non-main thread (tests)

    def run(self, params, opt_state, start_step: int = 0, err_buf=None,
            log: Callable[[str], None] = print):
        self._install_signal_handlers()
        lc = self.loop_cfg
        step = start_step
        losses = []
        while step < lc.total_steps:
            t0 = time.monotonic()
            batch = self.pipeline.batch_at(step)
            params, opt_state, metrics, err_buf = self.train_step(
                params, opt_state, batch, err_buf)
            jax.block_until_ready(metrics["loss"])
            dt = time.monotonic() - t0
            if self.stats.record(step, dt, lc.straggler_factor):
                log(f"[straggler] step {step}: {dt:.2f}s "
                    f"(median {np.median(self.stats.times[-50:]):.2f}s)")
            losses.append(float(metrics["loss"]))
            step += 1
            if step % lc.log_every == 0:
                log(f"step {step}: loss={losses[-1]:.4f} ({dt:.2f}s/step)")
            if step % lc.ckpt_every == 0 or step == lc.total_steps:
                self.ckpt.save(step, {"params": params, "opt": opt_state},
                               extra={"loss": losses[-1]},
                               background=lc.background_ckpt)
            if self._preempted:
                log(f"[preempt] checkpointing at step {step} and exiting")
                self.ckpt.wait()
                self.ckpt.save(step, {"params": params, "opt": opt_state},
                               extra={"loss": losses[-1], "preempted": True})
                break
        self.ckpt.wait()
        return params, opt_state, {"losses": losses,
                                   "stragglers": self.stats.stragglers,
                                   "last_step": step}

    def resume_or_init(self, init_fn: Callable[[], tuple]):
        """Restore latest checkpoint if present, else initialize fresh."""
        latest = self.ckpt.latest_step()
        if latest is None:
            params, opt_state = init_fn()
            return params, opt_state, 0
        params0, opt0 = init_fn()
        step, state, _ = self.ckpt.restore({"params": params0, "opt": opt0})
        return state["params"], state["opt"], step
