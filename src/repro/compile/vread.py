"""Structural-Verilog netlist reader — the independent RTL check.

Parses the subset `repro.compile.verilog` emits (ANSI scalar ports, `wire`
declarations, single-gate `assign` expressions over ~ & | ^ with explicit
parentheses, named-port module instantiations) and re-evaluates the design
bit-parallel in numpy, 64 vectors per uint64 word.  This closes the loop on
the Verilog backend: the emitted RTL is executed by a *separate* evaluator
that never sees the IR, and must reproduce the compiled `CircuitProgram`
bit-for-bit (tests pin >= 10k random vectors per Table-2 dataset).

The evaluator is deliberately strict rather than general: statements must
appear in dependency order (the emitter's levelized order guarantees it),
every referenced signal must be declared, and mixing binary operators
without parentheses is a parse error.  Anything outside the subset raises
`VerilogError` instead of guessing.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field

import numpy as np

from repro.core import circuits as C

_FULL = np.uint64(0xFFFFFFFFFFFFFFFF)

_KEYWORDS = {"module", "endmodule", "input", "output", "wire", "assign"}
_TOKEN_RE = re.compile(
    r"\s+|(?P<comment>//[^\n]*)|(?P<const>1'b[01])"
    r"|(?P<name>[A-Za-z_][A-Za-z0-9_$]*)|(?P<punc>[~&|^();,.=])")


class VerilogError(ValueError):
    pass


def _tokenize(text: str) -> list[str]:
    toks, pos = [], 0
    while pos < len(text):
        m = _TOKEN_RE.match(text, pos)
        if m is None:
            raise VerilogError(f"bad character at offset {pos}: "
                               f"{text[pos:pos + 20]!r}")
        pos = m.end()
        if m.lastgroup in ("const", "name", "punc"):
            toks.append(m.group())
    return toks


# expression AST: ("const", 0|1) | ("sig", name) | ("not", e) | ("bin", op, l, r)
@dataclass
class VModule:
    name: str
    ports: list[tuple[str, str]]             # (direction, name) in header order
    wires: set[str] = field(default_factory=set)
    stmts: list[tuple] = field(default_factory=list)
    # ("assign", lhs, expr) | ("inst", module, instance, {port: signal})

    @property
    def inputs(self) -> list[str]:
        return [n for d, n in self.ports if d == "input"]

    @property
    def outputs(self) -> list[str]:
        return [n for d, n in self.ports if d == "output"]


class _Parser:
    def __init__(self, toks: list[str]):
        self.toks = toks
        self.i = 0

    def peek(self) -> str | None:
        return self.toks[self.i] if self.i < len(self.toks) else None

    def next(self) -> str:
        if self.i >= len(self.toks):
            raise VerilogError("unexpected end of file")
        self.i += 1
        return self.toks[self.i - 1]

    def expect(self, tok: str) -> None:
        got = self.next()
        if got != tok:
            raise VerilogError(f"expected {tok!r}, got {got!r}")

    def name(self) -> str:
        tok = self.next()
        if tok in _KEYWORDS or not re.fullmatch(r"[A-Za-z_][A-Za-z0-9_$]*", tok):
            raise VerilogError(f"expected identifier, got {tok!r}")
        return tok

    # -- modules -----------------------------------------------------------
    def parse_design(self) -> dict[str, VModule]:
        mods: dict[str, VModule] = {}
        while self.peek() is not None:
            self.expect("module")
            mod = self.parse_module()
            if mod.name in mods:
                raise VerilogError(f"duplicate module {mod.name!r}")
            mods[mod.name] = mod
        return mods

    def parse_module(self) -> VModule:
        name = self.name()
        self.expect("(")
        ports: list[tuple[str, str]] = []
        direction = None
        while True:
            tok = self.peek()
            if tok in ("input", "output"):
                direction = self.next()
                tok = self.peek()
            if direction is None:
                raise VerilogError("port without direction")
            ports.append((direction, self.name()))
            if self.peek() == ",":
                self.next()
                continue
            self.expect(")")
            break
        self.expect(";")
        mod = VModule(name, ports)
        declared = {n for _, n in ports}
        while True:
            tok = self.next()
            if tok == "endmodule":
                return mod
            if tok == "wire":
                while True:
                    w = self.name()
                    if w in declared:
                        raise VerilogError(f"redeclared signal {w!r}")
                    declared.add(w)
                    mod.wires.add(w)
                    if self.peek() == ",":
                        self.next()
                        continue
                    self.expect(";")
                    break
            elif tok == "assign":
                lhs = self.name()
                if lhs not in declared:
                    raise VerilogError(f"assign to undeclared signal {lhs!r}")
                self.expect("=")
                expr = self.parse_expr()
                self.expect(";")
                mod.stmts.append(("assign", lhs, expr))
            elif tok not in _KEYWORDS:  # instantiation: MODULE instance (...)
                inst = self.name()
                self.expect("(")
                conns: dict[str, str] = {}
                while True:
                    self.expect(".")
                    port = self.name()
                    self.expect("(")
                    sig = self.name()
                    self.expect(")")
                    if port in conns:
                        raise VerilogError(f"duplicate port {port!r} on {inst!r}")
                    conns[port] = sig
                    if self.peek() == ",":
                        self.next()
                        continue
                    self.expect(")")
                    break
                self.expect(";")
                mod.stmts.append(("inst", tok, inst, conns))
            else:
                raise VerilogError(f"unexpected token {tok!r} in module body")

    # -- expressions -------------------------------------------------------
    def parse_expr(self) -> tuple:
        node = self.parse_unary()
        op = None
        while self.peek() in ("&", "|", "^"):
            tok = self.next()
            if op is not None and tok != op:
                raise VerilogError("mixed binary operators without parentheses")
            op = tok
            node = ("bin", op, node, self.parse_unary())
        return node

    def parse_unary(self) -> tuple:
        tok = self.peek()
        if tok == "~":
            self.next()
            return ("not", self.parse_unary())
        if tok == "(":
            self.next()
            node = self.parse_expr()
            self.expect(")")
            return node
        if tok in ("1'b0", "1'b1"):
            self.next()
            return ("const", int(tok[-1]))
        return ("sig", self.name())


@dataclass
class VerilogDesign:
    """A parsed design: bit-parallel re-evaluation of emitted RTL."""

    modules: dict[str, VModule]

    @classmethod
    def parse(cls, text: str) -> "VerilogDesign":
        return cls(_Parser(_tokenize(text)).parse_design())

    def module(self, name: str) -> VModule:
        if name not in self.modules:
            raise VerilogError(f"no module {name!r}")
        return self.modules[name]

    def evaluate(self, top: str, inputs: dict[str, np.ndarray]
                 ) -> dict[str, np.ndarray]:
        """Evaluate `top` on packed uint64 word arrays, one per input port.

        Returns {output port: (W,) uint64 words}.  Statements are evaluated
        in file order; reading a signal before it is driven is an error.
        """
        mod = self.module(top)
        env: dict[str, np.ndarray] = {}
        shape = None
        for port in mod.inputs:
            if port not in inputs:
                raise VerilogError(f"missing value for input port {port!r}")
            env[port] = np.asarray(inputs[port], dtype=np.uint64)
            if shape is None:
                shape = env[port].shape
        if shape is None:  # input-less module (constant circuit)
            shape = (1,)

        def read(sig: str) -> np.ndarray:
            if sig not in env:
                raise VerilogError(f"signal {sig!r} read before it is driven "
                                   f"(in {mod.name!r})")
            return env[sig]

        def ev(expr: tuple) -> np.ndarray:
            kind = expr[0]
            if kind == "const":
                return np.full(shape, _FULL if expr[1] else np.uint64(0),
                               dtype=np.uint64)
            if kind == "sig":
                return read(expr[1])
            if kind == "not":
                return ~ev(expr[1])
            _, op, lhs, rhs = expr
            a, b = ev(lhs), ev(rhs)
            return a & b if op == "&" else a | b if op == "|" else a ^ b

        for stmt in mod.stmts:
            if stmt[0] == "assign":
                _, lhs, expr = stmt
                if lhs in env:
                    raise VerilogError(f"signal {lhs!r} driven twice")
                env[lhs] = ev(expr)
            else:
                _, sub_name, inst, conns = stmt
                sub = self.module(sub_name)
                sub_in = {p: read(conns[p]) for p in sub.inputs if p in conns}
                missing = [p for p in sub.inputs if p not in conns]
                if missing:
                    raise VerilogError(f"instance {inst!r} leaves inputs "
                                       f"{missing} unconnected")
                out = self.evaluate(sub_name, sub_in)
                for p in sub.outputs:
                    if p not in conns:
                        continue
                    if conns[p] in env:
                        raise VerilogError(f"signal {conns[p]!r} driven twice")
                    env[conns[p]] = out[p]
        return {p: read(p) for p in mod.outputs}

    def eval_uint(self, top: str, xbits: np.ndarray,
                  input_prefix: str = "x") -> np.ndarray:
        """`(S, n)` 0/1 matrix -> `(S,)` int64 decoded module outputs.

        Input port `<prefix>{i}` takes column i; output ports are decoded
        LSB-first in header order (y0/k0 is bit 0) — the same convention as
        `Netlist.eval_uint`, so results compare directly.
        """
        xbits = np.asarray(xbits)
        S = xbits.shape[0]
        packed = C.pack_vectors(xbits.astype(np.uint8))   # (n, W)
        mod = self.module(top)
        inputs = {}
        for port in mod.inputs:
            if not port.startswith(input_prefix):
                raise VerilogError(f"input port {port!r} lacks prefix "
                                   f"{input_prefix!r}")
            inputs[port] = packed[int(port[len(input_prefix):])]
        out = self.evaluate(top, inputs)
        words = np.stack([out[p] for p in mod.outputs])    # (n_out, W)
        return C._decode_words(words[None])[0][:S]


def eval_classifier_verilog(text_or_design: str | VerilogDesign,
                            xbits: np.ndarray,
                            top: str = "tnn_classifier") -> np.ndarray:
    """Binarized readings `(S, F)` -> class labels via the emitted RTL."""
    design = (text_or_design if isinstance(text_or_design, VerilogDesign)
              else VerilogDesign.parse(text_or_design))
    return design.eval_uint(top, xbits).astype(np.int32)
