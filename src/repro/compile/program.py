"""Device backend — `CircuitProgram`: batched bit-packed circuit execution.

Executes a lowered `CircuitIR` for thousands of sensor readings per
dispatch.  Two interchangeable, bit-identical backends:

  * ``jax`` (default) — the jitted uint32-SWAR evaluator from
    `kernels.circuit_sim` (one `lax.scan` over levelized gate columns), the
    path the serving engine runs on;
  * ``np`` — the uint64 `Netlist.simulate` reference, used for
    cross-checking and as a dependency-free fallback.

Readings are packed 32/64-per-word along the batch axis, so one dispatch
costs O(n_gates * ceil(S/32)) word ops regardless of feature count or
class count.  For classifier programs (`from_classifier`) the circuit's
own argmax plane produces the class index — `predict` is end-to-end
(raw sensor floats -> ABC comparators -> gates -> label) and bit-identical
to `repro.core.tnn.predict_with_circuits`.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from repro.core import circuits as C
from repro.compile.ir import CircuitIR, CompiledClassifier, lower_netlist


BACKENDS = ("jax", "np", "swar", "pallas")


@dataclass
class CircuitProgram:
    """An executable compiled circuit (optionally a full classifier).

    `backend` picks the executor: ``np`` is the uint64 `Netlist` reference;
    ``swar`` (alias ``jax``, the historical name) and ``pallas`` route
    through `kernels.dispatch.program_eval_words`, which shards large
    batches along the packed-word axis across `devices` (default: all
    local devices).
    """

    ir: CircuitIR
    thresholds: np.ndarray | None = None   # (F,) ABC V_q — classifier only
    n_classes: int | None = None
    backend: str = "jax"
    devices: tuple | None = None
    # Pallas tuning knobs (word-tile width / interpret-mode override);
    # forwarded to the kernel on the pallas backend, ignored elsewhere so
    # configs can set them unconditionally
    pallas_block_words: int | None = None
    pallas_interpret: bool | None = None
    _netlist: C.Netlist | None = field(default=None, repr=False)
    _jax_plan: tuple | None = field(default=None, repr=False)

    def __post_init__(self):
        if self.backend not in BACKENDS:
            raise ValueError(f"unknown backend {self.backend!r}; "
                             f"valid: {', '.join(BACKENDS)}")
        if self.backend != "np":
            # plan arrays are P=1 population rows for kernels.circuit_sim
            self._jax_plan = (
                self.ir.op.astype(np.int32)[None],
                self.ir.in0.astype(np.int32)[None],
                self.ir.in1.astype(np.int32)[None],
                self.ir.outputs.astype(np.int32)[None],
            )
        else:
            self._netlist = self.ir.to_netlist()

    # -- construction -------------------------------------------------------
    @classmethod
    def from_netlist(cls, nl: C.Netlist, backend: str = "jax",
                     devices: tuple | None = None, **kw) -> "CircuitProgram":
        """Compile a bare netlist (DCE + levelize) into a program."""
        return cls(ir=lower_netlist(nl), backend=backend, devices=devices,
                   **kw)

    @classmethod
    def from_classifier(cls, cc: CompiledClassifier, backend: str = "jax",
                        devices: tuple | None = None,
                        **kw) -> "CircuitProgram":
        return cls(ir=cc.ir, thresholds=cc.thresholds,
                   n_classes=cc.n_classes, backend=backend, devices=devices,
                   **kw)

    # -- plan access ---------------------------------------------------------
    def plan(self) -> tuple:
        """`(op, in0, in1, outputs, n_inputs)` flat plan arrays — the tuple
        `kernels.dispatch.fleet_eval_words` eats, so a serving fleet can
        pool many programs into one multi-tenant megakernel launch."""
        return (self.ir.op.astype(np.int16), self.ir.in0.astype(np.int32),
                self.ir.in1.astype(np.int32),
                self.ir.outputs.astype(np.int32), self.ir.n_inputs)

    def pack_input_bits(self, xbin: np.ndarray) -> np.ndarray:
        """Binarized readings `(S, F)` -> packed `(F, ceil(S/32))` uint32
        words (the megakernel's word-plane layout)."""
        from repro.kernels import circuit_sim as CS
        return np.asarray(CS.pack_bits32(np.asarray(xbin)), dtype=np.uint32)

    def binarize(self, x: np.ndarray) -> np.ndarray:
        """Raw readings `(S, F)` -> 0/1 uint8 via the compiled ABC
        thresholds (strict `>`, same as `predict`)."""
        if self.thresholds is None:
            raise ValueError("program has no ABC thresholds")
        return (np.asarray(x) > self.thresholds[None, :]).astype(np.uint8)

    # -- execution ----------------------------------------------------------
    def eval_uint(self, packed_u64: np.ndarray) -> np.ndarray:
        """`(n_inputs, W)` uint64 packed vectors -> `(W*64,)` int64 decoded
        outputs (LSB-first), bit-identical to `Netlist.eval_uint`."""
        if self.backend == "np":
            return self._netlist.eval_uint(packed_u64)
        from repro.kernels import circuit_sim as CS
        return self._eval_words32(CS.pack_words32(packed_u64))

    def eval_bits(self, bits: np.ndarray) -> np.ndarray:
        """`(S, n_inputs)` 0/1 matrix -> `(S,)` int64 decoded outputs."""
        S = bits.shape[0]
        if self.backend == "np":
            return self._netlist.eval_uint(C.pack_vectors(bits))[:S]
        from repro.kernels import circuit_sim as CS
        return self._eval_words32(CS.pack_bits32(bits))[:S]

    def _eval_words32(self, words32: np.ndarray) -> np.ndarray:
        from repro.kernels import dispatch as D
        op, in0, in1, outs = self._jax_plan
        exec_backend = "swar" if self.backend == "jax" else self.backend
        out = D.program_eval_words(op, in0, in1, outs, words32,
                                   self.ir.n_inputs, backend=exec_backend,
                                   devices=self.devices,
                                   block_words=self.pallas_block_words,
                                   interpret=self.pallas_interpret)
        return np.asarray(out[0], dtype=np.int64)

    # -- classifier inference ----------------------------------------------
    def predict_bits(self, xbin: np.ndarray) -> np.ndarray:
        """Binarized readings `(S, F)` -> class labels `(S,)` int32."""
        if self.n_classes is None:
            raise ValueError("not a classifier program")
        return self.eval_bits(xbin).astype(np.int32)

    def predict(self, x: np.ndarray) -> np.ndarray:
        """Raw sensor readings `(S, F)` float -> class labels `(S,)` int32.

        Applies the compiled ABC thresholds (strict `>` comparators, same
        as `ternary.abc_binarize`) before the gate plane.
        """
        if self.thresholds is None:
            raise ValueError("program has no ABC thresholds")
        xbin = (np.asarray(x) > self.thresholds[None, :]).astype(np.uint8)
        return self.predict_bits(xbin)

    def scores(self, xbin: np.ndarray) -> np.ndarray:
        """Per-class XNOR-match scores `(S, C)` from the score tap plane."""
        if "score" not in self.ir.taps:
            raise ValueError("program has no score taps")
        tap = self.ir.taps["score"]              # (C, j)
        Cc, j = tap.shape
        S = xbin.shape[0]
        nl = self.ir.to_netlist(outputs=tap.reshape(-1))
        words = nl.simulate(C.pack_vectors(xbin))        # (C*j, W)
        ints = C._decode_words(words.reshape(Cc, j, -1))  # (C, W*64)
        return ints[:, :S].T                              # (S, C)
