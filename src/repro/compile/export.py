"""Compile-and-export CLI: train -> compile -> emit RTL -> verify -> serve.

The CI smoke path for the whole evolve->compile->emit->serve layer: trains
a quick exact TNN on one Table-2 dataset, lowers it, writes the structural
Verilog + EGFET report, re-evaluates the emitted RTL with the independent
`vread` reader against the compiled device program, and runs a short
sensor-stream serving burst.

Usage:  PYTHONPATH=src python -m repro.compile.export [dataset] [out_dir]
"""
from __future__ import annotations

import sys

import numpy as np

from repro.core import tnn as T
from repro.core.ternary import abc_binarize
from repro.data.tabular import make_dataset
from repro.compile.ir import lower_classifier
from repro.compile.program import CircuitProgram
from repro.compile.verilog import egfet_report, write_artifacts
from repro.compile.vread import VerilogDesign, eval_classifier_verilog
from repro.serve.engine import CircuitServingEngine


def main(dataset: str = "breast_cancer", out_dir: str = "artifacts",
         epochs: int = 6, n_verify: int = 2048, n_serve: int = 1024) -> dict:
    ds = make_dataset(dataset)
    tnn = T.train_tnn(ds, T.TNNTrainConfig(
        n_hidden=ds.spec.topology[1], epochs=epochs, lr=1e-2))
    hidden_nls, out_nls = T.exact_netlists(tnn)
    cc = lower_classifier(tnn, hidden_nls, out_nls)
    paths = write_artifacts(cc, out_dir, base=f"tnn_{dataset}",
                            dataset=dataset)
    report = egfet_report(cc)
    print(f"[compile] {dataset}: acc={tnn.test_acc:.3f} "
          f"gates={cc.ir.n_gates} depth={cc.ir.depth} "
          f"area={report['total_area_mm2']:.2f}mm^2 "
          f"power={report['total_power_mw']:.3f}mW "
          f"({report['power_source']})")
    print(f"[emit] {paths['verilog']}  {paths['report']}")
    print(f"[emit] tenant tnn_{dataset} -> {paths['manifest']} "
          f"(serve with: python -m repro.serve --emit-dir {out_dir})")

    # independent RTL re-evaluation vs the compiled device program
    rng = np.random.default_rng(0)
    xbits = rng.integers(0, 2, size=(n_verify, cc.n_features)).astype(np.uint8)
    prog = CircuitProgram.from_classifier(cc)
    design = VerilogDesign.parse(open(paths["verilog"]).read())
    rtl = eval_classifier_verilog(design, xbits)
    dev = prog.predict_bits(xbits)
    if not (rtl == dev).all():
        raise SystemExit("emitted RTL disagrees with compiled program")
    print(f"[verify] RTL == device program on {n_verify} random vectors")

    # serving smoke: classify a sensor stream, report throughput
    engine = CircuitServingEngine(prog, max_batch=256)
    engine.warmup()
    reps = int(np.ceil(n_serve / ds.x_test.shape[0]))
    stream = np.tile(ds.x_test, (reps, 1))[:n_serve]
    labels = engine.classify_stream(stream)
    xb_stream = np.asarray(abc_binarize(stream, tnn.thresholds)).astype(np.uint8)
    ref = T.predict_with_circuits(tnn, xb_stream, hidden_nls, out_nls)
    if not (labels == ref).all():
        raise SystemExit("serving labels disagree with reference path")
    s = engine.stats.summary()
    print(f"[serve] {s['n_readings']} readings in {s['n_batches']} batches: "
          f"{s['readings_per_s']:.0f} readings/s "
          f"(p50 {s['p50_ms']:.2f} ms/batch)")
    return {"report": report, "paths": paths, "serve": s}


if __name__ == "__main__":
    main(*sys.argv[1:3])
