"""repro.compile — evolve -> compile -> emit -> serve.

Lowers evolved classifiers (`core.tnn` + NSGA-II netlist selections) into a
single levelized gate IR with two backends: a jitted bit-packed device
program for batched sensor-stream inference, and synthesizable structural
Verilog with an EGFET area/power report (plus an independent reader that
re-evaluates the emitted RTL in Python).
"""
from repro.compile.artifact import (
    ArtifactCorruptError,
    load_manifest,
    load_manifest_doc,
    load_program,
    register_tenant,
    save_program,
    verify_program_bundle,
)
from repro.compile.ir import (
    CircuitIR,
    CompiledClassifier,
    argmax_netlist,
    lower,
    lower_classifier,
    lower_netlist,
)
from repro.compile.program import CircuitProgram
from repro.compile.verilog import (
    egfet_report,
    emit_classifier_verilog,
    emit_netlist_module,
    write_artifacts,
)
from repro.compile.vread import VerilogDesign, eval_classifier_verilog

# NOTE: repro.compile.zoo (the batch compiler CLI) is deliberately not
# imported here — `python -m repro.compile.zoo` would re-execute the
# already-imported module (runpy warns).  Import it directly:
# `from repro.compile.zoo import ZooEntry, build_zoo, make_entries`.

__all__ = [
    "ArtifactCorruptError",
    "CircuitIR",
    "CompiledClassifier",
    "CircuitProgram",
    "VerilogDesign",
    "argmax_netlist",
    "egfet_report",
    "emit_classifier_verilog",
    "emit_netlist_module",
    "eval_classifier_verilog",
    "load_manifest",
    "load_manifest_doc",
    "load_program",
    "verify_program_bundle",
    "lower",
    "lower_classifier",
    "lower_netlist",
    "register_tenant",
    "save_program",
    "write_artifacts",
]
