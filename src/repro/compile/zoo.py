"""Zoo batch compiler — sweep campaigns into one servable model fleet.

Generator-style batch lowering: a grid of `ZooEntry` recipes (dataset x
variant x budgets) each runs the full producer pipeline — phase-cached
TNN/CGP/PCC products, a serial NSGA-II campaign, `compile_archive_winner`
on the archive's best-accuracy chromosome — and emits Verilog + EGFET
report + servable program bundle into one shared emit directory whose
``fleet.json`` indexes every tenant.  The point is scale-testing the
serving side: a zoo directory is exactly what ``python -m repro.serve
--emit-dir <zoo> --megakernel`` wants for multi-tenant megakernel
dispatch.

Incremental by construction: every manifest row is stamped with the
entry's content fingerprint (sha256 over the full recipe), and a rebuild
skips any entry whose row still matches *and* whose program bundle
verifies against the row's recorded sha256.  A stale fingerprint, a
missing bundle, or a corrupt one (checksum mismatch) rebuilds that entry
alone.  ``--force`` rebuilds everything.

Entries are independent, so the sweep fans out over a spawned worker
pool (``--workers``).  Workers compile and emit files only
(``write_artifacts(register=False)``): the ``fleet.json`` manifest is
read-modify-write, so the parent registers the returned rows serially —
no manifest races, deterministic generation numbering.

CLI:

    PYTHONPATH=src python -m repro.compile.zoo \
        --datasets cardio seeds --variants base lean \
        --emit-dir zoo_out --workers 4 --out zoo_report.json
"""
from __future__ import annotations

import argparse
import hashlib
import json
import time
from dataclasses import asdict, dataclass
from pathlib import Path

# Bump when the campaign->compile->emit pipeline changes in a way that
# invalidates previously emitted zoo entries.
ZOO_VERSION = 1

# Variant presets: overrides applied to the CLI's base budgets.  Plain
# keys replace the value; ``<field>_scale`` keys multiply it (rounded,
# floored at 1) — so one ``--pop/--epochs`` baseline fans into a family
# of differently shaped searches.
VARIANTS: dict[str, dict] = {
    "base": {},
    "lean": {"pop_scale": 0.5, "gens_per_epoch_scale": 0.5},
    "wide": {"islands_scale": 2.0, "pop_scale": 1.5},
    "alt-seed": {"seed": 17},
}


@dataclass(frozen=True)
class ZooEntry:
    """One zoo recipe: everything its emitted artifact depends on."""

    dataset: str
    tag: str = "base"
    seed: int = 0
    # campaign budgets
    islands: int = 4
    pop: int = 24
    epochs: int = 8
    gens_per_epoch: int = 5
    migrate_k: int = 2
    # Phase-1/2 budgets (phase-cache key inputs)
    tnn_epochs: int = 12
    cgp_points: int = 3
    cgp_iters: int = 500
    pcc_samples: int = 30000
    backend: str = "np"
    replicas: int = 1

    @property
    def name(self) -> str:
        return f"tnn_{self.dataset}__{self.tag}"

    def fingerprint(self) -> str:
        """sha256 over the full recipe — the manifest skip key."""
        blob = json.dumps({"zoo_version": ZOO_VERSION, **asdict(self)},
                          sort_keys=True)
        return hashlib.sha256(blob.encode()).hexdigest()


def apply_variant(base: dict, overrides: dict) -> dict:
    out = dict(base)
    for k, v in overrides.items():
        if k.endswith("_scale"):
            f = k[: -len("_scale")]
            out[f] = max(1, int(round(out[f] * v)))
        else:
            out[k] = v
    return out


def make_entries(datasets: list[str], variants: list[str],
                 **base) -> list[ZooEntry]:
    """The dataset x variant grid over one set of base budgets."""
    unknown = [v for v in variants if v not in VARIANTS]
    if unknown:
        raise ValueError(f"unknown variant(s) {', '.join(unknown)}; "
                         f"valid: {', '.join(sorted(VARIANTS))}")
    entries = []
    for ds in datasets:
        for tag in variants:
            kw = apply_variant(base, VARIANTS[tag])
            entries.append(ZooEntry(dataset=ds, tag=tag, **kw))
    return entries


def _compile_entry(entry_dict: dict, emit_dir: str,
                   cache_dir: str | None) -> dict:
    """Worker: campaign -> winner -> artifacts; returns the manifest row.

    Module-level (spawn-picklable).  Emits files only — the parent owns
    the manifest.  The Phase-1/2 half rides the content-addressed phase
    cache, so N entries over one dataset/budget pair train its TNN once.
    """
    from repro.compile.verilog import write_artifacts
    from repro.evolve.campaign import Campaign
    from repro.evolve.config import CampaignConfig
    from repro.evolve.problems import (ProblemSpec, build_problem,
                                       compile_archive_winner)

    entry = ZooEntry(**entry_dict)
    spec = ProblemSpec("tnn", {
        "dataset": entry.dataset, "seed": entry.seed,
        "epochs": entry.tnn_epochs, "cgp_points": entry.cgp_points,
        "cgp_iters": entry.cgp_iters, "pcc_samples": entry.pcc_samples,
        "eval_backend": entry.backend, "cache_dir": cache_dir})
    problem = build_problem(spec)
    cfg = CampaignConfig(n_islands=entry.islands, pop_size=entry.pop,
                         n_epochs=entry.epochs,
                         gens_per_epoch=entry.gens_per_epoch,
                         migrate_k=entry.migrate_k, seed=entry.seed,
                         eval_backend=entry.backend)
    campaign = Campaign(problem.domains, problem.objective, cfg,
                        seed_population=problem.seed_population,
                        name=entry.name)
    res = campaign.run()
    x, f = campaign.best_by_objective(0)
    cc = compile_archive_winner(problem, x)
    provenance = {
        "seed": cfg.seed, "islands": cfg.n_islands, "pop_size": cfg.pop_size,
        "generations": cfg.total_generations,
        "objectives": [float(v) for v in f],
        "config_fingerprint": campaign.fingerprint(),
        "backend": cfg.eval_backend,
        "zoo_fingerprint": entry.fingerprint(),
        "zoo_tag": entry.tag,
        "archive_size": int(len(res.archive_x)),
    }
    paths = write_artifacts(cc, emit_dir, base=entry.name,
                            dataset=entry.dataset, replicas=entry.replicas,
                            provenance=provenance, register=False)
    return paths["entry"]


def _is_current(entry: ZooEntry, row: dict | None, emit_dir: Path) -> bool:
    """True iff `row` still vouches for `entry`: fingerprint match AND the
    bundle on disk verifies against the sha256 the row recorded."""
    from repro.compile import artifact as A

    if row is None:
        return False
    if row.get("provenance", {}).get("zoo_fingerprint") != entry.fingerprint():
        return False
    try:
        A.verify_program_bundle(emit_dir / row["program"],
                                expect_sha256=row.get("sha256"))
    except (A.ArtifactCorruptError, FileNotFoundError, KeyError):
        return False
    return True


def build_zoo(entries: list[ZooEntry], emit_dir: str | Path,
              workers: int = 1, cache_dir: str | None = None,
              force: bool = False) -> dict:
    """Compile every stale entry, register all rows, return a report.

    Report: ``built`` / ``cached`` name lists, per-entry seconds, and the
    manifest path.  Raises on duplicate entry names (two recipes cannot
    share a tenant slot).
    """
    from repro.compile import artifact as A

    emit_dir = Path(emit_dir)
    names = [e.name for e in entries]
    dupes = {n for n in names if names.count(n) > 1}
    if dupes:
        raise ValueError(f"duplicate zoo entry names: {', '.join(sorted(dupes))}"
                         " — same dataset+tag twice in one sweep")
    try:
        rows = {r["name"]: r for r in A.load_manifest(emit_dir)}
    except FileNotFoundError:
        rows = {}

    cached = [] if force else [e for e in entries
                               if _is_current(e, rows.get(e.name), emit_dir)]
    cached_names = {e.name for e in cached}
    pending = [e for e in entries if e.name not in cached_names]

    t0 = time.perf_counter()
    built_rows: list[dict] = []
    if pending:
        if workers > 1:
            import multiprocessing as mp
            from concurrent.futures import ProcessPoolExecutor

            with ProcessPoolExecutor(
                    max_workers=min(workers, len(pending)),
                    mp_context=mp.get_context("spawn")) as pool:
                futs = [pool.submit(_compile_entry, asdict(e), str(emit_dir),
                                    cache_dir)
                        for e in pending]
                built_rows = [f.result() for f in futs]
        else:
            built_rows = [_compile_entry(asdict(e), str(emit_dir), cache_dir)
                          for e in pending]
    # manifest registration is read-modify-write: parent only, serial
    manifest = None
    for row in built_rows:
        manifest = A.register_tenant(emit_dir, row)
    if manifest is None:
        manifest = A.manifest_path(emit_dir)
    return {
        "entries": len(entries),
        "built": sorted(e.name for e in pending),
        "cached": sorted(e.name for e in cached),
        "build_s": round(time.perf_counter() - t0, 3),
        "workers": int(workers),
        "manifest": str(manifest),
    }


def _parse_args(argv=None) -> argparse.Namespace:
    from repro.data.tabular import DATASETS

    ap = argparse.ArgumentParser(prog="python -m repro.compile.zoo",
                                 description=__doc__)
    ap.add_argument("--datasets", nargs="+", default=["all"],
                    help=f"subset of {', '.join(sorted(DATASETS))}, or all")
    ap.add_argument("--variants", nargs="+", default=["base"],
                    help=f"subset of {', '.join(sorted(VARIANTS))}")
    ap.add_argument("--emit-dir", required=True)
    ap.add_argument("--workers", type=int, default=1)
    ap.add_argument("--phase-cache", default=None,
                    help="Phase-1/2 product cache dir (default: "
                         "$REPRO_PHASE_CACHE or ~/.cache/repro/phase_cache)")
    ap.add_argument("--force", action="store_true",
                    help="rebuild every entry, cached or not")
    ap.add_argument("--out", default=None,
                    help="write the build report JSON here")
    # base budgets the variant presets scale from
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--islands", type=int, default=4)
    ap.add_argument("--pop", type=int, default=24)
    ap.add_argument("--epochs", type=int, default=8)
    ap.add_argument("--gens-per-epoch", type=int, default=5)
    ap.add_argument("--migrate-k", type=int, default=2)
    ap.add_argument("--tnn-epochs", type=int, default=12)
    ap.add_argument("--cgp-points", type=int, default=3)
    ap.add_argument("--cgp-iters", type=int, default=500)
    ap.add_argument("--pcc-samples", type=int, default=30000)
    ap.add_argument("--backend", choices=("np", "swar", "pallas"),
                    default="np")
    ap.add_argument("--replicas", type=int, default=1)
    return ap.parse_args(argv)


def main(argv=None) -> None:
    from repro.data.tabular import DATASETS

    args = _parse_args(argv)
    datasets = (sorted(DATASETS) if args.datasets == ["all"]
                else args.datasets)
    unknown = [d for d in datasets if d not in DATASETS]
    if unknown:
        raise SystemExit(f"unknown dataset(s): {', '.join(unknown)}; "
                         f"valid: {', '.join(sorted(DATASETS))}, all")
    entries = make_entries(
        datasets, args.variants, seed=args.seed, islands=args.islands,
        pop=args.pop, epochs=args.epochs,
        gens_per_epoch=args.gens_per_epoch, migrate_k=args.migrate_k,
        tnn_epochs=args.tnn_epochs, cgp_points=args.cgp_points,
        cgp_iters=args.cgp_iters, pcc_samples=args.pcc_samples,
        backend=args.backend, replicas=args.replicas)
    print(f"[zoo] {len(entries)} entries "
          f"({len(datasets)} datasets x {len(args.variants)} variants) "
          f"-> {args.emit_dir} [workers={args.workers}]")
    report = build_zoo(entries, args.emit_dir, workers=args.workers,
                       cache_dir=args.phase_cache, force=args.force)
    print(f"[zoo] built {len(report['built'])}, "
          f"cached {len(report['cached'])} in {report['build_s']:.1f}s "
          f"-> {report['manifest']}")
    print(f"[zoo] serve it: python -m repro.serve --emit-dir "
          f"{args.emit_dir} --megakernel")
    if args.out:
        Path(args.out).parent.mkdir(parents=True, exist_ok=True)
        Path(args.out).write_text(json.dumps(report, indent=2, sort_keys=True)
                                  + "\n")
        print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
