"""Circuit compiler IR — lowering evolved classifiers to deployable gates.

After Phase 3 an evolved design exists as scattered `Netlist` objects (one
approximate PCC per hidden neuron, one approximate popcount per output
neuron) plus the TNN's ternary wiring.  `lower_classifier` flattens the
whole decision function

    ABC bits -> per-neuron PCCs -> XNOR/popcount scores -> argmax

into ONE `CircuitIR`: a dead-gate-eliminated, levelized gate array whose
outputs are the binary class index.  The same IR drives both backends:

  * `repro.compile.program.CircuitProgram` — jitted bit-packed SWAR device
    execution (batched sensor-stream inference), and
  * `repro.compile.verilog` — synthesizable structural RTL + EGFET report.

Levelization sorts gates by logic depth (stable within a level), which (a)
keeps the array a valid feed-forward schedule, (b) makes emitted RTL read
level-by-level, and (c) exposes the critical-path depth for the 5 Hz EGFET
timing sanity check.  The argmax is lowered to real gates
(`argmax_netlist`) so the compiled circuit — unlike the analytic
`tnn.argmax_cost` estimate — *is* the full classifier, with np.argmax
first-max tie semantics preserved bit-for-bit.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from repro.core import circuits as C
from repro.core.circuits import Netlist, _Builder
from repro.hw.egfet import Gate, HwCost


@dataclass
class CircuitIR:
    """Levelized, dead-gate-eliminated single-circuit gate array.

    Same array layout as `Netlist` plus per-gate `levels` and named `taps`
    (interior node groups — e.g. hidden-neuron bits — kept live through DCE
    so backends can observe them).  Every gate is reachable from a root by
    construction, so `cost()` needs no liveness pass.
    """

    n_inputs: int
    op: np.ndarray        # (n_gates,) int16 Gate opcodes, level-sorted
    in0: np.ndarray       # (n_gates,) int32 node ids
    in1: np.ndarray       # (n_gates,) int32 node ids
    outputs: np.ndarray   # (n_outputs,) int32 node ids, LSB-first
    levels: np.ndarray    # (n_gates,) int32 logic depth (inputs are level 0)
    taps: dict[str, np.ndarray] = field(default_factory=dict)
    name: str = ""
    meta: dict = field(default_factory=dict)

    @property
    def n_gates(self) -> int:
        return int(self.op.shape[0])

    @property
    def n_outputs(self) -> int:
        return int(self.outputs.shape[0])

    @property
    def depth(self) -> int:
        return int(self.levels.max()) if self.n_gates else 0

    def to_netlist(self, outputs: np.ndarray | None = None) -> Netlist:
        """View as a `Netlist` (optionally re-rooted at tap nodes)."""
        nl = Netlist(self.n_inputs, self.op, self.in0, self.in1,
                     np.asarray(self.outputs if outputs is None else outputs,
                                dtype=np.int32),
                     name=self.name, meta=dict(self.meta))
        nl.validate()
        return nl

    def cost(self) -> HwCost:
        """EGFET cost of the lowered logic (all gates are live)."""
        area = float(C.GATE_AREA_VEC[self.op].sum())
        power = float(C.GATE_POWER_VEC[self.op].sum()) * 1e-3
        return HwCost(area, power)

    def gate_histogram(self) -> dict[str, int]:
        names, counts = np.unique(self.op, return_counts=True)
        return {Gate(int(o)).name: int(c) for o, c in zip(names, counts)
                if int(c)}

    def stats(self) -> dict:
        cost = self.cost()
        return {
            "n_inputs": self.n_inputs,
            "n_gates": self.n_gates,
            "n_outputs": self.n_outputs,
            "depth": self.depth,
            "area_mm2": round(cost.area_mm2, 4),
            "power_mw": round(cost.power_mw, 5),
            "gates": self.gate_histogram(),
        }


def _live_nodes(n_inputs: int, op: np.ndarray, in0: np.ndarray,
                in1: np.ndarray, roots: np.ndarray) -> np.ndarray:
    """Boolean liveness over all nodes, seeded at `roots` (node ids)."""
    G = int(op.shape[0])
    live = np.zeros(n_inputs + G, dtype=bool)
    live[roots] = True
    uses_a = C._USES_A[op]
    uses_b = C._USES_B[op]
    for g in range(G - 1, -1, -1):
        if live[n_inputs + g]:
            if uses_a[g]:
                live[in0[g]] = True
            if uses_b[g]:
                live[in1[g]] = True
    return live


def lower(n_inputs: int, op: np.ndarray, in0: np.ndarray, in1: np.ndarray,
          outputs: np.ndarray, taps: dict[str, np.ndarray] | None = None,
          name: str = "", meta: dict | None = None) -> CircuitIR:
    """Dead-gate eliminate + levelize raw gate arrays into a `CircuitIR`.

    Roots are `outputs` plus every tap node.  Unused operand slots (NOT/BUF
    `in1`, CONST operands) are normalized to input 0 so they never pin dead
    gates live or survive as dangling references after compaction.
    """
    op = np.asarray(op, dtype=np.int16)
    in0 = np.ascontiguousarray(in0, dtype=np.int32).copy()
    in1 = np.ascontiguousarray(in1, dtype=np.int32).copy()
    outputs = np.asarray(outputs, dtype=np.int32)
    taps = {k: np.asarray(v, dtype=np.int32) for k, v in (taps or {}).items()}
    in0[~C._USES_A[op]] = 0
    in1[~C._USES_B[op]] = 0

    roots = np.concatenate([outputs.ravel()]
                           + [t.ravel() for t in taps.values()]).astype(np.int64)
    live = _live_nodes(n_inputs, op, in0, in1, roots)
    keep = np.where(live[n_inputs:])[0]

    # logic depth over live gates (inputs and consts anchor at 0 / 1)
    lvl = np.zeros(n_inputs + op.shape[0], dtype=np.int32)
    uses_a = C._USES_A[op]
    uses_b = C._USES_B[op]
    for g in keep:
        la = lvl[in0[g]] if uses_a[g] else 0
        lb = lvl[in1[g]] if uses_b[g] else 0
        lvl[n_inputs + g] = max(la, lb) + 1

    order = keep[np.argsort(lvl[n_inputs + keep], kind="stable")]
    new_id = np.full(n_inputs + op.shape[0], -1, dtype=np.int64)
    new_id[:n_inputs] = np.arange(n_inputs)
    new_id[n_inputs + order] = n_inputs + np.arange(order.shape[0])

    ir = CircuitIR(
        n_inputs=n_inputs,
        op=op[order],
        in0=new_id[in0[order]].astype(np.int32),
        in1=new_id[in1[order]].astype(np.int32),
        outputs=new_id[outputs].astype(np.int32).reshape(outputs.shape),
        levels=lvl[n_inputs + order],
        taps={k: new_id[v].astype(np.int32).reshape(v.shape)
              for k, v in taps.items()},
        name=name,
        meta=meta or {},
    )
    ir.to_netlist()  # validates feed-forwardness of the compacted arrays
    return ir


def lower_netlist(nl: Netlist, taps: dict[str, np.ndarray] | None = None
                  ) -> CircuitIR:
    """Lower a single `Netlist` (keeps its outputs as the only roots)."""
    return lower(nl.n_inputs, nl.op, nl.in0, nl.in1, nl.outputs, taps=taps,
                 name=nl.name, meta=dict(nl.meta))


class _ConstPool:
    """Memoized CONST0/CONST1 nodes for one builder (one gate per value)."""

    def __init__(self, b: _Builder):
        self.b = b
        self.ids: dict[int, int] = {}

    def __call__(self, v: int) -> int:
        if v not in self.ids:
            self.ids[v] = self.b.const(v)
        return self.ids[v]


def argmax_netlist(n_classes: int, score_bits: int) -> Netlist:
    """First-max argmax over `n_classes` unsigned scores, as pure gates.

    Inputs are class-major LSB-first score bits (input o*score_bits + k is
    bit k of class o); outputs are the winning class index (LSB-first,
    ceil(log2(C)) bits).  Fold semantics: the running best is replaced only
    on strictly-greater score, which reproduces `np.argmax`'s first-max tie
    behaviour exactly.
    """
    if n_classes < 1 or score_bits < 1:
        raise ValueError("argmax needs n_classes >= 1 and score_bits >= 1")
    idx_bits = max(1, int(np.ceil(np.log2(n_classes)))) if n_classes > 1 else 1
    b = _Builder(n_classes * score_bits)
    const = _ConstPool(b)

    def score(o: int) -> list[int]:
        return [o * score_bits + k for k in range(score_bits)]

    best_s = score(0)
    best_i = [const(0)] * idx_bits
    for o in range(1, n_classes):
        cand = score(o)
        ge = b.geq(best_s, cand)            # best >= cand
        take = b.gate(Gate.NOT, ge)         # cand strictly greater -> replace
        best_s = [b.gate(Gate.OR, b.gate(Gate.AND, take, c),
                         b.gate(Gate.ANDN, s, take))
                  for c, s in zip(cand, best_s)]
        obits = [const((o >> k) & 1) for k in range(idx_bits)]
        best_i = [b.gate(Gate.OR, b.gate(Gate.AND, take, c),
                         b.gate(Gate.ANDN, s, take))
                  for c, s in zip(obits, best_i)]
    return b.finish(best_i, name=f"argmax_{n_classes}x{score_bits}",
                    meta={"n_classes": n_classes, "score_bits": score_bits})


@dataclass
class CompiledClassifier:
    """A fully lowered classifier: one IR + the structure it came from.

    `ir` outputs are the class-index bits; taps `hidden` (H,) and `score`
    (C, score_bits) expose the interior planes.  The source netlists and
    ternary output wiring are retained for the Verilog backend, which emits
    module-per-PCC structural RTL instead of one flat gate soup.
    """

    ir: CircuitIR
    thresholds: np.ndarray          # (F,) ABC V_q per feature
    n_features: int
    n_classes: int
    score_bits: int
    hidden_nls: list[Netlist]
    out_nls: list[Netlist]
    w1t: np.ndarray                 # (F, H) int8 ternary input wiring
    w2t: np.ndarray                 # (H, C) int8 ternary output wiring
    name: str = ""

    @property
    def index_bits(self) -> int:
        return self.ir.n_outputs


def hidden_input_map(w1_col: np.ndarray, n_inputs: int) -> list[int]:
    """Feature ids feeding one hidden PCC: [w=+1 features..., w=-1 features...].

    Degenerate PCCs (constant-1 netlists for all-zero / no-negative columns)
    carry dummy input ports; those are padded with feature 0, matching the
    `predict_with_circuits` convention of never reading them.
    """
    fmap = list(np.where(w1_col == 1)[0]) + list(np.where(w1_col == -1)[0])
    while len(fmap) < n_inputs:
        fmap.append(0)
    return fmap


def lower_classifier(tnn, hidden_nls: list[Netlist], out_nls: list[Netlist],
                     name: str | None = None) -> CompiledClassifier:
    """Flatten a (possibly approximate) evolved TNN into one `CircuitIR`.

    `tnn` is a `repro.core.tnn.TrainedTNN`; `hidden_nls`/`out_nls` come from
    `exact_netlists` or an NSGA-II chromosome via `TNNApproxProblem.decode`.
    The lowered circuit is bit-identical to `predict_with_circuits` (pinned
    by tests/test_compile.py across all Table-2 datasets).
    """
    F, H = tnn.w1t.shape
    Cc = tnn.w2t.shape[1]
    if len(hidden_nls) != H or len(out_nls) != Cc:
        raise ValueError("need one hidden netlist per neuron and one output "
                         "netlist per class")
    b = _Builder(F)

    # hidden plane: inline each PCC over its +/- feature slices
    h_nodes = [b.inline(nl, hidden_input_map(tnn.w1t[:, i], nl.n_inputs))[0]
               for i, nl in enumerate(hidden_nls)]

    const = _ConstPool(b)

    # output plane: XNOR simplification (wire for w=+1, NOT for w=-1) into
    # the per-class popcount netlist; zero-extend scores to a common width
    j = max((nl.n_outputs for nl in out_nls), default=1)
    score_nodes = np.empty((Cc, j), dtype=np.int64)
    for o in range(Cc):
        col = tnn.w2t[:, o]
        bmap = [h_nodes[i] for i in np.where(col == 1)[0]]
        bmap += [b.gate(Gate.NOT, h_nodes[i]) for i in np.where(col == -1)[0]]
        if not bmap:
            bits = [const(0)] * j
        else:
            bits = b.inline(out_nls[o], bmap)
            bits += [const(0)] * (j - len(bits))
        score_nodes[o] = bits[:j]

    # argmax plane (first-max fold, real gates)
    am = argmax_netlist(Cc, j)
    class_bits = b.inline(am, list(score_nodes.reshape(-1)))

    ir = lower(
        F, np.array(b.ops, dtype=np.int16), np.array(b.i0, dtype=np.int32),
        np.array(b.i1, dtype=np.int32), np.array(class_bits, dtype=np.int32),
        taps={"hidden": np.array(h_nodes, dtype=np.int32),
              "score": score_nodes.astype(np.int32)},
        name=name or f"tnn_classifier_{tnn.name or 'anon'}",
        meta={"n_classes": Cc, "score_bits": j, "n_hidden": H,
              "dataset": tnn.name},
    )
    return CompiledClassifier(
        ir=ir, thresholds=np.asarray(tnn.thresholds, dtype=np.float64),
        n_features=F, n_classes=Cc, score_bits=j,
        hidden_nls=list(hidden_nls), out_nls=list(out_nls),
        w1t=tnn.w1t.copy(), w2t=tnn.w2t.copy(),
        name=ir.name)
