"""Verilog backend — synthesizable structural RTL + EGFET report.

Emits the subset of structural Verilog-2001 a printed-electronics PDK flow
(Synopsys DC on the EGFET library, cf. the paper's Sec. 5 setup) consumes:
scalar ports, `wire` declarations, one primitive-gate `assign` per line and
named-port module instantiations — nothing behavioural.  Structure mirrors
the paper's bespoke architecture: one module per distinct PCC / popcount
circuit (deduplicated by lowered-netlist content), one `argmax` module, and
a top-level classifier module wiring features -> hidden PCCs -> XNOR NOT
gates -> per-class score popcounts -> argmax.

Statements are emitted in topological (levelized) order, which lets the
single-pass reader in `repro.compile.vread` re-evaluate the file and pin
bit-identity against the compiled `CircuitProgram`.

The EGFET area/power report comes from the *same* `CircuitIR` the device
backend executes — gate histogram, logic depth, core + sensor-interface
area/power and the Sec.-5 printed power-source verdict.
"""
from __future__ import annotations

import json
import re
from pathlib import Path

import numpy as np

from repro.core import circuits as C
from repro.core.circuits import Netlist
from repro.hw.egfet import Gate, HwCost, interface_cost, power_source
from repro.compile.ir import (CircuitIR, CompiledClassifier, argmax_netlist,
                              hidden_input_map, lower_netlist)

# one primitive gate per assign; {a}/{b} are operand signal names
_OP_EXPR = {
    int(Gate.CONST0): "1'b0",
    int(Gate.CONST1): "1'b1",
    int(Gate.INPUT): "{a}",
    int(Gate.BUF): "{a}",
    int(Gate.NOT): "~{a}",
    int(Gate.AND): "({a} & {b})",
    int(Gate.OR): "({a} | {b})",
    int(Gate.XOR): "({a} ^ {b})",
    int(Gate.NAND): "~({a} & {b})",
    int(Gate.NOR): "~({a} | {b})",
    int(Gate.XNOR): "~({a} ^ {b})",
    int(Gate.ANDN): "({a} & ~{b})",
    int(Gate.ORN): "({a} | ~{b})",
}


def _sanitize(name: str) -> str:
    s = re.sub(r"[^A-Za-z0-9_]", "_", name)
    s = re.sub(r"__+", "_", s).strip("_")
    if not s or not (s[0].isalpha() or s[0] == "_"):
        s = "m_" + s
    return s


def emit_netlist_module(nl_or_ir: Netlist | CircuitIR, name: str) -> str:
    """One circuit -> one Verilog module (inputs x0.., outputs y0..).

    `Netlist` arguments are lowered first, so the RTL carries only live
    gates in level order.
    """
    ir = nl_or_ir if isinstance(nl_or_ir, CircuitIR) else lower_netlist(nl_or_ir)

    def sig(node: int) -> str:
        return f"x{node}" if node < ir.n_inputs else f"n{node}"

    ports = [f"    input  x{i}" for i in range(ir.n_inputs)]
    ports += [f"    output y{k}" for k in range(ir.n_outputs)]
    lines = [f"module {name} ("] + [p + "," for p in ports[:-1]] + [ports[-1], ");"]
    for g in range(ir.n_gates):
        lines.append(f"  wire n{ir.n_inputs + g};")
    for g in range(ir.n_gates):
        expr = _OP_EXPR[int(ir.op[g])].format(a=sig(int(ir.in0[g])),
                                              b=sig(int(ir.in1[g])))
        lines.append(f"  assign n{ir.n_inputs + g} = {expr};")
    for k, node in enumerate(ir.outputs):
        lines.append(f"  assign y{k} = {sig(int(node))};")
    lines.append("endmodule")
    return "\n".join(lines) + "\n"


class _ModuleLibrary:
    """Content-addressed module dedup: identical lowered netlists share RTL."""

    def __init__(self):
        self._by_key: dict[tuple, str] = {}
        self.texts: list[str] = []

    def add(self, nl: Netlist) -> tuple[str, CircuitIR]:
        ir = lower_netlist(nl)
        key = (ir.n_inputs, ir.op.tobytes(), ir.in0.tobytes(),
               ir.in1.tobytes(), ir.outputs.tobytes())
        if key not in self._by_key:
            mod = f"m{len(self._by_key)}_{_sanitize(nl.name or 'circuit')}"
            self._by_key[key] = mod
            self.texts.append(emit_netlist_module(ir, mod))
        return self._by_key[key], ir


def emit_classifier_verilog(cc: CompiledClassifier,
                            top: str = "tnn_classifier") -> str:
    """Full classifier RTL: PCC/PC/argmax modules + top-level wiring.

    Top-level ports: `x0..x{F-1}` (ABC comparator outputs) in, class-index
    bits `k0..k{IB-1}` (LSB-first) out.  Statement order in every module
    body is topological, a guarantee `vread.VerilogDesign` relies on.
    """
    lib = _ModuleLibrary()
    body: list[str] = []

    # hidden plane
    h_sigs = []
    for i, nl in enumerate(cc.hidden_nls):
        mod, ir = lib.add(nl)
        fmap = hidden_input_map(cc.w1t[:, i], nl.n_inputs)
        h = f"h{i}"
        body.append(f"  wire {h};")
        conns = [f".x{p}(x{fid})" for p, fid in enumerate(fmap)]
        conns.append(f".y0({h})")
        body.append(f"  {mod} u_h{i} ({', '.join(conns)});")
        h_sigs.append(h)

    # output plane: XNOR NOTs + per-class score popcounts, zero-extended
    j = cc.score_bits
    score_sigs: list[list[str]] = []
    for o in range(cc.n_classes):
        col = cc.w2t[:, o]
        in_sigs = [h_sigs[i] for i in np.where(col == 1)[0]]
        for i in np.where(col == -1)[0]:
            neg = f"hn{o}_{i}"
            body.append(f"  wire {neg};")
            body.append(f"  assign {neg} = ~{h_sigs[i]};")
            in_sigs.append(neg)
        sigs = [f"s{o}_{k}" for k in range(j)]
        for s in sigs:
            body.append(f"  wire {s};")
        if not in_sigs:
            for s in sigs:
                body.append(f"  assign {s} = 1'b0;")
        else:
            nl = cc.out_nls[o]
            mod, ir = lib.add(nl)
            conns = [f".x{p}({s})" for p, s in enumerate(in_sigs)]
            conns += [f".y{k}({sigs[k]})" for k in range(ir.n_outputs)]
            body.append(f"  {mod} u_o{o} ({', '.join(conns)});")
            for k in range(ir.n_outputs, j):
                body.append(f"  assign {sigs[k]} = 1'b0;")
        score_sigs.append(sigs)

    # argmax plane
    am_mod, am_ir = lib.add(argmax_netlist(cc.n_classes, j))
    idx_bits = am_ir.n_outputs
    conns = [f".x{o * j + k}({score_sigs[o][k]})"
             for o in range(cc.n_classes) for k in range(j)]
    conns += [f".y{b}(k{b})" for b in range(idx_bits)]
    body.append(f"  {am_mod} u_argmax ({', '.join(conns)});")

    ports = [f"    input  x{i}" for i in range(cc.n_features)]
    ports += [f"    output k{b}" for b in range(idx_bits)]
    header = ([f"// {cc.name}: printed-TNN classifier "
               f"({cc.n_features} features, {cc.n_classes} classes, "
               f"{cc.ir.n_gates} gates, depth {cc.ir.depth})",
               f"module {top} ("]
              + [p + "," for p in ports[:-1]] + [ports[-1], ");"])
    text = "\n".join(["// Generated by repro.compile.verilog — structural "
                      "EGFET netlist, one assign per gate.", ""]
                     + lib.texts
                     + header + body + ["endmodule", ""])
    return text


def egfet_report(cc: CompiledClassifier, interface: str | None = "abc") -> dict:
    """EGFET area/power report from the compiled IR (+ sensor interface)."""
    core = cc.ir.cost()
    iface = (interface_cost(cc.n_features, interface) if interface
             else HwCost(0.0, 0.0))
    total = core + iface
    return {
        "name": cc.name,
        "n_features": cc.n_features,
        "n_classes": cc.n_classes,
        "n_gates": cc.ir.n_gates,
        "logic_depth": cc.ir.depth,
        "gates": cc.ir.gate_histogram(),
        "core_area_mm2": round(core.area_mm2, 4),
        "core_power_mw": round(core.power_mw, 5),
        "interface": interface,
        "interface_area_mm2": round(iface.area_mm2, 4),
        "interface_power_mw": round(iface.power_mw, 5),
        "total_area_mm2": round(total.area_mm2, 4),
        "total_area_cm2": round(total.area_cm2, 5),
        "total_power_mw": round(total.power_mw, 5),
        "power_source": power_source(total.power_mw),
    }


def write_artifacts(cc: CompiledClassifier, out_dir: str | Path,
                    base: str | None = None,
                    interface: str | None = "abc",
                    dataset: str | None = None,
                    replicas: int = 1,
                    provenance: dict | None = None,
                    register: bool = True) -> dict[str, str]:
    """Write `<base>.v` + `<base>_egfet.json` + a servable program bundle
    under `out_dir`, and register the design as tenant `base` in the
    directory's `fleet.json` manifest (`repro.serve` consumes it).
    `replicas` is a serving hint: how many engine replicas the fleet
    should stand up for this tenant by default.  `provenance` (seed,
    generations, objective values, config fingerprint — whatever produced
    this design) is stamped into the manifest row so a later promotion
    decision can tell *which search* a live tenant came from.

    `register=False` writes the files but skips the manifest: manifest
    registration is read-modify-write on one `fleet.json`, so concurrent
    writers (the zoo batch compiler's worker pool) emit with
    `register=False` and the parent registers the returned `entry` rows
    serially via `artifact.register_tenant`."""
    from repro.compile import artifact as A

    out = Path(out_dir)
    out.mkdir(parents=True, exist_ok=True)
    base = base or _sanitize(cc.name or "tnn_classifier")
    vpath = out / f"{base}.v"
    rpath = out / f"{base}_egfet.json"
    ppath = out / f"{base}{A.PROGRAM_SUFFIX}"
    vpath.write_text(emit_classifier_verilog(cc))
    rpath.write_text(json.dumps(egfet_report(cc, interface), indent=2) + "\n")
    A.save_program(cc, ppath)
    entry = {
        "name": base,
        "program": str(ppath),
        "verilog": str(vpath),
        "report": str(rpath),
        # only an explicit dataset is trustworthy here: ir.meta["dataset"]
        # holds the model *name*, which need not be a loadable dataset
        "dataset": dataset,
        "n_features": cc.n_features,
        "n_classes": cc.n_classes,
        "n_gates": cc.ir.n_gates,
        "replicas": int(replicas),
        # the digest save_program just wrote — no need to re-hash the npz
        "sha256": ppath.with_name(ppath.name
                                  + A.SHA_SUFFIX).read_text().strip(),
    }
    if provenance is not None:
        entry["provenance"] = dict(provenance)
    paths = {"verilog": str(vpath), "report": str(rpath),
             "program": str(ppath), "entry": entry}
    if register:
        paths["manifest"] = str(A.register_tenant(out, entry))
    return paths
