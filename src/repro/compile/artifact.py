"""Servable artifact bundles + the fleet manifest.

The Verilog + EGFET report that `write_artifacts` emits are what a printed
fab consumes; a *serving* process needs the executable side of the same
design — the levelized `CircuitIR` arrays plus the ABC thresholds — so it
can rebuild a `CircuitProgram` without retraining or re-lowering anything.
`save_program`/`load_program` round-trip exactly that as one compressed
npz (pure integer arrays + float64 thresholds, so a bundle written on one
host serves bit-identically on another).  Every bundle is written with a
sha256 sidecar (`<bundle>.sha256`, same story as
`checkpoint/manager.py`'s leaves checksum): `load_program` refuses a
truncated or bit-flipped bundle with `ArtifactCorruptError` instead of
serving garbage labels.

An emit directory accumulates one bundle per classifier plus a single
``fleet.json`` manifest listing every tenant (`register_tenant` is
last-write-wins per name, so re-emitting a design replaces its row).  The
manifest carries a monotonically increasing **generation** counter —
bumped on every register — and stamps each row with the generation that
wrote it, which is what lets a live `ClassifierFleet.sync_manifest()`
tell "same tenant, re-emitted program" from "nothing changed" without
hashing bundles.  Rows may also carry serving hints (`replicas`): the
manifest is the handshake between the emit side (`repro.evolve
--emit-dir`, `python -m repro.compile.export`) and the serving side
(`repro.serve.ClassifierFleet.from_emit_dir`): a fleet is "whatever this
directory says it serves".
"""
from __future__ import annotations

import hashlib
import json
import os
from pathlib import Path

import numpy as np

from repro.compile.ir import CircuitIR, CompiledClassifier
from repro.compile.program import CircuitProgram

MANIFEST_NAME = "fleet.json"
MANIFEST_VERSION = 1
PROGRAM_SUFFIX = "_program.npz"
SHA_SUFFIX = ".sha256"


class ArtifactCorruptError(RuntimeError):
    """A program bundle failed its sha256 (truncated/bit-flipped on disk)."""


def _sha256_file(path: Path) -> str:
    h = hashlib.sha256()
    with open(path, "rb") as f:
        for chunk in iter(lambda: f.read(1 << 20), b""):
            h.update(chunk)
    return h.hexdigest()


def save_program(cc: CompiledClassifier, path: str | Path) -> str:
    """Write the servable slice of a `CompiledClassifier` as one npz.

    A `<path>.sha256` sidecar records the bundle digest (written only
    after the payload it vouches for), so `load_program` can detect
    corruption the way checkpoint restore does.
    """
    ir = cc.ir
    path = Path(path)
    path.parent.mkdir(parents=True, exist_ok=True)
    header = {
        "version": MANIFEST_VERSION,
        "name": ir.name,
        "meta": ir.meta,
        "taps": sorted(ir.taps),
        "n_classes": cc.n_classes,
        "score_bits": cc.score_bits,
    }
    arrays = {
        "n_inputs": np.int64(ir.n_inputs),
        "op": ir.op,
        "in0": ir.in0,
        "in1": ir.in1,
        "outputs": ir.outputs,
        "levels": ir.levels,
        "thresholds": np.asarray(cc.thresholds, dtype=np.float64),
        "header_json": np.frombuffer(
            json.dumps(header, sort_keys=True).encode(), dtype=np.uint8),
    }
    for key in header["taps"]:
        arrays[f"tap_{key}"] = ir.taps[key]
    with open(path, "wb") as f:
        np.savez_compressed(f, **arrays)
        f.flush()
        os.fsync(f.fileno())
    digest = _sha256_file(path)
    path.with_name(path.name + SHA_SUFFIX).write_text(digest + "\n")
    return str(path)


def verify_program_bundle(path: str | Path,
                          expect_sha256: str | None = None) -> str | None:
    """Check `path` against its sha256 sidecar; returns the digest.

    Returns None when neither a sidecar nor `expect_sha256` exists
    (pre-checksum bundle — accepted for compatibility); raises
    `ArtifactCorruptError` on any mismatch or an unreadable payload.

    `expect_sha256` is the digest an *external record* claims for this
    bundle — a manifest row, a decision journal — and is cross-checked
    against the actual file: a sidecar that agrees with its bundle can
    still disagree with the manifest row that promised it (stale emit,
    swapped file, tampered row), and serving under the wrong identity is
    exactly as bad as serving corrupt bits.
    """
    path = Path(path)
    sidecar = path.with_name(path.name + SHA_SUFFIX)
    if not path.exists():
        raise ArtifactCorruptError(f"program bundle {path} does not exist")
    if not sidecar.exists() and expect_sha256 is None:
        return None
    got = _sha256_file(path)
    if sidecar.exists():
        want = sidecar.read_text().strip()
        if got != want:
            raise ArtifactCorruptError(
                f"program bundle {path} fails its checksum "
                f"(sha256 {got[:12]}… != recorded {want[:12]}…) — the bundle "
                "was truncated or corrupted on disk; re-emit the artifact")
    if expect_sha256 is not None and got != expect_sha256.strip():
        raise ArtifactCorruptError(
            f"program bundle {path} does not match the manifest row that "
            f"references it (sha256 {got[:12]}… != manifest "
            f"{expect_sha256.strip()[:12]}…) — the row is stale or "
            "tampered; re-emit the artifact")
    return got


def load_program(path: str | Path, backend: str = "jax",
                 devices: tuple | None = None,
                 expect_sha256: str | None = None,
                 **program_kw) -> CircuitProgram:
    """Rebuild a classifier `CircuitProgram` from a `save_program` bundle.

    Validates the bundle against its sha256 sidecar first: a truncated or
    bit-flipped npz raises `ArtifactCorruptError` with a clear message
    instead of a deep numpy decode error (or, worse, silently wrong
    labels).  `expect_sha256` additionally cross-checks the digest a
    manifest row recorded for this bundle (see `verify_program_bundle`).
    """
    path = Path(path)
    verify_program_bundle(path, expect_sha256=expect_sha256)
    try:
        with np.load(path) as fix:
            header = json.loads(bytes(fix["header_json"]).decode())
            ir = CircuitIR(
                n_inputs=int(fix["n_inputs"]),
                op=fix["op"].astype(np.int16),
                in0=fix["in0"].astype(np.int32),
                in1=fix["in1"].astype(np.int32),
                outputs=fix["outputs"].astype(np.int32),
                levels=fix["levels"].astype(np.int32),
                taps={k: fix[f"tap_{k}"].astype(np.int32)
                      for k in header["taps"]},
                name=header["name"],
                meta=header["meta"],
            )
            thresholds = fix["thresholds"].astype(np.float64)
    except ArtifactCorruptError:
        raise
    except Exception as exc:   # an unreadable archive that passed (or had no)
        raise ArtifactCorruptError(          # checksum is still corruption
            f"program bundle {path} cannot be decoded "
            f"({type(exc).__name__}: {exc}) — re-emit the artifact") from exc
    ir.to_netlist()   # validates feed-forwardness before anything executes
    return CircuitProgram(ir=ir, thresholds=thresholds,
                          n_classes=header["n_classes"], backend=backend,
                          devices=devices, **program_kw)


# -- fleet manifest ---------------------------------------------------------
def manifest_path(emit_dir: str | Path) -> Path:
    return Path(emit_dir) / MANIFEST_NAME


def load_manifest_doc(emit_dir: str | Path) -> dict:
    """The full manifest document: version, generation, sorted tenant rows."""
    path = manifest_path(emit_dir)
    if not path.exists():
        raise FileNotFoundError(
            f"no {MANIFEST_NAME} under {emit_dir} — emit artifacts first "
            "(repro.evolve --emit-dir / python -m repro.compile.export)")
    doc = json.loads(path.read_text())
    if doc.get("version") != MANIFEST_VERSION:
        raise ValueError(f"unsupported manifest version {doc.get('version')}")
    doc.setdefault("generation", 0)
    doc["tenants"] = sorted(doc["tenants"], key=lambda t: t["name"])
    return doc


def load_manifest(emit_dir: str | Path) -> list[dict]:
    """Tenant rows of `emit_dir`'s fleet manifest (sorted by name)."""
    return load_manifest_doc(emit_dir)["tenants"]


def register_tenant(emit_dir: str | Path, entry: dict) -> Path:
    """Add/replace one tenant row in `emit_dir`'s manifest (atomic write).

    `entry` must carry at least name/program; paths are stored relative to
    the emit dir so the directory can be tarred up and served elsewhere.
    Every call bumps the manifest's generation counter and stamps the row
    with it — a live fleet watching the file reloads exactly the rows
    whose generation moved.
    """
    if "name" not in entry or "program" not in entry:
        raise ValueError("manifest entry needs at least name + program")
    emit_dir = Path(emit_dir)
    emit_dir.mkdir(parents=True, exist_ok=True)
    path = manifest_path(emit_dir)
    tenants, generation = [], 0
    if path.exists():
        doc = json.loads(path.read_text())
        generation = int(doc.get("generation", 0))
        tenants = [t for t in doc.get("tenants", [])
                   if t["name"] != entry["name"]]
    generation += 1
    entry = {k: (os.path.relpath(v, emit_dir)
                 if k in ("program", "verilog", "report") else v)
             for k, v in entry.items()}
    entry["generation"] = generation
    tenants.append(entry)
    doc = {"version": MANIFEST_VERSION, "generation": generation,
           "tenants": sorted(tenants, key=lambda t: t["name"])}
    tmp = path.with_suffix(".json.tmp")
    tmp.write_text(json.dumps(doc, indent=2, sort_keys=True) + "\n")
    os.replace(tmp, path)
    return path
