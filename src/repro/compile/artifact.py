"""Servable artifact bundles + the fleet manifest.

The Verilog + EGFET report that `write_artifacts` emits are what a printed
fab consumes; a *serving* process needs the executable side of the same
design — the levelized `CircuitIR` arrays plus the ABC thresholds — so it
can rebuild a `CircuitProgram` without retraining or re-lowering anything.
`save_program`/`load_program` round-trip exactly that as one compressed
npz (pure integer arrays + float64 thresholds, so a bundle written on one
host serves bit-identically on another).

An emit directory accumulates one bundle per classifier plus a single
``fleet.json`` manifest listing every tenant (`register_tenant` is
last-write-wins per name, so re-emitting a design replaces its row).  The
manifest is the handshake between the emit side (`repro.evolve --emit-dir`,
`python -m repro.compile.export`) and the serving side
(`repro.serve.ClassifierFleet.from_emit_dir`): a fleet is "whatever this
directory says it serves".
"""
from __future__ import annotations

import json
import os
from pathlib import Path

import numpy as np

from repro.compile.ir import CircuitIR, CompiledClassifier
from repro.compile.program import CircuitProgram

MANIFEST_NAME = "fleet.json"
MANIFEST_VERSION = 1
PROGRAM_SUFFIX = "_program.npz"


def save_program(cc: CompiledClassifier, path: str | Path) -> str:
    """Write the servable slice of a `CompiledClassifier` as one npz."""
    ir = cc.ir
    path = Path(path)
    path.parent.mkdir(parents=True, exist_ok=True)
    header = {
        "version": MANIFEST_VERSION,
        "name": ir.name,
        "meta": ir.meta,
        "taps": sorted(ir.taps),
        "n_classes": cc.n_classes,
        "score_bits": cc.score_bits,
    }
    arrays = {
        "n_inputs": np.int64(ir.n_inputs),
        "op": ir.op,
        "in0": ir.in0,
        "in1": ir.in1,
        "outputs": ir.outputs,
        "levels": ir.levels,
        "thresholds": np.asarray(cc.thresholds, dtype=np.float64),
        "header_json": np.frombuffer(
            json.dumps(header, sort_keys=True).encode(), dtype=np.uint8),
    }
    for key in header["taps"]:
        arrays[f"tap_{key}"] = ir.taps[key]
    np.savez_compressed(path, **arrays)
    return str(path)


def load_program(path: str | Path, backend: str = "jax",
                 devices: tuple | None = None) -> CircuitProgram:
    """Rebuild a classifier `CircuitProgram` from a `save_program` bundle."""
    with np.load(Path(path)) as fix:
        header = json.loads(bytes(fix["header_json"]).decode())
        ir = CircuitIR(
            n_inputs=int(fix["n_inputs"]),
            op=fix["op"].astype(np.int16),
            in0=fix["in0"].astype(np.int32),
            in1=fix["in1"].astype(np.int32),
            outputs=fix["outputs"].astype(np.int32),
            levels=fix["levels"].astype(np.int32),
            taps={k: fix[f"tap_{k}"].astype(np.int32)
                  for k in header["taps"]},
            name=header["name"],
            meta=header["meta"],
        )
        thresholds = fix["thresholds"].astype(np.float64)
    ir.to_netlist()   # validates feed-forwardness before anything executes
    return CircuitProgram(ir=ir, thresholds=thresholds,
                          n_classes=header["n_classes"], backend=backend,
                          devices=devices)


# -- fleet manifest ---------------------------------------------------------
def manifest_path(emit_dir: str | Path) -> Path:
    return Path(emit_dir) / MANIFEST_NAME


def load_manifest(emit_dir: str | Path) -> list[dict]:
    """Tenant rows of `emit_dir`'s fleet manifest (sorted by name)."""
    path = manifest_path(emit_dir)
    if not path.exists():
        raise FileNotFoundError(
            f"no {MANIFEST_NAME} under {emit_dir} — emit artifacts first "
            "(repro.evolve --emit-dir / python -m repro.compile.export)")
    doc = json.loads(path.read_text())
    if doc.get("version") != MANIFEST_VERSION:
        raise ValueError(f"unsupported manifest version {doc.get('version')}")
    return sorted(doc["tenants"], key=lambda t: t["name"])


def register_tenant(emit_dir: str | Path, entry: dict) -> Path:
    """Add/replace one tenant row in `emit_dir`'s manifest (atomic write).

    `entry` must carry at least name/program; paths are stored relative to
    the emit dir so the directory can be tarred up and served elsewhere.
    """
    if "name" not in entry or "program" not in entry:
        raise ValueError("manifest entry needs at least name + program")
    emit_dir = Path(emit_dir)
    emit_dir.mkdir(parents=True, exist_ok=True)
    path = manifest_path(emit_dir)
    tenants = []
    if path.exists():
        doc = json.loads(path.read_text())
        tenants = [t for t in doc.get("tenants", [])
                   if t["name"] != entry["name"]]
    entry = {k: (os.path.relpath(v, emit_dir)
                 if k in ("program", "verilog", "report") else v)
             for k, v in entry.items()}
    tenants.append(entry)
    doc = {"version": MANIFEST_VERSION,
           "tenants": sorted(tenants, key=lambda t: t["name"])}
    tmp = path.with_suffix(".json.tmp")
    tmp.write_text(json.dumps(doc, indent=2, sort_keys=True) + "\n")
    os.replace(tmp, path)
    return path
