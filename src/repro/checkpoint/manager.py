"""Checkpointing: atomic, validated, retained, background-capable, elastic.

Design (DESIGN.md §6):
  * atomicity  — write into `<dir>/.tmp-<step>`, fsync every file, then
    `os.rename` to `<dir>/step_<N>` (atomic on POSIX); a crash mid-save
    never corrupts the latest checkpoint;
  * validation — the manifest records a sha256 of the leaf payload, written
    *after* the payload is durable; `restore()` verifies it, and a snapshot
    truncated or bit-flipped mid-write is detected instead of half-loaded.
    With `step=None` restore walks newest -> oldest and transparently falls
    back to the most recent *valid* snapshot (the SIGKILL-mid-save story for
    `repro.evolve` campaign resume);
  * manifest   — msgpack with step, leaf paths, shapes, dtypes; leaves are
    stored in a single .npz keyed by leaf index (paths recorded for safety);
  * retention  — keep the most recent `keep` checkpoints;
  * background — `save(..., background=True)` snapshots to host memory
    synchronously (cheap) and writes to disk on a thread, so the train loop
    is blocked only for the device->host copy;
  * elasticity — `restore(template, mesh, specs)` re-device_puts every leaf
    with the *current* mesh's NamedSharding: a job restarted on a different
    topology reshards transparently (logical arrays are global).
    `restore(..., to_device=False)` keeps leaves as host numpy arrays with
    their exact saved dtypes — required for bit-identical resume of int64 /
    float64 search state, which `jnp.asarray` would silently narrow under
    JAX's default x64-disabled config.

Single-process container note: arrays are gathered to host before writing.
On a real multi-host pod this becomes per-host shard files keyed by
(process_index, shard_index) — the manifest format already carries what's
needed; the gather/scatter is the only host-local piece.
"""
from __future__ import annotations

import hashlib
import os
import re
import shutil
import threading
from typing import Any

import jax
import jax.numpy as jnp
import msgpack
import numpy as np

_STEP_RE = re.compile(r"^step_(\d+)$")


class CheckpointCorruptError(RuntimeError):
    """A specific requested snapshot failed validation."""


def _leaf_paths(tree: Any) -> list[str]:
    paths = []
    for kp, _ in jax.tree_util.tree_flatten_with_path(tree)[0]:
        paths.append(jax.tree_util.keystr(kp))
    return paths


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: threading.Thread | None = None

    # -- discovery -----------------------------------------------------------
    def all_steps(self) -> list[int]:
        steps = []
        for name in os.listdir(self.dir):
            m = _STEP_RE.match(name)
            if m and os.path.exists(os.path.join(self.dir, name, "MANIFEST.msgpack")):
                steps.append(int(m.group(1)))
        return sorted(steps)

    def latest_step(self) -> int | None:
        steps = self.all_steps()
        return steps[-1] if steps else None

    # -- save -----------------------------------------------------------------
    def save(self, step: int, state: Any, extra: dict | None = None,
             background: bool = False) -> None:
        leaves, _ = jax.tree_util.tree_flatten(state)
        host_leaves = [np.asarray(x) for x in leaves]      # device -> host
        manifest = {
            "step": int(step),
            "paths": _leaf_paths(state),
            "shapes": [list(a.shape) for a in host_leaves],
            "dtypes": [str(a.dtype) for a in host_leaves],
            "extra": extra or {},
        }
        if background:
            self.wait()
            self._thread = threading.Thread(
                target=self._write, args=(step, host_leaves, manifest), daemon=True)
            self._thread.start()
        else:
            self._write(step, host_leaves, manifest)

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _write(self, step: int, host_leaves: list[np.ndarray], manifest: dict) -> None:
        tmp = os.path.join(self.dir, f".tmp-{step}")
        final = os.path.join(self.dir, f"step_{step}")
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        # store raw bytes: npz cannot roundtrip ml_dtypes (bfloat16 etc.)
        leaves_path = os.path.join(tmp, "leaves.npz")
        with open(leaves_path, "wb") as f:
            np.savez(f, **{f"leaf_{i}": np.ascontiguousarray(a).view(np.uint8)
                           for i, a in enumerate(host_leaves)})
            f.flush()
            os.fsync(f.fileno())
        with open(leaves_path, "rb") as f:
            manifest["leaves_sha256"] = hashlib.sha256(f.read()).hexdigest()
        # manifest lands only after the payload it vouches for is durable
        with open(os.path.join(tmp, "MANIFEST.msgpack"), "wb") as f:
            f.write(msgpack.packb(manifest))
            f.flush()
            os.fsync(f.fileno())
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
        try:
            dfd = os.open(self.dir, os.O_RDONLY)
            try:
                os.fsync(dfd)                      # persist the rename itself
            finally:
                os.close(dfd)
        except OSError:
            pass
        self._retain()

    # -- validation ----------------------------------------------------------
    def validate(self, step: int) -> bool:
        """True iff snapshot `step` is complete and passes its checksum."""
        d = os.path.join(self.dir, f"step_{step}")
        try:
            with open(os.path.join(d, "MANIFEST.msgpack"), "rb") as f:
                manifest = msgpack.unpackb(f.read())
            with open(os.path.join(d, "leaves.npz"), "rb") as f:
                payload = f.read()
            want = manifest.get("leaves_sha256")
            if want is not None:
                if hashlib.sha256(payload).hexdigest() != want:
                    return False
            else:
                # pre-checksum snapshot: at least require a loadable archive
                np.load(os.path.join(d, "leaves.npz")).close()
            return True
        except Exception:   # noqa: BLE001 — any decode failure is "invalid"
            return False

    def latest_valid_step(self) -> int | None:
        for s in reversed(self.all_steps()):
            if self.validate(s):
                return s
        return None

    def _retain(self) -> None:
        steps = self.all_steps()
        for s in steps[: max(0, len(steps) - self.keep)]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s}"), ignore_errors=True)

    # -- restore ---------------------------------------------------------------
    def restore(self, template: Any, step: int | None = None,
                mesh=None, specs: Any = None,
                to_device: bool = True) -> tuple[int, Any, dict]:
        """Restore into the structure of `template` (abstract or concrete).

        With (mesh, specs): every leaf is device_put with the current mesh's
        NamedSharding — elastic resharding across topologies.  `step=None`
        picks the newest snapshot that passes validation (a truncated or
        corrupt latest snapshot is skipped, falling back to its predecessor);
        an explicit `step` that fails validation raises
        `CheckpointCorruptError`.  `to_device=False` returns host numpy
        arrays with the exact saved dtypes (no jnp narrowing)."""
        if step is None:
            step = self.latest_valid_step()
            if step is None:
                raise FileNotFoundError(
                    f"no valid checkpoints under {self.dir}")
        elif not self.validate(step):
            raise CheckpointCorruptError(
                f"checkpoint step {step} under {self.dir} is missing or "
                "fails its checksum")
        d = os.path.join(self.dir, f"step_{step}")
        with open(os.path.join(d, "MANIFEST.msgpack"), "rb") as f:
            manifest = msgpack.unpackb(f.read())
        data = np.load(os.path.join(d, "leaves.npz"))
        leaves, treedef = jax.tree_util.tree_flatten(template)
        saved_paths = manifest["paths"]
        tmpl_paths = _leaf_paths(template)
        if saved_paths != tmpl_paths:
            raise ValueError(
                "checkpoint/template structure mismatch: "
                f"{set(saved_paths) ^ set(tmpl_paths)}")
        out = []
        spec_leaves = (jax.tree_util.tree_flatten(
            specs, is_leaf=lambda s: isinstance(s, jax.sharding.PartitionSpec)
        )[0] if specs is not None else [None] * len(leaves))
        import ml_dtypes
        for i, (leaf, sp) in enumerate(zip(leaves, spec_leaves)):
            raw = data[f"leaf_{i}"]
            dt_str = manifest["dtypes"][i]
            shape = tuple(manifest["shapes"][i])
            try:
                dtype = np.dtype(dt_str)
            except TypeError:
                dtype = np.dtype(getattr(ml_dtypes, dt_str))
            arr = raw.view(dtype).reshape(shape)
            want_dtype = leaf.dtype if hasattr(leaf, "dtype") else dtype
            if np.dtype(want_dtype) != dtype:
                arr = arr.astype(want_dtype)
            if mesh is not None and sp is not None:
                out.append(jax.device_put(
                    arr, jax.sharding.NamedSharding(mesh, sp)))
            elif to_device:
                out.append(jnp.asarray(arr))
            else:
                out.append(np.asarray(arr))
        return int(manifest["step"]), jax.tree_util.tree_unflatten(treedef, out), \
            manifest.get("extra", {})
