"""Backend dispatch + device sharding for population circuit simulation.

One entry point, three interchangeable bit-identical executors for the
population x packed-word gate-simulation hot loop:

  * ``np``     — `NetlistPopulation` structure-of-arrays uint64 simulation
    (host reference);
  * ``swar``   — the jitted `lax.scan` uint32-SWAR twin in
    `kernels.circuit_sim` (the PR 1 device path / benchmark baseline);
  * ``pallas`` — the Pallas kernel in `kernels.pallas_circuit_sim`
    (compiled on TPU, interpret-mode elsewhere).

Device sharding: for the device backends the population axis is split
round-even across `jax.local_devices()` (or an explicit device list) —
fitness rows are independent, so each device simulates its slice of
genomes against the (shared or per-individual) word plane and results
concatenate on host.  On this container that degenerates to a single CPU
device; the split logic is identical for an 8-chip pod.

This lives in `kernels` (not `repro.evolve`) so consumers below the
orchestration layer — e.g. `core.tnn.TNNApproxProblem` — can select a
backend without importing upward; `repro.evolve.evaluator` re-exports it
as the campaign-facing API.
"""
from __future__ import annotations

import numpy as np

from repro.core.circuits import NetlistPopulation

BACKENDS = ("np", "swar", "pallas")


def configure_worker_process(n_procs: int = 1) -> None:
    """Cap math-library threading for a serve worker subprocess.

    Must run *before* the first jax / BLAS import in the child: a fleet
    spawning N worker processes on an M-core host wants each child's
    intra-op thread pools sized ~M/N, not M — otherwise N children times
    M threads oversubscribe the host and the per-dispatch latency the
    deadline policy feeds on turns to noise.  `setdefault` keeps any
    operator-provided caps; jax is left on its normal platform selection
    (CPU on this container) and device counts are untouched, so worker
    replicas still pin through `replica_devices` identically to in-process
    ones.
    """
    import os

    if n_procs < 1:
        raise ValueError("n_procs must be >= 1")
    cores = len(os.sched_getaffinity(0)) if hasattr(os, "sched_getaffinity") \
        else (os.cpu_count() or 1)
    per = str(max(1, cores // n_procs))
    for var in ("OMP_NUM_THREADS", "OPENBLAS_NUM_THREADS",
                "MKL_NUM_THREADS", "XLA_CPU_MULTI_THREAD_EIGEN_THREADS"):
        os.environ.setdefault(var, per)


def replica_devices(index: int, devices=None) -> tuple:
    """Round-robin device pin for serving-engine replica `index`.

    A fleet tenant running N engine replicas wants replica i's dispatches
    resident on local device ``i % n_devices`` so hot-tenant batches
    overlap across chips instead of queueing on one; the returned 1-tuple
    plugs straight into `CircuitProgram(devices=...)`, whose
    `program_eval_words` treats any explicit device list as a pinning
    request (device_put even for a single shard).  On this single-device
    container every replica pins to the same CPU device — the round-robin
    is identical on an 8-chip pod.
    """
    if index < 0:
        raise ValueError("replica index must be >= 0")
    import jax

    devs = list(devices) if devices is not None else jax.local_devices()
    if not devs:
        raise ValueError("no devices to pin replicas to")
    return (devs[index % len(devs)],)


def _device_slices(P: int, n_dev: int) -> list[slice]:
    """Round-even contiguous row slices, one per device (empty ones drop)."""
    per = -(-P // n_dev)
    return [slice(s, min(s + per, P)) for s in range(0, P, per)]


def _pallas_kwargs(block_words, interpret) -> dict:
    """Only non-default Pallas knobs, so jit static-arg caches stay warm."""
    kw = {}
    if block_words is not None:
        kw["block_words"] = int(block_words)
    if interpret is not None:
        kw["interpret"] = bool(interpret)
    return kw


def _eval_device(op, in0, in1, outputs, packed_u64, n_inputs, backend,
                 devices, block_words=None, interpret=None) -> np.ndarray:
    import jax

    from repro.kernels import circuit_sim as CS
    if backend == "pallas":
        from functools import partial

        from repro.kernels import pallas_circuit_sim as PS
        eval_fn = partial(PS.population_eval_uint,
                          **_pallas_kwargs(block_words, interpret))
    else:
        eval_fn = CS.population_eval_uint
    words32 = CS.pack_words32(packed_u64)
    per_individual = words32.ndim == 3
    devices = list(devices) if devices is not None else jax.local_devices()
    P = op.shape[0]
    slices = (_device_slices(P, len(devices)) if len(devices) > 1
              else [slice(0, P)])
    outs = []
    for sl, dev in zip(slices, devices):
        shard = (op[sl], in0[sl], in1[sl], outputs[sl],
                 words32[sl] if per_individual else words32)
        if len(slices) > 1:
            shard = tuple(jax.device_put(a, dev) for a in shard)
        outs.append(np.asarray(eval_fn(*shard, n_inputs)))
    return np.concatenate(outs, axis=0) if len(outs) > 1 else outs[0]


def population_eval_uint(op: np.ndarray, in0: np.ndarray, in1: np.ndarray,
                         outputs: np.ndarray, packed_u64: np.ndarray,
                         n_inputs: int, backend: str = "swar",
                         devices=None, block_words=None,
                         interpret=None) -> np.ndarray:
    """Per-vector decoded outputs `(P, S)` for a population of netlists.

    `packed_u64` is `(n_inputs, W)` shared or `(P, n_inputs, W)`
    per-individual uint64 words; every backend returns the same integers
    for the same words (rows are `Netlist.eval_uint` of the row's genome).

    `block_words` / `interpret` are Pallas tuning knobs (word-tile width
    and interpret-mode override) forwarded to
    `pallas_circuit_sim.population_eval_uint`; the other backends ignore
    them, so campaign/tenant configs can set them unconditionally.
    """
    if backend not in BACKENDS:
        raise ValueError(f"unknown eval backend {backend!r}; "
                         f"valid: {', '.join(BACKENDS)}")
    if backend == "np":
        pop = NetlistPopulation(n_inputs, np.asarray(op, dtype=np.int16),
                                np.asarray(in0, dtype=np.int32),
                                np.asarray(in1, dtype=np.int32),
                                np.asarray(outputs, dtype=np.int32))
        return pop.eval_uint(packed_u64)
    op32 = np.asarray(op, dtype=np.int32)
    return _eval_device(op32, np.asarray(in0, dtype=np.int32),
                        np.asarray(in1, dtype=np.int32),
                        np.asarray(outputs, dtype=np.int32),
                        packed_u64, n_inputs, backend, devices,
                        block_words=block_words,
                        interpret=interpret).astype(np.int64)


def population_eval_pop(pop: NetlistPopulation, packed_u64: np.ndarray,
                        backend: str = "swar", devices=None,
                        block_words=None, interpret=None) -> np.ndarray:
    """`population_eval_uint` over an existing `NetlistPopulation`."""
    return population_eval_uint(pop.op, pop.in0, pop.in1, pop.outputs,
                                packed_u64, pop.n_inputs, backend=backend,
                                devices=devices, block_words=block_words,
                                interpret=interpret)


def program_eval_words(op: np.ndarray, in0: np.ndarray, in1: np.ndarray,
                       outputs: np.ndarray, words32: np.ndarray,
                       n_inputs: int, backend: str = "swar",
                       devices=None, block_words=None,
                       interpret=None) -> np.ndarray:
    """Single-program serving dispatch: `(n_inputs, W)` uint32 words ->
    `(P, W*32)` int64 decoded outputs, on any backend.

    The population twin of `population_eval_uint` shards the *population*
    axis; a serving engine runs one program (P=1 plan rows) over a large
    batch, so here the independent axis is the packed *word* plane — for
    the device backends large batches split round-even along the word axis
    across `jax.local_devices()` (or an explicit device list) and results
    concatenate on host.  `repro.serve` pins each fleet tenant's dispatches
    through this entry point, so a tenant maps to `np`/`swar`/`pallas`
    exactly like a campaign evaluator does.
    """
    if backend not in BACKENDS:
        raise ValueError(f"unknown eval backend {backend!r}; "
                         f"valid: {', '.join(BACKENDS)}")
    op = np.asarray(op)
    words32 = np.ascontiguousarray(words32, dtype=np.uint32)
    if words32.ndim != 2:
        raise ValueError("program_eval_words wants a shared (n_inputs, W) "
                         "word plane")
    if backend == "np":
        # repack the uint32 lanes as the uint64 words the reference eats —
        # the inverse of pack_words32, whose contract is that lane 2k holds
        # the LOW 32 bits of word k and lane 2k+1 the high 32.  A
        # `.view(np.uint64)` only honours that on little-endian hosts, so
        # combine the lanes arithmetically instead of reinterpreting bytes.
        W32 = words32.shape[1]
        if W32 % 2:
            words32 = np.concatenate(
                [words32, np.zeros((words32.shape[0], 1), np.uint32)], axis=1)
        lo = words32[:, 0::2].astype(np.uint64)
        hi = words32[:, 1::2].astype(np.uint64)
        packed_u64 = np.ascontiguousarray(lo | (hi << np.uint64(32)))
        pop = NetlistPopulation(n_inputs, np.asarray(op, dtype=np.int16),
                                np.asarray(in0, dtype=np.int32),
                                np.asarray(in1, dtype=np.int32),
                                np.asarray(outputs, dtype=np.int32))
        return pop.eval_uint(packed_u64)[:, : W32 * 32]

    import jax

    from repro.kernels import circuit_sim as CS
    if backend == "pallas":
        from functools import partial

        from repro.kernels import pallas_circuit_sim as PS
        eval_fn = partial(PS.population_eval_uint,
                          **_pallas_kwargs(block_words, interpret))
    else:
        eval_fn = CS.population_eval_uint
    plan = (np.asarray(op, dtype=np.int32), np.asarray(in0, dtype=np.int32),
            np.asarray(in1, dtype=np.int32),
            np.asarray(outputs, dtype=np.int32))
    # an explicit device list is a pinning request even when it yields a
    # single shard — only the implicit all-local-devices default may skip
    # the device_put and run wherever jit places it
    pinned = devices is not None
    devices = list(devices) if pinned else jax.local_devices()
    W = words32.shape[1]
    slices = (_device_slices(W, len(devices)) if len(devices) > 1
              else [slice(0, W)])
    outs = []
    for sl, dev in zip(slices, devices):
        shard = words32[:, sl]
        if pinned or len(slices) > 1:
            shard = jax.device_put(shard, dev)
        outs.append(np.asarray(eval_fn(*plan, shard, n_inputs)))
    out = np.concatenate(outs, axis=1) if len(outs) > 1 else outs[0]
    return out.astype(np.int64)


def fleet_eval_words(plans: list, words_list: list, backend: str = "pallas",
                     block_words=None, interpret=None) -> list[np.ndarray]:
    """Whole-manifest serving dispatch: T tenants' circuits in ONE launch.

    `plans` holds one `(op, in0, in1, outputs, n_inputs)` plan tuple per
    tenant (P=1 rows or flat 1-D arrays both accepted) and `words_list`
    the matching `(n_inputs_t, W_t)` uint32 word planes.  On the
    ``pallas`` backend this pads every tenant's gate-op/ANF-mask tables
    to a common gate budget and runs the multi-program megakernel —
    grid over (tenant x word-tile), one `pallas_call` for the manifest.
    ``np``/``swar`` fall back to per-tenant `program_eval_words` loops
    (same answers, T launches), so callers can flip backends freely.

    Returns one `(W_t * 32,)` int64 decoded-label array per tenant,
    bit-identical to dispatching each tenant through
    `program_eval_words` on its own.
    """
    if backend not in BACKENDS:
        raise ValueError(f"unknown eval backend {backend!r}; "
                         f"valid: {', '.join(BACKENDS)}")
    if backend == "pallas":
        from repro.kernels import pallas_circuit_sim as PS
        outs = PS.fleet_eval_words(plans, words_list,
                                   **_pallas_kwargs(block_words, interpret))
        return [np.asarray(o, dtype=np.int64) for o in outs]
    outs = []
    for (op, in0, in1, outputs, n_in), w in zip(plans, words_list):
        out = program_eval_words(
            np.asarray(op).reshape(1, -1), np.asarray(in0).reshape(1, -1),
            np.asarray(in1).reshape(1, -1),
            np.asarray(outputs).reshape(1, -1), w, n_in, backend=backend)
        outs.append(np.asarray(out[0], dtype=np.int64))
    return outs


def population_pc_errors(pop: NetlistPopulation, packed_u64: np.ndarray,
                         true: np.ndarray, backend: str = "swar",
                         devices=None) -> tuple[np.ndarray, np.ndarray]:
    """Per-individual (mae, wcae) against true counts, any backend."""
    if backend == "np":
        return pop.pc_errors(packed_u64, true)
    approx = population_eval_pop(pop, packed_u64, backend=backend,
                                 devices=devices)
    err = np.abs(approx - np.asarray(true)[None, :])
    return err.mean(axis=1), err.max(axis=1).astype(np.float64)
