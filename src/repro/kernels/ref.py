"""Pure-jnp oracles for every Pallas kernel (and the CPU/dry-run lowering).

`ops.py` dispatches: Pallas on TPU, these references elsewhere.  Tests sweep
shapes/dtypes and assert the interpret-mode kernels match these bit-exactly
(integer paths) or to fp tolerance (matmul paths).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.ternary import unpack_ternary


def ternary_matmul_ref(x: jax.Array, w2: jax.Array, scale: jax.Array
                       ) -> jax.Array:
    """x: (M, K) float; w2: (K//4, N) int8 2-bit codes; scale: (1, N).

    Returns (M, N) f32 = (x @ unpack(w2)) * scale.
    """
    w = unpack_ternary(w2, dtype=jnp.float32)
    y = x.astype(jnp.float32) @ w
    return y * scale.astype(jnp.float32)


def packed_popcount_ref(words: jax.Array) -> jax.Array:
    """words: (B, W) uint32 bit-packed -> (B,) int32 popcount (SWAR)."""
    v = words.astype(jnp.uint32)
    v = v - ((v >> 1) & jnp.uint32(0x55555555))
    v = (v & jnp.uint32(0x33333333)) + ((v >> 2) & jnp.uint32(0x33333333))
    v = (v + (v >> 4)) & jnp.uint32(0x0F0F0F0F)
    v = (v * jnp.uint32(0x01010101)) >> 24
    return v.astype(jnp.int32).sum(axis=-1)


def rwkv6_scan_ref(r: jax.Array, k: jax.Array, v: jax.Array, w: jax.Array,
                   u: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Sequential WKV-6 oracle.  r,k,v,w: (BH, T, dh); u: (BH, dh).

    S_t = diag(w_t) S_{t-1} + k_t^T v_t ;  y_t = r_t (S_{t-1} + diag(u) k_t^T v_t)
    """
    BH, T, dh = r.shape

    def step(S, inp):
        rt, kt, vt, wt = inp                           # (BH, dh) each
        kv = kt[..., :, None] * vt[..., None, :]       # (BH, dh, dh)
        y = jnp.einsum("bk,bkv->bv", rt, S + u[..., :, None] * kv)
        S = wt[..., :, None] * S + kv
        return S, y

    xs = tuple(x.transpose(1, 0, 2).astype(jnp.float32) for x in (r, k, v, w))
    S0 = jnp.zeros((BH, dh, dh), jnp.float32)
    S_fin, ys = jax.lax.scan(step, S0, xs)
    return ys.transpose(1, 0, 2), S_fin


def binary_ternary_matvec_ref(xbits: jax.Array, w2: jax.Array) -> jax.Array:
    """TNN neuron batch: xbits (M, K) in {0,1}; w2 (K//4, N) ternary codes.

    Returns (M, N) int32 = popcount-accumulate sum_k x_k * w_kn — the
    integer semantics of the paper's hidden-layer accumulation.
    """
    w = unpack_ternary(w2, dtype=jnp.int32)
    return xbits.astype(jnp.int32) @ w
