"""Pallas TPU kernel: chunked RWKV-6 WKV recurrence (matmul form).

The per-token recurrence  S_t = diag(w_t) S_{t-1} + k_t^T v_t,
y_t = r_t (S_{t-1} + diag(u) k_t^T v_t)  is sequential and VPU-bound.  The
TPU-native reformulation processes chunks of T_c tokens as dense matmuls
(MXU work, DESIGN.md §4):

  W_t   = prod_{s<=t} w_s                     (cumulative decay, per k-dim)
  a_t   = r_t * W_{t-1},   b_s = k_s / W_s
  A     = strict_lower(a @ b^T) + diag(r_t . (u * k_t))     (T_c x T_c)
  y     = A @ V + a @ S_0
  S_end = W_T * S_0 + (b * W_T)^T @ V

The cumulative product is computed as exp(L @ log w) with L the lower-
triangular ones matrix — a single MXU matmul, avoiding cumprod lowering.
Chunks iterate sequentially per (batch, head) via the innermost grid dim;
the running state lives in a VMEM scratch.

Numerical note: b_s = k_s / W_s grows like prod w^-1 within a chunk, so
T_c must keep max |log w| * T_c well inside f32 range; with RWKV-6 decays
(w >= ~0.6) T_c <= 64 is safe (tested).  ref.py / models/ssm.py hold the
sequential oracle.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(r_ref, k_ref, v_ref, w_ref, u_ref, y_ref, s_out_ref, state_ref,
            *, tc: int, n_chunks: int):
    c = pl.program_id(1)

    @pl.when(c == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    r = r_ref[0].astype(jnp.float32)          # (tc, dh)
    k = k_ref[0].astype(jnp.float32)
    v = v_ref[0].astype(jnp.float32)
    w = w_ref[0].astype(jnp.float32)
    u = u_ref[0].astype(jnp.float32)          # (1, dh)

    logw = jnp.log(jnp.maximum(w, 1e-12))
    tri = (jnp.arange(tc)[:, None] >= jnp.arange(tc)[None, :]).astype(jnp.float32)
    cum = jax.lax.dot_general(tri, logw, (((1,), (0,)), ((), ())),
                              preferred_element_type=jnp.float32)
    W = jnp.exp(cum)                          # (tc, dh): prod_{s<=t} w_s
    W_prev = jnp.exp(cum - logw)              # prod_{s<t}  w_s
    a = r * W_prev
    b = k / jnp.maximum(W, 1e-30)

    A = jax.lax.dot_general(a, b, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)
    strict = (jnp.arange(tc)[:, None] > jnp.arange(tc)[None, :]).astype(jnp.float32)
    diag = jnp.sum(r * (u * k), axis=-1)      # (tc,)
    A = A * strict + jnp.diag(diag)

    S0 = state_ref[...]                       # (dh, dh)
    y = (jax.lax.dot_general(A, v, (((1,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)
         + jax.lax.dot_general(a, S0, (((1,), (0,)), ((), ())),
                               preferred_element_type=jnp.float32))
    y_ref[0] = y.astype(y_ref.dtype)

    WT = W[tc - 1]                            # (dh,)
    bw = b * WT[None, :]
    S_new = WT[:, None] * S0 + jax.lax.dot_general(
        bw, v, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    state_ref[...] = S_new

    @pl.when(c == n_chunks - 1)
    def _done():
        s_out_ref[0] = S_new.astype(s_out_ref.dtype)


def rwkv6_chunked(r: jax.Array, k: jax.Array, v: jax.Array, w: jax.Array,
                  u: jax.Array, *, chunk: int = 32,
                  interpret: bool = False) -> tuple[jax.Array, jax.Array]:
    """r,k,v,w: (BH, T, dh) f32; u: (BH, dh). T % chunk == 0.

    Returns (y (BH, T, dh), final_state (BH, dh, dh))."""
    BH, T, dh = r.shape
    assert T % chunk == 0, (T, chunk)
    nc = T // chunk
    u3 = u[:, None, :]                        # (BH, 1, dh)

    y, s_out = pl.pallas_call(
        functools.partial(_kernel, tc=chunk, n_chunks=nc),
        grid=(BH, nc),
        in_specs=[
            pl.BlockSpec((1, chunk, dh), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, dh), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, dh), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, dh), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, 1, dh), lambda b, c: (b, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, dh), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, dh, dh), lambda b, c: (b, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((BH, T, dh), jnp.float32),
            jax.ShapeDtypeStruct((BH, dh, dh), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((dh, dh), jnp.float32)],
        interpret=interpret,
    )(r, k, v, w, u3)
    return y, s_out
