"""Jit'd public wrappers for the Pallas kernels.

Dispatch policy: Pallas (compiled) on TPU; interpret-mode or the pure-jnp
reference elsewhere.  Model code imports from here so the same graph lowers
on every backend (the CPU dry-run sees the reference HLO; a TPU run sees
the kernels).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.packed_popcount import packed_popcount as _pp_kernel
from repro.kernels.ternary_matmul import ternary_matmul as _tm_kernel


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("use_kernel", "interpret"))
def ternary_matmul(x: jax.Array, w2: jax.Array, scale: jax.Array,
                   use_kernel: bool | None = None,
                   interpret: bool = False) -> jax.Array:
    """(M, K) x packed (K//4, N) ternary -> (M, N) f32."""
    use = _on_tpu() if use_kernel is None else use_kernel
    if use:
        return _tm_kernel(x, w2, scale, interpret=interpret or not _on_tpu())
    return ref.ternary_matmul_ref(x, w2, scale)


@functools.partial(jax.jit, static_argnames=("use_kernel", "interpret"))
def packed_popcount(words: jax.Array, use_kernel: bool | None = None,
                    interpret: bool = False) -> jax.Array:
    """(B, W) uint32 -> (B,) int32."""
    use = _on_tpu() if use_kernel is None else use_kernel
    if use:
        return _pp_kernel(words, interpret=interpret or not _on_tpu())
    return ref.packed_popcount_ref(words)


@functools.partial(jax.jit, static_argnames=("chunk", "use_kernel", "interpret"))
def rwkv6_scan(r: jax.Array, k: jax.Array, v: jax.Array, w: jax.Array,
               u: jax.Array, chunk: int = 32,
               use_kernel: bool | None = None,
               interpret: bool = False) -> tuple[jax.Array, jax.Array]:
    """Chunked WKV-6: (BH, T, dh) x4 + u (BH, dh) -> (y, final_state)."""
    from repro.kernels.rwkv6_scan import rwkv6_chunked
    use = _on_tpu() if use_kernel is None else use_kernel
    if use:
        return rwkv6_chunked(r, k, v, w, u, chunk=chunk,
                             interpret=interpret or not _on_tpu())
    return ref.rwkv6_scan_ref(r, k, v, w, u)
