"""Jittable population-parallel gate-level simulation (uint32 SWAR).

JAX twin of `core.circuits.NetlistPopulation`: a whole population of
same-shape genomes — `(P, n_gates)` opcode/operand plan arrays — evaluated
over all packed test words in one `lax.scan` over gate columns, so CGP
fitness can run on device.  Words are uint32 (JAX disables x64 by default);
`pack_words32` reinterprets the numpy evaluator's uint64 words as pairs of
uint32 lanes in the same SWAR style as `kernels/packed_popcount.py`, which
keeps the two paths bit-compatible: vector s lives in bit (s % 32) of word
(s // 32).

Each gate column applies every individual's opcode simultaneously through
its algebraic normal form r = c0 ^ (ca & a) ^ (cb & b) ^ (cab & a & b)
with per-individual coefficient masks — branch-free, so the scan body is a
fixed handful of vector ops regardless of population size or opcode mix.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.circuits import _ANF_COEFF

_U32 = jnp.uint32
_FULL32 = np.uint32(0xFFFFFFFF)

_N_OPS = max(int(g) for g in _ANF_COEFF) + 1
_C0_TBL = np.zeros(_N_OPS, dtype=np.uint32)
_CA_TBL = np.zeros(_N_OPS, dtype=np.uint32)
_CB_TBL = np.zeros(_N_OPS, dtype=np.uint32)
_CAB_TBL = np.zeros(_N_OPS, dtype=np.uint32)
for _g, (_c0, _ca, _cb, _cab) in _ANF_COEFF.items():
    _C0_TBL[int(_g)] = _FULL32 * np.uint32(_c0)
    _CA_TBL[int(_g)] = _FULL32 * np.uint32(_ca)
    _CB_TBL[int(_g)] = _FULL32 * np.uint32(_cb)
    _CAB_TBL[int(_g)] = _FULL32 * np.uint32(_cab)


def pack_words32(packed_u64: np.ndarray) -> np.ndarray:
    """Reinterpret `(..., n, W)` uint64 packed vectors as `(..., n, 2W)` uint32.

    Little-endian lane split: uint64 word w's low half becomes word 2w, so
    vector s sits in bit (s % 32) of word (s // 32) — the invariant both
    evaluators share.  Leading batch axes (per-individual word planes) pass
    through unchanged.
    """
    packed_u64 = np.ascontiguousarray(packed_u64, dtype=np.uint64)
    *lead, n, W = packed_u64.shape
    return packed_u64.view(np.uint32).reshape(*lead, n, 2 * W)


def pack_bits32(bits: np.ndarray) -> np.ndarray:
    """Pack a `(S, n)` 0/1 matrix straight into `(n, ceil(S/32))` uint32 words.

    The direct 32-bit twin of `circuits.pack_vectors` (vector s in bit
    (s % 32) of word (s // 32)) without the uint64 detour — the serving hot
    path packs each request batch exactly once, so the pad only rounds S up
    to 32 instead of 64.
    """
    bits = np.asarray(bits)
    S, n = bits.shape
    W = (S + 31) // 32
    padded = np.zeros((W * 32, n), dtype=np.uint8)
    padded[:S] = bits.astype(np.uint8)
    blocks = padded.reshape(W, 32, n)
    weights = (np.uint32(1) << np.arange(32, dtype=np.uint32))[None, :, None]
    words = (blocks.astype(np.uint32) * weights).sum(axis=1, dtype=np.uint32)
    return np.ascontiguousarray(words.T)


@partial(jax.jit, static_argnames=("n_inputs",))
def simulate_population(op: jax.Array, in0: jax.Array, in1: jax.Array,
                        outputs: jax.Array, words32: jax.Array,
                        n_inputs: int) -> jax.Array:
    """op/in0/in1: (P, G) int32; outputs: (P, n_out) int32;
    words32: (n_inputs, W) uint32 shared test words, or (P, n_inputs, W)
    per-individual words (the TNN integration scores every genome on its own
    packed input plane).

    Returns (P, n_out, W) uint32 output words, bit-identical (lane-split)
    to `NetlistPopulation.simulate`.
    """
    P, G = op.shape
    W = words32.shape[-1]
    c0 = jnp.asarray(_C0_TBL)[op]      # (P, G) uint32 ANF masks
    ca = jnp.asarray(_CA_TBL)[op]
    cb = jnp.asarray(_CB_TBL)[op]
    cab = jnp.asarray(_CAB_TBL)[op]

    vals = jnp.zeros((P, n_inputs + G, W), dtype=_U32)
    inw = words32.astype(_U32)
    vals = vals.at[:, :n_inputs].set(inw[None] if inw.ndim == 2 else inw)

    def body(vals, xs):
        g, i0, i1, m0, ma, mb, mab = xs
        a = jnp.take_along_axis(vals, i0[:, None, None], axis=1)[:, 0]
        b = jnp.take_along_axis(vals, i1[:, None, None], axis=1)[:, 0]
        r = (m0[:, None] ^ (ma[:, None] & a) ^ (mb[:, None] & b)
             ^ (mab[:, None] & (a & b)))
        vals = jax.lax.dynamic_update_slice_in_dim(
            vals, r[:, None], n_inputs + g, axis=1)
        return vals, None

    xs = (jnp.arange(G, dtype=jnp.int32), in0.T, in1.T,
          c0.T, ca.T, cb.T, cab.T)
    vals, _ = jax.lax.scan(body, vals, xs)
    return jnp.take_along_axis(vals, outputs[:, :, None], axis=1)


@partial(jax.jit, static_argnames=("n_inputs",))
def population_eval_uint(op: jax.Array, in0: jax.Array, in1: jax.Array,
                         outputs: jax.Array, words32: jax.Array,
                         n_inputs: int) -> jax.Array:
    """Decode output words (LSB-first) into per-vector ints: (P, W*32) int32."""
    outw = simulate_population(op, in0, in1, outputs, words32, n_inputs)
    P, n_out, W = outw.shape
    shifts = jnp.arange(32, dtype=_U32)
    acc = jnp.zeros((P, W, 32), dtype=jnp.int32)
    for o in range(n_out):
        bits = ((outw[:, o, :, None] >> shifts) & _U32(1)).astype(jnp.int32)
        acc = acc + (bits << o)
    return acc.reshape(P, W * 32)


@partial(jax.jit, static_argnames=("n_inputs",))
def population_pc_errors(op: jax.Array, in0: jax.Array, in1: jax.Array,
                         outputs: jax.Array, words32: jax.Array,
                         true: jax.Array, n_inputs: int
                         ) -> tuple[jax.Array, jax.Array]:
    """Per-individual (mae, wcae) vs true popcounts — the device-side
    fitness term of CGP Eq. (3).  true: (W*32,) int32."""
    approx = population_eval_uint(op, in0, in1, outputs, words32, n_inputs)
    err = jnp.abs(approx - true[None, :])
    return err.mean(axis=1), err.max(axis=1).astype(jnp.float32)
