"""Pallas kernels: population-parallel gate-level circuit simulation.

The campaign hot loop — (population of genomes) x (packed test words) —
as real Pallas kernels instead of the `lax.scan` SWAR twin in
`kernels/circuit_sim.py`.  Three entry points share one kernel body:

  * `simulate_population` — output *words* `(P, n_out, W)`, the
    conformance-suite surface (bit-identical to both host evaluators);
  * `fused_eval_uint` — the **fused megakernel**: gate walk, output-word
    extraction and LSB-first integer decode in ONE `pallas_call`.  The
    value plane never leaves VMEM and the per-output-bit `(P, W, 32)`
    planes the old two-stage path materialized in HBM are gone — each
    grid cell writes its decoded int32 tile directly;
  * `fleet_eval_words` — the **multi-program megakernel**: T tenants'
    plan tables padded to a common gate budget and paged into VMEM, grid
    over (tenant x word-tile), so a serving fleet evaluates its whole
    manifest in one launch instead of per-tenant batches.

Grid layout for the fused kernel is (population tiles, word tiles): each
program instance owns a `block_pop`-row slab of plan tables and a
`block_words`-wide slab of packed uint32 test words, walks the gate
columns with a `fori_loop` over a VMEM-resident value plane of shape
`(block_pop, n_inputs + n_gates, block_words)`, and writes that tile's
decoded integers.  Word tiles stream through the grid — Pallas
double-buffers the per-tile DMA behind the gate walk automatically, so
HBM traffic for the word plane overlaps compute.  Gates apply through the
same algebraic normal form r = m0 ^ (ma & a) ^ (mb & b) ^ (mab & (a & b))
as both existing evaluators, with the per-gate coefficient masks
precomputed on the host — the kernel body is branch-free regardless of
opcode mix.

Bit-compatibility contract (pinned by tests/test_conformance.py):
identical output words to `NetlistPopulation.simulate` (lane-split via
`pack_words32`) and to `circuit_sim.simulate_population`, for both shared
`(n_inputs, W)` and per-individual `(P, n_inputs, W)` word planes; the
fused decode matches `circuit_sim.population_eval_uint` integer for
integer, and the fleet kernel matches per-tenant dispatch on every
tenant regardless of gate-count/feature-count/output-width skew
(padding must never leak into outputs).

On TPU the plan rows stay resident in VMEM and the word axis streams
through the grid; off-TPU the kernels run in interpret mode (the
repo-wide dispatch policy, cf. `kernels/ops.py`), where the population
tiling keeps the XLA program shape close to the SWAR scan.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.circuit_sim import (_C0_TBL, _CA_TBL, _CAB_TBL, _CB_TBL,
                                       _U32)

DEFAULT_BLOCK_WORDS = 128
DEFAULT_BLOCK_POP = 8


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _pad_gateless(op, in0, in1):
    """Zero-size blocks are illegal in pallas_call — pad gateless plans
    with one dead CONST0 gate (node n_inputs, unreachable by outputs)."""
    from repro.hw.egfet import Gate
    P = op.shape[0]
    op = np.full((P, 1), int(Gate.CONST0), dtype=np.int16)
    in0 = np.zeros((P, 1), dtype=np.int32)
    in1 = np.zeros((P, 1), dtype=np.int32)
    return op, in0, in1


def _kernel(in0_ref, in1_ref, m0_ref, ma_ref, mb_ref, mab_ref, out_idx_ref,
            words_ref, out_ref, vals_ref, *, n_inputs: int, n_gates: int,
            n_out: int):
    # blocks: plan rows (1, G) int32 / uint32; words (n_inputs, bw) or
    # (1, n_inputs, bw) uint32; out (1, n_out, bw); vals scratch
    # (n_inputs + G, bw) uint32.
    w = words_ref[...]
    vals_ref[pl.ds(0, n_inputs), :] = w.reshape(n_inputs, -1)
    if n_gates:
        vals_ref[pl.ds(n_inputs, n_gates), :] = jnp.zeros(
            (n_gates, w.shape[-1]), dtype=_U32)

    def body(g, carry):
        a = vals_ref[pl.ds(in0_ref[0, g], 1), :]
        b = vals_ref[pl.ds(in1_ref[0, g], 1), :]
        r = (m0_ref[0, g] ^ (ma_ref[0, g] & a) ^ (mb_ref[0, g] & b)
             ^ (mab_ref[0, g] & (a & b)))
        vals_ref[pl.ds(n_inputs + g, 1), :] = r
        return carry

    if n_gates:
        jax.lax.fori_loop(0, n_gates, body, 0)
    for o in range(n_out):           # n_out is static and small (<= 8)
        out_ref[0, pl.ds(o, 1), :] = vals_ref[pl.ds(out_idx_ref[0, o], 1), :]


@partial(jax.jit,
         static_argnames=("n_inputs", "block_words", "interpret"))
def _simulate_padded(in0, in1, m0, ma, mb, mab, outputs, words32, *,
                     n_inputs: int, block_words: int, interpret: bool):
    P, G = in0.shape
    n_out = outputs.shape[1]
    Wp = words32.shape[-1]
    shared = words32.ndim == 2
    grid = (P, Wp // block_words)
    words_spec = (pl.BlockSpec((n_inputs, block_words), lambda p, w: (0, w))
                  if shared else
                  pl.BlockSpec((1, n_inputs, block_words),
                               lambda p, w: (p, 0, w)))
    plan_spec = pl.BlockSpec((1, G), lambda p, w: (p, 0))
    return pl.pallas_call(
        partial(_kernel, n_inputs=n_inputs, n_gates=G, n_out=n_out),
        grid=grid,
        in_specs=[plan_spec, plan_spec, plan_spec, plan_spec, plan_spec,
                  plan_spec,
                  pl.BlockSpec((1, n_out), lambda p, w: (p, 0)),
                  words_spec],
        out_specs=pl.BlockSpec((1, n_out, block_words),
                               lambda p, w: (p, 0, w)),
        out_shape=jax.ShapeDtypeStruct((P, n_out, Wp), jnp.uint32),
        scratch_shapes=[pltpu.VMEM((n_inputs + G, block_words), jnp.uint32)],
        interpret=interpret,
    )(in0, in1, m0, ma, mb, mab, outputs, words32)


def simulate_population(op, in0, in1, outputs, words32, n_inputs: int, *,
                        block_words: int = DEFAULT_BLOCK_WORDS,
                        interpret: bool | None = None) -> jax.Array:
    """Pallas twin of `circuit_sim.simulate_population`.

    op/in0/in1: (P, G) int; outputs: (P, n_out) int; words32: (n_inputs, W)
    shared or (P, n_inputs, W) per-individual uint32 words.  Returns
    (P, n_out, W) uint32, bit-identical to both existing evaluators.
    """
    if interpret is None:
        interpret = not _on_tpu()
    op = np.asarray(op)
    P = op.shape[0]
    n_out = np.asarray(outputs).shape[1]
    W = np.asarray(words32).shape[-1]
    if W == 0:
        # a zero-width word plane has nothing to simulate — mirror the
        # gateless-plan pad guard instead of handing pallas_call a
        # zero-size grid/block (which it rejects)
        return jnp.zeros((P, n_out, 0), dtype=jnp.uint32)
    if op.shape[1] == 0:
        op, in0, in1 = _pad_gateless(op, in0, in1)
    m0 = _C0_TBL[op]                   # (P, G) uint32 ANF masks
    ma = _CA_TBL[op]
    mb = _CB_TBL[op]
    mab = _CAB_TBL[op]
    in0 = jnp.asarray(np.asarray(in0, dtype=np.int32))
    in1 = jnp.asarray(np.asarray(in1, dtype=np.int32))
    outputs = jnp.asarray(np.asarray(outputs, dtype=np.int32))
    words32 = jnp.asarray(words32, dtype=jnp.uint32)
    bw = min(block_words, max(W, 1))
    pad = (-W) % bw
    if pad:
        pad_width = ([(0, 0), (0, pad)] if words32.ndim == 2
                     else [(0, 0), (0, 0), (0, pad)])
        words32 = jnp.pad(words32, pad_width)
    out = _simulate_padded(in0, in1, jnp.asarray(m0), jnp.asarray(ma),
                           jnp.asarray(mb), jnp.asarray(mab), outputs,
                           words32, n_inputs=n_inputs, block_words=bw,
                           interpret=interpret)
    return out[:, :, :W]


# ---------------------------------------------------------------------------
# Fused megakernel: gate walk + output extraction + LSB-first decode in one
# pallas_call.  Grid is (population tiles, word tiles); the value plane for
# a (block_pop, block_words) tile lives in VMEM for the whole gate walk and
# the decoded int32 tile is written directly — no (P, n_out, W) word plane
# and no per-output-bit (P, W, 32) planes ever reach HBM.
# ---------------------------------------------------------------------------
def _fused_kernel(in0_ref, in1_ref, m0_ref, ma_ref, mb_ref, mab_ref,
                  out_idx_ref, words_ref, out_ref, *, n_inputs: int,
                  n_gates: int, n_out: int, block_pop: int, shared: bool):
    bp = block_pop
    w = words_ref[...]                      # (n_inputs, bw) | (bp, n_in, bw)
    bw = w.shape[-1]
    inw = (jnp.broadcast_to(w.reshape(1, n_inputs, bw), (bp, n_inputs, bw))
           if shared else w.reshape(bp, n_inputs, bw))
    vals = jnp.zeros((bp, n_inputs + n_gates, bw), dtype=_U32)
    vals = jax.lax.dynamic_update_slice_in_dim(vals, inw, 0, axis=1)

    def body(g, vals):
        i0 = in0_ref[:, pl.ds(g, 1)]        # (bp, 1) per-individual taps
        i1 = in1_ref[:, pl.ds(g, 1)]
        a = jnp.take_along_axis(vals, i0[:, :, None], axis=1)[:, 0]
        b = jnp.take_along_axis(vals, i1[:, :, None], axis=1)[:, 0]
        r = (m0_ref[:, pl.ds(g, 1)] ^ (ma_ref[:, pl.ds(g, 1)] & a)
             ^ (mb_ref[:, pl.ds(g, 1)] & b)
             ^ (mab_ref[:, pl.ds(g, 1)] & (a & b)))
        return jax.lax.dynamic_update_slice_in_dim(
            vals, r[:, None, :], n_inputs + g, axis=1)

    if n_gates:
        vals = jax.lax.fori_loop(0, n_gates, body, vals)
    outs = out_idx_ref[...]                 # (bp, n_out)
    outw = jnp.take_along_axis(vals, outs[:, :, None], axis=1)
    # LSB-first decode, fused: vector s of word w is bit (s % 32), so the
    # (bp, bw, 32) bit cube reshapes straight into the per-vector ints
    shifts = jnp.arange(32, dtype=_U32)
    acc = jnp.zeros((bp, bw, 32), dtype=jnp.int32)
    for o in range(n_out):                  # n_out is static and small
        bits = ((outw[:, o, :, None] >> shifts) & _U32(1)).astype(jnp.int32)
        acc = acc + (bits << o)
    out_ref[...] = acc.reshape(bp, bw * 32)


@partial(jax.jit, static_argnames=("n_inputs", "block_words", "block_pop",
                                   "interpret"))
def _fused_padded(in0, in1, m0, ma, mb, mab, outputs, words32, *,
                  n_inputs: int, block_words: int, block_pop: int,
                  interpret: bool):
    Pp, G = in0.shape
    n_out = outputs.shape[1]
    Wp = words32.shape[-1]
    shared = words32.ndim == 2
    bp, bw = block_pop, block_words
    grid = (Pp // bp, Wp // bw)
    words_spec = (pl.BlockSpec((n_inputs, bw), lambda p, w: (0, w))
                  if shared else
                  pl.BlockSpec((bp, n_inputs, bw), lambda p, w: (p, 0, w)))
    plan_spec = pl.BlockSpec((bp, G), lambda p, w: (p, 0))
    return pl.pallas_call(
        partial(_fused_kernel, n_inputs=n_inputs, n_gates=G, n_out=n_out,
                block_pop=bp, shared=shared),
        grid=grid,
        in_specs=[plan_spec, plan_spec, plan_spec, plan_spec, plan_spec,
                  plan_spec,
                  pl.BlockSpec((bp, n_out), lambda p, w: (p, 0)),
                  words_spec],
        out_specs=pl.BlockSpec((bp, bw * 32), lambda p, w: (p, w)),
        out_shape=jax.ShapeDtypeStruct((Pp, Wp * 32), jnp.int32),
        interpret=interpret,
    )(in0, in1, m0, ma, mb, mab, outputs, words32)


def fused_eval_uint(op, in0, in1, outputs, words32, n_inputs: int, *,
                    block_words: int | None = None,
                    block_pop: int | None = None,
                    interpret: bool | None = None) -> jax.Array:
    """Fused gate-walk + decode: `(P, W*32)` int32 in one `pallas_call`.

    Bit-identical to `circuit_sim.population_eval_uint` (and therefore to
    decoding `simulate_population`'s words on the host), for shared and
    per-individual word planes.
    """
    if interpret is None:
        interpret = not _on_tpu()
    if block_words is None:
        block_words = DEFAULT_BLOCK_WORDS
    op = np.asarray(op)
    P = op.shape[0]
    W = np.asarray(words32).shape[-1]
    if W == 0:
        return jnp.zeros((P, 0), dtype=jnp.int32)
    if op.shape[1] == 0:
        op, in0, in1 = _pad_gateless(op, in0, in1)
    m0 = _C0_TBL[op]
    ma = _CA_TBL[op]
    mb = _CB_TBL[op]
    mab = _CAB_TBL[op]
    in0 = np.asarray(in0, dtype=np.int32)
    in1 = np.asarray(in1, dtype=np.int32)
    outputs = np.asarray(outputs, dtype=np.int32)
    words32 = jnp.asarray(words32, dtype=jnp.uint32)
    bp = min(block_pop if block_pop is not None else DEFAULT_BLOCK_POP,
             max(P, 1))
    bw = min(block_words, max(W, 1))
    wpad = (-W) % bw
    if wpad:
        pad_width = ([(0, 0), (0, wpad)] if words32.ndim == 2
                     else [(0, 0), (0, 0), (0, wpad)])
        words32 = jnp.pad(words32, pad_width)
    ppad = (-P) % bp
    if ppad:
        # pad plan rows with copies of row 0 — cheap, always well-formed,
        # and the padded rows are sliced off below
        idx = np.concatenate([np.arange(P), np.zeros(ppad, dtype=np.int64)])
        in0, in1 = in0[idx], in1[idx]
        m0, ma, mb, mab = m0[idx], ma[idx], mb[idx], mab[idx]
        outputs = outputs[idx]
        if words32.ndim == 3:
            words32 = jnp.concatenate(
                [words32, jnp.repeat(words32[:1], ppad, axis=0)], axis=0)
    out = _fused_padded(jnp.asarray(in0), jnp.asarray(in1), jnp.asarray(m0),
                        jnp.asarray(ma), jnp.asarray(mb), jnp.asarray(mab),
                        jnp.asarray(outputs), words32, n_inputs=n_inputs,
                        block_words=bw, block_pop=bp, interpret=interpret)
    return out[:P, : W * 32]


def population_eval_uint(op, in0, in1, outputs, words32, n_inputs: int, *,
                         block_words: int | None = None,
                         block_pop: int | None = None,
                         interpret: bool | None = None) -> jax.Array:
    """Decode output words (LSB-first) into per-vector ints: (P, W*32) int32.

    Routed through the fused megakernel — one launch, no intermediate
    output-word plane (the old two-stage path built an extra `(P, W, 32)`
    plane per output bit on the host side of the kernel).
    """
    return fused_eval_uint(op, in0, in1, outputs, words32, n_inputs,
                           block_words=block_words, block_pop=block_pop,
                           interpret=interpret)


# ---------------------------------------------------------------------------
# Multi-program megakernel: T tenants' plans padded to one gate budget,
# grid over (tenant x word-tile), one launch for the whole manifest.
# ---------------------------------------------------------------------------
def fleet_eval_words(plans, words_list, *, block_words: int | None = None,
                     interpret: bool | None = None) -> list[np.ndarray]:
    """Evaluate T single-program circuits over T word planes in ONE launch.

    `plans` is a list of `(op, in0, in1, outputs, n_inputs)` tuples —
    each a single program's plan (arrays may be `(G,)`/`(n_out,)` 1-D or
    `(1, G)`/`(1, n_out)` rows); `words_list` holds each tenant's packed
    `(n_inputs_t, W_t)` uint32 word plane.  Plans are padded to a common
    gate budget and feature count (node indices remapped so gate nodes
    land after the padded input rows), every tenant gets one trailing
    CONST0 pad gate, and padded output taps point at that known-zero node
    — so neither the gate-budget pad, the feature pad, the word pad nor
    the output pad can leak into any tenant's decoded integers.  Returns
    one `(W_t * 32,)` int32 array per tenant, bit-identical to running
    each plan through `fused_eval_uint` on its own.
    """
    from repro.hw.egfet import Gate

    if not plans:
        return []
    if len(plans) != len(words_list):
        raise ValueError(f"{len(plans)} plans but {len(words_list)} word "
                         "planes")
    norm = []
    for i, (op, in0, in1, outputs, n_in) in enumerate(plans):
        op = np.asarray(op, dtype=np.int16).reshape(-1)
        in0 = np.asarray(in0, dtype=np.int32).reshape(-1)
        in1 = np.asarray(in1, dtype=np.int32).reshape(-1)
        outputs = np.asarray(outputs, dtype=np.int32).reshape(-1)
        w = np.ascontiguousarray(words_list[i], dtype=np.uint32)
        if w.ndim != 2 or w.shape[0] != n_in:
            raise ValueError(f"plan {i}: word plane {w.shape} does not "
                             f"match n_inputs={n_in}")
        norm.append((op, in0, in1, outputs, int(n_in), w))

    T = len(norm)
    n_in_max = max(p[4] for p in norm)
    G_max = max(p[0].shape[0] for p in norm) + 1      # +1: shared zero node
    n_out_max = max(p[3].shape[0] for p in norm)
    W_list = [p[5].shape[1] for p in norm]
    W_max = max(W_list)
    if W_max == 0:
        return [np.zeros(0, dtype=np.int32) for _ in norm]

    zero_node = n_in_max + G_max - 1    # the trailing CONST0 pad gate
    op_t = np.full((T, G_max), int(Gate.CONST0), dtype=np.int16)
    in0_t = np.zeros((T, G_max), dtype=np.int32)
    in1_t = np.zeros((T, G_max), dtype=np.int32)
    out_t = np.full((T, n_out_max), zero_node, dtype=np.int32)
    words_t = np.zeros((T, n_in_max, W_max), dtype=np.uint32)

    def remap(idx: np.ndarray, n_in: int) -> np.ndarray:
        # tenant node numbering: inputs 0..n_in-1, gates n_in.. — shift the
        # gate nodes past the padded input rows
        return np.where(idx >= n_in, idx + (n_in_max - n_in), idx)

    for t, (op, in0, in1, outputs, n_in, w) in enumerate(norm):
        G = op.shape[0]
        op_t[t, :G] = op
        in0_t[t, :G] = remap(in0, n_in)
        in1_t[t, :G] = remap(in1, n_in)
        out_t[t, : outputs.shape[0]] = remap(outputs, n_in)
        words_t[t, :n_in, : w.shape[1]] = w

    out = np.asarray(fused_eval_uint(
        op_t, in0_t, in1_t, out_t, words_t, n_in_max,
        block_words=block_words, block_pop=1, interpret=interpret))
    return [out[t, : W_list[t] * 32] for t in range(T)]
