"""Pallas kernel: population-parallel gate-level circuit simulation.

The campaign hot loop — (population of genomes) x (packed test words) —
as a real Pallas kernel instead of the `lax.scan` SWAR twin in
`kernels/circuit_sim.py`.  Grid is (population, word tiles): each program
instance owns one individual's plan row and one `block_words`-wide slab of
packed uint32 test words, walks the gate columns with a `fori_loop` over a
VMEM value plane of shape (n_inputs + n_gates, block_words), and writes that
individual's output words.  Gates apply through the same algebraic normal
form r = m0 ^ (ma & a) ^ (mb & b) ^ (mab & (a & b)) as both existing
evaluators, with the per-gate coefficient masks precomputed on the host —
the kernel body is branch-free regardless of opcode mix.

Bit-compatibility contract (pinned by tests/test_conformance.py): identical
output words to `NetlistPopulation.simulate` (lane-split via `pack_words32`)
and to `circuit_sim.simulate_population`, for both shared `(n_inputs, W)`
and per-individual `(P, n_inputs, W)` word planes.

On TPU the plan rows stay resident in VMEM and the word axis streams through
the grid; off-TPU the kernel runs in interpret mode (the repo-wide dispatch
policy, cf. `kernels/ops.py`), which is slower than the SWAR scan on CPU but
exercises the exact kernel program the accelerator runs.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.circuit_sim import (_C0_TBL, _CA_TBL, _CAB_TBL, _CB_TBL,
                                       _U32)

DEFAULT_BLOCK_WORDS = 128


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _kernel(in0_ref, in1_ref, m0_ref, ma_ref, mb_ref, mab_ref, out_idx_ref,
            words_ref, out_ref, vals_ref, *, n_inputs: int, n_gates: int,
            n_out: int):
    # blocks: plan rows (1, G) int32 / uint32; words (n_inputs, bw) or
    # (1, n_inputs, bw) uint32; out (1, n_out, bw); vals scratch
    # (n_inputs + G, bw) uint32.
    w = words_ref[...]
    vals_ref[pl.ds(0, n_inputs), :] = w.reshape(n_inputs, -1)
    if n_gates:
        vals_ref[pl.ds(n_inputs, n_gates), :] = jnp.zeros(
            (n_gates, w.shape[-1]), dtype=_U32)

    def body(g, carry):
        a = vals_ref[pl.ds(in0_ref[0, g], 1), :]
        b = vals_ref[pl.ds(in1_ref[0, g], 1), :]
        r = (m0_ref[0, g] ^ (ma_ref[0, g] & a) ^ (mb_ref[0, g] & b)
             ^ (mab_ref[0, g] & (a & b)))
        vals_ref[pl.ds(n_inputs + g, 1), :] = r
        return carry

    if n_gates:
        jax.lax.fori_loop(0, n_gates, body, 0)
    for o in range(n_out):           # n_out is static and small (<= 8)
        out_ref[0, pl.ds(o, 1), :] = vals_ref[pl.ds(out_idx_ref[0, o], 1), :]


@partial(jax.jit,
         static_argnames=("n_inputs", "block_words", "interpret"))
def _simulate_padded(in0, in1, m0, ma, mb, mab, outputs, words32, *,
                     n_inputs: int, block_words: int, interpret: bool):
    P, G = in0.shape
    n_out = outputs.shape[1]
    Wp = words32.shape[-1]
    shared = words32.ndim == 2
    grid = (P, Wp // block_words)
    words_spec = (pl.BlockSpec((n_inputs, block_words), lambda p, w: (0, w))
                  if shared else
                  pl.BlockSpec((1, n_inputs, block_words),
                               lambda p, w: (p, 0, w)))
    plan_spec = pl.BlockSpec((1, G), lambda p, w: (p, 0))
    return pl.pallas_call(
        partial(_kernel, n_inputs=n_inputs, n_gates=G, n_out=n_out),
        grid=grid,
        in_specs=[plan_spec, plan_spec, plan_spec, plan_spec, plan_spec,
                  plan_spec,
                  pl.BlockSpec((1, n_out), lambda p, w: (p, 0)),
                  words_spec],
        out_specs=pl.BlockSpec((1, n_out, block_words),
                               lambda p, w: (p, 0, w)),
        out_shape=jax.ShapeDtypeStruct((P, n_out, Wp), jnp.uint32),
        scratch_shapes=[pltpu.VMEM((n_inputs + G, block_words), jnp.uint32)],
        interpret=interpret,
    )(in0, in1, m0, ma, mb, mab, outputs, words32)


def simulate_population(op, in0, in1, outputs, words32, n_inputs: int, *,
                        block_words: int = DEFAULT_BLOCK_WORDS,
                        interpret: bool | None = None) -> jax.Array:
    """Pallas twin of `circuit_sim.simulate_population`.

    op/in0/in1: (P, G) int; outputs: (P, n_out) int; words32: (n_inputs, W)
    shared or (P, n_inputs, W) per-individual uint32 words.  Returns
    (P, n_out, W) uint32, bit-identical to both existing evaluators.
    """
    if interpret is None:
        interpret = not _on_tpu()
    op = np.asarray(op)
    if op.shape[1] == 0:
        # zero-size blocks are illegal in pallas_call — pad gateless plans
        # with one dead CONST0 gate (node n_inputs, unreachable by outputs)
        from repro.hw.egfet import Gate
        P = op.shape[0]
        op = np.full((P, 1), int(Gate.CONST0), dtype=np.int16)
        in0 = np.zeros((P, 1), dtype=np.int32)
        in1 = np.zeros((P, 1), dtype=np.int32)
    m0 = _C0_TBL[op]                   # (P, G) uint32 ANF masks
    ma = _CA_TBL[op]
    mb = _CB_TBL[op]
    mab = _CAB_TBL[op]
    in0 = jnp.asarray(np.asarray(in0, dtype=np.int32))
    in1 = jnp.asarray(np.asarray(in1, dtype=np.int32))
    outputs = jnp.asarray(np.asarray(outputs, dtype=np.int32))
    words32 = jnp.asarray(words32, dtype=jnp.uint32)
    W = words32.shape[-1]
    bw = min(block_words, max(W, 1))
    pad = (-W) % bw
    if pad:
        pad_width = ([(0, 0), (0, pad)] if words32.ndim == 2
                     else [(0, 0), (0, 0), (0, pad)])
        words32 = jnp.pad(words32, pad_width)
    out = _simulate_padded(in0, in1, jnp.asarray(m0), jnp.asarray(ma),
                           jnp.asarray(mb), jnp.asarray(mab), outputs,
                           words32, n_inputs=n_inputs, block_words=bw,
                           interpret=interpret)
    return out[:, :, :W]


def population_eval_uint(op, in0, in1, outputs, words32, n_inputs: int, *,
                         block_words: int = DEFAULT_BLOCK_WORDS,
                         interpret: bool | None = None) -> jax.Array:
    """Decode output words (LSB-first) into per-vector ints: (P, W*32) int32."""
    outw = simulate_population(op, in0, in1, outputs, words32, n_inputs,
                               block_words=block_words, interpret=interpret)
    P, n_out, W = outw.shape
    shifts = jnp.arange(32, dtype=_U32)
    acc = jnp.zeros((P, W, 32), dtype=jnp.int32)
    for o in range(n_out):
        bits = ((outw[:, o, :, None] >> shifts) & _U32(1)).astype(jnp.int32)
        acc = acc + (bits << o)
    return acc.reshape(P, W * 32)
