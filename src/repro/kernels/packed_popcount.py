"""Pallas TPU kernel: popcount over bit-packed uint32 lanes (SWAR on VPU).

Used by the circuit-accurate TNN inference path and the CGP fitness
simulator's hot loop: inputs are (B, W) words of packed binary features,
output is the per-row popcount — i.e. the paper's popcount unit, vectorized
over a batch.  Bit-twiddling runs on the VPU (8x128 lanes); each grid step
processes a (bb, W) block resident in VMEM.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(w_ref, o_ref):
    v = w_ref[...].astype(jnp.uint32)
    v = v - ((v >> 1) & jnp.uint32(0x55555555))
    v = (v & jnp.uint32(0x33333333)) + ((v >> 2) & jnp.uint32(0x33333333))
    v = (v + (v >> 4)) & jnp.uint32(0x0F0F0F0F)
    v = (v * jnp.uint32(0x01010101)) >> 24
    o_ref[...] = v.astype(jnp.int32).sum(axis=-1, keepdims=True)


def packed_popcount(words: jax.Array, *, bb: int = 256,
                    interpret: bool = False) -> jax.Array:
    """words: (B, W) uint32 -> (B,) int32 popcounts."""
    B, W = words.shape
    bb = min(bb, B)
    assert B % bb == 0, (B, bb)
    out = pl.pallas_call(
        _kernel,
        grid=(B // bb,),
        in_specs=[pl.BlockSpec((bb, W), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((bb, 1), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, 1), jnp.int32),
        interpret=interpret,
    )(words)
    return out[:, 0]
