"""Pallas TPU kernel: 2-bit-packed ternary matmul.

The TPU realization of the paper's multiplier-free ternary neuron
(DESIGN.md §3): weights live in HBM as 2-bit codes (4 per int8 byte,
code 01 -> +1, 10 -> -1, 00 -> 0), are unpacked inside VMEM, and the ±1/0
matrix feeds the MXU.  Weight traffic is 8x lower than bf16 — on a
decode-shaped (memory-bound) workload that moves the *memory roofline term*
the way bespoke wiring moves printed-circuit area.

Tiling: grid (M/bm, N/bn, K/bk); the packed block is (bk//4, bn) int8.
bm, bn multiples of 128 (MXU-aligned), bk a multiple of 512 so the packed
rows stay 128-aligned.  f32 accumulation in a VMEM scratch across the K
grid dimension (revisiting semantics: K is the innermost grid dim).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _unpack_block(w2: jax.Array, bk: int, dtype) -> jax.Array:
    """(bk//4, bn) int8 -> (bk, bn) ±1/0 in `dtype` (VMEM-local)."""
    u = w2.astype(jnp.uint8)
    parts = [(u >> (2 * i)) & jnp.uint8(0x3) for i in range(4)]
    st = jnp.stack(parts, axis=1)                      # (bk//4, 4, bn)
    w = (st == 1).astype(dtype) - (st == 2).astype(dtype)
    return w.reshape(bk, -1)


def _kernel(x_ref, w2_ref, o_ref, acc_ref, *, bk: int, n_k: int):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[...]
    w = _unpack_block(w2_ref[...], bk, x.dtype)
    acc_ref[...] += jax.lax.dot_general(
        x, w, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(2) == n_k - 1)
    def _done():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def ternary_matmul(x: jax.Array, w2: jax.Array, scale: jax.Array, *,
                   bm: int = 128, bk: int = 512, bn: int = 128,
                   interpret: bool = False) -> jax.Array:
    """x: (M, K); w2: (K//4, N) int8 codes; scale: (1, N) f32 -> (M, N) f32."""
    M, K = x.shape
    K4, N = w2.shape
    assert K4 * 4 == K, (K4, K)
    bm, bk, bn = min(bm, M), min(bk, K), min(bn, N)
    assert M % bm == 0 and K % bk == 0 and N % bn == 0, (M, K, N, bm, bk, bn)
    assert bk % 4 == 0
    grid = (M // bm, N // bn, K // bk)

    out = pl.pallas_call(
        functools.partial(_kernel, bk=bk, n_k=K // bk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk // 4, bn), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(x, w2)
    return out * scale.astype(jnp.float32)
