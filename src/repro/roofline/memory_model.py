"""Analytic (fusion-aware) HBM traffic model — the realistic memory term.

XLA's cost_analysis "bytes accessed" sums operand+output bytes of every HLO
op with no fusion model: it reports ~TB/step/device where a fused TPU
program moves ~GB.  For bottleneck identification we therefore compute a
first-principles per-device traffic estimate alongside the XLA number
(which is kept in the tables as the stated upper bound):

train, per device per step:
    weights  : mb * L * 2 * W_layer            (FSDP gather-write + read)
    grads    : mb * L * W_layer                (+ reduce-scatter write)
    optimizer: (2 + moments_bpe/2) * P_shard   (read/write params + moments)
    acts     : mb * L * act_tok * B_mb * S / n_dev
    logits/CE: 2 * B * S * V * 4 / n_dev       (chunk write + read)
    accum    : mb * 3 * P_shard_accum
decode, per device per step:
    weights  : full active param bytes / n_dev (every weight read once)
               x2 when FSDP-sharded (gather-write + read)
    cache    : full cache bytes / n_dev (read) + one slot write
    logits   : 2 * B * V * 4 / n_dev

act_tok (bytes/token/layer) counts the remat-boundary stash (2D), the
recomputed MLP/MoE intermediates (2*F_active), attention projections
(4*H*dh) and flash-attention KV reads (amortized) at bf16.
"""
from __future__ import annotations

import numpy as np

import jax

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models.params import param_defs, param_count, is_def

_BPE = {"bfloat16": 2, "float32": 4}


def _layer_param_bytes(cfg: ModelConfig) -> float:
    """Full (unsharded) per-layer parameter bytes."""
    defs = param_defs(cfg, 16)["layers"]
    total = sum(int(np.prod(d.shape)) * np.dtype(d.dtype).itemsize
                for d in jax.tree.leaves(defs, is_leaf=is_def))
    return total / cfg.n_layers


def _active_layer_param_bytes(cfg: ModelConfig) -> float:
    """Per-layer bytes actually touched per token batch (MoE: only the
    experts that receive tokens — at large batch every expert is hit, so
    train uses the full bytes; decode at small batch touches ~top_k experts
    per token group).  Returned as (train_bytes, decode_bytes)."""
    full = _layer_param_bytes(cfg)
    if cfg.moe is None:
        return full, full
    defs = param_defs(cfg, 16)["layers"]
    expert_bytes = sum(
        int(np.prod(d.shape)) * np.dtype(d.dtype).itemsize
        for d in jax.tree.leaves(defs["moe"]["experts"], is_leaf=is_def)
    ) / cfg.n_layers
    dense_rest = full - expert_bytes
    frac = cfg.moe.top_k / cfg.moe.n_experts
    return full, dense_rest + expert_bytes * frac


def _act_token_bytes(cfg: ModelConfig) -> float:
    D, H, dh = cfg.d_model, cfg.n_heads, cfg.head_dim
    F = cfg.d_ff * (cfg.moe.top_k if cfg.moe else 1)
    if cfg.moe and cfg.moe.dense_residual:
        F += cfg.moe.d_ff_dense or cfg.d_ff
    bpe = _BPE[cfg.compute_dtype]
    if cfg.family == "ssm" and cfg.ssm is not None and cfg.ssm.kind == "rwkv6":
        mix = 6 * D            # r,k,v,g,w streams + wkv state traffic
        return bpe * (2 * D + 2 * cfg.d_ff + mix)
    extra = 0.0
    if cfg.family == "hybrid" and cfg.ssm is not None:
        di = cfg.ssm.expand * D
        extra = 2 * di + 2 * di * cfg.ssm.state_size / 16  # ssm scan traffic
    return bpe * (2 * D + 2 * F + 4 * H * dh + 2 * cfg.n_kv_heads * dh + extra)


def analytic_memory_bytes(cfg: ModelConfig, shape: ShapeConfig,
                          microbatches: int, n_dev: int = 256) -> dict:
    """Per-device HBM traffic estimate (bytes) with component breakdown."""
    B, S, L = shape.global_batch, shape.seq_len, cfg.n_layers
    V = cfg.vocab
    P_total = param_count(cfg, 16)
    bpe_p = _BPE[cfg.param_dtype]
    W_layer_full, W_layer_active = _active_layer_param_bytes(cfg)
    out: dict = {}

    if shape.kind == "train":
        mb = microbatches
        Bm = max(B // mb, 1)
        fsdp = 2.0     # gather-write + read of FSDP-sharded weights
        out["weights"] = mb * L * fsdp * W_layer_full / 1.0 / 16  # model-shard
        # NOTE: with EP/TP, each device only touches its weight shard after
        # the FSDP gather along data; model-axis sharding divides by 16.
        out["grads"] = mb * L * W_layer_full / 16
        moments_bpe = 2 if cfg.opt_8bit else 8
        out["optimizer"] = (2 * bpe_p + moments_bpe) * (P_total / n_dev)
        out["activations"] = mb * L * _act_token_bytes(cfg) * Bm * S / n_dev
        out["logits_ce"] = 2.0 * B * S * V * 4 / n_dev
        acc_bpe = _BPE[cfg.accum_dtype]
        out["grad_accum"] = (mb * 2 + 1) * acc_bpe * (P_total / n_dev) \
            if mb > 1 else 0.0
        if cfg.enc_layers:
            out["encoder"] = (mb * cfg.enc_layers * _act_token_bytes(cfg)
                              * Bm * cfg.enc_seq / n_dev)
    elif shape.kind == "prefill":
        out["weights"] = L * 2.0 * W_layer_full / 16
        out["activations"] = L * _act_token_bytes(cfg) * B * S / n_dev / 2
        out["logits"] = 2.0 * B * S * V * 4 / n_dev
        out["cache_write"] = (2 * L * B * min(S, cfg.swa_window or S)
                              * cfg.n_kv_heads * cfg.head_dim
                              * _BPE[cfg.compute_dtype] / n_dev)
    else:   # decode
        W_active_total = L * W_layer_active + (P_total * bpe_p
                                               - L * W_layer_full)
        if cfg.quant == "ternary_packed":
            # 2-bit packed layer weights (embeddings/head stay bf16)
            W_active_total = (L * W_layer_active * (0.25 / bpe_p)
                              + (P_total * bpe_p - L * W_layer_full))
        # FSDP-sharded serving re-gathers weights per token (factor 2:
        # gather-write + read); TP-only serving reads the resident shard.
        gather = 2.0 if cfg.serve_fsdp else 1.0
        out["weights"] = gather * W_active_total / n_dev * 16  # /16 model only
        eff = min(S, cfg.swa_window) if cfg.swa_window else S
        kv_bpe = 1 if cfg.kv_cache_dtype == "float8_e4m3fn" \
            else _BPE[cfg.compute_dtype]
        if cfg.family == "ssm" and cfg.ssm is not None and cfg.ssm.kind == "rwkv6":
            cache_bytes = L * B * cfg.n_heads * cfg.head_dim ** 2 * 4
        else:
            cache_bytes = 2 * L * B * eff * cfg.n_kv_heads * cfg.head_dim \
                * kv_bpe
            if cfg.enc_layers:
                cache_bytes += 2 * L * B * cfg.enc_seq * cfg.n_kv_heads \
                    * cfg.head_dim * kv_bpe
        out["cache"] = cache_bytes / n_dev
        out["activations"] = L * _act_token_bytes(cfg) * B / n_dev
        out["logits"] = 2.0 * B * V * 4 / n_dev
    out["total"] = sum(out.values())
    return out
