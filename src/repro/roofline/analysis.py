"""Roofline-term extraction from compiled dry-run artifacts.

All quantities are PER DEVICE: XLA's cost_analysis and the optimized HLO
text both describe the post-SPMD per-device program, so

    compute_s    = flops / PEAK_FLOPS
    memory_s     = bytes_accessed / HBM_BW
    collective_s = collective_output_bytes / ICI_BW

Hardware constants (TPU v5e, per chip): 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI.  collective bytes are not in cost_analysis, so we parse
the optimized HLO and sum the *output* tensor bytes of every all-gather /
all-reduce / reduce-scatter / all-to-all / collective-permute.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field

PEAK_FLOPS = 197e12        # bf16 / chip
HBM_BW = 819e9             # B/s / chip
ICI_BW = 50e9              # B/s / link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLL_KINDS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

# e.g.:  %all-gather.7 = bf16[8,512,128]{2,1,0} all-gather(...)
#        ROOT %t = (f32[8]{0}, f32[8]{0}) all-reduce(...)
_OP_RE = re.compile(
    r"=\s*(\(?[a-z0-9]+\[[^=]*?)\s*"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(")
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    if dtype not in _DTYPE_BYTES:
        return 0
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


@dataclass
class CollectiveStats:
    bytes_by_kind: dict = field(default_factory=dict)
    count_by_kind: dict = field(default_factory=dict)

    @property
    def total_bytes(self) -> int:
        return sum(self.bytes_by_kind.values())


def parse_collectives(hlo_text: str) -> CollectiveStats:
    """Sum output tensor bytes of every collective in optimized HLO."""
    stats = CollectiveStats()
    seen_done = set()
    for m in _OP_RE.finditer(hlo_text):
        shapes_str, kind = m.group(1), m.group(2)
        # async pairs: -start carries the shape; skip double counting -done
        span_text = hlo_text[max(0, m.start() - 120): m.start()]
        if f"{kind}-done" in m.group(0):
            continue
        b = sum(_shape_bytes(dt, dims)
                for dt, dims in _SHAPE_RE.findall(shapes_str))
        stats.bytes_by_kind[kind] = stats.bytes_by_kind.get(kind, 0) + b
        stats.count_by_kind[kind] = stats.count_by_kind.get(kind, 0) + 1
    return stats


@dataclass
class Roofline:
    flops: float
    bytes_accessed: float
    collective_bytes: float
    coll_by_kind: dict = field(default_factory=dict)

    @property
    def compute_s(self) -> float:
        return self.flops / PEAK_FLOPS

    @property
    def memory_s(self) -> float:
        return self.bytes_accessed / HBM_BW

    @property
    def collective_s(self) -> float:
        return self.collective_bytes / ICI_BW

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def bound_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    def summary(self) -> dict:
        return {
            "flops_per_device": self.flops,
            "bytes_per_device": self.bytes_accessed,
            "collective_bytes_per_device": self.collective_bytes,
            "collective_by_kind": self.coll_by_kind,
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "dominant": self.dominant,
        }


def roofline_from_compiled(compiled) -> Roofline:
    ca = compiled.cost_analysis()
    if isinstance(ca, list):            # older API returns [dict]
        ca = ca[0]
    flops = float(ca.get("flops", 0.0))
    byt = float(ca.get("bytes accessed", 0.0))
    stats = parse_collectives(compiled.as_text())
    return Roofline(flops=flops, bytes_accessed=byt,
                    collective_bytes=float(stats.total_bytes),
                    coll_by_kind=dict(stats.bytes_by_kind))


def model_flops(active_params: int, tokens: int, kind: str) -> float:
    """MODEL_FLOPS = 6*N*D (train) or 2*N*D (inference) with N = active."""
    per_tok = 6 if kind == "train" else 2
    return float(per_tok * active_params * tokens)
