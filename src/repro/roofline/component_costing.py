"""Trip-count-correct roofline costing via loop-free component compiles.

Why this exists: XLA's `cost_analysis()` counts a while-loop body ONCE,
regardless of trip count (verified empirically: a scan of 50 matmuls
reports the flops of 1).  Our production builds scan over layers,
microbatches, attention KV blocks and loss chunks, so whole-program
cost_analysis underestimates by orders of magnitude.

Methodology (EXPERIMENTS.md §Roofline):
  * compile each REPEATED UNIT standalone and loop-free, with the same
    shardings as the production build, on the same 256/512-device mesh:
      - train:   layer fwd+bwd (vjp, remat honored), embed fwd+bwd,
                 head+loss fwd+bwd (1 chunk), optimizer update
      - prefill: layer fwd, embed, head
      - decode:  layer decode step, embed+head
    with attention block_k = full KV length (=> its scan has 1 trip).
  * total = sum(component x exact trip count); trip counts are static
    (L layers, mb microbatches, ...).
  * recurrent time-scans (RWKV, which cannot be made trip-1) are costed at
    two short sequence lengths and extrapolated linearly in S — the
    recurrence body is S-invariant so cost is affine in S.
  * collective bytes are parsed from each component's optimized HLO and
    composed the same way.
Known approximation: cross-layer CSE (e.g. hoisted all-gathers) is lost,
so collective totals are slightly conservative (upper bounds).
"""
from __future__ import annotations

from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import transformer as TF
from repro.models.params import param_defs, is_def
from repro.models.sharding import ShardCtx
from repro.optim import adamw, adamw8bit
from repro.roofline.analysis import Roofline, parse_collectives
from repro.launch import specs as SP


@dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    coll: float = 0.0
    coll_by_kind: dict = field(default_factory=dict)

    def __mul__(self, k: float) -> "Cost":
        return Cost(self.flops * k, self.bytes * k, self.coll * k,
                    {kk: v * k for kk, v in self.coll_by_kind.items()})

    def __add__(self, o: "Cost") -> "Cost":
        kinds = dict(self.coll_by_kind)
        for k, v in o.coll_by_kind.items():
            kinds[k] = kinds.get(k, 0) + v
        return Cost(self.flops + o.flops, self.bytes + o.bytes,
                    self.coll + o.coll, kinds)


def _cost_of(fn, args, shardings, mesh) -> Cost:
    with mesh:
        lowered = jax.jit(fn, in_shardings=shardings).lower(*args)
        compiled = lowered.compile()
    ca = compiled.cost_analysis()
    if isinstance(ca, list):
        ca = ca[0]
    stats = parse_collectives(compiled.as_text())
    return Cost(float(ca.get("flops", 0.0)),
                float(ca.get("bytes accessed", 0.0)),
                float(stats.total_bytes), dict(stats.bytes_by_kind))


def _layer_tree(cfg: ModelConfig, which: str = "layers", serve: bool = False):
    """(abstract single-layer params, shardings) — leading L dim dropped."""
    defs = param_defs(cfg, 16)[which]
    structs = jax.tree.map(
        lambda d: jax.ShapeDtypeStruct(d.shape[1:], d.dtype), defs,
        is_leaf=is_def)
    specs = jax.tree.map(lambda d: P(*tuple(d.spec)[1:]), defs, is_leaf=is_def)
    if serve and not cfg.serve_fsdp:
        from repro.models.params import strip_fsdp_tree
        specs = strip_fsdp_tree(specs)
    return structs, specs


def _sh(mesh, tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), tree,
                        is_leaf=lambda s: isinstance(s, P))


def _rope_structs(cfg: ModelConfig, S: int):
    if cfg.rope == "none":
        return (), ()
    half = cfg.head_dim // 2
    cs = jax.ShapeDtypeStruct((1, S, half), jnp.float32)
    return (cs, cs), (P(None, None, None), P(None, None, None))


def _x_struct(cfg, B, S, mesh):
    dt = {"bfloat16": jnp.bfloat16, "float32": jnp.float32}[cfg.compute_dtype]
    bax = SP.batch_axes(mesh) if B % SP.data_size(mesh) == 0 and B > 1 else None
    return (jax.ShapeDtypeStruct((B, S, cfg.d_model), dt), P(bax, None, None))


# ---------------------------------------------------------------------------
# Per-kind cell costing
# ---------------------------------------------------------------------------
def _cost_layer_train(cfg, mesh, ctx, B, S, enc=False) -> Cost:
    costing_cfg = cfg.replace(attn_block_k=max(S, 1024))
    lp_struct, lp_spec = _layer_tree(costing_cfg,
                                     "enc_layers" if enc else "layers")
    x_struct, x_spec = _x_struct(costing_cfg, B, S, mesh)
    rope_structs, rope_specs = _rope_structs(costing_cfg, S)
    enc_struct = None
    extra_structs: tuple = ()
    extra_specs: tuple = ()
    if costing_cfg.enc_layers and not enc:
        enc_struct = jax.ShapeDtypeStruct(
            (B, costing_cfg.enc_seq, costing_cfg.d_model), x_struct.dtype)
        extra_structs = (enc_struct,)
        extra_specs = (x_spec[1] if False else P(None, None, None),)

    def f(lp, x, ct, *rest):
        cos, sin = (rest[0], rest[1]) if costing_cfg.rope != "none" else (None, None)
        eo = rest[-1] if enc_struct is not None else None

        def body(lp, x):
            if enc:
                return TF._block_enc(costing_cfg, lp, x, ctx)
            return TF.apply_block(costing_cfg, lp, x, cos=cos, sin=sin,
                                  ctx=ctx, enc_out=eo)[0]

        if costing_cfg.remat:
            body = jax.checkpoint(body)
        y, vjp = jax.vjp(body, lp, x)
        dlp, dx = vjp(ct)
        return y, dlp, dx

    args = (lp_struct, x_struct, x_struct) + rope_structs + extra_structs
    sh = (_sh(mesh, lp_spec), NamedSharding(mesh, x_spec),
          NamedSharding(mesh, x_spec)) + tuple(
        NamedSharding(mesh, s) for s in rope_specs) + tuple(
        NamedSharding(mesh, s) for s in extra_specs)
    return _cost_of(f, args, sh, mesh)


def _cost_layer_fwd(cfg, mesh, ctx, B, S, enc=False) -> Cost:
    costing_cfg = cfg.replace(attn_block_k=max(S, 1024))
    lp_struct, lp_spec = _layer_tree(costing_cfg,
                                     "enc_layers" if enc else "layers")
    x_struct, x_spec = _x_struct(costing_cfg, B, S, mesh)
    rope_structs, rope_specs = _rope_structs(costing_cfg, S)
    enc_struct = None
    extra_structs: tuple = ()
    extra_specs: tuple = ()
    if costing_cfg.enc_layers and not enc:
        enc_struct = jax.ShapeDtypeStruct(
            (B, costing_cfg.enc_seq, costing_cfg.d_model), x_struct.dtype)
        extra_structs = (enc_struct,)
        extra_specs = (P(None, None, None),)

    def f(lp, x, *rest):
        cos, sin = (rest[0], rest[1]) if costing_cfg.rope != "none" else (None, None)
        eo = rest[-1] if enc_struct is not None else None
        if enc:
            return TF._block_enc(costing_cfg, lp, x, ctx)
        return TF.apply_block(costing_cfg, lp, x, cos=cos, sin=sin, ctx=ctx,
                              enc_out=eo)[0]

    args = (lp_struct, x_struct) + rope_structs + extra_structs
    sh = (_sh(mesh, lp_spec), NamedSharding(mesh, x_spec)) + tuple(
        NamedSharding(mesh, s) for s in rope_specs) + tuple(
        NamedSharding(mesh, s) for s in extra_specs)
    return _cost_of(f, args, sh, mesh)


def _cost_embed_head_train(cfg, mesh, ctx, B, S) -> Cost:
    """embed fwd+bwd + final norm + head + CE (1 chunk) fwd+bwd."""
    defs = param_defs(cfg, 16)
    emb_struct = jax.tree.map(
        lambda d: jax.ShapeDtypeStruct(d.shape, d.dtype),
        {"embed": defs["embed"], "final_norm": defs["final_norm"],
         **({"lm_head": defs["lm_head"]} if "lm_head" in defs else {})},
        is_leaf=is_def)
    emb_spec = jax.tree.map(lambda d: d.spec, {
        "embed": defs["embed"], "final_norm": defs["final_norm"],
        **({"lm_head": defs["lm_head"]} if "lm_head" in defs else {})},
        is_leaf=is_def)
    x_struct, x_spec = _x_struct(cfg, B, S, mesh)
    bax = tuple(x_spec)[0]
    tok = jax.ShapeDtypeStruct((B, S), jnp.int32)

    def f(p, tokens, labels, x_mid, ct_mid):
        def g(p, tokens, x_mid):
            comp = x_mid.dtype
            x0 = TF.embed(p["embed"]["tokens"], tokens, comp)
            xs = x_mid + 0 * x0   # couple: embedding feeds the stack
            xf = TF._norm(cfg, p["final_norm"], xs)
            nll, ntok = TF.chunked_ce_loss(cfg, p, xf, labels, n_chunks=1)
            return nll / jnp.maximum(ntok, 1.0)

        loss, vjp = jax.vjp(lambda p, xm: g(p, tokens, xm), p, x_mid)
        dp, dxm = vjp(jnp.ones((), jnp.float32))
        return loss, dp, dxm, ct_mid

    args = (emb_struct, tok, tok, x_struct, x_struct)
    sh = (_sh(mesh, emb_spec), NamedSharding(mesh, P(bax, None)),
          NamedSharding(mesh, P(bax, None)), NamedSharding(mesh, x_spec),
          NamedSharding(mesh, x_spec))
    return _cost_of(f, args, sh, mesh)


def _cost_embed_head_infer(cfg, mesh, ctx, B, S) -> Cost:
    defs = param_defs(cfg, 16)
    sub = {"embed": defs["embed"], "final_norm": defs["final_norm"],
           **({"lm_head": defs["lm_head"]} if "lm_head" in defs else {})}
    emb_struct = jax.tree.map(
        lambda d: jax.ShapeDtypeStruct(d.shape, d.dtype), sub, is_leaf=is_def)
    emb_spec = jax.tree.map(lambda d: d.spec, sub, is_leaf=is_def)
    x_struct, x_spec = _x_struct(cfg, B, S, mesh)
    bax = tuple(x_spec)[0]
    tok = jax.ShapeDtypeStruct((B, S), jnp.int32)

    def f(p, tokens, x_mid):
        comp = x_mid.dtype
        x0 = TF.embed(p["embed"]["tokens"], tokens, comp)
        xf = TF._norm(cfg, p["final_norm"], x_mid + 0 * x0)
        return TF.logits_from_hidden(cfg, p, xf)

    args = (emb_struct, tok, x_struct)
    sh = (_sh(mesh, emb_spec), NamedSharding(mesh, P(bax, None)),
          NamedSharding(mesh, x_spec))
    if cfg.serve_sharded_logits and cfg.vocab % 16 == 0:
        out_sh = NamedSharding(mesh, P(bax, None, "model"))
        with mesh:
            lowered = jax.jit(f, in_shardings=sh,
                              out_shardings=out_sh).lower(*args)
            compiled = lowered.compile()
        ca = compiled.cost_analysis()
        if isinstance(ca, list):
            ca = ca[0]
        stats = parse_collectives(compiled.as_text())
        return Cost(float(ca.get("flops", 0.0)),
                    float(ca.get("bytes accessed", 0.0)),
                    float(stats.total_bytes), dict(stats.bytes_by_kind))
    return _cost_of(f, args, sh, mesh)


def _cost_optimizer(cfg, mesh) -> Cost:
    params, pspecs, opt, ospecs = SP.abstract_state(cfg, mesh)
    opt_mod = adamw8bit if cfg.opt_8bit else adamw
    from repro.optim.adamw import AdamWConfig

    def f(p, g, s):
        return opt_mod.apply_updates(p, g, s, AdamWConfig(lr=1e-3))

    sh = (SP.to_shardings(mesh, pspecs), SP.to_shardings(mesh, pspecs),
          SP.to_shardings(mesh, ospecs))
    return _cost_of(f, (params, params, opt), sh, mesh)


def _cost_decode_layer(cfg, mesh, ctx, B, cache_len) -> Cost:
    lp_struct, lp_spec = _layer_tree(cfg, serve=True)
    cache_full = jax.eval_shape(lambda: TF.init_cache(cfg, B, cache_len))
    cl_struct = {k: jax.ShapeDtypeStruct(v.shape[1:], v.dtype)
                 for k, v in cache_full.items()}
    specs_full = TF.cache_partition_specs(cfg, B, cache_len, 16,
                                          mesh.shape["model"])
    def remap(p):
        parts = [SP.batch_axes(mesh) if ax == "data" else ax
                 for ax in tuple(p)[1:]]
        return P(*parts)
    cl_spec = {k: remap(v) for k, v in specs_full.items()}
    x_struct, x_spec = _x_struct(cfg, B, 1, mesh)
    rope_structs, rope_specs = _rope_structs(cfg, 1)
    spec_obj = TF.cache_spec(cfg, cache_len)
    Sc = spec_obj.cache_len
    rolling = cfg.swa_window is not None and Sc == cfg.swa_window
    pos_struct = jax.ShapeDtypeStruct((), jnp.int32)

    def f(lp, cl, x, pos, *rope):
        cos, sin = (rope[0], rope[1]) if cfg.rope != "none" else (None, None)
        if Sc:
            slot = jnp.mod(pos, Sc) if rolling else pos
            mask = (TF.ATT.rolling_mask(pos, Sc) if rolling
                    else TF.ATT.linear_mask(pos, Sc))
        else:
            slot = mask = None
        return TF.apply_block_decode(cfg, lp, cl, x, pos, cos, sin, mask,
                                     slot, ctx)

    args = (lp_struct, cl_struct, x_struct, pos_struct) + rope_structs
    sh = (_sh(mesh, lp_spec), _sh(mesh, cl_spec),
          NamedSharding(mesh, x_spec), NamedSharding(mesh, P())) + tuple(
        NamedSharding(mesh, s) for s in rope_specs)
    return _cost_of(f, args, sh, mesh)


# ---------------------------------------------------------------------------
# Public: corrected roofline per cell
# ---------------------------------------------------------------------------
def _rwkv_affine(cost_fn, s_lo=64, s_hi=128):
    """Affine-in-S extrapolation for recurrent time scans."""
    c_lo = cost_fn(s_lo)
    c_hi = cost_fn(s_hi)
    def at(S):
        slope = (c_hi + c_lo * -1.0) * (1.0 / (s_hi - s_lo))
        return c_lo + slope * (S - s_lo)
    return at


def cost_cell(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh,
              microbatches: int = 1) -> dict:
    """Corrected per-device roofline for one (arch x shape) cell."""
    ctx = ShardCtx(mesh)
    B, S = shape.global_batch, shape.seq_len
    L = cfg.n_layers
    comps: dict[str, tuple[Cost, float]] = {}   # name -> (unit cost, trips)

    recurrent = (cfg.family == "ssm" and cfg.ssm is not None
                 and cfg.ssm.kind == "rwkv6")

    if shape.kind == "train":
        mb = microbatches
        Bm = max(B // mb, 1)
        if recurrent:
            aff = _rwkv_affine(lambda s: _cost_layer_train(cfg, mesh, ctx, Bm, s))
            comps["layer_fwd_bwd"] = (aff(S), L * mb)
        else:
            comps["layer_fwd_bwd"] = (
                _cost_layer_train(cfg, mesh, ctx, Bm, S), L * mb)
        if cfg.enc_layers:
            comps["enc_layer_fwd_bwd"] = (
                _cost_layer_train(cfg, mesh, ctx, Bm, cfg.enc_seq, enc=True),
                cfg.enc_layers * mb)
        comps["embed_head_loss"] = (
            _cost_embed_head_train(cfg, mesh, ctx, Bm, S), mb)
        comps["optimizer"] = (_cost_optimizer(cfg, mesh), 1)
        # gradient accumulation traffic (analytic): read+write accum buffer
        if mb > 1:
            from repro.models.params import param_count
            n = param_count(cfg, 16)
            bpe = 4 if cfg.accum_dtype == "float32" else 2
            acc = Cost(flops=n, bytes=3.0 * n * bpe / 256)
            comps["grad_accum(analytic)"] = (acc, mb)
        tokens = B * S
    elif shape.kind == "prefill":
        if recurrent:
            aff = _rwkv_affine(lambda s: _cost_layer_fwd(cfg, mesh, ctx, B, s))
            comps["layer_fwd"] = (aff(S), L)
        else:
            comps["layer_fwd"] = (_cost_layer_fwd(cfg, mesh, ctx, B, S), L)
        if cfg.enc_layers:
            comps["enc_layer_fwd"] = (
                _cost_layer_fwd(cfg, mesh, ctx, B, cfg.enc_seq, enc=True),
                cfg.enc_layers)
        comps["embed_head"] = (_cost_embed_head_infer(cfg, mesh, ctx, B, S), 1)
        tokens = B * S
    else:  # decode
        comps["layer_decode"] = (_cost_decode_layer(cfg, mesh, ctx, B, S), L)
        comps["embed_head"] = (_cost_embed_head_infer(cfg, mesh, ctx, B, 1), 1)
        tokens = B

    total = Cost()
    breakdown = {}
    for name, (c, trips) in comps.items():
        tc = c * trips
        total = total + tc
        breakdown[name] = {"flops": tc.flops, "bytes": tc.bytes,
                           "coll_bytes": tc.coll, "trips": trips}
    roof = Roofline(flops=total.flops, bytes_accessed=total.bytes,
                    collective_bytes=total.coll,
                    coll_by_kind=total.coll_by_kind)
    return {"roofline": roof.summary(), "breakdown": breakdown,
            "tokens": tokens}
