"""Analytic roofline placement for the circuit-simulation kernel variants.

`roofline.analysis` extracts terms from *compiled* XLA artifacts; the
Pallas circuit kernels need the complementary view — a first-principles
count of the word-ops and HBM bytes each variant moves for a given
workload shape, so BENCH_evolve.json can show *why* the fused megakernel
wins: not a faster gate loop, but orders of magnitude less traffic.

Workload: P programs x G gates x W uint32 words (32 test vectors per
word), n_in packed input rows, n_out output taps.  Every gate applies the
4-term ANF form (xor/and over 32-lane words — ~6 word-ops), so all
variants share one compute term:

    ops = P * G * W * ANF_OPS_PER_GATE_WORD

What separates them is bytes:

  * ``swar``    — the `lax.scan` twin keeps a (P, n_in+G, W) value carry
    live across gate steps; XLA materializes the carry per step, so each
    gate pays a gather read (2 operand rows) and a row write, and the
    LSB-first decode then expands each output's words into a (P, W, 32)
    int32 bit plane on the host side of the kernel boundary.
  * ``pallas_unfused`` — the pre-fusion two-stage path: the kernel walks
    gates in VMEM scratch (plan + words in, (P, n_out, W) words out), but
    the decode stage re-reads those words and builds the same per-output
    (P, W, 32) planes.
  * ``pallas_fused`` — gate walk + output extraction + decode in ONE
    launch: plan tables and the word plane stream in, the value plane
    never leaves VMEM, and the ONLY output traffic is the decoded
    (P, W*32) int32 plane.
  * ``fleet``   — the multi-tenant variant: same fused traffic but over
    tables padded to (T, G_max+1) / (T, n_in_max, W_max); `efficiency`
    reports real work / padded work, the price of one-launch dispatch.

All byte counts are HBM-side (VMEM-resident traffic is free by
construction — that is the point of the fusion); `Roofline.dominant`
then places each variant on the same TPU-v5e roofline the rest of the
repo uses.
"""
from __future__ import annotations

from dataclasses import dataclass

from repro.roofline.analysis import Roofline

ANF_OPS_PER_GATE_WORD = 6     # r = m0 ^ (ma&a) ^ (mb&b) ^ (mab&(a&b))
_PLAN_BYTES_PER_GATE = 4 + 4 + 4 * 4   # in0 + in1 + four uint32 ANF masks
_WORD = 4                     # uint32
_INT = 4                      # int32 decoded outputs


@dataclass
class CircuitShape:
    """One population-eval workload: P programs x G gates x W words."""
    P: int
    G: int
    n_in: int
    W: int
    n_out: int
    shared_words: bool = True

    @property
    def vectors(self) -> int:
        return self.W * 32

    def _words_bytes(self) -> int:
        rows = self.n_in if self.shared_words else self.P * self.n_in
        return rows * self.W * _WORD

    def _plan_bytes(self) -> int:
        return self.P * (self.G * _PLAN_BYTES_PER_GATE + self.n_out * 4)

    def _decode_plane_bytes(self) -> int:
        # the unfused decode builds one (P, W, 32) int32 bit plane per
        # output bit (write + accumulate read), then the final int plane
        per_output = 2 * self.P * self.W * 32 * _INT
        return self.n_out * per_output + self.P * self.vectors * _INT

    @property
    def ops(self) -> float:
        return float(self.P * self.G * self.W * ANF_OPS_PER_GATE_WORD)


def swar_roofline(s: CircuitShape) -> Roofline:
    # per gate step the scan carry pays 2 gathered operand rows (read) and
    # one result row (write) at HBM, per program
    carry = s.P * s.G * 3 * s.W * _WORD
    out_words = s.P * s.n_out * s.W * _WORD
    byt = s._plan_bytes() + s._words_bytes() + carry + out_words \
        + out_words + s._decode_plane_bytes()
    return Roofline(flops=s.ops, bytes_accessed=float(byt),
                    collective_bytes=0.0)


def pallas_unfused_roofline(s: CircuitShape) -> Roofline:
    # stage 1: plan + words in, output words out (value plane in VMEM);
    # stage 2: output words back in, decode planes out
    out_words = s.P * s.n_out * s.W * _WORD
    byt = s._plan_bytes() + s._words_bytes() + out_words \
        + out_words + s._decode_plane_bytes()
    return Roofline(flops=s.ops, bytes_accessed=float(byt),
                    collective_bytes=0.0)


def pallas_fused_roofline(s: CircuitShape, block_pop: int = 8) -> Roofline:
    # one launch: a shared word plane is re-streamed once per pop tile,
    # and the only output is the decoded int plane
    tiles = max(1, -(-s.P // block_pop)) if s.shared_words else 1
    byt = s._plan_bytes() + tiles * s._words_bytes() \
        + s.P * s.vectors * _INT
    return Roofline(flops=s.ops, bytes_accessed=float(byt),
                    collective_bytes=0.0)


def fleet_roofline(shapes: list[CircuitShape]) -> tuple[Roofline, float]:
    """Padded multi-tenant launch over per-tenant shapes (P=1 each).

    Returns the roofline of the ONE fused launch plus its padding
    efficiency (real gate-word work / padded gate-word work) — the cost
    of forcing T heterogeneous plans into common (G_max, n_in_max,
    W_max) tables.
    """
    T = len(shapes)
    if T == 0:
        raise ValueError("fleet_roofline needs at least one tenant shape")
    G_max = max(s.G for s in shapes) + 1      # +1 trailing CONST0 pad gate
    n_in_max = max(s.n_in for s in shapes)
    W_max = max(s.W for s in shapes)
    n_out_max = max(s.n_out for s in shapes)
    padded = CircuitShape(P=T, G=G_max, n_in=n_in_max, W=W_max,
                          n_out=n_out_max, shared_words=False)
    real_ops = sum(s.ops for s in shapes)
    eff = real_ops / padded.ops if padded.ops else 1.0
    return pallas_fused_roofline(padded, block_pop=1), eff


def variant_rows(s: CircuitShape, block_pop: int = 8) -> list[dict]:
    """One BENCH-ready row per single-program kernel variant."""
    rows = []
    for name, rl in (("swar", swar_roofline(s)),
                     ("pallas_unfused", pallas_unfused_roofline(s)),
                     ("pallas_fused", pallas_fused_roofline(s, block_pop))):
        rows.append({
            "variant": name,
            "ops": rl.flops,
            "hbm_bytes": rl.bytes_accessed,
            "arith_intensity": round(rl.flops / rl.bytes_accessed, 3),
            "dominant": rl.dominant,
            "bound_s": rl.bound_s,
        })
    return rows
