"""Serving driver: batched greedy decoding through the ServingEngine.

  python -m repro.launch.serve --arch llama3.2-1b --reduced \
      --requests 8 --max-new 16
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.models.params import init_params, param_count
from repro.serve.lm_engine import Request, ServingEngine


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--cache-len", type=int, default=128)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    params = init_params(jax.random.PRNGKey(args.seed), cfg)
    engine = ServingEngine(cfg, params, max_batch=args.max_batch,
                           cache_len=args.cache_len)

    rng = np.random.default_rng(args.seed)
    reqs = []
    for i in range(args.requests):
        plen = int(rng.integers(4, 12))
        reqs.append(Request(
            uid=i, prompt=rng.integers(1, cfg.vocab, plen).tolist(),
            max_new_tokens=args.max_new))
    t0 = time.monotonic()
    out = engine.run(reqs)
    dt = time.monotonic() - t0
    total_new = sum(len(r.output) for r in out)
    print(f"{param_count(cfg)/1e6:.1f}M params | {len(out)} requests, "
          f"{total_new} tokens in {dt:.1f}s ({total_new/dt:.1f} tok/s)")
    for r in out[:3]:
        print(json.dumps({"uid": r.uid, "prompt": r.prompt,
                          "output": r.output}))


if __name__ == "__main__":
    main()
