"""Production mesh construction.

A function (not a module-level constant) so importing this module never
touches jax device state.  Single pod: 16x16 = 256 chips (v5e pod),
axes (data, model).  Multi-pod: 2 pods = 512 chips, axes (pod, data, model)
— the "pod" axis is the slow DCI dimension; batch shards over (pod, data).
"""
from __future__ import annotations

import jax
from jax.sharding import AxisType


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes,
                         axis_types=(AxisType.Auto,) * len(axes))


def make_host_mesh():
    """1-device mesh for CPU smoke paths (same axis names, all size 1)."""
    return jax.make_mesh((1, 1), ("data", "model"),
                         axis_types=(AxisType.Auto, AxisType.Auto))
