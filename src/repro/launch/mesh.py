"""Production mesh construction.

A function (not a module-level constant) so importing this module never
touches jax device state.  Single pod: 16x16 = 256 chips (v5e pod),
axes (data, model).  Multi-pod: 2 pods = 512 chips, axes (pod, data, model)
— the "pod" axis is the slow DCI dimension; batch shards over (pod, data).

`jax.sharding.AxisType` only exists on jax >= 0.5; on the pinned 0.4.37 the
`axis_types=` kwarg is unsupported, so `make_mesh_compat` transparently drops
it (every axis is then implicitly "auto", which is the behaviour we rely on).
Tests and launch code must build meshes through this shim, never through
`jax.make_mesh(..., axis_types=...)` directly.
"""
from __future__ import annotations

import jax

try:  # jax >= 0.5
    from jax.sharding import AxisType as _AxisType
except ImportError:  # jax 0.4.x: no explicit/auto axis types, all axes auto
    _AxisType = None

HAS_AXIS_TYPES = _AxisType is not None


def make_mesh_compat(shape, axis_names):
    """`jax.make_mesh` with all-auto axis types where the API supports them."""
    if HAS_AXIS_TYPES:
        return jax.make_mesh(shape, axis_names,
                             axis_types=(_AxisType.Auto,) * len(axis_names))
    return jax.make_mesh(shape, axis_names)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh_compat(shape, axes)


def make_host_mesh():
    """1-device mesh for CPU smoke paths (same axis names, all size 1)."""
    return make_mesh_compat((1, 1), ("data", "model"))
