import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Corrected roofline runner: component costing + analytic memory model.

Writes reports/roofline.jsonl with, per (arch x shape) single-pod cell:
  * trip-count-correct compute / collective terms (component compiles),
  * XLA bytes term (stated unfused upper bound) AND the analytic fused
    memory estimate used for bottleneck identification,
  * per-component breakdown (the §Perf iteration input).

Usage: python -m repro.launch.roofline_run [--arch all] [--shape all]
       [--quant ...] [--microbatches N] [--tag label] [--moe-fsdp d|f|none]
"""
import argparse
import json
import time
import traceback

from repro.configs import ARCHS, SHAPES, get_config, shape_applicable
from repro.launch.mesh import make_production_mesh
from repro.roofline.analysis import HBM_BW, PEAK_FLOPS, model_flops
from repro.roofline.component_costing import cost_cell
from repro.roofline.memory_model import analytic_memory_bytes


def run_cell(arch: str, shape_name: str, *, quant=None, microbatches=None,
             remat=None, moe_fsdp=None, serve_tp_only=False,
             kv_dtype=None, replicate_kv=False, capacity_factor=None,
             sharded_logits=False) -> dict:
    cfg = get_config(arch)
    if quant:
        cfg = cfg.replace(quant=quant)
    if remat is not None:
        cfg = cfg.replace(remat=remat)
    if moe_fsdp:
        cfg = cfg.replace(moe_fsdp=moe_fsdp)
    if serve_tp_only:
        cfg = cfg.replace(serve_fsdp=False)
    if kv_dtype:
        cfg = cfg.replace(kv_cache_dtype=kv_dtype)
    if replicate_kv:
        cfg = cfg.replace(replicate_kv=True)
    if capacity_factor is not None and cfg.moe is not None:
        import dataclasses
        cfg = cfg.replace(moe=dataclasses.replace(
            cfg.moe, capacity_factor=capacity_factor))
    if sharded_logits:
        cfg = cfg.replace(serve_sharded_logits=True)
    shape = SHAPES[shape_name]
    rec = {"arch": arch, "shape": shape_name, "quant": cfg.quant,
           "remat": cfg.remat, "moe_fsdp": cfg.moe_fsdp,
           "serve_fsdp": cfg.serve_fsdp, "kv_cache_dtype": cfg.kv_cache_dtype,
           "replicate_kv": cfg.replicate_kv,
           "capacity_factor": cfg.moe.capacity_factor if cfg.moe else None,
           "sharded_logits": cfg.serve_sharded_logits}
    ok, reason = shape_applicable(cfg, shape)
    if not ok:
        rec.update(status="skipped", reason=reason)
        return rec
    mesh = make_production_mesh()
    if shape.kind == "train":
        dsz = 16
        per_shard = max(1, shape.global_batch // dsz)
        mb = microbatches or min(16 if cfg.d_model >= 7000 else 8, per_shard)
    else:
        mb = 1
    rec["microbatches"] = mb
    t0 = time.monotonic()
    out = cost_cell(cfg, shape, mesh, microbatches=mb)
    rec["cost_s"] = round(time.monotonic() - t0, 1)
    roof = out["roofline"]
    mem = analytic_memory_bytes(cfg, shape, mb)
    rec["roofline"] = roof
    rec["breakdown"] = out["breakdown"]
    rec["memory_analytic"] = mem
    rec["memory_analytic_s"] = mem["total"] / HBM_BW
    terms = {"compute": roof["compute_s"],
             "memory": mem["total"] / HBM_BW,
             "collective": roof["collective_s"]}
    rec["dominant"] = max(terms, key=terms.get)
    rec["bound_s"] = max(terms.values())
    tokens = out["tokens"]
    from repro.models.params import active_param_count
    mf = model_flops(active_param_count(cfg.replace(quant="dense")), tokens, shape.kind) / 256
    rec["model_flops_per_device"] = mf
    rec["useful_flops_ratio"] = mf / max(roof["flops_per_device"], 1.0)
    # roofline fraction: useful model flops time / achievable bound
    rec["roofline_fraction"] = (mf / PEAK_FLOPS) / max(rec["bound_s"], 1e-12)
    rec["status"] = "ok"
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--quant", default=None)
    ap.add_argument("--microbatches", type=int, default=None)
    ap.add_argument("--remat", default=None, choices=[None, "on", "off"])
    ap.add_argument("--moe-fsdp", default=None, choices=[None, "d", "f", "none"])
    ap.add_argument("--serve-tp-only", action="store_true")
    ap.add_argument("--kv-dtype", default=None,
                    choices=[None, "compute", "float8_e4m3fn"])
    ap.add_argument("--replicate-kv", action="store_true")
    ap.add_argument("--capacity-factor", type=float, default=None)
    ap.add_argument("--sharded-logits", action="store_true")
    ap.add_argument("--tag", default="baseline")
    ap.add_argument("--out", default="reports/roofline.jsonl")
    args = ap.parse_args()
    archs = list(ARCHS) if args.arch == "all" else [args.arch]
    shapes = list(SHAPES) if args.shape == "all" else [args.shape]
    remat = None if args.remat is None else (args.remat == "on")

    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    with open(args.out, "a") as f:
        for arch in archs:
            for shape in shapes:
                try:
                    rec = run_cell(arch, shape, quant=args.quant,
                                   microbatches=args.microbatches,
                                   remat=remat, moe_fsdp=args.moe_fsdp,
                                   serve_tp_only=args.serve_tp_only,
                                   kv_dtype=args.kv_dtype,
                                   replicate_kv=args.replicate_kv,
                                   capacity_factor=args.capacity_factor,
                                   sharded_logits=args.sharded_logits)
                except Exception as e:   # noqa: BLE001
                    rec = {"arch": arch, "shape": shape, "status": "error",
                           "error": f"{type(e).__name__}: {e}",
                           "trace": traceback.format_exc()[-1500:]}
                rec["tag"] = args.tag
                f.write(json.dumps(rec) + "\n")
                f.flush()
                if rec["status"] == "ok":
                    r = rec["roofline"]
                    print(f"[ok] {arch} x {shape}: "
                          f"compute {r['compute_s']*1e3:.1f} ms | "
                          f"mem(xla) {r['memory_s']*1e3:.0f} ms | "
                          f"mem(analytic) {rec['memory_analytic_s']*1e3:.1f} ms | "
                          f"coll {r['collective_s']*1e3:.1f} ms "
                          f"-> {rec['dominant']}-bound, "
                          f"roofline {rec['roofline_fraction']:.1%} "
                          f"({rec['cost_s']}s)")
                elif rec["status"] == "skipped":
                    print(f"[skip] {arch} x {shape}")
                else:
                    print(f"[FAIL] {arch} x {shape}: {rec['error']}")


if __name__ == "__main__":
    main()
