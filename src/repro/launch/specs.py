"""Input/ state specs for every (arch x shape) dry-run cell.

`input_specs(cfg, shape)` returns ShapeDtypeStruct stand-ins for every model
input (weak-type-correct, shardable, no device allocation) together with
PartitionSpecs; `abstract_state` does the same for params + optimizer state.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import transformer as TF
from repro.models.params import abstract_params, partition_specs
from repro.optim import adamw, adamw8bit


def batch_axes(mesh: Mesh) -> tuple[str, ...]:
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def data_size(mesh: Mesh) -> int:
    n = mesh.shape["data"]
    if "pod" in mesh.axis_names:
        n *= mesh.shape["pod"]
    return n


def _sds(shape, dtype) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def train_batch_specs(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh,
                      with_labels: bool = True) -> tuple[dict, dict]:
    """(ShapeDtypeStruct dict, PartitionSpec dict) for a train/prefill batch."""
    B, S = shape.global_batch, shape.seq_len
    bax = batch_axes(mesh) if B % data_size(mesh) == 0 else None
    structs: dict = {"tokens": _sds((B, S), jnp.int32)}
    specs: dict = {"tokens": P(bax, None)}
    if with_labels:
        structs["labels"] = _sds((B, S), jnp.int32)
        specs["labels"] = P(bax, None)
    if cfg.rope == "mrope":
        structs["positions"] = _sds((B, 3, S), jnp.int32)
        specs["positions"] = P(bax, None, None)
        structs["vision_embeds"] = _sds((B, cfg.n_vision_tokens, cfg.d_model),
                                        jnp.bfloat16)
        specs["vision_embeds"] = P(bax, None, None)
    if cfg.enc_layers:
        structs["enc_frames"] = _sds((B, cfg.enc_seq, cfg.d_model), jnp.bfloat16)
        specs["enc_frames"] = P(bax, None, None)
    return structs, specs


def decode_inputs(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh
                  ) -> tuple[tuple, tuple]:
    """(cache, tokens, pos) structs + specs for a serve_step cell."""
    B, S = shape.global_batch, shape.seq_len
    dsz = data_size(mesh)
    msz = mesh.shape["model"]
    bax = batch_axes(mesh) if B % dsz == 0 and B > 1 else None

    cache_struct = jax.eval_shape(lambda: TF.init_cache(cfg, B, S))
    cache_specs_raw = TF.cache_partition_specs(cfg, B, S, dsz, msz)
    # remap "data" -> (pod, data) batch axes for the multi-pod mesh
    def remap(p: P) -> P:
        parts = []
        for ax in p:
            if ax == "data":
                parts.append(batch_axes(mesh))
            else:
                parts.append(ax)
        return P(*parts)
    cache_specs = jax.tree.map(
        remap, cache_specs_raw,
        is_leaf=lambda s: isinstance(s, P))

    tok_struct = _sds((B, 1), jnp.int32)
    tok_spec = P(bax, None)
    pos_struct = _sds((), jnp.int32)
    pos_spec = P()
    structs = (cache_struct, tok_struct, pos_struct)
    specs = (cache_specs, tok_spec, pos_spec)
    if cfg.rope == "mrope":
        structs += (_sds((B, 3, 1), jnp.int32),)
        specs += (P(bax, None, None),)
    return structs, specs


def opt_partition_specs(cfg: ModelConfig, pspecs: Any) -> Any:
    """Optimizer state specs congruent with adamw/adamw8bit state trees.

    f32 moments inherit the parameter specs (ZeRO via the FSDP dim);
    int8 codes inherit the param spec, per-block scales drop the last axis.
    """
    if not cfg.opt_8bit:
        return adamw.AdamWState(step=P(), mu=pspecs, nu=pspecs)

    def q8spec(ps: P) -> adamw8bit.Q8Tensor:
        axes = tuple(ps) if len(ps) else (None,)
        scale_axes = axes[:-1] + (None,) if len(axes) else (None,)
        return adamw8bit.Q8Tensor(codes=P(*axes), scales=P(*scale_axes))

    q = jax.tree.map(q8spec, pspecs, is_leaf=lambda s: isinstance(s, P))
    return adamw8bit.AdamW8bitState(step=P(), mu=q, nu=q)


def abstract_state(cfg: ModelConfig, mesh: Mesh) -> tuple[Any, Any, Any, Any]:
    """(params_struct, params_specs, opt_struct, opt_specs) — no allocation."""
    msz = mesh.shape["model"]
    params = abstract_params(cfg, msz)
    pspecs = partition_specs(cfg, msz)
    opt_mod = adamw8bit if cfg.opt_8bit else adamw
    opt = jax.eval_shape(lambda: opt_mod.init(params))
    ospecs = opt_partition_specs(cfg, pspecs)
    return params, pspecs, opt, ospecs


def to_shardings(mesh: Mesh, specs: Any) -> Any:
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                        is_leaf=lambda s: isinstance(s, P))
