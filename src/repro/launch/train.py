"""Training driver (real execution, CPU-runnable).

Examples:
  # reduced-config smoke train of any assigned arch
  python -m repro.launch.train --arch qwen3-4b --reduced --steps 20

  # ~100M-param LM trained for a few hundred steps (deliverable (b) driver)
  python -m repro.launch.train --preset lm100m --steps 300 \
      --ckpt-dir /tmp/ckpt_lm100m

  # the paper's technique at LM scale: ternary QAT
  python -m repro.launch.train --preset lm100m --quant ternary --steps 300
"""
from __future__ import annotations

import argparse
import json

import jax

from repro.configs import get_config
from repro.configs.base import ModelConfig
from repro.data.tokens import TokenPipeline, TokenPipelineConfig
from repro.models.params import init_params, param_count
from repro.optim import adamw, adamw8bit
from repro.optim.adamw import AdamWConfig
from repro.optim.grad_compress import init_error_buffer
from repro.train.loop import Trainer, TrainLoopConfig


def preset_lm100m() -> ModelConfig:
    """~100M-param llama-style config that trains in minutes on CPU."""
    return ModelConfig(
        name="lm100m", family="dense", n_layers=8, d_model=512,
        n_heads=8, n_kv_heads=4, d_head=64, d_ff=1536, vocab=8192,
        rope="std", rope_theta=1e4, tie_embeddings=True,
        param_dtype="float32", compute_dtype="float32", remat=False)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--preset", default=None, choices=[None, "lm100m"])
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--quant", default=None,
                    choices=[None, "dense", "ternary"])
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--grad-compress", action="store_true")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=100)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    if args.preset == "lm100m":
        cfg = preset_lm100m()
    elif args.arch:
        cfg = get_config(args.arch)
        if args.reduced:
            cfg = cfg.reduced()
    else:
        raise SystemExit("pass --arch or --preset")
    if args.quant:
        cfg = cfg.replace(quant=args.quant)

    print(f"config {cfg.name}: {param_count(cfg)/1e6:.1f}M params, "
          f"quant={cfg.quant}")
    pipe = TokenPipeline(TokenPipelineConfig(
        vocab=cfg.vocab, seq_len=args.seq, global_batch=args.batch,
        seed=args.seed))
    loop_cfg = TrainLoopConfig(
        total_steps=args.steps, microbatches=args.microbatches,
        ckpt_every=args.ckpt_every, log_every=10,
        grad_compress=args.grad_compress,
        optimizer=AdamWConfig(lr=args.lr, warmup_steps=min(20, args.steps // 5),
                              total_steps=args.steps))
    trainer = Trainer(cfg, loop_cfg, pipe, args.ckpt_dir)

    opt_mod = adamw8bit if cfg.opt_8bit else adamw

    def init_fn():
        params = init_params(jax.random.PRNGKey(args.seed), cfg)
        return params, opt_mod.init(params)

    params, opt_state, start = trainer.resume_or_init(init_fn)
    if start:
        print(f"resumed from step {start}")
    err = init_error_buffer(params) if args.grad_compress else None
    params, opt_state, result = trainer.run(params, opt_state,
                                            start_step=start, err_buf=err)
    print(json.dumps({"first_loss": result["losses"][0] if result["losses"] else None,
                      "last_loss": result["losses"][-1] if result["losses"] else None,
                      "steps": result["last_step"],
                      "stragglers": len(result["stragglers"])}))


if __name__ == "__main__":
    main()
