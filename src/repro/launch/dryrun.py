import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: AOT-lower + compile every (arch x shape x mesh) cell.

The two lines above MUST stay first (before any jax-importing module): jax
locks the device count at first init, and the production meshes need 512
placeholder host devices.  Do not set that flag anywhere global — smoke
tests and benches see 1 device.

Per cell this driver:
  1. builds abstract params/optimizer/batch (ShapeDtypeStruct, no alloc),
  2. jits the step with explicit in/out shardings and lowers it,
  3. compiles — success proves the distribution config is coherent,
  4. prints compiled.memory_analysis()  (fits-in-HBM evidence) and
     cost_analysis() + parsed collective bytes (the §Roofline inputs),
  5. appends a JSON record to reports/dryrun.jsonl.

Usage:
  python -m repro.launch.dryrun --arch qwen3-4b --shape train_4k
  python -m repro.launch.dryrun --arch all --shape all --mesh both
  python -m repro.launch.dryrun ... --quant ternary_packed   (perf variants)
"""
import argparse
import json
import time
import traceback

import jax

from repro.configs import ARCHS, SHAPES, get_config, shape_applicable
from repro.launch import specs as SP
from repro.launch.mesh import make_production_mesh
from repro.models import transformer as TF
from repro.models.params import active_param_count, param_count
from repro.models.sharding import ShardCtx
from repro.roofline.analysis import model_flops, roofline_from_compiled
from repro.train.loop import TrainLoopConfig, make_train_step


def _default_microbatches(cfg, shape, mesh) -> int:
    dsz = SP.data_size(mesh)
    per_shard = max(1, shape.global_batch // dsz)
    want = 16 if cfg.d_model >= 7000 else 8
    mb = min(want, per_shard)
    while per_shard % mb:
        mb -= 1
    return max(1, mb)


def lower_cell(arch: str, shape_name: str, *, multi_pod: bool,
               quant: str | None = None, microbatches: int | None = None,
               remat: bool | None = None, accum_dtype: str | None = None,
               moe_fsdp: str | None = None, serve_tp_only: bool = False,
               kv_dtype: str | None = None) -> dict:
    cfg = get_config(arch)
    if quant:
        cfg = cfg.replace(quant=quant)
    if remat is not None:
        cfg = cfg.replace(remat=remat)
    if accum_dtype:
        cfg = cfg.replace(accum_dtype=accum_dtype)
    if moe_fsdp:
        cfg = cfg.replace(moe_fsdp=moe_fsdp)
    if serve_tp_only:
        cfg = cfg.replace(serve_fsdp=False)
    if kv_dtype:
        cfg = cfg.replace(kv_cache_dtype=kv_dtype)
    shape = SHAPES[shape_name]
    ok, reason = shape_applicable(cfg, shape)
    rec: dict = {"arch": arch, "shape": shape_name,
                 "mesh": "2x16x16" if multi_pod else "16x16",
                 "quant": cfg.quant, "remat": cfg.remat,
                 "accum_dtype": cfg.accum_dtype, "moe_fsdp": cfg.moe_fsdp,
                 "params": param_count(cfg),
                 "active_params": active_param_count(cfg.replace(quant="dense"))}
    if not ok:
        rec["status"] = "skipped"
        rec["reason"] = reason
        return rec

    mesh = make_production_mesh(multi_pod=multi_pod)
    ctx = ShardCtx(mesh)
    sh = lambda s: SP.to_shardings(mesh, s)
    t0 = time.monotonic()

    with mesh:
        if shape.kind == "train":
            mb = microbatches or _default_microbatches(cfg, shape, mesh)
            rec["microbatches"] = mb
            params_s, pspecs, opt_s, ospecs = SP.abstract_state(cfg, mesh)
            batch_s, bspecs = SP.train_batch_specs(cfg, shape, mesh)
            step_fn = make_train_step(cfg, TrainLoopConfig(microbatches=mb), ctx)

            def train_step(params, opt, batch):
                p, o, metrics, _ = step_fn(params, opt, batch, None)
                return p, o, metrics["loss"]

            jitted = jax.jit(
                train_step,
                in_shardings=(sh(pspecs), sh(ospecs), sh(bspecs)),
                out_shardings=(sh(pspecs), sh(ospecs), None),
                donate_argnums=(0, 1))
            lowered = jitted.lower(params_s, opt_s, batch_s)
            tokens = shape.global_batch * shape.seq_len
        elif shape.kind == "prefill":
            params_s, pspecs, _, _ = SP.abstract_state(cfg, mesh)
            batch_s, bspecs = SP.train_batch_specs(cfg, shape, mesh,
                                                   with_labels=False)

            def prefill_fn(params, batch):
                return TF.prefill(cfg, params, batch, cache_len=shape.seq_len,
                                  ctx=ctx)

            jitted = jax.jit(prefill_fn,
                             in_shardings=(sh(pspecs), sh(bspecs)))
            lowered = jitted.lower(params_s, batch_s)
            tokens = shape.global_batch * shape.seq_len
        else:   # decode
            params_s, pspecs, _, _ = SP.abstract_state(cfg, mesh)
            if not cfg.serve_fsdp:
                from repro.models.params import strip_fsdp_tree
                pspecs = strip_fsdp_tree(pspecs)
            (structs, dspecs) = SP.decode_inputs(cfg, shape, mesh)

            if cfg.rope == "mrope":
                def serve_step(params, cache, tok, pos, positions):
                    return TF.decode_step(cfg, params, cache, tok, pos, ctx,
                                          positions=positions)
            else:
                def serve_step(params, cache, tok, pos):
                    return TF.decode_step(cfg, params, cache, tok, pos, ctx)

            cache_spec = dspecs[0]
            jitted = jax.jit(serve_step,
                             in_shardings=(sh(pspecs),) + tuple(
                                 sh(s) for s in dspecs),
                             out_shardings=(None, sh(cache_spec)),
                             donate_argnums=(1,))
            lowered = jitted.lower(params_s, *structs)
            tokens = shape.global_batch

        rec["lower_s"] = round(time.monotonic() - t0, 1)
        t1 = time.monotonic()
        compiled = lowered.compile()
        rec["compile_s"] = round(time.monotonic() - t1, 1)

    mem = compiled.memory_analysis()
    rec["memory"] = {
        "argument_bytes": int(mem.argument_size_in_bytes),
        "output_bytes": int(mem.output_size_in_bytes),
        "temp_bytes": int(mem.temp_size_in_bytes),
        "alias_bytes": int(mem.alias_size_in_bytes),
        "peak_estimate_bytes": int(mem.argument_size_in_bytes
                                   + mem.output_size_in_bytes
                                   + mem.temp_size_in_bytes
                                   - mem.alias_size_in_bytes),
    }
    roof = roofline_from_compiled(compiled)
    rec["roofline"] = roof.summary()
    mf = model_flops(rec["active_params"], tokens, shape.kind)
    rec["model_flops_total"] = mf
    n_dev = 512 if multi_pod else 256
    rec["model_flops_per_device"] = mf / n_dev
    rec["useful_flops_ratio"] = (mf / n_dev) / max(roof.flops, 1.0)
    rec["status"] = "ok"
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--quant", default=None,
                    choices=[None, "dense", "ternary", "ternary_packed"])
    ap.add_argument("--microbatches", type=int, default=None)
    ap.add_argument("--remat", default=None, choices=[None, "on", "off"])
    ap.add_argument("--accum-dtype", default=None,
                    choices=[None, "float32", "bfloat16"])
    ap.add_argument("--moe-fsdp", default=None, choices=[None, "d", "f", "none"])
    ap.add_argument("--serve-tp-only", action="store_true")
    ap.add_argument("--kv-dtype", default=None,
                    choices=[None, "compute", "float8_e4m3fn"])
    ap.add_argument("--out", default="reports/dryrun.jsonl")
    args = ap.parse_args()

    archs = list(ARCHS) if args.arch == "all" else [args.arch]
    shapes = list(SHAPES) if args.shape == "all" else [args.shape]
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]
    remat = None if args.remat is None else (args.remat == "on")

    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    n_fail = 0
    with open(args.out, "a") as f:
        for arch in archs:
            for shape in shapes:
                for mp in meshes:
                    label = (f"{arch} x {shape} x "
                             f"{'2x16x16' if mp else '16x16'}")
                    try:
                        rec = lower_cell(arch, shape, multi_pod=mp,
                                         quant=args.quant,
                                         microbatches=args.microbatches,
                                         remat=remat,
                                         accum_dtype=args.accum_dtype,
                                         moe_fsdp=args.moe_fsdp,
                                         serve_tp_only=args.serve_tp_only,
                                         kv_dtype=args.kv_dtype)
                    except Exception as e:   # noqa: BLE001 — report & continue
                        rec = {"arch": arch, "shape": shape,
                               "mesh": "2x16x16" if mp else "16x16",
                               "status": "error",
                               "error": f"{type(e).__name__}: {e}",
                               "trace": traceback.format_exc()[-2000:]}
                        n_fail += 1
                    f.write(json.dumps(rec) + "\n")
                    f.flush()
                    if rec["status"] == "ok":
                        m = rec["memory"]
                        r = rec["roofline"]
                        print(f"[ok] {label}: compile {rec['compile_s']}s | "
                              f"args {m['argument_bytes']/2**30:.2f} GiB/dev, "
                              f"temp {m['temp_bytes']/2**30:.2f} GiB/dev | "
                              f"compute {r['compute_s']*1e3:.1f} ms, "
                              f"memory {r['memory_s']*1e3:.1f} ms, "
                              f"collective {r['collective_s']*1e3:.1f} ms "
                              f"-> {r['dominant']}-bound")
                    elif rec["status"] == "skipped":
                        print(f"[skip] {label}: {rec['reason']}")
                    else:
                        print(f"[FAIL] {label}: {rec['error']}")
    if n_fail:
        raise SystemExit(f"{n_fail} cells failed")


if __name__ == "__main__":
    main()
