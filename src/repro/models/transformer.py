"""Model assembly: all 10 assigned architectures from one set of blocks.

Structure:
  * layer stacks are `lax.scan` over (L, ...)-stacked params — HLO size and
    compile time are depth-independent (essential for the 80L/56L dry-runs);
  * `jax.checkpoint` (full remat) wraps the scanned body when cfg.remat;
  * decode threads the per-layer cache through the same scan as xs/ys;
  * the LM loss is computed in sequence chunks so the (B, S, 152k) logits
    tensor never materializes (chunked softmax-CE).

Batch dict keys by family:
  tokens (B,S) i32, labels (B,S) i32 (pad = -1)
  vlm:   + positions (B,3,S) i32 (M-RoPE), vision_embeds (B,Nv,D)
  audio: + enc_frames (B,enc_seq,D)   [conv frontend stub]
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models import attention as ATT
from repro.models import moe as MOE
from repro.models import ssm as SSM
from repro.models.layers import (
    apply_rope, embed, layer_norm, linear, mrope_cos_sin, rms_norm,
    rope_cos_sin,
)
from repro.models.params import moe_is_ep
from repro.models.sharding import ShardCtx, batch_shard, shard

MOE_AUX_COEF = 0.01


def _cdt(cfg: ModelConfig):
    return {"bfloat16": jnp.bfloat16, "float32": jnp.float32}[cfg.compute_dtype]


def _kv_dt(cfg: ModelConfig):
    """KV-cache storage dtype (fp8 halves cache traffic; math stays f32)."""
    if cfg.kv_cache_dtype == "float8_e4m3fn":
        return jnp.float8_e4m3fn
    return _cdt(cfg)


def _norm(cfg: ModelConfig, p: dict, x: jax.Array) -> jax.Array:
    if cfg.norm == "layernorm":
        return layer_norm(x, p["scale"], p["bias"], cfg.norm_eps)
    return rms_norm(x, p["scale"], cfg.norm_eps)


def _qkv(cfg: ModelConfig, p: dict, x: jax.Array):
    B, S, _ = x.shape
    H, K, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = linear(p["wq"], x, cfg.quant).reshape(B, S, H, dh)
    k = linear(p["wk"], x, cfg.quant).reshape(B, S, K, dh)
    v = linear(p["wv"], x, cfg.quant).reshape(B, S, K, dh)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    return q, k, v


def _attn_full(cfg: ModelConfig, p: dict, x: jax.Array, cos, sin, ctx,
               *, causal: bool = True, window: int | None = None,
               kv_override: tuple | None = None):
    """Full-sequence attention (train/prefill). Returns (out, (k, v))."""
    B, S, _ = x.shape
    H, dh = cfg.n_heads, cfg.head_dim
    q, k, v = _qkv(cfg, p, x)
    if cos is not None:
        q = apply_rope(q, cos, sin)
        k = apply_rope(k, cos, sin)
    if kv_override is not None:          # cross attention
        k, v = kv_override
    if ctx is not None:
        q = shard(q, ctx, P(ctx.batch_axes, None, "model", None))
        k = shard(k, ctx, P(ctx.batch_axes, None, None, None))
        v = shard(v, ctx, P(ctx.batch_axes, None, None, None))
    o = ATT.blockwise_attention(q, k, v, causal=causal, window=window,
                                block_k=cfg.attn_block_k)
    out = linear(p["wo"], o.reshape(B, S, H * dh), cfg.quant)
    return out, (k, v)


def _cross_kv(cfg: ModelConfig, p: dict, enc_out: jax.Array):
    B, Se, _ = enc_out.shape
    K, dh = cfg.n_kv_heads, cfg.head_dim
    k = linear(p["wk"], enc_out, cfg.quant).reshape(B, Se, K, dh)
    v = linear(p["wv"], enc_out, cfg.quant).reshape(B, Se, K, dh)
    return k, v


def _mlp(cfg: ModelConfig, p: dict, x: jax.Array) -> jax.Array:
    if cfg.act == "swiglu" and "w_gate" in p:
        h = jax.nn.silu(linear(p["w_gate"], x, cfg.quant)) \
            * linear(p["w_up"], x, cfg.quant)
        return linear(p["w_down"], h, cfg.quant)
    h = jax.nn.gelu(linear(p["w_in"], x, cfg.quant))
    return linear(p["w_out"], h, cfg.quant)


# ---------------------------------------------------------------------------
# Train/prefill blocks (return (x, aux, cache_entry))
# ---------------------------------------------------------------------------
def _block_dense(cfg, lp, x, cos, sin, ctx):
    a, kv = _attn_full(cfg, lp["attn"], _norm(cfg, lp["ln1"], x), cos, sin, ctx,
                       window=cfg.swa_window)
    x = x + a
    h = _norm(cfg, lp["ln2"], x)
    aux = jnp.zeros((), jnp.float32)
    if cfg.moe is not None:
        ep = moe_is_ep(cfg, 16)
        y, aux = MOE.moe_ffn(lp["moe"], h, n_experts=cfg.moe.n_experts,
                             top_k=cfg.moe.top_k,
                             capacity_factor=cfg.moe.capacity_factor,
                             quant=cfg.quant, ctx=ctx, ep=ep,
                             moe_fsdp=cfg.moe_fsdp)
        if cfg.moe.dense_residual:
            y = y + _mlp(cfg, lp["mlp"], h)
        x = x + y
    else:
        x = x + _mlp(cfg, lp["mlp"], h)
    return x, aux, kv


def _block_hybrid(cfg, lp, x, cos, sin, ctx, mamba_state=None):
    h = _norm(cfg, lp["ln1"], x)
    a, kv = _attn_full(cfg, lp["attn"], h, cos, sin, ctx, window=cfg.swa_window)
    m, mstate = SSM.mamba_forward(lp["mamba"], h, mamba_state)
    mix = 0.5 * (_norm(cfg, lp["attn_out_norm"], a)
                 + _norm(cfg, lp["mamba_out_norm"], m))
    x = x + mix
    x = x + _mlp(cfg, lp["mlp"], _norm(cfg, lp["ln2"], x))
    return x, jnp.zeros((), jnp.float32), (kv, mstate)


def _block_rwkv(cfg, lp, x, state=None):
    h = _norm(cfg, lp["ln1"], x)
    tm_out, last_tm, wkv = SSM.rwkv6_timemix(lp["tm"], h, cfg.n_heads, state)
    x = x + tm_out
    h2 = _norm(cfg, lp["ln2"], x)
    cm_out, last_cm = SSM.rwkv6_channelmix(lp["cm"], h2, state)
    x = x + cm_out
    return x, jnp.zeros((), jnp.float32), (last_tm, last_cm, wkv)


def _block_enc(cfg, lp, x, ctx):
    a, _ = _attn_full(cfg, lp["attn"], _norm(cfg, lp["ln1"], x), None, None,
                      ctx, causal=False)
    x = x + a
    x = x + _mlp(cfg, lp["mlp"], _norm(cfg, lp["ln2"], x))
    return x


def _block_dec_xattn(cfg, lp, x, enc_out, cos, sin, ctx):
    a, kv = _attn_full(cfg, lp["attn"], _norm(cfg, lp["ln1"], x), cos, sin, ctx)
    x = x + a
    xk, xv = _cross_kv(cfg, lp["xattn"], enc_out)
    hq = _norm(cfg, lp["ln_x"], x)
    B, S, _ = hq.shape
    H, dh = cfg.n_heads, cfg.head_dim
    q = linear(lp["xattn"]["wq"], hq, cfg.quant).reshape(B, S, H, dh)
    o = ATT.blockwise_attention(q, xk, xv, causal=False,
                                block_k=cfg.attn_block_k)
    x = x + linear(lp["xattn"]["wo"], o.reshape(B, S, H * dh), cfg.quant)
    x = x + _mlp(cfg, lp["mlp"], _norm(cfg, lp["ln2"], x))
    return x, jnp.zeros((), jnp.float32), (kv, (xk, xv))


def apply_block(cfg: ModelConfig, lp: dict, x: jax.Array, *,
                cos=None, sin=None, ctx: ShardCtx | None = None,
                enc_out: jax.Array | None = None):
    """One full-sequence layer application (the scanned body), standalone.

    Used by roofline/component_costing.py to compile a single layer
    loop-free (XLA's cost analysis counts while-loop bodies once, so
    per-layer costs must be measured outside the scan)."""
    if cfg.family == "ssm" and cfg.ssm is not None and cfg.ssm.kind == "rwkv6":
        return _block_rwkv(cfg, lp, x)
    if cfg.family == "hybrid":
        return _block_hybrid(cfg, lp, x, cos, sin, ctx)
    if cfg.enc_layers and enc_out is not None:
        return _block_dec_xattn(cfg, lp, x, enc_out, cos, sin, ctx)
    return _block_dense(cfg, lp, x, cos, sin, ctx)


def apply_block_decode(cfg: ModelConfig, lp: dict, cl: dict, x: jax.Array,
                       pos, cos, sin, mask, slot,
                       ctx: ShardCtx | None = None):
    """One decode-step layer application (the scanned body), standalone."""
    B = x.shape[0]
    kind = ("rwkv" if (cfg.family == "ssm" and cfg.ssm is not None
                       and cfg.ssm.kind == "rwkv6")
            else "hybrid" if cfg.family == "hybrid"
            else "encdec" if cfg.enc_layers else "attn")
    ncl = dict(cl)
    if kind == "rwkv":
        st = SSM.RWKVState(cl["shift_tm"], cl["shift_cm"], cl["wkv"])
        x, _, (ltm, lcm, wkv) = _block_rwkv(cfg, lp, x, state=st)
        ncl["shift_tm"], ncl["shift_cm"], ncl["wkv"] = ltm, lcm, wkv
        return x, ncl
    h = _norm(cfg, lp["ln1"], x)
    a, nk, nv = _attn_decode(cfg, lp["attn"], h, cl["k"], cl["v"],
                             pos, cos, sin, mask, slot)
    ncl["k"], ncl["v"] = nk, nv
    if kind == "hybrid":
        m, mstate = SSM.mamba_decode(
            lp["mamba"], h, SSM.MambaState(cl["mamba_h"], cl["mamba_conv"]))
        ncl["mamba_h"], ncl["mamba_conv"] = mstate.h, mstate.conv
        a = 0.5 * (_norm(cfg, lp["attn_out_norm"], a)
                   + _norm(cfg, lp["mamba_out_norm"], m))
    x = x + a
    if kind == "encdec":
        hq = _norm(cfg, lp["ln_x"], x)
        H, dh = cfg.n_heads, cfg.head_dim
        q = linear(lp["xattn"]["wq"], hq, cfg.quant).reshape(B, 1, H, dh)
        xo = ATT.decode_attention(q, cl["xk"], cl["xv"],
                                  jnp.ones((cl["xk"].shape[1],), bool))
        x = x + linear(lp["xattn"]["wo"], xo.reshape(B, 1, H * dh), cfg.quant)
    h2 = _norm(cfg, lp["ln2"], x)
    if cfg.moe is not None:
        y, _ = MOE.moe_ffn(lp["moe"], h2, n_experts=cfg.moe.n_experts,
                           top_k=cfg.moe.top_k,
                           capacity_factor=cfg.moe.capacity_factor,
                           quant=cfg.quant, ctx=ctx, ep=moe_is_ep(cfg, 16),
                           moe_fsdp=cfg.moe_fsdp)
        if cfg.moe.dense_residual:
            y = y + _mlp(cfg, lp["mlp"], h2)
        x = x + y
    else:
        x = x + _mlp(cfg, lp["mlp"], h2)
    return x, ncl


# ---------------------------------------------------------------------------
# Stacks
# ---------------------------------------------------------------------------
def _scan_stack(cfg, layer_params, x, body, ctx, collect_cache: bool):
    """Scan `body(x, lp) -> (x, aux, cache_entry)` over stacked layers."""

    def f(carry, lp):
        xx, aux = carry
        xx = batch_shard(xx, ctx, None, None) if (
            ctx is not None and xx.shape[0] % ctx.data_size == 0) else xx
        xx, aux_l, cache_entry = body(xx, lp)
        return (xx, aux + aux_l), (cache_entry if collect_cache else None)

    if cfg.remat:
        f = jax.checkpoint(f)
    (x, aux), caches = jax.lax.scan(f, (x, jnp.zeros((), jnp.float32)),
                                    layer_params)
    return x, aux, caches


def _rope_for(cfg: ModelConfig, batch: dict, S: int, B: int):
    if cfg.rope == "none":
        return None, None
    if cfg.rope == "mrope":
        pos = batch.get("positions")
        if pos is None:
            pos = jnp.broadcast_to(jnp.arange(S)[None, None, :], (B, 3, S))
        return mrope_cos_sin(pos, cfg.head_dim, cfg.rope_theta,
                             cfg.mrope_sections)
    pos = batch.get("positions")
    if pos is None:
        pos = jnp.arange(S)[None, :]
    return rope_cos_sin(pos, cfg.head_dim, cfg.rope_theta)


def forward(cfg: ModelConfig, params: dict, batch: dict,
            ctx: ShardCtx | None = None, *, collect_cache: bool = False):
    """Full-sequence forward. Returns (hidden (B,S,D), aux, caches|None)."""
    comp = _cdt(cfg)
    tokens = batch["tokens"]
    B, S = tokens.shape
    x = embed(params["embed"]["tokens"], tokens, comp)
    if cfg.frontend == "vision" and "vision_embeds" in batch:
        ve = batch["vision_embeds"].astype(comp)
        x = jax.lax.dynamic_update_slice(x, ve, (0, 0, 0))
    x = batch_shard(x, ctx, None, None) if (
        ctx is not None and B % ctx.data_size == 0) else x
    cos, sin = _rope_for(cfg, batch, S, B)

    enc_out = None
    if cfg.enc_layers:
        enc = batch["enc_frames"].astype(comp) + params["enc_pos"][None].astype(comp)

        def enc_body(xx, lp):
            return _block_enc(cfg, lp, xx, ctx), jnp.zeros((), jnp.float32), None

        enc_out, _, _ = _scan_stack(cfg, params["enc_layers"], enc, enc_body,
                                    ctx, collect_cache=False)
        enc_out = _norm(cfg, params["enc_final_norm"], enc_out)
        x = x + jax.lax.dynamic_slice_in_dim(
            params["dec_pos"], 0, S, axis=0)[None].astype(comp)

    if cfg.family == "ssm" and cfg.ssm.kind == "rwkv6":
        body = lambda xx, lp: _block_rwkv(cfg, lp, xx)
    elif cfg.family == "hybrid":
        body = lambda xx, lp: _block_hybrid(cfg, lp, xx, cos, sin, ctx)
    elif cfg.enc_layers:
        body = lambda xx, lp: _block_dec_xattn(cfg, lp, xx, enc_out, cos, sin, ctx)
    else:
        body = lambda xx, lp: _block_dense(cfg, lp, xx, cos, sin, ctx)

    x, aux, caches = _scan_stack(cfg, params["layers"], x, body, ctx,
                                 collect_cache)
    x = _norm(cfg, params["final_norm"], x)
    return x, aux, caches


def logits_from_hidden(cfg: ModelConfig, params: dict, x: jax.Array) -> jax.Array:
    if cfg.tie_embeddings:
        return x.astype(jnp.float32) @ params["embed"]["tokens"].astype(jnp.float32).T
    return linear(params["lm_head"], x.astype(jnp.float32), "dense")


def chunked_ce_loss(cfg: ModelConfig, params: dict, x: jax.Array,
                    labels: jax.Array, n_chunks: int = 8):
    """Cross-entropy without materializing (B, S, V) logits.

    Scans over S chunks; each chunk computes logits, logZ, and the label
    log-prob.  Returns (sum_nll, n_valid_tokens)."""
    B, S, D = x.shape
    n_chunks = max(1, min(n_chunks, S))
    while S % n_chunks:
        n_chunks -= 1
    Sc = S // n_chunks
    xs = x.reshape(B, n_chunks, Sc, D).transpose(1, 0, 2, 3)
    ls = labels.reshape(B, n_chunks, Sc).transpose(1, 0, 2)

    def body(acc, inp):
        xc, lc = inp
        logits = logits_from_hidden(cfg, params, xc)           # (B, Sc, V) f32
        logz = jax.scipy.special.logsumexp(logits, axis=-1)
        ll = jnp.take_along_axis(logits, jnp.maximum(lc, 0)[..., None],
                                 axis=-1)[..., 0]
        mask = (lc >= 0).astype(jnp.float32)
        nll = ((logz - ll) * mask).sum()
        return (acc[0] + nll, acc[1] + mask.sum()), None

    (nll, n_tok), _ = jax.lax.scan(
        body, (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32)),
        (xs, ls))
    return nll, n_tok


def loss_fn(cfg: ModelConfig, params: dict, batch: dict,
            ctx: ShardCtx | None = None):
    """Scalar LM loss + metrics (the train_step objective)."""
    x, aux, _ = forward(cfg, params, batch, ctx)
    nll, n_tok = chunked_ce_loss(cfg, params, x, batch["labels"])
    loss = nll / jnp.maximum(n_tok, 1.0) + MOE_AUX_COEF * aux
    return loss, {"loss": loss, "nll": nll, "tokens": n_tok, "moe_aux": aux}


# ---------------------------------------------------------------------------
# Decode: cache init + single step
# ---------------------------------------------------------------------------
class CacheSpec(NamedTuple):
    kind: str            # attn | hybrid | rwkv | encdec
    cache_len: int       # self-attn cache slots (window for SWA)


def cache_spec(cfg: ModelConfig, seq_len: int) -> CacheSpec:
    if cfg.family == "ssm" and cfg.ssm.kind == "rwkv6":
        return CacheSpec("rwkv", 0)
    eff = min(seq_len, cfg.swa_window) if cfg.swa_window else seq_len
    if cfg.family == "hybrid":
        return CacheSpec("hybrid", eff)
    if cfg.enc_layers:
        return CacheSpec("encdec", eff)
    return CacheSpec("attn", eff)


def init_cache(cfg: ModelConfig, batch_size: int, seq_len: int) -> dict:
    """Zero-filled cache sized for `seq_len` context."""
    spec = cache_spec(cfg, seq_len)
    L, B = cfg.n_layers, batch_size
    K, dh, D = cfg.n_kv_heads, cfg.head_dim, cfg.d_model
    comp = _cdt(cfg)
    kvdt = _kv_dt(cfg)
    c: dict = {}
    if spec.kind in ("attn", "hybrid", "encdec"):
        c["k"] = jnp.zeros((L, B, spec.cache_len, K, dh), kvdt)
        c["v"] = jnp.zeros((L, B, spec.cache_len, K, dh), kvdt)
    if spec.kind == "hybrid":
        di = cfg.ssm.expand * D
        c["mamba_h"] = jnp.zeros((L, B, di, cfg.ssm.state_size), jnp.float32)
        c["mamba_conv"] = jnp.zeros((L, B, cfg.ssm.conv_width - 1, di), comp)
    if spec.kind == "rwkv":
        c["shift_tm"] = jnp.zeros((L, B, D), comp)
        c["shift_cm"] = jnp.zeros((L, B, D), comp)
        c["wkv"] = jnp.zeros((L, B, cfg.n_heads, cfg.head_dim, cfg.head_dim),
                             jnp.float32)
    if spec.kind == "encdec":
        c["xk"] = jnp.zeros((L, B, cfg.enc_seq, K, dh), kvdt)
        c["xv"] = jnp.zeros((L, B, cfg.enc_seq, K, dh), kvdt)
    return c


def cache_partition_specs(cfg: ModelConfig, batch_size: int, seq_len: int,
                          data_size: int, model_size: int) -> dict:
    """PartitionSpecs matching init_cache's tree, divisibility-aware."""
    spec = cache_spec(cfg, seq_len)
    bax = ("data",) if batch_size % data_size == 0 and batch_size > 1 else None
    sax = "model" if spec.cache_len % model_size == 0 and spec.cache_len > 0 else None
    c: dict = {}
    if spec.kind in ("attn", "hybrid", "encdec"):
        c["k"] = P(None, bax, sax, None, None)
        c["v"] = P(None, bax, sax, None, None)
    if spec.kind == "hybrid":
        di = cfg.ssm.expand * cfg.d_model
        dax = "model" if di % model_size == 0 else None
        c["mamba_h"] = P(None, bax, dax, None)
        c["mamba_conv"] = P(None, bax, None, dax)
    if spec.kind == "rwkv":
        hax = "model" if cfg.n_heads % model_size == 0 else None
        c["shift_tm"] = P(None, bax, None)
        c["shift_cm"] = P(None, bax, None)
        c["wkv"] = P(None, bax, hax, None, None)
    if spec.kind == "encdec":
        c["xk"] = P(None, bax, None, None, None)
        c["xv"] = P(None, bax, None, None, None)
    return c


def _attn_decode(cfg, lp, x, cache_k, cache_v, pos, cos, sin, mask, slot):
    B = x.shape[0]
    H, K, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q, k, v = _qkv(cfg, lp, x)
    if cos is not None:
        q = apply_rope(q, cos, sin)
        k = apply_rope(k, cos, sin)
    cache_k = jax.lax.dynamic_update_slice(cache_k, k.astype(cache_k.dtype),
                                           (0, slot, 0, 0))
    cache_v = jax.lax.dynamic_update_slice(cache_v, v.astype(cache_v.dtype),
                                           (0, slot, 0, 0))
    o = ATT.decode_attention(q, cache_k, cache_v, mask)
    out = linear(lp["wo"], o.reshape(B, 1, H * dh), cfg.quant)
    return out, cache_k, cache_v


def decode_step(cfg: ModelConfig, params: dict, cache: dict, tokens: jax.Array,
                pos: jax.Array, ctx: ShardCtx | None = None,
                positions: jax.Array | None = None):
    """One decode step for the whole batch at absolute position `pos`.

    tokens: (B, 1) i32; pos: scalar i32.  Returns (logits (B,1,V), cache)."""
    comp = _cdt(cfg)
    B = tokens.shape[0]
    x = embed(params["embed"]["tokens"], tokens, comp)
    cspec = cache_spec(cfg, int(cache["k"].shape[2]) if "k" in cache else 0)
    Sc = cspec.cache_len

    if cfg.rope == "mrope":
        p3 = positions if positions is not None else \
            jnp.broadcast_to(pos[None, None, None] if jnp.ndim(pos) else
                             jnp.full((B, 3, 1), pos), (B, 3, 1))
        cos, sin = mrope_cos_sin(p3, cfg.head_dim, cfg.rope_theta,
                                 cfg.mrope_sections)
    elif cfg.rope == "std":
        p1 = jnp.full((1, 1), pos)
        cos, sin = rope_cos_sin(p1, cfg.head_dim, cfg.rope_theta)
    else:
        cos = sin = None

    rolling = cfg.swa_window is not None and Sc == cfg.swa_window
    if Sc:
        slot = jnp.mod(pos, Sc) if rolling else pos
        mask = ATT.rolling_mask(pos, Sc) if rolling else ATT.linear_mask(pos, Sc)
    else:
        slot = mask = None

    def body(xx, xs):
        lp, cl = xs
        return apply_block_decode(cfg, lp, cl, xx, pos, cos, sin, mask, slot,
                                  ctx)

    if cfg.enc_layers:
        x = x + jax.lax.dynamic_slice_in_dim(
            params["dec_pos"], pos, 1, axis=0)[None].astype(comp)
    x, new_cache = jax.lax.scan(body, x, (params["layers"], cache))
    x = _norm(cfg, params["final_norm"], x)
    logits = logits_from_hidden(cfg, params, x)
    return logits, new_cache


def prefill(cfg: ModelConfig, params: dict, batch: dict, cache_len: int,
            ctx: ShardCtx | None = None):
    """Full-context forward that also materializes the decode cache.

    Returns (hidden (B,S,D), cache dict ready for decode_step at pos=S).
    For SWA archs requires S % window == 0 (slot order == position order)."""
    x, aux, caches = forward(cfg, params, batch, ctx, collect_cache=True)
    B, S, _ = x.shape
    spec = cache_spec(cfg, cache_len)
    Sc = spec.cache_len
    c: dict = {}

    def fit(k):   # (L, B, S, K, dh) -> (L, B, Sc, K, dh)
        if Sc == S:
            return k
        if Sc < S:     # rolling window: keep the last Sc positions
            assert S % Sc == 0, "SWA prefill requires S % window == 0"
            return k[:, :, S - Sc:]
        pad = [(0, 0)] * k.ndim
        pad[2] = (0, Sc - S)
        return jnp.pad(k, pad)

    kvdt = _kv_dt(cfg)

    if spec.kind == "attn":
        k, v = caches
        c["k"], c["v"] = fit(k).astype(kvdt), fit(v).astype(kvdt)
    elif spec.kind == "hybrid":
        (k, v), mstate = caches
        c["k"], c["v"] = fit(k).astype(kvdt), fit(v).astype(kvdt)
        c["mamba_h"] = mstate.h
        c["mamba_conv"] = mstate.conv.astype(_cdt(cfg))
    elif spec.kind == "rwkv":
        ltm, lcm, wkv = caches
        c["shift_tm"], c["shift_cm"], c["wkv"] = ltm, lcm, wkv
    elif spec.kind == "encdec":
        (k, v), (xk, xv) = caches
        c["k"], c["v"] = fit(k).astype(kvdt), fit(v).astype(kvdt)
        c["xk"], c["xv"] = xk.astype(kvdt), xv.astype(kvdt)
    return x, c
