"""Model zoo: layers, attention, MoE, SSM, transformer assembly."""
