"""Sharding context threaded through model code.

Model functions never hard-code mesh axis names; they request logical
placements through a ShardCtx.  With ctx=None (CPU smoke tests) every
constraint is a no-op, so the same code runs unsharded.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


@dataclass(frozen=True)
class ShardCtx:
    mesh: Mesh

    @property
    def batch_axes(self) -> tuple[str, ...]:
        ax = tuple(self.mesh.axis_names)
        return ("pod", "data") if "pod" in ax else ("data",)

    @property
    def model_axis(self) -> str:
        return "model"

    @property
    def fsdp_axis(self) -> str:
        return "data"

    @property
    def model_size(self) -> int:
        return self.mesh.shape["model"]

    @property
    def data_size(self) -> int:
        n = self.mesh.shape["data"]
        if "pod" in self.mesh.axis_names:
            n *= self.mesh.shape["pod"]
        return n

    def spec(self, *axes) -> P:
        return P(*axes)

    def batch_spec(self, *rest) -> P:
        return P(self.batch_axes, *rest)

    def sharding(self, spec: P) -> NamedSharding:
        return NamedSharding(self.mesh, spec)


def shard(x: jax.Array, ctx: ShardCtx | None, spec) -> jax.Array:
    """with_sharding_constraint when a ctx is present, else identity."""
    if ctx is None:
        return x
    return jax.lax.with_sharding_constraint(x, NamedSharding(ctx.mesh, spec))


def batch_shard(x: jax.Array, ctx: ShardCtx | None, *rest) -> jax.Array:
    """Shard leading (batch) dim over (pod?, data); rest as given."""
    if ctx is None:
        return x
    return shard(x, ctx, P(ctx.batch_axes, *rest))
