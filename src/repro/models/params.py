"""Parameter definitions: shapes, dtypes, initializers, partition specs.

Each architecture's parameter tree is declared once as a tree of ParamDef;
from it we derive (a) real initialized params (smoke tests / real training),
(b) ShapeDtypeStruct trees for AOT lowering (dry-run: no allocation), and
(c) the PartitionSpec tree consumed by pjit in_shardings.

Sharding scheme (DESIGN.md §6): TP ("model") on attention heads / FFN hidden
/ vocab; FSDP ("data") on the other matrix dim of every large projection;
experts on "model" when divisible (EP) else TP inside experts.  Layer-stacked
params carry a leading L dim with spec None (scanned).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig

FSDP = "data"
TP = "model"


@dataclass(frozen=True)
class ParamDef:
    shape: tuple[int, ...]
    dtype: Any
    spec: P
    init: str = "normal"       # normal | zeros | ones | small_normal
    init_scale: float | None = None


def _dt(name: str):
    return {"bfloat16": jnp.bfloat16, "float32": jnp.float32}[name]


def _lin(cfg: ModelConfig, K: int, N: int, spec: P, *, L: int | None = None,
         bias: bool = False, quant: str | None = None) -> dict:
    """Linear param defs honoring the quant mode (packed stores 2-bit codes)."""
    quant = cfg.quant if quant is None else quant
    dt = _dt(cfg.param_dtype)
    lead = () if L is None else (L,)
    lead_spec = () if L is None else (None,)
    d: dict = {}
    if quant == "ternary_packed":
        assert K % 4 == 0, f"K={K} not packable"
        d["w2"] = ParamDef(lead + (K // 4, N), jnp.int8, P(*lead_spec, *spec), "zeros")
        d["scale"] = ParamDef(lead + (1, N), jnp.float32,
                              P(*lead_spec, None, spec[-1]), "ones")
    else:
        d["w"] = ParamDef(lead + (K, N), dt, P(*lead_spec, *spec),
                          "normal", 1.0 / np.sqrt(K))
    if bias:
        d["b"] = ParamDef(lead + (N,), dt, P(*lead_spec, spec[-1]), "zeros")
    return d


def _vec(shape, spec, dtype, init="ones") -> ParamDef:
    return ParamDef(tuple(shape), dtype, spec, init)


# ---------------------------------------------------------------------------
# Per-family layer stacks
# ---------------------------------------------------------------------------
def _attn_defs(cfg: ModelConfig, L: int) -> dict:
    D, H, K, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    dt = _dt(cfg.param_dtype)
    kv_spec = P(FSDP, None) if cfg.replicate_kv else P(FSDP, TP)
    d = {
        "wq": _lin(cfg, D, H * dh, P(FSDP, TP), L=L, bias=cfg.qkv_bias),
        "wk": _lin(cfg, D, K * dh, kv_spec, L=L, bias=cfg.qkv_bias),
        "wv": _lin(cfg, D, K * dh, kv_spec, L=L, bias=cfg.qkv_bias),
        "wo": _lin(cfg, H * dh, D, P(TP, FSDP), L=L),
    }
    if cfg.qk_norm:
        d["q_norm"] = _vec((L, dh), P(None, None), dt)
        d["k_norm"] = _vec((L, dh), P(None, None), dt)
    return d


def _mlp_defs(cfg: ModelConfig, L: int, d_ff: int) -> dict:
    D = cfg.d_model
    if cfg.act == "swiglu":
        return {
            "w_gate": _lin(cfg, D, d_ff, P(FSDP, TP), L=L),
            "w_up": _lin(cfg, D, d_ff, P(FSDP, TP), L=L),
            "w_down": _lin(cfg, d_ff, D, P(TP, FSDP), L=L),
        }
    return {   # gelu MLP (whisper)
        "w_in": _lin(cfg, D, d_ff, P(FSDP, TP), L=L, bias=True),
        "w_out": _lin(cfg, d_ff, D, P(TP, FSDP), L=L, bias=True),
    }


def _moe_defs(cfg: ModelConfig, L: int, ep: bool) -> dict:
    D, F, E = cfg.d_model, cfg.d_ff, cfg.moe.n_experts
    dt = _dt(cfg.param_dtype)
    # expert weight sharding: E on "model" when divisible (EP) else the FFN
    # hidden F on "model" (TP).  cfg.moe_fsdp picks which remaining dim (if
    # any) additionally shards over "data" — a §Perf knob: "d" trades
    # weight-gather collectives for memory, "f"/"none" the reverse.
    if ep:
        if cfg.moe_fsdp == "d":
            espec_in, espec_out = P(TP, FSDP, None), P(TP, None, FSDP)
        elif cfg.moe_fsdp == "f":
            espec_in, espec_out = P(TP, None, FSDP), P(TP, FSDP, None)
        else:
            espec_in, espec_out = P(TP, None, None), P(TP, None, None)
    else:
        if cfg.moe_fsdp == "d":
            espec_in, espec_out = P(None, FSDP, TP), P(None, TP, FSDP)
        else:
            espec_in, espec_out = P(None, None, TP), P(None, TP, None)
    return {
        "router": {"w": ParamDef((L, D, E), jnp.float32, P(None, None, None),
                                 "normal", 0.02)},
        "experts": {
            "w_gate": ParamDef((L, E, D, F), dt, P(None, *espec_in),
                               "normal", 1.0 / np.sqrt(D)),
            "w_up": ParamDef((L, E, D, F), dt, P(None, *espec_in),
                             "normal", 1.0 / np.sqrt(D)),
            "w_down": ParamDef((L, E, F, D), dt, P(None, *espec_out),
                               "normal", 1.0 / np.sqrt(F)),
        },
    }


def _mamba_defs(cfg: ModelConfig, L: int) -> dict:
    D = cfg.d_model
    di = cfg.ssm.expand * D
    N = cfg.ssm.state_size
    W = cfg.ssm.conv_width
    dt = _dt(cfg.param_dtype)
    return {
        "in_proj": _lin(cfg, D, 2 * di, P(FSDP, TP), L=L),
        "conv_w": ParamDef((L, W, di), dt, P(None, None, TP), "normal", 0.2),
        "conv_b": ParamDef((L, di), dt, P(None, TP), "zeros"),
        "w_dt": ParamDef((L, di, di), dt, P(None, None, TP), "normal",
                         1.0 / np.sqrt(di)),
        "dt_bias": ParamDef((L, di), dt, P(None, TP), "zeros"),
        "w_B": ParamDef((L, di, N), dt, P(None, TP, None), "normal",
                        1.0 / np.sqrt(di)),
        "w_C": ParamDef((L, di, N), dt, P(None, TP, None), "normal",
                        1.0 / np.sqrt(di)),
        "A_log": ParamDef((L, di, N), jnp.float32, P(None, TP, None), "zeros"),
        "d_skip": ParamDef((L, di), jnp.float32, P(None, TP), "ones"),
        "out_proj": _lin(cfg, di, D, P(TP, FSDP), L=L),
    }


def _rwkv_defs(cfg: ModelConfig, L: int) -> dict:
    D, F = cfg.d_model, cfg.d_ff
    r = cfg.ssm.lora_rank
    dt = _dt(cfg.param_dtype)
    tm = {
        "lora_A": ParamDef((L, D, r), dt, P(None, None, None), "normal",
                           1.0 / np.sqrt(D)),
        "w0": ParamDef((L, D), jnp.float32, P(None, TP), "zeros"),
        "wA": ParamDef((L, D, r), dt, P(None, None, None), "normal",
                       1.0 / np.sqrt(D)),
        "wB": ParamDef((L, r, D), dt, P(None, None, TP), "normal",
                       1.0 / np.sqrt(r)),
        "u": ParamDef((L, D), jnp.float32, P(None, TP), "zeros"),
        "gn_scale": ParamDef((L, D), dt, P(None, TP), "ones"),
        "w_r": _lin(cfg, D, D, P(FSDP, TP), L=L),
        "w_k": _lin(cfg, D, D, P(FSDP, TP), L=L),
        "w_v": _lin(cfg, D, D, P(FSDP, TP), L=L),
        "w_g": _lin(cfg, D, D, P(FSDP, TP), L=L),
        "w_o": _lin(cfg, D, D, P(TP, FSDP), L=L),
    }
    for n in ("r", "k", "v", "w", "g"):
        tm[f"mu_{n}"] = ParamDef((L, D), dt, P(None, None), "zeros")
        tm[f"lora_B_{n}"] = ParamDef((L, r, D), dt, P(None, None, None),
                                     "normal", 1.0 / np.sqrt(r))
    cm = {
        "mu_k": ParamDef((L, D), dt, P(None, None), "zeros"),
        "mu_r": ParamDef((L, D), dt, P(None, None), "zeros"),
        "w_in": _lin(cfg, D, F, P(FSDP, TP), L=L),
        "w_recv": _lin(cfg, D, D, P(FSDP, None), L=L),
        "w_out": _lin(cfg, F, D, P(TP, FSDP), L=L),
    }
    return {"tm": tm, "cm": cm}


def _norm_def(cfg: ModelConfig, L: int | None, name: str) -> dict:
    dt = _dt(cfg.param_dtype)
    shape = (cfg.d_model,) if L is None else (L, cfg.d_model)
    spec = P(None) if L is None else P(None, None)
    d = {"scale": ParamDef(shape, dt, spec, "ones")}
    if cfg.norm == "layernorm":
        d["bias"] = ParamDef(shape, dt, spec, "zeros")
    return d


def moe_is_ep(cfg: ModelConfig, model_axis_size: int) -> bool:
    return (cfg.moe is not None
            and cfg.moe.n_experts % max(model_axis_size, 1) == 0)


def param_defs(cfg: ModelConfig, model_axis_size: int = 16) -> dict:
    """Full parameter tree of ParamDef for one architecture."""
    L, D, V = cfg.n_layers, cfg.d_model, cfg.vocab
    dt = _dt(cfg.param_dtype)
    # vocab TP only when divisible (whisper 51865 / hymba 32001 stay
    # replicated on the model axis; they are small)
    vtp = TP if V % max(model_axis_size, 1) == 0 else None
    tree: dict = {
        "embed": {"tokens": ParamDef((V, D), dt, P(vtp, None), "normal", 0.02)},
        "final_norm": _norm_def(cfg, None, "final"),
    }
    if not cfg.tie_embeddings:
        tree["lm_head"] = {"w": ParamDef((D, V), dt, P(FSDP, vtp), "normal",
                                         1.0 / np.sqrt(D))}

    layer: dict = {"ln1": _norm_def(cfg, L, "ln1"), "ln2": _norm_def(cfg, L, "ln2")}
    if cfg.family == "ssm" and cfg.ssm.kind == "rwkv6":
        layer.update(_rwkv_defs(cfg, L))
    else:
        layer["attn"] = _attn_defs(cfg, L)
        if cfg.family == "hybrid":
            layer["mamba"] = _mamba_defs(cfg, L)
            layer["attn_out_norm"] = _norm_def(cfg, L, "aon")
            layer["mamba_out_norm"] = _norm_def(cfg, L, "mon")
        if cfg.moe is not None:
            layer["moe"] = _moe_defs(cfg, L, moe_is_ep(cfg, model_axis_size))
            if cfg.moe.dense_residual:
                layer["mlp"] = _mlp_defs(cfg, L, cfg.moe.d_ff_dense or cfg.d_ff)
        else:
            layer["mlp"] = _mlp_defs(cfg, L, cfg.d_ff)
    tree["layers"] = layer

    if cfg.enc_layers:    # whisper encoder stack + positional tables
        Le = cfg.enc_layers
        enc = {
            "ln1": _norm_def(cfg, Le, "eln1"),
            "ln2": _norm_def(cfg, Le, "eln2"),
            "attn": _attn_defs(cfg, Le),
            "mlp": _mlp_defs(cfg, Le, cfg.d_ff),
        }
        tree["enc_layers"] = enc
        tree["enc_pos"] = ParamDef((cfg.enc_seq, D), dt, P(None, None),
                                   "normal", 0.02)
        tree["dec_pos"] = ParamDef((32768, D), dt, P(None, None), "normal", 0.02)
        tree["enc_final_norm"] = _norm_def(cfg, None, "efn")
        # decoder cross-attention
        tree["layers"]["xattn"] = _attn_defs(cfg, L)
        tree["layers"]["ln_x"] = _norm_def(cfg, L, "lnx")
    return tree


# ---------------------------------------------------------------------------
# Materialization
# ---------------------------------------------------------------------------
def is_def(x) -> bool:
    return isinstance(x, ParamDef)


def init_params(rng: jax.Array, cfg: ModelConfig, model_axis_size: int = 16):
    defs = param_defs(cfg, model_axis_size)
    leaves, treedef = jax.tree.flatten(defs, is_leaf=is_def)
    keys = jax.random.split(rng, len(leaves))

    def mk(d: ParamDef, key):
        if d.init == "zeros":
            return jnp.zeros(d.shape, d.dtype)
        if d.init == "ones":
            return jnp.ones(d.shape, d.dtype)
        scale = d.init_scale if d.init_scale is not None else 0.02
        return (jax.random.normal(key, d.shape, jnp.float32) * scale).astype(d.dtype)

    return jax.tree.unflatten(treedef, [mk(d, k) for d, k in zip(leaves, keys)])


def abstract_params(cfg: ModelConfig, model_axis_size: int = 16):
    """ShapeDtypeStruct tree — dry-run stand-in, zero allocation."""
    defs = param_defs(cfg, model_axis_size)
    return jax.tree.map(lambda d: jax.ShapeDtypeStruct(d.shape, d.dtype),
                        defs, is_leaf=is_def)


def partition_specs(cfg: ModelConfig, model_axis_size: int = 16):
    defs = param_defs(cfg, model_axis_size)
    return jax.tree.map(lambda d: d.spec, defs, is_leaf=is_def)


def strip_fsdp_tree(spec_tree):
    """Drop the FSDP ("data") axis from every PartitionSpec — used for
    TP-only serving layouts (cfg.serve_fsdp=False): weights stay resident
    per device instead of being re-gathered every decode step."""
    def fix(p: P) -> P:
        out = []
        for ax in tuple(p):
            if ax == FSDP:
                out.append(None)
            elif isinstance(ax, tuple):
                kept = tuple(a for a in ax if a != FSDP)
                out.append(kept if kept else None)
            else:
                out.append(ax)
        return P(*out)
    return jax.tree.map(fix, spec_tree, is_leaf=lambda s: isinstance(s, P))


def param_count(cfg: ModelConfig, model_axis_size: int = 16) -> int:
    defs = param_defs(cfg, model_axis_size)
    return sum(int(np.prod(d.shape)) for d in
               jax.tree.leaves(defs, is_leaf=is_def))


def active_param_count(cfg: ModelConfig) -> int:
    """Active params per token (MoE: top_k of E experts) for 6*N*D."""
    total = param_count(cfg)
    if cfg.moe is None:
        return total
    defs = param_defs(cfg)
    expert_total = sum(int(np.prod(d.shape)) for d in
                       jax.tree.leaves(defs["layers"]["moe"]["experts"],
                                       is_leaf=is_def))
    active_frac = cfg.moe.top_k / cfg.moe.n_experts
    return int(total - expert_total * (1.0 - active_frac))
