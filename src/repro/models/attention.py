"""Attention: blockwise online-softmax (train/prefill) + cached decode.

Supports GQA natively (queries grouped per KV head — KV tensors are never
materialized at H heads), sliding-window (SWA) masking, per-head qk-norm
(qwen3), and QKV bias (qwen2).  The blockwise implementation scans over KV
blocks with running (max, sum) statistics — memory O(Sq * block) instead of
O(S^2), which is what lets the 32k-prefill and 4k x 256-batch train cells
fit 16 GB/chip at dry-run time.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def blockwise_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                        *, causal: bool = True, window: int | None = None,
                        q_offset: int = 0, block_k: int = 1024) -> jax.Array:
    """Online-softmax attention with grouped queries.

    q: (B, Sq, H, h); k, v: (B, Sk, K, h) with H % K == 0.
    q_offset: absolute position of q[0] relative to k[0] (self-attention
    chunks); ignored for cross attention (causal=False, window=None).
    Returns (B, Sq, H, h).
    """
    b, sq, hh, dh = q.shape
    sk, kk = k.shape[1], k.shape[2]
    g = hh // kk
    scale = dh ** -0.5
    nb = max(1, (sk + block_k - 1) // block_k)
    pad = nb * block_k - sk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    kb = k.reshape(b, nb, block_k, kk, dh).transpose(1, 0, 2, 3, 4)
    vb = v.reshape(b, nb, block_k, kk, dh).transpose(1, 0, 2, 3, 4)

    qg = (q.astype(jnp.float32) * scale).reshape(b, sq, kk, g, dh)
    q_pos = q_offset + jnp.arange(sq)

    def body(carry, xs):
        acc, m_run, l_run = carry
        kblk, vblk, blk_idx = xs
        k_pos = blk_idx * block_k + jnp.arange(block_k)
        s = jnp.einsum("bqkgd,bskd->bkgqs", qg, kblk.astype(jnp.float32))
        mask = jnp.ones((sq, block_k), dtype=bool)
        if causal:
            mask &= q_pos[:, None] >= k_pos[None, :]
        if window is not None:
            mask &= q_pos[:, None] - k_pos[None, :] < window
        mask &= (k_pos < sk)[None, :]
        s = jnp.where(mask[None, None, None], s, NEG_INF)
        m_new = jnp.maximum(m_run, s.max(axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m_run - m_new)
        l_new = l_run * corr + p.sum(axis=-1)
        acc = acc * corr[..., None] + jnp.einsum(
            "bkgqs,bskd->bkgqd", p, vblk.astype(jnp.float32))
        return (acc, m_new, l_new), None

    acc0 = jnp.zeros((b, kk, g, sq, dh), jnp.float32)
    m0 = jnp.full((b, kk, g, sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, kk, g, sq), jnp.float32)
    (acc, _, l_run), _ = jax.lax.scan(
        body, (acc0, m0, l0), (kb, vb, jnp.arange(nb)))
    out = acc / jnp.maximum(l_run[..., None], 1e-30)
    # (B, K, G, Sq, h) -> (B, Sq, H, h)
    return out.transpose(0, 3, 1, 2, 4).reshape(b, sq, hh, dh).astype(q.dtype)


def decode_attention(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                     mask: jax.Array) -> jax.Array:
    """Single-step attention over a KV cache, GQA-native.

    q: (B, 1, H, h); caches: (B, Sc, K, h); mask: (Sc,) or (B, Sc) bool —
    True = slot attendable (validity/causality/window already folded in).
    """
    b, sc, kk, dh = k_cache.shape
    hh = q.shape[2]
    g = hh // kk
    scale = dh ** -0.5
    qg = (q.astype(jnp.float32) * scale).reshape(b, kk, g, dh)
    s = jnp.einsum("bkgd,bskd->bkgs", qg, k_cache.astype(jnp.float32))
    m = mask if mask.ndim == 2 else mask[None, :]
    s = jnp.where(m[:, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgs,bskd->bkgd", p, v_cache.astype(jnp.float32))
    return out.reshape(b, 1, hh, dh).astype(q.dtype)


def rolling_slot(pos: jax.Array, cache_size: int) -> jax.Array:
    """Write slot for a rolling (SWA) cache."""
    return jnp.mod(pos, cache_size)


def rolling_mask(pos: jax.Array, cache_size: int) -> jax.Array:
    """Validity mask (Sc,) for a rolling cache *after* writing `pos`.

    Slot s holds absolute position  p_s = pos - ((pos - s) mod Sc);
    valid iff p_s >= 0 (and p_s automatically within the window = Sc).
    """
    s = jnp.arange(cache_size)
    kp = pos - jnp.mod(pos - s, cache_size)
    return kp >= 0


def linear_mask(pos: jax.Array, cache_size: int) -> jax.Array:
    """Validity mask for an append-only cache after writing at index `pos`."""
    return jnp.arange(cache_size) <= pos
