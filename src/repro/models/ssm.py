"""State-space sequence mixers: Mamba (hymba's parallel head) and RWKV-6.

TPU adaptation notes (DESIGN.md §3): both recurrences are *diagonal* linear
state updates, so training/prefill uses `jax.lax.associative_scan` (Mamba)
or a length-S `lax.scan` (RWKV-6, whose per-step outer product k v^T makes
the associative form rank-growing; the sequential scan keeps the HLO small
and the state in registers/VMEM).  Decode is a single fused state update —
O(1) per token, which is what makes the long_500k cells runnable for the
ssm/hybrid architectures.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.models.layers import rms_norm


# ---------------------------------------------------------------------------
# Mamba (selective SSM, diagonal A) — hymba's parallel head
# ---------------------------------------------------------------------------
class MambaState(NamedTuple):
    h: jax.Array        # (B, d_inner, N)
    conv: jax.Array     # (B, conv_w - 1, d_inner) rolling window


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv. x: (B, S, C); w: (W, C); b: (C,)."""
    W = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (W - 1, 0), (0, 0)))
    # gather W shifted views — cheap, avoids conv lowering issues on CPU
    y = sum(xp[:, i:i + x.shape[1], :] * w[i] for i in range(W))
    return y + b


def mamba_forward(p: dict, x: jax.Array, state: MambaState | None = None
                  ) -> tuple[jax.Array, MambaState]:
    """Full-sequence selective scan. x: (B, S, D) -> (B, S, D).

    Params: in_proj (D, 2*di), conv_w (W, di), conv_b (di), x_dt (di, dt_rank->di)
    simplified: dt_proj (di,), W_dt (D_or_di ...) — see param builder.
    """
    B, S, D = x.shape
    xz = x @ p["in_proj"]["w"].astype(x.dtype)                 # (B, S, 2*di)
    xi, z = jnp.split(xz, 2, axis=-1)
    di = xi.shape[-1]
    xi_preconv = xi
    xi = _causal_conv(xi, p["conv_w"].astype(x.dtype), p["conv_b"].astype(x.dtype))
    xi = jax.nn.silu(xi)

    N = p["A_log"].shape[-1]
    A = -jnp.exp(p["A_log"].astype(jnp.float32))               # (di, N)
    dt = jax.nn.softplus(xi @ p["w_dt"].astype(x.dtype)
                         + p["dt_bias"].astype(x.dtype))       # (B, S, di)
    Bm = (xi @ p["w_B"].astype(x.dtype)).astype(jnp.float32)   # (B, S, N)
    Cm = (xi @ p["w_C"].astype(x.dtype)).astype(jnp.float32)   # (B, S, N)

    dtf = dt.astype(jnp.float32)
    Abar = jnp.exp(dtf[..., None] * A)                         # (B, S, di, N)
    Bu = (dtf * xi.astype(jnp.float32))[..., None] * Bm[:, :, None, :]

    def combine(e1, e2):
        a1, b1 = e1
        a2, b2 = e2
        return a1 * a2, a2 * b1 + b2

    if state is not None:
        Bu = Bu.at[:, 0].add(Abar[:, 0] * state.h)
    a_cum, h_all = jax.lax.associative_scan(combine, (Abar, Bu), axis=1)
    y = jnp.einsum("bsdn,bsn->bsd", h_all, Cm)                 # (B, S, di)
    y = y + xi.astype(jnp.float32) * p["d_skip"].astype(jnp.float32)
    y = y.astype(x.dtype) * jax.nn.silu(z)
    out = y @ p["out_proj"]["w"].astype(x.dtype)
    W = p["conv_w"].shape[0]
    new_state = MambaState(h=h_all[:, -1], conv=xi_preconv[:, S - (W - 1):, :])
    return out, new_state


def mamba_decode(p: dict, x: jax.Array, state: MambaState
                 ) -> tuple[jax.Array, MambaState]:
    """One-token step. x: (B, 1, D)."""
    B = x.shape[0]
    xz = x[:, 0] @ p["in_proj"]["w"].astype(x.dtype)
    xi, z = jnp.split(xz, 2, axis=-1)
    di = xi.shape[-1]
    W = p["conv_w"].shape[0]
    window = jnp.concatenate([state.conv, xi[:, None, :]], axis=1)  # (B, W, di)
    xi = (window * p["conv_w"].astype(x.dtype)[None]).sum(axis=1) \
        + p["conv_b"].astype(x.dtype)
    xi = jax.nn.silu(xi)

    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    dt = jax.nn.softplus(xi @ p["w_dt"].astype(x.dtype) + p["dt_bias"].astype(x.dtype))
    Bm = (xi @ p["w_B"].astype(x.dtype)).astype(jnp.float32)
    Cm = (xi @ p["w_C"].astype(x.dtype)).astype(jnp.float32)
    dtf = dt.astype(jnp.float32)
    Abar = jnp.exp(dtf[:, :, None] * A)                        # (B, di, N)
    h = Abar * state.h + (dtf * xi.astype(jnp.float32))[..., None] * Bm[:, None, :]
    y = jnp.einsum("bdn,bn->bd", h, Cm)
    y = y + xi.astype(jnp.float32) * p["d_skip"].astype(jnp.float32)
    y = y.astype(x.dtype) * jax.nn.silu(z)
    out = (y @ p["out_proj"]["w"].astype(x.dtype))[:, None, :]
    return out, MambaState(h=h, conv=window[:, 1:, :])


# ---------------------------------------------------------------------------
# RWKV-6 "Finch" — data-dependent decay linear attention
# ---------------------------------------------------------------------------
class RWKVState(NamedTuple):
    shift_tm: jax.Array   # (B, D) previous token (time-mix)
    shift_cm: jax.Array   # (B, D) previous token (channel-mix)
    wkv: jax.Array        # (B, H, dh, dh) f32 outer-product state


def _ddlerp(x, xx, mu, A, Bm):
    """Data-dependent lerp (v6): x + (xx-x) * (mu + tanh((x+(xx-x)*mu0)@A)@B).

    Simplified single-stream variant; A: (D, r), Bm: (r, D)."""
    d = xx - x
    lora = jnp.tanh((x + d * mu) @ A.astype(x.dtype)) @ Bm.astype(x.dtype)
    return x + d * (mu + lora)


def rwkv6_timemix(p: dict, x: jax.Array, n_heads: int,
                  state: RWKVState | None) -> tuple[jax.Array, jax.Array, jax.Array]:
    """x: (B, S, D) -> (out, last_x, new_wkv).  Sequential scan over S."""
    B, S, D = x.shape
    dh = D // n_heads
    prev = jnp.zeros((B, D), x.dtype) if state is None else state.shift_tm
    xx = jnp.concatenate([prev[:, None, :], x[:, :-1, :]], axis=1)

    def stream(name):
        return _ddlerp(x, xx, p[f"mu_{name}"].astype(x.dtype),
                       p["lora_A"], p[f"lora_B_{name}"])

    xr, xk, xv, xw, xg = (stream(n) for n in ("r", "k", "v", "w", "g"))
    r = (xr @ p["w_r"]["w"].astype(x.dtype)).reshape(B, S, n_heads, dh)
    k = (xk @ p["w_k"]["w"].astype(x.dtype)).reshape(B, S, n_heads, dh)
    v = (xv @ p["w_v"]["w"].astype(x.dtype)).reshape(B, S, n_heads, dh)
    g = jax.nn.silu(xg @ p["w_g"]["w"].astype(x.dtype))
    # data-dependent decay per channel, in (0, 1)
    wdec = p["w0"].astype(x.dtype) + jnp.tanh(xw @ p["wA"].astype(x.dtype)) \
        @ p["wB"].astype(x.dtype)
    wdec = jnp.exp(-jnp.exp(wdec.astype(jnp.float32))).reshape(B, S, n_heads, dh)
    u = p["u"].astype(jnp.float32).reshape(n_heads, dh)         # bonus

    s0 = (jnp.zeros((B, n_heads, dh, dh), jnp.float32)
          if state is None else state.wkv)

    def step(s, inp):
        rt, kt, vt, wt = inp                                    # (B, H, dh) f32
        kv = kt[..., :, None] * vt[..., None, :]                # (B, H, dh, dh)
        y = jnp.einsum("bhk,bhkv->bhv", rt, s + u[None, :, :, None] * kv)
        s = wt[..., :, None] * s + kv
        return s, y

    rs = r.transpose(1, 0, 2, 3).astype(jnp.float32)
    ks = k.transpose(1, 0, 2, 3).astype(jnp.float32)
    vs = v.transpose(1, 0, 2, 3).astype(jnp.float32)
    ws = wdec.transpose(1, 0, 2, 3).astype(jnp.float32)
    s_fin, ys = jax.lax.scan(step, s0, (rs, ks, vs, ws))
    y = ys.transpose(1, 0, 2, 3).reshape(B, S, D)               # (B, S, D)
    y = rms_norm(y.astype(x.dtype), p["gn_scale"], eps=1e-5)    # per-head groupnorm ~ rms
    out = (y * g) @ p["w_o"]["w"].astype(x.dtype)
    return out, x[:, -1, :], s_fin


def rwkv6_channelmix(p: dict, x: jax.Array, state: RWKVState | None
                     ) -> tuple[jax.Array, jax.Array]:
    B, S, D = x.shape
    prev = jnp.zeros((B, D), x.dtype) if state is None else state.shift_cm
    xx = jnp.concatenate([prev[:, None, :], x[:, :-1, :]], axis=1)
    xk = x + (xx - x) * p["mu_k"].astype(x.dtype)
    xr = x + (xx - x) * p["mu_r"].astype(x.dtype)
    k = jnp.square(jax.nn.relu(xk @ p["w_in"]["w"].astype(x.dtype)))
    y = jax.nn.sigmoid(xr @ p["w_recv"]["w"].astype(x.dtype)) \
        * (k @ p["w_out"]["w"].astype(x.dtype))
    return y, x[:, -1, :]
