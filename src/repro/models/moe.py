"""Mixture-of-Experts FFN with group-local (dropping) token dispatch.

Scale design (arctic-480b: 128 experts, 1M tokens/step):
  * The classic one-hot dispatch einsum materializes a (T, E, C) tensor —
    2.5e12 elements at that scale.  A global argsort-based dispatch avoids
    that but makes XLA run a *distributed sort* and replicate the scatter
    update tensor across shards (measured: 70 GiB/dev temp on mixtral).
  * So dispatch is GROUP-LOCAL: tokens reshape to (G, Tg, D) with G
    sharded over the data axis.  Position-in-expert comes from a per-group
    one-hot cumsum (O(Tg*k*E) int32), and the only scatter is vmapped over
    G — GSPMD partitions scatters cleanly along batch dims, so no
    replication.  Expert weights are shared across groups; with E sharded
    on "model" (EP) the (G-sharded -> E-sharded) buffer handoff lowers to
    the expected all-to-all family.
  * EP vs TP fallback: experts shard over "model" when E % model_size == 0
    (arctic 128e); otherwise the expert FFN hidden dim shards over "model"
    and experts are co-located (mixtral 8e on a 16-way model axis).
Top-k weighting is renormalized; Switch-style load-balancing aux loss.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.sharding import ShardCtx, shard


def capacity(n_tokens: int, n_experts: int, top_k: int, factor: float) -> int:
    c = int(math.ceil(n_tokens * top_k * factor / n_experts))
    return max(8, -(-c // 8) * 8)   # round up to a multiple of 8


def moe_ffn(p: dict, x: jax.Array, *, n_experts: int, top_k: int,
            capacity_factor: float, quant: str, ctx: ShardCtx | None,
            ep: bool, n_groups: int | None = None, moe_fsdp: str = "d"
            ) -> tuple[jax.Array, jax.Array]:
    """x: (B, S, D) -> (y, aux_loss).

    p: router {"w": (D, E)}, experts {"w_gate","w_up": (E, D, F),
    "w_down": (E, F, D)} (stacked over experts).
    """
    B, S, D = x.shape
    T = B * S
    E, k = n_experts, top_k
    G = n_groups if n_groups is not None else (ctx.data_size if ctx else 1)
    if G < 1 or T % G or (T // G) < 1:
        G = 1
    Tg = T // G
    C = capacity(Tg, E, k, capacity_factor)
    bax = (ctx.batch_axes if ctx is not None and G % ctx.data_size == 0
           else None)

    xg = x.reshape(G, Tg, D)
    if ctx is not None:
        xg = shard(xg, ctx, P(bax, None, None))

    logits = jnp.einsum("gtd,de->gte", xg.astype(jnp.float32),
                        p["router"]["w"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)                    # (G, Tg, E)
    topw, tope = jax.lax.top_k(probs, k)                       # (G, Tg, k)
    topw = topw / jnp.maximum(topw.sum(-1, keepdims=True), 1e-9)

    # Switch-style load-balancing aux loss (global)
    me = jnp.mean(probs, axis=(0, 1))                          # (E,)
    ce = jnp.mean(jax.nn.one_hot(tope[..., 0], E, dtype=jnp.float32),
                  axis=(0, 1))
    aux = E * jnp.sum(me * ce)

    # ---- group-local positions: exclusive cumsum of assignment one-hots ----
    fe = tope.reshape(G, Tg * k)                               # token-major
    onehot = jax.nn.one_hot(fe, E, dtype=jnp.int32)            # (G, Tg*k, E)
    pos_all = jnp.cumsum(onehot, axis=1) - onehot              # exclusive
    seg_pos = jnp.take_along_axis(pos_all, fe[..., None], -1)[..., 0]
    keep = seg_pos < C
    dst = jnp.where(keep, fe * C + seg_pos, E * C)             # overflow slot

    # ---- dispatch: batched scatter over G (partitions along batch dims) ----
    xin = jnp.repeat(xg, k, axis=1)                            # (G, Tg*k, D)
    zeros = jnp.zeros((G, E * C + 1, D), x.dtype)
    buf = jax.vmap(lambda z, d, u: z.at[d].set(u))(zeros, dst, xin)
    eb = buf[:, : E * C].reshape(G, E, C, D)
    # weight-stationary ("f"): gather the small token buffer across data
    # instead of the huge FSDP-sharded expert weights — expert weights stay
    # resident (E on model, F on data); outputs reduce over the F shards.
    act_stationary = ep and moe_fsdp == "f"
    if act_stationary:
        espec = P(None, "model", None, None)
    else:
        espec = (P(bax, "model", None, None) if ep
                 else P(bax, None, None, None))
    if ctx is not None:
        eb = shard(eb, ctx, espec)

    # ---- expert FFN (SwiGLU), batched over the expert dim ----
    wg, wu, wd = (p["experts"]["w_gate"], p["experts"]["w_up"],
                  p["experts"]["w_down"])
    h = jax.nn.silu(jnp.einsum("gecd,edf->gecf", eb, wg.astype(x.dtype)))
    h = h * jnp.einsum("gecd,edf->gecf", eb, wu.astype(x.dtype))
    if ctx is not None:
        if act_stationary:
            h = shard(h, ctx, P(None, "model", None, "data"))
        elif not ep:
            h = shard(h, ctx, P(bax, None, None, "model"))
    out = jnp.einsum("gecf,efd->gecd", h, wd.astype(x.dtype))  # (G, E, C, D)
    if ctx is not None:
        out = shard(out, ctx, espec)

    # ---- combine: gather back + weighted sum over the k assignments ----
    flat = jnp.concatenate(
        [out.reshape(G, E * C, D), jnp.zeros((G, 1, D), x.dtype)], axis=1)
    contrib = jnp.take_along_axis(flat, dst[..., None], axis=1)  # (G, Tg*k, D)
    contrib = contrib * topw.reshape(G, Tg * k)[..., None].astype(x.dtype)
    y = contrib.reshape(G, Tg, k, D).sum(axis=2)
    return y.reshape(B, S, D), aux.astype(jnp.float32)
