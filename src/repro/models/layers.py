"""Shared model layers: norms, rotary embeddings (incl. M-RoPE), linears.

Every projection supports three quantization modes, selected statically by
the arch config:
  * "dense"          — plain bf16/f32 matmul,
  * "ternary"        — QAT: absmean-scaled ternary STE (the paper's neuron,
                       BitNet-b1.58-style scaling for LM trainability),
  * "ternary_packed" — serving: weights stored as 2-bit codes (4/int8 byte)
                       + per-channel scale; unpacked at use.  On TPU the
                       unpack+matmul is the `kernels/ternary_matmul` Pallas
                       kernel; the jnp path here is its reference and the
                       CPU/dry-run lowering.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.ternary import ternary_ste_lm, unpack_ternary


def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-5) -> jax.Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return ((x * jax.lax.rsqrt(var + eps)) * scale.astype(jnp.float32)).astype(dtype)


def layer_norm(x: jax.Array, scale: jax.Array, bias: jax.Array,
               eps: float = 1e-5) -> jax.Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32) + bias.astype(jnp.float32)).astype(dtype)


# ---------------------------------------------------------------------------
# Linear with static quant-mode dispatch
# ---------------------------------------------------------------------------
def linear(p: dict, x: jax.Array, quant: str = "dense") -> jax.Array:
    """p holds {"w": (K, N)} [+ "b"] or packed {"w2": (K//4, N), "scale": (1, N)}."""
    if quant == "ternary_packed":
        w = unpack_ternary(p["w2"], dtype=x.dtype) * p["scale"].astype(x.dtype)
        y = x @ w
    elif quant == "ternary":
        y = x @ ternary_ste_lm(p["w"]).astype(x.dtype)
    else:
        y = x @ p["w"].astype(x.dtype)
    if "b" in p:
        y = y + p["b"].astype(y.dtype)
    return y


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------
def _rope_freqs(d_head: int, theta: float) -> np.ndarray:
    return 1.0 / (theta ** (np.arange(0, d_head, 2, dtype=np.float32) / d_head))


def rope_cos_sin(positions: jax.Array, d_head: int, theta: float
                 ) -> tuple[jax.Array, jax.Array]:
    """positions (..., S) -> cos/sin (..., S, d_head//2) f32."""
    freqs = jnp.asarray(_rope_freqs(d_head, theta))
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """x: (B, S, H, dh); cos/sin: (B, S, dh//2) (broadcast over heads)."""
    dtype = x.dtype
    x = x.astype(jnp.float32)
    x1, x2 = jnp.split(x, 2, axis=-1)
    c = cos[:, :, None, :]
    s = sin[:, :, None, :]
    return jnp.concatenate([x1 * c - x2 * s, x2 * c + x1 * s], axis=-1).astype(dtype)


def mrope_cos_sin(positions: jax.Array, d_head: int, theta: float,
                  sections: tuple[int, ...]) -> tuple[jax.Array, jax.Array]:
    """Multimodal RoPE (Qwen2-VL): positions (B, 3, S) carry (t, h, w) ids.

    The dh//2 frequency dims are split into `sections` (sum == dh//2); each
    section takes its angle from the corresponding position stream.
    """
    assert sum(sections) == d_head // 2, (sections, d_head)
    freqs = jnp.asarray(_rope_freqs(d_head, theta))          # (dh//2,)
    ang_all = positions.astype(jnp.float32)[..., None] * freqs  # (B, 3, S, dh//2)
    parts, start = [], 0
    for si, sec in enumerate(sections):
        parts.append(ang_all[:, si, :, start:start + sec])
        start += sec
    ang = jnp.concatenate(parts, axis=-1)                    # (B, S, dh//2)
    return jnp.cos(ang), jnp.sin(ang)


# ---------------------------------------------------------------------------
# Embedding / LM head
# ---------------------------------------------------------------------------
def embed(table: jax.Array, tokens: jax.Array, compute_dtype) -> jax.Array:
    return jnp.take(table, tokens, axis=0).astype(compute_dtype)


def lm_head(x: jax.Array, table: jax.Array) -> jax.Array:
    """Logits in f32 (softmax stability at 152k vocab)."""
    return (x.astype(jnp.float32) @ table.astype(jnp.float32))
