"""Per-arch smoke tests (reduced configs): one forward/train step on CPU,
shape + finiteness assertions, and prefill->decode parity vs the full
forward — the invariant that the serving path computes the same function.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config
from repro.models import transformer as TF
from repro.models.params import init_params, param_count
from tests.conftest import make_lm_batch


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_and_grad(arch):
    cfg = get_config(arch).reduced()
    params = init_params(jax.random.PRNGKey(0), cfg)
    batch = make_lm_batch(cfg, B=2, S=16)
    x, aux, _ = TF.forward(cfg, params, batch)
    assert x.shape == (2, 16, cfg.d_model)
    assert bool(jnp.isfinite(x).all())
    loss, metrics = TF.loss_fn(cfg, params, batch)
    assert bool(jnp.isfinite(loss))
    g = jax.grad(lambda p: TF.loss_fn(cfg, p, batch)[0])(params)
    gn = jnp.sqrt(sum(jnp.sum(jnp.square(v.astype(jnp.float32)))
                      for v in jax.tree.leaves(g)))
    assert bool(jnp.isfinite(gn)) and float(gn) > 0


@pytest.mark.parametrize("arch", ["llama3.2-1b", "qwen3-4b", "mixtral-8x22b",
                                  "rwkv6-7b", "hymba-1.5b", "whisper-medium"])
def test_prefill_decode_parity(arch):
    """logits(decode at pos=S | prefill of S) == logits(forward of S+1)[-1]."""
    cfg = get_config(arch).reduced()
    if cfg.moe is not None:
        # capacity drops legitimately differ between prefill (token competes
        # with the whole batch) and decode (competes with 1); disable drops
        # so the test isolates routing/dispatch correctness.
        import dataclasses
        cfg = cfg.replace(moe=dataclasses.replace(cfg.moe,
                                                  capacity_factor=8.0))
    params = init_params(jax.random.PRNGKey(1), cfg)
    S = 16 if cfg.swa_window is None else cfg.swa_window  # rolling: S == W
    full = make_lm_batch(cfg, B=2, S=S + 1, seed=3)
    # reference: full forward over S+1 tokens
    x_full, _, _ = TF.forward(cfg, params, full)
    ref_logits = TF.logits_from_hidden(cfg, params, x_full[:, -1:, :])
    # prefill S, then decode token S.  Non-rolling caches need a slot for
    # the new token (cache_len > S); rolling caches reuse slot pos % W.
    cache_len = S if cfg.swa_window else S + 8
    pre = {k: (v[:, :S] if k in ("tokens", "labels") else
               v[:, :, :S] if k == "positions" else v)
           for k, v in full.items()}
    _, cache = TF.prefill(cfg, params, pre, cache_len=cache_len)
    tok = full["tokens"][:, S:S + 1]
    kwargs = {}
    if cfg.rope == "mrope":
        kwargs["positions"] = jnp.full((2, 3, 1), S, jnp.int32)
    got, _ = TF.decode_step(cfg, params, cache, tok, jnp.int32(S), **kwargs)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref_logits),
                               rtol=2e-2, atol=2e-2)


def test_param_counts_full_configs():
    """Full configs land near their nameplate sizes (sanity on shapes)."""
    expected = {"qwen2-vl-72b": (65e9, 80e9), "mixtral-8x22b": (130e9, 150e9),
                "arctic-480b": (430e9, 520e9), "llama3.2-1b": (1.0e9, 1.6e9),
                "qwen2-1.5b": (1.2e9, 1.9e9), "qwen2.5-14b": (12e9, 16e9),
                "rwkv6-7b": (6e9, 9e9), "hymba-1.5b": (1.2e9, 2.2e9),
                "whisper-medium": (0.6e9, 1.0e9), "qwen3-4b": (3.2e9, 5e9)}
    for arch, (lo, hi) in expected.items():
        n = param_count(get_config(arch))
        assert lo <= n <= hi, f"{arch}: {n/1e9:.2f}B not in [{lo/1e9},{hi/1e9}]"


def test_swa_masks_differ_from_full():
    cfg = get_config("mixtral-8x22b").reduced()   # swa_window=8
    params = init_params(jax.random.PRNGKey(0), cfg)
    batch = make_lm_batch(cfg, B=1, S=24)
    x_swa, _, _ = TF.forward(cfg, params, batch)
    cfg_full = cfg.replace(swa_window=None)
    x_full, _, _ = TF.forward(cfg_full, params, batch)
    # early positions identical (window not yet binding), late ones differ
    assert np.allclose(np.asarray(x_swa[:, :8]), np.asarray(x_full[:, :8]),
                       atol=1e-4)
    assert not np.allclose(np.asarray(x_swa[:, -1]), np.asarray(x_full[:, -1]),
                           atol=1e-4)


def test_ternary_quant_mode_trains():
    cfg = get_config("llama3.2-1b").reduced().replace(quant="ternary")
    params = init_params(jax.random.PRNGKey(0), cfg)
    batch = make_lm_batch(cfg, B=2, S=16)
    loss, _ = TF.loss_fn(cfg, params, batch)
    g = jax.grad(lambda p: TF.loss_fn(cfg, p, batch)[0])(params)
    gn = sum(float(jnp.abs(v).sum()) for v in jax.tree.leaves(g))
    assert bool(jnp.isfinite(loss)) and gn > 0


def test_serving_optimized_path_runs():
    """The §Perf decode config (TP-only + 2-bit packed + fp8 KV) must
    produce finite logits end-to-end on the reduced config."""
    cfg = get_config("llama3.2-1b").reduced().replace(
        quant="ternary_packed", serve_fsdp=False,
        kv_cache_dtype="float8_e4m3fn")
    params = init_params(jax.random.PRNGKey(0), cfg)
    cache = TF.init_cache(cfg, batch_size=2, seq_len=32)
    assert cache["k"].dtype == jnp.float8_e4m3fn
    tok = jnp.asarray([[1], [2]], jnp.int32)
    logits, cache2 = TF.decode_step(cfg, params, cache, tok, jnp.int32(0))
    assert bool(jnp.isfinite(logits).all())
    assert cache2["k"].dtype == jnp.float8_e4m3fn


def test_replicate_kv_spec_changes():
    from repro.models.params import param_defs, is_def
    cfg = get_config("hymba-1.5b")
    base = param_defs(cfg, 16)["layers"]["attn"]["wk"]["w"].spec
    repl = param_defs(cfg.replace(replicate_kv=True),
                      16)["layers"]["attn"]["wk"]["w"].spec
    assert tuple(base)[-1] == "model" and tuple(repl)[-1] is None


def test_ternary_packed_matches_dense_of_unpacked():
    """Packed serving path == dense forward over the unpacked weights."""
    from repro.core.ternary import pack_ternary
    from repro.models.layers import linear
    r = np.random.default_rng(0)
    codes = jnp.asarray(r.integers(-1, 2, (64, 32)), jnp.int8)
    x = jnp.asarray(r.normal(0, 1, (4, 64)), jnp.float32)
    scale = jnp.asarray(np.abs(r.normal(1, 0.1, (1, 32))), jnp.float32)
    packed = {"w2": pack_ternary(codes), "scale": scale}
    dense = {"w": codes.astype(jnp.float32) * scale}
    got = linear(packed, x, "ternary_packed")
    want = linear(dense, x, "dense")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)
