"""Cross-backend conformance: differential fuzz over every circuit evaluator.

Five executors claim to compute the same function of (netlist, test
vectors):

  1. `Netlist.simulate` / `eval_uint` — the serial uint64 reference,
  2. `NetlistPopulation` — structure-of-arrays batched numpy,
  3. `kernels.circuit_sim.simulate_population` — jitted uint32-SWAR scan,
  4. `kernels.pallas_circuit_sim` — the Pallas kernel (interpret off-TPU),
  5. `CircuitProgram` (jax + np backends) over the lowered `CircuitIR`,
plus the emitted-Verilog route: `compile.verilog.emit_netlist_module` ->
`compile.vread.VerilogDesign`, an evaluator that never sees the IR.

Any two of them disagreeing on any vector of any random netlist is a
failure.  The seeded sweep always runs; when hypothesis is installed the
same oracle is additionally driven by shrinking random shapes (example
budget scales with REPRO_CONFORMANCE_EXAMPLES — the nightly CI job raises
it), and the `slow`-marked sweep covers larger populations and widths.

The fused-megakernel matrix extends the oracle to the serving path: the
single-launch fused kernel (`fused_eval_uint`) and the multi-tenant
`fleet_eval_words` (heterogeneous plans padded to one gate budget) must
match `predict_with_circuits` on all five golden datasets, and a
hypothesis property pins that padding mixed gate counts / input widths /
word widths into one launch never leaks bits across tenants.
"""
import os

import numpy as np
import pytest

from repro.compile.program import CircuitProgram
from repro.compile.verilog import emit_netlist_module
from repro.compile.vread import VerilogDesign
from repro.core import circuits as C
from repro.kernels import circuit_sim as CS
from repro.kernels import pallas_circuit_sim as PS


def _rand_bits(rng, S, n):
    return (rng.random((S, n)) < 0.5).astype(np.uint8)


def assert_conformance(pop: C.NetlistPopulation, bits: np.ndarray,
                       check_programs: bool = True) -> None:
    """All evaluators must agree on every vector for every individual."""
    S = bits.shape[0]
    packed = C.pack_vectors(bits)
    ref = pop.eval_uint(packed)[:, :S]                       # batched numpy

    for p in range(pop.size):                                # serial reference
        nl = pop.netlist(p)
        np.testing.assert_array_equal(
            nl.eval_uint(packed)[:S], ref[p],
            err_msg=f"NetlistPopulation row {p} != Netlist.eval_uint")

    words32 = CS.pack_words32(packed)
    swar = np.asarray(CS.population_eval_uint(
        pop.op.astype(np.int32), pop.in0, pop.in1, pop.outputs, words32,
        pop.n_inputs))[:, :S]
    np.testing.assert_array_equal(swar, ref, err_msg="SWAR scan != numpy")

    pallas = np.asarray(PS.population_eval_uint(
        pop.op, pop.in0, pop.in1, pop.outputs, words32, pop.n_inputs))[:, :S]
    np.testing.assert_array_equal(pallas, ref,
                                  err_msg="Pallas kernel != numpy")

    if not check_programs:
        return
    for p in range(pop.size):
        nl = pop.netlist(p, name=f"fuzz{p}")
        for backend in ("jax", "np"):
            got = CircuitProgram.from_netlist(nl, backend=backend)
            np.testing.assert_array_equal(
                got.eval_bits(bits), ref[p],
                err_msg=f"CircuitProgram[{backend}] != numpy (row {p})")
        design = VerilogDesign.parse(emit_netlist_module(nl, "fuzz"))
        np.testing.assert_array_equal(
            design.eval_uint("fuzz", bits), ref[p],
            err_msg=f"Verilog reader != numpy (row {p})")


def _fuzz_case(seed: int, max_inputs=8, max_gates=32, max_pop=8,
               max_vectors=200, check_programs=True) -> None:
    rng = np.random.default_rng(seed)
    n_in = int(rng.integers(1, max_inputs + 1))
    n_gates = int(rng.integers(0, max_gates + 1))
    n_out = int(rng.integers(1, min(8, n_in + n_gates) + 1))
    P = int(rng.integers(1, max_pop + 1))
    S = int(rng.integers(1, max_vectors + 1))
    pop = C.random_netlist_population(rng, n_in, n_gates, n_out, P)
    assert_conformance(pop, _rand_bits(rng, S, n_in),
                       check_programs=check_programs)


N_EXAMPLES = int(os.environ.get("REPRO_CONFORMANCE_EXAMPLES", "20"))


@pytest.mark.parametrize("seed", range(N_EXAMPLES))
def test_random_netlists_all_backends_agree(seed):
    _fuzz_case(seed)


def test_per_individual_word_planes_agree():
    """Device paths must also match when every genome gets its own words —
    the TNN integration's output-plane shape."""
    rng = np.random.default_rng(1234)
    pop = C.random_netlist_population(rng, 6, 20, 3, 5)
    S = 150
    bits = np.stack([_rand_bits(rng, S, 6) for _ in range(pop.size)])
    packed = C.pack_vectors(bits)                        # (P, n_in, W)
    ref = pop.eval_uint(packed)[:, :S]
    words32 = CS.pack_words32(packed)
    swar = np.asarray(CS.population_eval_uint(
        pop.op.astype(np.int32), pop.in0, pop.in1, pop.outputs, words32,
        pop.n_inputs))[:, :S]
    pallas = np.asarray(PS.population_eval_uint(
        pop.op, pop.in0, pop.in1, pop.outputs, words32, pop.n_inputs))[:, :S]
    np.testing.assert_array_equal(swar, ref)
    np.testing.assert_array_equal(pallas, ref)


def test_degenerate_shapes_agree():
    """Gateless netlists, single-word batches, repeated output taps."""
    rng = np.random.default_rng(99)
    for (n_in, n_gates, n_out, P, S) in [(1, 0, 1, 1, 1), (2, 0, 2, 3, 5),
                                         (4, 1, 4, 2, 64), (3, 40, 1, 6, 65),
                                         (8, 16, 8, 4, 33)]:
        pop = C.random_netlist_population(rng, n_in, n_gates, n_out, P)
        assert_conformance(pop, _rand_bits(rng, S, n_in))


def test_zero_width_word_plane_returns_empty():
    """Regression (PR 9): `W == 0` used to hand pallas_call a zero-size
    grid/block; now both kernel entry points short-circuit to empty
    results, mirroring the gateless-plan pad guard."""
    from repro.kernels import dispatch as D

    rng = np.random.default_rng(7)
    pop = C.random_netlist_population(rng, 4, 10, 2, 3)
    empty = np.zeros((4, 0), dtype=np.uint32)
    words = np.asarray(PS.simulate_population(
        pop.op, pop.in0, pop.in1, pop.outputs, empty, 4))
    assert words.shape == (3, 2, 0)
    ints = np.asarray(PS.population_eval_uint(
        pop.op, pop.in0, pop.in1, pop.outputs, empty, 4))
    assert ints.shape == (3, 0)
    fleet = D.fleet_eval_words(
        [(pop.op[0], pop.in0[0], pop.in1[0], pop.outputs[0], 4)],
        [empty], backend="pallas")
    assert fleet[0].shape == (0,)


def test_block_words_knob_reaches_pallas_kernel(monkeypatch):
    """Regression (PR 9): dispatch used to silently drop the Pallas knobs
    — a campaign/tenant `block_words` override never reached the kernel.
    Pin the plumbing end-to-end by spying on the jitted pallas_call
    wrapper through `program_eval_words` AND `population_eval_uint`."""
    from repro.kernels import dispatch as D

    seen = []
    real = PS._fused_padded

    def spy(*args, **kw):
        seen.append(kw["block_words"])
        return real(*args, **kw)

    monkeypatch.setattr(PS, "_fused_padded", spy)
    rng = np.random.default_rng(11)
    pop = C.random_netlist_population(rng, 5, 12, 2, 4)
    bits = _rand_bits(rng, 200, 5)          # 7 words — default tile is 128
    words32 = np.asarray(CS.pack_bits32(bits))

    D.program_eval_words(pop.op[:1], pop.in0[:1], pop.in1[:1],
                         pop.outputs[:1], words32, 5, backend="pallas",
                         block_words=2)
    assert seen[-1] == 2, "block_words override never reached the kernel"

    D.population_eval_uint(pop.op, pop.in0, pop.in1, pop.outputs,
                           C.pack_vectors(bits), 5, backend="pallas",
                           block_words=3)
    assert seen[-1] == 3

    prog = CircuitProgram.from_netlist(pop.netlist(0), backend="pallas",
                                       pallas_block_words=4)
    prog.eval_bits(bits)
    assert seen[-1] == 4, "CircuitProgram.pallas_block_words was dropped"


def test_np_backend_odd_width_repack_matches_swar():
    """Regression (PR 9): the np backend's uint32->uint64 lane repack for
    odd-width word planes reinterpreted bytes (`.view(np.uint64)`), which
    is only the documented lane contract on little-endian hosts.  Pin
    np/swar/pallas bit-identity through `program_eval_words` on odd
    widths, and the repack itself against an arithmetic lane combine."""
    from repro.kernels import dispatch as D

    rng = np.random.default_rng(21)
    pop = C.random_netlist_population(rng, 6, 18, 3, 1)
    for W32 in (1, 3, 5):
        words32 = rng.integers(0, 2**32, size=(6, W32), dtype=np.uint32)
        outs = {b: D.program_eval_words(pop.op, pop.in0, pop.in1,
                                        pop.outputs, words32, 6, backend=b)
                for b in ("np", "swar", "pallas")}
        np.testing.assert_array_equal(
            outs["np"], outs["swar"],
            err_msg=f"np != swar on odd width W32={W32}")
        np.testing.assert_array_equal(
            outs["swar"], outs["pallas"],
            err_msg=f"swar != pallas on odd width W32={W32}")


@pytest.mark.slow
def test_fuzz_sweep_large():
    """Bigger populations / word planes; nightly raises the budget."""
    for seed in range(max(N_EXAMPLES, 30)):
        _fuzz_case(10_000 + seed, max_inputs=10, max_gates=96, max_pop=24,
                   max_vectors=2100, check_programs=False)


# ---------------------------------------------------------------------------
# Fleet serving path: emitted artifact -> ClassifierFleet -> labels must
# match predict_with_circuits on every golden vector, on every backend
# ---------------------------------------------------------------------------
@pytest.fixture(scope="module")
def golden_fleet():
    """Emit all five golden classifiers into one fleet dir (+ references)."""
    import tempfile

    from test_golden import GOLDEN_DIR, golden_classifier
    from repro.compile.verilog import write_artifacts
    from repro.core.ternary import abc_binarize
    from repro.core.tnn import TrainedTNN, predict_with_circuits
    from repro.data.tabular import DATASETS

    tmp = tempfile.TemporaryDirectory(prefix="golden_fleet_")
    refs = {}
    for name in sorted(DATASETS):
        cc, _ = golden_classifier(name)
        write_artifacts(cc, tmp.name, base=f"tnn_{name}", dataset=name)
        x = np.load(GOLDEN_DIR / f"{name}.npz")["x"]
        # offline oracle: the pre-compile netlist evaluator over ABC bits
        tnn = TrainedTNN(w1t=cc.w1t, w2t=cc.w2t, thresholds=cc.thresholds,
                         train_acc=0.0, test_acc=0.0, name=name)
        xbin = np.asarray(abc_binarize(x, cc.thresholds)).astype(np.uint8)
        labels = predict_with_circuits(tnn, xbin, cc.hidden_nls, cc.out_nls)
        refs[f"tnn_{name}"] = (x, labels)
    yield tmp.name, refs
    tmp.cleanup()


@pytest.mark.parametrize("backend", ("np", "swar", "pallas"))
def test_fleet_serving_matches_predict_with_circuits(golden_fleet, backend):
    """The whole serving stack — manifest load, router, micro-batcher,
    backend dispatch through kernels.dispatch — must be label-transparent:
    every golden vector of every Table-2 dataset gets the exact
    `predict_with_circuits` label, per tenant, on np/swar/pallas."""
    from repro.serve import ClassifierFleet

    emit_dir, refs = golden_fleet
    fleet = ClassifierFleet.from_emit_dir(emit_dir, backends=backend,
                                          max_batch=64, deadline_ms=5_000.0)
    try:
        handles = {tenant: [fleet.submit(tenant, row) for row in x]
                   for tenant, (x, _) in sorted(refs.items())}
        fleet.flush(timeout=120)
        for tenant, (_, want) in refs.items():
            got = np.array([r.result(timeout=120) for r in handles[tenant]],
                           dtype=np.int32)
            np.testing.assert_array_equal(
                got, want, err_msg=f"fleet[{backend}] != "
                                   f"predict_with_circuits ({tenant})")
        assert fleet.errors == []
    finally:
        fleet.shutdown(drain=True)


@pytest.mark.parametrize("variant", ("fused", "fleet"))
def test_megakernel_matches_predict_with_circuits(golden_fleet, variant):
    """Megakernel matrix: the fused single-program kernel and the
    multi-tenant `fleet_eval_words` launch must both reproduce
    `predict_with_circuits` labels on all five golden datasets.

    `fused` routes each golden program through the single-`pallas_call`
    gate-walk+decode path one tenant at a time; `fleet` pools all five
    tenants' plan tables into ONE padded multi-program launch — 5 tenants
    with different gate/feature/class counts sharing a kernel, every
    label still bit-exact."""
    from repro.compile.artifact import load_program
    from repro.kernels import dispatch as D

    emit_dir, refs = golden_fleet
    progs, planes = {}, {}
    for tenant, (x, _) in sorted(refs.items()):
        prog = load_program(f"{emit_dir}/{tenant}_program.npz",
                            backend="pallas")
        progs[tenant] = prog
        planes[tenant] = prog.pack_input_bits(prog.binarize(x))
    if variant == "fused":
        for tenant, (x, want) in refs.items():
            got = progs[tenant].predict(x)
            np.testing.assert_array_equal(
                got, want,
                err_msg=f"fused megakernel != predict_with_circuits "
                        f"({tenant})")
    else:
        order = sorted(refs)
        outs = D.fleet_eval_words([progs[t].plan() for t in order],
                                  [planes[t] for t in order],
                                  backend="pallas")
        for tenant, out in zip(order, outs):
            x, want = refs[tenant]
            np.testing.assert_array_equal(
                out[: x.shape[0]].astype(np.int32), want,
                err_msg=f"fleet megakernel != predict_with_circuits "
                        f"({tenant})")


def test_megakernel_fleet_serving_matches(golden_fleet):
    """Serving-path megakernel: all five golden tenants on the pallas
    backend with `megakernel=True` — the scheduler must carry every due
    tenant in one fused launch and still hand back exact labels."""
    from repro.serve import ClassifierFleet

    emit_dir, refs = golden_fleet
    fleet = ClassifierFleet.from_emit_dir(
        emit_dir, backends="pallas", max_batch=64, deadline_ms=5_000.0,
        megakernel=True, autostart=False, warmup=False)
    try:
        handles = {tenant: [fleet.submit(tenant, row) for row in x]
                   for tenant, (x, _) in sorted(refs.items())}
        fleet.start()
        fleet.flush(timeout=120)
        for tenant, (_, want) in refs.items():
            got = np.array([r.result(timeout=120) for r in handles[tenant]],
                           dtype=np.int32)
            np.testing.assert_array_equal(
                got, want,
                err_msg=f"megakernel fleet != predict_with_circuits "
                        f"({tenant})")
        assert fleet.errors == []
        mk = fleet.stats_summary()["megakernel"]
        assert mk["launches"] >= 1
        # every tenant was due before start(): the first pass must have
        # fused at least 4 of the 5 into one launch
        assert mk["peak_tenants_per_launch"] >= 4, mk
    finally:
        fleet.shutdown(drain=True)


# ---------------------------------------------------------------------------
# Hypothesis-driven variant (shrinks failures to minimal netlists)
# ---------------------------------------------------------------------------
try:
    from hypothesis import given, settings, strategies as st
    _HAVE_HYPOTHESIS = True
except ImportError:                                   # pragma: no cover
    _HAVE_HYPOTHESIS = False

if _HAVE_HYPOTHESIS:

    @settings(max_examples=N_EXAMPLES, deadline=None)
    @given(st.integers(1, 8), st.integers(0, 32), st.integers(1, 6),
           st.integers(1, 8), st.integers(1, 200), st.integers(0, 2**31 - 1))
    def test_hypothesis_netlists_all_backends_agree(n_in, n_gates, n_out,
                                                    P, S, seed):
        rng = np.random.default_rng(seed)
        n_out = min(n_out, n_in + n_gates)
        pop = C.random_netlist_population(rng, n_in, n_gates, n_out, P)
        assert_conformance(pop, _rand_bits(rng, S, n_in),
                           check_programs=False)

    @settings(max_examples=N_EXAMPLES, deadline=None)
    @given(st.lists(st.tuples(st.integers(1, 7),    # n_in per tenant
                              st.integers(0, 40),   # n_gates per tenant
                              st.integers(1, 5),    # n_out per tenant
                              st.integers(1, 100)),  # vectors per tenant
                    min_size=2, max_size=6),
           st.integers(0, 2**31 - 1))
    def test_hypothesis_fleet_megakernel_padding_never_leaks(shapes, seed):
        """Mixed per-tenant gate counts through the multi-program
        megakernel: every tenant's plan is padded to the common
        (G_max, n_in_max, W_max) tables, and NONE of that padding — pad
        gates, pad input rows, pad words, pad output taps — may change
        any tenant's decoded integers vs evaluating that tenant alone."""
        from repro.kernels import dispatch as D

        rng = np.random.default_rng(seed)
        plans, words_list, refs = [], [], []
        for (n_in, n_gates, n_out, S) in shapes:
            n_out = min(n_out, n_in + n_gates)
            pop = C.random_netlist_population(rng, n_in, n_gates, n_out, 1)
            bits = _rand_bits(rng, S, n_in)
            packed = C.pack_vectors(bits)
            refs.append((S, pop.eval_uint(packed)[0, :S]))
            plans.append((pop.op[0], pop.in0[0], pop.in1[0],
                          pop.outputs[0], n_in))
            words_list.append(np.asarray(CS.pack_bits32(bits)))
        outs = D.fleet_eval_words(plans, words_list, backend="pallas")
        for t, ((S, want), out) in enumerate(zip(refs, outs)):
            np.testing.assert_array_equal(
                out[:S], want,
                err_msg=f"fleet megakernel tenant {t} (shape "
                        f"{shapes[t]}) != Netlist reference")
