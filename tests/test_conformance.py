"""Cross-backend conformance: differential fuzz over every circuit evaluator.

Five executors claim to compute the same function of (netlist, test
vectors):

  1. `Netlist.simulate` / `eval_uint` — the serial uint64 reference,
  2. `NetlistPopulation` — structure-of-arrays batched numpy,
  3. `kernels.circuit_sim.simulate_population` — jitted uint32-SWAR scan,
  4. `kernels.pallas_circuit_sim` — the Pallas kernel (interpret off-TPU),
  5. `CircuitProgram` (jax + np backends) over the lowered `CircuitIR`,
plus the emitted-Verilog route: `compile.verilog.emit_netlist_module` ->
`compile.vread.VerilogDesign`, an evaluator that never sees the IR.

Any two of them disagreeing on any vector of any random netlist is a
failure.  The seeded sweep always runs; when hypothesis is installed the
same oracle is additionally driven by shrinking random shapes (example
budget scales with REPRO_CONFORMANCE_EXAMPLES — the nightly CI job raises
it), and the `slow`-marked sweep covers larger populations and widths.
"""
import os

import numpy as np
import pytest

from repro.compile.program import CircuitProgram
from repro.compile.verilog import emit_netlist_module
from repro.compile.vread import VerilogDesign
from repro.core import circuits as C
from repro.kernels import circuit_sim as CS
from repro.kernels import pallas_circuit_sim as PS


def _rand_bits(rng, S, n):
    return (rng.random((S, n)) < 0.5).astype(np.uint8)


def assert_conformance(pop: C.NetlistPopulation, bits: np.ndarray,
                       check_programs: bool = True) -> None:
    """All evaluators must agree on every vector for every individual."""
    S = bits.shape[0]
    packed = C.pack_vectors(bits)
    ref = pop.eval_uint(packed)[:, :S]                       # batched numpy

    for p in range(pop.size):                                # serial reference
        nl = pop.netlist(p)
        np.testing.assert_array_equal(
            nl.eval_uint(packed)[:S], ref[p],
            err_msg=f"NetlistPopulation row {p} != Netlist.eval_uint")

    words32 = CS.pack_words32(packed)
    swar = np.asarray(CS.population_eval_uint(
        pop.op.astype(np.int32), pop.in0, pop.in1, pop.outputs, words32,
        pop.n_inputs))[:, :S]
    np.testing.assert_array_equal(swar, ref, err_msg="SWAR scan != numpy")

    pallas = np.asarray(PS.population_eval_uint(
        pop.op, pop.in0, pop.in1, pop.outputs, words32, pop.n_inputs))[:, :S]
    np.testing.assert_array_equal(pallas, ref,
                                  err_msg="Pallas kernel != numpy")

    if not check_programs:
        return
    for p in range(pop.size):
        nl = pop.netlist(p, name=f"fuzz{p}")
        for backend in ("jax", "np"):
            got = CircuitProgram.from_netlist(nl, backend=backend)
            np.testing.assert_array_equal(
                got.eval_bits(bits), ref[p],
                err_msg=f"CircuitProgram[{backend}] != numpy (row {p})")
        design = VerilogDesign.parse(emit_netlist_module(nl, "fuzz"))
        np.testing.assert_array_equal(
            design.eval_uint("fuzz", bits), ref[p],
            err_msg=f"Verilog reader != numpy (row {p})")


def _fuzz_case(seed: int, max_inputs=8, max_gates=32, max_pop=8,
               max_vectors=200, check_programs=True) -> None:
    rng = np.random.default_rng(seed)
    n_in = int(rng.integers(1, max_inputs + 1))
    n_gates = int(rng.integers(0, max_gates + 1))
    n_out = int(rng.integers(1, min(8, n_in + n_gates) + 1))
    P = int(rng.integers(1, max_pop + 1))
    S = int(rng.integers(1, max_vectors + 1))
    pop = C.random_netlist_population(rng, n_in, n_gates, n_out, P)
    assert_conformance(pop, _rand_bits(rng, S, n_in),
                       check_programs=check_programs)


N_EXAMPLES = int(os.environ.get("REPRO_CONFORMANCE_EXAMPLES", "20"))


@pytest.mark.parametrize("seed", range(N_EXAMPLES))
def test_random_netlists_all_backends_agree(seed):
    _fuzz_case(seed)


def test_per_individual_word_planes_agree():
    """Device paths must also match when every genome gets its own words —
    the TNN integration's output-plane shape."""
    rng = np.random.default_rng(1234)
    pop = C.random_netlist_population(rng, 6, 20, 3, 5)
    S = 150
    bits = np.stack([_rand_bits(rng, S, 6) for _ in range(pop.size)])
    packed = C.pack_vectors(bits)                        # (P, n_in, W)
    ref = pop.eval_uint(packed)[:, :S]
    words32 = CS.pack_words32(packed)
    swar = np.asarray(CS.population_eval_uint(
        pop.op.astype(np.int32), pop.in0, pop.in1, pop.outputs, words32,
        pop.n_inputs))[:, :S]
    pallas = np.asarray(PS.population_eval_uint(
        pop.op, pop.in0, pop.in1, pop.outputs, words32, pop.n_inputs))[:, :S]
    np.testing.assert_array_equal(swar, ref)
    np.testing.assert_array_equal(pallas, ref)


def test_degenerate_shapes_agree():
    """Gateless netlists, single-word batches, repeated output taps."""
    rng = np.random.default_rng(99)
    for (n_in, n_gates, n_out, P, S) in [(1, 0, 1, 1, 1), (2, 0, 2, 3, 5),
                                         (4, 1, 4, 2, 64), (3, 40, 1, 6, 65),
                                         (8, 16, 8, 4, 33)]:
        pop = C.random_netlist_population(rng, n_in, n_gates, n_out, P)
        assert_conformance(pop, _rand_bits(rng, S, n_in))


@pytest.mark.slow
def test_fuzz_sweep_large():
    """Bigger populations / word planes; nightly raises the budget."""
    for seed in range(max(N_EXAMPLES, 30)):
        _fuzz_case(10_000 + seed, max_inputs=10, max_gates=96, max_pop=24,
                   max_vectors=2100, check_programs=False)


# ---------------------------------------------------------------------------
# Fleet serving path: emitted artifact -> ClassifierFleet -> labels must
# match predict_with_circuits on every golden vector, on every backend
# ---------------------------------------------------------------------------
@pytest.fixture(scope="module")
def golden_fleet():
    """Emit all five golden classifiers into one fleet dir (+ references)."""
    import tempfile

    from test_golden import GOLDEN_DIR, golden_classifier
    from repro.compile.verilog import write_artifacts
    from repro.core.ternary import abc_binarize
    from repro.core.tnn import TrainedTNN, predict_with_circuits
    from repro.data.tabular import DATASETS

    tmp = tempfile.TemporaryDirectory(prefix="golden_fleet_")
    refs = {}
    for name in sorted(DATASETS):
        cc, _ = golden_classifier(name)
        write_artifacts(cc, tmp.name, base=f"tnn_{name}", dataset=name)
        x = np.load(GOLDEN_DIR / f"{name}.npz")["x"]
        # offline oracle: the pre-compile netlist evaluator over ABC bits
        tnn = TrainedTNN(w1t=cc.w1t, w2t=cc.w2t, thresholds=cc.thresholds,
                         train_acc=0.0, test_acc=0.0, name=name)
        xbin = np.asarray(abc_binarize(x, cc.thresholds)).astype(np.uint8)
        labels = predict_with_circuits(tnn, xbin, cc.hidden_nls, cc.out_nls)
        refs[f"tnn_{name}"] = (x, labels)
    yield tmp.name, refs
    tmp.cleanup()


@pytest.mark.parametrize("backend", ("np", "swar", "pallas"))
def test_fleet_serving_matches_predict_with_circuits(golden_fleet, backend):
    """The whole serving stack — manifest load, router, micro-batcher,
    backend dispatch through kernels.dispatch — must be label-transparent:
    every golden vector of every Table-2 dataset gets the exact
    `predict_with_circuits` label, per tenant, on np/swar/pallas."""
    from repro.serve import ClassifierFleet

    emit_dir, refs = golden_fleet
    fleet = ClassifierFleet.from_emit_dir(emit_dir, backends=backend,
                                          max_batch=64, deadline_ms=5_000.0)
    try:
        handles = {tenant: [fleet.submit(tenant, row) for row in x]
                   for tenant, (x, _) in sorted(refs.items())}
        fleet.flush(timeout=120)
        for tenant, (_, want) in refs.items():
            got = np.array([r.result(timeout=120) for r in handles[tenant]],
                           dtype=np.int32)
            np.testing.assert_array_equal(
                got, want, err_msg=f"fleet[{backend}] != "
                                   f"predict_with_circuits ({tenant})")
        assert fleet.errors == []
    finally:
        fleet.shutdown(drain=True)


# ---------------------------------------------------------------------------
# Hypothesis-driven variant (shrinks failures to minimal netlists)
# ---------------------------------------------------------------------------
try:
    from hypothesis import given, settings, strategies as st
    _HAVE_HYPOTHESIS = True
except ImportError:                                   # pragma: no cover
    _HAVE_HYPOTHESIS = False

if _HAVE_HYPOTHESIS:

    @settings(max_examples=N_EXAMPLES, deadline=None)
    @given(st.integers(1, 8), st.integers(0, 32), st.integers(1, 6),
           st.integers(1, 8), st.integers(1, 200), st.integers(0, 2**31 - 1))
    def test_hypothesis_netlists_all_backends_agree(n_in, n_gates, n_out,
                                                    P, S, seed):
        rng = np.random.default_rng(seed)
        n_out = min(n_out, n_in + n_gates)
        pop = C.random_netlist_population(rng, n_in, n_gates, n_out, P)
        assert_conformance(pop, _rand_bits(rng, S, n_in),
                           check_programs=False)
