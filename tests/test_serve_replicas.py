"""Replica pools, manifest hot-reload, and artifact-bundle integrity.

  * **property** — the least-loaded replica policy is pure counter
    bookkeeping (`ReplicaPool.acquire`/`release`), so hypothesis drives
    arbitrary acquire/release schedules through the production code:
    work conserving (an idle replica is always handed out, refusal only
    when all are busy), conservation (readings handed out are accounted
    exactly once; inflight returns to zero), and no starvation (under
    sustained balanced load every replica serves within one batch of its
    fair share).
  * **hot reload** — `sync_manifest()` add/replace/retire against a live
    fleet: queued requests survive a replace with their deadline clocks
    intact, in-flight batches finish on the old engines, retired tenants
    drain before vanishing.
  * **integrity** — `save_program` bundles carry a sha256 sidecar;
    truncation or a bit flip turns `load_program` into a clear
    `ArtifactCorruptError` (mirroring checkpoint/manager.py), and the
    manifest generation counter increments per register so a watcher can
    tell re-emits from no-ops.
"""
import os

import numpy as np
import pytest

from repro.compile import (ArtifactCorruptError, CircuitProgram,
                           load_manifest_doc, load_program, lower_classifier,
                           save_program, verify_program_bundle)
from repro.compile.verilog import write_artifacts
from repro.core import tnn as T
from repro.serve import ClassifierFleet, ReplicaPool, TenantSpec

N_EXAMPLES = int(os.environ.get("REPRO_CONFORMANCE_EXAMPLES", "20"))


def _toy_classifier(F=9, H=5, Cc=4, seed=7):
    rng = np.random.default_rng(seed)
    w1t = rng.integers(-1, 2, size=(F, H)).astype(np.int8)
    w2t = T.balance_zero_counts(rng.normal(size=(H, Cc)), 1 / 3)
    tnn = T.TrainedTNN(w1t=w1t, w2t=w2t, thresholds=np.full(F, 0.5),
                       train_acc=0.0, test_acc=0.0, name=f"toy{seed}")
    return lower_classifier(tnn, *T.exact_netlists(tnn))


def _pool(n: int, seed=7) -> ReplicaPool:
    prog = CircuitProgram.from_classifier(_toy_classifier(seed=seed))
    return ReplicaPool.from_program(prog, n, max_batch=32)


# ---------------------------------------------------------------------------
# Replica pool: the pick policy as pure logic
# ---------------------------------------------------------------------------
def test_pool_routes_least_loaded_and_refuses_only_when_saturated():
    pool = _pool(3)
    a = pool.acquire(10)
    b = pool.acquire(10)
    c = pool.acquire(10)
    assert {r.index for r in (a, b, c)} == {0, 1, 2}
    assert pool.acquire(1) is None          # saturated: refuse, don't stack
    pool.release(b)
    d = pool.acquire(4)                     # the only idle replica wins
    assert d is b
    pool.release(a), pool.release(c), pool.release(d)
    # now idle: least total readings (b: 14? no — b got 10+4) → a or c (10)
    e = pool.acquire(1)
    assert e.index == min(r.index for r in (a, c))
    pool.release(e)
    with pytest.raises(ValueError):
        pool.release(e)                     # double release


def test_pool_replicas_pin_round_robin_devices():
    import jax

    pool = _pool(4)
    n_dev = len(jax.local_devices())
    for r in pool.replicas:
        assert r.devices is not None and len(r.devices) == 1
        assert r.devices[0] == jax.local_devices()[r.index % n_dev]
    # np pools have no device placement
    prog = CircuitProgram.from_classifier(_toy_classifier(), backend="np")
    for r in ReplicaPool.from_program(prog, 2, max_batch=8).replicas:
        assert r.devices is None


try:
    from hypothesis import given, settings, strategies as st
    _HAVE_HYPOTHESIS = True
except ImportError:                                   # pragma: no cover
    _HAVE_HYPOTHESIS = False

if _HAVE_HYPOTHESIS:

    @settings(max_examples=N_EXAMPLES, deadline=None)
    @given(st.integers(1, 5),
           st.lists(st.one_of(st.integers(1, 64),      # acquire(n readings)
                              st.just("release")),     # release oldest held
                    max_size=80))
    def test_pool_work_conserving_and_balanced(n_replicas, ops):
        """Arbitrary acquire/release schedules: refusal iff saturated,
        accounting conserved, and—because ties rotate by index—no idle
        replica ever lags the pool by more than one batch of readings."""
        pool = _pool(n_replicas)
        held = []
        handed = n_acquired = 0
        for op in ops:
            if op == "release":
                if held:
                    pool.release(held.pop(0))
            else:
                rep = pool.acquire(op)
                if rep is None:
                    # work conserving: refusal only when all busy
                    assert all(r.inflight > 0 for r in pool.replicas)
                    continue
                # least-loaded: no *idle* replica had strictly less load
                idle_loads = [r.n_readings for r in pool.replicas
                              if r.inflight == 0]
                if idle_loads:
                    assert rep.n_readings - op <= min(idle_loads)
                handed += op
                n_acquired += 1
                held.append(rep)
        for rep in held:
            pool.release(rep)
        assert pool.idle()
        assert sum(r.n_readings for r in pool.replicas) == handed
        assert sum(r.n_dispatches for r in pool.replicas) == n_acquired

    @settings(max_examples=N_EXAMPLES, deadline=None)
    @given(st.integers(1, 4),
           st.lists(st.tuples(st.booleans(),            # acquire vs release
                              st.integers(1, 64),       # readings to charge
                              st.booleans()),           # release outcome
                    max_size=64))
    def test_release_outcome_credits_failed_dispatch(n_replicas, ops):
        """A failed dispatch did no useful work: releasing with ok=False
        credits its exact `n_readings` charge back (so the least-loaded
        pick keeps routing on *served* readings, not attempted ones) and
        bumps `n_errors`; counters never go negative and inflight always
        returns to zero."""
        pool = _pool(n_replicas)
        held = []                            # (replica, charge) FIFO
        served = failures = 0
        for is_acquire, n, ok in ops:
            if is_acquire or not held:
                rep = pool.acquire(n)
                if rep is not None:
                    held.append((rep, n))
            else:
                rep, charge = held.pop(0)
                pool.release(rep, n_readings=charge, ok=ok)
                if ok:
                    served += charge
                else:
                    failures += 1
            assert all(r.n_readings >= 0 for r in pool.replicas)
        for rep, charge in held:
            pool.release(rep, n_readings=charge, ok=True)
            served += charge
        assert pool.idle()
        assert sum(r.n_readings for r in pool.replicas) == served
        assert sum(r.n_errors for r in pool.replicas) == failures

    @settings(max_examples=N_EXAMPLES, deadline=None)
    @given(st.integers(1, 5), st.integers(1, 60))
    def test_pool_no_starvation_under_sequential_load(n_replicas, rounds):
        """Sequential unit batches with immediate release: every replica's
        share is within one dispatch of every other's — nobody starves."""
        pool = _pool(n_replicas)
        for _ in range(rounds):
            rep = pool.acquire(1)
            pool.release(rep)
        counts = [r.n_dispatches for r in pool.replicas]
        assert sum(counts) == rounds
        assert max(counts) - min(counts) <= 1


def test_fleet_spreads_batches_over_replicas():
    """Through the real scheduler: a burst of batches lands on every
    replica of the pool, not just replica 0."""
    prog = CircuitProgram.from_classifier(_toy_classifier())
    spec = TenantSpec(name="hot", program=prog, backend="swar", max_batch=8,
                      deadline_ms=60_000.0, replicas=3)
    fleet = ClassifierFleet([spec], warmup=False)
    x = np.random.default_rng(0).random((240, 9))
    try:
        reqs = [fleet.submit("hot", row) for row in x]
        fleet.flush(timeout=60.0)
        assert all(r.done() for r in reqs)
        counts = [rep.n_dispatches
                  for rep in fleet._tenant("hot").pool.replicas]
        assert sum(counts) == 240 // 8
        assert all(c > 0 for c in counts), counts
        ref = prog.predict(x)
        assert [r.label for r in reqs] == [int(v) for v in ref]
    finally:
        fleet.shutdown(drain=True)


# ---------------------------------------------------------------------------
# Hot reload: add / replace / retire on a live fleet
# ---------------------------------------------------------------------------
@pytest.fixture()
def emit_dir(tmp_path):
    write_artifacts(_toy_classifier(seed=7), tmp_path, base="alpha")
    write_artifacts(_toy_classifier(F=6, H=4, Cc=3, seed=11), tmp_path,
                    base="beta")
    return tmp_path


def test_sync_manifest_add_replace_retire_without_dropping_requests(
        emit_dir):
    # max_batch > the queued burst: nothing is due before the reload, so
    # every queued request must be served by the *successor* program
    fleet = ClassifierFleet.from_emit_dir(emit_dir, backends="swar",
                                          max_batch=64, deadline_ms=60_000.0)
    try:
        assert fleet.tenants == ["alpha", "beta"]
        gen0 = fleet._tenant("alpha").spec.generation

        # a no-op sync moves nothing
        actions = fleet.sync_manifest()
        assert actions["added"] == actions["replaced"] == \
            actions["retired"] == []

        # queue work against alpha, then replace it (same features, new
        # program) + add gamma + retire beta — all in one manifest move
        x = np.random.default_rng(1).random((24, 9))
        queued = [fleet.submit("alpha", row) for row in x]
        new_cc = _toy_classifier(seed=99)
        write_artifacts(new_cc, emit_dir, base="alpha")
        write_artifacts(_toy_classifier(F=12, H=6, Cc=5, seed=13), emit_dir,
                        base="gamma")
        import json
        mpath = emit_dir / "fleet.json"
        doc = json.loads(mpath.read_text())
        doc["tenants"] = [t for t in doc["tenants"] if t["name"] != "beta"]
        mpath.write_text(json.dumps(doc))

        actions = fleet.sync_manifest()
        assert actions == {"added": ["gamma"], "replaced": ["alpha"],
                           "retired": ["beta"],
                           "generation": actions["generation"]}
        assert fleet.tenants == ["alpha", "gamma"]
        assert fleet._tenant("alpha").spec.generation > gen0

        # queued alpha requests transferred to the successor and serve
        # with the *new* program — nothing dropped, nothing errored
        fleet.flush(timeout=60.0)
        new_ref = CircuitProgram.from_classifier(new_cc).predict(x)
        assert all(r.done() and r.error is None for r in queued)
        assert [r.label for r in queued] == [int(v) for v in new_ref]

        # the new tenant serves; the retired one refuses
        req = fleet.submit("gamma", np.zeros(12), deadline_ms=200.0)
        assert req.result(timeout=30.0) is not None
        with pytest.raises(KeyError):
            fleet.submit("beta", np.zeros(6))
        assert fleet.errors == []
    finally:
        fleet.shutdown(drain=True)


def test_retire_drains_backlog_before_vanishing(emit_dir):
    fleet = ClassifierFleet.from_emit_dir(emit_dir, backends="swar",
                                          max_batch=64,
                                          deadline_ms=60_000.0)
    try:
        x = np.random.default_rng(2).random((20, 6))
        reqs = [fleet.submit("beta", row) for row in x]
        fleet.retire_tenant("beta", timeout=30.0)
        assert all(r.done() and r.error is None for r in reqs)
        prog = fleet and reqs[0].label is not None
        assert prog
        with pytest.raises(KeyError):
            fleet.submit("beta", x[0])
        assert fleet.tenants == ["alpha"]
    finally:
        fleet.shutdown(drain=True)


def test_replace_with_incompatible_features_fails_queued_loudly(emit_dir):
    fleet = ClassifierFleet.from_emit_dir(emit_dir, backends="swar",
                                          max_batch=64, deadline_ms=60_000.0)
    try:
        x = np.random.default_rng(3).random((4, 9))
        queued = [fleet.submit("alpha", row) for row in x]
        # re-emit alpha with a different feature count
        write_artifacts(_toy_classifier(F=5, H=3, Cc=2, seed=21), emit_dir,
                        base="alpha")
        fleet.sync_manifest()
        for r in queued:
            assert r.done()
            with pytest.raises(RuntimeError, match="incompatible"):
                r.result(timeout=5.0)
        # the successor serves the new shape
        req = fleet.submit("alpha", np.zeros(5), deadline_ms=200.0)
        assert req.result(timeout=30.0) is not None
    finally:
        fleet.shutdown(drain=True)


def test_add_tenant_on_new_backend_spawns_worker(emit_dir):
    fleet = ClassifierFleet.from_emit_dir(emit_dir, backends="swar",
                                          tenants=["alpha"],
                                          max_batch=32, deadline_ms=500.0)
    try:
        assert set(fleet._workers) == {"swar"}
        prog = CircuitProgram.from_classifier(_toy_classifier(seed=31),
                                              backend="np")
        fleet.add_tenant(TenantSpec(name="cpu", program=prog, backend="np",
                                    max_batch=16, deadline_ms=500.0))
        assert set(fleet._workers) == {"np", "swar"}
        req = fleet.submit("cpu", np.zeros(9), deadline_ms=200.0)
        assert req.result(timeout=30.0) is not None
    finally:
        fleet.shutdown(drain=True)


# ---------------------------------------------------------------------------
# Bundle integrity + manifest generation counter
# ---------------------------------------------------------------------------
def test_manifest_generation_increments_per_register(tmp_path):
    write_artifacts(_toy_classifier(seed=7), tmp_path, base="a")
    doc = load_manifest_doc(tmp_path)
    assert doc["generation"] == 1
    assert doc["tenants"][0]["generation"] == 1
    write_artifacts(_toy_classifier(seed=8), tmp_path, base="b")
    write_artifacts(_toy_classifier(seed=9), tmp_path, base="a")  # re-emit
    doc = load_manifest_doc(tmp_path)
    assert doc["generation"] == 3
    gens = {t["name"]: t["generation"] for t in doc["tenants"]}
    assert gens == {"a": 3, "b": 2}
    assert all("sha256" in t and t["sha256"] for t in doc["tenants"])


def test_program_bundle_round_trips_with_checksum(tmp_path):
    cc = _toy_classifier(seed=7)
    path = tmp_path / "p.npz"
    save_program(cc, path)
    assert (tmp_path / "p.npz.sha256").exists()
    assert verify_program_bundle(path)
    prog = load_program(path)
    x = np.random.default_rng(0).random((32, 9))
    np.testing.assert_array_equal(
        prog.predict(x), CircuitProgram.from_classifier(cc).predict(x))


def test_truncated_bundle_fails_with_clear_error(tmp_path):
    cc = _toy_classifier(seed=7)
    path = tmp_path / "p.npz"
    save_program(cc, path)
    blob = path.read_bytes()
    path.write_bytes(blob[: len(blob) // 2])
    with pytest.raises(ArtifactCorruptError, match="checksum"):
        load_program(path)


def test_bitflipped_bundle_fails_with_clear_error(tmp_path):
    cc = _toy_classifier(seed=7)
    path = tmp_path / "p.npz"
    save_program(cc, path)
    blob = bytearray(path.read_bytes())
    blob[len(blob) // 3] ^= 0x40
    path.write_bytes(bytes(blob))
    with pytest.raises(ArtifactCorruptError, match="checksum"):
        load_program(path)


def test_missing_bundle_and_legacy_bundle_paths(tmp_path):
    with pytest.raises(ArtifactCorruptError, match="does not exist"):
        load_program(tmp_path / "nope.npz")
    # a pre-checksum bundle (no sidecar) still loads...
    cc = _toy_classifier(seed=7)
    path = tmp_path / "legacy.npz"
    save_program(cc, path)
    (tmp_path / "legacy.npz.sha256").unlink()
    assert verify_program_bundle(path) is None
    assert load_program(path).predict(np.zeros((1, 9))).shape == (1,)
    # ...but a *corrupt* legacy bundle still fails loudly, not deep in numpy
    blob = path.read_bytes()
    path.write_bytes(blob[: len(blob) // 2])
    with pytest.raises(ArtifactCorruptError, match="cannot be decoded"):
        load_program(path)


def test_tampered_manifest_row_sha_fails_tenant_load(emit_dir):
    """A manifest row whose sha256 disagrees with the bundle it references
    must refuse to serve — the sidecar alone can't catch a stale or
    swapped row (regression pin for the manifest/sidecar integrity gap)."""
    import json

    mpath = emit_dir / "fleet.json"
    doc = json.loads(mpath.read_text())
    for t in doc["tenants"]:
        if t["name"] == "alpha":
            t["sha256"] = "0" * 64          # plausible but wrong digest
    mpath.write_text(json.dumps(doc))
    with pytest.raises(ArtifactCorruptError, match="manifest"):
        ClassifierFleet.from_emit_dir(emit_dir, backends="np",
                                      warmup=False, autostart=False)
    # load_program cross-checks the external record even when the sidecar
    # itself is happy
    row = {t["name"]: t for t in doc["tenants"]}["alpha"]
    with pytest.raises(ArtifactCorruptError, match="stale or tampered"):
        load_program(emit_dir / row["program"], expect_sha256="0" * 64)


def test_sync_manifest_generation_rollback_restores_old_program(emit_dir):
    """A manifest whose generation *decreased* (emit dir restored from a
    backup) is honored: any generation difference — not just an increase —
    replaces the tenant, and the fleet adopts the older counter, so the
    serving state always converges to what the directory says."""
    backed_up = ("fleet.json", "alpha_program.npz",
                 "alpha_program.npz.sha256")
    backup = {f: (emit_dir / f).read_bytes() for f in backed_up}
    old_sha = {t["name"]: t for t in load_manifest_doc(emit_dir)
               ["tenants"]}["alpha"]["sha256"]
    fleet = ClassifierFleet.from_emit_dir(emit_dir, backends="np",
                                          warmup=False)
    try:
        write_artifacts(_toy_classifier(seed=19), emit_dir, base="alpha")
        new_doc = load_manifest_doc(emit_dir)
        assert fleet.sync_manifest()["replaced"] == ["alpha"]
        assert fleet.stats_summary()["manifest_generation"] == \
            new_doc["generation"]
        # ...now the directory is restored from backup: generation drops
        # (manifest *and* bundles — a restore brings back the whole dir)
        for f, blob in backup.items():
            (emit_dir / f).write_bytes(blob)
        old_doc = load_manifest_doc(emit_dir)
        assert old_doc["generation"] < new_doc["generation"]
        actions = fleet.sync_manifest()
        assert actions["replaced"] == ["alpha"]
        assert actions["generation"] == old_doc["generation"]
        t = fleet._tenant("alpha")
        old_row = {r["name"]: r for r in old_doc["tenants"]}["alpha"]
        assert t.spec.generation == old_row["generation"]
        assert t.spec.sha256 == old_sha
        assert fleet.stats_summary()["manifest_generation"] == \
            old_doc["generation"]
        # the restored program serves (and is the *old* bits)
        x = np.random.default_rng(5).random((4, 9))
        reqs, _, _ = fleet.submit_many("alpha", x)
        fleet.flush()
        ref = CircuitProgram.from_classifier(_toy_classifier(seed=7))
        np.testing.assert_array_equal([r.result(5.0) for r in reqs],
                                      ref.predict(x))
    finally:
        fleet.shutdown(drain=False)


def test_stats_surface_deploy_identity(emit_dir):
    """Per-tenant artifact sha256 + fleet manifest generation in stats."""
    doc = load_manifest_doc(emit_dir)
    rows = {t["name"]: t for t in doc["tenants"]}
    fleet = ClassifierFleet.from_emit_dir(emit_dir, backends="np",
                                          warmup=False, autostart=False)
    try:
        s = fleet.stats_summary()
        assert s["manifest_generation"] == doc["generation"]
        for name in ("alpha", "beta"):
            assert s["tenants"][name]["sha256"] == rows[name]["sha256"]
            assert len(s["tenants"][name]["sha256"]) == 64
    finally:
        fleet.shutdown(drain=False)
