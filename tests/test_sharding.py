"""Static sharding validation — catches divisibility/partition bugs for all
40 dry-run cells WITHOUT compiling (the fast guard in front of dryrun.py).
"""
import jax
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import ARCHS, SHAPES, get_config, shape_applicable
from repro.models import transformer as TF
from repro.models.params import (abstract_params, param_defs, partition_specs,
                                 is_def)

AXIS_SIZES = {"data": 16, "model": 16, "pod": 2}


def _check_divisible(shape, spec, where):
    assert len(spec) <= len(shape), f"{where}: spec longer than shape"
    for dim, ax in zip(shape, tuple(spec)):
        if ax is None:
            continue
        axes = ax if isinstance(ax, tuple) else (ax,)
        size = int(np.prod([AXIS_SIZES[a] for a in axes]))
        assert dim % size == 0, (f"{where}: dim {dim} not divisible by "
                                 f"{axes} (={size})")


@pytest.mark.parametrize("arch", ARCHS)
def test_param_specs_divisible(arch):
    cfg = get_config(arch)
    defs = param_defs(cfg, model_axis_size=16)
    leaves = jax.tree_util.tree_flatten_with_path(
        defs, is_leaf=is_def)[0]
    for kp, d in leaves:
        _check_divisible(d.shape, d.spec, f"{arch}{jax.tree_util.keystr(kp)}")


@pytest.mark.parametrize("arch", ARCHS)
def test_specs_tree_congruent(arch):
    cfg = get_config(arch)
    params = abstract_params(cfg)
    specs = partition_specs(cfg)
    s1 = jax.tree_util.tree_structure(params)
    s2 = jax.tree_util.tree_structure(
        specs, is_leaf=lambda s: isinstance(s, P))
    assert s1 == s2


@pytest.mark.parametrize("arch", ARCHS)
@pytest.mark.parametrize("shape_name", list(SHAPES))
def test_cache_specs_divisible(arch, shape_name):
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    if shape.kind != "decode":
        pytest.skip("train/prefill cells have no cache")
    ok, _ = shape_applicable(cfg, shape)
    if not ok:
        pytest.skip("long_500k inapplicable for pure full-attention")
    cache = jax.eval_shape(
        lambda: TF.init_cache(cfg, shape.global_batch, shape.seq_len))
    specs = TF.cache_partition_specs(cfg, shape.global_batch, shape.seq_len,
                                     data_size=16, model_size=16)
    for key, struct in cache.items():
        _check_divisible(struct.shape, specs[key],
                         f"{arch}/{shape_name}/cache[{key}]")


def test_all_cells_enumerate_40():
    cells = [(a, s) for a in ARCHS for s in SHAPES]
    assert len(cells) == 40
    skips = [c for c in cells
             if not shape_applicable(get_config(c[0]), SHAPES[c[1]])[0]]
    # 7 pure full-attention archs skip long_500k (DESIGN.md)
    assert len(skips) == 7
    assert all(s == "long_500k" for _, s in skips)
