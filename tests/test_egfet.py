"""EGFET cost model: printed power-source budget boundaries and
sensor-interface costs for both converter kinds."""
import pytest

from repro.hw.egfet import (ABC_AREA_MM2, ABC_POWER_MW, ADC4_AREA_MM2,
                            ADC4_POWER_MW, HARVESTER_BUDGET_MW,
                            MOLEX_BATTERY_MW, ZINERGY_BATTERY_MW,
                            interface_cost, power_source)


@pytest.mark.parametrize("power_mw,source", [
    (0.0, "energy-harvester"),
    (HARVESTER_BUDGET_MW, "energy-harvester"),          # inclusive boundary
    (HARVESTER_BUDGET_MW + 1e-9, "zinergy-battery"),
    (ZINERGY_BATTERY_MW, "zinergy-battery"),            # inclusive boundary
    (ZINERGY_BATTERY_MW + 1e-9, "molex-battery"),
    (MOLEX_BATTERY_MW, "molex-battery"),                # inclusive boundary
    (MOLEX_BATTERY_MW + 1e-9, "exceeds-printed-budget"),
    (1e6, "exceeds-printed-budget"),
])
def test_power_source_budget_boundaries(power_mw, source):
    assert power_source(power_mw) == source


@pytest.mark.parametrize("n", [0, 1, 10, 274])
def test_interface_cost_scales_per_feature(n):
    adc = interface_cost(n, "adc4")
    assert adc.area_mm2 == pytest.approx(ADC4_AREA_MM2 * n)
    assert adc.power_mw == pytest.approx(ADC4_POWER_MW * n)
    abc = interface_cost(n, "abc")
    assert abc.area_mm2 == pytest.approx(ABC_AREA_MM2 * n)
    assert abc.power_mw == pytest.approx(ABC_POWER_MW * n)
    if n:
        # the whole point of the paper's ABC: orders of magnitude cheaper
        assert abc.area_mm2 < adc.area_mm2 / 100
        assert abc.power_mw < adc.power_mw / 30


def test_interface_cost_unknown_kind_raises():
    with pytest.raises(ValueError, match="unknown interface kind"):
        interface_cost(10, "dac")
    with pytest.raises(ValueError, match="unknown interface kind"):
        interface_cost(10, "ABC")     # kinds are case-sensitive
