"""Fault-tolerance behaviours: straggler detection, preemption checkpoint,
elastic restore across different mesh topologies (subprocess: own device
count)."""
import json
import os
import subprocess
import sys
import textwrap
import time

import jax
import numpy as np
import pytest

from repro.train.loop import StepStats


def test_straggler_detection_flags_slow_steps():
    stats = StepStats()
    flagged = []
    for step in range(20):
        dt = 1.0 if step != 15 else 5.0       # one 5x straggler
        if stats.record(step, dt, factor=3.0):
            flagged.append(step)
    assert flagged == [15]
    assert stats.stragglers[0][0] == 15


def test_straggler_needs_history():
    stats = StepStats()
    # first few steps never flag (no stable median yet)
    assert not stats.record(0, 100.0, factor=3.0)


@pytest.mark.slow
def test_elastic_restore_across_topologies(tmp_path):
    """Save on a (2,2) mesh, restore on a (4,1) mesh — different shard
    layout, same logical arrays.  Runs in subprocesses so each side owns
    its XLA device count."""
    script_save = textwrap.dedent(f"""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.checkpoint import CheckpointManager
        from repro.launch.mesh import make_mesh_compat
        mesh = make_mesh_compat((2, 2), ("data", "model"))
        w = jax.device_put(jnp.arange(64, dtype=jnp.float32).reshape(8, 8),
                           NamedSharding(mesh, P("data", "model")))
        cm = CheckpointManager({str(tmp_path)!r})
        cm.save(5, {{"w": w}})
        print("SAVED")
    """)
    script_load = textwrap.dedent(f"""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.checkpoint import CheckpointManager
        from repro.launch.mesh import make_mesh_compat
        mesh = make_mesh_compat((4, 1), ("data", "model"))
        cm = CheckpointManager({str(tmp_path)!r})
        step, state, _ = cm.restore({{"w": jnp.zeros((8, 8), jnp.float32)}},
                                    mesh=mesh,
                                    specs={{"w": P("data", "model")}})
        assert step == 5
        w = state["w"]
        assert len(w.sharding.device_set) == 4
        np.testing.assert_array_equal(
            np.asarray(w), np.arange(64, dtype=np.float32).reshape(8, 8))
        print("RESTORED-ELASTIC")
    """)
    env = dict(os.environ, PYTHONPATH="src")
    for script, marker in [(script_save, "SAVED"),
                           (script_load, "RESTORED-ELASTIC")]:
        out = subprocess.run([sys.executable, "-c", script], env=env,
                             capture_output=True, text=True, timeout=300,
                             cwd=os.path.dirname(os.path.dirname(
                                 os.path.abspath(__file__))))
        assert marker in out.stdout, out.stderr[-2000:]


def test_preemption_checkpoint(tmp_path):
    """SIGTERM-equivalent: the trainer's preempt flag forces a checkpoint."""
    from repro.configs import get_config
    from repro.data.tokens import TokenPipeline, TokenPipelineConfig
    from repro.models.params import init_params
    from repro.optim import adamw
    from repro.optim.adamw import AdamWConfig
    from repro.train.loop import Trainer, TrainLoopConfig

    cfg = get_config("llama3.2-1b").reduced()
    pipe = TokenPipeline(TokenPipelineConfig(vocab=cfg.vocab, seq_len=16,
                                             global_batch=2, seed=0))
    tr = Trainer(cfg, TrainLoopConfig(total_steps=50, ckpt_every=100,
                                      optimizer=AdamWConfig(lr=1e-3)),
                 pipe, str(tmp_path))
    params = init_params(jax.random.PRNGKey(0), cfg)

    # trip the preemption flag after the second step via the log hook
    calls = []

    def log(msg):
        calls.append(msg)

    orig_record = tr.stats.record

    def record_and_preempt(step, dt, factor):
        if step >= 1:
            tr._preempted = True
        return orig_record(step, dt, factor)

    tr.stats.record = record_and_preempt
    _, _, result = tr.run(params, adamw.init(params), log=log)
    assert result["last_step"] < 50                  # stopped early
    assert tr.ckpt.latest_step() == result["last_step"]
    _, _, extra = tr.ckpt.restore(
        {"params": params, "opt": adamw.init(params)})
    assert extra.get("preempted") is True
