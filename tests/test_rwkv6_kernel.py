"""rwkv6_scan Pallas kernel vs the sequential oracle (interpret mode)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

RNG = np.random.default_rng(7)


def _inputs(BH, T, dh, w_lo=0.85):
    r = jnp.asarray(RNG.normal(0, 1, (BH, T, dh)), jnp.float32)
    k = jnp.asarray(RNG.normal(0, 1, (BH, T, dh)), jnp.float32)
    v = jnp.asarray(RNG.normal(0, 1, (BH, T, dh)), jnp.float32)
    w = jnp.asarray(RNG.uniform(w_lo, 0.999, (BH, T, dh)), jnp.float32)
    u = jnp.asarray(RNG.normal(0, 0.5, (BH, dh)), jnp.float32)
    return r, k, v, w, u


@pytest.mark.parametrize("BH,T,dh,chunk", [(2, 64, 16, 16), (4, 128, 32, 32),
                                           (1, 96, 8, 32), (3, 64, 64, 64)])
def test_chunked_matches_sequential(BH, T, dh, chunk):
    r, k, v, w, u = _inputs(BH, T, dh)
    y_k, s_k = ops.rwkv6_scan(r, k, v, w, u, chunk=chunk,
                              use_kernel=True, interpret=True)
    y_r, s_r = ref.rwkv6_scan_ref(r, k, v, w, u)
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_r),
                               rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(np.asarray(s_k), np.asarray(s_r),
                               rtol=2e-3, atol=2e-3)


def test_state_carry_across_chunks():
    """Chunk boundaries must be invisible: chunk=T/4 vs chunk=T agree."""
    r, k, v, w, u = _inputs(2, 64, 16)
    y_a, s_a = ops.rwkv6_scan(r, k, v, w, u, chunk=16, use_kernel=True,
                              interpret=True)
    y_b, s_b = ops.rwkv6_scan(r, k, v, w, u, chunk=64, use_kernel=True,
                              interpret=True)
    np.testing.assert_allclose(np.asarray(y_a), np.asarray(y_b),
                               rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(np.asarray(s_a), np.asarray(s_b),
                               rtol=2e-3, atol=2e-3)


def test_decay_actually_decays():
    """With strong decay and zero u, late outputs forget early tokens."""
    BH, T, dh = 1, 32, 8
    r = jnp.ones((BH, T, dh), jnp.float32)
    k = jnp.zeros((BH, T, dh), jnp.float32).at[:, 0].set(1.0)  # one impulse
    v = jnp.ones((BH, T, dh), jnp.float32)
    w = jnp.full((BH, T, dh), 0.5, jnp.float32)
    u = jnp.zeros((BH, dh), jnp.float32)
    y, _ = ops.rwkv6_scan(r, k, v, w, u, chunk=8, use_kernel=True,
                          interpret=True)
    mag = np.abs(np.asarray(y[0, :, 0]))
    assert mag[1] > mag[8] > mag[16]          # geometric forgetting
