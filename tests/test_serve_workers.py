"""Fleet-controller tier: QoS admission, token buckets, autoscaler, workers.

Four layers of pinning:

  * **control law** — `TokenBucket` and `Autoscaler` are pure logic over
    injected clocks/round counters, so grow-after-sustained-pressure,
    shrink-after-idle, cooldown, min/max bounds and shadow immunity are
    all stepped to a decision in a bounded, known number of rounds.
  * **admission** — QoS classes and per-tenant rate limits gate
    `submit`/`submit_many` deterministically (fake fleet clock, workers
    parked): best-effort gives way to backend backlog while guaranteed
    traffic keeps admitting, malformed deadline tables reject the whole
    frame before any state changes, and under live synthetic overload
    guaranteed tenants finish with zero SLO misses while best-effort
    sheds absorb the pressure.
  * **autoscaling end-to-end** — a live fleet with `autoscale_tick()`
    driven manually (interval 0 ⇒ no background thread) grows a hot
    tenant to its ceiling and shrinks it back to the floor once drained,
    with no wall-clock dependence; shadows are never resized.
  * **worker processes** — a `WorkerHost` serves labels bit-identical to
    the offline `CircuitProgram.predict` over shared-memory planes,
    propagates engine errors as `WorkerError`, and survives a killed
    worker (pendings fail fast, the proc respawns with tenants intact);
    a fleet in `workers=N` mode stays bit-identical through the full
    scheduler path.

Hypothesis drives protocol-v2 deadline tables (NaN / scalar / per-row
mixes) end-to-end through encode → decode → `fleet.submit_many`,
asserting tail-shed ordering, shed accounting and `retry_after_ms`
consistency.  Example count follows REPRO_CONFORMANCE_EXAMPLES.
"""
import os
import threading
import time

import numpy as np
import pytest

from repro.compile import CircuitProgram, lower_classifier
from repro.core import tnn as T
from repro.serve import (AutoscaleConfig, Autoscaler, ClassifierFleet,
                         FleetOverloadError, MicroBatcher, TenantSignals,
                         TenantSpec, TokenBucket, WorkerError, WorkerHost)
from repro.serve import protocol as P

N_EXAMPLES = int(os.environ.get("REPRO_CONFORMANCE_EXAMPLES", "20"))
F = 9       # toy tenant feature count


def _toy_classifier(seed=7, H=5, Cc=4):
    rng = np.random.default_rng(seed)
    w1t = rng.integers(-1, 2, size=(F, H)).astype(np.int8)
    w2t = T.balance_zero_counts(rng.normal(size=(H, Cc)), 1 / 3)
    tnn = T.TrainedTNN(w1t=w1t, w2t=w2t, thresholds=np.full(F, 0.5),
                       train_acc=0.0, test_acc=0.0, name=f"toy{seed}")
    return lower_classifier(tnn, *T.exact_netlists(tnn))


@pytest.fixture(scope="module")
def prog():
    return CircuitProgram.from_classifier(_toy_classifier(), backend="np")


def _spec(prog, name="toy", **kw):
    kw.setdefault("backend", "np")
    kw.setdefault("max_batch", 8)
    kw.setdefault("deadline_ms", 50.0)
    return TenantSpec(name=name, program=prog, **kw)


class _Clock:
    """Injectable fleet clock; tests advance `t` explicitly."""

    def __init__(self):
        self.t = 0.0

    def __call__(self) -> float:
        return self.t


class _SlowProgram:
    """Delegating program wrapper: every dispatch costs `delay_s` —
    synthetic overload without timing-sensitive producers."""

    def __init__(self, inner, delay_s):
        self._inner = inner
        self._delay_s = delay_s

    def __getattr__(self, name):
        return getattr(self._inner, name)

    def predict(self, x):
        time.sleep(self._delay_s)
        return self._inner.predict(x)


# ---------------------------------------------------------------------------
# Token bucket: pure clock-injected logic
# ---------------------------------------------------------------------------
def test_token_bucket_grants_refills_and_hints():
    b = TokenBucket(10.0, 5.0, now=0.0)
    assert b.take_upto(3, 0.0) == 3          # starts full
    assert b.take_upto(10, 0.0) == 2         # partial grant, never negative
    assert b.take_upto(1, 0.0) == 0
    assert 0.0 < b.retry_after_s(1, 0.0) <= 0.1 + 1e-9
    assert b.take_upto(1, 0.11) == 1         # refilled at `rate`/s
    assert b.tokens(1e9) == 5.0              # capped at burst
    assert b.take_upto(0, 0.0) == 0
    assert b.retry_after_s(1, 1e9) == 0.0    # already available: no wait
    with pytest.raises(ValueError):
        TokenBucket(0.0, 5.0)
    with pytest.raises(ValueError):
        TokenBucket(1.0, 0.5)


def test_token_bucket_clock_never_runs_backwards():
    b = TokenBucket(1.0, 4.0, now=10.0)
    assert b.take_upto(4, 10.0) == 4
    assert b.take_upto(1, 9.0) == 0          # stale `now` cannot refill
    assert b.take_upto(1, 11.0) == 1


# ---------------------------------------------------------------------------
# Autoscaler: the round-based control law
# ---------------------------------------------------------------------------
def _sig(name, **kw):
    base = dict(pool_size=1, queue_depth=0, inflight=0, shed_delta=0,
                request_delta=0, est_dispatch_ms=0.1, max_batch=32,
                max_queue=64, min_replicas=1, max_replicas=4)
    base.update(kw)
    return TenantSignals(name=name, **base)


def test_autoscaler_grows_after_up_rounds_then_cools_down():
    a = Autoscaler(AutoscaleConfig(up_rounds=2, down_rounds=3,
                                   cooldown_rounds=1))
    assert a.observe([_sig("t", shed_delta=5)]) == []       # round 1 of 2
    acts = a.observe([_sig("t", shed_delta=5)])
    assert [(x.delta, x.reason) for x in acts] == [(1, "pressure")]
    # refractory round: pressure is ignored, counters reset
    assert a.observe([_sig("t", shed_delta=5, pool_size=2)]) == []
    assert a.observe([_sig("t", shed_delta=5, pool_size=2)]) == []
    acts = a.observe([_sig("t", shed_delta=5, pool_size=2)])
    assert acts and acts[0].delta == 1


def test_autoscaler_pressure_sources_queue_and_cost():
    cfg = AutoscaleConfig(up_rounds=1, cooldown_rounds=0, cost_high_ms=5.0)
    a = Autoscaler(cfg)
    # queue past queue_high_frac of capacity counts as pressure
    acts = a.observe([_sig("q", queue_depth=40, max_queue=64)])
    assert acts and acts[0].reason == "pressure"
    # dispatch-cost EMA past cost_high_ms counts as pressure
    acts = a.observe([_sig("c", est_dispatch_ms=9.0)])
    assert [x.name for x in acts] == ["c"]


def test_autoscaler_shrinks_only_after_sustained_idle():
    a = Autoscaler(AutoscaleConfig(up_rounds=1, down_rounds=2,
                                   cooldown_rounds=0))
    # busy-but-not-pressured rounds reset both hysteresis counters
    a.observe([_sig("t", pool_size=2, request_delta=3)])
    assert a.observe([_sig("t", pool_size=2)]) == []        # idle 1 of 2
    a.observe([_sig("t", pool_size=2, request_delta=1)])    # reset
    assert a.observe([_sig("t", pool_size=2)]) == []        # idle 1 of 2
    acts = a.observe([_sig("t", pool_size=2)])
    assert [(x.delta, x.reason) for x in acts] == [(-1, "idle")]


def test_autoscaler_respects_min_max_bounds():
    a = Autoscaler(AutoscaleConfig(up_rounds=1, down_rounds=1,
                                   cooldown_rounds=0))
    # at the ceiling: pressure decides nothing
    assert a.observe([_sig("t", shed_delta=9, pool_size=4,
                           max_replicas=4)]) == []
    # at the floor: idle decides nothing
    assert a.observe([_sig("t", pool_size=2, min_replicas=2)]) == []
    # grow is clamped to the remaining headroom
    a2 = Autoscaler(AutoscaleConfig(up_rounds=1, cooldown_rounds=0,
                                    grow_step=4))
    acts = a2.observe([_sig("t", shed_delta=9, pool_size=3, max_replicas=4)])
    assert [x.delta for x in acts] == [1]


def test_autoscaler_never_scales_shadows_and_drops_vanished_state():
    a = Autoscaler(AutoscaleConfig(up_rounds=1, cooldown_rounds=0))
    for _ in range(4):
        assert a.observe([_sig("sh", shed_delta=99, is_shadow=True)]) == []
    assert a.summary()["tracked"] == []
    a.observe([_sig("t", shed_delta=5)])
    assert a.summary()["tracked"] == ["t"]
    a.observe([])                            # tenant retired between rounds
    assert a.summary()["tracked"] == []


def test_autoscale_config_validates():
    for bad in (dict(up_rounds=0), dict(down_rounds=0),
                dict(cooldown_rounds=-1), dict(grow_step=0),
                dict(queue_high_frac=0.0), dict(queue_high_frac=1.5)):
        with pytest.raises(ValueError):
            AutoscaleConfig(**bad)


# ---------------------------------------------------------------------------
# Admission: rate limits + QoS, deterministic (fake clock, parked workers)
# ---------------------------------------------------------------------------
def test_rate_limit_gates_admission_under_fake_clock(prog):
    clk = _Clock()
    spec = _spec(prog, rate_limit_rps=10.0, rate_burst=4.0, max_queue=None)
    fleet = ClassifierFleet([spec], warmup=False, autostart=False, clock=clk)
    x = np.zeros((6, F))
    reqs, shed, retry = fleet.submit_many("toy", x)
    assert len(reqs) == 4                    # burst grants the head...
    assert shed.tolist() == [4, 5]           # ...and the tail sheds
    assert retry > 0.0
    with pytest.raises(FleetOverloadError) as ei:
        fleet.submit("toy", x[0])            # bucket is dry
    assert ei.value.reason == "rate" and ei.value.retry_after_ms >= 1.0
    clk.t = 0.5                              # 10 rps * 0.5 s = 5, cap 4
    reqs2, shed2, _ = fleet.submit_many("toy", x)
    assert len(reqs2) == 4 and shed2.tolist() == [4, 5]
    s = fleet.stats_summary()
    assert s["tenants"]["toy"]["n_shed"] == 5 == s["fleet"]["n_shed"]
    assert s["tenants"]["toy"]["rate_limit_rps"] == 10.0


def test_best_effort_gives_way_to_backend_backlog(prog):
    gold = _spec(prog, "gold", qos="guaranteed", max_queue=64)
    cheap = _spec(prog, "cheap", qos="best_effort", max_queue=64)
    fleet = ClassifierFleet([gold, cheap], warmup=False, autostart=False,
                            best_effort_backlog=4)
    x = np.zeros(F)
    for _ in range(3):                       # below threshold: both admit
        fleet.submit("gold", x)
    fleet.submit("cheap", x)
    with pytest.raises(FleetOverloadError) as ei:
        fleet.submit("cheap", x)             # backlog hit 4: give way
    assert ei.value.reason == "qos"
    reqs, shed, retry = fleet.submit_many("cheap", np.zeros((3, F)))
    assert reqs == [] and shed.tolist() == [0, 1, 2] and retry > 0
    fleet.submit("gold", x)                  # guaranteed keeps admitting
    s = fleet.stats_summary()
    assert s["tenants"]["cheap"]["n_shed"] == 4
    assert s["tenants"]["gold"]["n_shed"] == 0
    assert s["tenants"]["gold"]["qos"] == "guaranteed"
    assert s["tenants"]["cheap"]["qos"] == "best_effort"


def test_qos_class_and_bound_validation(prog):
    with pytest.raises(ValueError, match="qos"):
        ClassifierFleet([_spec(prog, qos="platinum")], warmup=False,
                        autostart=False)
    with pytest.raises(ValueError, match="min_replicas"):
        ClassifierFleet([_spec(prog, min_replicas=0)], warmup=False,
                        autostart=False)
    with pytest.raises(ValueError, match="max_replicas"):
        ClassifierFleet([_spec(prog, min_replicas=2, max_replicas=1)],
                        warmup=False, autostart=False)


def test_guaranteed_zero_slo_miss_while_best_effort_sheds():
    """Acceptance: under live synthetic overload, best-effort absorbs the
    sheds and every guaranteed request is served in budget."""
    cc = _toy_classifier()
    gprog = CircuitProgram.from_classifier(cc, backend="np")
    ref = CircuitProgram.from_classifier(cc).predict
    bprog = CircuitProgram.from_classifier(_toy_classifier(seed=11),
                                           backend="np")
    deadline_ms = 20_000.0
    gold = TenantSpec(name="gold", program=gprog, backend="np", max_batch=8,
                      deadline_ms=deadline_ms, qos="guaranteed")
    cheap = TenantSpec(name="cheap", program=bprog, backend="np",
                       max_batch=8, deadline_ms=deadline_ms,
                       max_queue=64, qos="best_effort")
    fleet = ClassifierFleet([gold, cheap], warmup=False, autostart=False,
                            best_effort_backlog=4)
    for name in ("gold", "cheap"):
        for rep in fleet._tenant(name).pool.replicas:
            rep.engine.program = _SlowProgram(rep.engine.program, 0.01)
    fleet.start()
    x = np.random.default_rng(7).random(F)
    want = int(ref(x[None, :])[0])
    g_reqs, cheap_sheds = [], 0
    try:
        for _ in range(120):
            g_reqs.append(fleet.submit("gold", x))
            try:
                fleet.submit("cheap", x)
            except FleetOverloadError as exc:
                assert exc.reason in ("qos", "queue")
                assert exc.retry_after_ms >= 1.0
                cheap_sheds += 1
        for r in g_reqs:                     # guaranteed: all served, right
            assert r.result(timeout=120.0) == want
    finally:
        fleet.shutdown(drain=True)
    s = fleet.stats_summary()
    assert cheap_sheds > 0, "overload never shed best-effort traffic"
    assert len(g_reqs) == 120                # guaranteed never shed
    assert s["tenants"]["gold"]["n_shed"] == 0
    assert s["tenants"]["gold"]["n_slo_miss"] == 0
    assert s["tenants"]["cheap"]["n_shed"] == cheap_sheds


# ---------------------------------------------------------------------------
# All-or-nothing frame admission (the validation-ordering regression)
# ---------------------------------------------------------------------------
def test_batcher_validates_whole_deadline_table_before_enqueue():
    mb = MicroBatcher(8, 20.0)
    mb.submit("keep", now=0.0)
    with pytest.raises(ValueError, match="deadline budget must be positive"):
        mb.submit_many(["a", "b", "c"], now=0.0,
                       deadlines_ms=[50.0, 30.0, -1.0])
    # the bad tail row must not leave earlier rows enqueued
    assert len(mb) == 1 and next(iter(mb)).item == "keep"
    entries = mb.submit_many(["a", "b"], now=0.0,
                             deadlines_ms=[float("nan"), 30.0])
    assert [e.deadline_s for e in entries] == pytest.approx([0.020, 0.030])


def test_fleet_submit_many_rejects_malformed_frames_whole(prog):
    fleet = ClassifierFleet([_spec(prog, max_queue=32)], warmup=False,
                            autostart=False)
    x = np.zeros((4, F))
    for bad in ([50.0, -1.0, 30.0, 20.0], 0.0, float("-inf")):
        with pytest.raises(ValueError, match="rejected whole"):
            fleet.submit_many("toy", x, deadlines_ms=bad)
    s = fleet.stats_summary()
    assert s["tenants"]["toy"]["pending"] == 0       # nothing enqueued
    assert s["fleet"]["n_shed"] == 0                 # nothing shed-counted
    reqs, shed, _ = fleet.submit_many("toy", x)
    assert len(reqs) == 4 and shed.size == 0
    assert reqs[0].uid == 0                          # no uids leaked


# ---------------------------------------------------------------------------
# Fleet autoscaling end-to-end: manual ticks, zero wall-clock dependence
# ---------------------------------------------------------------------------
def test_fleet_autoscaler_grows_hot_tenant_and_shrinks_idle(prog):
    cfg = AutoscaleConfig(up_rounds=2, down_rounds=2, cooldown_rounds=0)
    spec = _spec(prog, max_queue=4, replicas=1, max_replicas=3)
    fleet = ClassifierFleet([spec], warmup=False, autoscale=cfg,
                            autoscale_interval_s=0.0)    # no tick thread
    try:
        x = np.random.default_rng(0).normal(size=(64, F))
        for _ in range(2):                   # 64 rows into a 4-deep queue
            fleet.submit_many("toy", x)      # → sheds every round
            fleet.autoscale_tick()
        assert fleet.tenant_replicas("toy") == 2
        events = fleet.autoscale_events
        assert events and events[-1]["reason"] == "pressure"
        assert events[-1]["tenant"] == "toy" and events[-1]["applied"] == 1
        for _ in range(4):                   # two more hot rounds → ceiling
            fleet.submit_many("toy", x)
            fleet.autoscale_tick()
        assert fleet.tenant_replicas("toy") == 3     # capped at max_replicas
        fleet.flush()                        # drain; then idle rounds shrink
        for _ in range(8):
            fleet.autoscale_tick()
        assert fleet.tenant_replicas("toy") == 1     # back to the floor
        assert any(e["reason"] == "idle" for e in fleet.autoscale_events)
        s = fleet.stats_summary()
        assert s["autoscale"]["events"]              # surfaced to operators
        assert s["tenants"]["toy"]["pool_size"] == 1
    finally:
        fleet.shutdown(drain=False)


def test_fleet_autoscaler_never_scales_shadows(prog):
    shadow_prog = CircuitProgram.from_classifier(_toy_classifier(seed=11),
                                                 backend="np")
    cfg = AutoscaleConfig(up_rounds=1, cooldown_rounds=0)
    spec = _spec(prog, max_queue=4, max_replicas=3)
    fleet = ClassifierFleet([spec], warmup=False, autoscale=cfg,
                            autoscale_interval_s=0.0)
    try:
        fleet.deploy_shadow(_spec(shadow_prog, "toy-next", max_queue=4,
                                  max_replicas=3), of="toy")
        x = np.random.default_rng(1).normal(size=(64, F))
        for _ in range(3):                   # mirrored overload every round
            fleet.submit_many("toy", x)
            fleet.autoscale_tick()
        assert fleet.tenant_replicas("toy") == 3     # incumbent grew
        assert fleet._shadows["toy"].pool.size == 1  # shadow untouched
        assert all(e["tenant"] != "toy-next"
                   for e in fleet.autoscale_events)
    finally:
        fleet.shutdown(drain=False)


# ---------------------------------------------------------------------------
# stats_summary: consistent snapshots under concurrent admission
# ---------------------------------------------------------------------------
def test_stats_summary_consistent_under_concurrent_sheds(prog):
    specs = [_spec(prog, f"t{i}", max_queue=8) for i in range(3)]
    fleet = ClassifierFleet(specs, warmup=False)
    stop = threading.Event()

    def blast(name, seed):
        x = np.random.default_rng(seed).normal(size=(32, F))
        while not stop.is_set():
            fleet.submit_many(name, x)       # sheds return, never raise

    threads = [threading.Thread(target=blast, args=(s.name, i), daemon=True)
               for i, s in enumerate(specs)]
    for th in threads:
        th.start()
    try:
        torn = []
        for _ in range(200):
            snap = fleet.stats_summary()
            total = snap["fleet"]["n_shed"]
            per = sum(row["n_shed"] for row in snap["tenants"].values())
            if total != per:
                torn.append((total, per))
            for row in snap["tenants"].values():
                assert row["pending"] <= row["max_queue"]
        assert not torn, f"fleet/tenant shed totals disagreed: {torn[:5]}"
    finally:
        stop.set()
        for th in threads:
            th.join(timeout=10.0)
        fleet.shutdown(drain=False)


# ---------------------------------------------------------------------------
# Worker processes: shared-memory dispatch, faults, respawn
# ---------------------------------------------------------------------------
def test_worker_host_bit_identity_errors_and_respawn(prog):
    host = WorkerHost("np", 2, slab_bytes=1 << 16)
    host.start()
    try:
        host.load("toy#1", prog, 32)
        assert host.warmup("toy#1") > 0.0
        rng = np.random.default_rng(3)
        x = rng.normal(size=(24, F))
        want = prog.predict(x)
        np.testing.assert_array_equal(host.eval("toy#1", x), want)
        with pytest.raises(WorkerError, match="not loaded"):
            host.eval("nope#0", x)           # engine errors come back typed
        # kill one worker: the proc respawns with its tenants reloaded and
        # keeps answering bit-identically
        host._procs[0].process.terminate()
        host._procs[0].process.join(timeout=10.0)
        deadline = time.monotonic() + 30.0
        while host.n_respawns == 0 and time.monotonic() < deadline:
            time.sleep(0.05)
        assert host.n_respawns >= 1
        for _ in range(4):                   # lands on both procs
            np.testing.assert_array_equal(host.eval("toy#1", x), want)
        s = host.summary()
        assert s["n_evals"] >= 5 and s["tenants"] == ["toy#1"]
        assert all(p["alive"] for p in s["procs"])
        host.unload("toy#1")
        assert host.summary()["tenants"] == []
    finally:
        host.close()


def test_fleet_worker_mode_bit_identity(prog):
    spec = _spec(prog, max_batch=16)
    fleet = ClassifierFleet([spec], warmup=False, workers=1)
    try:
        rng = np.random.default_rng(5)
        x = rng.normal(size=(40, F))
        want = prog.predict(x)
        reqs, shed, _ = fleet.submit_many("toy", x)
        assert shed.size == 0
        got = np.array([r.result(timeout=60.0) for r in reqs])
        np.testing.assert_array_equal(got, want)
        s = fleet.stats_summary()
        assert s["workers"]["np"]["n_evals"] >= 1
        assert s["workers"]["np"]["n_errors"] == 0
        assert s["tenants"]["toy"]["n_slo_miss"] == 0
    finally:
        fleet.shutdown()


# ---------------------------------------------------------------------------
# Hypothesis: protocol-v2 deadline tables end-to-end
# ---------------------------------------------------------------------------
try:
    from hypothesis import given, settings, strategies as st
    _HAVE_HYPOTHESIS = True
except ImportError:                                   # pragma: no cover
    _HAVE_HYPOTHESIS = False

if _HAVE_HYPOTHESIS:

    _deadline_row = st.one_of(st.just(float("nan")),      # tenant default
                              st.floats(1.0, 1e4, allow_nan=False))
    _tables = st.one_of(st.none(), st.floats(1.0, 1e4, allow_nan=False))
    _E2E_PROG = CircuitProgram.from_classifier(_toy_classifier(),
                                               backend="np")

    @settings(max_examples=N_EXAMPLES, deadline=None)
    @given(st.integers(1, 24), st.integers(1, 16), st.data())
    def test_deadline_tables_end_to_end(B, max_queue, data):
        """Arbitrary v2 deadline tables (NaN/default/per-row mixes, shed
        tails) through decode → `fleet.submit_many`: admitted+shed == B,
        sheds are exactly the frame tail, NaN rows take the tenant
        default budget, shed accounting and the retry hint agree."""
        prog = _E2E_PROG
        dls = data.draw(st.one_of(
            _tables, st.lists(_deadline_row, min_size=B, max_size=B)))
        default_ms = 25.0
        fleet = ClassifierFleet([_spec(prog, max_queue=max_queue,
                                       deadline_ms=default_ms)],
                                warmup=False, autostart=False)
        plane = np.arange(B * F, dtype=np.float64).reshape(B, F)
        frame = P.encode_submit_batch(np.arange(B, dtype=np.uint64), "toy",
                                      plane, deadlines_ms=dls)
        msg = P.decode_message(frame[4:])     # strip the length prefix
        assert msg.tenant == "toy" and msg.readings.shape == (B, F)
        reqs, shed, retry = fleet.submit_many("toy", msg.readings,
                                              msg.deadlines_ms)
        n_admit = len(reqs)
        assert n_admit == min(B, max_queue)
        assert shed.tolist() == list(range(n_admit, B))   # tail, in order
        assert (retry > 0.0) == (n_admit < B)
        table = (np.full(B, np.nan) if dls is None
                 else np.broadcast_to(np.asarray(dls, dtype=np.float64),
                                      (B,)))
        for i, r in enumerate(reqs):
            d = table[i]
            want = default_ms if d != d else d
            assert r.deadline_ms == pytest.approx(want)
            np.testing.assert_array_equal(r.readings, plane[i])
        s = fleet.stats_summary()
        assert s["tenants"]["toy"]["n_shed"] == B - n_admit
        assert s["fleet"]["n_shed"] == B - n_admit
        assert s["tenants"]["toy"]["pending"] == n_admit
