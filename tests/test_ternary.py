"""Quantizers, ABC interface, 2-bit packing (hypothesis roundtrip)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.ternary import (abc_binarize, abc_fit_thresholds,
                                binary_step_ste, pack_ternary, ternarize,
                                ternary_quantize_lm, ternary_ste,
                                unpack_ternary, zero_fraction)


def test_ternarize_values():
    w = jnp.asarray([-2.0, -0.5, -0.2, 0.0, 0.2, 0.5, 2.0])
    q = ternarize(w)
    assert q.tolist() == [-1.0, -1.0, 0.0, 0.0, 0.0, 1.0, 1.0]


def test_ste_gradient_window():
    g = jax.grad(lambda w: ternary_ste(w).sum())(jnp.asarray([0.1, 0.9, 1.5]))
    assert g.tolist() == [1.0, 1.0, 0.0]          # clipped outside [-1,1]


def test_binary_step_matches_comparator():
    a = jnp.asarray([-3.0, -0.001, 0.0, 0.001, 3.0])
    h = binary_step_ste(a)
    assert h.tolist() == [-1.0, -1.0, 1.0, 1.0, 1.0]   # a>=0 -> +1


def test_abc_median_threshold():
    x = np.random.default_rng(0).random((100, 4))
    thr = abc_fit_thresholds(x)
    xb = np.asarray(abc_binarize(x, thr))
    frac = xb.mean(0)
    assert ((frac > 0.3) & (frac < 0.7)).all()    # median splits ~50/50


@settings(max_examples=30, deadline=None)
@given(st.integers(1, 16), st.integers(1, 8), st.integers(0, 2**31 - 1))
def test_pack_unpack_roundtrip(kq, n, seed):
    K = kq * 4
    r = np.random.default_rng(seed)
    codes = jnp.asarray(r.integers(-1, 2, (K, n)), jnp.int8)
    packed = pack_ternary(codes)
    assert packed.shape == (K // 4, n)
    got = unpack_ternary(packed, dtype=jnp.int8)
    assert (np.asarray(got) == np.asarray(codes)).all()


def test_lm_quantizer_scale():
    w = jnp.asarray(np.random.default_rng(0).normal(0, 0.1, (64, 32)),
                    jnp.float32)
    codes, alpha = ternary_quantize_lm(w)
    assert set(np.unique(np.asarray(codes))) <= {-1.0, 0.0, 1.0}
    assert alpha.shape == (1, 32)
    err = jnp.abs(codes * alpha - w).mean()
    assert float(err) < 0.1


def test_zero_fraction():
    codes = jnp.asarray([[0, 1], [-1, 0]], jnp.int8)
    assert float(zero_fraction(codes)) == 0.5
