"""Island-model campaign: determinism, migration, resume, CLI, sharding."""
import json
import os
import signal
import subprocess
import sys
from pathlib import Path

import numpy as np
import pytest

from repro.core.nsga2 import NSGA2Config, extract_front, nsga2
from repro.evolve import (Campaign, CampaignConfig, ParetoArchive,
                          ProblemSpec, build_synth_problem, migrate_ring)

SRC = str(Path(__file__).resolve().parents[1] / "src")


def _cfg(**kw) -> CampaignConfig:
    base = dict(n_islands=3, pop_size=12, n_epochs=4, gens_per_epoch=3,
                migrate_k=2, seed=7)
    base.update(kw)
    return CampaignConfig(**base)


def _campaign(cfg=None, ckpt=None) -> Campaign:
    p = build_synth_problem()
    return Campaign(p.domains, p.objective, cfg or _cfg(),
                    checkpoint_dir=ckpt, name=p.name)


# ---------------------------------------------------------------------------
# Core campaign semantics
# ---------------------------------------------------------------------------
def test_archive_is_nondominated_and_canonical():
    res = _campaign().run()
    F = res.archive_f
    assert len(F) > 0
    for i in range(len(F)):
        dominated = ((F <= F[i]).all(1) & (F < F[i]).any(1)).any()
        assert not dominated, f"archive row {i} is dominated"
    # canonical order: sorted by (f0, f1)
    key = list(map(tuple, np.round(F, 12)))
    assert key == sorted(key)
    # duplicate chromosomes collapsed
    assert len(np.unique(res.archive_x, axis=0)) == len(res.archive_x)


def test_migration_moves_elites():
    c = _campaign()
    c.init_or_resume()
    for i, d in enumerate(c.drivers):
        c.states[i] = d.step(c.states[i])
    elite_x, _ = extract_front(c.states[0].pop, c.states[0].F)
    placed = migrate_ring(c.states, k=2)
    assert placed > 0
    # island 1 (ring successor of 0) now contains island 0's top elite
    assert any((row == elite_x[0]).all() for row in c.states[1].pop)


def test_migration_noop_for_single_island():
    c = _campaign(_cfg(n_islands=1))
    c.init_or_resume()
    assert migrate_ring(c.states, k=2) == 0


def test_campaign_beats_or_matches_single_island_budget():
    """Sanity: the campaign front is at least as good at the extremes as a
    single island given the same per-island budget (elitist archive)."""
    res = _campaign().run()
    single = nsga2(build_synth_problem().domains,
                   build_synth_problem().objective,
                   NSGA2Config(pop_size=12, n_generations=12, seed=7))
    assert res.archive_f[:, 0].min() <= single.pareto_f[:, 0].min() + 1e-12


def test_in_process_resume_bit_identical(tmp_path):
    full = _campaign(ckpt=str(tmp_path / "a")).run()
    # same campaign stopped after 2 epochs, then resumed by a fresh object
    stopped = _campaign(_cfg(n_epochs=2), ckpt=str(tmp_path / "b")).run()
    assert stopped.epochs_run == 2
    resumed = _campaign(ckpt=str(tmp_path / "b")).run()
    assert resumed.resumed_from == 1 and resumed.epochs_run == 2
    np.testing.assert_array_equal(full.archive_x, resumed.archive_x)
    np.testing.assert_array_equal(full.archive_f, resumed.archive_f)


def test_resume_rejects_incompatible_config(tmp_path):
    _campaign(ckpt=str(tmp_path)).run()
    for change in ({"pop_size": 8}, {"migrate_k": 0}, {"seed": 8},
                   {"base": NSGA2Config(mutation_eta=5.0)}):
        other = _campaign(_cfg(**change), ckpt=str(tmp_path))
        with pytest.raises(ValueError, match="incompatible campaign config"):
            other.run()


def test_archive_update_keeps_best():
    a = ParetoArchive(2)
    a.update(np.array([[0, 0], [1, 1]]), np.array([[1.0, 2.0], [2.0, 1.0]]))
    a.update(np.array([[2, 2]]), np.array([[0.5, 0.5]]))   # dominates both
    assert len(a) == 1 and a.F[0].tolist() == [0.5, 0.5]


# ---------------------------------------------------------------------------
# Cross-process determinism + SIGKILL resume (the acceptance criterion)
# ---------------------------------------------------------------------------
def _cli(tmp, extra, timeout=240):
    cmd = [sys.executable, "-m", "repro.evolve", "--problem", "synth",
           "--islands", "3", "--pop", "12", "--epochs", "4",
           "--gens-per-epoch", "3", "--seed", "7"] + extra
    env = dict(os.environ, PYTHONPATH=SRC + os.pathsep
               + os.environ.get("PYTHONPATH", ""))
    return subprocess.run(cmd, cwd=str(tmp), env=env, capture_output=True,
                          text=True, timeout=timeout)


def test_seed_determinism_across_processes(tmp_path):
    """Two fresh processes, same seed -> byte-identical Pareto archives."""
    for tag in ("p1", "p2"):
        r = _cli(tmp_path, ["--out", f"front_{tag}.json"])
        assert r.returncode == 0, r.stderr
    a = json.loads((tmp_path / "front_p1.json").read_text())
    b = json.loads((tmp_path / "front_p2.json").read_text())
    assert a["archive"] == b["archive"] and len(a["archive"]) > 0


def test_sigkill_resume_bit_identical_front(tmp_path):
    """A campaign SIGKILLed between generations resumes from its checkpoint
    to a bit-identical final front versus an uninterrupted run."""
    r = _cli(tmp_path, ["--out", "front_full.json"])
    assert r.returncode == 0, r.stderr

    r = _cli(tmp_path, ["--ckpt-dir", "ck", "--out", "front_killed.json",
                        "--kill-after-epoch", "1"])
    assert r.returncode == -signal.SIGKILL          # really died mid-campaign
    assert not (tmp_path / "front_killed.json").exists()

    r = _cli(tmp_path, ["--ckpt-dir", "ck", "--out", "front_killed.json"])
    assert r.returncode == 0, r.stderr
    assert "resumed from epoch 1" in r.stdout

    full = json.loads((tmp_path / "front_full.json").read_text())
    resumed = json.loads((tmp_path / "front_killed.json").read_text())
    assert full["archive"] == resumed["archive"]
    assert resumed["resumed_from"] == 1


def test_seed_changes_front(tmp_path):
    r1 = _cli(tmp_path, ["--out", "s7.json"])
    cmd_alt = ["--out", "s8.json"]
    r2 = subprocess.run(
        [sys.executable, "-m", "repro.evolve", "--problem", "synth",
         "--islands", "3", "--pop", "12", "--epochs", "4",
         "--gens-per-epoch", "3", "--seed", "8"] + cmd_alt,
        cwd=str(tmp_path),
        env=dict(os.environ, PYTHONPATH=SRC + os.pathsep
                 + os.environ.get("PYTHONPATH", "")),
        capture_output=True, text=True, timeout=240)
    assert r1.returncode == 0 and r2.returncode == 0
    a = json.loads((tmp_path / "s7.json").read_text())
    b = json.loads((tmp_path / "s8.json").read_text())
    assert a["archive"] != b["archive"]


# ---------------------------------------------------------------------------
# Parallel island executor: bit-identity with serial stepping
# ---------------------------------------------------------------------------
def _spec_campaign(workers, ckpt=None, **kw) -> Campaign:
    spec = ProblemSpec("synth", {})
    p = spec.build()
    return Campaign(p.domains, p.objective, _cfg(workers=workers, **kw),
                    checkpoint_dir=ckpt, name=p.name, problem_spec=spec)


@pytest.mark.parametrize("workers", [1, 2, 4])
def test_parallel_campaign_bit_identical(workers):
    """The acceptance criterion: same archive X/F and same per-island
    histories whether islands step serially or across N workers."""
    serial = _campaign().run()
    with _spec_campaign(workers) as c:
        par = c.run()
    np.testing.assert_array_equal(serial.archive_x, par.archive_x)
    np.testing.assert_array_equal(serial.archive_f, par.archive_f)
    assert serial.histories == par.histories


def test_parallel_resume_crosses_worker_counts(tmp_path):
    """workers is excluded from the resume fingerprint: a checkpoint
    written serially resumes under a worker pool bit-identically."""
    full = _campaign().run()
    _campaign(_cfg(n_epochs=2), ckpt=str(tmp_path)).run()
    with _spec_campaign(2, ckpt=str(tmp_path)) as c:
        resumed = c.run()
    assert resumed.resumed_from == 1
    np.testing.assert_array_equal(full.archive_x, resumed.archive_x)
    np.testing.assert_array_equal(full.archive_f, resumed.archive_f)


def test_workers_require_problem_spec():
    p = build_synth_problem()
    with pytest.raises(ValueError, match="problem_spec"):
        Campaign(p.domains, p.objective, _cfg(workers=2))


def test_executor_rejects_bare_callable():
    from repro.evolve.executor import IslandExecutor
    with pytest.raises(TypeError, match="ProblemSpec"):
        IslandExecutor(lambda X: X, _cfg(workers=2))


def test_cache_history_rows_serial_and_parallel():
    res = _campaign().run()
    assert len(res.cache_history) == _cfg().n_epochs
    last = res.cache_history[-1]
    assert last["mode"] == "serial" and last["epoch"] == _cfg().n_epochs - 1
    assert last["misses"] > 0 and last["hits"] >= 0
    assert last["maxsize"] == _cfg().memo_maxsize

    with _spec_campaign(2) as c:
        par = c.run()
    plast = par.cache_history[-1]
    assert plast["mode"] == "parallel" and plast["workers"] == 2
    assert plast["misses"] > 0 and plast["reports"] >= 1


def test_memo_bound_does_not_change_front():
    """Eviction re-evaluates to identical values — a pathologically tiny
    memo bound must not alter the trajectory."""
    ref = _campaign().run()
    tiny = _campaign(_cfg(memo_maxsize=4))
    res = tiny.run()
    np.testing.assert_array_equal(ref.archive_x, res.archive_x)
    info = tiny._evaluate.cache_info()
    assert info["evictions"] > 0 and info["size"] <= 4


# ---------------------------------------------------------------------------
# Bounded fitness memo (_memoized LRU)
# ---------------------------------------------------------------------------
def _counting_objective():
    calls = {"rows": 0}

    def objective(pop):
        calls["rows"] += pop.shape[0]
        return np.stack([pop.sum(1).astype(float),
                         (5 - pop).sum(1).astype(float)], 1)

    return objective, calls


def test_memoized_hits_and_misses():
    from repro.core.nsga2 import _memoized

    objective, calls = _counting_objective()
    evaluate = _memoized(objective)
    X = np.arange(12, dtype=np.int64).reshape(4, 3)
    first = evaluate(X)
    assert calls["rows"] == 4
    again = evaluate(X)                       # pure cache hits
    np.testing.assert_array_equal(first, again)
    assert calls["rows"] == 4
    info = evaluate.cache_info()
    assert info["hits"] == 4 and info["misses"] == 4
    assert info["evictions"] == 0 and info["maxsize"] is None


def test_memoized_lru_evicts_and_recomputes_identically():
    from repro.core.nsga2 import _memoized

    objective, calls = _counting_objective()
    evaluate = _memoized(objective, maxsize=2)
    X = np.arange(12, dtype=np.int64).reshape(4, 3)
    first = evaluate(X)                       # 4 misses, bound 2 -> evicts 2
    info = evaluate.cache_info()
    assert info["size"] == 2 and info["evictions"] == 2
    again = evaluate(X)                       # evicted rows recompute
    np.testing.assert_array_equal(first, again)
    assert calls["rows"] > 4
    assert evaluate.cache_info()["size"] <= 2


def test_memoized_tiny_bound_smaller_than_batch():
    """Eviction must never drop a row the *current* batch still needs."""
    from repro.core.nsga2 import _memoized

    objective, _ = _counting_objective()
    evaluate = _memoized(objective, maxsize=1)
    X = np.arange(18, dtype=np.int64).reshape(6, 3)
    # duplicate rows inside one batch: dedup within the call, one value each
    Xdup = np.vstack([X, X[::-1]])
    out = evaluate(Xdup)
    np.testing.assert_array_equal(out[:6], out[6:][::-1])
    assert evaluate.cache_info()["size"] <= 1


def test_memoized_cache_clear_resets():
    from repro.core.nsga2 import _memoized

    objective, calls = _counting_objective()
    evaluate = _memoized(objective, maxsize=8)
    X = np.arange(6, dtype=np.int64).reshape(2, 3)
    evaluate(X)
    evaluate.cache_clear()
    assert evaluate.cache_info()["size"] == 0
    evaluate(X)
    assert calls["rows"] == 4                 # recomputed after clear


# ---------------------------------------------------------------------------
# Evaluator dispatch + sharding
# ---------------------------------------------------------------------------
def test_evaluator_backends_agree_on_random_circuits():
    from repro.core import circuits as C
    from repro.evolve.evaluator import population_eval_pop

    rng = np.random.default_rng(3)
    pop = C.random_netlist_population(rng, 6, 24, 3, 9)
    bits = (rng.random((257, 6)) < 0.5).astype(np.uint8)
    packed = C.pack_vectors(bits)
    ref = population_eval_pop(pop, packed, backend="np")
    for backend in ("swar", "pallas"):
        got = population_eval_pop(pop, packed, backend=backend)
        np.testing.assert_array_equal(got, ref)


def test_evaluator_row_sharding_matches_single_device():
    """Force the multi-shard code path by passing duplicate device handles —
    row-slicing must be a pure partition of the population."""
    import jax

    from repro.core import circuits as C
    from repro.evolve.evaluator import population_eval_pop

    rng = np.random.default_rng(4)
    pop = C.random_netlist_population(rng, 5, 16, 2, 7)
    bits = (rng.random((100, 5)) < 0.5).astype(np.uint8)
    packed = C.pack_vectors(bits)
    dev = jax.local_devices()[0]
    ref = population_eval_pop(pop, packed, backend="swar")
    got = population_eval_pop(pop, packed, backend="swar",
                              devices=[dev, dev, dev])
    np.testing.assert_array_equal(got, ref)


def test_unknown_backend_rejected():
    from repro.evolve.evaluator import population_eval_uint
    with pytest.raises(ValueError, match="unknown eval backend"):
        population_eval_uint(np.zeros((1, 1), np.int16),
                             np.zeros((1, 1), np.int32),
                             np.zeros((1, 1), np.int32),
                             np.zeros((1, 1), np.int32),
                             np.zeros((1, 1), np.uint64), 1, backend="cuda")
