"""Bespoke TNN: QAT <-> circuit exactness, balancing, hardware accounting."""
import numpy as np
import pytest

from repro.core import tnn as T
from repro.core.ternary import abc_binarize
from repro.data.tabular import make_dataset
from repro.hw.egfet import interface_cost


@pytest.fixture(scope="module")
def cardio_tnn():
    ds = make_dataset("cardio")
    t = T.train_tnn(ds, T.TNNTrainConfig(n_hidden=3, epochs=10, seed=0,
                                         lr=1e-2))
    return ds, t


def test_training_beats_majority(cardio_tnn):
    ds, t = cardio_tnn
    maj = np.bincount(ds.y_train).max() / len(ds.y_train)
    assert t.test_acc > maj


def test_circuit_exact_equals_integer_path(cardio_tnn):
    """The central invariant: exact netlists == integer forward == argmax of
    the QAT training forward (given balanced zero counts)."""
    ds, t = cardio_tnn
    xb = np.asarray(abc_binarize(ds.x_test, t.thresholds))
    hnl, onl = T.exact_netlists(t)
    pred_circ = T.predict_with_circuits(t, xb, hnl, onl)
    pred_int = T.predict_exact(t, xb)
    assert (pred_circ == pred_int).all()


def test_zero_counts_balanced(cardio_tnn):
    _, t = cardio_tnn
    zeros = (t.w2t == 0).sum(axis=0)
    assert (zeros == zeros[0]).all()
    _ = t.out_nnz    # must not raise


def test_balance_preserves_accuracy():
    """Median-target balancing must not collapse narrow output layers
    (the max-target projection zeroed whole columns on arrhythmia)."""
    r = np.random.default_rng(0)
    w = r.normal(0, 1, (3, 16))
    w[:, 0] = [0.01, 0.02, 2.0]      # a column that max-balancing would kill
    codes = T.balance_zero_counts(w, threshold=1 / 3)
    zeros = (codes == 0).sum(axis=0)
    assert (zeros == zeros[0]).all()
    assert (codes != 0).any(axis=0).sum() >= 12   # most columns stay alive


def test_hw_cost_scales_with_interface(cardio_tnn):
    _, t = cardio_tnn
    hnl, onl = T.exact_netlists(t)
    core = T.tnn_hw_cost(t, hnl, onl, interface=None)
    abc = T.tnn_hw_cost(t, hnl, onl, interface="abc")
    adc = T.tnn_hw_cost(t, hnl, onl, interface="adc4")
    F = t.w1t.shape[0]
    assert abc.area_mm2 == pytest.approx(
        core.area_mm2 + interface_cost(F, "abc").area_mm2)
    # the paper's headline: the ADC *interface* dwarfs the ABC interface
    # (167x area, 34x power per feature — Sec. 3.1)
    iface_adc = adc.area_mm2 - core.area_mm2
    iface_abc = abc.area_mm2 - core.area_mm2
    assert iface_adc > iface_abc * 100
    assert (adc.power_mw - core.power_mw) > (abc.power_mw - core.power_mw) * 30


def test_degenerate_hidden_neurons():
    nl = T.hidden_exact_netlist(3, 0)
    assert nl.cost().area_mm2 == 0.0              # constant-1, zero hardware
    nl2 = T.hidden_exact_netlist(0, 3)
    assert nl2.cost().area_mm2 > 0                # NOR tree
