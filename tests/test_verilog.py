"""Verilog backend: emitter/reader roundtrips on real circuit families,
module deduplication, reader strictness, and the EGFET report."""
import numpy as np
import pytest

from repro.core import circuits as C
from repro.core import tnn as T
from repro.compile import (CircuitProgram, argmax_netlist, egfet_report,
                           emit_classifier_verilog, emit_netlist_module,
                           lower_classifier, write_artifacts)
from repro.compile.vread import (VerilogDesign, VerilogError,
                                 eval_classifier_verilog)


def _roundtrip(nl: C.Netlist, n_in: int):
    design = VerilogDesign.parse(emit_netlist_module(nl, "dut"))
    vecs = ((np.arange(1 << n_in)[:, None] >> np.arange(n_in)[None, :]) & 1
            ).astype(np.uint8)
    got = design.eval_uint("dut", vecs)
    ref = nl.eval_uint(C.exhaustive_vectors(n_in))[: 1 << n_in]
    assert (got == ref).all()


@pytest.mark.parametrize("n", [1, 3, 6, 9])
def test_popcount_module_roundtrip(n):
    _roundtrip(C.popcount_netlist(n), n)


def test_truncated_and_pcc_and_comparator_roundtrip():
    _roundtrip(C.truncated_popcount_netlist(6, 3), 6)
    _roundtrip(C.compose_pcc(C.popcount_netlist(4),
                             C.truncated_popcount_netlist(5, 2), 4, 5), 9)
    _roundtrip(C.comparator_geq_netlist(3), 6)
    _roundtrip(argmax_netlist(3, 2), 6)


def _toy_classifier(seed=0, F=6, H=4, Cc=3):
    rng = np.random.default_rng(seed)
    w1t = rng.integers(-1, 2, size=(F, H)).astype(np.int8)
    w2t = T.balance_zero_counts(rng.normal(size=(H, Cc)), 1 / 3)
    tnn = T.TrainedTNN(w1t=w1t, w2t=w2t, thresholds=np.full(F, 0.5),
                       train_acc=0.0, test_acc=0.0, name="toy")
    return tnn, lower_classifier(tnn, *T.exact_netlists(tnn))


def test_classifier_verilog_matches_program():
    _, cc = _toy_classifier()
    text = emit_classifier_verilog(cc)
    rng = np.random.default_rng(1)
    vecs = rng.integers(0, 2, size=(3000, cc.n_features)).astype(np.uint8)
    prog = CircuitProgram.from_classifier(cc, backend="np")
    assert (eval_classifier_verilog(text, vecs) == prog.predict_bits(vecs)).all()


def test_identical_netlists_share_one_module():
    """Content-addressed dedup: C identical output popcounts -> 1 module."""
    tnn, cc = _toy_classifier(seed=3)
    text = emit_classifier_verilog(cc)
    n_out_mods = sum(1 for nl in cc.out_nls)
    assert n_out_mods == cc.n_classes
    # modules: distinct hidden PCCs + ONE shared output PC + argmax + top
    distinct_hidden = {(nl.n_inputs, nl.op.tobytes(), nl.in0.tobytes(),
                        nl.in1.tobytes(), nl.outputs.tobytes())
                       for nl in cc.hidden_nls}
    n_modules = text.count("\nmodule ") + text.startswith("module ")
    assert n_modules <= len(distinct_hidden) + 1 + 1 + 1


def test_reader_rejects_malformed():
    with pytest.raises(VerilogError):
        VerilogDesign.parse("module m (input x0, output y0); assign y0 = ; endmodule")
    with pytest.raises(VerilogError):   # undefined signal
        VerilogDesign.parse(
            "module m (input x0, output y0);\n  assign y0 = ghost;\nendmodule"
        ).evaluate("m", {"x0": np.zeros(1, np.uint64)})
    with pytest.raises(VerilogError):   # mixed operators without parens
        VerilogDesign.parse(
            "module m (input x0, input x1, input x2, output y0);\n"
            "  assign y0 = x0 & x1 | x2;\nendmodule")
    with pytest.raises(VerilogError):   # double driver
        VerilogDesign.parse(
            "module m (input x0, output y0);\n  wire w;\n"
            "  assign w = x0;\n  assign w = ~x0;\n  assign y0 = w;\nendmodule"
        ).evaluate("m", {"x0": np.zeros(1, np.uint64)})


def test_egfet_report_totals_and_artifacts(tmp_path):
    _, cc = _toy_classifier()
    rep = egfet_report(cc, interface="abc")
    assert rep["total_area_mm2"] == pytest.approx(
        rep["core_area_mm2"] + rep["interface_area_mm2"], abs=1e-3)
    assert rep["total_power_mw"] == pytest.approx(
        rep["core_power_mw"] + rep["interface_power_mw"], abs=1e-4)
    assert rep["n_gates"] == cc.ir.n_gates
    assert sum(rep["gates"].values()) == cc.ir.n_gates
    assert rep["power_source"] in ("energy-harvester", "zinergy-battery",
                                   "molex-battery", "exceeds-printed-budget")
    # no-interface report drops the interface contribution
    rep0 = egfet_report(cc, interface=None)
    assert rep0["total_area_mm2"] == pytest.approx(rep0["core_area_mm2"])

    paths = write_artifacts(cc, tmp_path, base="toy")
    vtext = open(paths["verilog"]).read()
    assert "module tnn_classifier" in vtext
    import json
    assert json.load(open(paths["report"]))["n_gates"] == cc.ir.n_gates
