"""Phase-1 CGP: the evolved circuits respect the Eq.(3) constraint and
beat the exact circuit's area."""
import numpy as np
import pytest

from repro.core.cgp import CGPConfig, evolve_popcount, evolve_pc_library, tau_schedule
from repro.core.circuits import eval_vectors, pc_error, popcount_netlist, popcount_width


def test_cgp_respects_error_bound_and_shrinks():
    n, tau = 8, 0.5
    exact = popcount_netlist(n)
    cfg = CGPConfig(n_inputs=n, n_outputs=popcount_width(n), n_nodes=50,
                    tau=tau, error_metric="mae", max_iters=800, seed=3)
    res = evolve_popcount(cfg)
    assert np.isfinite(res.best_area)
    assert res.best_area <= exact.area()
    packed, true = eval_vectors(n)
    mae, _ = pc_error(res.best, packed, true)
    assert mae <= tau + 1e-9


def test_cgp_wcae_mode():
    n, tau = 6, 2.0
    cfg = CGPConfig(n_inputs=n, n_outputs=popcount_width(n), n_nodes=40,
                    tau=tau, error_metric="wcae", max_iters=500, seed=1)
    res = evolve_popcount(cfg)
    packed, true = eval_vectors(n)
    _, wcae = pc_error(res.best, packed, true)
    assert wcae <= tau


def test_library_monotone_tradeoff():
    lib = evolve_pc_library(8, n_points=3, max_iters=300, seed=0)
    assert lib[0].meta["metric"] == "exact"
    areas = [nl.cost().area_mm2 for nl in lib]
    maes = [nl.meta["mae"] for nl in lib]
    # the exact circuit is the largest; some approximation strictly smaller
    assert min(areas[1:]) < areas[0]
    assert all(m >= 0 for m in maes)


def test_tau_schedule_shape():
    sched = tau_schedule(16, n_points=4)
    assert len(sched) == 8
    mets = {m for m, _ in sched}
    assert mets == {"mae", "wcae"}
    taus = [t for m, t in sched if m == "mae"]
    assert taus == sorted(taus) and taus[0] == pytest.approx(0.1)
