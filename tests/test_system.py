"""End-to-end behaviour tests: the paper's 3-phase pipeline on a real
(synthetic) dataset, the training loop with fault-tolerance features, and
the serving engine."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core.cgp import evolve_pc_library
from repro.core.nsga2 import NSGA2Config
from repro.core.pcc import build_pcc_library, pc_pareto
from repro.core.ternary import abc_binarize
from repro.core import tnn as T
from repro.data.tabular import make_dataset
from repro.data.tokens import TokenPipeline, TokenPipelineConfig
from repro.models.params import init_params
from repro.optim import adamw
from repro.optim.adamw import AdamWConfig
from repro.serve.lm_engine import Request, ServingEngine
from repro.train.loop import Trainer, TrainLoopConfig


@pytest.mark.slow
def test_three_phase_pipeline_end_to_end():
    """Phases 1-3 on cardio: approximate TNNs must trade area for accuracy,
    with an iso-accuracy point cheaper than the exact design (paper Fig. 7).
    """
    ds = make_dataset("cardio")
    tnn = T.train_tnn(ds, T.TNNTrainConfig(n_hidden=3, epochs=10, seed=0,
                                           lr=1e-2))
    sizes = set()
    pcc_sizes = []
    for (p, n) in tnn.hidden_sizes():
        if p >= 1 and n >= 1:
            sizes.update([p, n])
            pcc_sizes.append((p, n))
    sizes.add(max(tnn.out_nnz, 1))
    pc_libs = {n: evolve_pc_library(n, n_points=2, max_iters=250, seed=0)
               for n in sorted(sizes)}
    pcc_lib = build_pcc_library(pcc_sizes, pc_libs, n_samples=20000)
    pc_out = pc_pareto(pc_libs[max(tnn.out_nnz, 1)])

    xb_tr = np.asarray(abc_binarize(ds.x_train, tnn.thresholds))
    prob = T.TNNApproxProblem(tnn=tnn, pcc_lib=pcc_lib, pc_out_lib=pc_out,
                              xbin=xb_tr, y=ds.y_train)
    res = prob.optimize(NSGA2Config(pop_size=16, n_generations=12, seed=0))

    assert len(res.pareto_f) >= 2
    exact_err = res.pareto_f[0, 0]
    hx, ox = T.exact_netlists(tnn)
    exact_area = T.tnn_hw_cost(tnn, hx, ox, interface=None).area_mm2
    # at least one design with near-exact accuracy but smaller area
    found = False
    for x, f in zip(res.pareto_x, res.pareto_f):
        hnl, onl = prob.decode(x)
        area = T.tnn_hw_cost(tnn, hnl, onl, interface=None).area_mm2
        if f[0] <= exact_err + 0.02 and area < exact_area * 0.95:
            found = True
    assert found, "no iso-accuracy approximate design found"


def test_trainer_loss_decreases_and_resumes(tmp_path):
    cfg = get_config("qwen2-1.5b").reduced()
    pipe = TokenPipeline(TokenPipelineConfig(vocab=cfg.vocab, seq_len=32,
                                             global_batch=4, seed=0))
    loop = TrainLoopConfig(total_steps=8, ckpt_every=4, log_every=100,
                           optimizer=AdamWConfig(lr=3e-3))
    tr = Trainer(cfg, loop, pipe, str(tmp_path))
    params = init_params(jax.random.PRNGKey(0), cfg)
    params, opt, res = tr.run(params, adamw.init(params),
                              log=lambda s: None)
    assert res["losses"][-1] < res["losses"][0]
    # resume
    tr2 = Trainer(cfg, TrainLoopConfig(total_steps=10, ckpt_every=4,
                                       optimizer=AdamWConfig(lr=3e-3)),
                  pipe, str(tmp_path))
    p0 = init_params(jax.random.PRNGKey(0), cfg)
    _, _, start = tr2.resume_or_init(lambda: (p0, adamw.init(p0)))
    assert start == 8


def test_trainer_microbatch_equivalence(tmp_path):
    """Grad accumulation over 2 microbatches ~ single full batch step."""
    from repro.train.loop import make_train_step
    cfg = get_config("llama3.2-1b").reduced()
    pipe = TokenPipeline(TokenPipelineConfig(vocab=cfg.vocab, seq_len=16,
                                             global_batch=4, seed=0))
    batch = pipe.batch_at(0)
    params = init_params(jax.random.PRNGKey(0), cfg)
    ocfg = AdamWConfig(lr=1e-3, grad_clip=None)
    s1 = make_train_step(cfg, TrainLoopConfig(microbatches=1, optimizer=ocfg))
    s2 = make_train_step(cfg, TrainLoopConfig(microbatches=2, optimizer=ocfg))
    p1, _, m1, _ = s1(params, adamw.init(params), batch, None)
    p2, _, m2, _ = s2(params, adamw.init(params), batch, None)
    d = max(float(jnp.max(jnp.abs(a - b)))
            for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)))
    assert d < 5e-4                           # same update up to fp/average
    assert abs(float(m1["loss"]) - float(m2["loss"])) < 5e-2


def test_serving_batched_requests():
    cfg = get_config("llama3.2-1b").reduced()
    params = init_params(jax.random.PRNGKey(0), cfg)
    eng = ServingEngine(cfg, params, max_batch=4, cache_len=64)
    reqs = [Request(uid=i, prompt=[1 + i, 2, 3], max_new_tokens=5)
            for i in range(6)]
    out = eng.run(reqs)
    assert all(len(r.output) == 5 for r in out)
    # determinism: same prompt -> same output
    again = eng.run([Request(uid=99, prompt=[1, 2, 3], max_new_tokens=5)])
    assert again[0].output == out[0].output
