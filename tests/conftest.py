"""Shared fixtures.  NOTE: no XLA_FLAGS here — tests run on 1 CPU device;
only launch/dryrun.py (separate process) requests 512 placeholder devices."""
import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


def make_lm_batch(cfg, B=2, S=16, seed=0):
    import jax.numpy as jnp
    r = np.random.default_rng(seed)
    batch = {
        "tokens": jnp.asarray(r.integers(0, cfg.vocab, (B, S)), jnp.int32),
        "labels": jnp.asarray(r.integers(0, cfg.vocab, (B, S)), jnp.int32),
    }
    if cfg.rope == "mrope":
        batch["positions"] = jnp.broadcast_to(
            jnp.arange(S)[None, None, :], (B, 3, S)).astype(jnp.int32)
        batch["vision_embeds"] = jnp.asarray(
            r.normal(0, 0.02, (B, cfg.n_vision_tokens, cfg.d_model)),
            jnp.float32)
    if cfg.enc_layers:
        batch["enc_frames"] = jnp.asarray(
            r.normal(0, 0.02, (B, cfg.enc_seq, cfg.d_model)), jnp.float32)
    return batch
