"""Circuit compiler: IR lowering invariants, device `CircuitProgram`
equivalence with `Netlist.simulate` (numpy + JAX backends, plus a
hypothesis sweep over random netlists), and the acceptance pin: for all
five Table-2 datasets the compiled classifier and the emitted Verilog are
bit-identical to the `predict_with_circuits` reference path."""
import numpy as np
import pytest

from repro.core import circuits as C
from repro.core import tnn as T
from repro.core.ternary import abc_binarize
from repro.data.tabular import DATASETS, make_dataset
from repro.hw.egfet import Gate
from repro.compile import (CircuitProgram, argmax_netlist,
                           emit_classifier_verilog, eval_classifier_verilog,
                           lower_classifier, lower_netlist)

_FUNCS = np.array([Gate.AND, Gate.OR, Gate.XOR, Gate.NAND, Gate.NOR,
                   Gate.XNOR, Gate.NOT, Gate.BUF, Gate.ANDN, Gate.ORN,
                   Gate.CONST0, Gate.CONST1])


def _random_netlist(rng, n_in, n_gates, n_out):
    op = _FUNCS[rng.integers(len(_FUNCS), size=n_gates)].astype(np.int16)
    in0 = np.array([rng.integers(n_in + g) for g in range(n_gates)], np.int32)
    in1 = np.array([rng.integers(n_in + g) for g in range(n_gates)], np.int32)
    outs = rng.integers(n_in + n_gates, size=n_out).astype(np.int32)
    nl = C.Netlist(n_in, op, in0, in1, outs)
    nl.validate()
    return nl


# ---------------------------------------------------------------------------
# IR lowering
# ---------------------------------------------------------------------------
def test_lower_preserves_semantics_and_eliminates_dead_gates():
    rng = np.random.default_rng(0)
    for _ in range(20):
        n_in = int(rng.integers(2, 9))
        nl = _random_netlist(rng, n_in, int(rng.integers(5, 40)), 3)
        ir = lower_netlist(nl)
        packed = C.exhaustive_vectors(n_in)
        assert (ir.to_netlist().eval_uint(packed) == nl.eval_uint(packed)).all()
        assert ir.n_gates == int(nl.active_mask().sum())
        # levelized: every used operand sits at a strictly smaller level
        lvl = np.concatenate([np.zeros(ir.n_inputs, np.int32), ir.levels])
        for g in range(ir.n_gates):
            o = Gate(int(ir.op[g]))
            if o not in (Gate.CONST0, Gate.CONST1):
                assert lvl[ir.in0[g]] < ir.levels[g]
                if o not in (Gate.NOT, Gate.BUF):
                    assert lvl[ir.in1[g]] < ir.levels[g]
        assert np.all(np.diff(ir.levels) >= 0)          # level-sorted
        # all gates live: cost equals the active-gate cost of the source
        assert ir.cost().area_mm2 == pytest.approx(nl.cost().area_mm2)


def test_lower_keeps_tap_nodes_live():
    b = C._Builder(2)
    x = b.gate(Gate.XOR, 0, 1)
    dead = b.gate(Gate.AND, 0, 1)       # unreachable from outputs
    nl = b.finish([x])
    ir = lower_netlist(nl, taps={"extra": np.array([dead])})
    assert ir.n_gates == 2              # tap pins the otherwise-dead gate
    assert lower_netlist(nl).n_gates == 1


def test_argmax_netlist_matches_np_argmax_first_max():
    rng = np.random.default_rng(1)
    for n_classes, bits in [(2, 2), (3, 3), (7, 3), (16, 4)]:
        am = argmax_netlist(n_classes, bits)
        S = 4096
        scores = rng.integers(0, 1 << bits, size=(S, n_classes))
        scores[: S // 8] = scores[0, 0]       # force plenty of ties
        planes = np.zeros((S, n_classes * bits), np.uint8)
        for o in range(n_classes):
            for k in range(bits):
                planes[:, o * bits + k] = (scores[:, o] >> k) & 1
        got = am.eval_uint(C.pack_vectors(planes))[:S]
        assert (got == np.argmax(scores, axis=1)).all()


# ---------------------------------------------------------------------------
# Device program vs Netlist.simulate
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("backend", ["np", "jax"])
def test_program_matches_netlist_simulate(backend):
    rng = np.random.default_rng(2)
    for _ in range(10):
        n_in = int(rng.integers(2, 9))
        nl = _random_netlist(rng, n_in, int(rng.integers(1, 48)), 4)
        prog = CircuitProgram.from_netlist(nl, backend=backend)
        packed = C.exhaustive_vectors(n_in)
        assert (prog.eval_uint(packed) == nl.eval_uint(packed)).all()
        bits = rng.integers(0, 2, size=(777, n_in)).astype(np.uint8)
        assert (prog.eval_bits(bits)
                == nl.eval_uint(C.pack_vectors(bits))[:777]).all()


def test_program_property_random_netlists():
    """Hypothesis sweep: compiled program == Netlist.simulate on random
    valid netlists (random gates, fan-in, input counts), both backends."""
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=25, deadline=None)
    @given(st.integers(1, 6), st.integers(0, 24), st.integers(1, 4),
           st.integers(0, 2 ** 31 - 1))
    def check(n_in, n_gates, n_out, seed):
        rng = np.random.default_rng(seed)
        nl = _random_netlist(rng, n_in, n_gates, n_out)
        packed = C.exhaustive_vectors(n_in)
        ref = nl.eval_uint(packed)
        for backend in ("np", "jax"):
            prog = CircuitProgram.from_netlist(nl, backend=backend)
            assert (prog.eval_uint(packed) == ref).all()

    check()


# ---------------------------------------------------------------------------
# Classifier acceptance pin: all five Table-2 datasets
# ---------------------------------------------------------------------------
def _quick_tnn(dataset: str) -> tuple:
    ds = make_dataset(dataset)
    tnn = T.train_tnn(ds, T.TNNTrainConfig(n_hidden=ds.spec.topology[1],
                                           epochs=2, lr=1e-2))
    return ds, tnn


@pytest.mark.parametrize("dataset", sorted(DATASETS))
def test_compiled_classifier_bit_identical_per_dataset(dataset):
    ds, tnn = _quick_tnn(dataset)
    hidden_nls, out_nls = T.exact_netlists(tnn)
    xb = np.asarray(abc_binarize(ds.x_test, tnn.thresholds)).astype(np.uint8)
    ref = T.predict_with_circuits(tnn, xb, hidden_nls, out_nls)

    cc = lower_classifier(tnn, hidden_nls, out_nls)
    for backend in ("np", "jax"):
        prog = CircuitProgram.from_classifier(cc, backend=backend)
        assert (prog.predict_bits(xb) == ref).all(), backend
    # raw-sensor path applies the same strict-> ABC comparators
    prog = CircuitProgram.from_classifier(cc)
    assert (prog.predict(ds.x_test) == ref).all()

    # emitted RTL re-evaluated by the independent reader: >= 10k vectors
    rng = np.random.default_rng(42)
    vecs = rng.integers(0, 2, size=(10_048, cc.n_features)).astype(np.uint8)
    rtl = eval_classifier_verilog(emit_classifier_verilog(cc), vecs)
    assert (rtl == prog.predict_bits(vecs)).all()


def test_compiled_classifier_approximate_netlists():
    """The compiler must be exact for *approximate* selections too."""
    ds, tnn = _quick_tnn("cardio")
    hidden_nls, out_nls = T.exact_netlists(tnn)
    # swap in truncated popcounts wherever the shape allows
    for i, (p, n) in enumerate(tnn.hidden_sizes()):
        if p >= 3 and n >= 1:
            hidden_nls[i] = C.compose_pcc(
                C.truncated_popcount_netlist(p, 2), C.popcount_netlist(n), p, n)
    nnz = max(tnn.out_nnz, 1)
    if nnz >= 3:
        out_nls = [C.truncated_popcount_netlist(nnz, 1)] * tnn.w2t.shape[1]
    xb = np.asarray(abc_binarize(ds.x_test, tnn.thresholds)).astype(np.uint8)
    ref = T.predict_with_circuits(tnn, xb, hidden_nls, out_nls)
    cc = lower_classifier(tnn, hidden_nls, out_nls)
    for backend in ("np", "jax"):
        prog = CircuitProgram.from_classifier(cc, backend=backend)
        assert (prog.predict_bits(xb) == ref).all(), backend
    # scores tap reproduces the argmax decision
    sc = CircuitProgram.from_classifier(cc, backend="np").scores(xb)
    assert (np.argmax(sc, axis=1) == ref).all()
