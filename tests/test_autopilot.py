"""The autopilot loop: shadow deployment, journaled decisions, resume.

  * **comparator** — mirrored completions pair with their primaries by
    uid regardless of completion order; ground truth may arrive before
    or after a pair closes; errors and drops are counted, not scored.
  * **fleet shadows** — mirrored traffic reaches the shadow replica and
    *only* the shadow: incumbent labels, fleet-level stats, and the
    fleet error log are bit-for-bit what they'd be without the shadow
    (the SLO-isolation acceptance criterion).
  * **decisions** — `decide` is a pure function of the journaled
    evidence: accuracy-primary when ground truth exists, agreement
    fallback otherwise, and a broken candidate (label bits flipped)
    rolls back with the incumbent untouched.
  * **end-to-end + resume** — a scripted bad→good candidate sequence
    rolls back then promotes (generation flips atomically, in-flight
    requests keep their labels), and a controller SIGKILLed after
    journaling its verdict resumes to the same decision it would have
    made uninterrupted.
"""
import json
import os
import signal
import subprocess
import sys
import textwrap
from pathlib import Path

import numpy as np
import pytest

from repro.autopilot import (Autopilot, AutopilotConfig, Candidate,
                             DecisionJournal, JournalCorruptError,
                             PromotionPolicy, ScriptedSource, decide,
                             sabotage_classifier)
from repro.compile import CircuitProgram, load_manifest_doc, load_program
from repro.compile.verilog import write_artifacts
from repro.core import tnn as T
from repro.serve import ClassifierFleet, TenantSpec
from repro.serve.shadow import ShadowComparator

SRC = str(Path(__file__).resolve().parents[1] / "src")


def _toy_classifier(F=9, H=5, Cc=4, seed=7):
    from repro.compile import lower_classifier
    rng = np.random.default_rng(seed)
    w1t = rng.integers(-1, 2, size=(F, H)).astype(np.int8)
    w2t = T.balance_zero_counts(rng.normal(size=(H, Cc)), 1 / 3)
    tnn = T.TrainedTNN(w1t=w1t, w2t=w2t, thresholds=np.full(F, 0.5),
                       train_acc=0.0, test_acc=0.0, name=f"toy{seed}")
    return lower_classifier(tnn, *T.exact_netlists(tnn))


@pytest.fixture
def emit_dir(tmp_path):
    write_artifacts(_toy_classifier(seed=7), tmp_path, base="alpha",
                    provenance={"seed": 7, "objectives": [0.25, 1.0]})
    return tmp_path


def _fleet(emit_dir, **kw):
    kw.setdefault("backends", "np")
    return ClassifierFleet.from_emit_dir(emit_dir, **kw)


class _Req:
    def __init__(self, uid, label=None, latency_ms=None, error=None):
        self.uid = uid
        self.label = label
        self.latency_ms = latency_ms
        self.error = error


# ---------------------------------------------------------------------------
# Journal
# ---------------------------------------------------------------------------
def test_journal_roundtrip_and_seq_survives_reopen(tmp_path):
    j = DecisionJournal(tmp_path / "j.jsonl")
    j.append("candidate", round=0, name="a")
    j.append("verdict", round=0, summary={"n_pairs": 3})
    j2 = DecisionJournal(tmp_path / "j.jsonl")      # reopen: replay + resume
    events = j2.replay()
    assert [e["event"] for e in events] == ["candidate", "verdict"]
    assert [e["seq"] for e in events] == [1, 2]
    assert j2.append("decision", round=0, action="hold")["seq"] == 3
    assert set(j2.rounds()) == {0}


def test_journal_tolerates_torn_tail_but_not_mid_corruption(tmp_path):
    path = tmp_path / "j.jsonl"
    j = DecisionJournal(path)
    j.append("candidate", round=0, name="a")
    j.append("verdict", round=0, summary={})
    with open(path, "a") as f:
        f.write('{"seq": 3, "event": "decis')        # crash mid-append
    assert [e["event"] for e in DecisionJournal(path).replay()] == \
        ["candidate", "verdict"]
    lines = path.read_text().splitlines()
    lines[0] = "garbage{{{"
    path.write_text("\n".join(lines) + "\n")
    with pytest.raises(JournalCorruptError):
        DecisionJournal(path)


# ---------------------------------------------------------------------------
# Comparator
# ---------------------------------------------------------------------------
def test_comparator_pairs_out_of_order_and_scores_truth():
    comp = ShadowComparator("inc", "sh")
    comp.expect(10)
    comp.expect(11)
    # shadow completes before its primary (mirror can win the race)
    comp.observe_shadow(10, _Req(100, label=2, latency_ms=1.5))
    comp.observe_primary(_Req(10, label=2, latency_ms=1.0))
    # truth attached before the pair closes
    comp.attach_truth(11, 3)
    comp.observe_primary(_Req(11, label=3, latency_ms=1.0))
    comp.observe_shadow(11, _Req(101, label=1, latency_ms=2.0))
    s = comp.summary()
    assert s["n_pairs"] == 2 and s["n_agree"] == 1
    assert s["agreement"] == 0.5
    assert s["n_truth"] == 1
    assert s["incumbent_accuracy"] == 1.0 and s["shadow_accuracy"] == 0.0


def test_comparator_truth_after_close_and_drop_error_accounting():
    comp = ShadowComparator("inc", "sh")
    comp.expect(5)
    comp.observe_primary(_Req(5, label=1, latency_ms=1.0))
    comp.observe_shadow(5, _Req(50, label=1, latency_ms=1.0))
    comp.attach_truth(5, 1)                  # truth loses the race: late
    assert comp.summary()["n_truth"] == 1
    assert comp.summary()["shadow_accuracy"] == 1.0
    comp.record_dropped(3)
    comp.expect(6)
    comp.observe_primary(_Req(6, label=1, latency_ms=1.0))
    comp.observe_shadow(6, _Req(60, error="boom"))
    s = comp.summary()
    assert s["n_dropped"] == 3
    assert s["n_shadow_errors"] == 1
    assert s["n_pairs"] == 1                 # errored pair is not scored


# ---------------------------------------------------------------------------
# decide(): the promotion policy as a pure function
# ---------------------------------------------------------------------------
def _summary(**kw):
    base = {"n_pairs": 100, "n_agree": 100, "agreement": 1.0,
            "n_shadow_errors": 0, "n_truth": 0,
            "incumbent_accuracy": None, "shadow_accuracy": None,
            "incumbent_p50_ms": 1.0, "shadow_p50_ms": 1.0}
    return {**base, **kw}


def test_decide_policy_matrix():
    pol = PromotionPolicy(min_pairs=64, min_agreement=0.98, min_truth=32)
    assert decide(_summary(), pol)[0] == "promote"
    assert decide(_summary(n_pairs=10), pol)[0] == "hold"
    assert decide(_summary(n_shadow_errors=2), pol)[0] == "rollback"
    assert decide(_summary(agreement=0.5), pol)[0] == "rollback"
    # accuracy is primary over agreement: an improved candidate disagrees
    better = _summary(agreement=0.7, n_truth=50,
                      incumbent_accuracy=0.80, shadow_accuracy=0.90)
    assert decide(better, pol)[0] == "promote"
    worse = _summary(agreement=0.99, n_truth=50,
                     incumbent_accuracy=0.90, shadow_accuracy=0.80)
    assert decide(worse, pol)[0] == "rollback"
    slow = _summary(shadow_p50_ms=9.0)
    assert decide(slow, PromotionPolicy(min_pairs=64,
                                        max_latency_factor=4.0))[0] == \
        "rollback"
    assert decide(slow, pol)[0] == "promote"     # latency guard off by default


# ---------------------------------------------------------------------------
# Fleet shadows: mirroring, isolation, lifecycle
# ---------------------------------------------------------------------------
def _shadow_spec(cc, name="alpha!shadow", **kw):
    kw.setdefault("backend", "np")
    return TenantSpec(name=name, program=CircuitProgram.from_classifier(
        cc, backend=kw["backend"]), **kw)


def test_shadow_mirrors_without_touching_incumbent_accounting(emit_dir):
    cc = _toy_classifier(seed=7)
    ref = CircuitProgram.from_classifier(cc).predict
    rng = np.random.default_rng(0)
    X = rng.random((48, 9))
    with _fleet(emit_dir) as fleet:
        # baseline labels with no shadow present
        want = ref(X)
        comp = fleet.deploy_shadow(_shadow_spec(cc), "alpha")
        reqs, shed, _ = fleet.submit_many("alpha", X)
        assert not len(shed)
        for r, y in zip(reqs, want):
            comp.attach_truth(r.uid, int(y))
        fleet.flush()
        got = np.array([r.result(5.0) for r in reqs])
        # in-flight + mirrored traffic: labels are exactly the no-shadow ones
        np.testing.assert_array_equal(got, want)
        s = comp.summary()
        assert s["n_pairs"] == 48 and s["agreement"] == 1.0
        assert s["n_truth"] == 48
        assert s["incumbent_accuracy"] == 1.0 == s["shadow_accuracy"]
        # fleet-level accounting never saw the mirrors
        stats = fleet.stats_summary()
        assert stats["fleet"]["n_requests"] == 48
        assert stats["fleet"]["n_readings"] == 48
        assert stats["tenants"]["alpha"]["n_requests"] == 48
        assert fleet.errors == []
        # identity satellites: sha256 + manifest generation + shadow block
        doc = load_manifest_doc(emit_dir)
        row = {t["name"]: t for t in doc["tenants"]}["alpha"]
        assert stats["tenants"]["alpha"]["sha256"] == row["sha256"]
        assert stats["manifest_generation"] == doc["generation"]
        assert stats["tenants"]["alpha"]["shadow"]["n_pairs"] == 48
        assert stats["tenants"]["alpha"]["shadow"]["name"] == "alpha!shadow"


def test_sabotaged_shadow_disagrees_totally_and_errors_stay_out(emit_dir):
    cc = _toy_classifier(seed=7)
    bad = sabotage_classifier(cc)
    rng = np.random.default_rng(1)
    X = rng.random((40, 9))
    with _fleet(emit_dir) as fleet:
        comp = fleet.deploy_shadow(_shadow_spec(bad), "alpha")
        reqs, _, _ = fleet.submit_many("alpha", X)
        fleet.flush()
        ref = CircuitProgram.from_classifier(cc).predict(X)
        np.testing.assert_array_equal([r.result(5.0) for r in reqs], ref)
        s = comp.summary()
        assert s["n_pairs"] == 40 and s["agreement"] == 0.0
        assert fleet.errors == []
        action, reason = decide(s, PromotionPolicy(min_pairs=16))
        assert action == "rollback"


def test_shadow_queue_cap_drops_mirrors_never_backpressures(emit_dir):
    cc = _toy_classifier(seed=7)
    with _fleet(emit_dir) as fleet:
        comp = fleet.deploy_shadow(
            _shadow_spec(cc, max_queue=4), "alpha")
        X = np.random.default_rng(2).random((32, 9))
        reqs, shed, _ = fleet.submit_many("alpha", X)
        assert len(reqs) == 32 and not len(shed)    # incumbent admits all
        fleet.flush()
        s = comp.summary()
        assert s["n_mirrored"] + s["n_dropped"] == 32
        assert s["n_dropped"] >= 28                 # queue held at most 4
        assert s["n_pairs"] == s["n_mirrored"]


def test_shadow_lifecycle_guards_and_retire(emit_dir):
    cc = _toy_classifier(seed=7)
    with _fleet(emit_dir) as fleet:
        fleet.deploy_shadow(_shadow_spec(cc), "alpha")
        with pytest.raises(ValueError, match="already has a shadow"):
            fleet.deploy_shadow(_shadow_spec(cc, name="other"), "alpha")
        with pytest.raises(KeyError):
            fleet.deploy_shadow(_shadow_spec(cc, name="x"), "missing")
        final = fleet.retire_shadow("alpha")
        assert final["n_pairs"] == 0
        with pytest.raises(KeyError):
            fleet.shadow_comparator("alpha")
        # after retirement, submits stop mirroring entirely
        reqs, _, _ = fleet.submit_many(
            "alpha", np.random.default_rng(3).random((8, 9)))
        fleet.flush()
        assert all(r.result(5.0) is not None for r in reqs)
        # feature-count mismatch is refused up front
        wrong = _toy_classifier(F=6, seed=11)
        with pytest.raises(ValueError, match="features"):
            fleet.deploy_shadow(_shadow_spec(wrong, name="w"), "alpha")


# ---------------------------------------------------------------------------
# End-to-end controller: bad candidate rolls back, good one promotes
# ---------------------------------------------------------------------------
def _pilot(fleet, emit_dir, candidates, journal=None, **cfg_kw):
    cc = _toy_classifier(seed=7)
    ref = CircuitProgram.from_classifier(cc).predict
    rng = np.random.default_rng(42)

    def traffic():
        while True:
            X = rng.random((16, 9))
            yield X, ref(X)          # incumbent's own labels as ground truth

    cfg_kw.setdefault("policy", PromotionPolicy(min_pairs=32, min_truth=16))
    cfg = AutopilotConfig(tenant="alpha", rounds=len(candidates),
                          mirror_pairs=48, verdict_timeout_s=60.0, **cfg_kw)
    journal = journal or DecisionJournal(emit_dir / "journal.jsonl")
    return Autopilot(fleet, ScriptedSource(candidates), traffic(),
                     journal, cfg), journal


def test_autopilot_rolls_back_bad_then_promotes_good(emit_dir):
    cc = _toy_classifier(seed=7)
    gen0 = load_manifest_doc(emit_dir)["generation"]
    candidates = [
        Candidate(cc=sabotage_classifier(cc), objectives=[0.2, 1.0],
                  provenance={"round": 0, "sabotaged": True}),
        Candidate(cc=cc, objectives=[0.2, 1.0], provenance={"round": 1}),
    ]
    with _fleet(emit_dir) as fleet:
        pilot, journal = _pilot(fleet, emit_dir, candidates)
        outcomes = pilot.run()
        assert [o["event"] for o in outcomes] == ["rolled_back", "promoted"]
        doc = load_manifest_doc(emit_dir)
        # promotion flipped the generation atomically and the fleet followed
        assert doc["generation"] > gen0
        assert outcomes[1]["generation"] == doc["generation"]
        row = {t["name"]: t for t in doc["tenants"]}["alpha"]
        assert row["sha256"] == outcomes[1]["sha256"]
        assert row["provenance"]["round"] == 1
        t = fleet._tenant("alpha")
        assert t.spec.generation == doc["generation"]
        assert t.spec.sha256 == row["sha256"]
        assert "alpha" not in fleet._shadows         # both rounds cleaned up
        assert fleet.errors == []
        # the staged candidates live in their own provenance-stamped manifest
        cand_doc = load_manifest_doc(emit_dir / "candidates")
        names = {t["name"] for t in cand_doc["tenants"]}
        assert names == {"alpha__cand_r0", "alpha__cand_r1"}
        # the rolled-back candidate's provenance records the sabotage
        r0 = {t["name"]: t for t in cand_doc["tenants"]}["alpha__cand_r0"]
        assert r0["provenance"]["sabotaged"] is True
        # decisions replay deterministically from the journaled evidence
        by_round = journal.rounds()
        for r, want in ((0, "rollback"), (1, "promote")):
            evs = {e["event"]: e for e in by_round[r]}
            action, _ = decide(evs["verdict"]["summary"],
                               pilot.cfg.policy)
            assert action == want == evs["decision"]["action"]
        # promoted program serves on: labels still bit-identical
        X = np.random.default_rng(9).random((8, 9))
        reqs, _, _ = fleet.submit_many("alpha", X)
        fleet.flush()
        np.testing.assert_array_equal(
            [r.result(5.0) for r in reqs],
            CircuitProgram.from_classifier(cc).predict(X))


def test_autopilot_sabotage_rounds_hook_and_no_candidate(emit_dir):
    cc = _toy_classifier(seed=7)
    candidates = [Candidate(cc=cc, objectives=[0.2, 1.0], provenance={}),
                  None]
    with _fleet(emit_dir) as fleet:
        pilot, _ = _pilot(fleet, emit_dir, candidates,
                          sabotage_rounds=frozenset({0}))
        outcomes = pilot.run()
    # the controller's own sabotage hook broke round 0's (good) candidate
    assert [o["event"] for o in outcomes] == ["rolled_back", "no_candidate"]


def test_autopilot_rerun_is_idempotent(emit_dir):
    cc = _toy_classifier(seed=7)
    candidates = [Candidate(cc=cc, objectives=[0.2, 1.0], provenance={})]
    with _fleet(emit_dir) as fleet:
        pilot, journal = _pilot(fleet, emit_dir, candidates)
        first = pilot.run()
        gen = load_manifest_doc(emit_dir)["generation"]
        again = pilot.run()                  # every round already terminal
        assert again == first
        assert load_manifest_doc(emit_dir)["generation"] == gen


# ---------------------------------------------------------------------------
# SIGKILL resume: the journaled verdict governs the post-crash decision
# ---------------------------------------------------------------------------
_DRIVER = textwrap.dedent("""\
    import json, sys
    import numpy as np
    from pathlib import Path

    from repro.autopilot import (Autopilot, AutopilotConfig, Candidate,
                                 DecisionJournal, PromotionPolicy,
                                 ScriptedSource, sabotage_classifier)
    from repro.compile import CircuitProgram
    from repro.compile.verilog import write_artifacts
    from repro.core import tnn as T
    from repro.serve import ClassifierFleet

    def toy(seed=7):
        from repro.compile import lower_classifier
        rng = np.random.default_rng(seed)
        w1t = rng.integers(-1, 2, size=(9, 5)).astype(np.int8)
        w2t = T.balance_zero_counts(rng.normal(size=(5, 4)), 1 / 3)
        tnn = T.TrainedTNN(w1t=w1t, w2t=w2t, thresholds=np.full(9, 0.5),
                           train_acc=0.0, test_acc=0.0, name="toy7")
        return lower_classifier(tnn, *T.exact_netlists(tnn))

    emit_dir = Path(sys.argv[1])
    kill_after = None
    if len(sys.argv) > 2 and sys.argv[2] != "-":
        stage, rnd = sys.argv[2].split(":")
        kill_after = (stage, int(rnd))

    cc = toy()
    if not (emit_dir / "fleet.json").exists():
        write_artifacts(cc, emit_dir, base="alpha")
    ref = CircuitProgram.from_classifier(cc).predict
    rng = np.random.default_rng(42)

    def traffic():
        while True:
            X = rng.random((16, 9))
            yield X, ref(X)

    candidates = [
        Candidate(cc=sabotage_classifier(cc), objectives=[0.2, 1.0],
                  provenance={"round": 0}),
        Candidate(cc=cc, objectives=[0.2, 1.0], provenance={"round": 1}),
    ]
    cfg = AutopilotConfig(
        tenant="alpha", rounds=2, mirror_pairs=48,
        policy=PromotionPolicy(min_pairs=32, min_truth=16),
        kill_after=kill_after)
    fleet = ClassifierFleet.from_emit_dir(emit_dir, backends="np")
    try:
        pilot = Autopilot(fleet, ScriptedSource(candidates), traffic(),
                          DecisionJournal(emit_dir / "journal.jsonl"), cfg)
        outcomes = pilot.run()
        print(json.dumps([(o["round"], o["event"]) for o in outcomes]))
    finally:
        fleet.shutdown(drain=False)
""")


def _run_driver(tmp_path, emit_dir, kill_after="-", timeout=180):
    driver = tmp_path / "driver.py"
    driver.write_text(_DRIVER)
    env = dict(os.environ, PYTHONPATH=SRC + os.pathsep
               + os.environ.get("PYTHONPATH", ""))
    return subprocess.run(
        [sys.executable, str(driver), str(emit_dir), kill_after],
        cwd=str(tmp_path), env=env, capture_output=True, text=True,
        timeout=timeout)


def test_sigkilled_controller_resumes_to_same_decision(tmp_path):
    killed = tmp_path / "killed"
    control = tmp_path / "control"
    # control run: never interrupted
    r = _run_driver(tmp_path, control)
    assert r.returncode == 0, r.stderr
    want = json.loads(r.stdout.strip().splitlines()[-1])
    # interrupted run: SIGKILL right after round 0's verdict is journaled
    r1 = _run_driver(tmp_path, killed, kill_after="verdict:0")
    assert r1.returncode == -signal.SIGKILL
    journal = DecisionJournal(killed / "journal.jsonl")
    evs = {e["event"] for e in journal.rounds()[0]}
    assert "verdict" in evs and "decision" not in evs   # died mid-rollout
    # resume: the journaled evidence must yield the identical decisions
    r2 = _run_driver(tmp_path, killed)
    assert r2.returncode == 0, r2.stderr
    got = json.loads(r2.stdout.strip().splitlines()[-1])
    assert got == want == [[0, "rolled_back"], [1, "promoted"]]
    # and the decision was *recomputed from the journal*, not re-measured:
    # exactly one verdict row exists for round 0
    verdicts = [e for e in DecisionJournal(killed / "journal.jsonl")
                .rounds()[0] if e["event"] == "verdict"]
    assert len(verdicts) == 1


def test_sigkill_between_decision_and_execution_still_promotes(tmp_path):
    emit = tmp_path / "emit"
    r1 = _run_driver(tmp_path, emit, kill_after="decision:1")
    assert r1.returncode == -signal.SIGKILL
    journal = DecisionJournal(emit / "journal.jsonl")
    evs = {e["event"]: e for e in journal.rounds()[1]}
    assert evs["decision"]["action"] == "promote"
    assert "promoted" not in evs
    gen_before = load_manifest_doc(emit)["generation"]
    r2 = _run_driver(tmp_path, emit)
    assert r2.returncode == 0, r2.stderr
    assert json.loads(r2.stdout.strip().splitlines()[-1]) == \
        [[0, "rolled_back"], [1, "promoted"]]
    doc = load_manifest_doc(emit)
    assert doc["generation"] > gen_before       # the journaled promotion ran
    row = {t["name"]: t for t in doc["tenants"]}["alpha"]
    cand = {e["event"]: e for e in
            DecisionJournal(emit / "journal.jsonl").rounds()[1]}["candidate"]
    assert row["sha256"] == cand["sha256"]
    bundle = load_program(emit / cand["program"],
                          expect_sha256=cand["sha256"])
    assert bundle.n_classes == 4
