"""Additional hypothesis property tests on system invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.circuits import (compose_pcc, eval_vectors, pc_error,
                                 popcount_netlist, popcount_width,
                                 truncated_popcount_netlist)
from repro.models import attention as ATT
from repro.models.moe import capacity, moe_ffn
from repro.roofline.analysis import parse_collectives, _shape_bytes


# ---------------------------------------------------------------------------
# Circuits
# ---------------------------------------------------------------------------
@settings(max_examples=15, deadline=None)
@given(st.integers(2, 10), st.integers(1, 8))
def test_truncation_error_bounded_by_drop(n, drop):
    """|error| of a truncated popcount never exceeds the dropped bits."""
    drop = min(drop, n - 1)
    nl = truncated_popcount_netlist(n, drop)
    packed, true = eval_vectors(n)
    _, wce = pc_error(nl, packed, true)
    assert wce <= drop


@settings(max_examples=10, deadline=None)
@given(st.integers(1, 6), st.integers(1, 6))
def test_pcc_is_monotone_in_pos_count(npos, nneg):
    """PCC output must be monotone: adding a positive input never flips
    the comparator from 1 to 0 (checked over the full domain)."""
    pcc = compose_pcc(popcount_netlist(npos), popcount_netlist(nneg),
                      npos, nneg)
    from repro.core.circuits import exhaustive_vectors
    vecs = exhaustive_vectors(npos + nneg)
    out = pcc.eval_uint(vecs)
    S = 1 << (npos + nneg)
    idx = np.arange(S)
    for bit in range(npos):    # flipping a pos bit 0->1 can't lower output
        without = idx[(idx >> bit) & 1 == 0]
        with_ = without | (1 << bit)
        assert (out[with_] >= out[without]).all()


def test_popcount_width_consistency():
    for n in range(1, 70):
        m = popcount_width(n)
        assert (1 << m) > n >= (1 << (m - 1)) - 1 or n == 1


# ---------------------------------------------------------------------------
# Attention
# ---------------------------------------------------------------------------
@settings(max_examples=10, deadline=None)
@given(st.integers(1, 3), st.sampled_from([8, 16, 24]),
       st.sampled_from([4, 8, 64]), st.booleans())
def test_blockwise_attention_matches_naive(b, s, block_k, causal):
    """Online-softmax attention == naive softmax attention, any block size."""
    r = np.random.default_rng(s * block_k + causal)
    H, K, dh = 4, 2, 8
    q = jnp.asarray(r.normal(0, 1, (b, s, H, dh)), jnp.float32)
    k = jnp.asarray(r.normal(0, 1, (b, s, K, dh)), jnp.float32)
    v = jnp.asarray(r.normal(0, 1, (b, s, K, dh)), jnp.float32)
    got = ATT.blockwise_attention(q, k, v, causal=causal, block_k=block_k)
    # naive reference
    kr = jnp.repeat(k, H // K, axis=2)
    vr = jnp.repeat(v, H // K, axis=2)
    sc = jnp.einsum("bqhd,bkhd->bhqk", q, kr) / np.sqrt(dh)
    if causal:
        mask = np.tril(np.ones((s, s), bool))
        sc = jnp.where(mask[None, None], sc, -1e30)
    want = jnp.einsum("bhqk,bkhd->bqhd", jax.nn.softmax(sc, -1), vr)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


def test_rolling_mask_semantics():
    m = np.asarray(ATT.rolling_mask(jnp.int32(2), 4))
    assert m.tolist() == [True, True, True, False]   # slots 0..2 written
    m2 = np.asarray(ATT.rolling_mask(jnp.int32(9), 4))
    assert m2.tolist() == [True, True, True, True]   # wrapped: all valid


# ---------------------------------------------------------------------------
# MoE dispatch
# ---------------------------------------------------------------------------
def _moe_params(rng, D, F, E):
    return {"router": {"w": jnp.asarray(rng.normal(0, .5, (D, E)), jnp.float32)},
            "experts": {
                "w_gate": jnp.asarray(rng.normal(0, .1, (E, D, F)), jnp.float32),
                "w_up": jnp.asarray(rng.normal(0, .1, (E, D, F)), jnp.float32),
                "w_down": jnp.asarray(rng.normal(0, .1, (E, F, D)), jnp.float32)}}


@settings(max_examples=8, deadline=None)
@given(st.integers(0, 100), st.sampled_from([1, 2]), st.sampled_from([1, 4]))
def test_moe_group_invariance(seed, k, G):
    """With no capacity drops, the group decomposition must not change the
    result (dispatch is a pure permutation)."""
    rng = np.random.default_rng(seed)
    B, S, D, F, E = 2, 8, 16, 32, 4
    p = _moe_params(rng, D, F, E)
    x = jnp.asarray(rng.normal(0, 1, (B, S, D)), jnp.float32)
    y1, _ = moe_ffn(p, x, n_experts=E, top_k=k, capacity_factor=8.0,
                    quant="dense", ctx=None, ep=False, n_groups=1)
    yG, _ = moe_ffn(p, x, n_experts=E, top_k=k, capacity_factor=8.0,
                    quant="dense", ctx=None, ep=False, n_groups=G)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(yG),
                               rtol=1e-4, atol=1e-5)


def test_moe_capacity_drops_zero_not_garbage():
    """Tokens dropped by capacity contribute exactly zero (overflow slot)."""
    rng = np.random.default_rng(0)
    B, S, D, F, E = 1, 16, 8, 16, 2
    p = _moe_params(rng, D, F, E)
    # skew router so expert 0 overflows any capacity: positive inputs x
    # a large positive column make logit_0 dominate for every token
    p["router"]["w"] = p["router"]["w"].at[:, 0].set(10.0)
    x = jnp.asarray(np.abs(rng.normal(0, 1, (B, S, D))) + 0.1, jnp.float32)
    y, _ = moe_ffn(p, x, n_experts=E, top_k=1, capacity_factor=0.1,
                   quant="dense", ctx=None, ep=False, n_groups=1)
    assert bool(jnp.isfinite(y).all())
    # capacity 8 (floor): at most 8 tokens got outputs; rest exactly 0
    nonzero_rows = int((jnp.abs(y[0]).sum(-1) > 1e-9).sum())
    assert nonzero_rows <= capacity(S, E, 1, 0.1)


# ---------------------------------------------------------------------------
# Roofline HLO parser
# ---------------------------------------------------------------------------
def test_collective_parser_on_synthetic_hlo():
    hlo = """
  %ag = bf16[8,128]{1,0} all-gather(bf16[1,128]{1,0} %p), replica_groups=...
  %ar = f32[64]{0} all-reduce(f32[64]{0} %x), to_apply=%sum
  %rs = f32[4,16]{1,0} reduce-scatter(f32[4,256]{1,0} %y), dimensions={1}
  %cp = u8[100]{0} collective-permute(u8[100]{0} %z)
  ROOT %t = tuple(...)
"""
    stats = parse_collectives(hlo)
    assert stats.bytes_by_kind["all-gather"] == 8 * 128 * 2
    assert stats.bytes_by_kind["all-reduce"] == 64 * 4
    assert stats.bytes_by_kind["reduce-scatter"] == 4 * 16 * 4
    assert stats.bytes_by_kind["collective-permute"] == 100
    assert stats.total_bytes == sum(stats.bytes_by_kind.values())


def test_shape_bytes_dtypes():
    assert _shape_bytes("bf16", "2,3") == 12
    assert _shape_bytes("f32", "") == 4          # scalar
    assert _shape_bytes("s8", "1024") == 1024
    assert _shape_bytes("unknown", "8") == 0
