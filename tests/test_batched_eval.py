"""Population-parallel evaluator: bit-exact equivalence with the serial
`Netlist` path (numpy + JAX backends), and trajectory equivalence of the
batched CGP loop."""
import numpy as np
import pytest

from repro.core.circuits import (
    Netlist,
    NetlistPopulation,
    eval_vectors,
    exhaustive_vectors,
    pack_vectors,
    popcount_netlist,
    popcount_width,
    truncated_popcount_netlist,
)
from repro.hw.egfet import Gate

_FUNCS = np.array([Gate.AND, Gate.OR, Gate.XOR, Gate.NAND, Gate.NOR,
                   Gate.XNOR, Gate.NOT, Gate.BUF, Gate.ANDN, Gate.ORN,
                   Gate.CONST0, Gate.CONST1])


def _random_netlists(rng, P, n_in, n_gates, n_out):
    nls = []
    for _ in range(P):
        op = _FUNCS[rng.integers(len(_FUNCS), size=n_gates)].astype(np.int16)
        in0 = np.array([rng.integers(n_in + g) for g in range(n_gates)], np.int32)
        in1 = np.array([rng.integers(n_in + g) for g in range(n_gates)], np.int32)
        outs = rng.integers(n_in + n_gates, size=n_out).astype(np.int32)
        nl = Netlist(n_in, op, in0, in1, outs)
        nl.validate()
        nls.append(nl)
    return nls


@pytest.mark.parametrize("n_in,n_gates", [(4, 12), (7, 40), (10, 25)])
def test_population_matches_serial_exhaustive(n_in, n_gates):
    rng = np.random.default_rng(n_in * 100 + n_gates)
    nls = _random_netlists(rng, 19, n_in, n_gates, 3)
    pop = NetlistPopulation.from_netlists(nls)
    vecs = exhaustive_vectors(n_in)
    words = pop.simulate(vecs)
    ints = pop.eval_uint(vecs)
    for p, nl in enumerate(nls):
        assert (words[p] == nl.simulate(vecs)).all()
        assert (ints[p] == nl.eval_uint(vecs)).all()


def test_population_padding_and_cost_match_serial():
    n = 9
    nls = [popcount_netlist(n)] + [truncated_popcount_netlist(n, d)
                                   for d in range(1, n - 1)]
    pop = NetlistPopulation.from_netlists(nls)   # heterogeneous gate counts
    packed, true = eval_vectors(n)
    ints = pop.eval_uint(packed)
    areas = pop.areas()
    masks = pop.active_masks()
    for p, nl in enumerate(nls):
        assert (ints[p] == nl.eval_uint(packed)).all()
        assert areas[p] == nl.cost().area_mm2
        assert (masks[p, :nl.n_gates] == nl.active_mask()).all()
        assert not masks[p, nl.n_gates:].any()          # padding stays dead
    mae, wcae = pop.pc_errors(packed, true)
    assert mae[0] == 0.0 and wcae[0] == 0.0


def test_population_per_individual_inputs():
    rng = np.random.default_rng(5)
    nls = _random_netlists(rng, 6, 5, 20, 2)
    pop = NetlistPopulation.from_netlists(nls)
    per_ind = np.stack([exhaustive_vectors(5)] * 6)
    shared = pop.eval_uint(exhaustive_vectors(5))
    assert (pop.eval_uint(per_ind) == shared).all()


def test_pack_vectors_batched_leading_axis():
    rng = np.random.default_rng(0)
    v = (rng.random((3, 130, 7)) < 0.5).astype(np.uint8)
    packed = pack_vectors(v)
    assert packed.shape == (3, 7, 3)
    for i in range(3):
        assert (packed[i] == pack_vectors(v[i])).all()


def test_jax_circuit_sim_matches_numpy():
    from repro.kernels import circuit_sim as CS
    rng = np.random.default_rng(11)
    nls = _random_netlists(rng, 9, 6, 30, 3)
    pop = NetlistPopulation.from_netlists(nls)
    packed, true = eval_vectors(6)
    ref = pop.eval_uint(packed)
    w32 = CS.pack_words32(packed)
    got = np.asarray(CS.population_eval_uint(
        pop.op.astype(np.int32), pop.in0, pop.in1, pop.outputs, w32,
        pop.n_inputs))
    assert (got == ref).all()
    mae, wcae = CS.population_pc_errors(
        pop.op.astype(np.int32), pop.in0, pop.in1, pop.outputs, w32,
        true.astype(np.int32), pop.n_inputs)
    mref, wref = pop.pc_errors(packed, true)
    np.testing.assert_allclose(np.asarray(mae), mref, rtol=1e-6)
    np.testing.assert_array_equal(np.asarray(wcae), wref)


@pytest.mark.parametrize("n,tau,metric", [(8, 0.5, "mae"), (6, 2.0, "wcae")])
def test_evolve_popcount_batched_equals_serial(n, tau, metric):
    """Seeded batched evolution reproduces the serial trajectory exactly."""
    from repro.core.cgp import CGPConfig, evolve_popcount

    def run(batch):
        cfg = CGPConfig(n_inputs=n, n_outputs=popcount_width(n), n_nodes=45,
                        tau=tau, error_metric=metric, max_iters=250, seed=13,
                        lam=16, batch_eval=batch)
        return evolve_popcount(cfg)

    a, b = run(True), run(False)
    assert a.best_area == b.best_area
    assert a.best_error == b.best_error
    assert a.evaluations == b.evaluations
    assert a.history == b.history
    assert (a.best.op == b.best.op).all()


def test_nsga2_dedup_eval_identical_and_cheaper():
    from repro.core.nsga2 import NSGA2Config, nsga2

    calls = {"dedup": 0, "plain": 0}

    def make_obj(tag):
        def obj(X):
            calls[tag] += X.shape[0]
            f0 = (X ** 2).sum(axis=1).astype(np.float64)
            f1 = ((X - 3) ** 2).sum(axis=1).astype(np.float64)
            return np.stack([f0, f1], axis=1)
        return obj

    domains = np.full(4, 5, dtype=np.int64)
    r1 = nsga2(domains, make_obj("dedup"), NSGA2Config(
        pop_size=12, n_generations=10, seed=2, dedup_eval=True))
    r2 = nsga2(domains, make_obj("plain"), NSGA2Config(
        pop_size=12, n_generations=10, seed=2, dedup_eval=False))
    np.testing.assert_array_equal(r1.pareto_x, r2.pareto_x)
    np.testing.assert_array_equal(r1.pareto_f, r2.pareto_f)
    assert calls["dedup"] < calls["plain"]
