"""Content-addressed phase cache + the zoo batch compiler.

The corruption tests pin the loud-rebuild contract: a truncated or
bit-flipped cache entry must raise/warn and recompute, never silently
serve stale Phase-1/2 products.  The zoo tests pin incremental rebuild
semantics: fingerprint-matched entries with verifying bundles are
skipped, anything stale or corrupt is rebuilt, `force` rebuilds all.
"""
import json
from pathlib import Path

import numpy as np
import pytest

from repro.evolve import phase_cache as PC
from repro.evolve.problems import build_tnn_problem, clear_phase_memo

# smallest budgets that still exercise the full pipeline
TINY = dict(seed=0, epochs=2, cgp_points=1, cgp_iters=25, pcc_samples=400)
DATASET = "breast_cancer"


def _tiny_key() -> str:
    return PC.phase_key(DATASET, TINY["seed"], TINY["epochs"],
                        TINY["cgp_points"], TINY["cgp_iters"],
                        TINY["pcc_samples"])


@pytest.fixture(scope="module")
def warm_cache(tmp_path_factory):
    """One real pipeline run, persisted to a module-lifetime cache dir."""
    root = tmp_path_factory.mktemp("phase_cache")
    clear_phase_memo()
    build_tnn_problem(DATASET, cache_dir=str(root), **TINY)
    return root


# ---------------------------------------------------------------------------
# phase cache: keying, roundtrip, corruption
# ---------------------------------------------------------------------------
def test_phase_key_sensitive_to_every_input():
    base = _tiny_key()
    for delta in ({"seed": 1}, {"epochs": 3}, {"cgp_points": 2},
                  {"cgp_iters": 26}, {"pcc_samples": 401}):
        kw = {**TINY, **delta}
        other = PC.phase_key(DATASET, kw["seed"], kw["epochs"],
                             kw["cgp_points"], kw["cgp_iters"],
                             kw["pcc_samples"])
        assert other != base, f"key ignored {delta}"
    assert PC.phase_key("cardio", **TINY) != base


def test_cache_dir_env_off_disables(monkeypatch):
    monkeypatch.setenv("REPRO_PHASE_CACHE", "off")
    assert PC.default_cache_dir() is None
    monkeypatch.setenv("REPRO_PHASE_CACHE", "/some/dir")
    assert PC.default_cache_dir() == Path("/some/dir")


def test_roundtrip_identity(warm_cache):
    """load_phase returns bit-identical products to what save_phase took."""
    tnn, pc_libs, pcc_lib, pc_out = PC.load_phase(warm_cache, _tiny_key())
    clear_phase_memo()
    tnn2, pc_libs2, pcc2, pc_out2 = PC.load_phase(warm_cache, _tiny_key())
    np.testing.assert_array_equal(tnn.w1t, tnn2.w1t)
    np.testing.assert_array_equal(tnn.w2t, tnn2.w2t)
    np.testing.assert_array_equal(tnn.thresholds, tnn2.thresholds)
    assert tnn.test_acc == tnn2.test_acc and tnn.name == tnn2.name
    assert sorted(pc_libs) == sorted(pc_libs2)
    for n in pc_libs:
        for a, b in zip(pc_libs[n], pc_libs2[n]):
            np.testing.assert_array_equal(a.op, b.op)
            np.testing.assert_array_equal(a.outputs, b.outputs)
            assert a.n_inputs == b.n_inputs and a.meta == b.meta
    assert sorted(pcc_lib.entries) == sorted(pcc2.entries)
    for size in pcc_lib.entries:
        for a, b in zip(pcc_lib.entries[size], pcc2.entries[size]):
            assert (a.est_area, a.mde, a.wcde) == (b.est_area, b.mde, b.wcde)
            np.testing.assert_array_equal(a.pc_pos.op, b.pc_pos.op)
    assert len(pc_out) == len(pc_out2)


def test_load_missing_entry_is_filenotfound(tmp_path):
    with pytest.raises(FileNotFoundError, match="no phase-cache entry"):
        PC.load_phase(tmp_path, "0" * 64)


def test_truncated_entry_is_loud(warm_cache, tmp_path):
    import shutil
    root = tmp_path / "c"
    shutil.copytree(warm_cache, root)
    path = PC.entry_path(root, _tiny_key())
    path.write_bytes(path.read_bytes()[: path.stat().st_size // 2])
    with pytest.raises(PC.PhaseCacheCorruptError, match="checksum"):
        PC.load_phase(root, _tiny_key())


def test_bitflipped_entry_is_loud(warm_cache, tmp_path):
    import shutil
    root = tmp_path / "c"
    shutil.copytree(warm_cache, root)
    path = PC.entry_path(root, _tiny_key())
    blob = bytearray(path.read_bytes())
    blob[len(blob) // 2] ^= 0xFF
    path.write_bytes(bytes(blob))
    with pytest.raises(PC.PhaseCacheCorruptError, match="checksum"):
        PC.load_phase(root, _tiny_key())


def test_missing_sidecar_is_loud(warm_cache, tmp_path):
    import shutil
    root = tmp_path / "c"
    shutil.copytree(warm_cache, root)
    path = PC.entry_path(root, _tiny_key())
    path.with_name(path.name + ".sha256").unlink()
    with pytest.raises(PC.PhaseCacheCorruptError, match="sidecar"):
        PC.load_phase(root, _tiny_key())


def test_corrupt_entry_warns_and_rebuilds(warm_cache, tmp_path):
    """The consumer path: build_tnn_problem on a corrupt entry warns,
    recomputes, and leaves a *valid* rewritten entry behind."""
    import shutil
    root = tmp_path / "c"
    shutil.copytree(warm_cache, root)
    path = PC.entry_path(root, _tiny_key())
    path.write_bytes(b"garbage")
    clear_phase_memo()
    with pytest.warns(RuntimeWarning, match="checksum"):
        build_tnn_problem(DATASET, cache_dir=str(root), **TINY)
    # rebuilt entry must load cleanly now
    PC.load_phase(root, _tiny_key())


def test_in_process_memo_shares_products(warm_cache):
    """Two build calls in one process reuse the very same trained TNN."""
    clear_phase_memo()
    a = build_tnn_problem(DATASET, cache_dir=str(warm_cache), **TINY)
    b = build_tnn_problem(DATASET, cache_dir=str(warm_cache), **TINY)
    assert a.tnn is b.tnn                    # memo hit, not a retrain
    assert a.approx is not b.approx          # Phase-3 wrapper stays per-call


# ---------------------------------------------------------------------------
# zoo batch compiler
# ---------------------------------------------------------------------------
ZOO_BUDGETS = dict(islands=2, pop=8, epochs=1, gens_per_epoch=2,
                   migrate_k=1, tnn_epochs=2, cgp_points=1, cgp_iters=25,
                   pcc_samples=400)


def _entries(variants=("base", "lean")):
    from repro.compile.zoo import make_entries
    return make_entries([DATASET], list(variants), **ZOO_BUDGETS)


def test_zoo_build_skip_corrupt_force(tmp_path, warm_cache):
    from repro.compile import artifact as A
    from repro.compile.zoo import build_zoo

    emit = tmp_path / "zoo"
    entries = _entries()
    rep = build_zoo(entries, emit, cache_dir=str(warm_cache))
    assert len(rep["built"]) == 2 and rep["cached"] == []
    rows = {r["name"]: r for r in A.load_manifest(emit)}
    assert sorted(rows) == sorted(e.name for e in entries)
    for row in rows.values():
        bundle = emit / row["program"]
        assert bundle.exists()
        assert bundle.with_name(bundle.name + ".sha256").exists()
        assert row["provenance"]["zoo_fingerprint"]
        A.verify_program_bundle(bundle, expect_sha256=row["sha256"])

    # identical re-run: pure skip
    rep = build_zoo(entries, emit, cache_dir=str(warm_cache))
    assert rep["built"] == [] and len(rep["cached"]) == 2

    # corrupt one bundle -> only that entry rebuilds
    victim = rows[entries[0].name]
    bundle = emit / victim["program"]
    bundle.write_bytes(b"garbage")
    rep = build_zoo(entries, emit, cache_dir=str(warm_cache))
    assert rep["built"] == [entries[0].name]
    A.verify_program_bundle(emit / victim["program"])

    # stale fingerprint (changed recipe) -> rebuild that entry
    import dataclasses
    changed = [dataclasses.replace(_entries(("base",))[0], seed=1)]
    rep = build_zoo(changed, emit, cache_dir=str(warm_cache))
    assert rep["built"] == [changed[0].name]

    # force rebuilds everything
    rep = build_zoo(entries, emit, cache_dir=str(warm_cache), force=True)
    assert len(rep["built"]) == 2 and rep["cached"] == []


def test_zoo_manifest_serves(tmp_path, warm_cache):
    """A zoo emit dir is a loadable fleet: every bundle rebuilds a program
    that classifies the right feature width."""
    from repro.compile import artifact as A
    from repro.compile.zoo import build_zoo

    emit = tmp_path / "zoo"
    build_zoo(_entries(("base",)), emit, cache_dir=str(warm_cache))
    (row,) = A.load_manifest(emit)
    prog = A.load_program(emit / row["program"], backend="np",
                          expect_sha256=row["sha256"])
    labels = prog.predict_bits(
        np.zeros((4, row["n_features"]), dtype=np.uint8))
    assert labels.shape == (4,)


def test_zoo_duplicate_names_rejected(tmp_path):
    from repro.compile.zoo import build_zoo
    entries = _entries(("base",)) * 2
    with pytest.raises(ValueError, match="duplicate zoo entry"):
        build_zoo(entries, tmp_path / "zoo")


def test_zoo_unknown_variant_rejected():
    from repro.compile.zoo import make_entries
    with pytest.raises(ValueError, match="unknown variant"):
        make_entries([DATASET], ["nope"], **ZOO_BUDGETS)


def test_zoo_report_written_by_cli(tmp_path, warm_cache):
    from repro.compile import zoo as Z

    out = tmp_path / "report.json"
    Z.main(["--datasets", DATASET, "--variants", "base",
            "--emit-dir", str(tmp_path / "zoo"),
            "--phase-cache", str(warm_cache),
            "--islands", "2", "--pop", "8", "--epochs", "1",
            "--gens-per-epoch", "2", "--migrate-k", "1",
            "--tnn-epochs", "2", "--cgp-points", "1", "--cgp-iters", "25",
            "--pcc-samples", "400", "--out", str(out)])
    rep = json.loads(out.read_text())
    assert rep["entries"] == 1 and rep["built"] == [f"tnn_{DATASET}__base"]


def test_zoo_cli_rejects_unknown_dataset(tmp_path):
    from repro.compile import zoo as Z
    with pytest.raises(SystemExit, match="unknown dataset"):
        Z.main(["--datasets", "nope", "--emit-dir", str(tmp_path)])
